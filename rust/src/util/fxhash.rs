//! In-tree FxHash (rustc-hash substitute — DESIGN.md "Offline
//! substitutions"): the multiply-rotate hash rustc uses for its interned
//! maps. Not cryptographic; chosen for single-digit-ns hashing of the
//! short fixed-width keys the engine's grid cache produces.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

/// The Firefox/rustc "Fx" multiply constant.
const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// Multiply-rotate hasher over 64-bit lanes.
#[derive(Debug, Clone, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            let mut buf = [0u8; 8];
            buf.copy_from_slice(c);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u16(&mut self, i: u16) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }
}

/// `BuildHasher` for [`FxHasher`].
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// A `HashMap` keyed with [`FxHasher`].
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;
    use std::hash::{BuildHasher, Hash};

    fn hash_of<T: Hash>(v: &T) -> u64 {
        let mut h = FxHasher::default();
        v.hash(&mut h);
        h.finish()
    }

    #[test]
    fn deterministic_and_discriminating() {
        assert_eq!(hash_of(&[1u32, 2, 3]), hash_of(&[1u32, 2, 3]));
        assert_ne!(hash_of(&[1u32, 2, 3]), hash_of(&[1u32, 3, 2]));
        assert_ne!(hash_of(&0u64), hash_of(&1u64));
    }

    #[test]
    fn build_hasher_starts_fresh() {
        let b = FxBuildHasher::default();
        let mut h1 = b.build_hasher();
        let mut h2 = b.build_hasher();
        h1.write_u64(42);
        h2.write_u64(42);
        assert_eq!(h1.finish(), h2.finish());
    }

    #[test]
    fn map_roundtrip() {
        let mut m: FxHashMap<[u32; 4], f64> = FxHashMap::default();
        for i in 0..1000u32 {
            m.insert([i, i + 1, i + 2, i + 3], i as f64);
        }
        assert_eq!(m.len(), 1000);
        assert_eq!(m[&[7u32, 8, 9, 10]], 7.0);
    }

    #[test]
    fn byte_writes_cover_remainders() {
        let mut h = FxHasher::default();
        h.write(&[1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11]); // 8 + 3 tail
        let a = h.finish();
        let mut h = FxHasher::default();
        h.write(&[1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 12]);
        assert_ne!(a, h.finish());
    }
}
