//! Tiny property-testing helper (proptest substitute): a deterministic
//! xorshift generator plus a `forall` runner that reports the failing
//! case and its seed index.

/// Deterministic xorshift64* PRNG.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f64 in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform u32 in [lo, hi].
    pub fn u32(&mut self, lo: u32, hi: u32) -> u32 {
        lo + (self.next_u64() % (hi - lo + 1) as u64) as u32
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

/// Run `prop` on `n` generated cases; panic with the case index and the
/// debug form of the failing value.
pub fn forall<T: std::fmt::Debug>(
    seed: u64,
    n: u32,
    mut generate: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let mut rng = Rng::new(seed);
    for i in 0..n {
        let case = generate(&mut rng);
        assert!(prop(&case), "property failed at case {i} (seed {seed}): {case:?}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn ranges_respected() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.range(3.0, 5.0);
            assert!((3.0..5.0).contains(&x));
            let u = r.u32(2, 9);
            assert!((2..=9).contains(&u));
        }
    }

    #[test]
    fn forall_passes_good_property() {
        forall(1, 200, |r| r.range(0.0, 10.0), |x| *x >= 0.0 && *x < 10.0);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(1, 50, |r| r.f64(), |x| *x < 0.5);
    }

    #[test]
    fn chance_is_roughly_calibrated() {
        let mut r = Rng::new(99);
        let hits = (0..10_000).filter(|_| r.chance(0.3)).count();
        assert!((2700..3300).contains(&hits), "{hits}");
    }
}
