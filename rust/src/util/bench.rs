//! Mini benchmark harness (criterion substitute): warmup, repeated
//! timed runs, mean/min/max reporting. Benches under `rust/benches/`
//! use `harness = false` and drive this directly.

use std::time::Instant;

/// Timing statistics over the measured runs.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: u32,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    /// Median of the measured runs (nearest rank).
    pub p50_ns: f64,
    /// 99th percentile of the measured runs (nearest rank; equals the
    /// max below 100 iterations).
    pub p99_ns: f64,
}

impl Stats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Nearest-rank percentile of an ascending-sorted sample vector.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
    sorted[idx]
}

/// Measure `f` with `warmup` unmeasured runs then `iters` measured runs.
pub fn measure<F: FnMut()>(warmup: u32, iters: u32, mut f: F) -> Stats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    let sum: f64 = samples.iter().sum();
    let mut sorted = samples.clone();
    sorted.sort_by(f64::total_cmp);
    Stats {
        iters,
        mean_ns: sum / iters as f64,
        min_ns: sorted[0],
        max_ns: sorted[sorted.len() - 1],
        p50_ns: percentile(&sorted, 0.5),
        p99_ns: percentile(&sorted, 0.99),
    }
}

/// Measure and print one line in a stable, grep-able format.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, f: F) -> Stats {
    let s = measure(warmup, iters, f);
    println!(
        "bench {name:<40} mean {:>12.3} ms   min {:>12.3} ms   max {:>12.3} ms   ({} iters)",
        s.mean_ns / 1e6,
        s.min_ns / 1e6,
        s.max_ns / 1e6,
        s.iters
    );
    s
}

/// Print a section header for a bench binary (one per paper artifact).
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_positive_time() {
        let s = measure(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(s.iters, 5);
        assert!(s.mean_ns > 0.0);
        assert!(s.min_ns <= s.mean_ns && s.mean_ns <= s.max_ns);
        assert!(s.min_ns <= s.p50_ns && s.p50_ns <= s.p99_ns && s.p99_ns <= s.max_ns);
    }

    #[test]
    fn percentile_nearest_rank() {
        let sorted: Vec<f64> = (1..=100).map(f64::from).collect();
        assert_eq!(percentile(&sorted, 0.0), 1.0);
        assert_eq!(percentile(&sorted, 1.0), 100.0);
        assert_eq!(percentile(&sorted, 0.5), 51.0); // round(99*0.5)=50 -> 51.0
        assert_eq!(percentile(&sorted, 0.99), 99.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[7.0], 0.999), 7.0);
    }

    #[test]
    #[should_panic]
    fn zero_iters_rejected() {
        measure(0, 0, || {});
    }
}
