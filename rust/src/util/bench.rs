//! Mini benchmark harness (criterion substitute): warmup, repeated
//! timed runs, mean/min/max reporting. Benches under `rust/benches/`
//! use `harness = false` and drive this directly.

use std::time::Instant;

/// Timing statistics over the measured runs.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: u32,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Stats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Measure `f` with `warmup` unmeasured runs then `iters` measured runs.
pub fn measure<F: FnMut()>(warmup: u32, iters: u32, mut f: F) -> Stats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    let sum: f64 = samples.iter().sum();
    Stats {
        iters,
        mean_ns: sum / iters as f64,
        min_ns: samples.iter().copied().fold(f64::INFINITY, f64::min),
        max_ns: samples.iter().copied().fold(0.0, f64::max),
    }
}

/// Measure and print one line in a stable, grep-able format.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, f: F) -> Stats {
    let s = measure(warmup, iters, f);
    println!(
        "bench {name:<40} mean {:>12.3} ms   min {:>12.3} ms   max {:>12.3} ms   ({} iters)",
        s.mean_ns / 1e6,
        s.min_ns / 1e6,
        s.max_ns / 1e6,
        s.iters
    );
    s
}

/// Print a section header for a bench binary (one per paper artifact).
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_positive_time() {
        let s = measure(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(s.iters, 5);
        assert!(s.mean_ns > 0.0);
        assert!(s.min_ns <= s.mean_ns && s.mean_ns <= s.max_ns);
    }

    #[test]
    #[should_panic]
    fn zero_iters_rejected() {
        measure(0, 0, || {});
    }
}
