//! Small in-tree utilities standing in for crates absent from the
//! offline vendor set (criterion, proptest, rand, rustc-hash) —
//! DESIGN.md "Offline substitutions".

pub mod bench;
pub mod dheap;
pub mod fxhash;
pub mod prop;
