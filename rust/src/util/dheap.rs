//! 4-ary min-heap — tried as the simulator's event queue and
//! **reverted** (EXPERIMENTS.md §Perf iteration 3): on 16-byte packed
//! events std's hole-based `BinaryHeap` sift beat this swap-based
//! implementation by ~1.5×. Kept as a tested utility and an honest
//! record of the experiment.

/// A d=4 min-heap. `T: Ord` with the *smallest* element at the root.
#[derive(Debug, Clone, Default)]
pub struct MinHeap4<T> {
    data: Vec<T>,
}

impl<T: Ord> MinHeap4<T> {
    pub fn new() -> Self {
        MinHeap4 { data: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        MinHeap4 { data: Vec::with_capacity(n) }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn peek(&self) -> Option<&T> {
        self.data.first()
    }

    pub fn push(&mut self, item: T) {
        self.data.push(item);
        self.sift_up(self.data.len() - 1);
    }

    pub fn pop(&mut self) -> Option<T> {
        let n = self.data.len();
        if n == 0 {
            return None;
        }
        self.data.swap(0, n - 1);
        let out = self.data.pop();
        if !self.data.is_empty() {
            self.sift_down(0);
        }
        out
    }

    #[inline]
    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 4;
            if self.data[i] < self.data[parent] {
                self.data.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    #[inline]
    fn sift_down(&mut self, mut i: usize) {
        let n = self.data.len();
        loop {
            let first_child = 4 * i + 1;
            if first_child >= n {
                break;
            }
            let last_child = (first_child + 4).min(n);
            // Smallest of up to four children.
            let mut best = first_child;
            for c in first_child + 1..last_child {
                if self.data[c] < self.data[best] {
                    best = c;
                }
            }
            if self.data[best] < self.data[i] {
                self.data.swap(i, best);
                i = best;
            } else {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Rng;

    #[test]
    fn pops_in_sorted_order() {
        let mut h = MinHeap4::new();
        let mut rng = Rng::new(5);
        let mut vals: Vec<u64> = (0..2000).map(|_| rng.next_u64() % 10_000).collect();
        for &v in &vals {
            h.push(v);
        }
        vals.sort();
        let mut out = Vec::new();
        while let Some(v) = h.pop() {
            out.push(v);
        }
        assert_eq!(out, vals);
    }

    #[test]
    fn peek_matches_pop() {
        let mut h = MinHeap4::new();
        for v in [5u32, 1, 9, 3] {
            h.push(v);
        }
        assert_eq!(h.peek(), Some(&1));
        assert_eq!(h.pop(), Some(1));
        assert_eq!(h.peek(), Some(&3));
        assert_eq!(h.len(), 3);
    }

    #[test]
    fn empty_behaviour() {
        let mut h: MinHeap4<u32> = MinHeap4::with_capacity(8);
        assert!(h.is_empty());
        assert_eq!(h.pop(), None);
        assert_eq!(h.peek(), None);
    }

    #[test]
    fn interleaved_push_pop_stays_ordered() {
        let mut h = MinHeap4::new();
        let mut rng = Rng::new(6);
        let mut last = 0u64;
        for round in 0..50 {
            for _ in 0..40 {
                // Monotone-ish inserts like simulator event times.
                h.push(last + rng.next_u64() % 100 + round);
            }
            let mut prev = 0;
            for _ in 0..30 {
                let v = h.pop().unwrap();
                assert!(v >= prev);
                prev = v;
            }
            last = prev;
        }
    }
}
