//! Baseline predictors the paper's related-work section argues against.
//! They consume exactly the same one-shot profile as the paper's model,
//! so the ablation bench (`ablation_baselines`) is a like-for-like
//! comparison of the *frequency-scaling* part of the models.

use crate::model::{self, HwParams, KernelCounters};

/// A time predictor under frequency scaling.
///
/// `Send + Sync` so any predictor can run behind the engine facade
/// (`engine::PredictorBackend` adapts a boxed `Predictor` into an
/// `engine::Backend`, giving every baseline the shared grid cache and
/// the streaming/batching paths for free); the reverse adapter
/// `engine::EnginePredictor` exposes an engine wherever a
/// `&dyn Predictor` is still accepted.
pub trait Predictor: Send + Sync {
    fn name(&self) -> &'static str;
    /// Predicted execution time in microseconds at (core_mhz, mem_mhz).
    fn predict_us(&self, c: &KernelCounters, core_mhz: f64, mem_mhz: f64) -> f64;
}

/// The paper's model (§V), as the `Predictor` trait object.
pub struct PaperModel {
    pub hw: HwParams,
}

impl Predictor for PaperModel {
    fn name(&self) -> &'static str {
        "paper"
    }
    fn predict_us(&self, c: &KernelCounters, core_mhz: f64, mem_mhz: f64) -> f64 {
        model::predict(c, &self.hw, core_mhz, mem_mhz).time_us
    }
}

/// Constant-latency baseline: prior pipeline models that treat memory
/// latency/delay as frequency-independent constants measured at the
/// baseline (§IV: "memory latency is usually set as a constant
/// parameter"). Everything is core cycles, so predicted time only
/// scales with the core clock.
pub struct ConstLatency {
    pub hw: HwParams,
    pub baseline_core_mhz: f64,
    pub baseline_mem_mhz: f64,
}

impl Predictor for ConstLatency {
    fn name(&self) -> &'static str {
        "const-latency"
    }
    fn predict_us(&self, c: &KernelCounters, core_mhz: f64, _mem_mhz: f64) -> f64 {
        let p = model::predict(c, &self.hw, self.baseline_core_mhz, self.baseline_mem_mhz);
        // Cycle count frozen at baseline; only the clock period changes.
        p.t_exec_cycles / core_mhz
    }
}

/// Linear-frequency baseline: time splits into a core-scaled and a
/// memory-scaled share, weighted by the baseline compute/memory balance
/// — the "simple speedup" heuristic DVFS controllers use.
pub struct LinearFreq {
    pub hw: HwParams,
    pub baseline_core_mhz: f64,
    pub baseline_mem_mhz: f64,
}

impl LinearFreq {
    /// Fraction of baseline time attributed to core-clocked work.
    fn core_fraction(&self, c: &KernelCounters) -> f64 {
        let a = model::amat(c, &self.hw, self.baseline_core_mhz, self.baseline_mem_mhz);
        let avr_comp = self.hw.inst_cycle * c.avr_inst;
        let mem = a.agl_del * c.gld_trans;
        let smem = if c.uses_smem { self.hw.sh_lat * c.i_itrs / c.o_itrs.max(1.0) } else { 0.0 };
        let core = avr_comp + smem;
        core / (core + mem)
    }
}

impl Predictor for LinearFreq {
    fn name(&self) -> &'static str {
        "linear-freq"
    }
    fn predict_us(&self, c: &KernelCounters, core_mhz: f64, mem_mhz: f64) -> f64 {
        let base =
            model::predict(c, &self.hw, self.baseline_core_mhz, self.baseline_mem_mhz).time_us;
        let alpha = self.core_fraction(c);
        base * (alpha * self.baseline_core_mhz / core_mhz
            + (1.0 - alpha) * self.baseline_mem_mhz / mem_mhz)
    }
}

/// L1-extended model: the paper's §VII future work, implemented.
///
/// The published model routes every global transaction through
/// L2/DRAM; kernels using the texture/L1 path are flagged by the paper
/// itself as a known error source. The extension applies one more AMAT
/// level: a fraction `l1_hr` of transactions is served at `l1_lat`
/// core cycles *inside the SM* — they neither pay `agl_lat` nor occupy
/// the L2/MC queues, so both AMAT terms shrink:
///
/// ```text
/// agl_lat' = l1_hr * l1_lat  + (1 - l1_hr) * agl_lat
/// agl_del' = l1_hr * lsu_del + (1 - l1_hr) * agl_del
/// ```
///
/// With `l1_hr = 0` this reduces exactly to the published model
/// (asserted by a test), so it is a strict extension.
pub struct L1Extended {
    pub hw: HwParams,
    /// Texture/L1 hit latency, core cycles (micro-benchmarked).
    pub l1_lat: f64,
    /// Service cost of an L1 hit (LSU issue), core cycles.
    pub lsu_del: f64,
}

impl L1Extended {
    pub fn new(hw: HwParams, l1_lat: f64) -> Self {
        L1Extended { hw, l1_lat, lsu_del: 1.0 }
    }
}

impl Predictor for L1Extended {
    fn name(&self) -> &'static str {
        "paper+l1"
    }
    fn predict_us(&self, c: &KernelCounters, core_mhz: f64, mem_mhz: f64) -> f64 {
        let a = model::amat(c, &self.hw, core_mhz, mem_mhz);
        let a = model::Amat {
            dm_lat: a.dm_lat,
            agl_lat: c.l1_hr * self.l1_lat + (1.0 - c.l1_hr) * a.agl_lat,
            agl_del: c.l1_hr * self.lsu_del + (1.0 - c.l1_hr) * a.agl_del,
        };
        model::predict_with_amat(c, &self.hw, a, core_mhz, mem_mhz).time_us
    }
}

/// MWP/CWP-lite: a simplified Hong–Kim [10] occupancy model. Memory
/// warp parallelism caps how much latency overlaps; whichever of
/// compute and memory dominates sets the period. No queueing, no L2
/// split — the structure the paper's §III says is insufficient under
/// DVFS.
pub struct MwpCwpLite {
    pub hw: HwParams,
}

impl Predictor for MwpCwpLite {
    fn name(&self) -> &'static str {
        "mwp-cwp-lite"
    }
    fn predict_us(&self, c: &KernelCounters, core_mhz: f64, mem_mhz: f64) -> f64 {
        let a = model::amat(c, &self.hw, core_mhz, mem_mhz);
        let avr_comp = self.hw.inst_cycle * c.avr_inst;
        // Memory warp parallelism: how many warps' requests fit in one
        // latency window at the sustained service rate.
        let mwp = (a.agl_lat / (a.agl_del * c.gld_trans).max(1e-9)).max(1.0).min(c.aw);
        let cwp = ((avr_comp + a.agl_lat) / avr_comp.max(1e-9)).min(c.aw);
        let per_iter = if mwp >= cwp {
            // Compute exposed.
            avr_comp * c.aw + a.agl_lat / c.aw.max(1.0)
        } else {
            // Memory exposed.
            (c.aw / mwp) * a.agl_lat
        };
        let rounds = (c.wpb * c.n_blocks / (c.aw * c.n_sm)).max(1.0);
        per_iter * c.o_itrs * rounds / core_mhz
    }
}

/// All baselines at the standard configuration.
pub fn standard_baselines(hw: HwParams) -> Vec<Box<dyn Predictor>> {
    vec![
        Box::new(PaperModel { hw }),
        Box::new(ConstLatency { hw, baseline_core_mhz: 700.0, baseline_mem_mhz: 700.0 }),
        Box::new(LinearFreq { hw, baseline_core_mhz: 700.0, baseline_mem_mhz: 700.0 }),
        Box::new(MwpCwpLite { hw }),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counters_membound() -> KernelCounters {
        KernelCounters {
            l2_hr: 0.0,
            gld_trans: 12.0,
            avr_inst: 0.4,
            n_blocks: 256.0,
            wpb: 8.0,
            aw: 64.0,
            n_sm: 16.0,
            o_itrs: 8.0,
            i_itrs: 0.0,
            uses_smem: false,
            smem_conflict: 1.0,
            gld_body: 4.0,
            gld_edge: 0.0,
            mem_ops: 1.0,
            l1_hr: 0.0,
        }
    }

    #[test]
    fn const_latency_ignores_memory_frequency() {
        let b = ConstLatency {
            hw: HwParams::paper_defaults(),
            baseline_core_mhz: 700.0,
            baseline_mem_mhz: 700.0,
        };
        let c = counters_membound();
        assert_eq!(b.predict_us(&c, 700.0, 400.0), b.predict_us(&c, 700.0, 1000.0));
        // And scales exactly inversely with core frequency.
        let r = b.predict_us(&c, 400.0, 700.0) / b.predict_us(&c, 1000.0, 700.0);
        assert!((r - 2.5).abs() < 1e-9);
    }

    #[test]
    fn const_latency_underestimates_membound_slowdown() {
        // Drop memory clock on a memory-bound kernel: the paper model
        // predicts a big slowdown, const-latency predicts none.
        let hw = HwParams::paper_defaults();
        let paper = PaperModel { hw };
        let cl = ConstLatency { hw, baseline_core_mhz: 700.0, baseline_mem_mhz: 700.0 };
        let c = counters_membound();
        let paper_ratio = paper.predict_us(&c, 700.0, 400.0) / paper.predict_us(&c, 700.0, 700.0);
        let cl_ratio = cl.predict_us(&c, 700.0, 400.0) / cl.predict_us(&c, 700.0, 700.0);
        assert!(paper_ratio > 1.4, "{paper_ratio}");
        assert!((cl_ratio - 1.0).abs() < 1e-9);
    }

    #[test]
    fn linear_freq_interpolates() {
        let hw = HwParams::paper_defaults();
        let lf = LinearFreq { hw, baseline_core_mhz: 700.0, baseline_mem_mhz: 700.0 };
        let c = counters_membound();
        let at_base = lf.predict_us(&c, 700.0, 700.0);
        let paper = PaperModel { hw }.predict_us(&c, 700.0, 700.0);
        assert!((at_base - paper).abs() / paper < 1e-9); // exact at baseline
        assert!(lf.predict_us(&c, 700.0, 400.0) > at_base);
        assert!(lf.predict_us(&c, 700.0, 1000.0) < at_base);
    }

    #[test]
    fn mwp_cwp_produces_finite_positive() {
        let hw = HwParams::paper_defaults();
        let m = MwpCwpLite { hw };
        let c = counters_membound();
        for (cf, mf) in [(400.0, 400.0), (1000.0, 400.0), (400.0, 1000.0)] {
            let t = m.predict_us(&c, cf, mf);
            assert!(t.is_finite() && t > 0.0);
        }
    }

    #[test]
    fn four_standard_baselines() {
        let bs = standard_baselines(HwParams::paper_defaults());
        assert_eq!(bs.len(), 4);
        let names: Vec<_> = bs.iter().map(|b| b.name()).collect();
        assert!(names.contains(&"paper"));
        assert!(names.contains(&"const-latency"));
    }
}
