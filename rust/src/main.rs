//! `gpufreq` launcher: the L3 leader entrypoint.

use gpufreq::cli;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match cli::parse_args(&argv) {
        Ok(args) => match cli::run(args) {
            Ok(code) => code,
            Err(e) => {
                eprintln!("error: {e:#}");
                1
            }
        },
        Err(e) => {
            eprintln!("error: {e:#}\n\n{}", cli::USAGE);
            2
        }
    };
    std::process::exit(code);
}
