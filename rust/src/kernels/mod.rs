//! The paper's Table VI workloads as parametric trace generators.
//!
//! Each constructor builds a `sim::Kernel` whose per-warp program mimics
//! the access pattern and instruction mix of the corresponding CUDA SDK
//! 6.5 kernel (DESIGN.md §2 substitution table). Table VI lists eleven
//! applications; the paper's §VI says "12 kernels" — we add `reduction`
//! (discussed in §V-B of the paper) as the twelfth and note the
//! discrepancy here.
//!
//! The set deliberately spans the four execution patterns the paper
//! calls out: DRAM-intensive (VA, BS, TR, SP, convSp), L2-intensive
//! (MMG, FWT, CG), shared-memory-intensive (MMS, SC, SN, RD) and
//! computation-intensive (MMG, BS).

use crate::sim::isa::{Addressing, Kernel, Launch, MemPat, Op, Program};

/// Address regions, one per logical buffer, so kernels never alias.
mod region {
    pub const IN_A: u8 = 1;
    pub const IN_B: u8 = 2;
    pub const OUT_C: u8 = 3;
    pub const OUT_D: u8 = 4;
    pub const TABLE: u8 = 5;
}

/// vectorAdd (VA): pure streaming, one add per element.
/// `c[i] = a[i] + b[i]` over a grid-stride loop.
pub fn vector_add() -> Kernel {
    Kernel::new(
        "VA",
        Launch::new(256, 256),
        Program {
            prologue: vec![],
            body: vec![
                Op::Load(MemPat::new(4, Addressing::OwnLinear, region::IN_A)),
                Op::Load(MemPat::new(4, Addressing::OwnLinear, region::IN_B)),
                Op::Compute(4),
                Op::Store(MemPat::new(4, Addressing::OwnLinear, region::OUT_C)),
            ],
            o_itrs: 8,
            epilogue: vec![],
        },
    )
}

/// BlackScholes (BS): streaming with a fat arithmetic tail (CNDF etc.)
/// — still DRAM-sensitive on real hardware (paper Fig. 2).
pub fn black_scholes() -> Kernel {
    Kernel::new(
        "BS",
        Launch::new(256, 128),
        Program {
            prologue: vec![],
            body: vec![
                Op::Load(MemPat::new(4, Addressing::OwnLinear, region::IN_A)),
                Op::Load(MemPat::new(4, Addressing::OwnLinear, region::IN_B)),
                Op::Compute(48),
                Op::Store(MemPat::new(4, Addressing::OwnLinear, region::OUT_C)),
                Op::Store(MemPat::new(4, Addressing::OwnLinear, region::OUT_D)),
            ],
            o_itrs: 8,
            epilogue: vec![],
        },
    )
}

/// transpose (TR, coalesced shared-memory version): coalesced read,
/// staging tile in smem, coalesced write of the transposed tile.
/// Shared traffic is tiny → the paper's "smem-light" case (Eq. 17).
pub fn transpose() -> Kernel {
    let mut launch = Launch::new(256, 256);
    launch.smem_per_block = 33 * 32 * 4; // 32x32 tile + padding column
    Kernel::new(
        "TR",
        launch,
        Program {
            prologue: vec![],
            body: vec![
                Op::Load(MemPat::new(4, Addressing::OwnLinear, region::IN_A)),
                Op::SharedStore { conflict: 1 },
                Op::Sync,
                Op::SharedLoad { conflict: 1 },
                Op::Store(MemPat::new(4, Addressing::OwnStrided { stride: 97 }, region::OUT_C)),
            ],
            o_itrs: 4,
            epilogue: vec![],
        },
    )
}

/// matrixMul global-memory version (MMG): per iteration one A element
/// (block-broadcast) and one B element (walked identically by every
/// block → very high L2 hit rate, the paper reports 97.5%) plus the FMA
/// chain. Compute-leaning but sensitive to both clocks.
pub fn matrix_mul_global() -> Kernel {
    Kernel::new(
        "MMG",
        Launch::new(128, 128),
        Program {
            prologue: vec![],
            body: vec![
                Op::Load(MemPat::new(1, Addressing::BlockShared, region::IN_A)),
                Op::Load(MemPat::new(1, Addressing::GridShared, region::IN_B)),
                Op::Compute(6),
            ],
            o_itrs: 128,
            epilogue: vec![Op::Store(MemPat::new(4, Addressing::OwnLinear, region::OUT_C))],
        },
    )
}

/// matrixMul shared-memory version (MMS): the paper's worked example of
/// the smem-intensive case (Fig. 11 / Eqs. 18-21): tile loads, barrier,
/// a dozen-plus smem reads feeding FMAs, barrier, next tile.
pub fn matrix_mul_shared() -> Kernel {
    let mut launch = Launch::new(128, 256);
    launch.smem_per_block = 2 * 16 * 16 * 4; // As + Bs tiles
    let mut body = vec![
        // A tile: broadcast within the block (high L2 reuse). B tile:
        // column-dependent working set larger than L2 (~25% hit), which
        // is what gives MMS its residual memory-frequency sensitivity at
        // high core clocks (paper Fig. 2b).
        Op::Load(MemPat::new(4, Addressing::BlockShared, region::IN_A)),
        Op::Load(MemPat::new(4, Addressing::Random { lines: 262144 }, region::IN_B)),
        Op::Sync,
    ];
    for _ in 0..16 {
        body.push(Op::SharedLoad { conflict: 1 });
        body.push(Op::SharedLoad { conflict: 1 });
        body.push(Op::Compute(4));
    }
    body.push(Op::Sync);
    Kernel::new(
        "MMS",
        launch,
        Program {
            prologue: vec![],
            body,
            o_itrs: 8,
            epilogue: vec![Op::Store(MemPat::new(4, Addressing::OwnLinear, region::OUT_C))],
        },
    )
}

/// conjugateGradient (CG): SpMV-dominated — irregular gathers over a
/// matrix too big for L2 (≈50% hit) plus a hot x-vector.
pub fn conjugate_gradient() -> Kernel {
    Kernel::new(
        "CG",
        Launch::new(128, 128),
        Program {
            prologue: vec![],
            body: vec![
                Op::Load(MemPat::new(4, Addressing::Random { lines: 131072 }, region::IN_A)),
                Op::Load(MemPat::new(1, Addressing::Hot { lines: 4096 }, region::TABLE)),
                Op::Compute(10),
            ],
            o_itrs: 32,
            epilogue: vec![Op::Store(MemPat::new(4, Addressing::OwnLinear, region::OUT_C))],
        },
    )
}

/// fastWalshTransform (FWT): butterfly passes with strided
/// read-modify-write — the store hits the line the load just brought in,
/// so L2 sits near 50%.
pub fn fast_walsh() -> Kernel {
    Kernel::new(
        "FWT",
        Launch::new(128, 256),
        Program {
            prologue: vec![],
            body: vec![
                Op::Load(
                    MemPat::new(4, Addressing::OwnStrided { stride: 65 }, region::IN_A)
                        .with_alias(0),
                ),
                Op::Compute(6),
                // In-place butterfly: the store writes the lines the load
                // just brought in (same alias), so it hits L2.
                Op::Store(
                    MemPat::new(4, Addressing::OwnStrided { stride: 65 }, region::IN_A)
                        .with_alias(0),
                ),
            ],
            o_itrs: 8,
            epilogue: vec![],
        },
    )
}

/// scan (SC): work-efficient smem tree (up-sweep/down-sweep): one global
/// load in, log2(block) smem passes with 2-way conflicts, one store out.
pub fn scan() -> Kernel {
    let mut launch = Launch::new(128, 256);
    launch.smem_per_block = 2 * 256 * 4;
    Kernel::new(
        "SC",
        launch,
        Program {
            prologue: vec![
                Op::Load(MemPat::new(4, Addressing::OwnLinear, region::IN_A)),
                Op::SharedStore { conflict: 1 },
                Op::Sync,
            ],
            body: vec![
                Op::SharedLoad { conflict: 2 },
                Op::SharedStore { conflict: 2 },
                Op::Compute(2),
                Op::Sync,
            ],
            o_itrs: 8,
            epilogue: vec![
                Op::SharedLoad { conflict: 1 },
                Op::Store(MemPat::new(4, Addressing::OwnLinear, region::OUT_C)),
            ],
        },
    )
}

/// sortingNetworks (SN, bitonic sort): many smem compare-exchange
/// stages; almost no global traffic → strongly core-frequency bound.
pub fn sorting_networks() -> Kernel {
    let mut launch = Launch::new(128, 128);
    launch.smem_per_block = 2 * 128 * 4;
    Kernel::new(
        "SN",
        launch,
        Program {
            prologue: vec![
                Op::Load(MemPat::new(4, Addressing::OwnLinear, region::IN_A)),
                Op::SharedStore { conflict: 1 },
                Op::Sync,
            ],
            body: vec![
                Op::SharedLoad { conflict: 2 },
                Op::Compute(6),
                Op::SharedStore { conflict: 2 },
                Op::Sync,
            ],
            o_itrs: 28, // sum of bitonic stages for 2^7 elements
            epilogue: vec![Op::Store(MemPat::new(4, Addressing::OwnLinear, region::OUT_C))],
        },
    )
}

/// scalarProd (SP): dot products over streamed pairs with a short smem
/// reduction tail — memory-sensitive despite touching smem.
pub fn scalar_prod() -> Kernel {
    let mut launch = Launch::new(128, 256);
    launch.smem_per_block = 256 * 4;
    Kernel::new(
        "SP",
        launch,
        Program {
            prologue: vec![],
            body: vec![
                Op::Load(MemPat::new(4, Addressing::OwnLinear, region::IN_A)),
                Op::Load(MemPat::new(4, Addressing::OwnLinear, region::IN_B)),
                Op::Compute(4),
            ],
            o_itrs: 16,
            epilogue: vec![
                Op::SharedStore { conflict: 1 },
                Op::Sync,
                Op::SharedLoad { conflict: 1 },
                Op::Compute(2),
                Op::Store(MemPat::new(1, Addressing::OwnLinear, region::OUT_C)),
            ],
        },
    )
}

/// convolutionSeparable (convSp): halo load into smem, taps applied from
/// smem, coalesced store. Global traffic dominates (paper: high DRAM
/// transaction share, near-linear memory-frequency scaling).
pub fn convolution_separable() -> Kernel {
    let mut launch = Launch::new(256, 128);
    launch.smem_per_block = 8 * 1024;
    Kernel::new(
        "convSp",
        launch,
        Program {
            prologue: vec![],
            body: vec![
                Op::Load(MemPat::new(8, Addressing::OwnLinear, region::IN_A)),
                Op::SharedStore { conflict: 1 },
                Op::Sync,
                Op::SharedLoad { conflict: 1 },
                Op::Compute(8),
                Op::SharedLoad { conflict: 1 },
                Op::Compute(8),
                Op::SharedLoad { conflict: 1 },
                Op::Compute(8),
                Op::SharedLoad { conflict: 1 },
                Op::Compute(10),
                Op::Store(MemPat::new(8, Addressing::OwnLinear, region::OUT_C)),
            ],
            o_itrs: 2,
            epilogue: vec![],
        },
    )
}

/// reduction (RD): the twelfth kernel (paper §VI says 12; Table VI lists
/// 11 — see module docs). Global gather then an smem tree.
pub fn reduction() -> Kernel {
    let mut launch = Launch::new(256, 256);
    launch.smem_per_block = 256 * 4;
    Kernel::new(
        "RD",
        launch,
        Program {
            prologue: vec![
                Op::Load(MemPat::new(8, Addressing::OwnLinear, region::IN_A)),
                Op::Compute(4),
                Op::SharedStore { conflict: 1 },
                Op::Sync,
            ],
            body: vec![
                Op::SharedLoad { conflict: 2 },
                Op::Compute(2),
                Op::SharedStore { conflict: 2 },
                Op::Sync,
            ],
            o_itrs: 8, // log2(256)
            epilogue: vec![Op::Store(MemPat::new(1, Addressing::OwnLinear, region::OUT_C))],
        },
    )
}

/// texture-filtering kernel (TEX) — an *extension* kernel exercising
/// the texture/L1 path the paper's §VII lists as future work ("does
/// not take texture/L1 cache into account, which may introduce larger
/// error"). Not part of the 12-kernel validation suite; used by the
/// `ablation_l1` experiment to quantify exactly that error and the
/// L1-extended model that repairs it.
pub fn texture_filter() -> Kernel {
    Kernel::new(
        "TEX",
        Launch::new(128, 256),
        Program {
            prologue: vec![],
            body: vec![
                // Bilinear taps over a hot texture window: strong
                // temporal locality, absorbed by the per-SM L1.
                Op::Load(
                    MemPat::new(4, Addressing::Hot { lines: 512 }, region::TABLE).through_l1(),
                ),
                Op::Compute(6),
                Op::Store(MemPat::new(4, Addressing::OwnLinear, region::OUT_C)),
            ],
            o_itrs: 16,
            epilogue: vec![],
        },
    )
}

/// All twelve benchmark kernels, in the paper's Table VI order plus RD.
pub fn all() -> Vec<Kernel> {
    vec![
        black_scholes(),
        conjugate_gradient(),
        fast_walsh(),
        matrix_mul_global(),
        matrix_mul_shared(),
        scan(),
        sorting_networks(),
        scalar_prod(),
        transpose(),
        vector_add(),
        convolution_separable(),
        reduction(),
    ]
}

/// Look a kernel up by its Table VI abbreviation (plus the TEX
/// extension kernel).
pub fn by_name(name: &str) -> Option<Kernel> {
    if name == "TEX" {
        return Some(texture_filter());
    }
    all().into_iter().find(|k| k.name == name)
}

/// The six kernels of the paper's Fig. 2 motivation study.
pub fn fig2_set() -> Vec<Kernel> {
    ["TR", "BS", "VA", "convSp", "MMG", "MMS"]
        .iter()
        .map(|n| by_name(n).unwrap())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{engine::simulate, Clocks, GpuSpec};

    #[test]
    fn twelve_kernels_with_unique_names() {
        let ks = all();
        assert_eq!(ks.len(), 12);
        let mut names: Vec<_> = ks.iter().map(|k| k.name.clone()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 12);
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("MMS").is_some());
        assert!(by_name("nope").is_none());
        assert_eq!(fig2_set().len(), 6);
    }

    #[test]
    fn smem_flags_match_design() {
        let smem_kernels = ["TR", "MMS", "SC", "SN", "SP", "convSp", "RD"];
        for k in all() {
            let want = smem_kernels.contains(&k.name.as_str());
            assert_eq!(k.program.uses_smem(), want, "{}", k.name);
        }
    }

    #[test]
    fn all_kernels_simulate_to_completion() {
        let spec = GpuSpec::default();
        for k in all() {
            let r = simulate(&spec, Clocks::new(700.0, 700.0), &k);
            assert_eq!(r.stats.blocks_retired as u32, k.launch.blocks, "{}", k.name);
            assert!(r.stats.elapsed_ns > 0.0, "{}", k.name);
            assert!(r.stats.gl_txns > 0, "{}", k.name);
        }
    }

    #[test]
    fn mmg_has_high_l2_hit_rate() {
        let spec = GpuSpec::default();
        let r = simulate(&spec, Clocks::new(700.0, 700.0), &matrix_mul_global());
        assert!(r.stats.l2_hit_rate() > 0.8, "hit rate {}", r.stats.l2_hit_rate());
    }

    #[test]
    fn va_has_negligible_l2_hit_rate() {
        let spec = GpuSpec::default();
        let r = simulate(&spec, Clocks::new(700.0, 700.0), &vector_add());
        assert!(r.stats.l2_hit_rate() < 0.05, "hit rate {}", r.stats.l2_hit_rate());
    }

    #[test]
    fn fwt_rmw_hits_about_half() {
        let spec = GpuSpec::default();
        let r = simulate(&spec, Clocks::new(700.0, 700.0), &fast_walsh());
        let hr = r.stats.l2_hit_rate();
        assert!(hr > 0.3 && hr < 0.7, "hit rate {hr}");
    }

    #[test]
    fn memory_bound_kernels_scale_with_mem_freq() {
        let spec = GpuSpec::default();
        for k in [vector_add(), black_scholes()] {
            let slow = simulate(&spec, Clocks::new(1000.0, 400.0), &k);
            let fast = simulate(&spec, Clocks::new(1000.0, 1000.0), &k);
            let sp = slow.stats.elapsed_ns / fast.stats.elapsed_ns;
            assert!(sp > 1.8, "{}: speedup {sp}", k.name);
        }
    }

    #[test]
    fn core_bound_kernels_scale_with_core_freq() {
        let spec = GpuSpec::default();
        for k in [matrix_mul_shared(), sorting_networks()] {
            let slow = simulate(&spec, Clocks::new(400.0, 1000.0), &k);
            let fast = simulate(&spec, Clocks::new(1000.0, 1000.0), &k);
            let sp = slow.stats.elapsed_ns / fast.stats.elapsed_ns;
            assert!(sp > 1.8, "{}: speedup {sp}", k.name);
            let a = simulate(&spec, Clocks::new(1000.0, 400.0), &k);
            let memsp = a.stats.elapsed_ns / fast.stats.elapsed_ns;
            assert!(memsp < 1.5, "{}: mem sensitivity {memsp}", k.name);
        }
    }

    #[test]
    fn mms_sensitive_to_both_frequencies() {
        // Paper Fig. 2: at high core frequency MMS gains from memory
        // frequency; at low core frequency it barely does.
        let spec = GpuSpec::default();
        let k = matrix_mul_shared();
        let base = simulate(&spec, Clocks::new(1000.0, 1000.0), &k);
        let low_mem = simulate(&spec, Clocks::new(1000.0, 400.0), &k);
        let low_core = simulate(&spec, Clocks::new(400.0, 1000.0), &k);
        let mem_sens = low_mem.stats.elapsed_ns / base.stats.elapsed_ns;
        let core_sens = low_core.stats.elapsed_ns / base.stats.elapsed_ns;
        assert!(mem_sens > 1.1, "mem sensitivity {mem_sens}");
        assert!(core_sens > 1.5, "core sensitivity {core_sens}");
    }
}
