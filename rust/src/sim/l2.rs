//! Set-associative L2 cache model (tags only, true LRU).
//!
//! The L2 runs entirely in the **core** clock domain (paper Table I):
//! its port occupancy and hit latency are charged in core cycles by the
//! engine; this module only answers hit/miss and maintains replacement
//! state. Hit *rates* therefore emerge from kernel address streams
//! rather than being asserted, which is what lets the profiler measure
//! `l2_hr` the way Nsight does on silicon.

/// Sentinel for an empty way (line ids are < 2^41, far below this).
const EMPTY: u64 = u64::MAX;

/// Tags-only set-associative cache with true LRU replacement.
///
/// Storage is one flat `n_sets * ways` array ordered MRU→LRU per set;
/// hits rotate the prefix right with `copy_within` (no per-access
/// allocation or `Vec` shuffling — this is the simulator's hottest
/// data structure, see EXPERIMENTS.md §Perf).
pub struct L2Cache {
    tags: Vec<u64>,
    ways: usize,
    set_mask: u64,
    line_shift: u32,
}

impl L2Cache {
    /// Build a cache of `bytes` capacity, `ways` associativity and
    /// `line_bytes` lines. Capacity must be a power-of-two multiple of
    /// `ways * line_bytes`.
    pub fn new(bytes: u64, ways: u32, line_bytes: u32) -> Self {
        assert!(ways > 0 && line_bytes.is_power_of_two());
        let n_lines = bytes / line_bytes as u64;
        let n_sets = (n_lines / ways as u64).max(1);
        assert!(n_sets.is_power_of_two(), "set count must be a power of two");
        L2Cache {
            tags: vec![EMPTY; (n_sets as usize) * ways as usize],
            ways: ways as usize,
            set_mask: n_sets - 1,
            line_shift: line_bytes.trailing_zeros(),
        }
    }

    /// Access one address; returns true on hit. Misses allocate
    /// (write-allocate for both loads and stores, like Maxwell's L2).
    #[inline]
    pub fn access(&mut self, addr: u64) -> bool {
        let line = addr >> self.line_shift;
        let set = (line & self.set_mask) as usize;
        let base = set * self.ways;
        let ways = &mut self.tags[base..base + self.ways];
        // MRU fast path: no reordering needed.
        if ways[0] == line {
            return true;
        }
        match ways.iter().position(|&t| t == line) {
            Some(pos) => {
                // Rotate [0..=pos] right by one: line becomes MRU.
                ways.copy_within(0..pos, 1);
                ways[0] = line;
                true
            }
            None => {
                // Shift everything right (LRU falls off), insert at MRU.
                ways.copy_within(0..self.ways - 1, 1);
                ways[0] = line;
                false
            }
        }
    }

    /// Drop all cached lines (between kernel launches, optionally).
    pub fn flush(&mut self) {
        self.tags.fill(EMPTY);
    }

    pub fn n_sets(&self) -> usize {
        self.tags.len() / self.ways
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry() {
        let c = L2Cache::new(2 * 1024 * 1024, 16, 32);
        assert_eq!(c.n_sets(), 4096);
    }

    #[test]
    fn hit_after_miss() {
        let mut c = L2Cache::new(1024, 2, 32);
        assert!(!c.access(0));
        assert!(c.access(0));
        assert!(c.access(31)); // same line
        assert!(!c.access(32)); // next line
    }

    #[test]
    fn lru_eviction_order() {
        // 1024 B, 2-way, 32 B lines -> 16 sets. Lines 0, 16, 32 map to set 0.
        let mut c = L2Cache::new(1024, 2, 32);
        let line = |i: u64| i * 16 * 32; // same set, different tags
        assert!(!c.access(line(0)));
        assert!(!c.access(line(1)));
        assert!(c.access(line(0))); // 0 is now MRU, 1 is LRU
        assert!(!c.access(line(2))); // evicts 1
        assert!(c.access(line(0)));
        assert!(!c.access(line(1))); // 1 was evicted
    }

    #[test]
    fn streaming_never_hits() {
        let mut c = L2Cache::new(64 * 1024, 16, 32);
        let mut hits = 0;
        for i in 0..100_000u64 {
            if c.access(i * 32) {
                hits += 1;
            }
        }
        assert_eq!(hits, 0);
    }

    #[test]
    fn working_set_fits_all_hits_once_warm() {
        let mut c = L2Cache::new(2 * 1024 * 1024, 16, 32);
        let lines = 10_000u64; // 320 KB, fits
        for i in 0..lines {
            c.access(i * 32);
        }
        let mut hits = 0;
        for i in 0..lines {
            if c.access(i * 32) {
                hits += 1;
            }
        }
        assert_eq!(hits, lines);
    }

    #[test]
    fn working_set_exceeds_capacity_thrashes() {
        let mut c = L2Cache::new(64 * 1024, 16, 32); // 2048 lines
        let lines = 4096u64;
        for _ in 0..2 {
            for i in 0..lines {
                c.access(i * 32);
            }
        }
        // Sequential walk over 2x capacity with LRU: everything misses.
        let mut hits = 0;
        for i in 0..lines {
            if c.access(i * 32) {
                hits += 1;
            }
        }
        assert_eq!(hits, 0);
    }

    #[test]
    fn flush_clears() {
        let mut c = L2Cache::new(1024, 2, 32);
        c.access(0);
        c.flush();
        assert!(!c.access(0));
    }
}
