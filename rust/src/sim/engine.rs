//! The dual-clock discrete-event engine.
//!
//! Warps are jobs; SM ALU/LSU/SMEM ports, per-SM L2 slice ports and
//! per-SM memory-controller channels are FCFS resources with "free-at"
//! timestamps. A binary heap orders warp wake-ups in global time (ns),
//! so resource grants happen in arrival order — exactly the FCFS
//! queueing the paper models in §IV.

use std::collections::{BinaryHeap, VecDeque};

use super::dram::Channel;
use super::isa::{Kernel, MemPat, Op};
use super::l2::L2Cache;
use super::sm::{BlockState, SmState, WarpState};
use super::stats::{LatencySample, SimStats};
use super::{Clocks, GpuSpec};

/// A scheduled warp wake-up, packed into one `u128` so the event queue
/// compares with a single integer instruction:
/// bits 127..64 = time quantized to femtoseconds (room for ~5 h of
/// simulated time; the sub-fs rounding is 9 orders of magnitude below
/// one cycle), bits 63..32 = push sequence (FIFO tie-break), bits
/// 31..0 = warp id. Stored negated so `BinaryHeap` (a max-heap) pops
/// the earliest event.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct Ev(u128);

impl Ev {
    #[inline]
    fn new(t_ns: f64, seq: u32, warp: u32) -> Self {
        let t_fs = (t_ns * 1e6).round() as u64;
        Ev(!(((t_fs as u128) << 64) | ((seq as u128) << 32) | warp as u128))
    }

    #[inline]
    fn t_ns(self) -> f64 {
        ((!self.0 >> 64) as u64) as f64 / 1e6
    }

    #[inline]
    fn warp(self) -> u32 {
        (!self.0) as u32
    }
}

/// Result of one simulated kernel execution.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub stats: SimStats,
    /// `#Aw` from the occupancy calculation (what the profiler reports).
    pub active_warps: u32,
    /// Blocks resident per SM.
    pub blocks_per_sm: u32,
}

/// Configuration for latency-sample recording (Fig. 5 experiments).
#[derive(Debug, Clone, Copy)]
pub struct SampleCfg {
    /// Record the first DRAM transaction of up to this many warps.
    pub max_samples: usize,
}

/// The simulator.
pub struct Engine<'k> {
    spec: GpuSpec,
    clocks: Clocks,
    kernel: &'k Kernel,
    sms: Vec<SmState>,
    channels: Vec<Channel>,
    l2: L2Cache,
    /// Per-SM texture/L1 caches (only consulted by `via_l1` loads —
    /// the paper's §VII future-work case).
    l1s: Vec<L2Cache>,
    warps: Vec<WarpState>,
    blocks: Vec<BlockState>,
    pending_blocks: VecDeque<u64>,
    heap: BinaryHeap<Ev>,
    stats: SimStats,
    seq: u64,
    blocks_per_sm: u32,
    sample_cfg: Option<SampleCfg>,
    end_ns: f64,
}

impl<'k> Engine<'k> {
    pub fn new(spec: GpuSpec, clocks: Clocks, kernel: &'k Kernel) -> Self {
        let n_sm = spec.n_sm as usize;
        let l2 = L2Cache::new(spec.l2_bytes, spec.l2_ways, spec.line_bytes);
        let blocks_per_sm = spec.blocks_per_sm(&kernel.launch);
        Engine {
            channels: (0..n_sm).map(|_| Channel::new(&spec)).collect(),
            sms: vec![SmState::default(); n_sm],
            l1s: (0..n_sm)
                .map(|_| L2Cache::new(spec.l1_bytes, spec.l1_ways, spec.line_bytes))
                .collect(),
            l2,
            spec,
            clocks,
            kernel,
            warps: Vec::new(),
            blocks: Vec::new(),
            pending_blocks: (0..kernel.launch.blocks as u64).collect(),
            heap: BinaryHeap::with_capacity(1024),
            stats: SimStats::default(),
            seq: 0,
            blocks_per_sm,
            sample_cfg: None,
            end_ns: 0.0,
        }
    }

    /// Enable Fig.-5 latency sampling.
    pub fn with_samples(mut self, cfg: SampleCfg) -> Self {
        self.sample_cfg = Some(cfg);
        self
    }

    fn push(&mut self, t_ns: f64, warp: u32) {
        self.seq += 1;
        debug_assert!(self.seq <= u32::MAX as u64, "sequence space exhausted");
        self.heap.push(Ev::new(t_ns, self.seq as u32, warp));
    }

    /// Place the next pending block on `sm` at time `t`.
    fn launch_block(&mut self, sm: u32, t_ns: f64) -> bool {
        let Some(block_id) = self.pending_blocks.pop_front() else {
            return false;
        };
        let wpb = self.kernel.launch.warps_per_block();
        let block_uid = self.blocks.len() as u32;
        self.blocks.push(BlockState::new(block_id, sm, wpb));
        let smst = &mut self.sms[sm as usize];
        smst.resident_blocks += 1;
        smst.resident_warps += wpb;
        smst.ever_active = true;
        self.stats.peak_warps_per_sm = self.stats.peak_warps_per_sm.max(smst.resident_warps);
        let t0 = t_ns + self.spec.block_launch_core_cycles * self.clocks.core_ns();
        for w in 0..wpb {
            let gwarp = block_id * wpb as u64 + w as u64;
            let uid = self.warps.len() as u32;
            self.warps.push(WarpState::new(block_uid, gwarp, block_id, sm));
            self.push(t0, uid);
        }
        true
    }

    /// Execute one global-memory instruction; returns completion time.
    fn mem_access(&mut self, t_ns: f64, warp_uid: u32, pat: MemPat, slot: u64, iter: u64) -> f64 {
        let core = self.clocks.core_ns();
        let mem = self.clocks.mem_ns();
        let (gwarp, block_id, sm_id, sampled) = {
            let w = &self.warps[warp_uid as usize];
            (w.gwarp, w.block_id, w.sm as usize, w.sampled)
        };
        let o_itrs = self.kernel.program.o_itrs as u64;
        let line = self.spec.line_bytes as u64;
        let mut ready = t_ns;
        let mut first_dram: Option<(f64, f64)> = None;
        for t in 0..pat.txns as u64 {
            let sm = &mut self.sms[sm_id];
            let issue = t_ns.max(sm.lsu_free_ns);
            sm.lsu_free_ns = issue + core;
            let addr = pat.address(gwarp, block_id, iter, t, o_itrs, line, slot);
            // Texture/L1 stage (paper §VII future work): hits are served
            // inside the SM and never touch the L2 port or the MC.
            if pat.via_l1 {
                self.stats.l1_accesses += 1;
                if self.l1s[sm_id].access(addr) {
                    self.stats.l1_hits += 1;
                    ready = ready.max(issue + self.spec.l1_hit_core_cycles * core);
                    continue;
                }
            }
            let sm = &mut self.sms[sm_id];
            let l2_at = issue.max(sm.l2_port_free_ns);
            sm.l2_port_free_ns = l2_at + self.spec.l2_ii_core_cycles * core;
            self.stats.l2_accesses += 1;
            let done = if self.l2.access(addr) {
                self.stats.l2_hits += 1;
                l2_at + self.spec.l2_hit_core_cycles * core
            } else {
                let arrive_mc = l2_at + self.spec.dm_path_core_cycles * core;
                let svc =
                    self.channels[sm_id].access(arrive_mc, addr / line, &self.spec, mem);
                self.stats.dram_txns += 1;
                if first_dram.is_none() {
                    first_dram = Some((issue, svc.done_ns - issue));
                }
                svc.done_ns
            };
            ready = ready.max(done);
        }
        self.stats.gl_txns += pat.txns as u64;
        // Fig. 5: record the first DRAM request latency of each warp.
        if let (Some(cfg), Some((issue, lat)), false) = (self.sample_cfg, first_dram, sampled) {
            if self.stats.latency_samples.len() < cfg.max_samples {
                self.stats.latency_samples.push(LatencySample {
                    warp: gwarp,
                    issue_ns: issue,
                    latency_ns: lat,
                });
                self.warps[warp_uid as usize].sampled = true;
            }
        }
        ready
    }

    /// Run to completion.
    pub fn run(mut self) -> SimResult {
        // Initial wave: fill every SM round-robin up to its residency.
        for _round in 0..self.blocks_per_sm {
            for sm in 0..self.spec.n_sm {
                if self.sms[sm as usize].resident_blocks < self.blocks_per_sm {
                    if !self.launch_block(sm, 0.0) {
                        break;
                    }
                }
            }
        }

        let core = self.clocks.core_ns();
        while let Some(ev) = self.heap.pop() {
            // Chain ops of the popped warp inline while their completion
            // precedes every other scheduled event — identical semantics
            // to push-and-repop, without the heap churn (EXPERIMENTS.md
            // §Perf iteration 2).
            let mut t = ev.t_ns();
            let warp = ev.warp();
            loop {
                self.end_ns = self.end_ns.max(t);
                let fetched = {
                    let prog = &self.kernel.program;
                    self.warps[warp as usize]
                        .fetch(prog)
                        .map(|(op, slot, iter)| (op.clone(), slot, iter))
                };
                let ready = match fetched {
                    None => {
                        // Warp retires.
                        self.stats.warps_retired += 1;
                        let block_uid = self.warps[warp as usize].block_uid as usize;
                        let sm_id = self.warps[warp as usize].sm;
                        self.blocks[block_uid].warps_done += 1;
                        if self.blocks[block_uid].done() {
                            self.stats.blocks_retired += 1;
                            let wpb = self.kernel.launch.warps_per_block();
                            let smst = &mut self.sms[sm_id as usize];
                            smst.resident_blocks -= 1;
                            smst.resident_warps -= wpb;
                            self.launch_block(sm_id, t);
                        }
                        break;
                    }
                    Some((Op::Compute(n), _, _)) => {
                        let sm = &mut self.sms[self.warps[warp as usize].sm as usize];
                        let start = t.max(sm.alu_free_ns);
                        let finish = start + n as f64 * self.spec.inst_core_cycles * core;
                        sm.alu_free_ns = finish;
                        self.stats.mix.compute += n as u64;
                        finish
                    }
                    Some((Op::Load(pat), slot, iter)) => {
                        let ready = self.mem_access(t, warp, pat, slot, iter);
                        self.stats.mix.global_ld += 1;
                        ready
                    }
                    Some((Op::Store(pat), slot, iter)) => {
                        let ready = self.mem_access(t, warp, pat, slot, iter);
                        self.stats.mix.global_st += 1;
                        ready
                    }
                    Some((Op::SharedLoad { conflict }, _, _))
                    | Some((Op::SharedStore { conflict }, _, _)) => {
                        let conflict = conflict.max(1) as f64;
                        let sm = &mut self.sms[self.warps[warp as usize].sm as usize];
                        let start = t.max(sm.smem_free_ns);
                        sm.smem_free_ns = start + conflict * core;
                        let finish =
                            start + (self.spec.smem_core_cycles + (conflict - 1.0)) * core;
                        self.stats.smem_accesses += 1;
                        self.stats.smem_txns += conflict as u64;
                        self.stats.mix.shared += 1;
                        finish
                    }
                    Some((Op::Sync, _, _)) => {
                        self.stats.mix.sync += 1;
                        let block_uid = self.warps[warp as usize].block_uid as usize;
                        let block = &mut self.blocks[block_uid];
                        block.at_barrier += 1;
                        if block.at_barrier == block.warps_total {
                            // Release everyone one cycle later.
                            block.at_barrier = 0;
                            let mut waiters = std::mem::take(&mut block.waiting);
                            waiters.push(warp);
                            for w in waiters {
                                self.push(t + core, w);
                            }
                            self.stats.barriers += 1;
                        } else {
                            block.waiting.push(warp);
                        }
                        break;
                    }
                };
                // Continue inline only if strictly earlier than the next
                // scheduled event (ties must go through the heap to keep
                // the original FIFO order).
                match self.heap.peek() {
                    Some(next) if ready >= next.t_ns() => {
                        self.push(ready, warp);
                        break;
                    }
                    _ => t = ready,
                }
            }
        }

        // Collect channel-level stats.
        for ch in &self.channels {
            self.stats.dram_row_misses += ch.row_misses;
            self.stats.dram_busy_ns += ch.busy_ns;
        }
        self.stats.active_sms = self.sms.iter().filter(|s| s.ever_active).count() as u32;
        self.stats.elapsed_ns = self.end_ns;
        debug_assert!(self.pending_blocks.is_empty());

        SimResult {
            stats: self.stats,
            active_warps: self.spec.active_warps(&self.kernel.launch),
            blocks_per_sm: self.blocks_per_sm,
        }
    }
}

/// Convenience wrapper: simulate `kernel` at `clocks` on `spec`.
pub fn simulate(spec: &GpuSpec, clocks: Clocks, kernel: &Kernel) -> SimResult {
    Engine::new(spec.clone(), clocks, kernel).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::isa::{Addressing, Launch, Program};

    fn spec() -> GpuSpec {
        GpuSpec::default()
    }

    fn compute_kernel(n_inst: u32, blocks: u32, tpb: u32, o_itrs: u32) -> Kernel {
        Kernel::new(
            "compute",
            Launch::new(blocks, tpb),
            Program {
                prologue: vec![],
                body: vec![Op::Compute(n_inst)],
                o_itrs,
                epilogue: vec![],
            },
        )
    }

    #[test]
    fn single_warp_compute_time_exact() {
        let s = spec();
        let k = compute_kernel(10, 1, 32, 4);
        let r = simulate(&s, Clocks::new(1000.0, 1000.0), &k);
        // 40 instructions * 2 cycles * 1 ns + launch overhead 32 cycles.
        let want = 40.0 * s.inst_core_cycles + s.block_launch_core_cycles;
        assert!(
            (r.stats.elapsed_ns - want).abs() < 1e-6,
            "elapsed {} want {}",
            r.stats.elapsed_ns,
            want
        );
        assert_eq!(r.stats.mix.compute, 40);
        assert_eq!(r.stats.warps_retired, 1);
        assert_eq!(r.stats.blocks_retired, 1);
        assert_eq!(r.stats.active_sms, 1);
    }

    #[test]
    fn compute_scales_inverse_with_core_freq() {
        let s = spec();
        let k = compute_kernel(16, 32, 128, 8);
        let slow = simulate(&s, Clocks::new(400.0, 700.0), &k);
        let fast = simulate(&s, Clocks::new(1000.0, 700.0), &k);
        let ratio = slow.stats.elapsed_ns / fast.stats.elapsed_ns;
        assert!((ratio - 2.5).abs() < 0.01, "ratio {ratio}");
    }

    #[test]
    fn compute_insensitive_to_mem_freq() {
        let s = spec();
        let k = compute_kernel(16, 32, 128, 8);
        let a = simulate(&s, Clocks::new(700.0, 400.0), &k);
        let b = simulate(&s, Clocks::new(700.0, 1000.0), &k);
        assert!((a.stats.elapsed_ns - b.stats.elapsed_ns).abs() < 1e-9);
    }

    #[test]
    fn alu_serializes_warps_on_one_sm() {
        let s = spec();
        // One block of 4 warps on one SM, pure compute.
        let k = compute_kernel(100, 1, 128, 1);
        let r = simulate(&s, Clocks::new(1000.0, 1000.0), &k);
        let want = 4.0 * 100.0 * s.inst_core_cycles + s.block_launch_core_cycles;
        assert!((r.stats.elapsed_ns - want).abs() < 1.0, "elapsed {}", r.stats.elapsed_ns);
    }

    fn stream_kernel(blocks: u32, tpb: u32, txns: u16, o_itrs: u32) -> Kernel {
        Kernel::new(
            "stream",
            Launch::new(blocks, tpb),
            Program {
                prologue: vec![],
                body: vec![Op::Load(MemPat::new(txns, Addressing::OwnLinear, 1))],
                o_itrs,
                epilogue: vec![],
            },
        )
    }

    #[test]
    fn unloaded_dram_latency_matches_eq4() {
        let s = spec();
        // Single warp, single txn per iteration, streaming (always misses).
        let k = stream_kernel(1, 32, 1, 50);
        for (cf, mf) in [(400.0, 400.0), (1000.0, 400.0), (400.0, 1000.0), (700.0, 700.0)] {
            let clocks = Clocks::new(cf, mf);
            let r = simulate(&s, clocks, &k);
            assert_eq!(r.stats.dram_txns, 50);
            // Per-iteration latency in core cycles ~= Eq. (4) + LSU/row terms.
            let cycles = r.stats.elapsed_core_cycles(cf) - s.block_launch_core_cycles;
            let per = cycles / 50.0;
            let eq4 = s.dm_access_mem_cycles * clocks.ratio() + s.dm_path_core_cycles;
            // Row misses add dram_row_miss_lat on most accesses (streaming
            // revisits rows every row_lines/txns, here never: stride 1 line
            // per iter within the same row -> row hits after first).
            assert!(
                (per - eq4).abs() / eq4 < 0.06,
                "cf={cf} mf={mf}: per-iter {per:.1} vs eq4 {eq4:.1}"
            );
        }
    }

    #[test]
    fn l2_hit_latency_flat_in_mem_freq() {
        let s = spec();
        // Hot set that fits in L2: after warm-up everything hits.
        let k = Kernel::new(
            "hot",
            Launch::new(1, 32),
            Program {
                prologue: vec![],
                body: vec![Op::Load(MemPat::new(1, Addressing::Hot { lines: 64 }, 1))],
                o_itrs: 2000,
                epilogue: vec![],
            },
        );
        let a = simulate(&s, Clocks::new(700.0, 400.0), &k);
        let b = simulate(&s, Clocks::new(700.0, 1000.0), &k);
        assert!(a.stats.l2_hit_rate() > 0.8, "hit rate {}", a.stats.l2_hit_rate());
        // Only the few cold misses differ; elapsed within 5%.
        let rel = (a.stats.elapsed_ns - b.stats.elapsed_ns).abs() / b.stats.elapsed_ns;
        assert!(rel < 0.05, "rel {rel}");
    }

    #[test]
    fn bandwidth_bound_scales_with_mem_freq() {
        let s = spec();
        // Many warps streaming: MC channels saturate.
        let k = stream_kernel(64, 256, 4, 16);
        let slow = simulate(&s, Clocks::new(1000.0, 400.0), &k);
        let fast = simulate(&s, Clocks::new(1000.0, 1000.0), &k);
        let ratio = slow.stats.elapsed_ns / fast.stats.elapsed_ns;
        assert!(ratio > 2.0 && ratio < 2.6, "ratio {ratio}");
    }

    #[test]
    fn barrier_joins_warps() {
        let s = spec();
        let k = Kernel::new(
            "sync",
            Launch::new(1, 128),
            Program {
                prologue: vec![],
                body: vec![Op::Compute(10), Op::Sync],
                o_itrs: 3,
                epilogue: vec![],
            },
        );
        let r = simulate(&s, Clocks::new(1000.0, 1000.0), &k);
        assert_eq!(r.stats.barriers, 3);
        assert_eq!(r.stats.mix.sync, 12); // 4 warps * 3 iters
        assert_eq!(r.stats.warps_retired, 4);
    }

    #[test]
    fn all_blocks_retire_with_oversubscription() {
        let s = spec();
        // 64 warps/SM limit, 8 wpb -> 8 blocks/SM; 16 SM -> 128 resident;
        // 300 blocks forces multiple waves.
        let k = compute_kernel(4, 300, 256, 2);
        let r = simulate(&s, Clocks::new(700.0, 700.0), &k);
        assert_eq!(r.stats.blocks_retired, 300);
        assert_eq!(r.stats.warps_retired, 2400);
        assert_eq!(r.blocks_per_sm, 8);
        assert_eq!(r.active_warps, 64);
        assert_eq!(r.stats.peak_warps_per_sm, 64);
    }

    #[test]
    fn latency_samples_recorded() {
        let s = spec();
        let k = stream_kernel(8, 256, 4, 4);
        let r = Engine::new(s, Clocks::new(700.0, 700.0), &k)
            .with_samples(SampleCfg { max_samples: 100 })
            .run();
        // One sample per warp; the grid has 64 warps.
        assert_eq!(r.stats.latency_samples.len(), 64);
        for smp in &r.stats.latency_samples {
            assert!(smp.latency_ns > 0.0);
        }
    }

    #[test]
    fn deterministic_repeat() {
        let s = spec();
        let k = stream_kernel(32, 128, 4, 8);
        let a = simulate(&s, Clocks::new(600.0, 800.0), &k);
        let b = simulate(&s, Clocks::new(600.0, 800.0), &k);
        assert_eq!(a.stats.elapsed_ns, b.stats.elapsed_ns);
        assert_eq!(a.stats.l2_hits, b.stats.l2_hits);
        assert_eq!(a.stats.dram_row_misses, b.stats.dram_row_misses);
    }

    #[test]
    fn smem_ops_charged_on_core_clock() {
        let s = spec();
        let k = Kernel::new(
            "smem",
            Launch::new(1, 32),
            Program {
                prologue: vec![],
                body: vec![Op::SharedLoad { conflict: 1 }],
                o_itrs: 100,
                epilogue: vec![],
            },
        );
        let a = simulate(&s, Clocks::new(500.0, 400.0), &k);
        let b = simulate(&s, Clocks::new(500.0, 1000.0), &k);
        assert_eq!(a.stats.smem_accesses, 100);
        assert!((a.stats.elapsed_ns - b.stats.elapsed_ns).abs() < 1e-9);
        let c = simulate(&s, Clocks::new(1000.0, 700.0), &k);
        let ratio = a.stats.elapsed_ns / c.stats.elapsed_ns;
        assert!((ratio - 2.0).abs() < 0.01, "ratio {ratio}");
    }

    #[test]
    fn bank_conflicts_serialize_smem() {
        let s = spec();
        let mk = |conflict: u8| {
            Kernel::new(
                "smemconf",
                Launch::new(1, 32),
                Program {
                    prologue: vec![],
                    body: vec![Op::SharedLoad { conflict }],
                    o_itrs: 200,
                    epilogue: vec![],
                },
            )
        };
        let k1 = mk(1);
        let k8 = mk(8);
        let a = simulate(&s, Clocks::new(700.0, 700.0), &k1);
        let b = simulate(&s, Clocks::new(700.0, 700.0), &k8);
        assert!(b.stats.elapsed_ns > a.stats.elapsed_ns);
        assert_eq!(b.stats.smem_txns, 1600);
    }
}
