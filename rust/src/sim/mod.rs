//! `gpusim`: a dual-clock-domain, trace-driven, cycle-approximate GPU
//! timing simulator — the ground-truth substrate standing in for the
//! paper's GTX 980 + NVIDIA-Inspector testbed (DESIGN.md §2).
//!
//! Two clock domains drive the machine, exactly as Table I of the paper
//! maps components to frequencies:
//!
//! | component                  | clock  |
//! |----------------------------|--------|
//! | SM issue / ALU             | core   |
//! | shared memory              | core   |
//! | L2 cache port + lookup     | core   |
//! | SM→MC path segment         | core   |
//! | memory-controller service  | memory |
//! | DRAM access segment        | memory |
//!
//! An L2 miss therefore costs `dm_path_core_cycles` on the core clock plus
//! queueing and `dm_access_mem_cycles` on the memory clock: the unloaded
//! latency measured by the P-chase probe in core cycles is
//! `dm_path + dm_access * core_f/mem_f` — the paper's Eq. (4) by
//! construction, with the calibration constants below reproducing the
//! paper's fitted 222.78/277.32 line.

pub mod dram;
pub mod engine;
pub mod isa;
pub mod l2;
pub mod sm;
pub mod stats;

pub use engine::{Engine, SimResult};
pub use isa::{Addressing, Kernel, Launch, MemPat, Op, Program};
pub use stats::SimStats;

/// The two frequency domains, in MHz (the paper sweeps 400–1000 MHz).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Clocks {
    pub core_mhz: f64,
    pub mem_mhz: f64,
}

impl Clocks {
    pub fn new(core_mhz: f64, mem_mhz: f64) -> Self {
        assert!(core_mhz > 0.0 && mem_mhz > 0.0, "frequencies must be positive");
        Clocks { core_mhz, mem_mhz }
    }

    /// Duration of one core cycle in nanoseconds.
    #[inline]
    pub fn core_ns(&self) -> f64 {
        1e3 / self.core_mhz
    }

    /// Duration of one memory cycle in nanoseconds.
    #[inline]
    pub fn mem_ns(&self) -> f64 {
        1e3 / self.mem_mhz
    }

    /// cf/mf, the ratio the paper's Eqs. (4)/(5) scale by.
    #[inline]
    pub fn ratio(&self) -> f64 {
        self.core_mhz / self.mem_mhz
    }
}

/// Hardware description of the simulated GPU (Table V of the paper plus
/// the timing constants the micro-benchmarks of §IV extract).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    /// Number of streaming multiprocessors (GTX 980: 16).
    pub n_sm: u32,
    /// Hardware warp-slot limit per SM (Maxwell: 64).
    pub max_warps_per_sm: u32,
    /// Hardware block limit per SM (Maxwell: 32).
    pub max_blocks_per_sm: u32,
    /// Shared memory per SM in bytes (Maxwell: 96 KiB).
    pub smem_per_sm: u32,
    /// Register file per SM (32-bit registers).
    pub regs_per_sm: u32,
    /// L2 capacity in bytes (GTX 980: 2 MiB).
    pub l2_bytes: u64,
    /// L2 associativity.
    pub l2_ways: u32,
    /// Cache line / memory transaction size in bytes.
    pub line_bytes: u32,
    /// L2 unloaded hit latency, core cycles (paper: ~222).
    pub l2_hit_core_cycles: f64,
    /// L2 port initiation interval per SM slice, core cycles (paper: 1).
    pub l2_ii_core_cycles: f64,
    /// Core-clocked segment of a DRAM access (SM→icnt→L2-miss→MC path),
    /// core cycles. Paper Eq. (4) intercept: 277.32.
    pub dm_path_core_cycles: f64,
    /// Memory-clocked segment of a DRAM access, memory cycles.
    /// Paper Eq. (4) slope: 222.78.
    pub dm_access_mem_cycles: f64,
    /// Memory-controller service interval per transaction per channel
    /// (one channel per SM), memory cycles. The theoretical burst floor;
    /// arbitration overhead and bank effects push the *measured* dm_del
    /// above this (Table III).
    pub dm_burst_mem_cycles: f64,
    /// Fixed MC arbitration/scheduling overhead added to every
    /// transaction's channel occupancy, memory cycles. This is what
    /// keeps measured bandwidth efficiency below 100 % uniformly across
    /// access patterns (the paper's Table III reports 76–85 %).
    pub mc_overhead_mem_cycles: f64,
    /// DRAM banks per channel.
    pub dram_banks: u32,
    /// Lines per DRAM row (row-buffer granularity in lines).
    pub dram_row_lines: u32,
    /// Extra latency on a row-buffer miss, memory cycles.
    pub dram_row_miss_lat_mem_cycles: f64,
    /// Extra channel occupancy on a row-buffer miss, memory cycles.
    pub dram_row_miss_occ_mem_cycles: f64,
    /// Per-SM texture/L1 cache capacity, bytes (16 KiB here; Maxwell's
    /// 24 KiB unified tex/L1 is not a power-of-two set count at 8 ways).
    /// Only consulted by loads marked `via_l1` — the paper's §VII
    /// future-work case, implemented here as an extension.
    pub l1_bytes: u64,
    /// Texture/L1 associativity.
    pub l1_ways: u32,
    /// Texture/L1 hit latency, core cycles (Maxwell tex: ~80).
    pub l1_hit_core_cycles: f64,
    /// Shared-memory unloaded latency, core cycles.
    pub smem_core_cycles: f64,
    /// Issue cost per compute instruction per warp on the SM ALU
    /// pipeline, core cycles (the model's `inst_cycle`).
    pub inst_core_cycles: f64,
    /// Block launch overhead, core cycles.
    pub block_launch_core_cycles: f64,
}

impl Default for GpuSpec {
    fn default() -> Self {
        GpuSpec {
            n_sm: 16,
            max_warps_per_sm: 64,
            max_blocks_per_sm: 32,
            smem_per_sm: 96 * 1024,
            regs_per_sm: 65536,
            l2_bytes: 2 * 1024 * 1024,
            l2_ways: 16,
            line_bytes: 32,
            l2_hit_core_cycles: 222.0,
            l2_ii_core_cycles: 1.0,
            dm_path_core_cycles: 277.32,
            dm_access_mem_cycles: 222.78,
            dm_burst_mem_cycles: 8.0,
            mc_overhead_mem_cycles: 1.5,
            dram_banks: 4,
            dram_row_lines: 64,
            dram_row_miss_lat_mem_cycles: 10.0,
            dram_row_miss_occ_mem_cycles: 0.5,
            l1_bytes: 16 * 1024,
            l1_ways: 8,
            l1_hit_core_cycles: 80.0,
            smem_core_cycles: 28.0,
            inst_core_cycles: 2.0,
            block_launch_core_cycles: 32.0,
        }
    }
}

impl GpuSpec {
    /// Number of concurrently-resident blocks per SM for a launch —
    /// the standard occupancy calculation (warps, blocks, smem, regs).
    pub fn blocks_per_sm(&self, launch: &Launch) -> u32 {
        let wpb = launch.warps_per_block();
        let by_warps = self.max_warps_per_sm / wpb.max(1);
        let by_blocks = self.max_blocks_per_sm;
        let by_smem = if launch.smem_per_block > 0 {
            self.smem_per_sm / launch.smem_per_block
        } else {
            u32::MAX
        };
        let regs_per_block = launch.regs_per_thread * launch.threads_per_block;
        let by_regs = if regs_per_block > 0 {
            self.regs_per_sm / regs_per_block
        } else {
            u32::MAX
        };
        by_warps.min(by_blocks).min(by_smem).min(by_regs).max(1)
    }

    /// Active warps per SM (`#Aw` in the paper's Table IV): residency is
    /// capped both by the occupancy limit and by how many blocks the
    /// grid actually puts on one SM.
    pub fn active_warps(&self, launch: &Launch) -> u32 {
        let per_sm = self.blocks_per_sm(launch);
        let grid_per_sm = launch.blocks.div_ceil(self.n_sm).max(1);
        per_sm.min(grid_per_sm) * launch.warps_per_block()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_periods() {
        let c = Clocks::new(1000.0, 500.0);
        assert!((c.core_ns() - 1.0).abs() < 1e-12);
        assert!((c.mem_ns() - 2.0).abs() < 1e-12);
        assert!((c.ratio() - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn zero_frequency_rejected() {
        Clocks::new(0.0, 500.0);
    }

    #[test]
    fn occupancy_limited_by_warps() {
        let spec = GpuSpec::default();
        let launch = Launch::new(256, 256); // 8 warps/block
        assert_eq!(spec.blocks_per_sm(&launch), 8); // 64 / 8
        assert_eq!(spec.active_warps(&launch), 64);
    }

    #[test]
    fn occupancy_limited_by_smem() {
        let spec = GpuSpec::default();
        let mut launch = Launch::new(256, 128); // 4 warps/block
        launch.smem_per_block = 48 * 1024; // two blocks fit
        assert_eq!(spec.blocks_per_sm(&launch), 2);
        assert_eq!(spec.active_warps(&launch), 8);
    }

    #[test]
    fn occupancy_limited_by_regs() {
        let spec = GpuSpec::default();
        let mut launch = Launch::new(64, 256);
        launch.regs_per_thread = 128; // 32768 regs/block -> 2 blocks
        assert_eq!(spec.blocks_per_sm(&launch), 2);
    }

    #[test]
    fn occupancy_capped_by_grid() {
        let spec = GpuSpec::default();
        // 2 blocks over 16 SMs: at most one block per SM.
        let launch = Launch::new(2, 64);
        assert_eq!(spec.active_warps(&launch), 2);
        // 24 blocks over 16 SMs: two blocks land on some SMs.
        let launch = Launch::new(24, 64);
        assert_eq!(spec.active_warps(&launch), 4);
    }

    #[test]
    fn eq4_constants_compose() {
        // The unloaded DRAM latency in core cycles must follow Eq. (4).
        let spec = GpuSpec::default();
        for (cf, mf) in [(400.0, 400.0), (1000.0, 400.0), (400.0, 1000.0)] {
            let clocks = Clocks::new(cf, mf);
            let lat_ns = spec.dm_path_core_cycles * clocks.core_ns()
                + spec.dm_access_mem_cycles * clocks.mem_ns();
            let lat_core_cycles = lat_ns / clocks.core_ns();
            let eq4 = spec.dm_access_mem_cycles * clocks.ratio() + spec.dm_path_core_cycles;
            assert!((lat_core_cycles - eq4).abs() < 1e-9);
        }
    }
}
