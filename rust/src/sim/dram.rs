//! Memory-controller channel + DRAM bank timing — the paper's FCFS queue
//! (§IV, Figs. 3/4), clocked entirely in the **memory** domain.
//!
//! One channel serves one SM (DESIGN.md §7: the physically-shared GDDR5
//! is abstracted as #SM interleaved channels, which is what makes the
//! per-SM `dm_del` the micro-benchmarks extract line up with the paper's
//! per-SM queue equations). A channel is a deterministic-service FCFS
//! pipeline:
//!
//! * a new transaction may *start* `dm_burst_mem_cycles` after the
//!   previous one started (the initiation interval that bounds
//!   bandwidth, i.e. the paper's `dm_del` floor);
//! * its data returns `dm_access_mem_cycles` after it starts (the
//!   memory-clocked half of Eq. (4));
//! * row-buffer misses at the addressed bank add latency and occupancy,
//!   which is what lifts measured `dm_del` above the burst floor and
//!   caps bandwidth efficiency below 100 % (Table III).

use super::GpuSpec;

/// One FCFS memory-controller channel with banked DRAM behind it.
#[derive(Debug, Clone)]
pub struct Channel {
    /// Earliest time (ns) the next transaction may start service.
    next_slot_ns: f64,
    /// Per-bank open row (row id), None = closed.
    open_row: Vec<Option<u64>>,
    n_banks: u64,
    row_lines: u64,
    /// Total transactions served.
    pub txns: u64,
    /// Row-buffer misses observed.
    pub row_misses: u64,
    /// Time the channel finished its last service start (for busy accounting).
    pub busy_ns: f64,
}

/// Outcome of enqueueing one transaction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Service {
    /// When service started (after FCFS wait), ns.
    pub start_ns: f64,
    /// When the data returns to the SM-side path, ns.
    pub done_ns: f64,
}

impl Channel {
    pub fn new(spec: &GpuSpec) -> Self {
        Channel {
            next_slot_ns: 0.0,
            open_row: vec![None; spec.dram_banks as usize],
            n_banks: spec.dram_banks as u64,
            row_lines: spec.dram_row_lines as u64,
            txns: 0,
            row_misses: 0,
            busy_ns: 0.0,
        }
    }

    /// Enqueue a transaction for `line` (global line index) arriving at
    /// `arrive_ns`. `mem_ns` is the current memory-clock period.
    pub fn access(&mut self, arrive_ns: f64, line: u64, spec: &GpuSpec, mem_ns: f64) -> Service {
        let bank = (line / self.row_lines % self.n_banks) as usize;
        let row = line / (self.row_lines * self.n_banks);

        let start = arrive_ns.max(self.next_slot_ns);
        let row_hit = self.open_row[bank] == Some(row);

        let mut occupancy = (spec.dm_burst_mem_cycles + spec.mc_overhead_mem_cycles) * mem_ns;
        let mut latency = spec.dm_access_mem_cycles * mem_ns;
        if !row_hit {
            occupancy += spec.dram_row_miss_occ_mem_cycles * mem_ns;
            latency += spec.dram_row_miss_lat_mem_cycles * mem_ns;
            self.row_misses += 1;
            self.open_row[bank] = Some(row);
        }

        self.next_slot_ns = start + occupancy;
        self.busy_ns += occupancy;
        self.txns += 1;

        Service { start_ns: start, done_ns: start + latency }
    }

    /// Earliest service-start time currently scheduled.
    pub fn next_slot(&self) -> f64 {
        self.next_slot_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> GpuSpec {
        GpuSpec::default()
    }

    #[test]
    fn unloaded_latency_is_access_segment() {
        let s = spec();
        let mut ch = Channel::new(&s);
        let mem_ns = 1.0; // 1000 MHz
        let svc = ch.access(100.0, 0, &s, mem_ns);
        assert_eq!(svc.start_ns, 100.0);
        // First access row-misses: access + row-miss latency.
        let want = 100.0 + (s.dm_access_mem_cycles + s.dram_row_miss_lat_mem_cycles) * mem_ns;
        assert!((svc.done_ns - want).abs() < 1e-9);
    }

    #[test]
    fn row_hit_has_min_latency() {
        let s = spec();
        let mut ch = Channel::new(&s);
        let mem_ns = 1.0;
        ch.access(0.0, 0, &s, mem_ns);
        let svc = ch.access(1000.0, 1, &s, mem_ns); // same row, channel idle
        assert!((svc.done_ns - svc.start_ns - s.dm_access_mem_cycles).abs() < 1e-9);
    }

    #[test]
    fn fcfs_backpressure() {
        let s = spec();
        let mut ch = Channel::new(&s);
        let mem_ns = 2.0; // 500 MHz
        // Two same-row transactions arriving together: second starts one
        // burst interval after the first.
        let a = ch.access(0.0, 0, &s, mem_ns);
        let b = ch.access(0.0, 1, &s, mem_ns);
        let ii = (s.dm_burst_mem_cycles
            + s.mc_overhead_mem_cycles
            + s.dram_row_miss_occ_mem_cycles)
            * mem_ns;
        assert!((b.start_ns - (a.start_ns + ii)).abs() < 1e-9);
    }

    #[test]
    fn saturated_throughput_matches_burst_interval() {
        let s = spec();
        let mut ch = Channel::new(&s);
        let mem_ns = 1.0;
        let n = 10_000u64;
        let mut last = Service { start_ns: 0.0, done_ns: 0.0 };
        for i in 0..n {
            last = ch.access(0.0, i, &s, mem_ns); // streaming same rows mostly
        }
        // Row misses every row_lines txns; effective interval = burst +
        // MC overhead + a sliver of row-miss occupancy.
        let span = last.start_ns;
        let per_txn = span / (n - 1) as f64;
        let floor = (s.dm_burst_mem_cycles + s.mc_overhead_mem_cycles) * mem_ns;
        assert!(per_txn >= floor);
        assert!(per_txn < floor + 1.0 * mem_ns);
    }

    #[test]
    fn memory_clock_scales_service() {
        let s = spec();
        let mut fast = Channel::new(&s);
        let mut slow = Channel::new(&s);
        let f = fast.access(0.0, 0, &s, 1.0); // 1000 MHz
        let sl = slow.access(0.0, 0, &s, 2.5); // 400 MHz
        assert!((sl.done_ns / f.done_ns - 2.5).abs() < 1e-9);
    }

    #[test]
    fn row_miss_counting() {
        let s = spec();
        let mut ch = Channel::new(&s);
        for i in 0..s.dram_row_lines as u64 {
            ch.access(0.0, i, &s, 1.0); // one row -> 1 miss
        }
        assert_eq!(ch.row_misses, 1);
        ch.access(0.0, (s.dram_row_lines * s.dram_banks) as u64, &s, 1.0); // same bank new row
        assert_eq!(ch.row_misses, 2);
    }
}
