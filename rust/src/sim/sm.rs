//! Per-SM and per-warp execution state.

use super::isa::{Op, Program};

/// Program-counter phase for a warp.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Phase {
    Prologue,
    Body,
    Epilogue,
    Done,
}

/// Execution state of one warp.
#[derive(Debug, Clone)]
pub struct WarpState {
    /// Index into the engine's block table.
    pub block_uid: u32,
    /// Grid-global warp id (`blockIdx * wpb + warpIdx`).
    pub gwarp: u64,
    /// Grid block index this warp belongs to.
    pub block_id: u64,
    /// SM the warp is resident on.
    pub sm: u32,
    pub phase: Phase,
    pub idx: usize,
    pub iter: u32,
    /// Whether a Fig.-5 latency sample was already taken for this warp.
    pub sampled: bool,
}

impl WarpState {
    pub fn new(block_uid: u32, gwarp: u64, block_id: u64, sm: u32) -> Self {
        WarpState {
            block_uid,
            gwarp,
            block_id,
            sm,
            phase: Phase::Prologue,
            idx: 0,
            iter: 0,
            sampled: false,
        }
    }

    /// Fetch the op at the current PC and advance. Returns `None` when
    /// the program is finished. `op_slot` out-param is the static index
    /// of the instruction in the flattened program (used to spread
    /// address sub-regions).
    pub fn fetch<'p>(&mut self, prog: &'p Program) -> Option<(&'p Op, u64, u64)> {
        loop {
            match self.phase {
                Phase::Prologue => {
                    if self.idx < prog.prologue.len() {
                        let op = &prog.prologue[self.idx];
                        let slot = self.idx as u64;
                        self.idx += 1;
                        return Some((op, slot, 0));
                    }
                    self.phase = Phase::Body;
                    self.idx = 0;
                    self.iter = 0;
                }
                Phase::Body => {
                    if prog.o_itrs == 0 || prog.body.is_empty() {
                        self.phase = Phase::Epilogue;
                        self.idx = 0;
                        continue;
                    }
                    if self.idx < prog.body.len() {
                        let op = &prog.body[self.idx];
                        let slot = (prog.prologue.len() + self.idx) as u64;
                        let it = self.iter as u64;
                        self.idx += 1;
                        return Some((op, slot, it));
                    }
                    self.iter += 1;
                    self.idx = 0;
                    if self.iter >= prog.o_itrs {
                        self.phase = Phase::Epilogue;
                    }
                }
                Phase::Epilogue => {
                    if self.idx < prog.epilogue.len() {
                        let op = &prog.epilogue[self.idx];
                        let slot = (prog.prologue.len() + prog.body.len() + self.idx) as u64;
                        self.idx += 1;
                        // Epilogue uses iteration index o_itrs so OwnLinear
                        // epilogue traffic does not alias body traffic.
                        return Some((op, slot, prog.o_itrs as u64));
                    }
                    self.phase = Phase::Done;
                }
                Phase::Done => return None,
            }
        }
    }
}

/// Execution state of one resident thread block.
#[derive(Debug, Clone)]
pub struct BlockState {
    pub block_id: u64,
    pub sm: u32,
    pub warps_total: u32,
    pub warps_done: u32,
    /// Warps currently parked at the barrier.
    pub at_barrier: u32,
    /// Warp uids parked at the barrier, released together.
    pub waiting: Vec<u32>,
}

impl BlockState {
    pub fn new(block_id: u64, sm: u32, warps_total: u32) -> Self {
        BlockState {
            block_id,
            sm,
            warps_total,
            warps_done: 0,
            at_barrier: 0,
            waiting: Vec::new(),
        }
    }

    pub fn done(&self) -> bool {
        self.warps_done == self.warps_total
    }
}

/// Shared execution resources of one SM. All fields are "free-at"
/// timestamps in ns; granting is FCFS in event order.
#[derive(Debug, Clone, Default)]
pub struct SmState {
    /// ALU pipeline: compute periods of different warps serialize here
    /// (this is what makes the paper's Eq. (9) `avr_comp * #Aw` hold).
    pub alu_free_ns: f64,
    /// Load/store unit: one global transaction issued per core cycle.
    pub lsu_free_ns: f64,
    /// Shared-memory port: one access per core cycle, conflicts serialize.
    pub smem_free_ns: f64,
    /// This SM's L2 slice port (one transaction per `l2_ii` core cycles).
    pub l2_port_free_ns: f64,
    pub resident_blocks: u32,
    pub resident_warps: u32,
    /// Whether this SM ever hosted a block (`#Asm` accounting).
    pub ever_active: bool,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::isa::{Addressing, MemPat};

    fn prog() -> Program {
        Program {
            prologue: vec![Op::Compute(1)],
            body: vec![Op::Compute(2), Op::Load(MemPat::new(1, Addressing::OwnLinear, 1))],
            o_itrs: 3,
            epilogue: vec![Op::Compute(3)],
        }
    }

    #[test]
    fn fetch_walks_full_program() {
        let p = prog();
        let mut w = WarpState::new(0, 0, 0, 0);
        let mut seen = Vec::new();
        while let Some((op, slot, iter)) = w.fetch(&p) {
            seen.push((op.clone(), slot, iter));
        }
        assert_eq!(seen.len() as u64, p.dynamic_len());
        // First op is the prologue compute with slot 0, iter 0.
        assert_eq!(seen[0], (Op::Compute(1), 0, 0));
        // Body iterations carry their iteration index.
        assert_eq!(seen[1].2, 0);
        assert_eq!(seen[3].2, 1);
        assert_eq!(seen[5].2, 2);
        // Epilogue uses iter == o_itrs.
        assert_eq!(seen.last().unwrap().2, 3);
        // Fetch after Done keeps returning None.
        assert!(w.fetch(&p).is_none());
    }

    #[test]
    fn empty_body_skipped() {
        let p = Program {
            prologue: vec![Op::Compute(1)],
            body: vec![],
            o_itrs: 5,
            epilogue: vec![Op::Compute(2)],
        };
        let mut w = WarpState::new(0, 0, 0, 0);
        let mut n = 0;
        while w.fetch(&p).is_some() {
            n += 1;
        }
        assert_eq!(n, 2);
    }

    #[test]
    fn block_done_tracking() {
        let mut b = BlockState::new(0, 0, 4);
        for _ in 0..4 {
            assert!(!b.done());
            b.warps_done += 1;
        }
        assert!(b.done());
    }
}
