//! Counter fabric: everything the profiler (Nsight stand-in) and the
//! figure/table emitters need from a simulation run.

/// Per-class dynamic instruction counts (paper Fig. 12 breakdown).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct InstMix {
    pub compute: u64,
    pub global_ld: u64,
    pub global_st: u64,
    pub shared: u64,
    pub sync: u64,
}

impl InstMix {
    pub fn total(&self) -> u64 {
        self.compute + self.global_ld + self.global_st + self.shared + self.sync
    }
}

/// One recorded memory-request latency sample (paper Fig. 5).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySample {
    pub warp: u64,
    pub issue_ns: f64,
    pub latency_ns: f64,
}

/// Aggregated counters for one kernel execution.
#[derive(Debug, Clone, Default)]
pub struct SimStats {
    /// Dynamic warp-level instruction mix.
    pub mix: InstMix,
    /// Global-memory transactions issued (loads + stores), all warps.
    pub gl_txns: u64,
    /// L2 accesses / hits (transaction granularity).
    pub l2_accesses: u64,
    pub l2_hits: u64,
    /// Texture/L1 accesses / hits (only loads marked `via_l1`).
    pub l1_accesses: u64,
    pub l1_hits: u64,
    /// DRAM transactions (L2 misses reaching the MC).
    pub dram_txns: u64,
    /// DRAM row-buffer misses.
    pub dram_row_misses: u64,
    /// Total channel busy time (ns) summed over channels.
    pub dram_busy_ns: f64,
    /// Shared-memory accesses (op granularity) and bank transactions.
    pub smem_accesses: u64,
    pub smem_txns: u64,
    /// Barriers executed (block-wide releases).
    pub barriers: u64,
    /// Blocks retired.
    pub blocks_retired: u64,
    /// Warps retired.
    pub warps_retired: u64,
    /// Peak resident warps observed on any SM (`#Aw` measured).
    pub peak_warps_per_sm: u32,
    /// Number of SMs that received at least one block (`#Asm`).
    pub active_sms: u32,
    /// Wall-clock kernel duration, ns.
    pub elapsed_ns: f64,
    /// Optional per-request latency samples (Fig. 5).
    pub latency_samples: Vec<LatencySample>,
}

impl SimStats {
    /// Measured L2 hit rate (`l2_hr`); 0 when no traffic.
    pub fn l2_hit_rate(&self) -> f64 {
        if self.l2_accesses == 0 {
            0.0
        } else {
            self.l2_hits as f64 / self.l2_accesses as f64
        }
    }

    /// Measured texture/L1 hit rate over all global transactions (the
    /// fraction of traffic the L1 absorbs: L1 misses continue to L2, so
    /// total traffic = l1_hits + l2_accesses); 0 when no L1 traffic.
    pub fn l1_hit_rate(&self) -> f64 {
        let total = self.l1_hits + self.l2_accesses;
        if total == 0 {
            0.0
        } else {
            self.l1_hits as f64 / total as f64
        }
    }

    /// Achieved DRAM bandwidth in bytes/ns (= GB/s).
    pub fn dram_bandwidth(&self, line_bytes: u32) -> f64 {
        if self.elapsed_ns <= 0.0 {
            0.0
        } else {
            self.dram_txns as f64 * line_bytes as f64 / self.elapsed_ns
        }
    }

    /// Elapsed time expressed in core cycles at `core_mhz`.
    pub fn elapsed_core_cycles(&self, core_mhz: f64) -> f64 {
        self.elapsed_ns * core_mhz / 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_rate_handles_zero() {
        let s = SimStats::default();
        assert_eq!(s.l2_hit_rate(), 0.0);
    }

    #[test]
    fn hit_rate_ratio() {
        let s = SimStats { l2_accesses: 200, l2_hits: 150, ..Default::default() };
        assert!((s.l2_hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn bandwidth_and_cycles() {
        let s = SimStats { dram_txns: 1000, elapsed_ns: 500.0, ..Default::default() };
        assert!((s.dram_bandwidth(32) - 64.0).abs() < 1e-12); // 32 KB / 500 ns
        assert!((s.elapsed_core_cycles(1000.0) - 500.0).abs() < 1e-12);
    }

    #[test]
    fn mix_total() {
        let m = InstMix { compute: 5, global_ld: 3, global_st: 2, shared: 4, sync: 1 };
        assert_eq!(m.total(), 15);
    }
}
