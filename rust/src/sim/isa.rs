//! Abstract warp-level ISA and kernel descriptions.
//!
//! Kernels are *trace generators*: every warp executes the same small
//! `Program` (prologue / body×o_itrs / epilogue) and an `Addressing`
//! pattern turns (warp id, iteration, transaction index) into global
//! addresses, from which L2 hit rates and DRAM row behaviour emerge in
//! the cache/DRAM models rather than being asserted.

/// Warp-level operations.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// `n` dependent arithmetic instructions issued back-to-back on the
    /// SM ALU pipeline (each costing `inst_core_cycles`).
    Compute(u32),
    /// Global-memory load; the warp blocks until all transactions return.
    Load(MemPat),
    /// Global-memory store. Modeled blocking, like loads — the paper's
    /// `gld_trans` counter folds loads and stores together (§V).
    Store(MemPat),
    /// Shared-memory load with a bank-conflict degree (1 = conflict-free).
    SharedLoad { conflict: u8 },
    /// Shared-memory store with a bank-conflict degree.
    SharedStore { conflict: u8 },
    /// Block-wide barrier (`__syncthreads()`).
    Sync,
}

/// How a warp's global transactions map to addresses.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Addressing {
    /// Per-warp streaming: every (warp, iteration, txn) touches a fresh
    /// line. Coalesced pass over a big array — vectorAdd-style.
    OwnLinear,
    /// Per-warp strided walk: consecutive transactions are `stride`
    /// lines apart (uncoalesced column access, transpose writes).
    OwnStrided { stride: u32 },
    /// All warps of a block touch the same lines for a given iteration
    /// (a broadcast tile: matrixMul's A-row).
    BlockShared,
    /// All blocks touch the same lines for a given iteration (a tile
    /// every block walks: matrixMul's B-column / filter taps).
    GridShared,
    /// Bounded working set of `lines` lines reused across iterations
    /// (hot table; hits once warm if it fits in L2).
    Hot { lines: u32 },
    /// Pseudo-random lines within a `lines`-sized window (CG's sparse
    /// gather).
    Random { lines: u32 },
}

/// One global-memory instruction pattern: `txns` transactions of one
/// line each, addressed per `addressing` within region `region` (regions
/// are disjoint 1-TiB address windows, so kernels never alias).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemPat {
    pub txns: u16,
    pub addressing: Addressing,
    pub region: u8,
    /// Optional explicit sub-region slot. Two instructions with the same
    /// `(region, alias)` touch the *same* addresses — e.g. FWT's
    /// read-modify-write, where the store hits the line its load just
    /// brought into L2. `None` = use the instruction's static position,
    /// i.e. distinct buffers.
    pub alias: Option<u8>,
    /// Route this access through the per-SM texture/L1 cache (the
    /// paper's §VII future-work case; `tex1Dfetch`-style loads).
    pub via_l1: bool,
}

impl MemPat {
    pub fn new(txns: u16, addressing: Addressing, region: u8) -> Self {
        assert!(txns > 0, "a memory op needs at least one transaction");
        MemPat { txns, addressing, region, alias: None, via_l1: false }
    }

    /// Pin this instruction's address sub-region (see `alias` field).
    pub fn with_alias(mut self, alias: u8) -> Self {
        self.alias = Some(alias);
        self
    }

    /// Route through the per-SM texture/L1 cache.
    pub fn through_l1(mut self) -> Self {
        self.via_l1 = true;
        self
    }

    /// Address of transaction `t` for warp `gwarp` (grid-global warp id)
    /// in block `block` at body iteration `iter`, given `o_itrs` total
    /// iterations and the line size.
    pub fn address(
        &self,
        gwarp: u64,
        block: u64,
        iter: u64,
        t: u64,
        o_itrs: u64,
        line: u64,
        op_slot: u64,
    ) -> u64 {
        let base = (self.region as u64) << 40;
        // The sub-region slot spreads distinct instructions in the same
        // region apart; an explicit alias makes instructions share one.
        let slot = (self.alias.map(u64::from).unwrap_or(op_slot)) << 34;
        let tx = self.txns as u64;
        let idx = match self.addressing {
            Addressing::OwnLinear => (gwarp * o_itrs.max(1) + iter) * tx + t,
            Addressing::OwnStrided { stride } => {
                // Per-warp strided walk: the warp's transactions sit
                // `stride` lines apart (uncoalesced); iterations advance
                // one line. Distinct warps never alias.
                (gwarp * tx + t) * stride as u64 + iter
            }
            Addressing::BlockShared => (block * o_itrs.max(1) + iter) * tx + t,
            Addressing::GridShared => iter * tx + t,
            Addressing::Hot { lines } => {
                (iter * tx + t + (gwarp % 7)) % lines.max(1) as u64
            }
            Addressing::Random { lines } => {
                let mut x = gwarp
                    .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    .wrapping_add(iter.wrapping_mul(0xBF58_476D_1CE4_E5B9))
                    .wrapping_add(t.wrapping_mul(0x94D0_49BB_1331_11EB));
                x ^= x >> 31;
                x = x.wrapping_mul(0xD6E8_FEB8_6659_FD93);
                x ^= x >> 27;
                x % lines.max(1) as u64
            }
        };
        base + slot + idx * line
    }
}

/// The per-warp program: `body` repeats `o_itrs` times.
#[derive(Debug, Clone, Default)]
pub struct Program {
    pub prologue: Vec<Op>,
    pub body: Vec<Op>,
    pub o_itrs: u32,
    pub epilogue: Vec<Op>,
}

impl Program {
    /// Total dynamic op count per warp.
    pub fn dynamic_len(&self) -> u64 {
        self.prologue.len() as u64
            + self.body.len() as u64 * self.o_itrs as u64
            + self.epilogue.len() as u64
    }

    /// Number of shared-memory operations in one body iteration —
    /// feeds the model's `i_itrs` (paper: source-code analysis).
    pub fn smem_ops_per_iter(&self) -> u32 {
        self.body
            .iter()
            .filter(|op| matches!(op, Op::SharedLoad { .. } | Op::SharedStore { .. }))
            .count() as u32
    }

    /// Global transactions per warp in one body iteration (feeds the
    /// model's `gld_body`; source analysis, like `o_itrs`).
    pub fn gld_body_per_iter(&self) -> u32 {
        Self::global_txns(&self.body)
    }

    /// Global transactions per warp in prologue + epilogue combined.
    pub fn gld_edge(&self) -> u32 {
        Self::global_txns(&self.prologue) + Self::global_txns(&self.epilogue)
    }

    fn global_txns(ops: &[Op]) -> u32 {
        ops.iter()
            .map(|op| match op {
                Op::Load(p) | Op::Store(p) => p.txns as u32,
                _ => 0,
            })
            .sum()
    }

    /// Global-memory *instructions* per body iteration (the model's
    /// `mem_ops`: each is a dependent latency exposure point).
    pub fn mem_ops_per_iter(&self) -> u32 {
        self.body
            .iter()
            .filter(|op| matches!(op, Op::Load(_) | Op::Store(_)))
            .count() as u32
    }

    /// Whether the kernel touches shared memory at all.
    pub fn uses_smem(&self) -> bool {
        let has = |ops: &[Op]| {
            ops.iter()
                .any(|op| matches!(op, Op::SharedLoad { .. } | Op::SharedStore { .. }))
        };
        has(&self.prologue) || has(&self.body) || has(&self.epilogue)
    }
}

/// Launch configuration (`<<<blocks, threads, smem>>>`).
#[derive(Debug, Clone, PartialEq)]
pub struct Launch {
    pub blocks: u32,
    pub threads_per_block: u32,
    pub smem_per_block: u32,
    pub regs_per_thread: u32,
}

impl Launch {
    pub fn new(blocks: u32, threads_per_block: u32) -> Self {
        assert!(blocks > 0 && threads_per_block > 0);
        assert!(
            threads_per_block % 32 == 0,
            "threads per block must be a whole number of warps"
        );
        Launch { blocks, threads_per_block, smem_per_block: 0, regs_per_thread: 32 }
    }

    /// `#Wpb` in the paper.
    pub fn warps_per_block(&self) -> u32 {
        self.threads_per_block / 32
    }

    /// `#W`, total warps in the grid.
    pub fn total_warps(&self) -> u64 {
        self.blocks as u64 * self.warps_per_block() as u64
    }
}

/// A complete simulated kernel: launch config + per-warp program.
#[derive(Debug, Clone)]
pub struct Kernel {
    pub name: String,
    pub launch: Launch,
    pub program: Program,
}

impl Kernel {
    pub fn new(name: impl Into<String>, launch: Launch, program: Program) -> Self {
        Kernel { name: name.into(), launch, program }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn own_linear_addresses_are_unique_lines() {
        let pat = MemPat::new(4, Addressing::OwnLinear, 1);
        let mut seen = std::collections::HashSet::new();
        for gwarp in 0..8u64 {
            for iter in 0..4u64 {
                for t in 0..4u64 {
                    assert!(seen.insert(pat.address(gwarp, 0, iter, t, 4, 32, 0)));
                }
            }
        }
    }

    #[test]
    fn block_shared_repeats_across_warps() {
        let pat = MemPat::new(2, Addressing::BlockShared, 2);
        let a = pat.address(0, 5, 3, 1, 8, 32, 0);
        let b = pat.address(99, 5, 3, 1, 8, 32, 0);
        assert_eq!(a, b);
        let c = pat.address(0, 6, 3, 1, 8, 32, 0);
        assert_ne!(a, c);
    }

    #[test]
    fn grid_shared_repeats_across_blocks() {
        let pat = MemPat::new(2, Addressing::GridShared, 3);
        assert_eq!(
            pat.address(0, 0, 7, 0, 8, 32, 1),
            pat.address(1234, 77, 7, 0, 8, 32, 1)
        );
    }

    #[test]
    fn hot_set_bounded() {
        let pat = MemPat::new(8, Addressing::Hot { lines: 16 }, 4);
        let base = (4u64 << 40) + 0;
        for gwarp in 0..32u64 {
            for iter in 0..8u64 {
                for t in 0..8u64 {
                    let a = pat.address(gwarp, 0, iter, t, 8, 32, 0);
                    assert!(a >= base && a < base + 16 * 32);
                }
            }
        }
    }

    #[test]
    fn random_bounded_and_deterministic() {
        let pat = MemPat::new(4, Addressing::Random { lines: 1024 }, 5);
        let a = pat.address(3, 0, 2, 1, 8, 32, 0);
        let b = pat.address(3, 0, 2, 1, 8, 32, 0);
        assert_eq!(a, b);
        assert!(a - (5u64 << 40) < 1024 * 32);
    }

    #[test]
    fn regions_disjoint() {
        let p1 = MemPat::new(1, Addressing::OwnLinear, 1);
        let p2 = MemPat::new(1, Addressing::OwnLinear, 2);
        // Even the largest index in region 1 sits below region 2's base.
        let hi = p1.address(u32::MAX as u64, 0, 0, 0, 1, 32, 15);
        let lo = p2.address(0, 0, 0, 0, 1, 32, 0);
        assert!(hi < lo);
    }

    #[test]
    fn program_dynamic_len() {
        let p = Program {
            prologue: vec![Op::Compute(4)],
            body: vec![Op::Compute(1), Op::Sync],
            o_itrs: 10,
            epilogue: vec![Op::Compute(2)],
        };
        assert_eq!(p.dynamic_len(), 1 + 2 * 10 + 1);
        assert_eq!(p.smem_ops_per_iter(), 0);
        assert!(!p.uses_smem());
    }

    #[test]
    fn smem_detection() {
        let p = Program {
            prologue: vec![],
            body: vec![Op::SharedLoad { conflict: 1 }, Op::Compute(2)],
            o_itrs: 4,
            epilogue: vec![],
        };
        assert!(p.uses_smem());
        assert_eq!(p.smem_ops_per_iter(), 1);
    }

    #[test]
    fn launch_warp_math() {
        let l = Launch::new(128, 256);
        assert_eq!(l.warps_per_block(), 8);
        assert_eq!(l.total_warps(), 1024);
    }

    #[test]
    #[should_panic]
    fn non_warp_multiple_rejected() {
        Launch::new(1, 33);
    }
}
