//! Hand-rolled CLI (clap is not in the offline vendor set — DESIGN.md
//! "Offline substitutions"): subcommand + `--flag value` parsing and
//! the command implementations behind the `gpufreq` launcher.
//!
//! Every prediction a command makes — validate, advise, serve, the
//! fig13/fig14/ablation reports — routes through one `engine::Engine`
//! built by [`build_engine`]; `--backend` picks the execution strategy
//! and the shared grid cache comes for free.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::baselines::standard_baselines;
use crate::config::{self, Config};
use crate::coordinator::sweep::run_sweep;
use crate::coordinator::validate::{validate_with_engine, SamplePoint, Validation};
use crate::dvfs::{advise_with_handles, Objective};
use crate::engine::{BatchServer, Engine, StreamJob};
use crate::kernels;
use crate::microbench;
use crate::model::{HwParams, KernelCounters};
use crate::planner::{self, Job, PlanObjective, PlannerConfig};
use crate::profiler;
use crate::registry::{DeviceRegistry, KernelCatalog, KernelId};
use crate::report::tables;
use crate::scheduler::{Event, JobSpec, SchedulerConfig, SchedulerCore};
use crate::service::{Service, ServiceConfig, ServiceState};
use crate::sim::isa::Kernel;

pub const USAGE: &str = "\
gpufreq — GPGPU performance estimation with core & memory frequency scaling
          (reproduction of Wang & Chu, 2017; see DESIGN.md)

USAGE: gpufreq <COMMAND> [OPTIONS]

COMMANDS:
  list-kernels            List the Table VI workloads
  microbench              Run the §IV probes: Eq. (4) fit, dm_del, latencies
  profile <KERNEL>        One-shot baseline profile of a kernel (or 'all')
  devices                 Register every configs/*.toml GPU (or just
                          --config) into a device registry — §IV probes
                          measure each device's parameters — and list
                          the dev-<n> handles (DESIGN.md §10)
  kernels                 Profile the workloads once at the baseline and
                          list the kernel catalog's krn-<n> handles
  sweep                   Simulate kernels over the frequency grid (ground truth)
  validate                Full Fig. 13/14 validation: simulate + predict + MAPE
  report <ARTIFACT>       Regenerate a paper artifact: table1 table2 table3
                          table6 fig2 fig5 fig12 fig13 fig14 ablation power
  advise <KERNEL>         DVFS energy advisor for one kernel (paper §VII
                          application), resolved through the device registry
  plan                    Fleet DVFS planner (DESIGN.md §11): register every
                          configs/*.toml device, profile the workloads,
                          synthesize a --jobs job fleet and print the
                          energy-minimal assignment vs. the run-at-max-
                          frequency baseline
  jobs                    Streaming scheduler (DESIGN.md §14): replay a
                          deterministic --jobs arrival trace on the virtual
                          clock — admission control rejects provably-
                          unmeetable deadlines at submit, arrivals place by
                          incremental repair, epochs re-solve the rolling
                          horizon — then print each job's lifecycle and the
                          repair vs full-solve work split
  serve                   Run the standing HTTP prediction service:
                          v2 (handle protocol): POST/GET /v2/devices ·
                          POST/GET /v2/kernels · POST /v2/predict (batch) ·
                          POST /v2/advise · POST /v2/plan (fleet planner) ·
                          POST+GET /v2/jobs · GET+DELETE /v2/jobs/{id}
                          (streaming scheduler, DESIGN.md §14) ·
                          POST /v2/observations (live model-accuracy MAPE);
                          v1 (compat shim): POST /v1/predict · /v1/grid ·
                          /v1/advise; GET /healthz · /metrics ·
                          /debug/traces (slow-trace ring) ·
                          /debug/plans (plan provenance ring) ·
                          /debug/drift (model drift states) —
                          DESIGN.md §9–§13. Runs until stdin closes
                          (EOF drains gracefully)
  stream-demo             Demo the streaming prediction path (always uses the
                          PJRT batching backend; --backend is ignored)
  help                    Show this message

OPTIONS:
  --config <PATH>         TOML config (default: configs/gtx980.toml if present);
                          devices/plan: restrict registration to this config
  --kernels <A,B,...>     Restrict to these kernels
  --backend <NAME>        Prediction backend: native | batch | pjrt (default native)
  --pjrt                  Alias for --backend pjrt
  --no-cache              Disable the engine's frequency-grid cache
  --csv                   Emit CSV instead of ASCII tables
  --objective <NAME>      advise: energy | edp | slack:<frac>;
                          plan/jobs: energy | edp (default energy)
  --workers <N>           sweep/validate/serve parallelism (default: # cpus)
  --jobs <N>              plan/jobs: synthetic fleet size (default 24)
  --device-cap <N>        plan: per-device concurrency cap (default 0 =
                          balanced, ceil(jobs / devices))
  --addr <HOST:PORT>      serve: bind address (default 127.0.0.1:8077; port 0
                          picks an ephemeral port)
  --queue-depth <N>       serve: admission credit beyond the executor pool —
                          up to workers + N connections stay live on the
                          readiness poll loop; past that, new connections are
                          shed with 429 + Retry-After (default 64)
  --slow-us <US>          serve: only retain request traces at least this
                          slow, in microseconds, for GET /debug/traces
                          (default 0 = retain every trace)
  --trace-capacity <N>    serve: slow-trace ring size; 0 disables retention
                          entirely — stage histograms and X-Request-Id stay
                          on (default 256)
  --explain               plan: print the solver telemetry (plan id, phase
                          timings, search counters) and the per-job
                          provenance — deadline slack, energy saved vs. the
                          max-frequency point, and the runner-up frequency
                          with the constraint that rejected it
  --plan-ring <N>         serve: plan-provenance ring size for
                          GET /debug/plans; 0 disables retention
                          (default 64)
  --event-log <PATH>      serve: append structured JSONL events
                          (request_span · solve · observation ·
                          drift_transition · job_transition) to PATH; off by
                          default. A bounded queue feeds a dedicated writer
                          thread — overflow is dropped and counted in
                          /metrics, never blocking a request
  --replan-interval <MS>  serve/jobs: streaming-scheduler re-plan epoch in
                          milliseconds; between epochs arrivals are placed
                          by incremental repair (default 1000)
  --horizon <MS>          serve/jobs: rolling planning horizon in
                          milliseconds — queued jobs whose deadline lies
                          beyond it wait for a later epoch (default 30000)
";

/// Parsed command line.
#[derive(Debug, Clone)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub config: Option<PathBuf>,
    pub kernels: Option<Vec<String>>,
    pub backend: String,
    pub cache: bool,
    pub csv: bool,
    pub objective: String,
    pub workers: usize,
    pub jobs: usize,
    pub device_cap: usize,
    pub addr: String,
    pub queue_depth: usize,
    pub slow_us: f64,
    pub trace_capacity: usize,
    pub explain: bool,
    pub plan_ring: usize,
    pub event_log: Option<PathBuf>,
    pub replan_interval_ms: f64,
    pub horizon_ms: f64,
}

impl Default for Args {
    fn default() -> Self {
        Args {
            command: "help".into(),
            positional: Vec::new(),
            config: None,
            kernels: None,
            backend: "native".into(),
            cache: true,
            csv: false,
            objective: "energy".into(),
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            jobs: 24,
            device_cap: 0,
            addr: "127.0.0.1:8077".into(),
            queue_depth: 64,
            slow_us: 0.0,
            trace_capacity: crate::obs::DEFAULT_TRACE_CAPACITY,
            explain: false,
            plan_ring: crate::service::DEFAULT_PLAN_RING,
            event_log: None,
            replan_interval_ms: 1_000.0,
            horizon_ms: 30_000.0,
        }
    }
}

/// Parse argv (excluding the binary name).
pub fn parse_args(argv: &[String]) -> Result<Args> {
    let mut args = Args::default();
    let mut it = argv.iter().peekable();
    let Some(cmd) = it.next() else {
        return Ok(args);
    };
    args.command = cmd.clone();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--config" => {
                args.config =
                    Some(PathBuf::from(it.next().context("--config needs a path")?))
            }
            "--kernels" => {
                args.kernels = Some(
                    it.next()
                        .context("--kernels needs a list")?
                        .split(',')
                        .map(|s| s.trim().to_string())
                        .collect(),
                )
            }
            "--backend" => {
                let b = it.next().context("--backend needs a name")?.clone();
                match b.as_str() {
                    "native" | "batch" | "pjrt" => args.backend = b,
                    other => bail!("unknown backend {other} (native | batch | pjrt)"),
                }
            }
            "--pjrt" => args.backend = "pjrt".into(),
            "--no-cache" => args.cache = false,
            "--csv" => args.csv = true,
            "--objective" => {
                args.objective = it.next().context("--objective needs a value")?.clone()
            }
            "--workers" => {
                args.workers = it
                    .next()
                    .context("--workers needs a number")?
                    .parse()
                    .context("--workers must be an integer")?
            }
            "--jobs" => {
                args.jobs = it
                    .next()
                    .context("--jobs needs a number")?
                    .parse()
                    .context("--jobs must be an integer")?
            }
            "--device-cap" => {
                args.device_cap = it
                    .next()
                    .context("--device-cap needs a number")?
                    .parse()
                    .context("--device-cap must be an integer")?
            }
            "--addr" => {
                args.addr = it.next().context("--addr needs host:port")?.clone()
            }
            "--queue-depth" => {
                args.queue_depth = it
                    .next()
                    .context("--queue-depth needs a number")?
                    .parse()
                    .context("--queue-depth must be an integer")?
            }
            "--slow-us" => {
                args.slow_us = it
                    .next()
                    .context("--slow-us needs a number")?
                    .parse()
                    .context("--slow-us must be a number of microseconds")?;
                if !(args.slow_us.is_finite() && args.slow_us >= 0.0) {
                    bail!("--slow-us must be finite and non-negative");
                }
            }
            "--trace-capacity" => {
                args.trace_capacity = it
                    .next()
                    .context("--trace-capacity needs a number")?
                    .parse()
                    .context("--trace-capacity must be an integer")?
            }
            "--explain" => args.explain = true,
            "--plan-ring" => {
                args.plan_ring = it
                    .next()
                    .context("--plan-ring needs a number")?
                    .parse()
                    .context("--plan-ring must be an integer")?
            }
            "--event-log" => {
                args.event_log =
                    Some(PathBuf::from(it.next().context("--event-log needs a path")?))
            }
            "--replan-interval" => {
                args.replan_interval_ms = it
                    .next()
                    .context("--replan-interval needs a number of milliseconds")?
                    .parse()
                    .context("--replan-interval must be a number of milliseconds")?;
                if !(args.replan_interval_ms.is_finite() && args.replan_interval_ms > 0.0) {
                    bail!("--replan-interval must be finite and positive");
                }
            }
            "--horizon" => {
                args.horizon_ms = it
                    .next()
                    .context("--horizon needs a number of milliseconds")?
                    .parse()
                    .context("--horizon must be a number of milliseconds")?;
                if !(args.horizon_ms.is_finite() && args.horizon_ms > 0.0) {
                    bail!("--horizon must be finite and positive");
                }
            }
            flag if flag.starts_with("--") => bail!("unknown flag {flag}"),
            pos => args.positional.push(pos.to_string()),
        }
    }
    Ok(args)
}

fn load_config(args: &Args) -> Result<Config> {
    if let Some(p) = &args.config {
        return config::load(p);
    }
    let default = PathBuf::from("configs/gtx980.toml");
    if default.exists() {
        config::load(&default)
    } else {
        Ok(Config::default())
    }
}

fn selected_kernels(args: &Args, cfg: &Config) -> Result<Vec<Kernel>> {
    let names: Option<&[String]> = args
        .kernels
        .as_deref()
        .or(if cfg.kernels.is_empty() { None } else { Some(&cfg.kernels) });
    match names {
        None => Ok(kernels::all()),
        Some(ns) => ns
            .iter()
            .map(|n| kernels::by_name(n).with_context(|| format!("unknown kernel {n}")))
            .collect(),
    }
}

fn print_table(t: &crate::report::Table, csv: bool) {
    if csv {
        print!("{}", t.csv());
    } else {
        print!("{}", t.ascii());
    }
}

/// Drain-worker cap for the PJRT service. The artifact executes a
/// fixed 1024-row padded batch per drain, so spreading a 49-pair grid
/// over ncpus queues would run many nearly-empty padded batches;
/// a few workers keep queues busy without collapsing occupancy.
const PJRT_MAX_WORKERS: usize = 4;

/// One construction path for the PJRT service (worker policy,
/// batching window, error context) — used by `build_engine` and
/// `serve` so the two cannot diverge.
fn start_pjrt_server(args: &Args, hw: HwParams) -> Result<BatchServer> {
    let workers = args.workers.clamp(1, PJRT_MAX_WORKERS);
    let (server, _handles) =
        BatchServer::start_auto(hw.to_f32(), Duration::from_millis(2), workers)
            .context("starting the PJRT batch service")?;
    Ok(server)
}

/// Build the prediction engine every command shares, per `--backend`.
pub fn build_engine(args: &Args, hw: HwParams) -> Result<Engine> {
    let builder = match args.backend.as_str() {
        "native" => Engine::builder(hw).scalar(),
        "batch" => Engine::builder(hw).batch(args.workers),
        "pjrt" => Engine::builder(hw).pjrt(start_pjrt_server(args, hw)?),
        other => bail!("unknown backend {other}"),
    };
    let builder = if args.cache { builder } else { builder.without_cache() };
    Ok(builder.build())
}

fn print_cache_line(engine: &Engine) {
    if engine.has_cache() {
        let s = engine.cache_stats();
        println!(
            "engine[{}] cache: {} hits / {} misses ({:.0}% hit rate, {} entries)",
            engine.backend_name(),
            s.hits,
            s.misses,
            s.hit_rate() * 100.0,
            s.entries
        );
    }
}

/// Run a parsed command. Returns the process exit code.
pub fn run(args: Args) -> Result<i32> {
    let cfg = load_config(&args)?;
    let spec = cfg.gpu.clone();
    let baseline = cfg.sweep.baseline();
    let pairs = cfg.sweep.pairs();

    match args.command.as_str() {
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
        }
        "list-kernels" => {
            print_table(&tables::table6(&selected_kernels(&args, &cfg)?), args.csv);
        }
        "microbench" => {
            let ex = microbench::extract(&spec, baseline);
            println!(
                "dm_lat  = {:.2} * (cf/mf) + {:.2} core cycles   (R^2 = {:.4}; paper: 222.78/277.32)",
                ex.hw.dm_lat_a, ex.hw.dm_lat_b, ex.dm_lat_fit.r_squared
            );
            println!(
                "dm_del  = {:.2} mem cycles/txn   bandwidth efficiency {:.1}%  ({:.1} GB/s)",
                ex.hw.dm_del,
                ex.bandwidth_at_baseline.efficiency * 100.0,
                ex.bandwidth_at_baseline.achieved_gbps
            );
            println!("l2_lat  = {:.1} core cycles   l2_del = {:.1}", ex.hw.l2_lat, ex.hw.l2_del);
            println!("sh_lat  = {:.1} core cycles", ex.hw.sh_lat);
            println!("inst    = {:.2} cycles/instruction", ex.hw.inst_cycle);
        }
        "profile" => {
            let what = args.positional.first().map(String::as_str).unwrap_or("all");
            let ks = if what == "all" {
                selected_kernels(&args, &cfg)?
            } else {
                vec![kernels::by_name(what).with_context(|| format!("unknown kernel {what}"))?]
            };
            let mut t = crate::report::Table::new(
                &format!(
                    "Baseline profile @ {:.0}/{:.0} MHz",
                    baseline.core_mhz, baseline.mem_mhz
                ),
                &["kernel", "time_us", "l2_hr", "gld", "avr_inst", "#Aw", "#SM", "smem", "regime"],
            );
            let ex = microbench::extract(&spec, baseline);
            let engine = build_engine(&args, ex.hw)?;
            for k in &ks {
                let p = profiler::profile_at(&spec, k, baseline);
                let pred = engine
                    .predict_one(&p.counters, baseline.core_mhz, baseline.mem_mhz)?;
                t.row(vec![
                    p.kernel.clone(),
                    format!("{:.1}", p.baseline_time_us),
                    format!("{:.3}", p.counters.l2_hr),
                    format!("{:.1}", p.counters.gld_trans),
                    format!("{:.2}", p.counters.avr_inst),
                    format!("{:.0}", p.counters.aw),
                    format!("{:.0}", p.counters.n_sm),
                    format!("{}", p.counters.uses_smem),
                    match pred.regime {
                        Some(r) => format!("{r:?}"),
                        None => "-".to_string(),
                    },
                ]);
            }
            print_table(&t, args.csv);
        }
        "devices" => {
            // One registry, one row per config: each GPU's parameters
            // are measured by the §IV probes against its own spec.
            let registry = DeviceRegistry::new();
            let paths = discover_configs(&args)?;
            let mut t = crate::report::Table::new(
                "Device registry (parameters measured per config, §IV)",
                &[
                    "handle", "name", "dm_lat_a", "dm_lat_b", "dm_del", "l2_lat", "sh_lat",
                    "inst", "P@1000/1000 W",
                ],
            );
            for path in &paths {
                let id = registry
                    .register_from_config(path)
                    .with_context(|| format!("registering {}", path.display()))?;
                let r = registry.get(id).expect("just registered");
                t.row(vec![
                    id.to_string(),
                    r.name.clone(),
                    format!("{:.2}", r.hw.dm_lat_a),
                    format!("{:.2}", r.hw.dm_lat_b),
                    format!("{:.2}", r.hw.dm_del),
                    format!("{:.1}", r.hw.l2_lat),
                    format!("{:.1}", r.hw.sh_lat),
                    format!("{:.2}", r.hw.inst_cycle),
                    format!("{:.1}", r.power.power_w(1000.0, 1000.0)),
                ]);
            }
            print_table(&t, args.csv);
        }
        "kernels" => {
            // Profile once at the baseline (the paper's one-shot
            // counter pass) and show the catalog handles the v2 API
            // addresses kernels by.
            let catalog = KernelCatalog::new();
            let ks = selected_kernels(&args, &cfg)?;
            let mut t = crate::report::Table::new(
                &format!(
                    "Kernel catalog (profiled @ {:.0}/{:.0} MHz)",
                    baseline.core_mhz, baseline.mem_mhz
                ),
                &["handle", "name", "time_us", "l2_hr", "gld", "avr_inst", "#Aw", "smem"],
            );
            for k in &ks {
                let p = profiler::profile_at(&spec, k, baseline);
                let id = catalog.register(&k.name, p.counters);
                t.row(vec![
                    id.to_string(),
                    k.name.clone(),
                    format!("{:.1}", p.baseline_time_us),
                    format!("{:.3}", p.counters.l2_hr),
                    format!("{:.1}", p.counters.gld_trans),
                    format!("{:.2}", p.counters.avr_inst),
                    format!("{:.0}", p.counters.aw),
                    format!("{}", p.counters.uses_smem),
                ]);
            }
            print_table(&t, args.csv);
        }
        "sweep" => {
            let ks = selected_kernels(&args, &cfg)?;
            let sweep = run_sweep(&spec, &ks, &pairs, args.workers);
            let mut t = crate::report::Table::new(
                "Ground-truth sweep (simulator)",
                &["kernel", "core MHz", "mem MHz", "time_us", "l2_hr"],
            );
            for p in &sweep.points {
                t.row(vec![
                    p.kernel.clone(),
                    format!("{:.0}", p.core_mhz),
                    format!("{:.0}", p.mem_mhz),
                    format!("{:.2}", p.time_us),
                    format!("{:.3}", p.l2_hr),
                ]);
            }
            print_table(&t, args.csv);
        }
        "validate" => {
            let ks = selected_kernels(&args, &cfg)?;
            let ex = microbench::extract(&spec, baseline);
            let engine = build_engine(&args, ex.hw)?;
            let v = validate_with_engine(&spec, &ks, &engine, &pairs)?;
            let (chart, summary) = tables::fig14(&v);
            println!("{chart}");
            print_table(&summary, args.csv);
            print_cache_line(&engine);
        }
        "report" => {
            let what = args.positional.first().map(String::as_str).unwrap_or("");
            run_report(what, &args, &cfg)?;
        }
        "advise" => {
            let name = args.positional.first().context("advise needs a kernel name")?;
            let k = kernels::by_name(name).with_context(|| format!("unknown kernel {name}"))?;
            let ex = microbench::extract(&spec, baseline);
            let p = profiler::profile_at(&spec, &k, baseline);
            let objective = match args.objective.as_str() {
                "energy" => Objective::Energy,
                "edp" => Objective::Edp,
                s if s.starts_with("slack:") => Objective::EnergyWithSlack(
                    s.trim_start_matches("slack:").parse().context("bad slack value")?,
                ),
                other => bail!("unknown objective {other}"),
            };
            // Resolve through the registry (DESIGN.md §10): the device
            // owns its measured parameters and `[power]` model, the
            // catalog owns the baseline profile, and the advisor works
            // on handles — the same path `POST /v2/advise` takes.
            let registry = Arc::new(DeviceRegistry::new());
            let device_name = cfg.device_name.clone().unwrap_or_else(|| "default".to_string());
            let device = registry
                .try_register(&device_name, ex.hw, cfg.power.clone(), usize::MAX)
                .map_err(|e| anyhow::anyhow!("registering `{device_name}`: {e}"))?;
            let catalog = Arc::new(KernelCatalog::new());
            let kernel = catalog.register(name, p.counters);
            let engine = build_engine(&args, ex.hw)?.with_handles(registry, catalog, device)?;
            let (best, points) = advise_with_handles(&engine, device, kernel, &pairs, objective)?;
            let title = format!(
                "DVFS advisor for {name} [{device}/{kernel} on {device_name}] ({objective:?})"
            );
            let mut t = crate::report::Table::new(
                &title,
                &[
                    "core MHz", "mem MHz", "time_us", "power W", "dyn W", "leak W",
                    "energy mJ", "EDP",
                ],
            );
            for cp in &points {
                t.row(vec![
                    format!("{:.0}", cp.core_mhz),
                    format!("{:.0}", cp.mem_mhz),
                    format!("{:.1}", cp.time_us),
                    format!("{:.1}", cp.power_w),
                    format!("{:.1}", cp.power_dynamic_w),
                    format!("{:.1}", cp.power_leakage_w),
                    format!("{:.2}", cp.energy_mj),
                    format!("{:.1}", cp.edp),
                ]);
            }
            print_table(&t, args.csv);
            println!(
                "BEST: {:.0}/{:.0} MHz  time {:.1} us  power {:.1} W ({:.1} dyn + {:.1} leak)  energy {:.2} mJ",
                best.core_mhz,
                best.mem_mhz,
                best.time_us,
                best.power_w,
                best.power_dynamic_w,
                best.power_leakage_w,
                best.energy_mj
            );
        }
        "plan" => {
            run_plan(&args, &cfg)?;
        }
        "jobs" => {
            run_jobs(&args, &cfg)?;
        }
        "serve" => {
            run_serve(&args, &cfg)?;
        }
        "stream-demo" => {
            // stream-demo IS the PJRT-service demo: --backend is
            // ignored here (USAGE documents the command as PJRT-backed).
            let ex = microbench::extract(&spec, baseline);
            let server = start_pjrt_server(&args, ex.hw)?;
            println!(
                "PJRT platform: {} ({} request shards)",
                server.platform(),
                server.shard_count()
            );
            let mut builder = Engine::builder(ex.hw).pjrt(server.clone());
            if !args.cache {
                builder = builder.without_cache();
            }
            let engine = builder.build();
            let ks = selected_kernels(&args, &cfg)?;
            let names: Vec<String> = ks.iter().map(|k| k.name.clone()).collect();
            // Profile kernels on scoped threads (one simulator run each
            // dominates serve's wall clock); predictions then stream
            // through the engine's sharded workers.
            let mut counters: Vec<Option<KernelCounters>> = vec![None; ks.len()];
            std::thread::scope(|scope| {
                for (slot, k) in counters.iter_mut().zip(&ks) {
                    let spec = &spec;
                    scope.spawn(move || {
                        *slot = Some(profiler::profile_at(spec, k, baseline).counters);
                    });
                }
            });
            let jobs: Vec<StreamJob> = counters
                .into_iter()
                .enumerate()
                .map(|(i, c)| StreamJob {
                    id: i as u64,
                    counters: c.expect("profiled"),
                    pairs: pairs.clone(),
                })
                .collect();
            for reply in engine.predict_stream(jobs) {
                let ests = reply
                    .result
                    .map_err(|e| anyhow::anyhow!("stream job failed: {e}"))?;
                let best = ests
                    .iter()
                    .zip(&pairs)
                    .min_by(|a, b| a.0.time_us.total_cmp(&b.0.time_us))
                    .expect("non-empty grid");
                println!(
                    "{:8} {} predictions; fastest {:.0}/{:.0} MHz -> {:.1} us",
                    names[reply.id as usize],
                    ests.len(),
                    best.1 .0,
                    best.1 .1,
                    best.0.time_us
                );
            }
            let st = server.stats();
            println!(
                "served {} rows in {} batches (mean occupancy {:.1}%)",
                st.requests(),
                st.batches(),
                st.mean_occupancy() * 100.0
            );
            print_cache_line(&engine);
        }
        other => {
            eprintln!("unknown command `{other}`\n");
            print!("{USAGE}");
            return Ok(2);
        }
    }
    Ok(0)
}

/// Device configs to register: just `--config` when given, otherwise
/// every `configs/*.toml`, sorted for a stable handle order.
fn discover_configs(args: &Args) -> Result<Vec<PathBuf>> {
    let paths: Vec<PathBuf> = match &args.config {
        Some(p) => vec![p.clone()],
        None => {
            let mut found: Vec<PathBuf> = std::fs::read_dir("configs")
                .map(|rd| {
                    rd.filter_map(|e| e.ok().map(|e| e.path()))
                        .filter(|p| p.extension().is_some_and(|x| x == "toml"))
                        .collect()
                })
                .unwrap_or_default();
            found.sort();
            found
        }
    };
    if paths.is_empty() {
        bail!(
            "no device configs found (run from rust/ with a configs/ dir, \
             or pass --config)"
        );
    }
    Ok(paths)
}

/// `gpufreq plan`: the fleet planner demo (DESIGN.md §11). Registers
/// every discovered GPU config (§IV probes measure each device's own
/// parameters), profiles the selected kernels once at the baseline,
/// synthesizes a deterministic fleet of `--jobs` jobs (mixed workload
/// scales, two in three with a latency budget) and prints the planned
/// assignment next to the run-everything-at-max-frequency baseline.
fn run_plan(args: &Args, cfg: &Config) -> Result<()> {
    let spec = cfg.gpu.clone();
    let baseline = cfg.sweep.baseline();
    let registry = Arc::new(DeviceRegistry::new());
    for path in discover_configs(args)? {
        registry
            .register_from_config(&path)
            .with_context(|| format!("registering {}", path.display()))?;
    }
    let records = registry.list();
    let primary = records.first().expect("discover_configs is non-empty").clone();

    let catalog = Arc::new(KernelCatalog::new());
    let ks = selected_kernels(args, cfg)?;
    // One-shot baseline profiles (the paper's counter pass) on scoped
    // threads — the simulator runs dominate the wall clock. Register
    // serially afterwards so handle numbering stays deterministic.
    let mut profiled: Vec<Option<(KernelCounters, f64)>> = vec![None; ks.len()];
    std::thread::scope(|scope| {
        for (slot, k) in profiled.iter_mut().zip(&ks) {
            let spec = &spec;
            scope.spawn(move || {
                let p = profiler::profile_at(spec, k, baseline);
                *slot = Some((p.counters, p.baseline_time_us));
            });
        }
    });
    let kernels: Vec<(KernelId, f64)> = ks
        .iter()
        .zip(profiled)
        .map(|(k, p)| {
            let (counters, base_us) = p.expect("profiled");
            (catalog.register(&k.name, counters), base_us)
        })
        .collect();

    let engine =
        build_engine(args, primary.hw)?.with_handles(Arc::clone(&registry), catalog, primary.id)?;

    // Deterministic synthetic fleet: cycle kernels, vary the workload
    // scale 1–5×, and give two jobs in three a latency budget with
    // comfortable headroom over the baseline-clock profile (max
    // frequency runs faster than the baseline clocks, so every budget
    // is meetable and the planner has real slack to spend).
    let n = args.jobs.max(1);
    let mut jobs = Vec::with_capacity(n);
    for i in 0..n {
        let (kid, base_us) = kernels[i % kernels.len()];
        let scale = (1 + i % 5) as f64;
        let mut job = Job::new(format!("job-{i}"), kid, scale);
        if i % 3 != 0 {
            let headroom = if i % 2 == 0 { 2.0 } else { 3.0 };
            job = job.with_deadline(headroom * scale * base_us);
        }
        jobs.push(job);
    }
    let device_cap = if args.device_cap == 0 {
        n.div_ceil(records.len())
    } else {
        args.device_cap
    };
    let objective = match args.objective.as_str() {
        "energy" => PlanObjective::Energy,
        "edp" => PlanObjective::Edp,
        other => bail!("plan supports --objective energy | edp (got {other})"),
    };
    let pcfg = PlannerConfig { objective, device_cap, ..PlannerConfig::default() };
    // One evaluation pass yields both the plan and the naive foil.
    let (planned, naive) = planner::plan_with_baseline(&engine, &jobs, &pcfg)?;
    let naive = naive.context("max-frequency baseline is unplaceable under this cap")?;

    let mut t = crate::report::Table::new(
        &format!(
            "Fleet plan: {n} jobs over {} devices (cap {device_cap}/device, {})",
            records.len(),
            objective.name()
        ),
        &[
            "job", "kernel", "device", "core MHz", "mem MHz", "time_us", "deadline_us",
            "power W", "dyn W", "leak W", "energy mJ",
        ],
    );
    for a in &planned.assignments {
        let job = &jobs[a.job];
        t.row(vec![
            job.name.clone(),
            job.kernel.to_string(),
            a.device.to_string(),
            format!("{:.0}", a.point.core_mhz),
            format!("{:.0}", a.point.mem_mhz),
            format!("{:.1}", a.time_us),
            match job.deadline_us {
                Some(d) => format!("{d:.1}"),
                None => "-".to_string(),
            },
            format!("{:.1}", a.power_w),
            format!("{:.1}", a.power_dynamic_w),
            format!("{:.1}", a.power_leakage_w),
            format!("{:.2}", a.energy_mj),
        ]);
    }
    print_table(&t, args.csv);
    let saved = planned.energy_savings_pct_vs(&naive);
    println!(
        "PLAN : {:.1} mJ total ({} local-search steps, {} deadline violations, longest job {:.1} us)",
        planned.total_energy_mj,
        planned.swaps_applied,
        planned.deadline_violations(&jobs),
        planned.max_time_us
    );
    println!(
        "NAIVE: {:.1} mJ at max frequency ({} deadline violations) -> {saved:.1}% energy saved",
        naive.total_energy_mj,
        naive.deadline_violations(&jobs)
    );
    if args.explain {
        let r = &planned.report;
        println!(
            "SOLVE: {} · {:.0} us (build {:.0} · greedy {:.0} · repair {:.0} · swap {:.0})",
            r.plan_id_str(),
            r.total_us,
            r.build_us,
            r.greedy_us,
            r.repair_us,
            r.swap_us
        );
        println!(
            "       {} candidates · {} slab calls · relocations {}/{} · swaps {}/{} (accepted/tried)",
            r.candidates_evaluated,
            r.slab_calls,
            r.relocations_accepted,
            r.relocations_tried,
            r.swaps_accepted,
            r.swaps_tried
        );
        let mut t = crate::report::Table::new(
            "Plan provenance (negative d_mJ = energy saved vs. running flat-out)",
            &["job", "slack_us", "d_mJ vs max", "runner-up", "ru time_us", "ru mJ", "rejected by"],
        );
        for e in &r.explains {
            t.row(vec![
                jobs[e.job].name.clone(),
                match e.deadline_slack_us {
                    Some(s) => format!("{s:.1}"),
                    None => "-".to_string(),
                },
                format!("{:+.2}", e.energy_delta_vs_max_mj),
                match e.runner_up {
                    Some(u) => format!("{:.0}/{:.0} MHz", u.point.core_mhz, u.point.mem_mhz),
                    None => "-".to_string(),
                },
                match e.runner_up {
                    Some(u) => format!("{:.1}", u.time_us),
                    None => "-".to_string(),
                },
                match e.runner_up {
                    Some(u) => format!("{:.2}", u.energy_mj),
                    None => "-".to_string(),
                },
                match e.runner_up {
                    Some(u) => u.rejected_by.to_string(),
                    None => "-".to_string(),
                },
            ]);
        }
        print_table(&t, args.csv);
    }
    print_cache_line(&engine);
    Ok(())
}

/// `gpufreq jobs`: the streaming scheduler (DESIGN.md §14) replayed on
/// the virtual clock. Registers every configs/*.toml device, profiles
/// the selected kernels once, then drives a deterministic arrival
/// trace through [`SchedulerCore`]: admission control rejects a
/// scripted provably-unmeetable deadline at submit, arrivals place by
/// incremental repair, re-plan epochs sweep the rolling horizon, and a
/// mid-trace device bounce displaces and re-places work. Ends with the
/// per-job lifecycle table and the repair vs full-solve work split.
fn run_jobs(args: &Args, cfg: &Config) -> Result<()> {
    let spec = cfg.gpu.clone();
    let baseline = cfg.sweep.baseline();
    let registry = Arc::new(DeviceRegistry::new());
    for path in discover_configs(args)? {
        registry
            .register_from_config(&path)
            .with_context(|| format!("registering {}", path.display()))?;
    }
    let records = registry.list();
    let primary = records.first().expect("discover_configs is non-empty").clone();

    let catalog = Arc::new(KernelCatalog::new());
    let ks = selected_kernels(args, cfg)?;
    // Same one-shot counter pass as `plan`: profile on scoped threads,
    // register serially for deterministic handle numbering.
    let mut profiled: Vec<Option<(KernelCounters, f64)>> = vec![None; ks.len()];
    std::thread::scope(|scope| {
        for (slot, k) in profiled.iter_mut().zip(&ks) {
            let spec = &spec;
            scope.spawn(move || {
                let p = profiler::profile_at(spec, k, baseline);
                *slot = Some((p.counters, p.baseline_time_us));
            });
        }
    });
    let kernels: Vec<(KernelId, f64)> = ks
        .iter()
        .zip(profiled)
        .map(|(k, p)| {
            let (counters, base_us) = p.expect("profiled");
            (catalog.register(&k.name, counters), base_us)
        })
        .collect();

    let engine =
        build_engine(args, primary.hw)?.with_handles(Arc::clone(&registry), catalog, primary.id)?;

    let n = args.jobs.max(1);
    let device_cap = if args.device_cap == 0 {
        n.div_ceil(records.len())
    } else {
        args.device_cap
    };
    let objective = match args.objective.as_str() {
        "energy" => PlanObjective::Energy,
        "edp" => PlanObjective::Edp,
        other => bail!("jobs supports --objective energy | edp (got {other})"),
    };
    let mut core = SchedulerCore::new(SchedulerConfig {
        replan_interval_us: args.replan_interval_ms * 1e3,
        horizon_us: args.horizon_ms * 1e3,
        planner: PlannerConfig { objective, device_cap, ..PlannerConfig::default() },
        ..SchedulerConfig::default()
    });

    // Deterministic arrival trace: bursty inter-arrival gaps scaled by
    // the mean baseline runtime, workload scale 1–5×, and two jobs in
    // three carrying a meetable deadline (the `plan` recipe). Job n/2
    // is scripted provably unmeetable so admission has something to
    // reject, and the last device bounces down/up around the same
    // burst so displacement and re-placement both show up.
    const GAPS: [f64; 5] = [0.2, 1.1, 0.4, 1.9, 0.7];
    let mean_us = kernels.iter().map(|&(_, b)| b).sum::<f64>() / kernels.len() as f64;
    let bounce = records.last().expect("non-empty").id;
    let mut now = 0.0;
    let mut rejected = Vec::new();
    for i in 0..n {
        now += GAPS[i % GAPS.len()] * mean_us;
        core.run_until(&engine, now);
        if records.len() > 1 && i == n / 2 {
            core.schedule(now, Event::DeviceDown(bounce));
            core.schedule(now + 2.0 * mean_us, Event::DeviceUp(bounce));
        }
        let (kid, base_us) = kernels[i % kernels.len()];
        let scale = (1 + i % 5) as f64;
        let mut job = JobSpec::new(format!("{}-{i}", ks[i % ks.len()].name), kid, scale);
        if i == n / 2 {
            // No frequency finishes any kernel in a nanosecond.
            job = job.with_deadline(1e-3);
        } else if i % 3 != 0 {
            let headroom = if i % 2 == 0 { 2.0 } else { 3.0 };
            job = job.with_deadline(headroom * scale * base_us);
        }
        if let Err(e) = core.submit(&engine, job) {
            rejected.push((format!("{}-{i}", ks[i % ks.len()].name), e.to_string()));
        }
    }
    // Roll the clock far past every predicted completion so each
    // admitted job reaches a terminal state.
    core.run_until(&engine, now + 1e4 * mean_us * n as f64);

    let mut t = crate::report::Table::new(
        &format!(
            "Streaming schedule: {n} arrivals over {} devices (cap {device_cap}/device, {})",
            records.len(),
            objective.name()
        ),
        &[
            "job", "name", "kernel", "state", "device", "core MHz", "mem MHz", "predicted_us",
            "deadline_us", "cause",
        ],
    );
    for r in core.jobs() {
        t.row(vec![
            r.id_str(),
            r.name.clone(),
            r.kernel.to_string(),
            r.state.name().to_string(),
            match r.device {
                Some(d) => d.to_string(),
                None => "-".to_string(),
            },
            match r.point {
                Some(p) => format!("{:.0}", p.core_mhz),
                None => "-".to_string(),
            },
            match r.point {
                Some(p) => format!("{:.0}", p.mem_mhz),
                None => "-".to_string(),
            },
            match r.predicted_us {
                Some(p) => format!("{p:.1}"),
                None => "-".to_string(),
            },
            match r.deadline_at_us {
                Some(d) => format!("{d:.1}"),
                None => "-".to_string(),
            },
            r.cause.clone().unwrap_or_else(|| "-".to_string()),
        ]);
    }
    print_table(&t, args.csv);

    let s = core.stats();
    println!(
        "ADMIT: {} submitted · {} admitted · {} rejected at the door",
        s.submitted, s.admitted, s.rejected
    );
    for (name, why) in &rejected {
        println!("       {name}: {why}");
    }
    println!(
        "RUN  : {} done · {} missed · {} cancelled ({} events processed)",
        s.completed, s.missed, s.cancelled, s.events_processed
    );
    let (candidates, slab_calls) = core.table_counters();
    println!(
        "SOLVE: {} incremental repairs · {} full re-solves ({} fallbacks) · {} candidates · {} slab calls",
        s.repairs, s.full_solves, s.repair_fallbacks, candidates, slab_calls
    );
    print_cache_line(&engine);
    Ok(())
}

/// `gpufreq serve`: profile the selected kernels once at the baseline
/// (the paper's one-shot counter pass), put the shared engine behind
/// the HTTP service (DESIGN.md §9), and run until stdin reaches EOF —
/// which triggers the graceful drain. Ctrl-C still hard-kills.
fn run_serve(args: &Args, cfg: &Config) -> Result<()> {
    let spec = cfg.gpu.clone();
    let baseline = cfg.sweep.baseline();
    let pairs = cfg.sweep.pairs();
    let ex = microbench::extract(&spec, baseline);
    let engine = build_engine(args, ex.hw)?;
    let backend_name = engine.backend_name();
    let ks = selected_kernels(args, cfg)?;
    // Profile on scoped threads — one simulator run per kernel
    // dominates startup, predictions afterwards are microseconds.
    let mut counters: Vec<Option<KernelCounters>> = vec![None; ks.len()];
    std::thread::scope(|scope| {
        for (slot, k) in counters.iter_mut().zip(&ks) {
            let spec = &spec;
            scope.spawn(move || {
                *slot = Some(profiler::profile_at(spec, k, baseline).counters);
            });
        }
    });
    let mut state = ServiceState::new(engine, cfg.power.clone(), pairs);
    for (k, c) in ks.iter().zip(counters) {
        state.register_kernel(&k.name, c.expect("profiled"));
    }
    let service = Service::start(
        state,
        ServiceConfig {
            addr: args.addr.clone(),
            workers: args.workers.clamp(1, 64),
            queue_capacity: args.queue_depth,
            slow_us: args.slow_us,
            trace_capacity: args.trace_capacity,
            plan_ring: args.plan_ring,
            event_log: args.event_log.clone(),
            replan_interval: Duration::from_secs_f64(args.replan_interval_ms / 1e3),
            horizon: Duration::from_secs_f64(args.horizon_ms / 1e3),
            ..ServiceConfig::default()
        },
    )?;
    println!("gpufreq service listening on http://{}", service.addr());
    println!("  v2     : POST+GET /v2/devices · POST+GET /v2/kernels · POST /v2/predict (batch) · POST /v2/advise · POST /v2/plan · POST+GET /v2/jobs · GET+DELETE /v2/jobs/{{id}} · POST /v2/observations");
    println!("  v1+ops : POST /v1/predict · POST /v1/grid · POST /v1/advise · GET /healthz · GET /metrics · GET /debug/traces · GET /debug/plans · GET /debug/drift");
    if args.trace_capacity == 0 {
        println!("  traces : disabled (--trace-capacity 0)");
    } else {
        println!(
            "  traces : ring of {} · retaining requests ≥ {:.0} µs (--slow-us)",
            args.trace_capacity, args.slow_us
        );
    }
    if args.plan_ring == 0 {
        println!("  plans  : provenance disabled (--plan-ring 0)");
    } else {
        println!("  plans  : provenance ring of {} solves (--plan-ring)", args.plan_ring);
    }
    match &args.event_log {
        Some(p) => println!("  events : JSONL -> {} (--event-log)", p.display()),
        None => println!("  events : off (enable with --event-log PATH)"),
    }
    println!(
        "  sched  : re-plan every {:.0} ms over a {:.0} ms horizon (--replan-interval, --horizon)",
        args.replan_interval_ms, args.horizon_ms
    );
    println!(
        "  config : {} kernels · backend {} · {} executors · admission credit {}+{}",
        ks.len(),
        backend_name,
        args.workers.clamp(1, 64),
        args.workers.clamp(1, 64),
        args.queue_depth
    );
    println!("close stdin (Ctrl-D) to drain and exit");
    // Park on stdin; EOF (or a read error) starts the drain.
    let mut sink = [0u8; 4096];
    let mut stdin = std::io::stdin().lock();
    loop {
        match std::io::Read::read(&mut stdin, &mut sink) {
            Ok(0) => break,
            Ok(_) => continue,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => break,
        }
    }
    let served = service.metrics().requests_total();
    service.shutdown();
    println!("drained cleanly after {served} requests");
    Ok(())
}

fn run_report(what: &str, args: &Args, cfg: &Config) -> Result<()> {
    let spec = cfg.gpu.clone();
    let baseline = cfg.sweep.baseline();
    let pairs = cfg.sweep.pairs();
    match what {
        "table1" => print_table(&tables::table1(), args.csv),
        "table2" => {
            let (t, note) = tables::table2(&spec);
            print_table(&t, args.csv);
            println!("{note}");
        }
        "table3" => print_table(&tables::table3(&spec), args.csv),
        "table6" => print_table(&tables::table6(&kernels::all()), args.csv),
        "fig2" => {
            let ks = kernels::fig2_set();
            let sweep = run_sweep(&spec, &ks, &pairs, args.workers);
            for (fixed, mem) in [(400.0, true), (1000.0, true), (400.0, false), (1000.0, false)] {
                print_table(&tables::fig2(&sweep, &ks, fixed, mem), args.csv);
            }
        }
        "fig5" => {
            let (a, b) = tables::fig5(&spec, baseline, 2048);
            print_table(&a, args.csv);
            print_table(&b, args.csv);
        }
        "fig12" => {
            let profiles: Vec<_> =
                kernels::all().iter().map(|k| profiler::profile_at(&spec, k, baseline)).collect();
            print_table(&tables::fig12(&profiles), args.csv);
        }
        "fig13" => {
            let ks = selected_kernels(args, cfg)?;
            let ex = microbench::extract(&spec, baseline);
            let engine = build_engine(args, ex.hw)?;
            let v = validate_with_engine(&spec, &ks, &engine, &pairs)?;
            for (fc, fm) in [(Some(400.0), None), (Some(1000.0), None)] {
                print_table(&tables::fig13(&v, fc, fm), args.csv);
            }
            for (fc, fm) in [(None, Some(400.0)), (None, Some(1000.0))] {
                print_table(&tables::fig13(&v, fc, fm), args.csv);
            }
        }
        "fig14" => {
            let ks = selected_kernels(args, cfg)?;
            let ex = microbench::extract(&spec, baseline);
            let engine = build_engine(args, ex.hw)?;
            let v = validate_with_engine(&spec, &ks, &engine, &pairs)?;
            let (chart, t) = tables::fig14(&v);
            println!("{chart}");
            print_table(&t, args.csv);
        }
        "ablation" => {
            let ks = selected_kernels(args, cfg)?;
            let ex = microbench::extract(&spec, baseline);
            let rows = tables::run_ablation(&spec, &ks, ex.hw, standard_baselines(ex.hw), &pairs);
            print_table(&tables::ablation(&rows), args.csv);
        }
        "power" => {
            // Where the watts go at each sweep point under the
            // configured device's v2 model (DESIGN.md §15).
            let p = &cfg.power;
            let mut t = crate::report::Table::new(
                "Power split: P = dyn(core) + dyn(mem) + static + leak(Vcore)",
                &["core MHz", "mem MHz", "Vcore", "Vmem", "dyn W", "leak W", "total W"],
            );
            for &(cf, mf) in &pairs {
                let s = p.split_w(cf, mf);
                t.row(vec![
                    format!("{cf:.0}"),
                    format!("{mf:.0}"),
                    format!("{:.4}", p.core_curve.volts(cf)),
                    format!("{:.4}", p.mem_curve.volts(mf)),
                    format!("{:.2}", s.dynamic_w),
                    format!("{:.2}", s.leakage_w),
                    format!("{:.2}", s.total_w),
                ]);
            }
            print_table(&t, args.csv);
        }
        other => bail!("unknown report `{other}` (see `gpufreq help`)"),
    }
    Ok(())
}

/// Expose sample-point construction for integration tests.
pub fn sample_point(kernel: &str, cf: f64, mf: f64, truth: f64, pred: f64) -> SamplePoint {
    SamplePoint { kernel: kernel.into(), core_mhz: cf, mem_mhz: mf, truth_us: truth, pred_us: pred }
}

/// Re-export for tests.
pub fn empty_validation() -> Validation {
    Validation { per_kernel: Vec::new() }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_command_and_flags() {
        let a = parse_args(&argv("validate --pjrt --workers 3 --kernels VA,MMS --csv")).unwrap();
        assert_eq!(a.command, "validate");
        assert_eq!(a.backend, "pjrt");
        assert!(a.csv && a.cache);
        assert_eq!(a.workers, 3);
        assert_eq!(a.kernels.as_deref().unwrap(), ["VA".to_string(), "MMS".to_string()]);
    }

    #[test]
    fn parses_backend_and_cache_flags() {
        let a = parse_args(&argv("validate --backend batch --no-cache")).unwrap();
        assert_eq!(a.backend, "batch");
        assert!(!a.cache);
        assert!(parse_args(&argv("validate --backend warp-drive")).is_err());
    }

    #[test]
    fn positionals_collected() {
        let a = parse_args(&argv("report fig14")).unwrap();
        assert_eq!(a.command, "report");
        assert_eq!(a.positional, vec!["fig14".to_string()]);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(parse_args(&argv("sweep --frobnicate")).is_err());
        assert!(parse_args(&argv("sweep --workers two")).is_err());
    }

    #[test]
    fn empty_argv_is_help() {
        let a = parse_args(&[]).unwrap();
        assert_eq!(a.command, "help");
    }

    #[test]
    fn build_engine_honors_backend_choice() {
        let hw = HwParams::paper_defaults();
        let mut args = Args::default();
        for (backend, name) in
            [("native", "native-scalar"), ("batch", "native-batch"), ("pjrt", "pjrt")]
        {
            args.backend = backend.into();
            let e = build_engine(&args, hw).unwrap();
            assert_eq!(e.backend_name(), name);
            assert!(e.has_cache());
        }
        args.backend = "native".into();
        args.cache = false;
        let uncached = build_engine(&args, hw).unwrap();
        assert!(!uncached.has_cache());
        // Disabled cache still reports (zeroed) stats — /metrics keeps
        // its cache series under --no-cache.
        assert_eq!(uncached.cache_stats().hits, 0);
    }

    #[test]
    fn usage_documents_every_command_and_v2_route() {
        // The help-drift audit: every subcommand `run` dispatches must
        // appear in USAGE, alongside the full v2 route surface and the
        // flags the planner added.
        let needles = [
            "list-kernels", "microbench", "profile", "devices", "kernels", "sweep",
            "validate", "report", "advise", "plan", "jobs", "serve", "stream-demo",
            "dev-<n>", "krn-<n>", "/v2/predict", "/v2/devices", "/v2/kernels",
            "/v2/advise", "/v2/plan", "/v2/jobs", "/v2/observations", "/v1/predict",
            "/debug/traces", "/debug/plans", "/debug/drift", "--jobs", "--device-cap",
            "--objective", "--queue-depth", "--addr", "--backend", "--workers",
            "--slow-us", "--trace-capacity", "--explain", "--plan-ring", "--event-log",
            "--replan-interval", "--horizon",
        ];
        for needle in needles {
            assert!(USAGE.contains(needle), "USAGE is missing `{needle}`");
        }
    }

    #[test]
    fn parses_plan_flags() {
        let a =
            parse_args(&argv("plan --jobs 100 --device-cap 8 --objective edp --explain")).unwrap();
        assert_eq!(a.command, "plan");
        assert_eq!(a.jobs, 100);
        assert_eq!(a.device_cap, 8);
        assert_eq!(a.objective, "edp");
        assert!(a.explain);
        assert!(parse_args(&argv("plan --jobs lots")).is_err());
        assert!(parse_args(&argv("plan --device-cap some")).is_err());
        // Defaults: a 24-job fleet, balanced caps, no provenance dump.
        let d = Args::default();
        assert_eq!(d.jobs, 24);
        assert_eq!(d.device_cap, 0);
        assert!(!d.explain);
    }

    #[test]
    fn parses_serve_flags() {
        let a = parse_args(&argv(
            "serve --addr 0.0.0.0:9000 --queue-depth 128 --slow-us 250.5 --trace-capacity 32 \
             --plan-ring 16 --event-log /tmp/events.jsonl",
        ))
        .unwrap();
        assert_eq!(a.command, "serve");
        assert_eq!(a.addr, "0.0.0.0:9000");
        assert_eq!(a.queue_depth, 128);
        assert_eq!(a.slow_us, 250.5);
        assert_eq!(a.trace_capacity, 32);
        assert_eq!(a.plan_ring, 16);
        assert_eq!(a.event_log.as_deref(), Some(std::path::Path::new("/tmp/events.jsonl")));
        assert!(parse_args(&argv("serve --queue-depth lots")).is_err());
        assert!(parse_args(&argv("serve --slow-us soon")).is_err());
        assert!(parse_args(&argv("serve --slow-us -1")).is_err());
        assert!(parse_args(&argv("serve --slow-us inf")).is_err());
        assert!(parse_args(&argv("serve --trace-capacity lots")).is_err());
        assert!(parse_args(&argv("serve --plan-ring lots")).is_err());
        assert!(parse_args(&argv("serve --event-log")).is_err());
        // Defaults are loopback + a 64-deep queue, tracing everything,
        // a 64-solve provenance ring, no event log.
        let d = Args::default();
        assert_eq!(d.addr, "127.0.0.1:8077");
        assert_eq!(d.queue_depth, 64);
        assert_eq!(d.slow_us, 0.0);
        assert_eq!(d.trace_capacity, 256);
        assert_eq!(d.plan_ring, 64);
        assert!(d.event_log.is_none());
    }

    #[test]
    fn parses_scheduler_flags() {
        let a = parse_args(&argv("serve --replan-interval 250 --horizon 5000")).unwrap();
        assert_eq!(a.replan_interval_ms, 250.0);
        assert_eq!(a.horizon_ms, 5000.0);
        let j = parse_args(&argv("jobs --jobs 12 --replan-interval 0.5")).unwrap();
        assert_eq!(j.command, "jobs");
        assert_eq!(j.jobs, 12);
        assert_eq!(j.replan_interval_ms, 0.5);
        // Epoch and horizon must be positive, finite milliseconds.
        assert!(parse_args(&argv("serve --replan-interval soon")).is_err());
        assert!(parse_args(&argv("serve --replan-interval 0")).is_err());
        assert!(parse_args(&argv("serve --replan-interval -10")).is_err());
        assert!(parse_args(&argv("serve --replan-interval inf")).is_err());
        assert!(parse_args(&argv("serve --horizon nan")).is_err());
        assert!(parse_args(&argv("serve --horizon 0")).is_err());
        let d = Args::default();
        assert_eq!(d.replan_interval_ms, 1_000.0);
        assert_eq!(d.horizon_ms, 30_000.0);
    }
}
