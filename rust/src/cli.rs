//! Hand-rolled CLI (clap is not in the offline vendor set — DESIGN.md
//! "Offline substitutions"): subcommand + `--flag value` parsing and
//! the command implementations behind the `gpufreq` launcher.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::baselines::{standard_baselines, PaperModel};
use crate::config::{self, Config};
use crate::coordinator::batcher::BatchServer;
use crate::coordinator::sweep::run_sweep;
use crate::coordinator::validate::{validate_with, SamplePoint, Validation};
use crate::dvfs::{advise, Objective, PowerModel};
use crate::kernels;
use crate::microbench;
use crate::model::HwParams;
use crate::profiler;
use crate::report::tables;
use crate::sim::isa::Kernel;
use crate::sim::Clocks;

pub const USAGE: &str = "\
gpufreq — GPGPU performance estimation with core & memory frequency scaling
          (reproduction of Wang & Chu, 2017; see DESIGN.md)

USAGE: gpufreq <COMMAND> [OPTIONS]

COMMANDS:
  list-kernels            List the Table VI workloads
  microbench              Run the §IV probes: Eq. (4) fit, dm_del, latencies
  profile <KERNEL>        One-shot baseline profile of a kernel (or 'all')
  sweep                   Simulate kernels over the frequency grid (ground truth)
  validate                Full Fig. 13/14 validation: simulate + predict + MAPE
  report <ARTIFACT>       Regenerate a paper artifact: table1 table2 table3
                          table6 fig2 fig5 fig12 fig13 fig14 ablation
  advise <KERNEL>         DVFS energy advisor (paper §VII application)
  serve                   Demo the batched PJRT prediction service
  help                    Show this message

OPTIONS:
  --config <PATH>         TOML config (default: configs/gtx980.toml if present)
  --kernels <A,B,...>     Restrict to these kernels
  --pjrt                  Predict through the AOT PJRT artifact (default: native)
  --csv                   Emit CSV instead of ASCII tables
  --objective <NAME>      advise: energy | edp | slack:<frac> (default energy)
  --workers <N>           sweep/validate parallelism (default: # cpus)
";

/// Parsed command line.
#[derive(Debug, Clone)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub config: Option<PathBuf>,
    pub kernels: Option<Vec<String>>,
    pub pjrt: bool,
    pub csv: bool,
    pub objective: String,
    pub workers: usize,
}

impl Default for Args {
    fn default() -> Self {
        Args {
            command: "help".into(),
            positional: Vec::new(),
            config: None,
            kernels: None,
            pjrt: false,
            csv: false,
            objective: "energy".into(),
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        }
    }
}

/// Parse argv (excluding the binary name).
pub fn parse_args(argv: &[String]) -> Result<Args> {
    let mut args = Args::default();
    let mut it = argv.iter().peekable();
    let Some(cmd) = it.next() else {
        return Ok(args);
    };
    args.command = cmd.clone();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--config" => {
                args.config =
                    Some(PathBuf::from(it.next().context("--config needs a path")?))
            }
            "--kernels" => {
                args.kernels = Some(
                    it.next()
                        .context("--kernels needs a list")?
                        .split(',')
                        .map(|s| s.trim().to_string())
                        .collect(),
                )
            }
            "--pjrt" => args.pjrt = true,
            "--csv" => args.csv = true,
            "--objective" => {
                args.objective = it.next().context("--objective needs a value")?.clone()
            }
            "--workers" => {
                args.workers = it
                    .next()
                    .context("--workers needs a number")?
                    .parse()
                    .context("--workers must be an integer")?
            }
            flag if flag.starts_with("--") => bail!("unknown flag {flag}"),
            pos => args.positional.push(pos.to_string()),
        }
    }
    Ok(args)
}

fn load_config(args: &Args) -> Result<Config> {
    if let Some(p) = &args.config {
        return config::load(p);
    }
    let default = PathBuf::from("configs/gtx980.toml");
    if default.exists() {
        config::load(&default)
    } else {
        Ok(Config::default())
    }
}

fn selected_kernels(args: &Args, cfg: &Config) -> Result<Vec<Kernel>> {
    let names: Option<&[String]> = args
        .kernels
        .as_deref()
        .or(if cfg.kernels.is_empty() { None } else { Some(&cfg.kernels) });
    match names {
        None => Ok(kernels::all()),
        Some(ns) => ns
            .iter()
            .map(|n| kernels::by_name(n).with_context(|| format!("unknown kernel {n}")))
            .collect(),
    }
}

fn print_table(t: &crate::report::Table, csv: bool) {
    if csv {
        print!("{}", t.csv());
    } else {
        print!("{}", t.ascii());
    }
}

/// PJRT-backed predictor for `validate --pjrt` (the production path).
struct PjrtPredictor {
    server: BatchServer,
}

impl crate::baselines::Predictor for PjrtPredictor {
    fn name(&self) -> &'static str {
        "paper-pjrt"
    }
    fn predict_us(&self, c: &crate::model::KernelCounters, cf: f64, mf: f64) -> f64 {
        self.server.predict(c, cf, mf).expect("batch server alive").time_us
    }
}

fn build_predictor(args: &Args, hw: HwParams) -> Result<Box<dyn crate::baselines::Predictor>> {
    if args.pjrt {
        let (server, _handle) = BatchServer::start_default(hw.to_f32(), Duration::from_millis(1))
            .context("loading AOT artifacts (run `make artifacts` first)")?;
        Ok(Box::new(PjrtPredictor { server }))
    } else {
        Ok(Box::new(PaperModel { hw }))
    }
}

/// Run a parsed command. Returns the process exit code.
pub fn run(args: Args) -> Result<i32> {
    let cfg = load_config(&args)?;
    let spec = cfg.gpu.clone();
    let baseline = cfg.sweep.baseline();
    let pairs = cfg.sweep.pairs();

    match args.command.as_str() {
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
        }
        "list-kernels" => {
            print_table(&tables::table6(&selected_kernels(&args, &cfg)?), args.csv);
        }
        "microbench" => {
            let ex = microbench::extract(&spec, baseline);
            println!(
                "dm_lat  = {:.2} * (cf/mf) + {:.2} core cycles   (R^2 = {:.4}; paper: 222.78/277.32)",
                ex.hw.dm_lat_a, ex.hw.dm_lat_b, ex.dm_lat_fit.r_squared
            );
            println!(
                "dm_del  = {:.2} mem cycles/txn   bandwidth efficiency {:.1}%  ({:.1} GB/s)",
                ex.hw.dm_del,
                ex.bandwidth_at_baseline.efficiency * 100.0,
                ex.bandwidth_at_baseline.achieved_gbps
            );
            println!("l2_lat  = {:.1} core cycles   l2_del = {:.1}", ex.hw.l2_lat, ex.hw.l2_del);
            println!("sh_lat  = {:.1} core cycles", ex.hw.sh_lat);
            println!("inst    = {:.2} cycles/instruction", ex.hw.inst_cycle);
        }
        "profile" => {
            let what = args.positional.first().map(String::as_str).unwrap_or("all");
            let ks = if what == "all" {
                selected_kernels(&args, &cfg)?
            } else {
                vec![kernels::by_name(what).with_context(|| format!("unknown kernel {what}"))?]
            };
            let mut t = crate::report::Table::new(
                &format!(
                    "Baseline profile @ {:.0}/{:.0} MHz",
                    baseline.core_mhz, baseline.mem_mhz
                ),
                &["kernel", "time_us", "l2_hr", "gld", "avr_inst", "#Aw", "#SM", "smem", "regime"],
            );
            let ex = microbench::extract(&spec, baseline);
            for k in &ks {
                let p = profiler::profile_at(&spec, k, baseline);
                let pred =
                    crate::model::predict(&p.counters, &ex.hw, baseline.core_mhz, baseline.mem_mhz);
                t.row(vec![
                    p.kernel.clone(),
                    format!("{:.1}", p.baseline_time_us),
                    format!("{:.3}", p.counters.l2_hr),
                    format!("{:.1}", p.counters.gld_trans),
                    format!("{:.2}", p.counters.avr_inst),
                    format!("{:.0}", p.counters.aw),
                    format!("{:.0}", p.counters.n_sm),
                    format!("{}", p.counters.uses_smem),
                    format!("{:?}", pred.regime),
                ]);
            }
            print_table(&t, args.csv);
        }
        "sweep" => {
            let ks = selected_kernels(&args, &cfg)?;
            let sweep = run_sweep(&spec, &ks, &pairs, args.workers);
            let mut t = crate::report::Table::new(
                "Ground-truth sweep (simulator)",
                &["kernel", "core MHz", "mem MHz", "time_us", "l2_hr"],
            );
            for p in &sweep.points {
                t.row(vec![
                    p.kernel.clone(),
                    format!("{:.0}", p.core_mhz),
                    format!("{:.0}", p.mem_mhz),
                    format!("{:.2}", p.time_us),
                    format!("{:.3}", p.l2_hr),
                ]);
            }
            print_table(&t, args.csv);
        }
        "validate" => {
            let ks = selected_kernels(&args, &cfg)?;
            let ex = microbench::extract(&spec, baseline);
            let predictor = build_predictor(&args, ex.hw)?;
            let v = validate_with(&spec, &ks, predictor.as_ref(), &pairs);
            let (chart, summary) = tables::fig14(&v);
            println!("{chart}");
            print_table(&summary, args.csv);
        }
        "report" => {
            let what = args.positional.first().map(String::as_str).unwrap_or("");
            run_report(what, &args, &cfg)?;
        }
        "advise" => {
            let name = args.positional.first().context("advise needs a kernel name")?;
            let k = kernels::by_name(name).with_context(|| format!("unknown kernel {name}"))?;
            let ex = microbench::extract(&spec, baseline);
            let p = profiler::profile_at(&spec, &k, baseline);
            let objective = match args.objective.as_str() {
                "energy" => Objective::Energy,
                "edp" => Objective::Edp,
                s if s.starts_with("slack:") => Objective::EnergyWithSlack(
                    s.trim_start_matches("slack:").parse().context("bad slack value")?,
                ),
                other => bail!("unknown objective {other}"),
            };
            let predictor = build_predictor(&args, ex.hw)?;
            let power = PowerModel::gtx980();
            let (best, points) =
                advise(&p.counters, predictor.as_ref(), &power, &pairs, objective);
            let mut t = crate::report::Table::new(
                &format!("DVFS advisor for {name} ({:?})", objective),
                &["core MHz", "mem MHz", "time_us", "power W", "energy mJ", "EDP"],
            );
            for cp in &points {
                t.row(vec![
                    format!("{:.0}", cp.core_mhz),
                    format!("{:.0}", cp.mem_mhz),
                    format!("{:.1}", cp.time_us),
                    format!("{:.1}", cp.power_w),
                    format!("{:.2}", cp.energy_mj),
                    format!("{:.1}", cp.edp),
                ]);
            }
            print_table(&t, args.csv);
            println!(
                "BEST: {:.0}/{:.0} MHz  time {:.1} us  power {:.1} W  energy {:.2} mJ",
                best.core_mhz, best.mem_mhz, best.time_us, best.power_w, best.energy_mj
            );
        }
        "serve" => {
            let ex = microbench::extract(&spec, baseline);
            let (server, _h) =
                BatchServer::start_default(ex.hw.to_f32(), Duration::from_millis(2))
                    .context("loading AOT artifacts (run `make artifacts` first)")?;
            println!("PJRT platform: {}", server.platform());
            let ks = selected_kernels(&args, &cfg)?;
            let mut joins = Vec::new();
            for k in ks {
                let server = server.clone();
                let spec = spec.clone();
                let pairs = pairs.clone();
                joins.push(std::thread::spawn(move || {
                    let p = profiler::profile_at(&spec, &k, Clocks::new(700.0, 700.0));
                    let out = server.predict_grid(&p.counters, &pairs).unwrap();
                    let best = out
                        .iter()
                        .zip(&pairs)
                        .min_by(|a, b| a.0.time_us.total_cmp(&b.0.time_us))
                        .unwrap();
                    (k.name.clone(), out.len(), best.1 .0, best.1 .1, best.0.time_us)
                }));
            }
            for j in joins {
                let (name, n, cf, mf, t) = j.join().unwrap();
                println!("{name:8} {n} predictions; fastest {cf:.0}/{mf:.0} MHz -> {t:.1} us");
            }
            let st = server.stats();
            println!(
                "served {} rows in {} batches (mean occupancy {:.1}%)",
                st.requests(),
                st.batches(),
                st.mean_occupancy() * 100.0
            );
        }
        other => {
            eprintln!("unknown command `{other}`\n");
            print!("{USAGE}");
            return Ok(2);
        }
    }
    Ok(0)
}

fn run_report(what: &str, args: &Args, cfg: &Config) -> Result<()> {
    let spec = cfg.gpu.clone();
    let baseline = cfg.sweep.baseline();
    let pairs = cfg.sweep.pairs();
    match what {
        "table1" => print_table(&tables::table1(), args.csv),
        "table2" => {
            let (t, note) = tables::table2(&spec);
            print_table(&t, args.csv);
            println!("{note}");
        }
        "table3" => print_table(&tables::table3(&spec), args.csv),
        "table6" => print_table(&tables::table6(&kernels::all()), args.csv),
        "fig2" => {
            let ks = kernels::fig2_set();
            let sweep = run_sweep(&spec, &ks, &pairs, args.workers);
            for (fixed, mem) in [(400.0, true), (1000.0, true), (400.0, false), (1000.0, false)] {
                print_table(&tables::fig2(&sweep, &ks, fixed, mem), args.csv);
            }
        }
        "fig5" => {
            let (a, b) = tables::fig5(&spec, baseline, 2048);
            print_table(&a, args.csv);
            print_table(&b, args.csv);
        }
        "fig12" => {
            let profiles: Vec<_> =
                kernels::all().iter().map(|k| profiler::profile_at(&spec, k, baseline)).collect();
            print_table(&tables::fig12(&profiles), args.csv);
        }
        "fig13" => {
            let ks = selected_kernels(args, cfg)?;
            let ex = microbench::extract(&spec, baseline);
            let predictor = build_predictor(args, ex.hw)?;
            let v = validate_with(&spec, &ks, predictor.as_ref(), &pairs);
            for (fc, fm) in [(Some(400.0), None), (Some(1000.0), None)] {
                print_table(&tables::fig13(&v, fc, fm), args.csv);
            }
            for (fc, fm) in [(None, Some(400.0)), (None, Some(1000.0))] {
                print_table(&tables::fig13(&v, fc, fm), args.csv);
            }
        }
        "fig14" => {
            let ks = selected_kernels(args, cfg)?;
            let ex = microbench::extract(&spec, baseline);
            let predictor = build_predictor(args, ex.hw)?;
            let v = validate_with(&spec, &ks, predictor.as_ref(), &pairs);
            let (chart, t) = tables::fig14(&v);
            println!("{chart}");
            print_table(&t, args.csv);
        }
        "ablation" => {
            let ks = selected_kernels(args, cfg)?;
            let ex = microbench::extract(&spec, baseline);
            let rows =
                tables::run_ablation(&spec, &ks, &standard_baselines(ex.hw), &pairs);
            print_table(&tables::ablation(&rows), args.csv);
        }
        other => bail!("unknown report `{other}` (see `gpufreq help`)"),
    }
    Ok(())
}

/// Expose sample-point construction for integration tests.
pub fn sample_point(kernel: &str, cf: f64, mf: f64, truth: f64, pred: f64) -> SamplePoint {
    SamplePoint { kernel: kernel.into(), core_mhz: cf, mem_mhz: mf, truth_us: truth, pred_us: pred }
}

/// Re-export for tests.
pub fn empty_validation() -> Validation {
    Validation { per_kernel: Vec::new() }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_command_and_flags() {
        let a = parse_args(&argv("validate --pjrt --workers 3 --kernels VA,MMS --csv")).unwrap();
        assert_eq!(a.command, "validate");
        assert!(a.pjrt && a.csv);
        assert_eq!(a.workers, 3);
        assert_eq!(a.kernels.as_deref().unwrap(), ["VA".to_string(), "MMS".to_string()]);
    }

    #[test]
    fn positionals_collected() {
        let a = parse_args(&argv("report fig14")).unwrap();
        assert_eq!(a.command, "report");
        assert_eq!(a.positional, vec!["fig14".to_string()]);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(parse_args(&argv("sweep --frobnicate")).is_err());
        assert!(parse_args(&argv("sweep --workers two")).is_err());
    }

    #[test]
    fn empty_argv_is_help() {
        let a = parse_args(&[]).unwrap();
        assert_eq!(a.command, "help");
    }
}
