//! Structured wide-event log (DESIGN.md §13): an opt-in JSONL sink
//! behind a bounded channel and a dedicated writer thread.
//!
//! The serving and solver hot paths must never block on disk, so
//! `emit` is a `try_send`: when the channel is full (or the writer has
//! exited on an I/O error) the event is *dropped and counted* —
//! `events_dropped_total` in `/metrics` makes the loss visible. Each
//! event is one pre-rendered JSON line; this module deliberately takes
//! opaque `String` lines rather than a JSON value type, keeping `obs`
//! below `service` in the crate graph.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Default bounded-channel depth between emitters and the writer.
pub const DEFAULT_EVENT_QUEUE: usize = 1024;

#[derive(Debug, Default)]
struct Counters {
    emitted: AtomicU64,
    dropped: AtomicU64,
}

/// Handle to the event-log writer. Cloning is cheap (the channel
/// sender and counters are shared); dropping the *last* handle closes
/// the channel, which flushes and joins the writer thread.
#[derive(Debug)]
pub struct EventSink {
    tx: Option<SyncSender<String>>,
    counters: Arc<Counters>,
    writer: Option<JoinHandle<()>>,
}

impl EventSink {
    /// Open (append/create) `path` and start the writer thread.
    pub fn to_path(path: &Path) -> std::io::Result<EventSink> {
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        Ok(EventSink::start(file, DEFAULT_EVENT_QUEUE))
    }

    /// Start a sink writing to an already-open file with a queue of
    /// `depth` pending events.
    pub fn start(file: File, depth: usize) -> EventSink {
        let (tx, rx) = sync_channel::<String>(depth.max(1));
        let writer = std::thread::Builder::new()
            .name("gpufreq-events".into())
            .spawn(move || writer_loop(rx, file))
            .expect("spawning the event-log writer");
        EventSink { tx: Some(tx), counters: Arc::new(Counters::default()), writer: Some(writer) }
    }

    /// Queue one pre-rendered JSON line. Never blocks: a full queue or
    /// a dead writer drops the event and bumps the drop counter.
    pub fn emit(&self, line: String) {
        let Some(tx) = &self.tx else {
            self.counters.dropped.fetch_add(1, Relaxed);
            return;
        };
        match tx.try_send(line) {
            Ok(()) => {
                self.counters.emitted.fetch_add(1, Relaxed);
            }
            Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {
                self.counters.dropped.fetch_add(1, Relaxed);
            }
        }
    }

    /// Events accepted onto the queue (cumulative).
    pub fn emitted_total(&self) -> u64 {
        self.counters.emitted.load(Relaxed)
    }

    /// Events dropped to backpressure or writer death (cumulative).
    pub fn dropped_total(&self) -> u64 {
        self.counters.dropped.load(Relaxed)
    }
}

impl Drop for EventSink {
    fn drop(&mut self) {
        // Close the channel first so the writer's `recv` returns, then
        // join it — a deterministic flush on shutdown.
        self.tx.take();
        if let Some(h) = self.writer.take() {
            let _ = h.join();
        }
    }
}

/// Drain the channel into the file, batching what is already queued
/// between flushes so a burst costs one syscall, not one per event.
fn writer_loop(rx: Receiver<String>, file: File) {
    let mut out = BufWriter::new(file);
    while let Ok(line) = rx.recv() {
        if writeln!(out, "{line}").is_err() {
            return; // disk gone; emitters keep counting drops
        }
        // Opportunistically drain whatever queued behind this event.
        while let Ok(more) = rx.try_recv() {
            if writeln!(out, "{more}").is_err() {
                return;
            }
        }
        if out.flush().is_err() {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_path(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("gpufreq-events-{}-{name}.jsonl", std::process::id()));
        p
    }

    #[test]
    fn events_land_in_the_file_one_line_each() {
        let path = temp_path("basic");
        let _ = std::fs::remove_file(&path);
        {
            let sink = EventSink::to_path(&path).unwrap();
            sink.emit(r#"{"event":"a"}"#.to_string());
            sink.emit(r#"{"event":"b"}"#.to_string());
            assert_eq!(sink.emitted_total(), 2);
            // Drop flushes and joins the writer.
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines, [r#"{"event":"a"}"#, r#"{"event":"b"}"#]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn full_queue_drops_and_counts_instead_of_blocking() {
        // A depth-1 queue under a 10k burst forces backpressure
        // regardless of writer speed; every emit must be accounted
        // as either accepted or dropped — never blocked or lost.
        let path = temp_path("drops");
        let _ = std::fs::remove_file(&path);
        let file = File::create(&path).unwrap();
        let sink = EventSink::start(file, 1);
        for i in 0..10_000 {
            sink.emit(format!(r#"{{"event":"spam","i":{i}}}"#));
        }
        // With a queue of 1 and a real writer racing, totals must
        // account for every emit exactly once.
        assert_eq!(sink.emitted_total() + sink.dropped_total(), 10_000);
        let _ = std::fs::remove_file(&path);
    }
}
