//! Per-request span capture: stages, trace records and the slow-trace
//! ring (DESIGN.md §13).

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

use crate::obs::ring::Ring;

/// Default ring capacity when `--trace-capacity` is not given.
pub const DEFAULT_TRACE_CAPACITY: usize = 256;

/// The stages of one request's lifecycle, in wall-clock order. Each
/// admitted request records one duration per stage; the sum is the
/// server-side total (client-observed latency adds network time).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// From connection-ready (accept, or the previous response on a
    /// keep-alive connection) until the request head+body had fully
    /// arrived — mostly client/network time the server waits out.
    Accept,
    /// HTTP head + body framing parse.
    Parse,
    /// Parsed and queued, waiting for an executor thread.
    Queue,
    /// The route handler: engine compute plus response-body JSON.
    Compute,
    /// Serializing the response head + body into the write buffer.
    Render,
    /// The synchronous socket flush after render (a slow consumer's
    /// residual bytes drain on later poll ticks and are not charged
    /// here — see DESIGN.md §13).
    Flush,
}

impl Stage {
    pub const COUNT: usize = 6;

    pub const ALL: [Stage; Stage::COUNT] = [
        Stage::Accept,
        Stage::Parse,
        Stage::Queue,
        Stage::Compute,
        Stage::Render,
        Stage::Flush,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Stage::Accept => "accept",
            Stage::Parse => "parse",
            Stage::Queue => "queue",
            Stage::Compute => "compute",
            Stage::Render => "render",
            Stage::Flush => "flush",
        }
    }

    pub fn index(self) -> usize {
        match self {
            Stage::Accept => 0,
            Stage::Parse => 1,
            Stage::Queue => 2,
            Stage::Compute => 3,
            Stage::Render => 4,
            Stage::Flush => 5,
        }
    }
}

/// One completed request trace: identity, outcome and the per-stage
/// latency breakdown, plus compute-side attribution (engine cache hits
/// and misses, SoA slab evaluations issued while the handler ran).
#[derive(Debug, Clone)]
pub struct TraceRecord {
    /// The request id echoed in `X-Request-Id` (client-supplied or
    /// server-generated `req-<n>`).
    pub id: String,
    /// Route name as metered (`Route::name`), `"other"` for 404s.
    pub route: &'static str,
    pub status: u16,
    /// Microseconds per [`Stage`], indexed by [`Stage::index`].
    pub stages_us: [f64; Stage::COUNT],
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub slab_calls: u64,
}

impl TraceRecord {
    /// Server-side total: the sum over every stage.
    pub fn total_us(&self) -> f64 {
        self.stages_us.iter().sum()
    }
}

/// Fixed-capacity ring of recent slow traces: a [`Ring<TraceRecord>`]
/// (the shared wait-free claim/`try_lock` retention idiom — see
/// `obs::ring`) plus the trace-specific policy. `slow_us` is the
/// retention threshold: traces whose server-side total is below it are
/// not retained (0 retains everything). Capacity 0 disables retention
/// entirely (`enabled()` is false) — the bench harness uses that as
/// the untraced baseline.
#[derive(Debug)]
pub struct TraceRing {
    ring: Ring<TraceRecord>,
    slow_us: f64,
    /// Source for server-generated request ids (`req-<n>`).
    next_id: AtomicU64,
}

impl TraceRing {
    pub fn new(capacity: usize, slow_us: f64) -> TraceRing {
        TraceRing {
            ring: Ring::new(capacity),
            slow_us: if slow_us.is_finite() { slow_us.max(0.0) } else { 0.0 },
            next_id: AtomicU64::new(1),
        }
    }

    /// A capacity-0 ring: ids still mint, nothing is retained.
    pub fn disabled() -> TraceRing {
        TraceRing::new(0, 0.0)
    }

    /// Whether traces are retained at all (capacity > 0).
    pub fn enabled(&self) -> bool {
        self.ring.enabled()
    }

    pub fn capacity(&self) -> usize {
        self.ring.capacity()
    }

    /// The retention threshold in microseconds (0 = keep everything).
    pub fn slow_us(&self) -> f64 {
        self.slow_us
    }

    /// Mint a fresh server-side request id (monotonic from 1). Minting
    /// works even on a disabled ring: `X-Request-Id` is unconditional.
    pub fn next_request_id(&self) -> u64 {
        self.next_id.fetch_add(1, Relaxed)
    }

    /// Total traces retained (cumulative, including overwritten ones).
    pub fn recorded_total(&self) -> u64 {
        self.ring.recorded_total()
    }

    /// Traces dropped to slot contention (cumulative).
    pub fn dropped_total(&self) -> u64 {
        self.ring.dropped_total()
    }

    /// Retain one completed trace if it clears the slow threshold.
    pub fn record(&self, t: TraceRecord) {
        if t.total_us() < self.slow_us {
            return;
        }
        self.ring.record(t);
    }

    /// The retained traces, newest first. Slots a writer holds at the
    /// moment of the snapshot are skipped, not waited on.
    pub fn snapshot(&self) -> Vec<TraceRecord> {
        self.ring.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(id: &str, total_us: f64) -> TraceRecord {
        let mut stages_us = [0.0; Stage::COUNT];
        stages_us[Stage::Compute.index()] = total_us;
        TraceRecord {
            id: id.to_string(),
            route: "/v1/predict",
            status: 200,
            stages_us,
            cache_hits: 0,
            cache_misses: 0,
            slab_calls: 0,
        }
    }

    #[test]
    fn stage_tables_are_consistent() {
        for (i, s) in Stage::ALL.into_iter().enumerate() {
            assert_eq!(s.index(), i);
            assert!(!s.name().is_empty());
        }
    }

    #[test]
    fn ring_keeps_the_newest_n_and_evicts_the_oldest() {
        let ring = TraceRing::new(2, 0.0);
        ring.record(trace("a", 10.0));
        ring.record(trace("b", 20.0));
        ring.record(trace("c", 30.0));
        let got: Vec<String> = ring.snapshot().into_iter().map(|t| t.id).collect();
        assert_eq!(got, ["c", "b"]); // newest first; "a" was evicted
        assert_eq!(ring.recorded_total(), 3);
    }

    #[test]
    fn slow_threshold_filters_fast_traces() {
        let ring = TraceRing::new(4, 100.0);
        ring.record(trace("fast", 50.0));
        ring.record(trace("slow", 250.0));
        ring.record(trace("edge", 100.0)); // exactly at threshold: kept
        let got: Vec<String> = ring.snapshot().into_iter().map(|t| t.id).collect();
        assert_eq!(got, ["edge", "slow"]);
    }

    #[test]
    fn disabled_ring_retains_nothing_but_still_mints_ids() {
        let ring = TraceRing::disabled();
        assert!(!ring.enabled());
        ring.record(trace("x", 1e9));
        assert!(ring.snapshot().is_empty());
        assert_eq!(ring.recorded_total(), 0);
        assert_eq!(ring.next_request_id(), 1);
        assert_eq!(ring.next_request_id(), 2);
    }

    #[test]
    fn total_sums_every_stage() {
        let mut t = trace("t", 0.0);
        for (i, v) in t.stages_us.iter_mut().enumerate() {
            *v = (i + 1) as f64;
        }
        assert_eq!(t.total_us(), 21.0);
    }
}
