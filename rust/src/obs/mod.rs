//! Trace-first observability (DESIGN.md §13): request spans and live
//! model-accuracy telemetry, `std`-only like every other layer.
//!
//! Two halves, both threaded through the serving stack:
//!
//! * [`trace`] — per-request span capture. Every admitted request gets
//!   a trace ID (echoed as `X-Request-Id`) and one duration per
//!   [`Stage`] of its lifecycle — accept, parse, queue-wait, engine
//!   compute (with cache and SoA-slab attribution), response render,
//!   write-flush. Completed traces land in a [`TraceRing`]: a
//!   fixed-capacity ring of recent slow traces (`--slow-us` sets the
//!   retention threshold) that `GET /debug/traces` dumps as JSON.
//!   Recording is wait-free on the hot path — one atomic slot claim
//!   plus a `try_lock` that *skips* under contention rather than
//!   blocking an executor.
//! * [`accuracy`] — the live half of the paper's 3.5%-error claim.
//!   `POST /v2/observations` feeds measured kernel times into an
//!   [`AccuracyTracker`], which keeps a rolling absolute-percent-error
//!   window per (device, kernel) and surfaces MAPE as
//!   `model_mape{device,kernel}` gauges in `/metrics` — the offline
//!   benchmark number becomes a monitored production SLO.
//!
//! This module deliberately sits *below* `service` in the crate graph
//! (it knows nothing about HTTP or routes), so the engine and future
//! calibration passes can consume the same signals.

pub mod accuracy;
pub mod trace;

pub use accuracy::{AccuracySeries, AccuracyTracker, DEFAULT_ERROR_WINDOW};
pub use trace::{Stage, TraceRecord, TraceRing, DEFAULT_TRACE_CAPACITY};
