//! Trace-first observability (DESIGN.md §13): request spans and live
//! model-accuracy telemetry, `std`-only like every other layer.
//!
//! Two halves, both threaded through the serving stack:
//!
//! * [`trace`] — per-request span capture. Every admitted request gets
//!   a trace ID (echoed as `X-Request-Id`) and one duration per
//!   [`Stage`] of its lifecycle — accept, parse, queue-wait, engine
//!   compute (with cache and SoA-slab attribution), response render,
//!   write-flush. Completed traces land in a [`TraceRing`]: a
//!   fixed-capacity ring of recent slow traces (`--slow-us` sets the
//!   retention threshold) that `GET /debug/traces` dumps as JSON.
//!   Recording is wait-free on the hot path — one atomic slot claim
//!   plus a `try_lock` that *skips* under contention rather than
//!   blocking an executor.
//! * [`accuracy`] — the live half of the paper's 3.5%-error claim.
//!   `POST /v2/observations` feeds measured kernel times into an
//!   [`AccuracyTracker`], which keeps a rolling absolute-percent-error
//!   window per (device, kernel) and surfaces MAPE as
//!   `model_mape{device,kernel}` gauges in `/metrics` — the offline
//!   benchmark number becomes a monitored production SLO.
//!
//! Three more members round out the observability layer:
//!
//! * [`ring`] — the wait-free fixed-capacity snapshot ring the trace
//!   ring is built on, generalized ([`Ring<T>`]) so plan provenance
//!   (`GET /debug/plans`) retains solve history the same way.
//! * [`drift`] — an EWMA-of-error state machine (ok / warn /
//!   critical, with hysteresis) layered on the accuracy tracker:
//!   the `model_drift_state` gauge and `GET /debug/drift` that tell
//!   the calibration loop *which* series needs a refit.
//! * [`events`] — the opt-in `--event-log` JSONL sink: a bounded
//!   channel into a dedicated writer thread that never blocks the
//!   poll loop or the solver (overflow is dropped and counted).
//!
//! This module deliberately sits *below* `service` in the crate graph
//! (it knows nothing about HTTP or routes), so the engine and future
//! calibration passes can consume the same signals.

pub mod accuracy;
pub mod drift;
pub mod events;
pub mod ring;
pub mod trace;

pub use accuracy::{AccuracySeries, AccuracyTracker, Observation, DEFAULT_ERROR_WINDOW};
pub use drift::{DriftConfig, DriftState};
pub use events::{EventSink, DEFAULT_EVENT_QUEUE};
pub use ring::Ring;
pub use trace::{Stage, TraceRecord, TraceRing, DEFAULT_TRACE_CAPACITY};
