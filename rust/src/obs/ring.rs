//! Generalized fixed-capacity snapshot ring (DESIGN.md §13).
//!
//! Factored out of the slow-trace ring so plan provenance (and any
//! future retained-history surface) shares one wait-free retention
//! idiom: writers claim a slot with a single `fetch_add` and then
//! `try_lock` it — a reader (or a same-slot writer) holding the lock
//! makes the writer *drop* the record instead of blocking, so the
//! executor and solver hot paths never wait on observability.
//! Capacity 0 disables retention entirely (`enabled()` is false).

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::Mutex;

/// Fixed-capacity ring of the most recent `capacity` records.
#[derive(Debug)]
pub struct Ring<T> {
    slots: Vec<Mutex<Option<T>>>,
    /// Total slot claims; the next record lands in `head % capacity`.
    head: AtomicU64,
    /// Records dropped to slot contention.
    dropped: AtomicU64,
}

impl<T: Clone> Ring<T> {
    pub fn new(capacity: usize) -> Ring<T> {
        Ring {
            slots: (0..capacity).map(|_| Mutex::new(None)).collect(),
            head: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Whether records are retained at all (capacity > 0).
    pub fn enabled(&self) -> bool {
        !self.slots.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total records retained (cumulative, including overwritten ones).
    pub fn recorded_total(&self) -> u64 {
        self.head.load(Relaxed)
    }

    /// Records dropped to slot contention (cumulative).
    pub fn dropped_total(&self) -> u64 {
        self.dropped.load(Relaxed)
    }

    /// Retain one record, overwriting the oldest once full.
    pub fn record(&self, t: T) {
        if !self.enabled() {
            return;
        }
        let seq = self.head.fetch_add(1, Relaxed);
        let slot = (seq % self.slots.len() as u64) as usize;
        match self.slots[slot].try_lock() {
            Ok(mut g) => *g = Some(t),
            Err(_) => {
                self.dropped.fetch_add(1, Relaxed);
            }
        }
    }

    /// The retained records, newest first. Slots a writer holds at the
    /// moment of the snapshot are skipped, not waited on.
    pub fn snapshot(&self) -> Vec<T> {
        let cap = self.slots.len() as u64;
        if cap == 0 {
            return Vec::new();
        }
        let head = self.head.load(Relaxed);
        let mut out = Vec::with_capacity(self.slots.len());
        for i in 0..cap.min(head) {
            let slot = ((head - 1 - i) % cap) as usize;
            if let Ok(g) = self.slots[slot].try_lock() {
                if let Some(t) = g.as_ref() {
                    out.push(t.clone());
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_keeps_the_newest_n_newest_first() {
        let ring: Ring<u32> = Ring::new(3);
        for v in 1..=5 {
            ring.record(v);
        }
        assert_eq!(ring.snapshot(), [5, 4, 3]);
        assert_eq!(ring.recorded_total(), 5);
        assert_eq!(ring.dropped_total(), 0);
    }

    #[test]
    fn zero_capacity_ring_is_disabled() {
        let ring: Ring<String> = Ring::new(0);
        assert!(!ring.enabled());
        ring.record("x".into());
        assert!(ring.snapshot().is_empty());
        assert_eq!(ring.recorded_total(), 0);
    }

    #[test]
    fn partial_fill_returns_only_what_was_recorded() {
        let ring: Ring<u32> = Ring::new(8);
        ring.record(1);
        ring.record(2);
        assert_eq!(ring.snapshot(), [2, 1]);
        assert_eq!(ring.capacity(), 8);
    }
}
