//! Live model-accuracy telemetry (DESIGN.md §13): rolling
//! absolute-percent-error windows per (device, kernel).
//!
//! The paper's validation is a one-time offline sweep (≈3.5% mean
//! error, Table VII). `POST /v2/observations` turns that into a
//! continuous signal: every measured sample is compared against the
//! model's prediction *at ingest time* and folded into a bounded
//! rolling window, so `/metrics` can expose a live
//! `model_mape{device,kernel}` gauge that drifts when the hardware or
//! the workload does. A future calibration pass refits when the gauge
//! leaves budget; this layer only measures.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::Mutex;

use crate::obs::drift::{DriftConfig, DriftState};

/// Default rolling-window length per (device, kernel) series.
pub const DEFAULT_ERROR_WINDOW: usize = 256;

/// Bound on distinct (device, kernel) series so an id-spraying client
/// cannot grow the tracker without limit. Matches the registry's own
/// capacity order (1024 devices × a few kernels each is far beyond
/// what one service instance meters in practice).
pub const MAX_SERIES: usize = 4096;

/// One (device, kernel) accuracy series as exposed in `/metrics`.
#[derive(Debug, Clone, PartialEq)]
pub struct AccuracySeries {
    /// Canonical device handle (`dev-<n>`).
    pub device: String,
    /// Canonical kernel handle (`krn-<n>`).
    pub kernel: String,
    /// Mean absolute percent error over the current window.
    pub mape_pct: f64,
    /// EWMA of the absolute percent error (reacts faster than the
    /// window mean; drives the drift state machine).
    pub ewma_pct: f64,
    /// Current drift classification with hysteresis applied.
    pub state: DriftState,
    /// Samples currently in the window (≤ the configured window).
    pub window: usize,
    /// Total samples ever ingested for this series.
    pub samples: u64,
}

/// The outcome of folding one sample: the error it contributed, the
/// updated EWMA, and the drift transition (if any) it caused.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Observation {
    /// Absolute percent error of this sample.
    pub err_pct: f64,
    /// EWMA of abs-%-error after folding this sample in.
    pub ewma_pct: f64,
    /// Drift state before this sample.
    pub prev_state: DriftState,
    /// Drift state after this sample (== `prev_state` unless the
    /// sample caused a transition).
    pub state: DriftState,
}

impl Observation {
    /// Whether this sample moved the drift state machine.
    pub fn transitioned(&self) -> bool {
        self.prev_state != self.state
    }
}

#[derive(Debug)]
struct Series {
    device: String,
    kernel: String,
    errors: VecDeque<f64>,
    samples: u64,
    ewma: Option<f64>,
    state: DriftState,
}

/// Rolling per-(device, kernel) error windows. Ingest is mutex-guarded
/// — observations arrive at calibration cadence (seconds), not at
/// predict cadence (microseconds), so a lock here never contends with
/// the serving hot path.
#[derive(Debug)]
pub struct AccuracyTracker {
    window: usize,
    drift: DriftConfig,
    series: Mutex<Vec<Series>>,
    /// Samples dropped because the series table was at [`MAX_SERIES`]
    /// and the (device, kernel) key was new.
    dropped: AtomicU64,
}

impl Default for AccuracyTracker {
    fn default() -> Self {
        AccuracyTracker::new(DEFAULT_ERROR_WINDOW)
    }
}

impl AccuracyTracker {
    pub fn new(window: usize) -> AccuracyTracker {
        AccuracyTracker::with_drift(window, DriftConfig::default())
    }

    pub fn with_drift(window: usize, drift: DriftConfig) -> AccuracyTracker {
        AccuracyTracker {
            window: window.max(1),
            drift,
            series: Mutex::new(Vec::new()),
            dropped: AtomicU64::new(0),
        }
    }

    /// The configured rolling-window length.
    pub fn window(&self) -> usize {
        self.window
    }

    /// Samples dropped at the [`MAX_SERIES`] bound (cumulative) — the
    /// `model_samples_dropped_total` counter in `/metrics`.
    pub fn dropped_total(&self) -> u64 {
        self.dropped.load(Relaxed)
    }

    /// Fold one measured sample into the (device, kernel) series and
    /// return the absolute percent error it contributed. `measured_us`
    /// must be positive (the route layer rejects non-positive
    /// measurements before calling). Returns `None` when the series
    /// table is full and this key is new — the sample is dropped
    /// rather than evicting someone else's history.
    pub fn observe(
        &self,
        device: &str,
        kernel: &str,
        predicted_us: f64,
        measured_us: f64,
    ) -> Option<f64> {
        self.observe_detailed(device, kernel, predicted_us, measured_us).map(|o| o.err_pct)
    }

    /// [`observe`](AccuracyTracker::observe) with the full outcome:
    /// the sample's error, the updated drift EWMA, and the drift
    /// transition (if any) — the event log emits a `drift_transition`
    /// record when `Observation::transitioned()` reports one.
    pub fn observe_detailed(
        &self,
        device: &str,
        kernel: &str,
        predicted_us: f64,
        measured_us: f64,
    ) -> Option<Observation> {
        let err_pct = ((predicted_us - measured_us) / measured_us).abs() * 100.0;
        let mut g = self.series.lock().expect("accuracy series poisoned");
        let idx = match g.iter().position(|s| s.device == device && s.kernel == kernel) {
            Some(i) => i,
            None => {
                if g.len() >= MAX_SERIES {
                    self.dropped.fetch_add(1, Relaxed);
                    return None;
                }
                g.push(Series {
                    device: device.to_string(),
                    kernel: kernel.to_string(),
                    errors: VecDeque::with_capacity(self.window.min(64)),
                    samples: 0,
                    ewma: None,
                    state: DriftState::Ok,
                });
                g.len() - 1
            }
        };
        let slot = &mut g[idx];
        if slot.errors.len() == self.window {
            slot.errors.pop_front();
        }
        slot.errors.push_back(err_pct);
        slot.samples += 1;
        let ewma_pct = self.drift.fold(slot.ewma, err_pct);
        slot.ewma = Some(ewma_pct);
        let prev_state = slot.state;
        slot.state = self.drift.step(prev_state, ewma_pct);
        Some(Observation { err_pct, ewma_pct, prev_state, state: slot.state })
    }

    /// Every series, in first-observation order, with its current MAPE.
    pub fn snapshot(&self) -> Vec<AccuracySeries> {
        let g = self.series.lock().expect("accuracy series poisoned");
        g.iter()
            .map(|s| AccuracySeries {
                device: s.device.clone(),
                kernel: s.kernel.clone(),
                mape_pct: if s.errors.is_empty() {
                    0.0
                } else {
                    s.errors.iter().sum::<f64>() / s.errors.len() as f64
                },
                ewma_pct: s.ewma.unwrap_or(0.0),
                state: s.state,
                window: s.errors.len(),
                samples: s.samples,
            })
            .collect()
    }

    /// [`snapshot`](AccuracyTracker::snapshot) sorted worst-first:
    /// highest drift state, then highest EWMA — the `/debug/drift`
    /// ordering (the series most in need of a refit leads).
    pub fn drift_snapshot(&self) -> Vec<AccuracySeries> {
        let mut snap = self.snapshot();
        snap.sort_by(|a, b| {
            b.state.cmp(&a.state).then(b.ewma_pct.total_cmp(&a.ewma_pct))
        });
        snap
    }

    /// Total samples ingested across every series.
    pub fn total_samples(&self) -> u64 {
        self.series.lock().expect("accuracy series poisoned").iter().map(|s| s.samples).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mape_is_the_mean_absolute_percent_error() {
        let t = AccuracyTracker::new(16);
        // +10% and -30% against a 100 µs measurement → MAPE 20%.
        assert_eq!(t.observe("dev-1", "krn-1", 110.0, 100.0), Some(10.0));
        assert_eq!(t.observe("dev-1", "krn-1", 70.0, 100.0), Some(30.0));
        let snap = t.snapshot();
        assert_eq!(snap.len(), 1);
        assert!((snap[0].mape_pct - 20.0).abs() < 1e-12, "mape {}", snap[0].mape_pct);
        assert_eq!(snap[0].window, 2);
        assert_eq!(snap[0].samples, 2);
    }

    #[test]
    fn window_rolls_old_errors_out() {
        let t = AccuracyTracker::new(2);
        t.observe("dev-1", "krn-1", 200.0, 100.0); // 100% — must roll out
        t.observe("dev-1", "krn-1", 110.0, 100.0); // 10%
        t.observe("dev-1", "krn-1", 130.0, 100.0); // 30%
        let snap = t.snapshot();
        assert!((snap[0].mape_pct - 20.0).abs() < 1e-12, "mape {}", snap[0].mape_pct);
        assert_eq!(snap[0].window, 2); // bounded by the window
        assert_eq!(snap[0].samples, 3); // lifetime count keeps growing
    }

    #[test]
    fn series_are_keyed_per_device_and_kernel() {
        let t = AccuracyTracker::default();
        t.observe("dev-1", "krn-1", 110.0, 100.0);
        t.observe("dev-1", "krn-2", 150.0, 100.0);
        t.observe("dev-2", "krn-1", 100.0, 100.0);
        let snap = t.snapshot();
        assert_eq!(snap.len(), 3);
        assert_eq!(snap[2].device, "dev-2");
        assert_eq!(snap[2].mape_pct, 0.0); // exact prediction
        assert_eq!(t.total_samples(), 3);
    }

    #[test]
    fn overprediction_and_underprediction_both_count_positive() {
        let t = AccuracyTracker::default();
        assert_eq!(t.observe("d", "k", 80.0, 100.0), Some(20.0));
        assert_eq!(t.observe("d", "k", 120.0, 100.0), Some(20.0));
        assert!((t.snapshot()[0].mape_pct - 20.0).abs() < 1e-12);
    }

    #[test]
    fn ewma_escalates_the_drift_state_and_reports_transitions() {
        let t = AccuracyTracker::default();
        // First sample seeds the EWMA directly: 30% lands in Warn.
        let o = t.observe_detailed("d", "k", 130.0, 100.0).unwrap();
        assert_eq!(o.err_pct, 30.0);
        assert_eq!(o.ewma_pct, 30.0);
        assert_eq!(o.prev_state, DriftState::Ok);
        assert_eq!(o.state, DriftState::Critical);
        assert!(o.transitioned());
        // A perfect sample decays the EWMA but hysteresis holds state.
        let o2 = t.observe_detailed("d", "k", 100.0, 100.0).unwrap();
        assert!((o2.ewma_pct - 27.0).abs() < 1e-12);
        assert_eq!(o2.state, DriftState::Critical);
        assert!(!o2.transitioned());
        let snap = t.snapshot();
        assert_eq!(snap[0].state, DriftState::Critical);
        assert!((snap[0].ewma_pct - 27.0).abs() < 1e-12);
    }

    #[test]
    fn drift_snapshot_orders_worst_first() {
        let t = AccuracyTracker::default();
        t.observe("dev-1", "krn-1", 101.0, 100.0); // 1% → ok
        t.observe("dev-1", "krn-2", 140.0, 100.0); // 40% → critical
        t.observe("dev-2", "krn-1", 115.0, 100.0); // 15% → warn
        let snap = t.drift_snapshot();
        assert_eq!(snap[0].kernel, "krn-2");
        assert_eq!(snap[0].state, DriftState::Critical);
        assert_eq!(snap[1].device, "dev-2");
        assert_eq!(snap[1].state, DriftState::Warn);
        assert_eq!(snap[2].state, DriftState::Ok);
    }

    #[test]
    fn samples_past_the_series_bound_are_counted_not_silent() {
        let t = AccuracyTracker::default();
        assert_eq!(t.dropped_total(), 0);
        // Fill the table to the bound, then present a new key: the
        // sample must be refused AND counted.
        for i in 0..MAX_SERIES {
            t.observe("dev", &format!("krn-{i}"), 100.0, 100.0);
        }
        assert_eq!(t.observe("dev", "krn-overflow", 100.0, 100.0), None);
        assert_eq!(t.dropped_total(), 1);
        // Existing series still ingest fine past the bound.
        assert!(t.observe("dev", "krn-0", 100.0, 100.0).is_some());
        assert_eq!(t.dropped_total(), 1);
    }
}
