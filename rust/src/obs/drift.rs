//! Model-drift state machine (DESIGN.md §13): an EWMA of
//! absolute-percent-error classified ok / warn / critical with
//! hysteresis.
//!
//! The rolling MAPE window in [`crate::obs::accuracy`] answers "how
//! accurate is the model right now"; this layer answers "has the model
//! *left budget*" — the trigger the ROADMAP's calibration-refit loop
//! consumes. The EWMA discounts old errors geometrically (a window
//! mean reacts a full window late), and the de-escalation thresholds
//! sit `hysteresis_pct` below the escalation thresholds so a series
//! oscillating around a boundary does not flap between states.

/// Drift severity for one (device, kernel) accuracy series.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum DriftState {
    /// EWMA within budget.
    Ok,
    /// EWMA over the warn threshold — watch, recalibration advised.
    Warn,
    /// EWMA over the critical threshold — model output untrustworthy
    /// for this series until refit.
    Critical,
}

impl DriftState {
    pub fn name(self) -> &'static str {
        match self {
            DriftState::Ok => "ok",
            DriftState::Warn => "warn",
            DriftState::Critical => "critical",
        }
    }

    /// Numeric encoding for the `model_drift_state` gauge
    /// (0 = ok, 1 = warn, 2 = critical).
    pub fn gauge(self) -> u64 {
        match self {
            DriftState::Ok => 0,
            DriftState::Warn => 1,
            DriftState::Critical => 2,
        }
    }
}

/// Thresholds for the drift state machine. Defaults key off the
/// paper's headline accuracy: the model validates at ≈3.5% mean error
/// (Table VII), so a sustained 10% EWMA is drift worth flagging and
/// 25% means the model is no longer describing this series.
#[derive(Debug, Clone, Copy)]
pub struct DriftConfig {
    /// EWMA smoothing factor in (0, 1]: weight of the newest error.
    pub alpha: f64,
    /// Escalate Ok → Warn at this EWMA abs-%-error.
    pub warn_pct: f64,
    /// Escalate → Critical at this EWMA abs-%-error.
    pub critical_pct: f64,
    /// De-escalate only once the EWMA falls this far *below* the
    /// threshold it crossed, so boundary noise cannot flap the state.
    pub hysteresis_pct: f64,
}

impl Default for DriftConfig {
    fn default() -> Self {
        DriftConfig { alpha: 0.1, warn_pct: 10.0, critical_pct: 25.0, hysteresis_pct: 2.0 }
    }
}

impl DriftConfig {
    /// Fold one absolute-percent-error sample into the EWMA. The first
    /// sample seeds the average directly.
    pub fn fold(&self, ewma: Option<f64>, err_pct: f64) -> f64 {
        match ewma {
            None => err_pct,
            Some(prev) => self.alpha * err_pct + (1.0 - self.alpha) * prev,
        }
    }

    /// One transition of the hysteresis state machine: escalation uses
    /// the raw thresholds, de-escalation requires clearing them by
    /// `hysteresis_pct`.
    pub fn step(&self, state: DriftState, ewma_pct: f64) -> DriftState {
        match state {
            DriftState::Ok => {
                if ewma_pct >= self.critical_pct {
                    DriftState::Critical
                } else if ewma_pct >= self.warn_pct {
                    DriftState::Warn
                } else {
                    DriftState::Ok
                }
            }
            DriftState::Warn => {
                if ewma_pct >= self.critical_pct {
                    DriftState::Critical
                } else if ewma_pct < self.warn_pct - self.hysteresis_pct {
                    DriftState::Ok
                } else {
                    DriftState::Warn
                }
            }
            DriftState::Critical => {
                if ewma_pct < self.critical_pct - self.hysteresis_pct {
                    // Re-classify against the remaining thresholds
                    // rather than forcing a stop at Warn.
                    if ewma_pct < self.warn_pct - self.hysteresis_pct {
                        DriftState::Ok
                    } else {
                        DriftState::Warn
                    }
                } else {
                    DriftState::Critical
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gauge_and_names_are_stable() {
        assert_eq!(DriftState::Ok.gauge(), 0);
        assert_eq!(DriftState::Warn.gauge(), 1);
        assert_eq!(DriftState::Critical.gauge(), 2);
        assert_eq!(DriftState::Warn.name(), "warn");
    }

    #[test]
    fn ewma_seeds_then_discounts_geometrically() {
        let cfg = DriftConfig::default();
        let e0 = cfg.fold(None, 8.0);
        assert_eq!(e0, 8.0);
        let e1 = cfg.fold(Some(e0), 18.0);
        assert!((e1 - (0.1 * 18.0 + 0.9 * 8.0)).abs() < 1e-12);
    }

    #[test]
    fn escalation_uses_raw_thresholds() {
        let cfg = DriftConfig::default();
        assert_eq!(cfg.step(DriftState::Ok, 9.9), DriftState::Ok);
        assert_eq!(cfg.step(DriftState::Ok, 10.0), DriftState::Warn);
        assert_eq!(cfg.step(DriftState::Ok, 25.0), DriftState::Critical);
        assert_eq!(cfg.step(DriftState::Warn, 25.0), DriftState::Critical);
    }

    #[test]
    fn deescalation_requires_clearing_the_hysteresis_band() {
        let cfg = DriftConfig::default();
        // Warn holds inside the band [8, 10), recovers below 8.
        assert_eq!(cfg.step(DriftState::Warn, 9.0), DriftState::Warn);
        assert_eq!(cfg.step(DriftState::Warn, 8.0), DriftState::Warn);
        assert_eq!(cfg.step(DriftState::Warn, 7.9), DriftState::Ok);
        // Critical holds inside [23, 25), drops to Warn below 23, and
        // straight to Ok when fully recovered.
        assert_eq!(cfg.step(DriftState::Critical, 24.0), DriftState::Critical);
        assert_eq!(cfg.step(DriftState::Critical, 22.9), DriftState::Warn);
        assert_eq!(cfg.step(DriftState::Critical, 1.0), DriftState::Ok);
    }
}
