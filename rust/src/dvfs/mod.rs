//! DVFS energy model and advisor — the paper's motivating application
//! (§I and §VII future work: "a real-time voltage and frequency
//! controller based on energy conservation strategies").
//!
//! Power v2 (DESIGN.md §15) is voltage-explicit:
//!
//! ```text
//! P(cf, mf) = P_dyn(cf, V_core(cf)) + P_dyn(mf, V_mem(mf)) + P_leak(V_core(cf))
//! P_dyn(f, V) = a·C·V²·f                      (Eq. 1, per clock domain)
//! P_leak(V)   = static_w + leak_w·(V/V_ref)·10^((V − V_ref)/V_slope)
//! ```
//!
//! The dynamic term is the paper's Eq. (1) applied per domain with a
//! voltage/frequency table; the leakage term follows the lumos-style
//! subthreshold model (exponential in supply voltage, normalised so
//! the excess equals `leak_w` at `V_ref`). With flat voltage tables
//! and `leak_w = 0`, v2 degrades **bit-identically** to the old
//! frequency-only v1 model — a guarantee the `tests/power_model.rs`
//! property suite pins.
//!
//! Energy = P(cf, mf) × T(cf, mf), with T from any `Predictor`.
//!
//! This module advises **one kernel on one device**. For batch
//! scheduling — many deadline-tagged jobs across every registered GPU,
//! under per-device concurrency caps — see [`crate::planner`], which
//! reuses the same [`PowerModel`] arithmetic per device (DESIGN.md
//! §11).

use anyhow::Result;

use crate::baselines::Predictor;
use crate::engine::Engine;
use crate::model::KernelCounters;

/// Structured rejection from [`VfCurve::try_from_points`]: every
/// construction path (TOML `[power]` sections, the `/v2` wire) funnels
/// through the same gate, so the variants here *are* the user-facing
/// validation vocabulary.
#[derive(Debug, Clone, PartialEq)]
pub enum VfCurveError {
    /// No points at all.
    Empty,
    /// A frequency or voltage is NaN or infinite.
    NonFinite { index: usize, mhz: f64, volts: f64 },
    /// A frequency or voltage is zero or negative.
    NonPositive { index: usize, mhz: f64, volts: f64 },
    /// The same frequency appears twice in a row.
    DuplicateFrequency { index: usize, mhz: f64 },
    /// Frequencies go backwards.
    NonAscendingFrequency { index: usize, prev_mhz: f64, mhz: f64 },
}

impl std::fmt::Display for VfCurveError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            VfCurveError::Empty => {
                write!(f, "curve needs at least one (mhz, volts) point")
            }
            VfCurveError::NonFinite { index, mhz, volts } => {
                write!(f, "point {index} ({mhz}:{volts}) must be finite")
            }
            VfCurveError::NonPositive { index, mhz, volts } => {
                write!(f, "point {index} ({mhz}:{volts}) must be positive")
            }
            VfCurveError::DuplicateFrequency { index, mhz } => {
                write!(f, "duplicate frequency {mhz} MHz at point {index}")
            }
            VfCurveError::NonAscendingFrequency { index, prev_mhz, mhz } => {
                write!(
                    f,
                    "frequencies must be strictly ascending: point {index} \
                     ({mhz} MHz) after {prev_mhz} MHz"
                )
            }
        }
    }
}

impl std::error::Error for VfCurveError {}

/// Voltage-frequency curve: linear interpolation over (MHz, V) points.
#[derive(Debug, Clone, PartialEq)]
pub struct VfCurve {
    /// Sorted (frequency MHz, volts) points.
    pub points: Vec<(f64, f64)>,
}

impl VfCurve {
    /// Validated constructor — the single invariant gate for every
    /// construction path (TOML `[power]` sections, the `/v2` wire):
    /// at least one point, positive finite values, strictly ascending
    /// frequencies.
    pub fn try_from_points(points: Vec<(f64, f64)>) -> Result<VfCurve, VfCurveError> {
        if points.is_empty() {
            return Err(VfCurveError::Empty);
        }
        let mut prev = f64::NEG_INFINITY;
        for (index, &(mhz, volts)) in points.iter().enumerate() {
            if !(mhz.is_finite() && volts.is_finite()) {
                return Err(VfCurveError::NonFinite { index, mhz, volts });
            }
            if mhz <= 0.0 || volts <= 0.0 {
                return Err(VfCurveError::NonPositive { index, mhz, volts });
            }
            if mhz == prev {
                return Err(VfCurveError::DuplicateFrequency { index, mhz });
            }
            if mhz < prev {
                return Err(VfCurveError::NonAscendingFrequency {
                    index,
                    prev_mhz: prev,
                    mhz,
                });
            }
            prev = mhz;
        }
        Ok(VfCurve { points })
    }

    /// A Maxwell-like curve: 0.85 V at 400 MHz up to 1.2125 V at
    /// 1000 MHz (matching published GTX 980 V/f steps in shape). The
    /// 100 MHz step table is the full DVFS ladder the planner's
    /// device grid enumerates.
    pub fn maxwell_core() -> Self {
        VfCurve {
            points: vec![
                (400.0, 0.85),
                (500.0, 0.9),
                (600.0, 0.95),
                (700.0, 1.0125),
                (800.0, 1.075),
                (900.0, 1.14375),
                (1000.0, 1.2125),
            ],
        }
    }

    /// GDDR5 voltage barely scales: flat-ish curve.
    pub fn gddr5_mem() -> Self {
        VfCurve { points: vec![(400.0, 1.35), (700.0, 1.425), (1000.0, 1.5)] }
    }

    /// Voltage at `f_mhz` (clamped linear interpolation).
    pub fn volts(&self, f_mhz: f64) -> f64 {
        let pts = &self.points;
        if f_mhz <= pts[0].0 {
            return pts[0].1;
        }
        for w in pts.windows(2) {
            let ((f0, v0), (f1, v1)) = (w[0], w[1]);
            if f_mhz <= f1 {
                return v0 + (v1 - v0) * (f_mhz - f0) / (f1 - f0);
            }
        }
        pts.last().unwrap().1
    }

    /// True when every point carries the same voltage — the regime in
    /// which the v2 model's voltage terms reduce to constants.
    pub fn is_flat(&self) -> bool {
        let v0 = self.points[0].1;
        self.points.iter().all(|&(_, v)| v == v0)
    }
}

/// Per-domain dynamic-power coefficients (`[power.dynamic]`): the
/// effective `a·C` in Eq. (1), in W / (MHz·V²).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DynamicParams {
    /// Core-domain coefficient.
    pub core_coeff: f64,
    /// Memory-domain coefficient.
    pub mem_coeff: f64,
}

/// Voltage-dependent leakage (`[power.leakage]`), lumos-style:
/// `P_leak(V) = static_w + leak_w·(V/v_ref)·10^((V − v_ref)/v_slope)`.
///
/// `static_w` is the voltage-independent floor (fans, VRM losses, the
/// memory rail's leakage — the mem domain's supply barely scales, so
/// its leakage is folded in here). The excess term is driven by the
/// **core** supply voltage and equals `leak_w` exactly at `v_ref`.
/// `leak_w = 0` switches the excess off entirely, recovering the v1
/// frequency-only model bit-for-bit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LeakageParams {
    /// Voltage-independent static power, W.
    pub static_w: f64,
    /// Leakage at the reference voltage, W. Zero disables the term.
    pub leak_w: f64,
    /// Reference voltage, V.
    pub v_ref: f64,
    /// Exponential slope: decades of leakage per `v_slope` volts.
    pub v_slope: f64,
}

impl LeakageParams {
    /// Voltage-independent leakage: the excess term off.
    pub fn flat(static_w: f64) -> Self {
        LeakageParams { static_w, leak_w: 0.0, v_ref: 1.0, v_slope: 0.8 }
    }

    /// The voltage-dependent excess above `static_w`, W. Exactly 0.0
    /// when `leak_w` is zero (the v1-equivalence guard: `x + 0.0`
    /// preserves `x` bit-for-bit for the positive totals we sum).
    pub fn excess_w(&self, volts: f64) -> f64 {
        if self.leak_w == 0.0 {
            return 0.0;
        }
        self.leak_w * (volts / self.v_ref) * 10f64.powf((volts - self.v_ref) / self.v_slope)
    }

    /// Total leakage at a supply voltage, W.
    pub fn total_w(&self, volts: f64) -> f64 {
        self.static_w + self.excess_w(volts)
    }
}

/// One evaluated power split: `total_w = dynamic_w + leakage_w` up to
/// summation order (the total is computed in v1's exact add order so
/// the flat/zero-leakage regime stays bit-identical).
#[derive(Debug, Clone, Copy)]
pub struct PowerSplit {
    /// Both domains' `a·C·V²·f`, W.
    pub dynamic_w: f64,
    /// Static floor plus voltage-dependent excess, W.
    pub leakage_w: f64,
    /// Board power, W.
    pub total_w: f64,
}

/// Eq. (1)-style power model with two frequency domains plus
/// voltage-dependent leakage (power v2, DESIGN.md §15).
#[derive(Debug, Clone, PartialEq)]
pub struct PowerModel {
    pub core_curve: VfCurve,
    pub mem_curve: VfCurve,
    /// Per-domain dynamic coefficients.
    pub dynamic: DynamicParams,
    /// Static + voltage-dependent leakage parameters.
    pub leakage: LeakageParams,
}

/// The GTX 980 calibration is the crate-wide default (matching
/// `HwParams::paper_defaults` and `GpuSpec::default`).
impl Default for PowerModel {
    fn default() -> Self {
        PowerModel::gtx980()
    }
}

impl PowerModel {
    /// Calibrated so the default GTX 980 lands near its 165 W TDP at
    /// 1000/1000 (185.6 W board power) and ~50 W at 400/400, with the
    /// leakage excess worth ~31 W at peak core voltage.
    pub fn gtx980() -> Self {
        PowerModel {
            core_curve: VfCurve::maxwell_core(),
            mem_curve: VfCurve::gddr5_mem(),
            dynamic: DynamicParams { core_coeff: 0.072, mem_coeff: 0.018 },
            leakage: LeakageParams { static_w: 8.0, leak_w: 14.0, v_ref: 1.0, v_slope: 0.8 },
        }
    }

    /// Board power split at a frequency pair. The total is summed in
    /// the v1 order (`static + core + mem`, then `+ excess`) so that
    /// flat curves with `leak_w = 0` reproduce v1 bit-identically.
    pub fn split_w(&self, core_mhz: f64, mem_mhz: f64) -> PowerSplit {
        let vc = self.core_curve.volts(core_mhz);
        let vm = self.mem_curve.volts(mem_mhz);
        let dyn_core = self.dynamic.core_coeff * core_mhz * vc * vc;
        let dyn_mem = self.dynamic.mem_coeff * mem_mhz * vm * vm;
        let excess = self.leakage.excess_w(vc);
        PowerSplit {
            dynamic_w: dyn_core + dyn_mem,
            leakage_w: self.leakage.static_w + excess,
            total_w: self.leakage.static_w + dyn_core + dyn_mem + excess,
        }
    }

    /// Board power at a frequency pair, watts.
    pub fn power_w(&self, core_mhz: f64, mem_mhz: f64) -> f64 {
        self.split_w(core_mhz, mem_mhz).total_w
    }

    /// The same model with the voltage-dependent leakage excess
    /// switched off (`leak_w = 0`); `static_w` and both dynamic terms
    /// are untouched. This is the v1-vs-v2 foil the planner bench and
    /// the energy-invariant property tests compare against.
    pub fn without_leakage(&self) -> PowerModel {
        PowerModel {
            leakage: LeakageParams { leak_w: 0.0, ..self.leakage },
            ..self.clone()
        }
    }
}

/// One evaluated DVFS configuration.
#[derive(Debug, Clone, Copy)]
pub struct ConfigPoint {
    pub core_mhz: f64,
    pub mem_mhz: f64,
    pub time_us: f64,
    pub power_w: f64,
    /// Dynamic share of `power_w` (both domains' a·C·V²·f), W.
    pub power_dynamic_w: f64,
    /// Leakage share of `power_w` (static floor + V-dependent excess), W.
    pub power_leakage_w: f64,
    /// Energy in millijoules.
    pub energy_mj: f64,
    /// Energy-delay product (mJ·µs).
    pub edp: f64,
}

/// What the advisor optimizes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Objective {
    /// Minimum energy.
    Energy,
    /// Minimum energy subject to `time <= (1 + slack) * t_fastest`.
    EnergyWithSlack(f64),
    /// Minimum energy-delay product.
    Edp,
}

/// Shared optimizer core: times are supplied per pair (from any
/// prediction path), power comes from the model, the objective picks.
fn advise_points(
    times_us: &[f64],
    power: &PowerModel,
    pairs: &[(f64, f64)],
    objective: Objective,
) -> (ConfigPoint, Vec<ConfigPoint>) {
    assert!(!pairs.is_empty());
    assert_eq!(times_us.len(), pairs.len());
    let points: Vec<ConfigPoint> = pairs
        .iter()
        .zip(times_us)
        .map(|(&(cf, mf), &time_us)| {
            let split = power.split_w(cf, mf);
            let energy_mj = split.total_w * time_us * 1e-3; // W·µs = µJ; /1e3 = mJ
            ConfigPoint {
                core_mhz: cf,
                mem_mhz: mf,
                time_us,
                power_w: split.total_w,
                power_dynamic_w: split.dynamic_w,
                power_leakage_w: split.leakage_w,
                energy_mj,
                edp: energy_mj * time_us,
            }
        })
        .collect();
    let t_fastest = points.iter().map(|p| p.time_us).fold(f64::INFINITY, f64::min);
    let feasible = |p: &&ConfigPoint| match objective {
        Objective::EnergyWithSlack(s) => p.time_us <= (1.0 + s) * t_fastest,
        _ => true,
    };
    let key = |p: &ConfigPoint| match objective {
        Objective::Edp => p.edp,
        _ => p.energy_mj,
    };
    let best = *points
        .iter()
        .filter(feasible)
        .min_by(|a, b| key(a).total_cmp(&key(b)))
        .expect("at least the fastest point is feasible");
    (best, points)
}

/// Evaluate every pair and pick the best per `objective`.
pub fn advise(
    counters: &KernelCounters,
    predictor: &dyn Predictor,
    power: &PowerModel,
    pairs: &[(f64, f64)],
    objective: Objective,
) -> (ConfigPoint, Vec<ConfigPoint>) {
    let times: Vec<f64> =
        pairs.iter().map(|&(cf, mf)| predictor.predict_us(counters, cf, mf)).collect();
    advise_points(&times, power, pairs, objective)
}

/// Engine-routed advisor — one batched `predict_grid` call per
/// invocation, so repeated advisor runs over the same grid (sweep of
/// objectives, per-kernel loops) are served from the engine's cache.
pub fn advise_with_engine(
    counters: &KernelCounters,
    engine: &Engine,
    power: &PowerModel,
    pairs: &[(f64, f64)],
    objective: Objective,
) -> Result<(ConfigPoint, Vec<ConfigPoint>)> {
    let times: Vec<f64> =
        engine.predict_grid(counters, pairs)?.iter().map(|e| e.time_us).collect();
    Ok(advise_points(&times, power, pairs, objective))
}

/// Handle-routed advisor (DESIGN.md §10): the device's own power model
/// comes from the engine's registry and timings from the device-keyed
/// handle path, so two registered GPUs get independent advice without
/// the caller threading `HwParams`/`PowerModel` structs around.
pub fn advise_with_handles(
    engine: &Engine,
    device: crate::registry::DeviceId,
    kernel: crate::registry::KernelId,
    pairs: &[(f64, f64)],
    objective: Objective,
) -> Result<(ConfigPoint, Vec<ConfigPoint>)> {
    let record = engine.device_record(device)?;
    let points: Vec<crate::registry::FreqPoint> =
        pairs.iter().map(|&p| p.into()).collect();
    let times: Vec<f64> = engine
        .predict_points(device, kernel, &points)?
        .iter()
        .map(|e| e.time_us)
        .collect();
    Ok(advise_points(&times, &record.power, pairs, objective))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::PaperModel;
    use crate::model::HwParams;

    fn counters_membound() -> KernelCounters {
        KernelCounters {
            l2_hr: 0.0,
            gld_trans: 12.0,
            avr_inst: 0.4,
            n_blocks: 256.0,
            wpb: 8.0,
            aw: 64.0,
            n_sm: 16.0,
            o_itrs: 8.0,
            i_itrs: 0.0,
            uses_smem: false,
            smem_conflict: 1.0,
            gld_body: 12.0,
            gld_edge: 0.0,
            mem_ops: 3.0,
            l1_hr: 0.0,
        }
    }

    fn counters_compbound() -> KernelCounters {
        KernelCounters { avr_inst: 100.0, l2_hr: 0.9, gld_trans: 2.0, ..counters_membound() }
    }

    fn grid() -> Vec<(f64, f64)> {
        crate::microbench::standard_grid()
    }

    #[test]
    fn vf_curve_interpolates_and_clamps() {
        let c = VfCurve::maxwell_core();
        assert_eq!(c.volts(300.0), 0.85);
        assert_eq!(c.volts(1200.0), 1.2125);
        let v = c.volts(500.0);
        assert!(v >= 0.85 && v < 0.95);
        assert!((c.volts(600.0) - 0.95).abs() < 1e-12);
    }

    #[test]
    fn vf_curve_clamps_at_both_ends() {
        // Below the first point and above the last, interpolation must
        // clamp (a controller can ask for out-of-table frequencies).
        for curve in [VfCurve::maxwell_core(), VfCurve::gddr5_mem()] {
            let (f_lo, v_lo) = curve.points[0];
            let (f_hi, v_hi) = *curve.points.last().unwrap();
            assert_eq!(curve.volts(f_lo - 1000.0), v_lo);
            assert_eq!(curve.volts(0.0), v_lo);
            assert_eq!(curve.volts(f_lo), v_lo);
            assert_eq!(curve.volts(f_hi), v_hi);
            assert_eq!(curve.volts(f_hi + 1000.0), v_hi);
            // Interior points stay within the envelope and monotone.
            let mut prev = v_lo;
            let mut f = f_lo;
            while f <= f_hi {
                let v = curve.volts(f);
                assert!(v >= prev - 1e-12, "non-monotone at {f}: {v} < {prev}");
                assert!((v_lo..=v_hi).contains(&v), "{v} outside [{v_lo}, {v_hi}]");
                prev = v;
                f += 25.0;
            }
        }
    }

    #[test]
    fn try_from_points_pins_every_error_path() {
        // Happy path.
        let ok = VfCurve::try_from_points(vec![(400.0, 0.85), (600.0, 0.95)]).unwrap();
        assert_eq!(ok.points.len(), 2);
        // Single point is valid (a flat one-step table).
        VfCurve::try_from_points(vec![(500.0, 1.0)]).unwrap();

        // Empty.
        let e = VfCurve::try_from_points(vec![]).unwrap_err();
        assert_eq!(e, VfCurveError::Empty);
        assert_eq!(e.to_string(), "curve needs at least one (mhz, volts) point");

        // Non-finite frequency and voltage, at the right index.
        let e = VfCurve::try_from_points(vec![(400.0, 0.85), (f64::NAN, 1.0)]).unwrap_err();
        assert!(matches!(e, VfCurveError::NonFinite { index: 1, .. }), "{e:?}");
        let e =
            VfCurve::try_from_points(vec![(400.0, f64::INFINITY)]).unwrap_err();
        assert!(matches!(e, VfCurveError::NonFinite { index: 0, .. }), "{e:?}");
        assert_eq!(e.to_string(), "point 0 (400:inf) must be finite");

        // Zero / negative values.
        let e = VfCurve::try_from_points(vec![(0.0, 0.85)]).unwrap_err();
        assert_eq!(e, VfCurveError::NonPositive { index: 0, mhz: 0.0, volts: 0.85 });
        let e = VfCurve::try_from_points(vec![(400.0, -0.85)]).unwrap_err();
        assert_eq!(e, VfCurveError::NonPositive { index: 0, mhz: 400.0, volts: -0.85 });
        assert_eq!(e.to_string(), "point 0 (400:-0.85) must be positive");

        // Exact duplicate frequency — distinct from merely descending.
        let e = VfCurve::try_from_points(vec![(400.0, 0.85), (400.0, 0.9)]).unwrap_err();
        assert_eq!(e, VfCurveError::DuplicateFrequency { index: 1, mhz: 400.0 });
        assert_eq!(e.to_string(), "duplicate frequency 400 MHz at point 1");

        // Backwards frequency.
        let e = VfCurve::try_from_points(vec![(600.0, 0.95), (400.0, 0.85)]).unwrap_err();
        assert_eq!(
            e,
            VfCurveError::NonAscendingFrequency { index: 1, prev_mhz: 600.0, mhz: 400.0 }
        );
        assert_eq!(
            e.to_string(),
            "frequencies must be strictly ascending: point 1 (400 MHz) after 600 MHz"
        );
    }

    #[test]
    fn leakage_excess_is_zero_off_and_anchored_at_vref() {
        let leak = LeakageParams { static_w: 8.0, leak_w: 14.0, v_ref: 1.0, v_slope: 0.8 };
        // Anchor: excess equals leak_w exactly at v_ref.
        assert!((leak.excess_w(1.0) - 14.0).abs() < 1e-12);
        assert_eq!(leak.total_w(1.0), 8.0 + leak.excess_w(1.0));
        // Off switch: exact 0.0, not merely small.
        let off = LeakageParams { leak_w: 0.0, ..leak };
        assert_eq!(off.excess_w(1.2125).to_bits(), 0.0f64.to_bits());
        assert_eq!(LeakageParams::flat(22.0).total_w(5.0), 22.0);
        // Monotone nondecreasing in V.
        let mut prev = 0.0;
        let mut v = 0.05;
        while v <= 1.5 {
            let e = leak.excess_w(v);
            assert!(e >= prev, "leakage fell at {v} V: {e} < {prev}");
            prev = e;
            v += 0.05;
        }
    }

    #[test]
    fn split_components_sum_to_total() {
        let p = PowerModel::gtx980();
        for &(cf, mf) in &[(400.0, 400.0), (700.0, 1000.0), (1000.0, 600.0)] {
            let s = p.split_w(cf, mf);
            assert!(
                (s.dynamic_w + s.leakage_w - s.total_w).abs() <= 1e-12 * s.total_w,
                "split does not sum at {cf}/{mf}"
            );
            assert_eq!(s.total_w.to_bits(), p.power_w(cf, mf).to_bits());
            assert!(s.dynamic_w > 0.0 && s.leakage_w > 0.0);
        }
    }

    #[test]
    fn without_leakage_drops_only_the_excess() {
        let p = PowerModel::gtx980();
        let v1 = p.without_leakage();
        assert_eq!(v1.leakage.leak_w, 0.0);
        assert_eq!(v1.leakage.static_w, p.leakage.static_w);
        assert_eq!(v1.dynamic, p.dynamic);
        let (s2, s1) = (p.split_w(900.0, 800.0), v1.split_w(900.0, 800.0));
        assert_eq!(s1.dynamic_w.to_bits(), s2.dynamic_w.to_bits());
        assert!(s1.leakage_w < s2.leakage_w);
        assert!(s1.total_w < s2.total_w);
    }

    #[test]
    fn energy_is_power_times_time_at_every_point() {
        // Every ConfigPoint must satisfy E = P × T (Eq. 1 applied to
        // the advisor's mJ bookkeeping: W·µs = µJ, /1e3 = mJ) and
        // EDP = E × T, for every objective — and carry the power
        // split that sums back to power_w.
        let model = PaperModel { hw: HwParams::paper_defaults() };
        let power = PowerModel::gtx980();
        for objective in
            [Objective::Energy, Objective::Edp, Objective::EnergyWithSlack(0.1)]
        {
            let (_, points) =
                advise(&counters_membound(), &model, &power, &grid(), objective);
            assert_eq!(points.len(), 49);
            for p in &points {
                assert_eq!(p.power_w.to_bits(), power.power_w(p.core_mhz, p.mem_mhz).to_bits());
                let split = power.split_w(p.core_mhz, p.mem_mhz);
                assert_eq!(p.power_dynamic_w.to_bits(), split.dynamic_w.to_bits());
                assert_eq!(p.power_leakage_w.to_bits(), split.leakage_w.to_bits());
                let want_mj = p.power_w * p.time_us * 1e-3;
                assert!(
                    (p.energy_mj - want_mj).abs() <= 1e-12 * want_mj.abs().max(1.0),
                    "E != P*T at {}/{}: {} vs {}",
                    p.core_mhz,
                    p.mem_mhz,
                    p.energy_mj,
                    want_mj
                );
                let want_edp = p.energy_mj * p.time_us;
                assert!((p.edp - want_edp).abs() <= 1e-12 * want_edp.abs().max(1.0));
            }
        }
    }

    #[test]
    fn advisor_picks_the_exhaustive_argmin() {
        // On a small grid, re-derive the optimum by brute force from
        // the returned points and check the advisor agrees exactly.
        let model = PaperModel { hw: HwParams::paper_defaults() };
        let power = PowerModel::gtx980();
        let small: Vec<(f64, f64)> = [400.0, 700.0, 1000.0]
            .iter()
            .flat_map(|&c| [400.0, 700.0, 1000.0].iter().map(move |&m| (c, m)))
            .collect();
        for c in [counters_membound(), counters_compbound()] {
            for objective in [Objective::Energy, Objective::Edp] {
                let (best, points) = advise(&c, &model, &power, &small, objective);
                assert_eq!(points.len(), 9);
                let key = |p: &ConfigPoint| match objective {
                    Objective::Edp => p.edp,
                    _ => p.energy_mj,
                };
                let brute = points
                    .iter()
                    .min_by(|a, b| key(a).total_cmp(&key(b)))
                    .unwrap();
                assert_eq!(best.core_mhz, brute.core_mhz, "{objective:?}");
                assert_eq!(best.mem_mhz, brute.mem_mhz, "{objective:?}");
                assert_eq!(key(&best).to_bits(), key(brute).to_bits());
                // And nothing beats it.
                for p in &points {
                    assert!(key(p) >= key(&best));
                }
            }
            // Slack: brute-force over the feasible subset only, using
            // the advisor's exact boundary arithmetic.
            let slack = 0.2;
            let (best, points) =
                advise(&c, &model, &power, &small, Objective::EnergyWithSlack(slack));
            let t_fast =
                points.iter().map(|p| p.time_us).fold(f64::INFINITY, f64::min);
            let brute = points
                .iter()
                .filter(|p| p.time_us <= (1.0 + slack) * t_fast)
                .min_by(|a, b| a.energy_mj.total_cmp(&b.energy_mj))
                .unwrap();
            assert_eq!(best.core_mhz, brute.core_mhz);
            assert_eq!(best.mem_mhz, brute.mem_mhz);
            assert!(best.time_us <= (1.0 + slack) * t_fast + 1e-9);
        }
    }

    #[test]
    fn power_monotone_in_both_domains() {
        let p = PowerModel::gtx980();
        assert!(p.power_w(1000.0, 700.0) > p.power_w(400.0, 700.0));
        assert!(p.power_w(700.0, 1000.0) > p.power_w(700.0, 400.0));
        // TDP-ish ballpark.
        let tdp = p.power_w(1000.0, 1000.0);
        assert!(tdp > 120.0 && tdp < 200.0, "{tdp}");
        assert!(p.power_w(400.0, 400.0) < 80.0);
    }

    #[test]
    fn membound_kernel_prefers_low_core_high_mem() {
        // The paper's motivation: for a DRAM-bound kernel, raising core
        // frequency burns power without speedup — the energy optimum
        // sits at low core, high memory.
        let (best, _) = advise(
            &counters_membound(),
            &PaperModel { hw: HwParams::paper_defaults() },
            &PowerModel::gtx980(),
            &grid(),
            Objective::Energy,
        );
        assert!(best.core_mhz <= 500.0, "core {}", best.core_mhz);
        assert!(best.mem_mhz >= 800.0, "mem {}", best.mem_mhz);
    }

    #[test]
    fn compbound_kernel_keeps_memory_low() {
        let (best, _) = advise(
            &counters_compbound(),
            &PaperModel { hw: HwParams::paper_defaults() },
            &PowerModel::gtx980(),
            &grid(),
            Objective::Energy,
        );
        assert!(best.mem_mhz <= 500.0, "mem {}", best.mem_mhz);
    }

    #[test]
    fn slack_constraint_binds() {
        let model = PaperModel { hw: HwParams::paper_defaults() };
        let power = PowerModel::gtx980();
        let c = counters_membound();
        let (unconstrained, points) = advise(&c, &model, &power, &grid(), Objective::Energy);
        let (tight, _) = advise(&c, &model, &power, &grid(), Objective::EnergyWithSlack(0.05));
        let t_fast = points.iter().map(|p| p.time_us).fold(f64::INFINITY, f64::min);
        assert!(tight.time_us <= 1.05 * t_fast + 1e-9);
        assert!(tight.energy_mj >= unconstrained.energy_mj - 1e-12);
    }

    #[test]
    fn engine_advisor_matches_predictor_advisor() {
        let hw = HwParams::paper_defaults();
        let model = PaperModel { hw };
        let power = PowerModel::gtx980();
        let c = counters_membound();
        let (direct_best, direct_points) =
            advise(&c, &model, &power, &grid(), Objective::Energy);
        let engine = Engine::native(hw);
        let (engine_best, engine_points) =
            advise_with_engine(&c, &engine, &power, &grid(), Objective::Energy).unwrap();
        assert_eq!(direct_best.core_mhz, engine_best.core_mhz);
        assert_eq!(direct_best.mem_mhz, engine_best.mem_mhz);
        assert_eq!(direct_best.energy_mj.to_bits(), engine_best.energy_mj.to_bits());
        for (a, b) in direct_points.iter().zip(&engine_points) {
            assert_eq!(a.time_us.to_bits(), b.time_us.to_bits());
        }
        // Second advisor run over the same grid never recomputes.
        advise_with_engine(&c, &engine, &power, &grid(), Objective::Edp).unwrap();
        assert!(engine.cache_stats().hits >= 49);
    }

    #[test]
    fn edp_objective_differs_from_energy() {
        let model = PaperModel { hw: HwParams::paper_defaults() };
        let power = PowerModel::gtx980();
        let c = counters_membound();
        let (e, points) = advise(&c, &model, &power, &grid(), Objective::Energy);
        let (d, _) = advise(&c, &model, &power, &grid(), Objective::Edp);
        // EDP never has larger EDP than the energy optimum's EDP.
        assert!(d.edp <= e.edp + 1e-12);
        assert_eq!(points.len(), 49);
    }
}
