//! DVFS energy model and advisor — the paper's motivating application
//! (§I and §VII future work: "a real-time voltage and frequency
//! controller based on energy conservation strategies").
//!
//! Power follows the paper's Eq. (1), `P_dynamic = a·C·V²·f`, applied
//! per clock domain with a voltage/frequency table, plus static power.
//! Energy = P(cf, mf) × T(cf, mf), with T from any `Predictor`.
//!
//! This module advises **one kernel on one device**. For batch
//! scheduling — many deadline-tagged jobs across every registered GPU,
//! under per-device concurrency caps — see [`crate::planner`], which
//! reuses the same [`PowerModel`] arithmetic per device (DESIGN.md
//! §11).

use anyhow::Result;

use crate::baselines::Predictor;
use crate::engine::Engine;
use crate::model::KernelCounters;

/// Voltage-frequency curve: linear interpolation over (MHz, V) points.
#[derive(Debug, Clone)]
pub struct VfCurve {
    /// Sorted (frequency MHz, volts) points.
    pub points: Vec<(f64, f64)>,
}

impl VfCurve {
    /// Validated constructor — the single invariant gate for every
    /// construction path (TOML `[power]` sections, the `/v2` wire):
    /// at least one point, positive finite values, strictly ascending
    /// frequencies.
    pub fn try_from_points(points: Vec<(f64, f64)>) -> Result<VfCurve, String> {
        if points.is_empty() {
            return Err("curve needs at least one (mhz, volts) point".to_string());
        }
        let mut prev = f64::NEG_INFINITY;
        for &(f, v) in &points {
            if !(f.is_finite() && v.is_finite() && f > 0.0 && v > 0.0) {
                return Err(format!("point {f}:{v} must be positive and finite"));
            }
            if f <= prev {
                return Err(format!("frequencies must be strictly ascending at {f}"));
            }
            prev = f;
        }
        Ok(VfCurve { points })
    }

    /// A Maxwell-like curve: 0.85 V at 400 MHz up to 1.2125 V at
    /// 1000 MHz (matching published GTX 980 V/f steps in shape).
    pub fn maxwell_core() -> Self {
        VfCurve {
            points: vec![(400.0, 0.85), (600.0, 0.95), (800.0, 1.075), (1000.0, 1.2125)],
        }
    }

    /// GDDR5 voltage barely scales: flat-ish curve.
    pub fn gddr5_mem() -> Self {
        VfCurve { points: vec![(400.0, 1.35), (1000.0, 1.5)] }
    }

    /// Voltage at `f_mhz` (clamped linear interpolation).
    pub fn volts(&self, f_mhz: f64) -> f64 {
        let pts = &self.points;
        if f_mhz <= pts[0].0 {
            return pts[0].1;
        }
        for w in pts.windows(2) {
            let ((f0, v0), (f1, v1)) = (w[0], w[1]);
            if f_mhz <= f1 {
                return v0 + (v1 - v0) * (f_mhz - f0) / (f1 - f0);
            }
        }
        pts.last().unwrap().1
    }
}

/// Eq. (1)-style power model with two frequency domains.
#[derive(Debug, Clone)]
pub struct PowerModel {
    pub core_curve: VfCurve,
    pub mem_curve: VfCurve,
    /// Effective a·C coefficient for the core domain, W / (MHz·V²).
    pub core_coeff: f64,
    /// Effective a·C coefficient for the memory domain, W / (MHz·V²).
    pub mem_coeff: f64,
    /// Static/leakage power, W.
    pub static_w: f64,
}

/// The GTX 980 calibration is the crate-wide default (matching
/// `HwParams::paper_defaults` and `GpuSpec::default`).
impl Default for PowerModel {
    fn default() -> Self {
        PowerModel::gtx980()
    }
}

impl PowerModel {
    /// Calibrated so the default GTX 980 lands near its 165 W TDP at
    /// 1000/1000 and ~60 W at 400/400.
    pub fn gtx980() -> Self {
        PowerModel {
            core_curve: VfCurve::maxwell_core(),
            mem_curve: VfCurve::gddr5_mem(),
            core_coeff: 0.072,
            mem_coeff: 0.018,
            static_w: 22.0,
        }
    }

    /// Board power at a frequency pair, watts.
    pub fn power_w(&self, core_mhz: f64, mem_mhz: f64) -> f64 {
        let vc = self.core_curve.volts(core_mhz);
        let vm = self.mem_curve.volts(mem_mhz);
        self.static_w + self.core_coeff * core_mhz * vc * vc + self.mem_coeff * mem_mhz * vm * vm
    }
}

/// One evaluated DVFS configuration.
#[derive(Debug, Clone, Copy)]
pub struct ConfigPoint {
    pub core_mhz: f64,
    pub mem_mhz: f64,
    pub time_us: f64,
    pub power_w: f64,
    /// Energy in millijoules.
    pub energy_mj: f64,
    /// Energy-delay product (mJ·µs).
    pub edp: f64,
}

/// What the advisor optimizes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Objective {
    /// Minimum energy.
    Energy,
    /// Minimum energy subject to `time <= (1 + slack) * t_fastest`.
    EnergyWithSlack(f64),
    /// Minimum energy-delay product.
    Edp,
}

/// Shared optimizer core: times are supplied per pair (from any
/// prediction path), power comes from the model, the objective picks.
fn advise_points(
    times_us: &[f64],
    power: &PowerModel,
    pairs: &[(f64, f64)],
    objective: Objective,
) -> (ConfigPoint, Vec<ConfigPoint>) {
    assert!(!pairs.is_empty());
    assert_eq!(times_us.len(), pairs.len());
    let points: Vec<ConfigPoint> = pairs
        .iter()
        .zip(times_us)
        .map(|(&(cf, mf), &time_us)| {
            let power_w = power.power_w(cf, mf);
            let energy_mj = power_w * time_us * 1e-3; // W·µs = µJ; /1e3 = mJ
            ConfigPoint {
                core_mhz: cf,
                mem_mhz: mf,
                time_us,
                power_w,
                energy_mj,
                edp: energy_mj * time_us,
            }
        })
        .collect();
    let t_fastest = points.iter().map(|p| p.time_us).fold(f64::INFINITY, f64::min);
    let feasible = |p: &&ConfigPoint| match objective {
        Objective::EnergyWithSlack(s) => p.time_us <= (1.0 + s) * t_fastest,
        _ => true,
    };
    let key = |p: &ConfigPoint| match objective {
        Objective::Edp => p.edp,
        _ => p.energy_mj,
    };
    let best = *points
        .iter()
        .filter(feasible)
        .min_by(|a, b| key(a).total_cmp(&key(b)))
        .expect("at least the fastest point is feasible");
    (best, points)
}

/// Evaluate every pair and pick the best per `objective`.
pub fn advise(
    counters: &KernelCounters,
    predictor: &dyn Predictor,
    power: &PowerModel,
    pairs: &[(f64, f64)],
    objective: Objective,
) -> (ConfigPoint, Vec<ConfigPoint>) {
    let times: Vec<f64> =
        pairs.iter().map(|&(cf, mf)| predictor.predict_us(counters, cf, mf)).collect();
    advise_points(&times, power, pairs, objective)
}

/// Engine-routed advisor — one batched `predict_grid` call per
/// invocation, so repeated advisor runs over the same grid (sweep of
/// objectives, per-kernel loops) are served from the engine's cache.
pub fn advise_with_engine(
    counters: &KernelCounters,
    engine: &Engine,
    power: &PowerModel,
    pairs: &[(f64, f64)],
    objective: Objective,
) -> Result<(ConfigPoint, Vec<ConfigPoint>)> {
    let times: Vec<f64> =
        engine.predict_grid(counters, pairs)?.iter().map(|e| e.time_us).collect();
    Ok(advise_points(&times, power, pairs, objective))
}

/// Handle-routed advisor (DESIGN.md §10): the device's own power model
/// comes from the engine's registry and timings from the device-keyed
/// handle path, so two registered GPUs get independent advice without
/// the caller threading `HwParams`/`PowerModel` structs around.
pub fn advise_with_handles(
    engine: &Engine,
    device: crate::registry::DeviceId,
    kernel: crate::registry::KernelId,
    pairs: &[(f64, f64)],
    objective: Objective,
) -> Result<(ConfigPoint, Vec<ConfigPoint>)> {
    let record = engine.device_record(device)?;
    let points: Vec<crate::registry::FreqPoint> =
        pairs.iter().map(|&p| p.into()).collect();
    let times: Vec<f64> = engine
        .predict_points(device, kernel, &points)?
        .iter()
        .map(|e| e.time_us)
        .collect();
    Ok(advise_points(&times, &record.power, pairs, objective))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::PaperModel;
    use crate::model::HwParams;

    fn counters_membound() -> KernelCounters {
        KernelCounters {
            l2_hr: 0.0,
            gld_trans: 12.0,
            avr_inst: 0.4,
            n_blocks: 256.0,
            wpb: 8.0,
            aw: 64.0,
            n_sm: 16.0,
            o_itrs: 8.0,
            i_itrs: 0.0,
            uses_smem: false,
            smem_conflict: 1.0,
            gld_body: 12.0,
            gld_edge: 0.0,
            mem_ops: 3.0,
            l1_hr: 0.0,
        }
    }

    fn counters_compbound() -> KernelCounters {
        KernelCounters { avr_inst: 100.0, l2_hr: 0.9, gld_trans: 2.0, ..counters_membound() }
    }

    fn grid() -> Vec<(f64, f64)> {
        crate::microbench::standard_grid()
    }

    #[test]
    fn vf_curve_interpolates_and_clamps() {
        let c = VfCurve::maxwell_core();
        assert_eq!(c.volts(300.0), 0.85);
        assert_eq!(c.volts(1200.0), 1.2125);
        let v = c.volts(500.0);
        assert!(v > 0.85 && v < 0.95);
        assert!((c.volts(600.0) - 0.95).abs() < 1e-12);
    }

    #[test]
    fn vf_curve_clamps_at_both_ends() {
        // Below the first point and above the last, interpolation must
        // clamp (a controller can ask for out-of-table frequencies).
        for curve in [VfCurve::maxwell_core(), VfCurve::gddr5_mem()] {
            let (f_lo, v_lo) = curve.points[0];
            let (f_hi, v_hi) = *curve.points.last().unwrap();
            assert_eq!(curve.volts(f_lo - 1000.0), v_lo);
            assert_eq!(curve.volts(0.0), v_lo);
            assert_eq!(curve.volts(f_lo), v_lo);
            assert_eq!(curve.volts(f_hi), v_hi);
            assert_eq!(curve.volts(f_hi + 1000.0), v_hi);
            // Interior points stay within the envelope and monotone.
            let mut prev = v_lo;
            let mut f = f_lo;
            while f <= f_hi {
                let v = curve.volts(f);
                assert!(v >= prev - 1e-12, "non-monotone at {f}: {v} < {prev}");
                assert!((v_lo..=v_hi).contains(&v), "{v} outside [{v_lo}, {v_hi}]");
                prev = v;
                f += 25.0;
            }
        }
    }

    #[test]
    fn energy_is_power_times_time_at_every_point() {
        // Every ConfigPoint must satisfy E = P × T (Eq. 1 applied to
        // the advisor's mJ bookkeeping: W·µs = µJ, /1e3 = mJ) and
        // EDP = E × T, for every objective.
        let model = PaperModel { hw: HwParams::paper_defaults() };
        let power = PowerModel::gtx980();
        for objective in
            [Objective::Energy, Objective::Edp, Objective::EnergyWithSlack(0.1)]
        {
            let (_, points) =
                advise(&counters_membound(), &model, &power, &grid(), objective);
            assert_eq!(points.len(), 49);
            for p in &points {
                assert_eq!(p.power_w.to_bits(), power.power_w(p.core_mhz, p.mem_mhz).to_bits());
                let want_mj = p.power_w * p.time_us * 1e-3;
                assert!(
                    (p.energy_mj - want_mj).abs() <= 1e-12 * want_mj.abs().max(1.0),
                    "E != P*T at {}/{}: {} vs {}",
                    p.core_mhz,
                    p.mem_mhz,
                    p.energy_mj,
                    want_mj
                );
                let want_edp = p.energy_mj * p.time_us;
                assert!((p.edp - want_edp).abs() <= 1e-12 * want_edp.abs().max(1.0));
            }
        }
    }

    #[test]
    fn advisor_picks_the_exhaustive_argmin() {
        // On a small grid, re-derive the optimum by brute force from
        // the returned points and check the advisor agrees exactly.
        let model = PaperModel { hw: HwParams::paper_defaults() };
        let power = PowerModel::gtx980();
        let small: Vec<(f64, f64)> = [400.0, 700.0, 1000.0]
            .iter()
            .flat_map(|&c| [400.0, 700.0, 1000.0].iter().map(move |&m| (c, m)))
            .collect();
        for c in [counters_membound(), counters_compbound()] {
            for objective in [Objective::Energy, Objective::Edp] {
                let (best, points) = advise(&c, &model, &power, &small, objective);
                assert_eq!(points.len(), 9);
                let key = |p: &ConfigPoint| match objective {
                    Objective::Edp => p.edp,
                    _ => p.energy_mj,
                };
                let brute = points
                    .iter()
                    .min_by(|a, b| key(a).total_cmp(&key(b)))
                    .unwrap();
                assert_eq!(best.core_mhz, brute.core_mhz, "{objective:?}");
                assert_eq!(best.mem_mhz, brute.mem_mhz, "{objective:?}");
                assert_eq!(key(&best).to_bits(), key(brute).to_bits());
                // And nothing beats it.
                for p in &points {
                    assert!(key(p) >= key(&best));
                }
            }
            // Slack: brute-force over the feasible subset only, using
            // the advisor's exact boundary arithmetic.
            let slack = 0.2;
            let (best, points) =
                advise(&c, &model, &power, &small, Objective::EnergyWithSlack(slack));
            let t_fast =
                points.iter().map(|p| p.time_us).fold(f64::INFINITY, f64::min);
            let brute = points
                .iter()
                .filter(|p| p.time_us <= (1.0 + slack) * t_fast)
                .min_by(|a, b| a.energy_mj.total_cmp(&b.energy_mj))
                .unwrap();
            assert_eq!(best.core_mhz, brute.core_mhz);
            assert_eq!(best.mem_mhz, brute.mem_mhz);
            assert!(best.time_us <= (1.0 + slack) * t_fast + 1e-9);
        }
    }

    #[test]
    fn power_monotone_in_both_domains() {
        let p = PowerModel::gtx980();
        assert!(p.power_w(1000.0, 700.0) > p.power_w(400.0, 700.0));
        assert!(p.power_w(700.0, 1000.0) > p.power_w(700.0, 400.0));
        // TDP-ish ballpark.
        let tdp = p.power_w(1000.0, 1000.0);
        assert!(tdp > 120.0 && tdp < 200.0, "{tdp}");
        assert!(p.power_w(400.0, 400.0) < 80.0);
    }

    #[test]
    fn membound_kernel_prefers_low_core_high_mem() {
        // The paper's motivation: for a DRAM-bound kernel, raising core
        // frequency burns power without speedup — the energy optimum
        // sits at low core, high memory.
        let (best, _) = advise(
            &counters_membound(),
            &PaperModel { hw: HwParams::paper_defaults() },
            &PowerModel::gtx980(),
            &grid(),
            Objective::Energy,
        );
        assert!(best.core_mhz <= 500.0, "core {}", best.core_mhz);
        assert!(best.mem_mhz >= 800.0, "mem {}", best.mem_mhz);
    }

    #[test]
    fn compbound_kernel_keeps_memory_low() {
        let (best, _) = advise(
            &counters_compbound(),
            &PaperModel { hw: HwParams::paper_defaults() },
            &PowerModel::gtx980(),
            &grid(),
            Objective::Energy,
        );
        assert!(best.mem_mhz <= 500.0, "mem {}", best.mem_mhz);
    }

    #[test]
    fn slack_constraint_binds() {
        let model = PaperModel { hw: HwParams::paper_defaults() };
        let power = PowerModel::gtx980();
        let c = counters_membound();
        let (unconstrained, points) = advise(&c, &model, &power, &grid(), Objective::Energy);
        let (tight, _) = advise(&c, &model, &power, &grid(), Objective::EnergyWithSlack(0.05));
        let t_fast = points.iter().map(|p| p.time_us).fold(f64::INFINITY, f64::min);
        assert!(tight.time_us <= 1.05 * t_fast + 1e-9);
        assert!(tight.energy_mj >= unconstrained.energy_mj - 1e-12);
    }

    #[test]
    fn engine_advisor_matches_predictor_advisor() {
        let hw = HwParams::paper_defaults();
        let model = PaperModel { hw };
        let power = PowerModel::gtx980();
        let c = counters_membound();
        let (direct_best, direct_points) =
            advise(&c, &model, &power, &grid(), Objective::Energy);
        let engine = Engine::native(hw);
        let (engine_best, engine_points) =
            advise_with_engine(&c, &engine, &power, &grid(), Objective::Energy).unwrap();
        assert_eq!(direct_best.core_mhz, engine_best.core_mhz);
        assert_eq!(direct_best.mem_mhz, engine_best.mem_mhz);
        assert_eq!(direct_best.energy_mj.to_bits(), engine_best.energy_mj.to_bits());
        for (a, b) in direct_points.iter().zip(&engine_points) {
            assert_eq!(a.time_us.to_bits(), b.time_us.to_bits());
        }
        // Second advisor run over the same grid never recomputes.
        advise_with_engine(&c, &engine, &power, &grid(), Objective::Edp).unwrap();
        assert!(engine.cache_stats().hits >= 49);
    }

    #[test]
    fn edp_objective_differs_from_energy() {
        let model = PaperModel { hw: HwParams::paper_defaults() };
        let power = PowerModel::gtx980();
        let c = counters_membound();
        let (e, points) = advise(&c, &model, &power, &grid(), Objective::Energy);
        let (d, _) = advise(&c, &model, &power, &grid(), Objective::Edp);
        // EDP never has larger EDP than the energy optimum's EDP.
        assert!(d.edp <= e.edp + 1e-12);
        assert_eq!(points.len(), 49);
    }
}
