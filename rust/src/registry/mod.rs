//! Device and kernel identity (DESIGN.md §10): the handle layer behind
//! the typed v2 prediction API.
//!
//! The paper's workflow is inherently multi-device — hardware
//! parameters are micro-benchmarked **per GPU** (§IV) and kernels are
//! profiled **once per device** at the baseline frequency (§V) — so a
//! production prediction service must address devices and kernels by
//! stable identity instead of re-shipping full `HwParams` /
//! `KernelCounters` blobs on every request:
//!
//! * [`DeviceRegistry`] — registered GPUs. Each [`DeviceRecord`] owns
//!   the device's measured [`HwParams`] and its DVFS [`PowerModel`]
//!   (V/f curves + Eq. (1) coefficients). Loadable from
//!   `configs/*.toml` via [`DeviceRegistry::register_from_config`],
//!   which runs the §IV micro-benchmarks against the config's
//!   `GpuSpec` — parameters are *measured per device*, never copied.
//! * [`KernelCatalog`] — named kernels with their baseline-profiled
//!   counters (the paper's one-shot Nsight pass).
//! * [`DeviceId`] / [`KernelId`] / [`FreqPoint`] — the handle triple
//!   `engine::Engine` and the `/v2` wire protocol operate on. The
//!   fleet planner ([`crate::planner`]) also derives each device's
//!   candidate operating points from the record's `PowerModel` V/f
//!   curves, so a registered GPU is plannable with no extra setup.
//!
//! Identity semantics: device records are **immutable** — re-registering
//! a name mints a fresh id (the name resolves to the latest record), so
//! a cache entry keyed on a `DeviceId` can never silently refer to
//! changed parameters. Kernels follow the v1 service semantics instead:
//! re-registering a name updates the counters in place under the same
//! id (counters are part of every cache key, so stale hits cannot
//! survive a counter change above f32 resolution).

use std::fmt;
use std::path::Path;
use std::str::FromStr;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::RwLock;

use anyhow::{Context as _, Result};

use crate::config;
use crate::dvfs::PowerModel;
use crate::microbench;
use crate::model::{HwParams, KernelCounters};

/// Opaque handle for a registered device. Renders as `dev-<n>` on the
/// wire; ids start at 1 (0 is reserved for the anonymous raw-struct
/// path in the engine's cache key).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DeviceId(pub u64);

/// Opaque handle for a catalogued kernel. Renders as `krn-<n>`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct KernelId(pub u64);

impl fmt::Display for DeviceId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "dev-{}", self.0)
    }
}

impl fmt::Display for KernelId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "krn-{}", self.0)
    }
}

fn parse_handle(s: &str, prefix: &str) -> Option<u64> {
    let n: u64 = s.strip_prefix(prefix)?.parse().ok()?;
    (n > 0).then_some(n)
}

impl FromStr for DeviceId {
    type Err = ();

    fn from_str(s: &str) -> std::result::Result<Self, ()> {
        parse_handle(s, "dev-").map(DeviceId).ok_or(())
    }
}

impl FromStr for KernelId {
    type Err = ();

    fn from_str(s: &str) -> std::result::Result<Self, ()> {
        parse_handle(s, "krn-").map(KernelId).ok_or(())
    }
}

/// Whether `name` collides with the wire-handle grammar
/// (`dev-<n>` / `krn-<n>`). Such names are reserved: a device literally
/// named "dev-1" would be shadowed by whichever record holds id 1, so
/// every registration path rejects them (enforced in `try_register`).
pub fn is_reserved_name(name: &str) -> bool {
    name.parse::<DeviceId>().is_ok() || name.parse::<KernelId>().is_ok()
}

/// Why a registration was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RegisterError {
    /// The capacity bound was reached.
    Full,
    /// The name collides with the `dev-<n>`/`krn-<n>` handle grammar.
    ReservedName,
}

impl fmt::Display for RegisterError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RegisterError::Full => write!(f, "registry is full"),
            RegisterError::ReservedName => {
                write!(f, "names matching the handle grammar (dev-<n> / krn-<n>) are reserved")
            }
        }
    }
}

impl std::error::Error for RegisterError {}

/// One (core, mem) frequency operating point, MHz.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FreqPoint {
    pub core_mhz: f64,
    pub mem_mhz: f64,
}

impl FreqPoint {
    pub fn new(core_mhz: f64, mem_mhz: f64) -> Self {
        FreqPoint { core_mhz, mem_mhz }
    }

    /// Frequencies a prediction can be evaluated at: positive, finite.
    pub fn is_valid(&self) -> bool {
        self.core_mhz.is_finite()
            && self.mem_mhz.is_finite()
            && self.core_mhz > 0.0
            && self.mem_mhz > 0.0
    }
}

impl From<(f64, f64)> for FreqPoint {
    fn from((core_mhz, mem_mhz): (f64, f64)) -> Self {
        FreqPoint { core_mhz, mem_mhz }
    }
}

/// Everything the system knows about one registered GPU.
#[derive(Debug, Clone)]
pub struct DeviceRecord {
    pub id: DeviceId,
    pub name: String,
    /// Measured hardware parameters (§IV micro-benchmarks).
    pub hw: HwParams,
    /// DVFS V/f curves + Eq. (1) power coefficients.
    pub power: PowerModel,
}

/// Registered GPUs, addressed by [`DeviceId`] or name. Thread-safe and
/// cheap to share behind an `Arc`; all methods take `&self`.
#[derive(Debug)]
pub struct DeviceRegistry {
    records: RwLock<Vec<DeviceRecord>>,
    next_id: AtomicU64,
}

/// Manual impl: ids must start at 1 (0 is the reserved anonymous
/// device word), which a derived `Default` would violate.
impl Default for DeviceRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl DeviceRegistry {
    pub fn new() -> Self {
        DeviceRegistry { records: RwLock::new(Vec::new()), next_id: AtomicU64::new(1) }
    }

    /// Register a device; returns its fresh handle. Re-registering an
    /// existing name mints a new id (records are immutable) and the
    /// name resolves to the newest record from then on.
    ///
    /// Panics on a handle-shaped name ([`is_reserved_name`]) — use
    /// [`DeviceRegistry::try_register`] for externally-supplied names.
    pub fn register(&self, name: &str, hw: HwParams, power: PowerModel) -> DeviceId {
        match self.try_register(name, hw, power, usize::MAX) {
            Ok(id) => id,
            Err(e) => panic!("register `{name}`: {e}"),
        }
    }

    /// [`DeviceRegistry::register`] with the invariants made fallible:
    /// handle-shaped names are rejected (they would be shadowed by
    /// real ids — enforced here so *every* construction path agrees),
    /// and the capacity bound is checked under the same write lock
    /// that appends the record, so concurrent registrations (service
    /// workers) can never overshoot `max`.
    pub fn try_register(
        &self,
        name: &str,
        hw: HwParams,
        power: PowerModel,
        max: usize,
    ) -> Result<DeviceId, RegisterError> {
        if is_reserved_name(name) {
            return Err(RegisterError::ReservedName);
        }
        let mut g = self.records.write().expect("registry poisoned");
        if g.len() >= max {
            return Err(RegisterError::Full);
        }
        let id = DeviceId(self.next_id.fetch_add(1, Relaxed));
        g.push(DeviceRecord { id, name: name.to_string(), hw, power });
        Ok(id)
    }

    /// Load a `configs/*.toml` GPU description and register it: the
    /// §IV micro-benchmarks run against the config's simulator spec to
    /// *measure* `HwParams`, and `[power]`/`[device]` sections supply
    /// the power model and name (file stem when unnamed).
    pub fn register_from_config(&self, path: &Path) -> Result<DeviceId> {
        let cfg = config::load(path)?;
        let name = cfg
            .device_name
            .clone()
            .or_else(|| path.file_stem().map(|s| s.to_string_lossy().into_owned()))
            .context("config has no [device] name and the path has no file stem")?;
        let ex = microbench::extract(&cfg.gpu, cfg.sweep.baseline());
        self.try_register(&name, ex.hw, cfg.power, usize::MAX)
            .map_err(|e| anyhow::anyhow!("registering `{name}`: {e}"))
    }

    pub fn get(&self, id: DeviceId) -> Option<DeviceRecord> {
        self.records
            .read()
            .expect("registry poisoned")
            .iter()
            .find(|r| r.id == id)
            .cloned()
    }

    /// Latest record registered under `name`.
    pub fn by_name(&self, name: &str) -> Option<DeviceRecord> {
        self.records
            .read()
            .expect("registry poisoned")
            .iter()
            .rev()
            .find(|r| r.name == name)
            .cloned()
    }

    /// Resolve a wire handle to just its id — no record clone, for
    /// hot paths that only route. `dev-<n>` wins when that id exists;
    /// anything else (including a handle-shaped string whose id is
    /// absent) falls back to name lookup.
    pub fn resolve_id(&self, handle: &str) -> Option<DeviceId> {
        let g = self.records.read().expect("registry poisoned");
        if let Ok(id) = handle.parse::<DeviceId>() {
            if g.iter().any(|r| r.id == id) {
                return Some(id);
            }
        }
        g.iter().rev().find(|r| r.name == handle).map(|r| r.id)
    }

    /// Resolve a wire handle to a full record clone (see
    /// [`DeviceRegistry::resolve_id`] for precedence).
    pub fn resolve(&self, handle: &str) -> Option<DeviceRecord> {
        let id = self.resolve_id(handle)?;
        self.get(id)
    }

    /// Every record, in registration order.
    pub fn list(&self) -> Vec<DeviceRecord> {
        self.records.read().expect("registry poisoned").clone()
    }

    pub fn len(&self) -> usize {
        self.records.read().expect("registry poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One catalogued kernel: a name plus its baseline-profiled counters.
#[derive(Debug, Clone)]
pub struct KernelEntry {
    pub id: KernelId,
    pub name: String,
    pub counters: KernelCounters,
}

/// Named kernels with baseline-profiled counters, addressed by
/// [`KernelId`] or name. Same sharing model as [`DeviceRegistry`].
#[derive(Debug)]
pub struct KernelCatalog {
    entries: RwLock<Vec<KernelEntry>>,
    next_id: AtomicU64,
}

/// Manual impl: ids start at 1, matching [`KernelCatalog::new`].
impl Default for KernelCatalog {
    fn default() -> Self {
        Self::new()
    }
}

impl KernelCatalog {
    pub fn new() -> Self {
        KernelCatalog { entries: RwLock::new(Vec::new()), next_id: AtomicU64::new(1) }
    }

    /// Register (or re-profile) a kernel. A known name keeps its id and
    /// gets the new counters; a new name mints a fresh id.
    ///
    /// Panics on a handle-shaped name ([`is_reserved_name`]) — use
    /// [`KernelCatalog::try_register`] for externally-supplied names.
    pub fn register(&self, name: &str, counters: KernelCounters) -> KernelId {
        match self.try_register(name, counters, usize::MAX) {
            Ok(id) => id,
            Err(e) => panic!("register `{name}`: {e}"),
        }
    }

    /// [`KernelCatalog::register`] with the invariants made fallible:
    /// handle-shaped names are rejected, and the capacity bound on
    /// **new** names (in-place re-profiles never grow the catalog and
    /// always succeed) is checked under the write lock so concurrent
    /// registrations can never overshoot `max`.
    pub fn try_register(
        &self,
        name: &str,
        counters: KernelCounters,
        max: usize,
    ) -> Result<KernelId, RegisterError> {
        if is_reserved_name(name) {
            return Err(RegisterError::ReservedName);
        }
        let mut g = self.entries.write().expect("catalog poisoned");
        if let Some(e) = g.iter_mut().find(|e| e.name == name) {
            e.counters = counters;
            return Ok(e.id);
        }
        if g.len() >= max {
            return Err(RegisterError::Full);
        }
        let id = KernelId(self.next_id.fetch_add(1, Relaxed));
        g.push(KernelEntry { id, name: name.to_string(), counters });
        Ok(id)
    }

    pub fn get(&self, id: KernelId) -> Option<KernelEntry> {
        self.entries
            .read()
            .expect("catalog poisoned")
            .iter()
            .find(|e| e.id == id)
            .cloned()
    }

    pub fn by_name(&self, name: &str) -> Option<KernelEntry> {
        self.entries
            .read()
            .expect("catalog poisoned")
            .iter()
            .find(|e| e.name == name)
            .cloned()
    }

    /// Resolve a wire handle to just its id — no entry clone. Same
    /// precedence as [`DeviceRegistry::resolve_id`].
    pub fn resolve_id(&self, handle: &str) -> Option<KernelId> {
        let g = self.entries.read().expect("catalog poisoned");
        if let Ok(id) = handle.parse::<KernelId>() {
            if g.iter().any(|e| e.id == id) {
                return Some(id);
            }
        }
        g.iter().find(|e| e.name == handle).map(|e| e.id)
    }

    /// Resolve a wire handle to a full entry clone.
    pub fn resolve(&self, handle: &str) -> Option<KernelEntry> {
        let id = self.resolve_id(handle)?;
        self.get(id)
    }

    pub fn list(&self) -> Vec<KernelEntry> {
        self.entries.read().expect("catalog poisoned").clone()
    }

    pub fn names(&self) -> Vec<String> {
        self.entries.read().expect("catalog poisoned").iter().map(|e| e.name.clone()).collect()
    }

    pub fn len(&self) -> usize {
        self.entries.read().expect("catalog poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counters() -> KernelCounters {
        KernelCounters {
            l2_hr: 0.1,
            gld_trans: 6.0,
            avr_inst: 1.5,
            n_blocks: 128.0,
            wpb: 8.0,
            aw: 64.0,
            n_sm: 16.0,
            o_itrs: 8.0,
            i_itrs: 0.0,
            uses_smem: false,
            smem_conflict: 1.0,
            gld_body: 6.0,
            gld_edge: 0.0,
            mem_ops: 2.0,
            l1_hr: 0.0,
        }
    }

    #[test]
    fn handles_render_and_parse() {
        assert_eq!(DeviceId(3).to_string(), "dev-3");
        assert_eq!("dev-3".parse::<DeviceId>(), Ok(DeviceId(3)));
        assert_eq!(KernelId(7).to_string(), "krn-7");
        assert_eq!("krn-7".parse::<KernelId>(), Ok(KernelId(7)));
        for bad in ["dev-", "dev-0", "krn-x", "dev-3x", "3", "", "krn--1"] {
            assert!(bad.parse::<DeviceId>().is_err(), "{bad}");
            assert!(bad.parse::<KernelId>().is_err(), "{bad}");
        }
        // 0 is reserved for the anonymous raw path.
        assert!("dev-0".parse::<DeviceId>().is_err());
    }

    #[test]
    fn freq_point_validity() {
        assert!(FreqPoint::new(700.0, 700.0).is_valid());
        for bad in [
            FreqPoint::new(0.0, 700.0),
            FreqPoint::new(700.0, -1.0),
            FreqPoint::new(f64::NAN, 700.0),
            FreqPoint::new(700.0, f64::INFINITY),
        ] {
            assert!(!bad.is_valid(), "{bad:?}");
        }
    }

    #[test]
    fn registry_register_get_list() {
        let reg = DeviceRegistry::new();
        assert!(reg.is_empty());
        let a = reg.register("gtx980", HwParams::paper_defaults(), PowerModel::gtx980());
        let mut hw2 = HwParams::paper_defaults();
        hw2.dm_del += 1.0;
        let b = reg.register("gtx960", hw2, PowerModel::gtx980());
        // Ids start at 1 — 0 is the engine's anonymous raw-path word —
        // and `Default` must agree with `new`.
        assert_eq!(a, DeviceId(1));
        assert_ne!(a, b);
        assert_eq!(reg.len(), 2);
        let fresh = DeviceRegistry::default();
        assert_eq!(
            fresh.register("d", HwParams::paper_defaults(), PowerModel::gtx980()),
            DeviceId(1)
        );
        assert_eq!(KernelCatalog::default().register("k", counters()), KernelId(1));
        assert_eq!(reg.get(a).unwrap().name, "gtx980");
        assert_eq!(reg.by_name("gtx960").unwrap().id, b);
        assert_eq!(reg.resolve(&a.to_string()).unwrap().id, a);
        assert_eq!(reg.resolve("gtx980").unwrap().id, a);
        assert!(reg.get(DeviceId(99)).is_none());
        assert!(reg.resolve("dev-99").is_none());
        let names: Vec<String> = reg.list().into_iter().map(|r| r.name).collect();
        assert_eq!(names, ["gtx980", "gtx960"]);
    }

    #[test]
    fn reregistered_device_name_mints_a_fresh_id() {
        let reg = DeviceRegistry::new();
        let a = reg.register("lab", HwParams::paper_defaults(), PowerModel::gtx980());
        let mut hw2 = HwParams::paper_defaults();
        hw2.l2_lat += 10.0;
        let b = reg.register("lab", hw2, PowerModel::gtx980());
        assert_ne!(a, b, "records are immutable; re-register mints a new id");
        // The name resolves to the newest record; the old id still works.
        assert_eq!(reg.by_name("lab").unwrap().id, b);
        assert_eq!(reg.get(a).unwrap().hw, HwParams::paper_defaults());
        assert_eq!(reg.get(b).unwrap().hw, hw2);
    }

    #[test]
    fn catalog_updates_counters_in_place() {
        let cat = KernelCatalog::new();
        let a = cat.register("VA", counters());
        let mut c2 = counters();
        c2.avr_inst = 42.0;
        let b = cat.register("VA", c2);
        assert_eq!(a, b, "known names keep their id");
        assert_eq!(cat.len(), 1);
        assert_eq!(cat.get(a).unwrap().counters.avr_inst, 42.0);
        assert_eq!(cat.resolve("VA").unwrap().id, a);
        assert_eq!(cat.resolve(&a.to_string()).unwrap().name, "VA");
        assert!(cat.resolve("krn-9").is_none());
        assert_eq!(cat.names(), ["VA"]);
    }

    #[test]
    fn resolve_prefers_live_ids_then_names() {
        let reg = DeviceRegistry::new();
        let a = reg.register("gpu-a", HwParams::paper_defaults(), PowerModel::gtx980());
        // A handle-shaped string resolves by id when that id is live.
        assert_eq!(reg.resolve_id("dev-1"), Some(a));
        assert_eq!(reg.resolve_id("gpu-a"), Some(a));
        assert_eq!(reg.resolve_id("dev-99"), None);
        let cat = KernelCatalog::new();
        let k = cat.register("va", counters());
        assert_eq!(cat.resolve_id("krn-1"), Some(k));
        assert_eq!(cat.resolve_id("va"), Some(k));
        assert_eq!(cat.resolve_id("krn-9"), None);
    }

    #[test]
    fn reserved_names_are_rejected_at_the_source() {
        // Handle-shaped names would be shadowed by real ids; every
        // construction path funnels through try_register, which
        // refuses them.
        let hw = HwParams::paper_defaults();
        let reg = DeviceRegistry::new();
        assert_eq!(
            reg.try_register("dev-9", hw, PowerModel::gtx980(), 10),
            Err(RegisterError::ReservedName)
        );
        assert_eq!(
            reg.try_register("krn-3", hw, PowerModel::gtx980(), 10),
            Err(RegisterError::ReservedName)
        );
        assert_eq!(reg.len(), 0);
        let cat = KernelCatalog::new();
        assert_eq!(cat.try_register("krn-1", counters(), 10), Err(RegisterError::ReservedName));
        assert_eq!(cat.len(), 0);
        assert!(is_reserved_name("dev-9"));
        assert!(is_reserved_name("krn-3"));
        assert!(!is_reserved_name("gtx980"));
        assert!(!is_reserved_name("dev-x"));
        assert!(!is_reserved_name(""));
    }

    #[test]
    fn try_register_enforces_the_bound_under_the_lock() {
        let reg = DeviceRegistry::new();
        let hw = HwParams::paper_defaults();
        assert!(reg.try_register("a", hw, PowerModel::gtx980(), 1).is_ok());
        assert_eq!(
            reg.try_register("b", hw, PowerModel::gtx980(), 1),
            Err(RegisterError::Full)
        );
        assert_eq!(reg.len(), 1);
        let cat = KernelCatalog::new();
        let k = cat.try_register("k", counters(), 1).unwrap();
        // In-place re-profiles bypass the bound; new names do not.
        let mut c2 = counters();
        c2.avr_inst = 7.0;
        assert_eq!(cat.try_register("k", c2, 1), Ok(k));
        assert_eq!(cat.get(k).unwrap().counters.avr_inst, 7.0);
        assert_eq!(cat.try_register("k2", counters(), 1), Err(RegisterError::Full));
        assert_eq!(cat.len(), 1);
    }

    #[test]
    fn register_from_config_measures_per_device_params() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
        let reg = DeviceRegistry::new();
        let a = reg.register_from_config(&dir.join("gtx980.toml")).unwrap();
        let b = reg.register_from_config(&dir.join("gtx960.toml")).unwrap();
        let ra = reg.get(a).unwrap();
        let rb = reg.get(b).unwrap();
        assert_eq!(ra.name, "gtx980");
        assert_eq!(rb.name, "gtx960");
        // The 960 config describes a slower memory subsystem; the
        // measured Eq. (4) slope must reflect it (no parameter copying).
        assert!(rb.hw.dm_lat_a > ra.hw.dm_lat_a);
    }
}
