//! Config system: typed loading of GPU specs (the paper's Table V),
//! sweep/baseline settings, and per-device DVFS power models from
//! TOML-subset files in `configs/`. A config file is the on-disk form
//! of one `registry::DeviceRecord`: `[gpu]` feeds the §IV
//! micro-benchmarks that *measure* `HwParams`, `[power]` carries the
//! Eq. (1) coefficients and V/f curves, and `[device] name` labels the
//! record (file stem when absent).

pub mod toml;

use std::path::Path;

use crate::dvfs::{DynamicParams, LeakageParams, PowerModel, VfCurve};
use crate::sim::{Clocks, GpuSpec};
use toml::Document;

/// Frequency-sweep settings (§VI-A: 400–1000 MHz, 100 MHz stride, 49
/// pairs, baseline 700/700).
#[derive(Debug, Clone, PartialEq)]
pub struct SweepConfig {
    pub core_min_mhz: f64,
    pub core_max_mhz: f64,
    pub mem_min_mhz: f64,
    pub mem_max_mhz: f64,
    pub stride_mhz: f64,
    pub baseline_core_mhz: f64,
    pub baseline_mem_mhz: f64,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            core_min_mhz: 400.0,
            core_max_mhz: 1000.0,
            mem_min_mhz: 400.0,
            mem_max_mhz: 1000.0,
            stride_mhz: 100.0,
            baseline_core_mhz: 700.0,
            baseline_mem_mhz: 700.0,
        }
    }
}

impl SweepConfig {
    /// All (core, mem) pairs in the grid.
    pub fn pairs(&self) -> Vec<(f64, f64)> {
        let steps = |lo: f64, hi: f64, stride: f64| {
            let mut v = Vec::new();
            let mut f = lo;
            while f <= hi + 1e-9 {
                v.push(f);
                f += stride;
            }
            v
        };
        let cores = steps(self.core_min_mhz, self.core_max_mhz, self.stride_mhz);
        let mems = steps(self.mem_min_mhz, self.mem_max_mhz, self.stride_mhz);
        let mut out = Vec::with_capacity(cores.len() * mems.len());
        for &cf in &cores {
            for &mf in &mems {
                out.push((cf, mf));
            }
        }
        out
    }

    pub fn baseline(&self) -> Clocks {
        Clocks::new(self.baseline_core_mhz, self.baseline_mem_mhz)
    }
}

/// Complete runtime configuration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    pub gpu: GpuSpec,
    pub sweep: SweepConfig,
    /// Kernel names to run (empty = all).
    pub kernels: Vec<String>,
    /// Device label for the registry (`[device] name`); `None` falls
    /// back to the config file stem.
    pub device_name: Option<String>,
    /// DVFS power model (`[power]` section; GTX 980 defaults).
    pub power: PowerModel,
}

/// Build a `GpuSpec` from a parsed document's `[gpu]` section, using
/// the GTX 980 defaults for anything unspecified.
pub fn gpu_from_doc(doc: &Document) -> GpuSpec {
    let d = GpuSpec::default();
    GpuSpec {
        n_sm: doc.u32_or("gpu.n_sm", d.n_sm),
        max_warps_per_sm: doc.u32_or("gpu.max_warps_per_sm", d.max_warps_per_sm),
        max_blocks_per_sm: doc.u32_or("gpu.max_blocks_per_sm", d.max_blocks_per_sm),
        smem_per_sm: doc.u32_or("gpu.smem_per_sm", d.smem_per_sm),
        regs_per_sm: doc.u32_or("gpu.regs_per_sm", d.regs_per_sm),
        l2_bytes: doc.u64_or("gpu.l2_bytes", d.l2_bytes),
        l2_ways: doc.u32_or("gpu.l2_ways", d.l2_ways),
        line_bytes: doc.u32_or("gpu.line_bytes", d.line_bytes),
        l2_hit_core_cycles: doc.f64_or("gpu.l2_hit_core_cycles", d.l2_hit_core_cycles),
        l2_ii_core_cycles: doc.f64_or("gpu.l2_ii_core_cycles", d.l2_ii_core_cycles),
        dm_path_core_cycles: doc.f64_or("gpu.dm_path_core_cycles", d.dm_path_core_cycles),
        dm_access_mem_cycles: doc.f64_or("gpu.dm_access_mem_cycles", d.dm_access_mem_cycles),
        dm_burst_mem_cycles: doc.f64_or("gpu.dm_burst_mem_cycles", d.dm_burst_mem_cycles),
        mc_overhead_mem_cycles: doc
            .f64_or("gpu.mc_overhead_mem_cycles", d.mc_overhead_mem_cycles),
        dram_banks: doc.u32_or("gpu.dram_banks", d.dram_banks),
        dram_row_lines: doc.u32_or("gpu.dram_row_lines", d.dram_row_lines),
        dram_row_miss_lat_mem_cycles: doc
            .f64_or("gpu.dram_row_miss_lat_mem_cycles", d.dram_row_miss_lat_mem_cycles),
        dram_row_miss_occ_mem_cycles: doc
            .f64_or("gpu.dram_row_miss_occ_mem_cycles", d.dram_row_miss_occ_mem_cycles),
        l1_bytes: doc.u64_or("gpu.l1_bytes", d.l1_bytes),
        l1_ways: doc.u32_or("gpu.l1_ways", d.l1_ways),
        l1_hit_core_cycles: doc.f64_or("gpu.l1_hit_core_cycles", d.l1_hit_core_cycles),
        smem_core_cycles: doc.f64_or("gpu.smem_core_cycles", d.smem_core_cycles),
        inst_core_cycles: doc.f64_or("gpu.inst_core_cycles", d.inst_core_cycles),
        block_launch_core_cycles: doc
            .f64_or("gpu.block_launch_core_cycles", d.block_launch_core_cycles),
    }
}

/// Parse a V/f curve string of the form `"400:0.85, 600:0.95"`
/// ((MHz, volts) points, comma-separated); validity (non-empty,
/// positive finite, strictly ascending) is enforced by the shared
/// [`VfCurve::try_from_points`] constructor.
fn vf_curve_from_str(text: &str, key: &str) -> Result<VfCurve, toml::ParseError> {
    let bad = |message: String| toml::ParseError { line: 0, message };
    let mut points = Vec::new();
    for part in text.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (f, v) = part
            .split_once(':')
            .ok_or_else(|| bad(format!("{key}: expected `mhz:volts`, got `{part}`")))?;
        let f: f64 = f
            .trim()
            .parse()
            .map_err(|_| bad(format!("{key}: bad frequency `{f}`")))?;
        let v: f64 = v.trim().parse().map_err(|_| bad(format!("{key}: bad voltage `{v}`")))?;
        points.push((f, v));
    }
    VfCurve::try_from_points(points).map_err(|m| bad(format!("{key}: {m}")))
}

/// The complete key vocabulary of the `[power]` family of sections;
/// anything else under `power.` is a typo and rejected outright.
const POWER_KEYS: &[&str] = &[
    "power.core_vf",
    "power.mem_vf",
    // Legacy flat spelling of the dynamic coefficients + static floor.
    "power.core_coeff",
    "power.mem_coeff",
    "power.static_w",
    // Power v2 sections (DESIGN.md §15).
    "power.dynamic.core_coeff",
    "power.dynamic.mem_coeff",
    "power.leakage.static_w",
    "power.leakage.leak_w",
    "power.leakage.v_ref",
    "power.leakage.v_slope",
];

/// Build a `PowerModel` from a document's `[power]`, `[power.dynamic]`
/// and `[power.leakage]` sections, with the GTX 980 calibration for
/// anything unspecified. V/f curves are strings of `mhz:volts` points:
/// `core_vf = "400:0.85, 1000:1.2125"`. The legacy flat keys
/// (`power.core_coeff` etc.) remain accepted but conflict with their
/// v2 spellings; present-but-mistyped or out-of-range values are hard
/// errors, never silent defaults.
pub fn power_from_doc(doc: &Document) -> Result<PowerModel, toml::ParseError> {
    let bad = |message: String| toml::ParseError { line: 0, message };
    for key in doc.section_keys("power") {
        if !POWER_KEYS.contains(&key) {
            return Err(bad(format!("unknown power key `{key}`")));
        }
    }
    let number = |key: &str, default: f64| -> Result<f64, toml::ParseError> {
        match doc.get(key) {
            None => Ok(default),
            Some(v) => match v.as_f64() {
                Some(x) if x.is_finite() => Ok(x),
                Some(x) => Err(bad(format!("{key}: must be finite, got {x}"))),
                None => Err(bad(format!("{key}: expected a number"))),
            },
        }
    };
    let nonneg = |key: &str, default: f64| -> Result<f64, toml::ParseError> {
        let x = number(key, default)?;
        if x < 0.0 {
            return Err(bad(format!("{key}: must be >= 0, got {x}")));
        }
        Ok(x)
    };
    let positive = |key: &str, default: f64| -> Result<f64, toml::ParseError> {
        let x = number(key, default)?;
        if x <= 0.0 {
            return Err(bad(format!("{key}: must be > 0, got {x}")));
        }
        Ok(x)
    };
    // A legacy flat key and its v2 spelling are the same knob — naming
    // both is ambiguous, not an override chain.
    let aliased = |legacy: &str, v2: &str| -> Result<&'static str, toml::ParseError> {
        match (doc.get(legacy).is_some(), doc.get(v2).is_some()) {
            (true, true) => Err(bad(format!("`{legacy}` conflicts with `{v2}`: set one"))),
            (true, false) => Ok("legacy"),
            _ => Ok("v2"),
        }
    };
    let pick = |legacy: &str, v2: &str, default: f64| -> Result<f64, toml::ParseError> {
        match aliased(legacy, v2)? {
            "legacy" => nonneg(legacy, default),
            _ => nonneg(v2, default),
        }
    };
    let d = PowerModel::gtx980();
    let curve = |key: &str, default: VfCurve| -> Result<VfCurve, toml::ParseError> {
        match doc.get(key) {
            None => Ok(default),
            Some(v) => match v.as_str() {
                Some(text) => vf_curve_from_str(text, key),
                None => Err(bad(format!("{key}: expected a string of mhz:volts points"))),
            },
        }
    };
    Ok(PowerModel {
        core_curve: curve("power.core_vf", d.core_curve)?,
        mem_curve: curve("power.mem_vf", d.mem_curve)?,
        dynamic: DynamicParams {
            core_coeff: pick(
                "power.core_coeff",
                "power.dynamic.core_coeff",
                d.dynamic.core_coeff,
            )?,
            mem_coeff: pick("power.mem_coeff", "power.dynamic.mem_coeff", d.dynamic.mem_coeff)?,
        },
        leakage: LeakageParams {
            static_w: pick("power.static_w", "power.leakage.static_w", d.leakage.static_w)?,
            leak_w: nonneg("power.leakage.leak_w", d.leakage.leak_w)?,
            v_ref: positive("power.leakage.v_ref", d.leakage.v_ref)?,
            v_slope: positive("power.leakage.v_slope", d.leakage.v_slope)?,
        },
    })
}

/// Format an `f64` so `to_text` → `parse` round-trips exactly: Rust's
/// shortest-representation `Display` re-parses to the same bits (whole
/// floats print as integers, which `as_f64` widens back losslessly).
fn fmt_f64(x: f64) -> String {
    format!("{x}")
}

fn fmt_curve(curve: &VfCurve) -> String {
    curve
        .points
        .iter()
        .map(|&(f, v)| format!("{}:{}", fmt_f64(f), fmt_f64(v)))
        .collect::<Vec<_>>()
        .join(", ")
}

/// Serialize a `Config` back to TOML text. `from_text(&to_text(c))`
/// reconstructs a `Config` equal to `c` — the round-trip the
/// `tests/config_roundtrip.rs` suite pins for every shipped config.
pub fn to_text(c: &Config) -> String {
    let mut out = String::new();
    let mut push = |line: String| {
        out.push_str(&line);
        out.push('\n');
    };
    if let Some(name) = &c.device_name {
        push("[device]".into());
        push(format!("name = \"{name}\""));
        push(String::new());
    }
    push("[gpu]".into());
    let g = &c.gpu;
    push(format!("n_sm = {}", g.n_sm));
    push(format!("max_warps_per_sm = {}", g.max_warps_per_sm));
    push(format!("max_blocks_per_sm = {}", g.max_blocks_per_sm));
    push(format!("smem_per_sm = {}", g.smem_per_sm));
    push(format!("regs_per_sm = {}", g.regs_per_sm));
    push(format!("l2_bytes = {}", g.l2_bytes));
    push(format!("l2_ways = {}", g.l2_ways));
    push(format!("line_bytes = {}", g.line_bytes));
    push(format!("l2_hit_core_cycles = {}", fmt_f64(g.l2_hit_core_cycles)));
    push(format!("l2_ii_core_cycles = {}", fmt_f64(g.l2_ii_core_cycles)));
    push(format!("dm_path_core_cycles = {}", fmt_f64(g.dm_path_core_cycles)));
    push(format!("dm_access_mem_cycles = {}", fmt_f64(g.dm_access_mem_cycles)));
    push(format!("dm_burst_mem_cycles = {}", fmt_f64(g.dm_burst_mem_cycles)));
    push(format!("mc_overhead_mem_cycles = {}", fmt_f64(g.mc_overhead_mem_cycles)));
    push(format!("dram_banks = {}", g.dram_banks));
    push(format!("dram_row_lines = {}", g.dram_row_lines));
    push(format!(
        "dram_row_miss_lat_mem_cycles = {}",
        fmt_f64(g.dram_row_miss_lat_mem_cycles)
    ));
    push(format!(
        "dram_row_miss_occ_mem_cycles = {}",
        fmt_f64(g.dram_row_miss_occ_mem_cycles)
    ));
    push(format!("l1_bytes = {}", g.l1_bytes));
    push(format!("l1_ways = {}", g.l1_ways));
    push(format!("l1_hit_core_cycles = {}", fmt_f64(g.l1_hit_core_cycles)));
    push(format!("smem_core_cycles = {}", fmt_f64(g.smem_core_cycles)));
    push(format!("inst_core_cycles = {}", fmt_f64(g.inst_core_cycles)));
    push(format!("block_launch_core_cycles = {}", fmt_f64(g.block_launch_core_cycles)));
    push(String::new());
    push("[sweep]".into());
    let s = &c.sweep;
    push(format!("core_min_mhz = {}", fmt_f64(s.core_min_mhz)));
    push(format!("core_max_mhz = {}", fmt_f64(s.core_max_mhz)));
    push(format!("mem_min_mhz = {}", fmt_f64(s.mem_min_mhz)));
    push(format!("mem_max_mhz = {}", fmt_f64(s.mem_max_mhz)));
    push(format!("stride_mhz = {}", fmt_f64(s.stride_mhz)));
    push(format!("baseline_core_mhz = {}", fmt_f64(s.baseline_core_mhz)));
    push(format!("baseline_mem_mhz = {}", fmt_f64(s.baseline_mem_mhz)));
    push(String::new());
    if !c.kernels.is_empty() {
        push("[kernels]".into());
        push(format!("names = \"{}\"", c.kernels.join(", ")));
        push(String::new());
    }
    let p = &c.power;
    push("[power]".into());
    push(format!("core_vf = \"{}\"", fmt_curve(&p.core_curve)));
    push(format!("mem_vf = \"{}\"", fmt_curve(&p.mem_curve)));
    push(String::new());
    push("[power.dynamic]".into());
    push(format!("core_coeff = {}", fmt_f64(p.dynamic.core_coeff)));
    push(format!("mem_coeff = {}", fmt_f64(p.dynamic.mem_coeff)));
    push(String::new());
    push("[power.leakage]".into());
    push(format!("static_w = {}", fmt_f64(p.leakage.static_w)));
    push(format!("leak_w = {}", fmt_f64(p.leakage.leak_w)));
    push(format!("v_ref = {}", fmt_f64(p.leakage.v_ref)));
    push(format!("v_slope = {}", fmt_f64(p.leakage.v_slope)));
    out
}

/// Build a `SweepConfig` from a document's `[sweep]` section.
pub fn sweep_from_doc(doc: &Document) -> SweepConfig {
    let d = SweepConfig::default();
    SweepConfig {
        core_min_mhz: doc.f64_or("sweep.core_min_mhz", d.core_min_mhz),
        core_max_mhz: doc.f64_or("sweep.core_max_mhz", d.core_max_mhz),
        mem_min_mhz: doc.f64_or("sweep.mem_min_mhz", d.mem_min_mhz),
        mem_max_mhz: doc.f64_or("sweep.mem_max_mhz", d.mem_max_mhz),
        stride_mhz: doc.f64_or("sweep.stride_mhz", d.stride_mhz),
        baseline_core_mhz: doc.f64_or("sweep.baseline_core_mhz", d.baseline_core_mhz),
        baseline_mem_mhz: doc.f64_or("sweep.baseline_mem_mhz", d.baseline_mem_mhz),
    }
}

/// Parse a configuration from TOML text.
pub fn from_text(text: &str) -> Result<Config, toml::ParseError> {
    let doc = toml::parse(text)?;
    let kernels = doc
        .get("kernels.names")
        .and_then(|v| v.as_str().map(|s| s.to_string()))
        .map(|s| s.split(',').map(|k| k.trim().to_string()).filter(|k| !k.is_empty()).collect())
        .unwrap_or_default();
    let device_name =
        doc.get("device.name").and_then(|v| v.as_str()).map(|s| s.to_string());
    Ok(Config {
        gpu: gpu_from_doc(&doc),
        sweep: sweep_from_doc(&doc),
        kernels,
        device_name,
        power: power_from_doc(&doc)?,
    })
}

/// Load a configuration file.
pub fn load(path: &Path) -> anyhow::Result<Config> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    from_text(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_sweep_is_49_pairs_with_paper_baseline() {
        let s = SweepConfig::default();
        let pairs = s.pairs();
        assert_eq!(pairs.len(), 49);
        assert_eq!(pairs[0], (400.0, 400.0));
        assert_eq!(pairs[48], (1000.0, 1000.0));
        assert_eq!(s.baseline().core_mhz, 700.0);
    }

    #[test]
    fn empty_text_gives_defaults() {
        let c = from_text("").unwrap();
        assert_eq!(c.gpu.n_sm, 16);
        assert_eq!(c.sweep, SweepConfig::default());
        assert!(c.kernels.is_empty());
    }

    #[test]
    fn overrides_apply() {
        let c = from_text(
            r#"
[gpu]
n_sm = 8
l2_bytes = 1048576
inst_core_cycles = 4.0
[sweep]
stride_mhz = 300.0
core_max_mhz = 700.0
[kernels]
names = "VA, MMS"
"#,
        )
        .unwrap();
        assert_eq!(c.gpu.n_sm, 8);
        assert_eq!(c.gpu.l2_bytes, 1048576);
        assert_eq!(c.gpu.inst_core_cycles, 4.0);
        assert_eq!(c.sweep.pairs().len(), 2 * 3); // cores {400,700}, mems {400,700,1000}
        assert_eq!(c.kernels, vec!["VA".to_string(), "MMS".to_string()]);
    }

    #[test]
    fn bad_config_is_an_error() {
        assert!(from_text("gpu = [broken").is_err());
    }

    #[test]
    fn device_and_power_sections_parse() {
        let c = from_text(
            r#"
[device]
name = "lab-rig"
[power]
core_coeff = 0.05
static_w = 30.0
core_vf = "400:0.9, 800:1.1"
"#,
        )
        .unwrap();
        assert_eq!(c.device_name.as_deref(), Some("lab-rig"));
        assert_eq!(c.power.dynamic.core_coeff, 0.05);
        assert_eq!(c.power.leakage.static_w, 30.0);
        // Unspecified power fields keep the GTX 980 calibration.
        assert_eq!(c.power.dynamic.mem_coeff, PowerModel::gtx980().dynamic.mem_coeff);
        assert_eq!(c.power.leakage.leak_w, PowerModel::gtx980().leakage.leak_w);
        assert_eq!(c.power.core_curve.points, vec![(400.0, 0.9), (800.0, 1.1)]);
        assert_eq!(c.power.mem_curve.points, PowerModel::gtx980().mem_curve.points);
        // Defaults when both sections are absent.
        let d = from_text("").unwrap();
        assert_eq!(d.device_name, None);
        assert_eq!(d.power, PowerModel::gtx980());
    }

    #[test]
    fn v2_power_sections_parse() {
        let c = from_text(
            r#"
[power]
core_vf = "400:0.85, 1000:1.2125"
[power.dynamic]
core_coeff = 0.065
mem_coeff = 0.021
[power.leakage]
static_w = 9.5
leak_w = 12.0
v_ref = 1.05
v_slope = 0.75
"#,
        )
        .unwrap();
        assert_eq!(c.power.dynamic, DynamicParams { core_coeff: 0.065, mem_coeff: 0.021 });
        assert_eq!(
            c.power.leakage,
            LeakageParams { static_w: 9.5, leak_w: 12.0, v_ref: 1.05, v_slope: 0.75 }
        );
    }

    #[test]
    fn legacy_and_v2_power_keys_conflict() {
        let e = from_text(
            "[power]\ncore_coeff = 0.05\n[power.dynamic]\ncore_coeff = 0.06\n",
        )
        .unwrap_err();
        assert!(e.message.contains("conflicts"), "{e}");
        let e = from_text(
            "[power]\nstatic_w = 20.0\n[power.leakage]\nstatic_w = 8.0\n",
        )
        .unwrap_err();
        assert!(e.message.contains("conflicts"), "{e}");
    }

    #[test]
    fn unknown_and_mistyped_power_keys_are_errors() {
        assert!(from_text("[power]\ncore_coef = 0.05\n").is_err(), "typo'd key");
        assert!(from_text("[power.leakage]\nleak_w = \"lots\"\n").is_err(), "string leak_w");
        assert!(from_text("[power]\ncore_vf = 400\n").is_err(), "numeric curve");
        assert!(from_text("[power.leakage]\nv_slope = 0\n").is_err(), "zero slope");
        assert!(from_text("[power.dynamic]\nmem_coeff = -0.1\n").is_err(), "negative coeff");
    }

    #[test]
    fn config_round_trips_through_to_text() {
        let mut c = from_text(
            r#"
[device]
name = "rig"
[gpu]
n_sm = 10
[kernels]
names = "VA, MMS"
[power.leakage]
leak_w = 9.25
"#,
        )
        .unwrap();
        c.sweep.stride_mhz = 150.0;
        let again = from_text(&to_text(&c)).unwrap();
        assert_eq!(c, again);
        // And the default config round-trips too.
        let d = Config::default();
        assert_eq!(d, from_text(&to_text(&d)).unwrap());
    }

    #[test]
    fn malformed_vf_curves_are_errors() {
        for bad in [
            r#"[power]
core_vf = "nonsense""#,
            r#"[power]
core_vf = "400:0.9, 300:1.0""#,
            r#"[power]
mem_vf = "400:-1""#,
            r#"[power]
mem_vf = "  ""#,
        ] {
            assert!(from_text(bad).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn gtx980_config_file_loads() {
        // The checked-in Table V config must parse and match defaults.
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/gtx980.toml");
        let c = load(&path).unwrap();
        assert_eq!(c.gpu.n_sm, 16);
        assert_eq!(c.gpu.l2_bytes, 2 * 1024 * 1024);
        assert_eq!(c.sweep.pairs().len(), 49);
        // The checked-in [power] sections ARE the built-in calibration.
        assert_eq!(c.power, PowerModel::gtx980());
    }
}
