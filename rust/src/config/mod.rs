//! Config system: typed loading of GPU specs (the paper's Table V),
//! sweep/baseline settings, and per-device DVFS power models from
//! TOML-subset files in `configs/`. A config file is the on-disk form
//! of one `registry::DeviceRecord`: `[gpu]` feeds the §IV
//! micro-benchmarks that *measure* `HwParams`, `[power]` carries the
//! Eq. (1) coefficients and V/f curves, and `[device] name` labels the
//! record (file stem when absent).

pub mod toml;

use std::path::Path;

use crate::dvfs::{PowerModel, VfCurve};
use crate::sim::{Clocks, GpuSpec};
use toml::Document;

/// Frequency-sweep settings (§VI-A: 400–1000 MHz, 100 MHz stride, 49
/// pairs, baseline 700/700).
#[derive(Debug, Clone, PartialEq)]
pub struct SweepConfig {
    pub core_min_mhz: f64,
    pub core_max_mhz: f64,
    pub mem_min_mhz: f64,
    pub mem_max_mhz: f64,
    pub stride_mhz: f64,
    pub baseline_core_mhz: f64,
    pub baseline_mem_mhz: f64,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            core_min_mhz: 400.0,
            core_max_mhz: 1000.0,
            mem_min_mhz: 400.0,
            mem_max_mhz: 1000.0,
            stride_mhz: 100.0,
            baseline_core_mhz: 700.0,
            baseline_mem_mhz: 700.0,
        }
    }
}

impl SweepConfig {
    /// All (core, mem) pairs in the grid.
    pub fn pairs(&self) -> Vec<(f64, f64)> {
        let steps = |lo: f64, hi: f64, stride: f64| {
            let mut v = Vec::new();
            let mut f = lo;
            while f <= hi + 1e-9 {
                v.push(f);
                f += stride;
            }
            v
        };
        let cores = steps(self.core_min_mhz, self.core_max_mhz, self.stride_mhz);
        let mems = steps(self.mem_min_mhz, self.mem_max_mhz, self.stride_mhz);
        let mut out = Vec::with_capacity(cores.len() * mems.len());
        for &cf in &cores {
            for &mf in &mems {
                out.push((cf, mf));
            }
        }
        out
    }

    pub fn baseline(&self) -> Clocks {
        Clocks::new(self.baseline_core_mhz, self.baseline_mem_mhz)
    }
}

/// Complete runtime configuration.
#[derive(Debug, Clone, Default)]
pub struct Config {
    pub gpu: GpuSpec,
    pub sweep: SweepConfig,
    /// Kernel names to run (empty = all).
    pub kernels: Vec<String>,
    /// Device label for the registry (`[device] name`); `None` falls
    /// back to the config file stem.
    pub device_name: Option<String>,
    /// DVFS power model (`[power]` section; GTX 980 defaults).
    pub power: PowerModel,
}

/// Build a `GpuSpec` from a parsed document's `[gpu]` section, using
/// the GTX 980 defaults for anything unspecified.
pub fn gpu_from_doc(doc: &Document) -> GpuSpec {
    let d = GpuSpec::default();
    GpuSpec {
        n_sm: doc.u32_or("gpu.n_sm", d.n_sm),
        max_warps_per_sm: doc.u32_or("gpu.max_warps_per_sm", d.max_warps_per_sm),
        max_blocks_per_sm: doc.u32_or("gpu.max_blocks_per_sm", d.max_blocks_per_sm),
        smem_per_sm: doc.u32_or("gpu.smem_per_sm", d.smem_per_sm),
        regs_per_sm: doc.u32_or("gpu.regs_per_sm", d.regs_per_sm),
        l2_bytes: doc.u64_or("gpu.l2_bytes", d.l2_bytes),
        l2_ways: doc.u32_or("gpu.l2_ways", d.l2_ways),
        line_bytes: doc.u32_or("gpu.line_bytes", d.line_bytes),
        l2_hit_core_cycles: doc.f64_or("gpu.l2_hit_core_cycles", d.l2_hit_core_cycles),
        l2_ii_core_cycles: doc.f64_or("gpu.l2_ii_core_cycles", d.l2_ii_core_cycles),
        dm_path_core_cycles: doc.f64_or("gpu.dm_path_core_cycles", d.dm_path_core_cycles),
        dm_access_mem_cycles: doc.f64_or("gpu.dm_access_mem_cycles", d.dm_access_mem_cycles),
        dm_burst_mem_cycles: doc.f64_or("gpu.dm_burst_mem_cycles", d.dm_burst_mem_cycles),
        mc_overhead_mem_cycles: doc
            .f64_or("gpu.mc_overhead_mem_cycles", d.mc_overhead_mem_cycles),
        dram_banks: doc.u32_or("gpu.dram_banks", d.dram_banks),
        dram_row_lines: doc.u32_or("gpu.dram_row_lines", d.dram_row_lines),
        dram_row_miss_lat_mem_cycles: doc
            .f64_or("gpu.dram_row_miss_lat_mem_cycles", d.dram_row_miss_lat_mem_cycles),
        dram_row_miss_occ_mem_cycles: doc
            .f64_or("gpu.dram_row_miss_occ_mem_cycles", d.dram_row_miss_occ_mem_cycles),
        l1_bytes: doc.u64_or("gpu.l1_bytes", d.l1_bytes),
        l1_ways: doc.u32_or("gpu.l1_ways", d.l1_ways),
        l1_hit_core_cycles: doc.f64_or("gpu.l1_hit_core_cycles", d.l1_hit_core_cycles),
        smem_core_cycles: doc.f64_or("gpu.smem_core_cycles", d.smem_core_cycles),
        inst_core_cycles: doc.f64_or("gpu.inst_core_cycles", d.inst_core_cycles),
        block_launch_core_cycles: doc
            .f64_or("gpu.block_launch_core_cycles", d.block_launch_core_cycles),
    }
}

/// Parse a V/f curve string of the form `"400:0.85, 600:0.95"`
/// ((MHz, volts) points, comma-separated); validity (non-empty,
/// positive finite, strictly ascending) is enforced by the shared
/// [`VfCurve::try_from_points`] constructor.
fn vf_curve_from_str(text: &str, key: &str) -> Result<VfCurve, toml::ParseError> {
    let bad = |message: String| toml::ParseError { line: 0, message };
    let mut points = Vec::new();
    for part in text.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (f, v) = part
            .split_once(':')
            .ok_or_else(|| bad(format!("{key}: expected `mhz:volts`, got `{part}`")))?;
        let f: f64 = f
            .trim()
            .parse()
            .map_err(|_| bad(format!("{key}: bad frequency `{f}`")))?;
        let v: f64 = v.trim().parse().map_err(|_| bad(format!("{key}: bad voltage `{v}`")))?;
        points.push((f, v));
    }
    VfCurve::try_from_points(points).map_err(|m| bad(format!("{key}: {m}")))
}

/// Build a `PowerModel` from a document's `[power]` section, with the
/// GTX 980 calibration for anything unspecified. V/f curves are
/// strings of `mhz:volts` points: `core_vf = "400:0.85, 1000:1.2125"`.
pub fn power_from_doc(doc: &Document) -> Result<PowerModel, toml::ParseError> {
    let d = PowerModel::gtx980();
    let curve = |key: &str, default: VfCurve| -> Result<VfCurve, toml::ParseError> {
        match doc.get(key).and_then(|v| v.as_str()) {
            Some(text) => vf_curve_from_str(text, key),
            None => Ok(default),
        }
    };
    Ok(PowerModel {
        core_curve: curve("power.core_vf", d.core_curve)?,
        mem_curve: curve("power.mem_vf", d.mem_curve)?,
        core_coeff: doc.f64_or("power.core_coeff", d.core_coeff),
        mem_coeff: doc.f64_or("power.mem_coeff", d.mem_coeff),
        static_w: doc.f64_or("power.static_w", d.static_w),
    })
}

/// Build a `SweepConfig` from a document's `[sweep]` section.
pub fn sweep_from_doc(doc: &Document) -> SweepConfig {
    let d = SweepConfig::default();
    SweepConfig {
        core_min_mhz: doc.f64_or("sweep.core_min_mhz", d.core_min_mhz),
        core_max_mhz: doc.f64_or("sweep.core_max_mhz", d.core_max_mhz),
        mem_min_mhz: doc.f64_or("sweep.mem_min_mhz", d.mem_min_mhz),
        mem_max_mhz: doc.f64_or("sweep.mem_max_mhz", d.mem_max_mhz),
        stride_mhz: doc.f64_or("sweep.stride_mhz", d.stride_mhz),
        baseline_core_mhz: doc.f64_or("sweep.baseline_core_mhz", d.baseline_core_mhz),
        baseline_mem_mhz: doc.f64_or("sweep.baseline_mem_mhz", d.baseline_mem_mhz),
    }
}

/// Parse a configuration from TOML text.
pub fn from_text(text: &str) -> Result<Config, toml::ParseError> {
    let doc = toml::parse(text)?;
    let kernels = doc
        .get("kernels.names")
        .and_then(|v| v.as_str().map(|s| s.to_string()))
        .map(|s| s.split(',').map(|k| k.trim().to_string()).filter(|k| !k.is_empty()).collect())
        .unwrap_or_default();
    let device_name =
        doc.get("device.name").and_then(|v| v.as_str()).map(|s| s.to_string());
    Ok(Config {
        gpu: gpu_from_doc(&doc),
        sweep: sweep_from_doc(&doc),
        kernels,
        device_name,
        power: power_from_doc(&doc)?,
    })
}

/// Load a configuration file.
pub fn load(path: &Path) -> anyhow::Result<Config> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    from_text(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_sweep_is_49_pairs_with_paper_baseline() {
        let s = SweepConfig::default();
        let pairs = s.pairs();
        assert_eq!(pairs.len(), 49);
        assert_eq!(pairs[0], (400.0, 400.0));
        assert_eq!(pairs[48], (1000.0, 1000.0));
        assert_eq!(s.baseline().core_mhz, 700.0);
    }

    #[test]
    fn empty_text_gives_defaults() {
        let c = from_text("").unwrap();
        assert_eq!(c.gpu.n_sm, 16);
        assert_eq!(c.sweep, SweepConfig::default());
        assert!(c.kernels.is_empty());
    }

    #[test]
    fn overrides_apply() {
        let c = from_text(
            r#"
[gpu]
n_sm = 8
l2_bytes = 1048576
inst_core_cycles = 4.0
[sweep]
stride_mhz = 300.0
core_max_mhz = 700.0
[kernels]
names = "VA, MMS"
"#,
        )
        .unwrap();
        assert_eq!(c.gpu.n_sm, 8);
        assert_eq!(c.gpu.l2_bytes, 1048576);
        assert_eq!(c.gpu.inst_core_cycles, 4.0);
        assert_eq!(c.sweep.pairs().len(), 2 * 3); // cores {400,700}, mems {400,700,1000}
        assert_eq!(c.kernels, vec!["VA".to_string(), "MMS".to_string()]);
    }

    #[test]
    fn bad_config_is_an_error() {
        assert!(from_text("gpu = [broken").is_err());
    }

    #[test]
    fn device_and_power_sections_parse() {
        let c = from_text(
            r#"
[device]
name = "lab-rig"
[power]
core_coeff = 0.05
static_w = 30.0
core_vf = "400:0.9, 800:1.1"
"#,
        )
        .unwrap();
        assert_eq!(c.device_name.as_deref(), Some("lab-rig"));
        assert_eq!(c.power.core_coeff, 0.05);
        assert_eq!(c.power.static_w, 30.0);
        // Unspecified power fields keep the GTX 980 calibration.
        assert_eq!(c.power.mem_coeff, PowerModel::gtx980().mem_coeff);
        assert_eq!(c.power.core_curve.points, vec![(400.0, 0.9), (800.0, 1.1)]);
        assert_eq!(c.power.mem_curve.points, PowerModel::gtx980().mem_curve.points);
        // Defaults when both sections are absent.
        let d = from_text("").unwrap();
        assert_eq!(d.device_name, None);
        assert_eq!(d.power.core_coeff, PowerModel::gtx980().core_coeff);
    }

    #[test]
    fn malformed_vf_curves_are_errors() {
        for bad in [
            r#"[power]
core_vf = "nonsense""#,
            r#"[power]
core_vf = "400:0.9, 300:1.0""#,
            r#"[power]
mem_vf = "400:-1""#,
            r#"[power]
mem_vf = "  ""#,
        ] {
            assert!(from_text(bad).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn gtx980_config_file_loads() {
        // The checked-in Table V config must parse and match defaults.
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/gtx980.toml");
        let c = load(&path).unwrap();
        assert_eq!(c.gpu.n_sm, 16);
        assert_eq!(c.gpu.l2_bytes, 2 * 1024 * 1024);
        assert_eq!(c.sweep.pairs().len(), 49);
    }
}
