//! Config system: typed loading of GPU specs (the paper's Table V) and
//! sweep/baseline settings from TOML-subset files in `configs/`.

pub mod toml;

use std::path::Path;

use crate::sim::{Clocks, GpuSpec};
use toml::Document;

/// Frequency-sweep settings (§VI-A: 400–1000 MHz, 100 MHz stride, 49
/// pairs, baseline 700/700).
#[derive(Debug, Clone, PartialEq)]
pub struct SweepConfig {
    pub core_min_mhz: f64,
    pub core_max_mhz: f64,
    pub mem_min_mhz: f64,
    pub mem_max_mhz: f64,
    pub stride_mhz: f64,
    pub baseline_core_mhz: f64,
    pub baseline_mem_mhz: f64,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            core_min_mhz: 400.0,
            core_max_mhz: 1000.0,
            mem_min_mhz: 400.0,
            mem_max_mhz: 1000.0,
            stride_mhz: 100.0,
            baseline_core_mhz: 700.0,
            baseline_mem_mhz: 700.0,
        }
    }
}

impl SweepConfig {
    /// All (core, mem) pairs in the grid.
    pub fn pairs(&self) -> Vec<(f64, f64)> {
        let steps = |lo: f64, hi: f64, stride: f64| {
            let mut v = Vec::new();
            let mut f = lo;
            while f <= hi + 1e-9 {
                v.push(f);
                f += stride;
            }
            v
        };
        let cores = steps(self.core_min_mhz, self.core_max_mhz, self.stride_mhz);
        let mems = steps(self.mem_min_mhz, self.mem_max_mhz, self.stride_mhz);
        let mut out = Vec::with_capacity(cores.len() * mems.len());
        for &cf in &cores {
            for &mf in &mems {
                out.push((cf, mf));
            }
        }
        out
    }

    pub fn baseline(&self) -> Clocks {
        Clocks::new(self.baseline_core_mhz, self.baseline_mem_mhz)
    }
}

/// Complete runtime configuration.
#[derive(Debug, Clone, Default)]
pub struct Config {
    pub gpu: GpuSpec,
    pub sweep: SweepConfig,
    /// Kernel names to run (empty = all).
    pub kernels: Vec<String>,
}

/// Build a `GpuSpec` from a parsed document's `[gpu]` section, using
/// the GTX 980 defaults for anything unspecified.
pub fn gpu_from_doc(doc: &Document) -> GpuSpec {
    let d = GpuSpec::default();
    GpuSpec {
        n_sm: doc.u32_or("gpu.n_sm", d.n_sm),
        max_warps_per_sm: doc.u32_or("gpu.max_warps_per_sm", d.max_warps_per_sm),
        max_blocks_per_sm: doc.u32_or("gpu.max_blocks_per_sm", d.max_blocks_per_sm),
        smem_per_sm: doc.u32_or("gpu.smem_per_sm", d.smem_per_sm),
        regs_per_sm: doc.u32_or("gpu.regs_per_sm", d.regs_per_sm),
        l2_bytes: doc.u64_or("gpu.l2_bytes", d.l2_bytes),
        l2_ways: doc.u32_or("gpu.l2_ways", d.l2_ways),
        line_bytes: doc.u32_or("gpu.line_bytes", d.line_bytes),
        l2_hit_core_cycles: doc.f64_or("gpu.l2_hit_core_cycles", d.l2_hit_core_cycles),
        l2_ii_core_cycles: doc.f64_or("gpu.l2_ii_core_cycles", d.l2_ii_core_cycles),
        dm_path_core_cycles: doc.f64_or("gpu.dm_path_core_cycles", d.dm_path_core_cycles),
        dm_access_mem_cycles: doc.f64_or("gpu.dm_access_mem_cycles", d.dm_access_mem_cycles),
        dm_burst_mem_cycles: doc.f64_or("gpu.dm_burst_mem_cycles", d.dm_burst_mem_cycles),
        mc_overhead_mem_cycles: doc
            .f64_or("gpu.mc_overhead_mem_cycles", d.mc_overhead_mem_cycles),
        dram_banks: doc.u32_or("gpu.dram_banks", d.dram_banks),
        dram_row_lines: doc.u32_or("gpu.dram_row_lines", d.dram_row_lines),
        dram_row_miss_lat_mem_cycles: doc
            .f64_or("gpu.dram_row_miss_lat_mem_cycles", d.dram_row_miss_lat_mem_cycles),
        dram_row_miss_occ_mem_cycles: doc
            .f64_or("gpu.dram_row_miss_occ_mem_cycles", d.dram_row_miss_occ_mem_cycles),
        l1_bytes: doc.u64_or("gpu.l1_bytes", d.l1_bytes),
        l1_ways: doc.u32_or("gpu.l1_ways", d.l1_ways),
        l1_hit_core_cycles: doc.f64_or("gpu.l1_hit_core_cycles", d.l1_hit_core_cycles),
        smem_core_cycles: doc.f64_or("gpu.smem_core_cycles", d.smem_core_cycles),
        inst_core_cycles: doc.f64_or("gpu.inst_core_cycles", d.inst_core_cycles),
        block_launch_core_cycles: doc
            .f64_or("gpu.block_launch_core_cycles", d.block_launch_core_cycles),
    }
}

/// Build a `SweepConfig` from a document's `[sweep]` section.
pub fn sweep_from_doc(doc: &Document) -> SweepConfig {
    let d = SweepConfig::default();
    SweepConfig {
        core_min_mhz: doc.f64_or("sweep.core_min_mhz", d.core_min_mhz),
        core_max_mhz: doc.f64_or("sweep.core_max_mhz", d.core_max_mhz),
        mem_min_mhz: doc.f64_or("sweep.mem_min_mhz", d.mem_min_mhz),
        mem_max_mhz: doc.f64_or("sweep.mem_max_mhz", d.mem_max_mhz),
        stride_mhz: doc.f64_or("sweep.stride_mhz", d.stride_mhz),
        baseline_core_mhz: doc.f64_or("sweep.baseline_core_mhz", d.baseline_core_mhz),
        baseline_mem_mhz: doc.f64_or("sweep.baseline_mem_mhz", d.baseline_mem_mhz),
    }
}

/// Parse a configuration from TOML text.
pub fn from_text(text: &str) -> Result<Config, toml::ParseError> {
    let doc = toml::parse(text)?;
    let kernels = doc
        .get("kernels.names")
        .and_then(|v| v.as_str().map(|s| s.to_string()))
        .map(|s| s.split(',').map(|k| k.trim().to_string()).filter(|k| !k.is_empty()).collect())
        .unwrap_or_default();
    Ok(Config { gpu: gpu_from_doc(&doc), sweep: sweep_from_doc(&doc), kernels })
}

/// Load a configuration file.
pub fn load(path: &Path) -> anyhow::Result<Config> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    from_text(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_sweep_is_49_pairs_with_paper_baseline() {
        let s = SweepConfig::default();
        let pairs = s.pairs();
        assert_eq!(pairs.len(), 49);
        assert_eq!(pairs[0], (400.0, 400.0));
        assert_eq!(pairs[48], (1000.0, 1000.0));
        assert_eq!(s.baseline().core_mhz, 700.0);
    }

    #[test]
    fn empty_text_gives_defaults() {
        let c = from_text("").unwrap();
        assert_eq!(c.gpu.n_sm, 16);
        assert_eq!(c.sweep, SweepConfig::default());
        assert!(c.kernels.is_empty());
    }

    #[test]
    fn overrides_apply() {
        let c = from_text(
            r#"
[gpu]
n_sm = 8
l2_bytes = 1048576
inst_core_cycles = 4.0
[sweep]
stride_mhz = 300.0
core_max_mhz = 700.0
[kernels]
names = "VA, MMS"
"#,
        )
        .unwrap();
        assert_eq!(c.gpu.n_sm, 8);
        assert_eq!(c.gpu.l2_bytes, 1048576);
        assert_eq!(c.gpu.inst_core_cycles, 4.0);
        assert_eq!(c.sweep.pairs().len(), 2 * 3); // cores {400,700}, mems {400,700,1000}
        assert_eq!(c.kernels, vec!["VA".to_string(), "MMS".to_string()]);
    }

    #[test]
    fn bad_config_is_an_error() {
        assert!(from_text("gpu = [broken").is_err());
    }

    #[test]
    fn gtx980_config_file_loads() {
        // The checked-in Table V config must parse and match defaults.
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/gtx980.toml");
        let c = load(&path).unwrap();
        assert_eq!(c.gpu.n_sm, 16);
        assert_eq!(c.gpu.l2_bytes, 2 * 1024 * 1024);
        assert_eq!(c.sweep.pairs().len(), 49);
    }
}
