//! Minimal TOML-subset parser (offline substitution for the `toml`
//! crate, which is not in the vendored set — DESIGN.md "Offline
//! substitutions").
//!
//! Supported: `[section]` / `[a.b]` headers, `key = value` with string,
//! integer, float and boolean values, `#` comments, blank lines.
//! Unsupported (rejected with an error): arrays, inline tables,
//! multi-line strings, datetimes.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_u32(&self) -> Option<u32> {
        match self {
            Value::Int(i) if *i >= 0 && *i <= u32::MAX as i64 => Some(*i as u32),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse error with line context.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Flat document: keys are `section.key` (dotted).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Document {
    pub entries: BTreeMap<String, Value>,
}

impl Document {
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn u32_or(&self, key: &str, default: u32) -> u32 {
        self.get(key).and_then(Value::as_u32).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(Value::as_u64).unwrap_or(default)
    }

    /// Keys under a section prefix.
    pub fn section_keys(&self, section: &str) -> Vec<&str> {
        let prefix = format!("{section}.");
        self.entries
            .keys()
            .filter(|k| k.starts_with(&prefix))
            .map(|k| k.as_str())
            .collect()
    }
}

fn parse_value(raw: &str, line: usize) -> Result<Value, ParseError> {
    let raw = raw.trim();
    let err = |m: &str| ParseError { line, message: m.to_string() };
    if raw.is_empty() {
        return Err(err("empty value"));
    }
    if let Some(stripped) = raw.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .ok_or_else(|| err("unterminated string"))?;
        if inner.contains('"') {
            return Err(err("embedded quotes not supported"));
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if raw.starts_with('[') || raw.starts_with('{') {
        return Err(err("arrays/inline tables not supported"));
    }
    match raw {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    let clean = raw.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(&format!("cannot parse value `{raw}`")))
}

/// Parse a document.
pub fn parse(text: &str) -> Result<Document, ParseError> {
    let mut doc = Document::default();
    let mut section = String::new();
    for (i, raw_line) in text.lines().enumerate() {
        let line_no = i + 1;
        // Strip comments outside strings (values with '#' must be quoted;
        // our subset strings never contain '#' + quote combos).
        let line = match raw_line.find('#') {
            Some(pos) if !raw_line[..pos].contains('"') => &raw_line[..pos],
            _ => raw_line,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| ParseError { line: line_no, message: "unterminated section".into() })?
                .trim();
            if name.is_empty() {
                return Err(ParseError { line: line_no, message: "empty section name".into() });
            }
            section = name.to_string();
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            return Err(ParseError { line: line_no, message: format!("expected key = value, got `{line}`") });
        };
        let key = key.trim();
        if key.is_empty() {
            return Err(ParseError { line: line_no, message: "empty key".into() });
        }
        let full_key = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        let v = parse_value(value, line_no)?;
        if doc.entries.insert(full_key.clone(), v).is_some() {
            return Err(ParseError { line: line_no, message: format!("duplicate key `{full_key}`") });
        }
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = parse(
            r#"
# GPU spec
name = "gtx980"
[gpu]
n_sm = 16
l2_bytes = 2_097_152
inst_cycle = 2.0
banks_enabled = true
[sweep.range]
lo = 400
"#,
        )
        .unwrap();
        assert_eq!(doc.get("name"), Some(&Value::Str("gtx980".into())));
        assert_eq!(doc.u32_or("gpu.n_sm", 0), 16);
        assert_eq!(doc.u64_or("gpu.l2_bytes", 0), 2_097_152);
        assert_eq!(doc.f64_or("gpu.inst_cycle", 0.0), 2.0);
        assert_eq!(doc.get("gpu.banks_enabled").unwrap().as_bool(), Some(true));
        assert_eq!(doc.f64_or("sweep.range.lo", 0.0), 400.0);
    }

    #[test]
    fn defaults_apply() {
        let doc = parse("").unwrap();
        assert_eq!(doc.f64_or("missing", 7.5), 7.5);
    }

    #[test]
    fn int_doubles_as_float() {
        let doc = parse("x = 3").unwrap();
        assert_eq!(doc.f64_or("x", 0.0), 3.0);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse("not a kv").is_err());
        assert!(parse("[unterminated").is_err());
        assert!(parse("x = ").is_err());
        assert!(parse("x = [1, 2]").is_err());
        assert!(parse("x = \"open").is_err());
    }

    #[test]
    fn rejects_duplicates() {
        let e = parse("x = 1\nx = 2").unwrap_err();
        assert!(e.message.contains("duplicate"));
        assert_eq!(e.line, 2);
    }

    #[test]
    fn comments_stripped() {
        let doc = parse("x = 5 # five\n# whole line\ny = \"a#b\"").unwrap();
        assert_eq!(doc.u32_or("x", 0), 5);
        assert_eq!(doc.get("y").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn section_keys_listed() {
        let doc = parse("[a]\nx = 1\ny = 2\n[b]\nz = 3").unwrap();
        let keys = doc.section_keys("a");
        assert_eq!(keys, vec!["a.x", "a.y"]);
    }

    #[test]
    fn negative_and_float_values() {
        let doc = parse("a = -4\nb = 277.32").unwrap();
        assert_eq!(doc.get("a"), Some(&Value::Int(-4)));
        assert_eq!(doc.f64_or("b", 0.0), 277.32);
        assert_eq!(doc.get("a").unwrap().as_u32(), None);
    }
}
