//! Fleet-scale DVFS planning (DESIGN.md §11): from a per-kernel
//! frequency advisor to a scheduler-facing subsystem.
//!
//! The paper's model exists to answer one question cheaply — which
//! (core, mem) frequency pair should a kernel run at to save energy
//! without blowing its latency budget. [`dvfs::advise_with_handles`]
//! answers it for a *single* kernel on a *single* device. The related
//! scheduling literature (Ilager et al.'s deadline-aware frequency
//! scaling, Wang et al.'s DSO optimizer — see PAPERS.md) shows the real
//! payoff is fleet-level: many jobs, many GPUs, one energy bill. This
//! module is that layer:
//!
//! ```text
//!   jobs:    [(kernel, workload scale, deadline?), …]
//!   devices: every DeviceRecord in the engine's registry
//!                         │
//!                  planner::plan
//!     exhaustive per-job argmin over each device's V/f grid
//!        → greedy placement under per-device concurrency caps
//!        → local search: relocations + pairwise swaps (solver.rs)
//!                         │
//!   Plan: per-job (device, core MHz, mem MHz) + fleet totals
//! ```
//!
//! Latency comes from [`engine::Engine::predict_tuples`] (one batched
//! call for the whole candidate table, cache-served on repeats); power
//! comes from each device's registered [`dvfs::PowerModel`]; energy is
//! the paper's Eq. (1) bookkeeping, `E = P(cf, mf) × T(cf, mf)`, per
//! job. A [`Plan`] either meets **every** deadline or is not emitted at
//! all — infeasibility is a structured [`PlanError::Infeasible`], never
//! a silently-late assignment.
//!
//! # Example
//!
//! ```
//! use std::sync::Arc;
//! use gpufreq::dvfs::PowerModel;
//! use gpufreq::engine::Engine;
//! use gpufreq::model::{HwParams, KernelCounters};
//! use gpufreq::planner::{plan, Job, PlannerConfig};
//! use gpufreq::registry::{DeviceRegistry, KernelCatalog};
//!
//! let hw = HwParams::paper_defaults();
//! let registry = Arc::new(DeviceRegistry::new());
//! let gpu = registry.register("gtx980", hw, PowerModel::gtx980());
//! let catalog = Arc::new(KernelCatalog::new());
//! # let counters = KernelCounters {
//! #     l2_hr: 0.1, gld_trans: 6.0, avr_inst: 1.5, n_blocks: 128.0,
//! #     wpb: 8.0, aw: 64.0, n_sm: 16.0, o_itrs: 8.0, i_itrs: 0.0,
//! #     uses_smem: false, smem_conflict: 1.0, gld_body: 6.0,
//! #     gld_edge: 0.0, mem_ops: 2.0, l1_hr: 0.0,
//! # };
//! let kernel = catalog.register("VA", counters);
//! let engine = Engine::native(hw).with_handles(registry, catalog, gpu).unwrap();
//!
//! let jobs = vec![Job::new("nightly-sweep", kernel, 4.0).with_deadline(1e9)];
//! let p = plan(&engine, &jobs, &PlannerConfig::default()).unwrap();
//! assert_eq!(p.assignments.len(), 1);
//! assert!(p.assignments[0].time_us <= 1e9);
//! ```
//!
//! [`dvfs::advise_with_handles`]: crate::dvfs::advise_with_handles
//! [`dvfs::PowerModel`]: crate::dvfs::PowerModel
//! [`engine::Engine::predict_tuples`]: crate::engine::Engine::predict_tuples

pub mod solver;

pub use solver::{
    device_grid, max_frequency_baseline, plan, plan_with_baseline, Placement, PlannerConfig,
    RepairOutcome, ScheduleTable, MAX_JOBS,
};

use std::fmt;

use crate::registry::{DeviceId, FreqPoint, KernelId};

/// Why the runner-up operating point lost to the chosen one.
pub mod rejected_by {
    /// The alternative scored better on the objective but misses the
    /// job's deadline — the constraint, not the objective, decided.
    pub const DEADLINE: &str = "deadline";
    /// The alternative is feasible but scores worse on the objective.
    pub const OBJECTIVE: &str = "objective";
}

/// The best losing operating point on the chosen device — the
/// provenance record's "what would it have taken" half.
#[derive(Debug, Clone, Copy)]
pub struct RunnerUp {
    /// The losing (core, mem) point.
    pub point: FreqPoint,
    /// Scaled job runtime at that point, µs.
    pub time_us: f64,
    /// Energy at that point, mJ.
    pub energy_mj: f64,
    /// Which constraint rejected it: [`rejected_by::DEADLINE`] when it
    /// beat the chosen point on the objective but missed the job's
    /// deadline, [`rejected_by::OBJECTIVE`] when it simply scored
    /// worse.
    pub rejected_by: &'static str,
}

/// Per-assignment explanation: why this job landed where it did.
#[derive(Debug, Clone)]
pub struct Explain {
    /// Index into the job slice (matches `Assignment::job`).
    pub job: usize,
    /// `deadline − time_us` at the chosen point, µs; `None` for jobs
    /// without a deadline.
    pub deadline_slack_us: Option<f64>,
    /// Energy at the chosen point minus energy at the same device's
    /// max-frequency point, mJ (negative = the plan saves energy on
    /// this job relative to running it flat-out where it is).
    pub energy_delta_vs_max_mj: f64,
    /// The best losing point on the chosen device, when the grid
    /// offers more than one point.
    pub runner_up: Option<RunnerUp>,
}

/// Solver telemetry for one solve: per-phase spans, work counters and
/// (when [`PlannerConfig::telemetry`] is on) per-assignment
/// provenance. Carried by every [`Plan`]; the `/v2/plan` route returns
/// it under `"telemetry"`, `gpufreq plan --explain` prints it, and
/// `/metrics` exports the phases as `planner_phase_us` histograms.
#[derive(Debug, Clone, Default)]
pub struct SolveReport {
    /// Monotonic per-process solve id (`plan-<n>` on the wire) — the
    /// correlation key shared by `/debug/plans` and the event log.
    pub plan_id: u64,
    /// Candidate-table build (slab predictions + argmin scans), µs.
    pub build_us: f64,
    /// Greedy placement (excluding repair scans), µs.
    pub greedy_us: f64,
    /// One-level relocation repair inside greedy, µs.
    pub repair_us: f64,
    /// Local search (relocation + swap passes), µs.
    pub swap_us: f64,
    /// Whole solve, entry to assembled plan, µs. Phase durations sum
    /// to ≤ this (glue and provenance are unattributed).
    pub total_us: f64,
    /// Candidate-table entries evaluated: K distinct kernels × the
    /// summed per-device grid sizes (D×P).
    pub candidates_evaluated: u64,
    /// SoA slab calls the engine issued for this solve (cache-served
    /// repeats do not count — see `engine::ComputeCounters`).
    pub slab_calls: u64,
    /// Candidate relocations priced (repair scan + local search).
    pub relocations_tried: u64,
    /// Relocations actually applied.
    pub relocations_accepted: u64,
    /// Pairwise swaps priced in local search.
    pub swaps_tried: u64,
    /// Swaps actually applied.
    pub swaps_accepted: u64,
    /// Per-assignment provenance, in job order; empty when
    /// [`PlannerConfig::telemetry`] is off.
    pub explains: Vec<Explain>,
}

impl SolveReport {
    /// Sum of the attributed phase durations, µs.
    pub fn phases_us(&self) -> f64 {
        self.build_us + self.greedy_us + self.repair_us + self.swap_us
    }

    /// The wire form of [`plan_id`](SolveReport::plan_id).
    pub fn plan_id_str(&self) -> String {
        format!("plan-{}", self.plan_id)
    }
}

/// One schedulable unit of fleet work: a catalogued kernel executed
/// `scale` times back-to-back, optionally under a latency budget.
#[derive(Debug, Clone)]
pub struct Job {
    /// Operator-facing label, echoed in plans and errors.
    pub name: String,
    /// The catalogued kernel the job runs.
    pub kernel: KernelId,
    /// Workload scale: the job's runtime is `scale ×` the kernel's
    /// single-invocation prediction. Must be positive and finite.
    pub scale: f64,
    /// Absolute budget on the *scaled* runtime, µs. `None` means the
    /// job only participates in the energy objective.
    pub deadline_us: Option<f64>,
}

impl Job {
    /// A job with no deadline (pure energy minimization).
    pub fn new(name: impl Into<String>, kernel: KernelId, scale: f64) -> Job {
        Job { name: name.into(), kernel, scale, deadline_us: None }
    }

    /// Attach an absolute deadline (µs, on the scaled runtime).
    pub fn with_deadline(mut self, deadline_us: f64) -> Job {
        self.deadline_us = Some(deadline_us);
        self
    }
}

/// What the planner minimizes, summed over all jobs. Deadline
/// feasibility is a hard constraint under either objective, not a
/// third objective — a plan that misses a deadline is not a worse
/// plan, it is not a plan (see [`PlanError::Infeasible`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanObjective {
    /// Total fleet energy, mJ.
    Energy,
    /// Total energy-delay product, mJ·µs (per job, then summed) —
    /// biases each job toward faster points than pure energy would.
    Edp,
}

impl PlanObjective {
    /// Stable wire name (`/v2/plan`'s `objective` field).
    pub fn name(self) -> &'static str {
        match self {
            PlanObjective::Energy => "energy",
            PlanObjective::Edp => "edp",
        }
    }
}

/// One job's placement in an emitted [`Plan`].
#[derive(Debug, Clone, Copy)]
pub struct Assignment {
    /// Index into the job slice the plan was built from.
    pub job: usize,
    pub device: DeviceId,
    /// The chosen (core, mem) operating point.
    pub point: FreqPoint,
    /// Scaled job runtime at `point`, µs.
    pub time_us: f64,
    /// Board power at `point` (the device's own Eq. (1) model), W.
    pub power_w: f64,
    /// Dynamic share of `power_w` (both domains' a·C·V²·f), W.
    pub power_dynamic_w: f64,
    /// Leakage share of `power_w` (static floor + V-dependent excess), W.
    pub power_leakage_w: f64,
    /// `power_w × time_us`, in mJ.
    pub energy_mj: f64,
    /// `energy_mj × time_us`.
    pub edp: f64,
}

/// An assignment of every job to a device and operating point. Plans
/// emitted by [`plan`] meet all deadlines by construction; plans from
/// [`max_frequency_baseline`] may not (count the misses with
/// [`Plan::deadline_violations`]).
#[derive(Debug, Clone)]
pub struct Plan {
    pub objective: PlanObjective,
    /// One entry per input job, in input order.
    pub assignments: Vec<Assignment>,
    /// Fleet energy, mJ (sum over assignments).
    pub total_energy_mj: f64,
    /// Fleet EDP, mJ·µs (sum over assignments).
    pub total_edp: f64,
    /// Longest single job runtime in the plan, µs.
    pub max_time_us: f64,
    /// Improvement steps the local-search phase applied (single-job
    /// relocations + pairwise device swaps).
    pub swaps_applied: usize,
    /// Solver telemetry: phase spans, work counters, provenance.
    pub report: SolveReport,
}

impl Plan {
    /// How many jobs the plan placed on `device`.
    pub fn load_of(&self, device: DeviceId) -> usize {
        self.assignments.iter().filter(|a| a.device == device).count()
    }

    /// Energy saved relative to `baseline`, in percent (0 when the
    /// baseline's total is not positive). The one formula the bench,
    /// the `/v2/plan` route and the CLI all report.
    pub fn energy_savings_pct_vs(&self, baseline: &Plan) -> f64 {
        if baseline.total_energy_mj > 0.0 {
            (1.0 - self.total_energy_mj / baseline.total_energy_mj) * 100.0
        } else {
            0.0
        }
    }

    /// Assignments whose runtime exceeds their job's deadline. Zero for
    /// every plan [`plan`] emits; possibly non-zero for the
    /// max-frequency baseline.
    pub fn deadline_violations(&self, jobs: &[Job]) -> usize {
        self.assignments
            .iter()
            .filter(|a| match jobs[a.job].deadline_us {
                Some(d) => a.time_us > d,
                None => false,
            })
            .count()
    }
}

/// Why no plan was produced.
#[derive(Debug)]
pub enum PlanError {
    /// Malformed input: empty job list, non-positive scale or deadline,
    /// an invalid candidate grid, or an engine without handles.
    Invalid(String),
    /// A job's kernel handle does not resolve in the engine's catalog.
    UnknownKernel { job: usize, name: String, kernel: KernelId },
    /// A requested device handle is not in the engine's registry.
    UnknownDevice { device: DeviceId },
    /// The solver could not satisfy this job under the deadlines and
    /// per-device concurrency caps. `detail` says which constraint
    /// binds. An unreachable deadline is a *proof* of infeasibility
    /// (every device × point was priced); the exhausted-capacity case
    /// is decided by a one-level relocation repair, so a rare,
    /// tightly-entangled instance can be refused even though some
    /// exotic assignment exists — the remedy either way is raising the
    /// cap or relaxing a deadline.
    Infeasible { job: usize, name: String, detail: String },
    /// The prediction engine itself failed.
    Engine(String),
}

impl fmt::Display for PlanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlanError::Invalid(m) => write!(f, "invalid plan request: {m}"),
            PlanError::UnknownKernel { job, name, kernel } => {
                write!(f, "job {job} (`{name}`): unknown kernel {kernel}")
            }
            PlanError::UnknownDevice { device } => write!(f, "unknown device {device}"),
            PlanError::Infeasible { job, name, detail } => {
                write!(f, "infeasible: job {job} (`{name}`): {detail}")
            }
            PlanError::Engine(m) => write!(f, "prediction engine failed: {m}"),
        }
    }
}

impl std::error::Error for PlanError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_builder_sets_fields() {
        let j = Job::new("batch", KernelId(3), 2.5);
        assert_eq!(j.name, "batch");
        assert_eq!(j.kernel, KernelId(3));
        assert_eq!(j.scale, 2.5);
        assert_eq!(j.deadline_us, None);
        let j = j.with_deadline(1500.0);
        assert_eq!(j.deadline_us, Some(1500.0));
    }

    #[test]
    fn objective_wire_names_are_stable() {
        assert_eq!(PlanObjective::Energy.name(), "energy");
        assert_eq!(PlanObjective::Edp.name(), "edp");
    }

    #[test]
    fn plan_error_displays_are_attributable() {
        let e = PlanError::Infeasible {
            job: 3,
            name: "night-batch".into(),
            detail: "deadline 10 µs is unreachable".into(),
        };
        let msg = e.to_string();
        assert!(msg.contains("job 3"), "{msg}");
        assert!(msg.contains("night-batch"), "{msg}");
        assert!(msg.contains("infeasible"), "{msg}");
        let e = PlanError::UnknownKernel { job: 0, name: "j".into(), kernel: KernelId(9) };
        assert!(e.to_string().contains("krn-9"), "{e}");
        let e = PlanError::UnknownDevice { device: DeviceId(4) };
        assert!(e.to_string().contains("dev-4"), "{e}");
    }
}
