//! The planning solver (DESIGN.md §11): exhaustive per-job argmin over
//! each device's V/f grid, greedy placement under per-device
//! concurrency caps, then pairwise-swap local search.
//!
//! Phases, for `J` jobs, `D` devices, `K` distinct kernels and `P`
//! candidate points per device:
//!
//! 1. **Evaluate** — `K × D` slab calls ([`Engine::predict_points`],
//!    one SoA-evaluated slab per (device, kernel)) covering the
//!    `K × D × P` table (jobs sharing a kernel share predictions),
//!    then an `O(J·D·P)` scan producing `best[j][d]`: the
//!    deadline-feasible objective argmin for job `j` on device `d`.
//! 2. **Greedy** — jobs in tightest-deadline-first order each take the
//!    globally cheapest `best[j][d]` among devices with spare capacity
//!    (`O(J·D)`); a one-level relocation repair handles the case where
//!    every deadline-feasible device is at its cap.
//! 3. **Local search** — interleaved single-job relocations (to any
//!    device with spare capacity — these can change the load vector
//!    greedy settled on) and pairwise device swaps (each side
//!    re-argmins its point via the precomputed table; loads are
//!    preserved), applying only strict improvements. `O(J·D + J²)`
//!    per round, bounded rounds.
//!
//! Greedy + swap is deliberate: at current grid sizes (`P ≤ 49`,
//! `D ≤ 1024`) the evaluation table dominates the cost, the greedy
//! choice is already the unconstrained optimum whenever caps don't
//! bind, and pairwise swaps remove the order-dependence caps introduce.
//! See DESIGN.md §11 for why heavier machinery (MILP, simulated
//! annealing) buys nothing measurable here.
//!
//! [`Engine::predict_points`]: crate::engine::Engine::predict_points

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::time::Instant;

use crate::dvfs::PowerModel;
use crate::engine::Engine;
use crate::registry::{DeviceId, DeviceRecord, FreqPoint, KernelId};
use crate::util::fxhash::FxHashMap;

use super::{rejected_by, Assignment, Explain, Job, Plan, PlanError, PlanObjective, RunnerUp, SolveReport};

/// Source for process-wide monotonic plan ids (`plan-<n>`), minted
/// once per solve regardless of the telemetry setting so provenance
/// rings and event logs can always correlate.
static NEXT_PLAN_ID: AtomicU64 = AtomicU64::new(1);

fn next_plan_id() -> u64 {
    NEXT_PLAN_ID.fetch_add(1, Relaxed)
}

/// Elapsed microseconds since `t`.
fn us_since(t: Instant) -> f64 {
    t.elapsed().as_secs_f64() * 1e6
}

/// Cost ceilings guarding the solve (checked arithmetically **before**
/// any table is allocated — the `/v2/plan` route is an unauthenticated
/// surface, so every dimension a caller controls must be bounded, and
/// the greedy repair phase gets an explicit work budget too):
///
/// * `MAX_JOBS` (this constant) bounds the `O(J²)`-per-round swap
///   phase; it is public so the `/v2/plan` route can refuse oversized
///   requests before parsing every job — one source of truth for the
///   limit.
/// * `MAX_JOB_DEVICE_PAIRS` bounds the `best[j][d]` table and every
///   `O(J·D)` scan (greedy, repair victims).
/// * `MAX_EVALUATIONS` bounds `jobs × total candidate points` — the
///   prediction table and the per-job candidate scan. A plan over the
///   full 49-pair grid, 8 devices and 4096 jobs sits at ~1.6M.
///
/// Violations are refused as [`PlanError::Invalid`].
pub const MAX_JOBS: usize = 4096;
const MAX_JOB_DEVICE_PAIRS: usize = 1 << 17;
const MAX_EVALUATIONS: usize = 2_000_000;

/// Solver knobs. The default plans over every registered device with
/// unbounded per-device concurrency, deriving each device's candidate
/// grid from its own V/f curves.
#[derive(Debug, Clone)]
pub struct PlannerConfig {
    pub objective: PlanObjective,
    /// Restrict planning to these devices; `None` means every device
    /// in the engine's registry. Duplicates are ignored.
    pub devices: Option<Vec<DeviceId>>,
    /// Per-device concurrency cap: at most this many jobs per device.
    /// `usize::MAX` (the default) is unbounded.
    pub device_cap: usize,
    /// Explicit candidate (core, mem) MHz points shared by every
    /// device; `None` derives each device's grid from its registered
    /// V/f curves ([`device_grid`]).
    pub pairs: Option<Vec<(f64, f64)>>,
    /// Upper bound on swap-refinement passes. Each pass only applies
    /// strict improvements, so the loop usually converges earlier.
    pub max_swap_rounds: usize,
    /// Collect phase timings and per-assignment provenance into the
    /// plan's [`SolveReport`] (default on). Work *counters* are always
    /// collected — they are integer adds; this flag gates the clock
    /// reads and the provenance pass. Telemetry never perturbs the
    /// solve: on or off, assignments are bit-identical.
    pub telemetry: bool,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            objective: PlanObjective::Energy,
            devices: None,
            device_cap: usize::MAX,
            pairs: None,
            max_swap_rounds: 8,
            telemetry: true,
        }
    }
}

/// Candidate operating points for one device: the cross product of the
/// frequency breakpoints of its registered core and memory V/f curves.
/// Never empty (a [`crate::dvfs::VfCurve`] validates at least one
/// point).
pub fn device_grid(power: &PowerModel) -> Vec<FreqPoint> {
    let mut out =
        Vec::with_capacity(power.core_curve.points.len() * power.mem_curve.points.len());
    for &(cf, _) in &power.core_curve.points {
        for &(mf, _) in &power.mem_curve.points {
            out.push(FreqPoint::new(cf, mf));
        }
    }
    out
}

/// One evaluated (device, point) choice for one job.
#[derive(Debug, Clone, Copy)]
struct Candidate {
    point: FreqPoint,
    time_us: f64,
    power_w: f64,
    power_dynamic_w: f64,
    power_leakage_w: f64,
    energy_mj: f64,
    edp: f64,
}

impl Candidate {
    fn key(&self, objective: PlanObjective) -> f64 {
        match objective {
            PlanObjective::Energy => self.energy_mj,
            PlanObjective::Edp => self.edp,
        }
    }
}

/// The evaluated candidate table: everything needed to price one
/// (job, device, point) choice without another engine call.
struct EvalTable {
    /// Candidate points per device.
    grids: Vec<Vec<FreqPoint>>,
    /// `times[d][k][p]`: single-invocation µs (k indexes the distinct
    /// kernels; see `job_kernel`).
    times: Vec<Vec<Vec<f64>>>,
    /// `power[d][p]`: board watts at that device's point `p`, split
    /// into the v2 dynamic/leakage components (DESIGN.md §15).
    /// `total_w` is what every energy figure is priced from.
    power: Vec<Vec<crate::dvfs::PowerSplit>>,
    /// Distinct-kernel table index per job.
    job_kernel: Vec<usize>,
}

impl EvalTable {
    fn eval(&self, jobs: &[Job], j: usize, di: usize, pi: usize) -> Candidate {
        let time_us = jobs[j].scale * self.times[di][self.job_kernel[j]][pi];
        let split = self.power[di][pi];
        let energy_mj = split.total_w * time_us * 1e-3; // W·µs = µJ; /1e3 = mJ
        Candidate {
            point: self.grids[di][pi],
            time_us,
            power_w: split.total_w,
            power_dynamic_w: split.dynamic_w,
            power_leakage_w: split.leakage_w,
            energy_mj,
            edp: energy_mj * time_us,
        }
    }
}

/// Everything the placement phases read, evaluated up front.
struct Prepared {
    devices: Vec<DeviceRecord>,
    table: EvalTable,
    /// Max-frequency point index per device (the baseline's choice,
    /// priced on demand through `table` — a dense J×D table would be
    /// 1/D used).
    max_point_idx: Vec<usize>,
    /// `best[j][d]`: deadline-feasible objective argmin for job `j` on
    /// device `d`; `None` when no point on `d` meets the deadline.
    best: Vec<Vec<Option<Candidate>>>,
    /// Fastest achievable scaled runtime per job over every device and
    /// point (µs) — the infeasibility diagnostic.
    fastest_us: Vec<f64>,
}

impl Prepared {
    /// The max-frequency candidate for job `j` on device `d`.
    fn at_max(&self, jobs: &[Job], j: usize, d: usize) -> Candidate {
        self.table.eval(jobs, j, d, self.max_point_idx[d])
    }
}

fn prepare(
    engine: &Engine,
    jobs: &[Job],
    cfg: &PlannerConfig,
    report: &mut SolveReport,
) -> Result<Prepared, PlanError> {
    let build_t = cfg.telemetry.then(Instant::now);
    let Some(registry) = engine.registry() else {
        return Err(PlanError::Invalid(
            "engine has no registry attached (Engine::with_handles)".to_string(),
        ));
    };
    if jobs.is_empty() {
        return Err(PlanError::Invalid("job list is empty".to_string()));
    }
    if jobs.len() > MAX_JOBS {
        return Err(PlanError::Invalid(format!(
            "plan is too large: {} jobs (limit {MAX_JOBS} per solve)",
            jobs.len()
        )));
    }
    for (i, job) in jobs.iter().enumerate() {
        if !(job.scale.is_finite() && job.scale > 0.0) {
            return Err(PlanError::Invalid(format!(
                "job {i} (`{}`): scale must be positive and finite, got {}",
                job.name, job.scale
            )));
        }
        if let Some(d) = job.deadline_us {
            if !(d.is_finite() && d > 0.0) {
                return Err(PlanError::Invalid(format!(
                    "job {i} (`{}`): deadline_us must be positive and finite, got {d}",
                    job.name
                )));
            }
        }
        if engine.kernel_counters(job.kernel).is_err() {
            return Err(PlanError::UnknownKernel {
                job: i,
                name: job.name.clone(),
                kernel: job.kernel,
            });
        }
    }

    // Resolve the device set (deduplicated, order-preserving).
    let devices: Vec<DeviceRecord> = match &cfg.devices {
        None => registry.list(),
        Some(ids) => {
            let mut seen: HashSet<DeviceId> = HashSet::with_capacity(ids.len());
            let mut out = Vec::with_capacity(ids.len());
            for &id in ids {
                if !seen.insert(id) {
                    continue;
                }
                match registry.get(id) {
                    Some(r) => out.push(r),
                    None => return Err(PlanError::UnknownDevice { device: id }),
                }
            }
            out
        }
    };
    if devices.is_empty() {
        return Err(PlanError::Invalid("no devices to plan over".to_string()));
    }
    if jobs.len().saturating_mul(devices.len()) > MAX_JOB_DEVICE_PAIRS {
        return Err(PlanError::Invalid(format!(
            "plan is too large: {} jobs x {} devices = {} job-device pairs (limit {})",
            jobs.len(),
            devices.len(),
            jobs.len().saturating_mul(devices.len()),
            MAX_JOB_DEVICE_PAIRS
        )));
    }

    // Candidate grids, per device.
    if let Some(pairs) = &cfg.pairs {
        if pairs.is_empty() {
            return Err(PlanError::Invalid("candidate pairs list is empty".to_string()));
        }
        for &(cf, mf) in pairs {
            if !FreqPoint::new(cf, mf).is_valid() {
                return Err(PlanError::Invalid(format!(
                    "candidate pair ({cf}, {mf}) MHz: frequencies must be positive and finite"
                )));
            }
        }
    }
    // Refuse oversized solves BEFORE any table is materialized: the
    // device set, explicit `pairs` and the registered V/f curves are
    // all caller-controlled (curves can carry arbitrarily many
    // breakpoints), so the point counts are computed arithmetically
    // first and only then are the grids allocated.
    let points_per_device: Vec<usize> = devices
        .iter()
        .map(|r| match &cfg.pairs {
            Some(pairs) => pairs.len(),
            None => r
                .power
                .core_curve
                .points
                .len()
                .saturating_mul(r.power.mem_curve.points.len()),
        })
        .collect();
    let total_points = points_per_device.iter().fold(0usize, |a, &b| a.saturating_add(b));
    let evaluations = jobs.len().saturating_mul(total_points);
    if evaluations > MAX_EVALUATIONS {
        return Err(PlanError::Invalid(format!(
            "plan is too large: {} jobs x {} candidate points over {} devices = {} \
             evaluations (limit {})",
            jobs.len(),
            total_points,
            devices.len(),
            evaluations,
            MAX_EVALUATIONS
        )));
    }
    let grids: Vec<Vec<FreqPoint>> = devices
        .iter()
        .map(|r| match &cfg.pairs {
            Some(pairs) => pairs.iter().map(|&p| p.into()).collect(),
            None => device_grid(&r.power),
        })
        .collect();

    // Distinct kernels, in first-appearance order.
    let mut kernel_ids: Vec<KernelId> = Vec::new();
    let mut kernel_index: FxHashMap<u64, usize> = FxHashMap::default();
    for job in jobs {
        kernel_index.entry(job.kernel.0).or_insert_with(|| {
            kernel_ids.push(job.kernel);
            kernel_ids.len() - 1
        });
    }

    // The K × D × P candidate table as K × D slab calls: one
    // [`Engine::predict_points`] per (device, kernel) over that
    // device's grid. Each call evaluates its whole slab through
    // `model::soa` (per-kernel invariants hoisted once), so fleet size
    // never multiplies engine work and no per-tuple structs are built.
    //
    // times[d][k][p]: single-invocation µs. Power depends only on the
    // device and point: power[d][p].
    report.candidates_evaluated = (kernel_ids.len() as u64) * (total_points as u64);
    let compute_before = engine.compute_stats();
    let mut times: Vec<Vec<Vec<f64>>> = Vec::with_capacity(devices.len());
    for (di, rec) in devices.iter().enumerate() {
        let mut per_kernel = Vec::with_capacity(kernel_ids.len());
        for &kid in &kernel_ids {
            let estimates = engine
                .predict_points(rec.id, kid, &grids[di])
                .map_err(|e| PlanError::Engine(format!("{e:#}")))?;
            per_kernel.push(estimates.into_iter().map(|e| e.time_us).collect::<Vec<f64>>());
        }
        times.push(per_kernel);
    }
    report.slab_calls = engine.compute_stats().since(compute_before).slab_calls;
    let power: Vec<Vec<crate::dvfs::PowerSplit>> = devices
        .iter()
        .enumerate()
        .map(|(di, rec)| {
            grids[di].iter().map(|p| rec.power.split_w(p.core_mhz, p.mem_mhz)).collect()
        })
        .collect();

    // Max-frequency point per device: highest core, then highest mem.
    let max_point_idx: Vec<usize> = grids.iter().map(|g| max_point_of(g)).collect();

    let job_kernel: Vec<usize> = jobs.iter().map(|job| kernel_index[&job.kernel.0]).collect();
    let table = EvalTable { grids, times, power, job_kernel };

    let mut best: Vec<Vec<Option<Candidate>>> = Vec::with_capacity(jobs.len());
    let mut fastest_us: Vec<f64> = Vec::with_capacity(jobs.len());
    for (j, job) in jobs.iter().enumerate() {
        let mut per_device: Vec<Option<Candidate>> = Vec::with_capacity(devices.len());
        let mut fastest = f64::INFINITY;
        for di in 0..devices.len() {
            let mut chosen: Option<Candidate> = None;
            let mut chosen_key = f64::INFINITY;
            for pi in 0..table.grids[di].len() {
                let c = table.eval(jobs, j, di, pi);
                fastest = fastest.min(c.time_us);
                let feasible = match job.deadline_us {
                    Some(d) => c.time_us <= d,
                    None => true,
                };
                if feasible && c.key(cfg.objective) < chosen_key {
                    chosen_key = c.key(cfg.objective);
                    chosen = Some(c);
                }
            }
            per_device.push(chosen);
        }
        best.push(per_device);
        fastest_us.push(fastest);
    }

    if let Some(t) = build_t {
        report.build_us = us_since(t);
    }
    Ok(Prepared { devices, table, max_point_idx, best, fastest_us })
}

/// Assemble the output [`Plan`] from a placement.
fn assemble(
    prepared: &Prepared,
    choice: impl Fn(usize, usize) -> Candidate,
    dev_of: &[usize],
    objective: PlanObjective,
    swaps_applied: usize,
    report: SolveReport,
) -> Plan {
    let mut assignments = Vec::with_capacity(dev_of.len());
    let (mut energy, mut edp, mut max_t) = (0.0f64, 0.0f64, 0.0f64);
    for (j, &d) in dev_of.iter().enumerate() {
        let c = choice(j, d);
        energy += c.energy_mj;
        edp += c.edp;
        max_t = max_t.max(c.time_us);
        assignments.push(Assignment {
            job: j,
            device: prepared.devices[d].id,
            point: c.point,
            time_us: c.time_us,
            power_w: c.power_w,
            power_dynamic_w: c.power_dynamic_w,
            power_leakage_w: c.power_leakage_w,
            energy_mj: c.energy_mj,
            edp: c.edp,
        });
    }
    Plan {
        objective,
        assignments,
        total_energy_mj: energy,
        total_edp: edp,
        max_time_us: max_t,
        swaps_applied,
        report,
    }
}

/// Per-assignment provenance: deadline slack and energy delta at the
/// chosen point, plus the best losing point on the same device and
/// the constraint that rejected it. Strictly read-only over the
/// prepared table — provenance cannot perturb the solve.
fn explain(
    prepared: &Prepared,
    jobs: &[Job],
    cfg: &PlannerConfig,
    dev_of: &[usize],
) -> Vec<Explain> {
    let mut out = Vec::with_capacity(dev_of.len());
    for (j, &d) in dev_of.iter().enumerate() {
        let chosen = prepared.best[j][d].expect("placed jobs are feasible");
        let at_max = prepared.at_max(jobs, j, d);
        // Best alternative by objective over the same device's grid,
        // feasible or not — a winner-but-for-the-deadline surfaces as
        // `rejected_by: deadline`.
        let mut runner: Option<Candidate> = None;
        let mut runner_key = f64::INFINITY;
        for pi in 0..prepared.table.grids[d].len() {
            let c = prepared.table.eval(jobs, j, d, pi);
            if c.point == chosen.point {
                continue;
            }
            let key = c.key(cfg.objective);
            if key < runner_key {
                runner_key = key;
                runner = Some(c);
            }
        }
        let runner_up = runner.map(|c| RunnerUp {
            point: c.point,
            time_us: c.time_us,
            energy_mj: c.energy_mj,
            // `chosen` is the feasible argmin, so an alternative with
            // a strictly better key can only have lost to the
            // deadline; otherwise it lost on the objective.
            rejected_by: if c.key(cfg.objective) < chosen.key(cfg.objective) {
                rejected_by::DEADLINE
            } else {
                rejected_by::OBJECTIVE
            },
        });
        out.push(Explain {
            job: j,
            deadline_slack_us: jobs[j].deadline_us.map(|dl| dl - chosen.time_us),
            energy_delta_vs_max_mj: chosen.energy_mj - at_max.energy_mj,
            runner_up,
        });
    }
    out
}

/// Produce an energy-minimal (or EDP-minimal) assignment of `jobs` to
/// the registered devices and per-job (core, mem) operating points.
/// Every deadline in an emitted plan is met; when the search cannot
/// achieve that, the result is a structured [`PlanError::Infeasible`]
/// naming the first unplaceable job (see that variant's docs for the
/// exact strength of the claim in the capacity-bound case).
///
/// Deterministic: identical inputs produce identical plans (ties break
/// toward lower device index, then lower point index).
pub fn plan(engine: &Engine, jobs: &[Job], cfg: &PlannerConfig) -> Result<Plan, PlanError> {
    let (planned, _) = solve(engine, jobs, cfg, false)?;
    Ok(planned)
}

/// [`plan`] and [`max_frequency_baseline`] from **one** evaluation
/// pass: the K×D×P prediction table and candidate scans are the
/// dominant cost of a solve, and callers that report the baseline next
/// to the plan (the `/v2/plan` route, `gpufreq plan`) must not pay it
/// twice. The baseline is advisory: a corner case that makes only the
/// round-robin placement infeasible yields `None` rather than failing
/// a valid plan.
pub fn plan_with_baseline(
    engine: &Engine,
    jobs: &[Job],
    cfg: &PlannerConfig,
) -> Result<(Plan, Option<Plan>), PlanError> {
    solve(engine, jobs, cfg, true)
}

/// The one solve path behind [`plan`] and [`plan_with_baseline`]:
/// prepare → greedy+swap → provenance, with one [`SolveReport`]
/// threaded through the phases. Timers and the provenance pass are
/// gated on [`PlannerConfig::telemetry`]; counters are always live.
fn solve(
    engine: &Engine,
    jobs: &[Job],
    cfg: &PlannerConfig,
    with_baseline: bool,
) -> Result<(Plan, Option<Plan>), PlanError> {
    let total_t = cfg.telemetry.then(Instant::now);
    let mut report = SolveReport { plan_id: next_plan_id(), ..SolveReport::default() };
    let prepared = prepare(engine, jobs, cfg, &mut report)?;
    let (dev_of, swaps) = greedy_and_swap(&prepared, jobs, cfg, &mut report)?;
    if cfg.telemetry {
        report.explains = explain(&prepared, jobs, cfg, &dev_of);
    }
    if let Some(t) = total_t {
        report.total_us = us_since(t);
    }
    // The advisory baseline shares the solve's plan_id (it is the same
    // evaluation pass) but carries no phase attribution of its own.
    let baseline_report = SolveReport { plan_id: report.plan_id, ..SolveReport::default() };
    let planned = assemble(
        &prepared,
        |j, d| prepared.best[j][d].expect("placed jobs are feasible"),
        &dev_of,
        cfg.objective,
        swaps,
        report,
    );
    let baseline = if with_baseline {
        baseline_assign(&prepared, jobs, cfg).ok().map(|b| {
            assemble(
                &prepared,
                |j, d| prepared.at_max(jobs, j, d),
                &b,
                cfg.objective,
                0,
                baseline_report,
            )
        })
    } else {
        None
    };
    Ok((planned, baseline))
}

/// Greedy + swap placement over an evaluated table: returns the device
/// index per job (input order) and the number of swaps applied.
fn greedy_and_swap(
    prepared: &Prepared,
    jobs: &[Job],
    cfg: &PlannerConfig,
    report: &mut SolveReport,
) -> Result<(Vec<usize>, usize), PlanError> {
    let d_count = prepared.devices.len();
    let n = jobs.len();
    let greedy_t = cfg.telemetry.then(Instant::now);

    // Greedy phase: tightest deadlines place first, so loose jobs
    // cannot squat on the only device a tight job fits.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        let da = jobs[a].deadline_us.unwrap_or(f64::INFINITY);
        let db = jobs[b].deadline_us.unwrap_or(f64::INFINITY);
        da.total_cmp(&db).then(a.cmp(&b))
    });
    let mut load = vec![0usize; d_count];
    let mut dev_of: Vec<usize> = vec![usize::MAX; n];
    let mut placed: Vec<usize> = Vec::with_capacity(n);
    // The one-level repair scans placed × devices per stuck job; on an
    // adversarially entangled fleet that is O(J²·D²) total, so it gets
    // an explicit work budget. Exhausting it is reported as the
    // capacity infeasibility it effectively is.
    let mut repair_budget: usize = MAX_EVALUATIONS;
    for &j in &order {
        let mut pick: Option<usize> = None;
        let mut pick_key = f64::INFINITY;
        for d in 0..d_count {
            if load[d] >= cfg.device_cap {
                continue;
            }
            if let Some(c) = prepared.best[j][d] {
                let key = c.key(cfg.objective);
                if key < pick_key {
                    pick_key = key;
                    pick = Some(d);
                }
            }
        }
        if let Some(d) = pick {
            dev_of[j] = d;
            load[d] += 1;
            placed.push(j);
            continue;
        }
        // No feasible device with spare capacity. Distinguish an
        // unreachable deadline from exhausted capacity, and in the
        // latter case attempt a one-level repair: relocate one placed
        // job off a deadline-feasible device so `j` fits.
        let repair_t = cfg.telemetry.then(Instant::now);
        let feasible_devs: Vec<usize> =
            (0..d_count).filter(|&d| prepared.best[j][d].is_some()).collect();
        if feasible_devs.is_empty() {
            return Err(PlanError::Infeasible {
                job: j,
                name: jobs[j].name.clone(),
                detail: match jobs[j].deadline_us {
                    Some(dl) => format!(
                        "deadline {dl} µs is unreachable on every device: fastest \
                         achievable runtime is {:.3} µs",
                        prepared.fastest_us[j]
                    ),
                    None => "no device offers a valid operating point".to_string(),
                },
            });
        }
        // (victim, from-device, to-device), cheapest total objective.
        let mut repair: Option<(usize, usize, usize)> = None;
        let mut repair_delta = f64::INFINITY;
        'search: for &d in &feasible_devs {
            let cost_j = prepared.best[j][d].expect("feasible").key(cfg.objective);
            for &i in &placed {
                if dev_of[i] != d {
                    continue;
                }
                if repair_budget < d_count {
                    // Budget exhausted: stop with whatever repair the
                    // scan found so far (possibly none).
                    break 'search;
                }
                repair_budget -= d_count;
                let cur_i = prepared.best[i][d].expect("placed jobs are feasible");
                for d2 in 0..d_count {
                    if d2 == d || load[d2] >= cfg.device_cap {
                        continue;
                    }
                    let Some(alt_i) = prepared.best[i][d2] else { continue };
                    report.relocations_tried += 1;
                    let delta =
                        alt_i.key(cfg.objective) - cur_i.key(cfg.objective) + cost_j;
                    if delta < repair_delta {
                        repair_delta = delta;
                        repair = Some((i, d, d2));
                    }
                }
            }
        }
        if let Some(t) = repair_t {
            report.repair_us += us_since(t);
        }
        match repair {
            Some((i, d, d2)) => {
                report.relocations_accepted += 1;
                dev_of[i] = d2;
                load[d] -= 1;
                load[d2] += 1;
                dev_of[j] = d;
                load[d] += 1;
                placed.push(j);
            }
            None => {
                return Err(PlanError::Infeasible {
                    job: j,
                    name: jobs[j].name.clone(),
                    detail: format!(
                        "every device that can meet the job's constraints is at its \
                         concurrency cap ({} jobs/device over {} devices)",
                        cfg.device_cap, d_count
                    ),
                })
            }
        }
    }

    if let Some(t) = greedy_t {
        // The greedy span excludes the repair scans timed above.
        report.greedy_us = (us_since(t) - report.repair_us).max(0.0);
    }

    // Local search: single-job relocations (which can change the load
    // vector greedy settled on, as long as the target device has spare
    // capacity) interleaved with pairwise device swaps (which preserve
    // loads). Every applied step strictly improves the objective, so
    // the loop terminates; caps and feasibility are preserved by
    // construction (`best` is deadline-filtered, loads are rechecked
    // on moves and untouched by swaps).
    let swap_t = cfg.telemetry.then(Instant::now);
    let mut steps = 0usize;
    for _ in 0..cfg.max_swap_rounds {
        let mut improved = false;
        for a in 0..n {
            let da = dev_of[a];
            let cur = prepared.best[a][da].expect("placed").key(cfg.objective);
            let mut target: Option<usize> = None;
            let mut target_key = cur;
            for d in 0..d_count {
                if d == da || load[d] >= cfg.device_cap {
                    continue;
                }
                if let Some(c) = prepared.best[a][d] {
                    report.relocations_tried += 1;
                    let key = c.key(cfg.objective);
                    if target_key - key > 1e-9 * cur.abs().max(1e-12) {
                        target_key = key;
                        target = Some(d);
                    }
                }
            }
            if let Some(d) = target {
                report.relocations_accepted += 1;
                load[da] -= 1;
                load[d] += 1;
                dev_of[a] = d;
                steps += 1;
                improved = true;
            }
        }
        for a in 0..n {
            for b in (a + 1)..n {
                let (da, db) = (dev_of[a], dev_of[b]);
                if da == db {
                    continue;
                }
                let (Some(a_on_db), Some(b_on_da)) =
                    (prepared.best[a][db], prepared.best[b][da])
                else {
                    continue;
                };
                report.swaps_tried += 1;
                let cur = prepared.best[a][da].expect("placed").key(cfg.objective)
                    + prepared.best[b][db].expect("placed").key(cfg.objective);
                let alt = a_on_db.key(cfg.objective) + b_on_da.key(cfg.objective);
                if cur - alt > 1e-9 * cur.abs().max(1e-12) {
                    report.swaps_accepted += 1;
                    dev_of[a] = db;
                    dev_of[b] = da;
                    steps += 1;
                    improved = true;
                }
            }
        }
        if !improved {
            break;
        }
    }
    if let Some(t) = swap_t {
        report.swap_us = us_since(t);
    }

    Ok((dev_of, steps))
}

/// The naive fleet: round-robin jobs over the devices (respecting the
/// same concurrency cap) and run everything at each device's maximum
/// frequency point. This is what a scheduler without the model does —
/// the reference [`plan`] must beat on total energy. Deadlines are
/// *not* enforced (audit the result with
/// [`Plan::deadline_violations`]).
pub fn max_frequency_baseline(
    engine: &Engine,
    jobs: &[Job],
    cfg: &PlannerConfig,
) -> Result<Plan, PlanError> {
    let mut report = SolveReport { plan_id: next_plan_id(), ..SolveReport::default() };
    let prepared = prepare(engine, jobs, cfg, &mut report)?;
    let dev_of = baseline_assign(&prepared, jobs, cfg)?;
    Ok(assemble(&prepared, |j, d| prepared.at_max(jobs, j, d), &dev_of, cfg.objective, 0, report))
}

/// Round-robin placement under the cap (the baseline's device choice).
fn baseline_assign(
    prepared: &Prepared,
    jobs: &[Job],
    cfg: &PlannerConfig,
) -> Result<Vec<usize>, PlanError> {
    let d_count = prepared.devices.len();
    let mut load = vec![0usize; d_count];
    let mut dev_of: Vec<usize> = Vec::with_capacity(jobs.len());
    let mut cursor = 0usize;
    for j in 0..jobs.len() {
        let mut placed = None;
        for step in 0..d_count {
            let d = (cursor + step) % d_count;
            if load[d] < cfg.device_cap {
                placed = Some(d);
                cursor = (d + 1) % d_count;
                break;
            }
        }
        let Some(d) = placed else {
            return Err(PlanError::Infeasible {
                job: j,
                name: jobs[j].name.clone(),
                detail: format!(
                    "every device is at its concurrency cap ({} jobs/device over {} \
                     devices)",
                    cfg.device_cap, d_count
                ),
            });
        };
        load[d] += 1;
        dev_of.push(d);
    }
    Ok(dev_of)
}

/// Max-frequency index of one grid: highest core, then highest mem —
/// the baseline's per-device point and the admission bound's anchor.
fn max_point_of(grid: &[FreqPoint]) -> usize {
    let mut best = 0usize;
    for (i, p) in grid.iter().enumerate() {
        let b = grid[best];
        if p.core_mhz > b.core_mhz || (p.core_mhz == b.core_mhz && p.mem_mhz > b.mem_mhz) {
            best = i;
        }
    }
    best
}

/// One priced choice for a single job on a single device — the
/// incremental counterpart of an [`Assignment`] (no job index: the
/// caller knows which job it priced).
#[derive(Debug, Clone, Copy)]
pub struct Placement {
    pub device: DeviceId,
    /// The chosen (core, mem) operating point.
    pub point: FreqPoint,
    /// Scaled job runtime at `point`, µs.
    pub time_us: f64,
    /// Board power at `point`, W.
    pub power_w: f64,
    /// Dynamic share of `power_w` (both domains' a·C·V²·f), W.
    pub power_dynamic_w: f64,
    /// Leakage share of `power_w` (static floor + V-dependent excess), W.
    pub power_leakage_w: f64,
    /// `power_w × time_us`, in mJ.
    pub energy_mj: f64,
    /// `energy_mj × time_us`.
    pub edp: f64,
}

impl Placement {
    /// The objective value placements are compared by.
    pub fn key(&self, objective: PlanObjective) -> f64 {
        match objective {
            PlanObjective::Energy => self.energy_mj,
            PlanObjective::Edp => self.edp,
        }
    }
}

/// What [`ScheduleTable::repair_insert`] did for one arriving job.
#[derive(Debug, Clone)]
pub struct RepairOutcome {
    /// Where the new job landed.
    pub placement: Placement,
    /// `Some((i, new))` when a one-level relocation moved `movable[i]`
    /// to `new` to make room for the arrival.
    pub moved: Option<(usize, Placement)>,
    /// Relative objective excess of the achieved insertion over the
    /// cap-free optimum: 0 means the arrival took the unconstrained
    /// argmin; large values mean caps forced an expensive detour and a
    /// full re-solve is likely to recover energy (the scheduler's
    /// fallback trigger).
    pub degradation: f64,
    /// Per-event solver telemetry: a fresh `plan_id`, the candidates
    /// priced *for this event* (only newly-cached kernel slabs count —
    /// repeat kernels cost zero), and the relocation scan counters.
    pub report: SolveReport,
}

/// The streaming scheduler's retained half of the batch solver's
/// `prepare` phase (DESIGN.md §14): device grids, power tables and
/// max-frequency indices built once, per-kernel prediction rows priced
/// lazily and **cached across events**. A single-job event then costs
/// at most one kernel slab (`total_points` candidate evaluations, zero
/// for a kernel seen before) instead of the batch solver's
/// `K × total_points` — the strict-inequality the scheduler bench
/// gates on. Placement decisions reuse the exact candidate economics
/// of [`plan`]: deadline-feasible objective argmin per device, greedy
/// insert into slack, one-level relocation repair when caps bind.
pub struct ScheduleTable {
    objective: PlanObjective,
    device_cap: usize,
    devices: Vec<DeviceRecord>,
    /// Availability mask (DeviceUp/DeviceDown), parallel to `devices`.
    available: Vec<bool>,
    grids: Vec<Vec<FreqPoint>>,
    /// `power[d][p]`: board watts at device `d`'s point `p`, split
    /// into the v2 dynamic/leakage components.
    power: Vec<Vec<crate::dvfs::PowerSplit>>,
    max_point_idx: Vec<usize>,
    /// Summed per-device grid sizes (the cost of pricing one kernel).
    total_points: usize,
    /// `rows[kernel.0][d][p]`: cached single-invocation µs.
    rows: FxHashMap<u64, Vec<Vec<f64>>>,
    candidates_evaluated: u64,
    slab_calls: u64,
}

impl ScheduleTable {
    /// Build the device-side tables (grids, power, max points) for
    /// every device `cfg` selects — no kernel is priced yet. Mirrors
    /// the validation `prepare` performs on the device dimension.
    pub fn new(engine: &Engine, cfg: &PlannerConfig) -> Result<ScheduleTable, PlanError> {
        let Some(registry) = engine.registry() else {
            return Err(PlanError::Invalid(
                "engine has no registry attached (Engine::with_handles)".to_string(),
            ));
        };
        let devices: Vec<DeviceRecord> = match &cfg.devices {
            None => registry.list(),
            Some(ids) => {
                let mut seen: HashSet<DeviceId> = HashSet::with_capacity(ids.len());
                let mut out = Vec::with_capacity(ids.len());
                for &id in ids {
                    if !seen.insert(id) {
                        continue;
                    }
                    match registry.get(id) {
                        Some(r) => out.push(r),
                        None => return Err(PlanError::UnknownDevice { device: id }),
                    }
                }
                out
            }
        };
        if devices.is_empty() {
            return Err(PlanError::Invalid("no devices to plan over".to_string()));
        }
        if let Some(pairs) = &cfg.pairs {
            if pairs.is_empty() {
                return Err(PlanError::Invalid("candidate pairs list is empty".to_string()));
            }
            for &(cf, mf) in pairs {
                if !FreqPoint::new(cf, mf).is_valid() {
                    return Err(PlanError::Invalid(format!(
                        "candidate pair ({cf}, {mf}) MHz: frequencies must be positive \
                         and finite"
                    )));
                }
            }
        }
        let grids: Vec<Vec<FreqPoint>> = devices
            .iter()
            .map(|r| match &cfg.pairs {
                Some(pairs) => pairs.iter().map(|&p| p.into()).collect(),
                None => device_grid(&r.power),
            })
            .collect();
        let total_points = grids.iter().fold(0usize, |a, g| a.saturating_add(g.len()));
        if total_points > MAX_EVALUATIONS {
            return Err(PlanError::Invalid(format!(
                "schedule table is too large: {total_points} candidate points over {} \
                 devices (limit {MAX_EVALUATIONS})",
                devices.len()
            )));
        }
        let power: Vec<Vec<crate::dvfs::PowerSplit>> = devices
            .iter()
            .enumerate()
            .map(|(di, rec)| {
                grids[di].iter().map(|p| rec.power.split_w(p.core_mhz, p.mem_mhz)).collect()
            })
            .collect();
        let max_point_idx: Vec<usize> = grids.iter().map(|g| max_point_of(g)).collect();
        let available = vec![true; devices.len()];
        Ok(ScheduleTable {
            objective: cfg.objective,
            device_cap: cfg.device_cap,
            devices,
            available,
            grids,
            power,
            max_point_idx,
            total_points,
            rows: FxHashMap::default(),
            candidates_evaluated: 0,
            slab_calls: 0,
        })
    }

    pub fn objective(&self) -> PlanObjective {
        self.objective
    }

    pub fn device_cap(&self) -> usize {
        self.device_cap
    }

    /// Summed per-device grid sizes: the candidate cost of pricing one
    /// kernel through the table (the batch solver pays `K ×` this).
    pub fn total_points(&self) -> usize {
        self.total_points
    }

    /// Every device in the table, in registration order.
    pub fn device_ids(&self) -> Vec<DeviceId> {
        self.devices.iter().map(|r| r.id).collect()
    }

    /// Devices currently marked up.
    pub fn available_ids(&self) -> Vec<DeviceId> {
        self.devices
            .iter()
            .zip(&self.available)
            .filter_map(|(r, &up)| up.then_some(r.id))
            .collect()
    }

    /// Flip a device's availability; `false` if the id is unknown.
    pub fn set_available(&mut self, device: DeviceId, up: bool) -> bool {
        match self.devices.iter().position(|r| r.id == device) {
            Some(i) => {
                self.available[i] = up;
                true
            }
            None => false,
        }
    }

    /// Cumulative `(candidates_evaluated, slab_calls)` since
    /// construction — callers diff around an event to attribute
    /// per-event work (admission pricing plus repair).
    pub fn counters(&self) -> (u64, u64) {
        (self.candidates_evaluated, self.slab_calls)
    }

    /// Price `kernel` on every device (one slab call per device) and
    /// cache the rows; a kernel seen before costs nothing. This is the
    /// only place the table evaluates candidates.
    pub fn ensure_kernel(&mut self, engine: &Engine, kernel: KernelId) -> Result<(), PlanError> {
        if self.rows.contains_key(&kernel.0) {
            return Ok(());
        }
        if engine.kernel_counters(kernel).is_err() {
            return Err(PlanError::UnknownKernel { job: 0, name: String::new(), kernel });
        }
        let before = engine.compute_stats();
        let mut rows = Vec::with_capacity(self.devices.len());
        for (di, rec) in self.devices.iter().enumerate() {
            let estimates = engine
                .predict_points(rec.id, kernel, &self.grids[di])
                .map_err(|e| PlanError::Engine(format!("{e:#}")))?;
            rows.push(estimates.into_iter().map(|e| e.time_us).collect::<Vec<f64>>());
        }
        self.slab_calls += engine.compute_stats().since(before).slab_calls;
        self.candidates_evaluated += self.total_points as u64;
        self.rows.insert(kernel.0, rows);
        Ok(())
    }

    /// Fastest achievable scaled runtime over every *available* device
    /// and point, µs — the admission bound. A deadline below this is
    /// provably unmeetable: runtime in this model depends only on the
    /// (device, point), never on co-located load, so even max frequency
    /// on the least-loaded device cannot beat it.
    pub fn fastest_us(
        &mut self,
        engine: &Engine,
        kernel: KernelId,
        scale: f64,
    ) -> Result<f64, PlanError> {
        self.ensure_kernel(engine, kernel)?;
        let rows = &self.rows[&kernel.0];
        let mut fastest = f64::INFINITY;
        for (di, row) in rows.iter().enumerate() {
            if !self.available[di] {
                continue;
            }
            for &t in row {
                fastest = fastest.min(scale * t);
            }
        }
        Ok(fastest)
    }

    fn price(&self, rows: &[Vec<f64>], scale: f64, di: usize, pi: usize) -> Placement {
        let time_us = scale * rows[di][pi];
        let split = self.power[di][pi];
        let energy_mj = split.total_w * time_us * 1e-3;
        Placement {
            device: self.devices[di].id,
            point: self.grids[di][pi],
            time_us,
            power_w: split.total_w,
            power_dynamic_w: split.dynamic_w,
            power_leakage_w: split.leakage_w,
            energy_mj,
            edp: energy_mj * time_us,
        }
    }

    /// Deadline-feasible objective argmin for `job` on device `di`
    /// (`None` when no point meets the deadline). The job's kernel must
    /// already be ensured.
    fn best_on(&self, job: &Job, di: usize) -> Option<Placement> {
        let rows = self.rows.get(&job.kernel.0)?;
        let mut chosen: Option<Placement> = None;
        let mut chosen_key = f64::INFINITY;
        for pi in 0..self.grids[di].len() {
            let c = self.price(rows, job.scale, di, pi);
            let feasible = match job.deadline_us {
                Some(d) => c.time_us <= d,
                None => true,
            };
            if feasible && c.key(self.objective) < chosen_key {
                chosen_key = c.key(self.objective);
                chosen = Some(c);
            }
        }
        chosen
    }

    /// The job's max-frequency placement on device `di` (the baseline
    /// point admission reasons about). Kernel must be ensured.
    pub fn at_max(&self, kernel: KernelId, scale: f64, device: DeviceId) -> Option<Placement> {
        let di = self.devices.iter().position(|r| r.id == device)?;
        let rows = self.rows.get(&kernel.0)?;
        Some(self.price(rows, scale, di, self.max_point_idx[di]))
    }

    /// The incremental-repair entry point: insert one arriving `job`
    /// into an existing placement without re-solving the fleet.
    ///
    /// `movable` is the current placement of every job the scheduler
    /// may relocate (typically Scheduled-but-not-Running jobs, with
    /// deadlines already rebased to their *remaining* budget);
    /// `pinned` lists the devices of unmovable (Running) jobs, which
    /// count toward caps but never move. The search is the batch
    /// solver's greedy step for a single job: cheapest feasible device
    /// with slack, else a one-level relocation (move one `movable` job
    /// elsewhere so the arrival fits), else a structured
    /// [`PlanError::Infeasible`].
    pub fn repair_insert(
        &mut self,
        engine: &Engine,
        job: &Job,
        movable: &[(Job, DeviceId)],
        pinned: &[DeviceId],
    ) -> Result<RepairOutcome, PlanError> {
        let total_t = Instant::now();
        let mut report = SolveReport { plan_id: next_plan_id(), ..SolveReport::default() };
        if !(job.scale.is_finite() && job.scale > 0.0) {
            return Err(PlanError::Invalid(format!(
                "job `{}`: scale must be positive and finite, got {}",
                job.name, job.scale
            )));
        }
        if let Some(d) = job.deadline_us {
            if !(d.is_finite() && d > 0.0) {
                return Err(PlanError::Invalid(format!(
                    "job `{}`: deadline_us must be positive and finite, got {d}",
                    job.name
                )));
            }
        }
        let (c0, s0) = (self.candidates_evaluated, self.slab_calls);
        let build_t = Instant::now();
        self.ensure_kernel(engine, job.kernel).map_err(|e| match e {
            PlanError::UnknownKernel { kernel, .. } => {
                PlanError::UnknownKernel { job: 0, name: job.name.clone(), kernel }
            }
            other => other,
        })?;
        for (mj, _) in movable {
            self.ensure_kernel(engine, mj.kernel)?;
        }
        report.build_us = us_since(build_t);
        report.candidates_evaluated = self.candidates_evaluated - c0;
        report.slab_calls = self.slab_calls - s0;

        let d_count = self.devices.len();
        let mut load = vec![0usize; d_count];
        let index_of = |id: DeviceId| self.devices.iter().position(|r| r.id == id);
        for (_, dev) in movable {
            if let Some(di) = index_of(*dev) {
                load[di] += 1;
            }
        }
        for dev in pinned {
            if let Some(di) = index_of(*dev) {
                load[di] += 1;
            }
        }

        // Direct insert: cheapest feasible available device with slack.
        // Track the cap-free optimum alongside for the degradation
        // measure, and the fastest runtime for the infeasibility
        // diagnostic.
        let mut capped: Option<(usize, Placement)> = None;
        let mut capped_key = f64::INFINITY;
        let mut free_key = f64::INFINITY;
        let mut fastest = f64::INFINITY;
        for di in 0..d_count {
            if !self.available[di] {
                continue;
            }
            let rows = &self.rows[&job.kernel.0];
            for pi in 0..self.grids[di].len() {
                fastest = fastest.min(job.scale * rows[di][pi]);
            }
            let Some(p) = self.best_on(job, di) else { continue };
            let key = p.key(self.objective);
            if key < free_key {
                free_key = key;
            }
            if load[di] < self.device_cap && key < capped_key {
                capped_key = key;
                capped = Some((di, p));
            }
        }
        let rel = |excess: f64, base: f64| (excess / base.abs().max(1e-12)).max(0.0);
        if !free_key.is_finite() {
            report.total_us = us_since(total_t);
            return Err(PlanError::Infeasible {
                job: 0,
                name: job.name.clone(),
                detail: match job.deadline_us {
                    Some(dl) => format!(
                        "deadline {dl} µs is unreachable on every available device: \
                         fastest achievable runtime is {fastest:.3} µs"
                    ),
                    None => "no available device offers a valid operating point".to_string(),
                },
            });
        }
        if let Some((_, p)) = capped {
            report.total_us = us_since(total_t);
            let degradation = rel(p.key(self.objective) - free_key, free_key);
            return Ok(RepairOutcome { placement: p, moved: None, degradation, report });
        }

        // Every feasible device is at its cap: one-level relocation —
        // move one movable job to another device with slack so the
        // arrival takes its place (the batch solver's greedy repair,
        // restricted to a single event).
        let repair_t = Instant::now();
        let mut best: Option<(usize, usize, Placement, Placement)> = None;
        let mut best_delta = f64::INFINITY;
        let mut budget: usize = MAX_EVALUATIONS;
        'search: for di in 0..d_count {
            if !self.available[di] {
                continue;
            }
            let Some(p_j) = self.best_on(job, di) else { continue };
            let cost_j = p_j.key(self.objective);
            for (i, (mj, mdev)) in movable.iter().enumerate() {
                if index_of(*mdev) != Some(di) {
                    continue;
                }
                if budget < d_count {
                    break 'search;
                }
                budget -= d_count;
                let Some(cur_i) = self.best_on(mj, di) else { continue };
                for d2 in 0..d_count {
                    if d2 == di || !self.available[d2] || load[d2] >= self.device_cap {
                        continue;
                    }
                    let Some(alt_i) = self.best_on(mj, d2) else { continue };
                    report.relocations_tried += 1;
                    let delta = alt_i.key(self.objective) - cur_i.key(self.objective) + cost_j;
                    if delta < best_delta {
                        best_delta = delta;
                        best = Some((i, di, p_j, alt_i));
                    }
                }
            }
        }
        report.repair_us = us_since(repair_t);
        report.total_us = us_since(total_t);
        match best {
            Some((i, _, p_j, alt_i)) => {
                report.relocations_accepted = 1;
                let degradation = rel(best_delta - free_key, free_key);
                Ok(RepairOutcome {
                    placement: p_j,
                    moved: Some((i, alt_i)),
                    degradation,
                    report,
                })
            }
            None => Err(PlanError::Infeasible {
                job: 0,
                name: job.name.clone(),
                detail: format!(
                    "every available device that can meet the job's constraints is at \
                     its concurrency cap ({} jobs/device over {} devices)",
                    self.device_cap,
                    self.available.iter().filter(|&&up| up).count()
                ),
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::model::{HwParams, KernelCounters};
    use crate::registry::{DeviceRegistry, KernelCatalog};

    fn counters_membound() -> KernelCounters {
        KernelCounters {
            l2_hr: 0.0,
            gld_trans: 12.0,
            avr_inst: 0.4,
            n_blocks: 256.0,
            wpb: 8.0,
            aw: 64.0,
            n_sm: 16.0,
            o_itrs: 8.0,
            i_itrs: 0.0,
            uses_smem: false,
            smem_conflict: 1.0,
            gld_body: 12.0,
            gld_edge: 0.0,
            mem_ops: 3.0,
            l1_hr: 0.0,
        }
    }

    fn counters_compbound() -> KernelCounters {
        KernelCounters { avr_inst: 100.0, l2_hr: 0.9, gld_trans: 2.0, ..counters_membound() }
    }

    /// Two-device fixture: the second GPU has slightly slower DRAM and
    /// a cheaper power model, so device choice matters.
    fn fixture() -> (Engine, Vec<DeviceId>, Vec<KernelId>) {
        let hw = HwParams::paper_defaults();
        let registry = Arc::new(DeviceRegistry::new());
        let a = registry.register("gpu-a", hw, PowerModel::gtx980());
        let mut hw_b = hw;
        hw_b.dm_del += 1.0;
        let mut power_b = PowerModel::gtx980();
        power_b.leakage.static_w = 14.0;
        power_b.dynamic.core_coeff = 0.05;
        let b = registry.register("gpu-b", hw_b, power_b);
        let catalog = Arc::new(KernelCatalog::new());
        let mem = catalog.register("membound", counters_membound());
        let comp = catalog.register("compbound", counters_compbound());
        let engine = Engine::native(hw).with_handles(registry, catalog, a).unwrap();
        (engine, vec![a, b], vec![mem, comp])
    }

    fn fleet(kernels: &[KernelId], n: usize) -> Vec<Job> {
        (0..n)
            .map(|i| {
                Job::new(format!("job-{i}"), kernels[i % kernels.len()], 1.0 + (i % 4) as f64)
            })
            .collect()
    }

    #[test]
    fn device_grid_is_the_curve_cross_product() {
        let p = PowerModel::gtx980();
        let g = device_grid(&p);
        assert_eq!(g.len(), p.core_curve.points.len() * p.mem_curve.points.len());
        // The full v2 ladder: 7 core × 3 mem breakpoints.
        assert_eq!(g.len(), 21);
        assert!(g.contains(&FreqPoint::new(400.0, 400.0)));
        assert!(g.contains(&FreqPoint::new(1000.0, 1000.0)));
        assert!(g.iter().all(FreqPoint::is_valid));
    }

    #[test]
    fn energy_is_power_times_time_for_every_assignment() {
        // The objective-math invariant: E = P×T (in mJ) and
        // EDP = E×T hold exactly for every emitted assignment, and the
        // plan totals are the sums.
        let (engine, devices, kernels) = fixture();
        let jobs = fleet(&kernels, 12);
        let p = plan(&engine, &jobs, &PlannerConfig::default()).unwrap();
        assert_eq!(p.assignments.len(), 12);
        let registry = engine.registry().unwrap();
        let (mut te, mut tedp) = (0.0, 0.0);
        for a in &p.assignments {
            let rec = registry.get(a.device).unwrap();
            assert!(devices.contains(&a.device));
            assert_eq!(
                a.power_w.to_bits(),
                rec.power.power_w(a.point.core_mhz, a.point.mem_mhz).to_bits(),
                "power must come from the device's own model"
            );
            let split = rec.power.split_w(a.point.core_mhz, a.point.mem_mhz);
            assert_eq!(a.power_dynamic_w.to_bits(), split.dynamic_w.to_bits());
            assert_eq!(a.power_leakage_w.to_bits(), split.leakage_w.to_bits());
            let want_mj = a.power_w * a.time_us * 1e-3;
            assert!(
                (a.energy_mj - want_mj).abs() <= 1e-12 * want_mj.abs().max(1.0),
                "E != P*T: {} vs {want_mj}",
                a.energy_mj
            );
            let want_edp = a.energy_mj * a.time_us;
            assert!((a.edp - want_edp).abs() <= 1e-12 * want_edp.abs().max(1.0));
            te += a.energy_mj;
            tedp += a.edp;
        }
        assert!((p.total_energy_mj - te).abs() <= 1e-9 * te.max(1.0));
        assert!((p.total_edp - tedp).abs() <= 1e-9 * tedp.max(1.0));
        let max_t = p.assignments.iter().map(|a| a.time_us).fold(0.0, f64::max);
        assert_eq!(p.max_time_us.to_bits(), max_t.to_bits());
    }

    #[test]
    fn uncapped_plan_matches_per_job_exhaustive_argmin() {
        // Without caps the planner must equal brute force: every job
        // independently takes the global (device, point) argmin.
        let (engine, devices, kernels) = fixture();
        let jobs = fleet(&kernels, 6);
        let p = plan(&engine, &jobs, &PlannerConfig::default()).unwrap();
        let registry = engine.registry().unwrap();
        for (j, job) in jobs.iter().enumerate() {
            let mut brute: Option<(DeviceId, FreqPoint, f64)> = None;
            for &d in &devices {
                let rec = registry.get(d).unwrap();
                for point in device_grid(&rec.power) {
                    let t = job.scale
                        * engine.predict_handle(d, job.kernel, point).unwrap().time_us;
                    let e = rec.power.power_w(point.core_mhz, point.mem_mhz) * t * 1e-3;
                    let better = match brute {
                        None => true,
                        Some((.., be)) => e < be,
                    };
                    if better {
                        brute = Some((d, point, e));
                    }
                }
            }
            let (bd, bp, be) = brute.unwrap();
            let a = &p.assignments[j];
            assert_eq!(a.device, bd, "job {j}");
            assert_eq!(a.point, bp, "job {j}");
            assert!((a.energy_mj - be).abs() <= 1e-12 * be.max(1.0));
        }
        assert_eq!(p.swaps_applied, 0, "unconstrained greedy is already optimal");
    }

    #[test]
    fn deadlines_are_hard_constraints() {
        let (engine, _, kernels) = fixture();
        // A roomy deadline: met, and the energy optimum may be slow.
        let loose = [Job::new("loose", kernels[0], 2.0).with_deadline(1e9)];
        let p = plan(&engine, &loose, &PlannerConfig::default()).unwrap();
        assert_eq!(p.deadline_violations(&loose), 0);
        // Tighten to just above the fastest achievable: still met,
        // with strictly more energy than the unconstrained optimum.
        let unconstrained = plan(
            &engine,
            &[Job::new("free", kernels[0], 2.0)],
            &PlannerConfig::default(),
        )
        .unwrap();
        let fastest = max_frequency_baseline(
            &engine,
            &[Job::new("fast", kernels[0], 2.0)],
            &PlannerConfig::default(),
        )
        .unwrap();
        let tight_dl = fastest.assignments[0].time_us * 1.01;
        let tight = [Job::new("tight", kernels[0], 2.0).with_deadline(tight_dl)];
        let p = plan(&engine, &tight, &PlannerConfig::default()).unwrap();
        assert!(p.assignments[0].time_us <= tight_dl);
        assert!(p.total_energy_mj >= unconstrained.total_energy_mj - 1e-12);
    }

    #[test]
    fn impossible_deadline_is_a_structured_infeasibility() {
        let (engine, _, kernels) = fixture();
        let jobs = [
            Job::new("fine", kernels[0], 1.0),
            Job::new("doomed", kernels[1], 1.0).with_deadline(1e-3),
        ];
        let err = plan(&engine, &jobs, &PlannerConfig::default()).unwrap_err();
        match err {
            PlanError::Infeasible { job, ref name, ref detail } => {
                assert_eq!(job, 1);
                assert_eq!(name, "doomed");
                assert!(detail.contains("unreachable"), "{detail}");
                assert!(detail.contains("fastest"), "{detail}");
            }
            other => panic!("expected Infeasible, got {other:?}"),
        }
    }

    #[test]
    fn capacity_caps_bind_and_repair_relocates() {
        let (engine, devices, kernels) = fixture();
        // Cap 1/device over 2 devices: three jobs cannot fit.
        let cfg = PlannerConfig { device_cap: 1, ..PlannerConfig::default() };
        let jobs = fleet(&kernels, 3);
        let err = plan(&engine, &jobs, &cfg).unwrap_err();
        assert!(matches!(err, PlanError::Infeasible { .. }), "{err:?}");
        // Two jobs fit exactly: one per device, caps respected.
        let jobs = fleet(&kernels, 2);
        let p = plan(&engine, &jobs, &cfg).unwrap();
        for &d in &devices {
            assert!(p.load_of(d) <= 1);
        }
        assert_eq!(p.load_of(devices[0]) + p.load_of(devices[1]), 2);
        // A deadline-squeezed job displaces a squatter: job 1 can only
        // run on SOME device fast enough, and the repair must relocate
        // whoever greedy parked there first.
        let mut tight = fleet(&kernels, 2);
        let fastest = max_frequency_baseline(&engine, &tight, &PlannerConfig::default())
            .unwrap()
            .assignments
            .iter()
            .map(|a| a.time_us)
            .fold(f64::INFINITY, f64::min);
        tight[1] = tight[1].clone().with_deadline(fastest * 100.0);
        let p = plan(&engine, &tight, &cfg).unwrap();
        assert_eq!(p.deadline_violations(&tight), 0);
    }

    #[test]
    fn swap_refinement_beats_or_matches_greedy_under_caps() {
        // Force caps to bind so greedy order matters, then check the
        // refined plan meets every constraint and the totals are no
        // worse than a cap-respecting round-robin at the energy argmin
        // point per device (a valid feasible reference).
        let (engine, devices, kernels) = fixture();
        let n = 8;
        let cfg = PlannerConfig { device_cap: n / 2, ..PlannerConfig::default() };
        let jobs = fleet(&kernels, n);
        let p = plan(&engine, &jobs, &cfg).unwrap();
        assert_eq!(p.deadline_violations(&jobs), 0);
        for &d in &devices {
            assert!(p.load_of(d) <= n / 2, "cap violated on {d}");
        }
        let baseline = max_frequency_baseline(&engine, &jobs, &cfg).unwrap();
        assert!(
            p.total_energy_mj < baseline.total_energy_mj,
            "planned {} mJ must beat max-frequency {} mJ",
            p.total_energy_mj,
            baseline.total_energy_mj
        );
    }

    #[test]
    fn explicit_pairs_override_the_curve_grid() {
        let (engine, _, kernels) = fixture();
        let cfg = PlannerConfig {
            pairs: Some(vec![(700.0, 700.0)]),
            ..PlannerConfig::default()
        };
        let jobs = fleet(&kernels, 4);
        let p = plan(&engine, &jobs, &cfg).unwrap();
        for a in &p.assignments {
            assert_eq!(a.point, FreqPoint::new(700.0, 700.0));
        }
        let bad = PlannerConfig { pairs: Some(vec![]), ..PlannerConfig::default() };
        assert!(matches!(plan(&engine, &jobs, &bad), Err(PlanError::Invalid(_))));
        let bad = PlannerConfig {
            pairs: Some(vec![(0.0, 700.0)]),
            ..PlannerConfig::default()
        };
        assert!(matches!(plan(&engine, &jobs, &bad), Err(PlanError::Invalid(_))));
    }

    #[test]
    fn input_validation_is_typed() {
        let (engine, devices, kernels) = fixture();
        let cfg = PlannerConfig::default();
        assert!(matches!(plan(&engine, &[], &cfg), Err(PlanError::Invalid(_))));
        let bad_scale = [Job::new("z", kernels[0], 0.0)];
        assert!(matches!(plan(&engine, &bad_scale, &cfg), Err(PlanError::Invalid(_))));
        let bad_deadline = [Job::new("d", kernels[0], 1.0).with_deadline(f64::NAN)];
        assert!(matches!(plan(&engine, &bad_deadline, &cfg), Err(PlanError::Invalid(_))));
        let ghost = [Job::new("g", KernelId(99), 1.0)];
        match plan(&engine, &ghost, &cfg) {
            Err(PlanError::UnknownKernel { job: 0, kernel, .. }) => {
                assert_eq!(kernel, KernelId(99))
            }
            other => panic!("expected UnknownKernel, got {other:?}"),
        }
        let ghost_dev = PlannerConfig {
            devices: Some(vec![devices[0], DeviceId(404)]),
            ..PlannerConfig::default()
        };
        let jobs = fleet(&kernels, 1);
        match plan(&engine, &jobs, &ghost_dev) {
            Err(PlanError::UnknownDevice { device }) => assert_eq!(device, DeviceId(404)),
            other => panic!("expected UnknownDevice, got {other:?}"),
        }
        // An engine without handles is an Invalid, not a panic.
        let bare = Engine::native(HwParams::paper_defaults());
        assert!(matches!(plan(&bare, &jobs, &cfg), Err(PlanError::Invalid(_))));
    }

    #[test]
    fn restricting_devices_is_honored_and_deduplicated() {
        let (engine, devices, kernels) = fixture();
        let cfg = PlannerConfig {
            devices: Some(vec![devices[1], devices[1]]),
            device_cap: 4,
            ..PlannerConfig::default()
        };
        let jobs = fleet(&kernels, 4);
        let p = plan(&engine, &jobs, &cfg).unwrap();
        assert_eq!(p.load_of(devices[1]), 4, "duplicates must not double the cap");
        assert_eq!(p.load_of(devices[0]), 0);
        // A fifth job cannot fit once the dedup'd cap binds.
        let jobs = fleet(&kernels, 5);
        assert!(matches!(plan(&engine, &jobs, &cfg), Err(PlanError::Infeasible { .. })));
    }

    #[test]
    fn plan_with_baseline_matches_the_separate_calls_bit_for_bit() {
        let (engine, _, kernels) = fixture();
        let jobs = fleet(&kernels, 10);
        let cfg = PlannerConfig { device_cap: 5, ..PlannerConfig::default() };
        let (p, b) = plan_with_baseline(&engine, &jobs, &cfg).unwrap();
        let p2 = plan(&engine, &jobs, &cfg).unwrap();
        let b2 = max_frequency_baseline(&engine, &jobs, &cfg).unwrap();
        let b = b.expect("balanced cap admits round-robin");
        let assert_same = |x: &Plan, y: &Plan| {
            assert_eq!(x.assignments.len(), y.assignments.len());
            for (ax, ay) in x.assignments.iter().zip(&y.assignments) {
                assert_eq!(ax.device, ay.device);
                assert_eq!(ax.point, ay.point);
                assert_eq!(ax.energy_mj.to_bits(), ay.energy_mj.to_bits());
            }
            assert_eq!(x.total_energy_mj.to_bits(), y.total_energy_mj.to_bits());
        };
        assert_same(&p, &p2);
        assert_same(&b, &b2);
    }

    #[test]
    fn oversized_solves_are_refused_before_allocation() {
        // An unauthenticated caller must not be able to force a
        // multi-gigabyte table: jobs × candidate points is bounded.
        let (engine, _, kernels) = fixture();
        let huge_grid: Vec<(f64, f64)> =
            (0..2001).map(|i| (400.0 + i as f64 * 0.1, 700.0)).collect();
        let jobs = fleet(&kernels, 1000);
        let cfg = PlannerConfig { pairs: Some(huge_grid), ..PlannerConfig::default() };
        // 1000 jobs × (2001 points × 2 devices) > 2M evaluations.
        match plan(&engine, &jobs, &cfg) {
            Err(PlanError::Invalid(m)) => assert!(m.contains("too large"), "{m}"),
            other => panic!("expected Invalid(too large), got {other:?}"),
        }
        // The job count itself is capped (the O(J²) swap phase).
        let too_many = fleet(&kernels, 4097);
        match plan(&engine, &too_many, &PlannerConfig::default()) {
            Err(PlanError::Invalid(m)) => assert!(m.contains("4096"), "{m}"),
            other => panic!("expected Invalid(job cap), got {other:?}"),
        }
    }

    #[test]
    fn plans_are_deterministic() {
        let (engine, _, kernels) = fixture();
        let jobs = fleet(&kernels, 16);
        let cfg = PlannerConfig { device_cap: 8, ..PlannerConfig::default() };
        let a = plan(&engine, &jobs, &cfg).unwrap();
        let b = plan(&engine, &jobs, &cfg).unwrap();
        assert_eq!(a.assignments.len(), b.assignments.len());
        for (x, y) in a.assignments.iter().zip(&b.assignments) {
            assert_eq!(x.device, y.device);
            assert_eq!(x.point, y.point);
            assert_eq!(x.energy_mj.to_bits(), y.energy_mj.to_bits());
        }
        assert_eq!(a.total_energy_mj.to_bits(), b.total_energy_mj.to_bits());
        assert_eq!(a.swaps_applied, b.swaps_applied);
    }

    #[test]
    fn solve_reports_carry_phases_counters_and_provenance() {
        let (engine, _, kernels) = fixture();
        let jobs = fleet(&kernels, 8);
        let cfg = PlannerConfig { device_cap: 4, ..PlannerConfig::default() };
        let p = plan(&engine, &jobs, &cfg).unwrap();
        let r = &p.report;
        assert!(r.plan_id >= 1);
        // 2 distinct kernels × (2 devices × the 21-point v2 grid each).
        let per_kernel = 2 * device_grid(&PowerModel::gtx980()).len() as u64;
        assert_eq!(r.candidates_evaluated, 2 * per_kernel);
        // One slab call per (device, kernel) on a cold cache.
        assert_eq!(r.slab_calls, 4);
        assert!(r.total_us > 0.0);
        assert!(r.phases_us() <= r.total_us * (1.0 + 1e-9) + 1e-6, "{r:?}");
        assert!(r.relocations_accepted <= r.relocations_tried, "{r:?}");
        assert!(r.swaps_accepted <= r.swaps_tried, "{r:?}");
        assert_eq!(r.explains.len(), jobs.len());
        for (j, e) in r.explains.iter().enumerate() {
            assert_eq!(e.job, j);
            assert!(e.deadline_slack_us.is_none(), "fleet() jobs carry no deadline");
            // Chosen by energy argmin, so flat-out on the same device
            // can never be cheaper.
            assert!(e.energy_delta_vs_max_mj <= 1e-12, "{e:?}");
            let ru = e.runner_up.expect("a 21-point grid always has a loser");
            assert_eq!(ru.rejected_by, rejected_by::OBJECTIVE);
        }
        // A warm cache serves the table without new slab calls, and
        // every solve mints a fresh id.
        let p2 = plan(&engine, &jobs, &cfg).unwrap();
        assert_eq!(p2.report.slab_calls, 0);
        assert!(p2.report.plan_id > r.plan_id);
    }

    #[test]
    fn deadline_squeezed_runner_up_is_rejected_by_the_deadline() {
        let (engine, _, kernels) = fixture();
        let fastest = max_frequency_baseline(
            &engine,
            &[Job::new("probe", kernels[0], 2.0)],
            &PlannerConfig::default(),
        )
        .unwrap();
        // A deadline just above the fastest runtime forces a near-max
        // point; the energy-optimal point loses on the deadline.
        let tight_dl = fastest.assignments[0].time_us * 1.01;
        let jobs = [Job::new("tight", kernels[0], 2.0).with_deadline(tight_dl)];
        let p = plan(&engine, &jobs, &PlannerConfig::default()).unwrap();
        let e = &p.report.explains[0];
        let slack = e.deadline_slack_us.expect("job has a deadline");
        assert!(slack >= 0.0, "emitted plans meet deadlines, slack {slack}");
        assert!((slack - (tight_dl - p.assignments[0].time_us)).abs() < 1e-9);
        let ru = e.runner_up.expect("grid has 21 points");
        assert_eq!(ru.rejected_by, rejected_by::DEADLINE);
        assert!(ru.energy_mj < p.assignments[0].energy_mj, "the loser was cheaper");
    }

    #[test]
    fn telemetry_off_skips_spans_and_provenance_but_not_the_plan() {
        let (engine, _, kernels) = fixture();
        let jobs = fleet(&kernels, 10);
        let on_cfg = PlannerConfig { device_cap: 5, ..PlannerConfig::default() };
        let off_cfg = PlannerConfig { telemetry: false, ..on_cfg.clone() };
        let on = plan(&engine, &jobs, &on_cfg).unwrap();
        let off = plan(&engine, &jobs, &off_cfg).unwrap();
        // Bit-identical placements either way — telemetry is passive.
        assert_eq!(on.assignments.len(), off.assignments.len());
        for (x, y) in on.assignments.iter().zip(&off.assignments) {
            assert_eq!(x.device, y.device);
            assert_eq!(x.point, y.point);
            assert_eq!(x.energy_mj.to_bits(), y.energy_mj.to_bits());
        }
        assert_eq!(on.total_energy_mj.to_bits(), off.total_energy_mj.to_bits());
        // Off: no clocks, no provenance; counters still live.
        assert_eq!(off.report.total_us, 0.0);
        assert_eq!(off.report.phases_us(), 0.0);
        assert!(off.report.explains.is_empty());
        assert_eq!(off.report.candidates_evaluated, on.report.candidates_evaluated);
        assert_eq!(off.report.swaps_tried, on.report.swaps_tried);
        // On: provenance present.
        assert_eq!(on.report.explains.len(), jobs.len());
        assert!(on.report.total_us > 0.0);
    }

    #[test]
    fn membound_jobs_downclock_core_compbound_keep_it_high() {
        // The paper's motivation carried to fleet scale: DRAM-bound
        // work parks at low core frequency, compute-bound work keeps
        // core high but memory low.
        let (engine, _, kernels) = fixture();
        let jobs = [
            Job::new("mem", kernels[0], 1.0),
            Job::new("comp", kernels[1], 1.0),
        ];
        let p = plan(&engine, &jobs, &PlannerConfig::default()).unwrap();
        let mem = &p.assignments[0];
        let comp = &p.assignments[1];
        assert!(mem.point.core_mhz <= 600.0, "membound core {}", mem.point.core_mhz);
        assert!(comp.point.mem_mhz <= 600.0, "compbound mem {}", comp.point.mem_mhz);
        assert!(comp.point.core_mhz >= mem.point.core_mhz);
    }

    #[test]
    fn schedule_table_prices_kernels_lazily_and_once() {
        let (engine, _, kernels) = fixture();
        let mut table = ScheduleTable::new(&engine, &PlannerConfig::default()).unwrap();
        // 2 devices × the 21-point grid each; nothing priced at build.
        let pts = (2 * device_grid(&PowerModel::gtx980()).len()) as u64;
        assert_eq!(table.total_points() as u64, pts);
        assert_eq!(table.counters(), (0, 0));
        let f = table.fastest_us(&engine, kernels[0], 2.0).unwrap();
        assert!(f.is_finite() && f > 0.0);
        let (cand, _) = table.counters();
        assert_eq!(cand, pts, "pricing one kernel costs total_points candidates");
        // The same kernel again is cache-served: zero new candidates.
        let f2 = table.fastest_us(&engine, kernels[0], 2.0).unwrap();
        assert_eq!(f2.to_bits(), f.to_bits());
        assert_eq!(table.counters().0, pts);
        // Scale is linear in the cached rows.
        let f_half = table.fastest_us(&engine, kernels[0], 1.0).unwrap();
        assert!((f - 2.0 * f_half).abs() <= 1e-9 * f.max(1.0));
    }

    #[test]
    fn repair_insert_into_slack_matches_the_batch_solver_argmin() {
        let (engine, _, kernels) = fixture();
        let mut table = ScheduleTable::new(&engine, &PlannerConfig::default()).unwrap();
        let job = Job::new("arrival", kernels[0], 3.0);
        let out = table.repair_insert(&engine, &job, &[], &[]).unwrap();
        assert!(out.moved.is_none());
        assert_eq!(out.degradation, 0.0, "uncapped insert is the unconstrained argmin");
        // The per-event work is one kernel slab, strictly below a
        // 2-kernel batch solve over the same table.
        let pts = (2 * device_grid(&PowerModel::gtx980()).len()) as u64;
        assert_eq!(out.report.candidates_evaluated, pts);
        let batch = plan(&engine, &[job.clone()], &PlannerConfig::default()).unwrap();
        let a = &batch.assignments[0];
        assert_eq!(out.placement.device, a.device);
        assert_eq!(out.placement.point, a.point);
        assert_eq!(out.placement.energy_mj.to_bits(), a.energy_mj.to_bits());
        // Second arrival with the same kernel: zero new candidates.
        let out2 = table.repair_insert(&engine, &job, &[], &[]).unwrap();
        assert_eq!(out2.report.candidates_evaluated, 0);
        assert!(out2.report.plan_id > out.report.plan_id, "each event mints a plan id");
    }

    #[test]
    fn repair_insert_relocates_a_squatter_when_caps_bind() {
        let (engine, _, kernels) = fixture();
        let cfg = PlannerConfig { device_cap: 1, ..PlannerConfig::default() };
        let mut table = ScheduleTable::new(&engine, &cfg).unwrap();
        // Place a movable job at its argmin device.
        let squatter = Job::new("squatter", kernels[0], 1.0);
        let first = table.repair_insert(&engine, &squatter, &[], &[]).unwrap();
        let movable = vec![(squatter.clone(), first.placement.device)];
        // An arrival that only fits on the squatter's device: deadline
        // just above its fastest runtime there — feasible on the faster
        // device only, which forces the one-level relocation.
        let mut fastest_on = f64::INFINITY;
        let mut fastest_any = f64::INFINITY;
        table.ensure_kernel(&engine, kernels[1]).unwrap();
        for id in table.device_ids() {
            let t = table.at_max(kernels[1], 1.0, id).unwrap().time_us;
            fastest_any = fastest_any.min(t);
            if id == first.placement.device {
                fastest_on = fastest_on.min(t);
            }
        }
        // Only meaningful when the squatter's device is also the fast
        // one for the arrival; both fixtures' device A is faster, so
        // this holds — assert it to keep the test honest.
        assert!(fastest_on <= fastest_any * 1.0 + 1e-9);
        let arrival =
            Job::new("urgent", kernels[1], 1.0).with_deadline(fastest_on * 1.001);
        let out = table.repair_insert(&engine, &arrival, &movable, &[]).unwrap();
        assert_eq!(out.placement.device, first.placement.device, "takes the fast device");
        let (idx, alt) = out.moved.expect("cap 1 forces a relocation");
        assert_eq!(idx, 0);
        assert_ne!(alt.device, first.placement.device, "squatter moved elsewhere");
        assert_eq!(out.report.relocations_accepted, 1);
        assert!(out.report.relocations_tried >= 1);
    }

    #[test]
    fn repair_insert_rejections_are_structured() {
        let (engine, devices, kernels) = fixture();
        let cfg = PlannerConfig { device_cap: 1, ..PlannerConfig::default() };
        let mut table = ScheduleTable::new(&engine, &cfg).unwrap();
        // Unreachable deadline: provable rejection with the fastest
        // runtime named (the admission-control path).
        let doomed = Job::new("doomed", kernels[0], 1.0).with_deadline(1e-6);
        match table.repair_insert(&engine, &doomed, &[], &[]) {
            Err(PlanError::Infeasible { detail, .. }) => {
                assert!(detail.contains("unreachable"), "{detail}");
                assert!(detail.contains("fastest"), "{detail}");
            }
            other => panic!("expected Infeasible, got {other:?}"),
        }
        // Pinned (Running) jobs fill caps without being movable: with
        // both devices pinned, a new arrival cannot be placed at all.
        let pinned = vec![devices[0], devices[1]];
        let job = Job::new("walk-in", kernels[0], 1.0);
        match table.repair_insert(&engine, &job, &[], &pinned) {
            Err(PlanError::Infeasible { detail, .. }) => {
                assert!(detail.contains("concurrency cap"), "{detail}");
            }
            other => panic!("expected Infeasible, got {other:?}"),
        }
        // A downed device is excluded from placement and from
        // fastest_us; downing everything is Invalid-free but
        // infeasible.
        assert!(table.set_available(devices[1], false));
        let one_dev = table.fastest_us(&engine, kernels[0], 1.0).unwrap();
        assert!(one_dev.is_finite());
        assert!(table.set_available(devices[0], false));
        let none = table.fastest_us(&engine, kernels[0], 1.0).unwrap();
        assert!(none.is_infinite(), "no available device → no achievable runtime");
        assert!(table.repair_insert(&engine, &job, &[], &[]).is_err());
        assert!(!table.set_available(DeviceId(404), true), "unknown device handle");
    }
}
