//! Batched prediction service: the hot path of the system.
//!
//! The AOT artifact is specialized to a fixed (1024, 16) batch, so the
//! coordinator's job is classic dynamic batching (vLLM-router style):
//! requests from many clients queue on a channel; a worker drains up to
//! a full batch (or until `max_wait` passes with a partial one),
//! executes a single PJRT call, and fans the rows back out to the
//! waiting clients. Python never runs here.

use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::model::params::{N_FEATURES, N_HW_PARAMS, N_OUTPUTS};
use crate::model::{KernelCounters, Regime};
use crate::runtime::{Runtime, PREDICT_BATCH};

/// A decoded prediction row.
#[derive(Debug, Clone, Copy)]
pub struct BatchPrediction {
    pub t_active: f64,
    pub t_exec_cycles: f64,
    pub time_us: f64,
    pub regime: Option<Regime>,
}

impl BatchPrediction {
    fn from_row(row: [f32; N_OUTPUTS]) -> Self {
        BatchPrediction {
            t_active: row[0] as f64,
            t_exec_cycles: row[1] as f64,
            time_us: row[2] as f64,
            regime: Regime::from_id(row[3] as u32),
        }
    }
}

struct Request {
    features: [f32; N_FEATURES],
    resp: Sender<BatchPrediction>,
}

/// Handle to the batching service. Cloneable; dropping every handle
/// shuts the worker down.
#[derive(Clone)]
pub struct BatchServer {
    tx: Sender<Request>,
    stats: Arc<ServerStats>,
    platform: String,
}

/// Counters the service exposes (all monotonically increasing).
#[derive(Debug, Default)]
pub struct ServerStats {
    pub requests: std::sync::atomic::AtomicU64,
    pub batches: std::sync::atomic::AtomicU64,
    pub rows_padded: std::sync::atomic::AtomicU64,
}

impl ServerStats {
    pub fn requests(&self) -> u64 {
        self.requests.load(std::sync::atomic::Ordering::Relaxed)
    }
    pub fn batches(&self) -> u64 {
        self.batches.load(std::sync::atomic::Ordering::Relaxed)
    }
    pub fn rows_padded(&self) -> u64 {
        self.rows_padded.load(std::sync::atomic::Ordering::Relaxed)
    }
    /// Mean occupancy of executed batches in [0, 1].
    pub fn mean_occupancy(&self) -> f64 {
        let b = self.batches();
        if b == 0 {
            return 0.0;
        }
        let total_rows = b * PREDICT_BATCH as u64;
        (total_rows - self.rows_padded()) as f64 / total_rows as f64
    }
}

fn worker_loop(
    runtime: Runtime,
    hw: [f32; N_HW_PARAMS],
    rx: Receiver<Request>,
    max_wait: Duration,
    stats: Arc<ServerStats>,
) {
    use std::sync::atomic::Ordering::Relaxed;
    loop {
        // Block for the first request of a batch.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all senders dropped
        };
        let mut pending = vec![first];
        let deadline = Instant::now() + max_wait;
        while pending.len() < PREDICT_BATCH {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }

        let rows: Vec<[f32; N_FEATURES]> = pending.iter().map(|r| r.features).collect();
        stats.requests.fetch_add(rows.len() as u64, Relaxed);
        stats.batches.fetch_add(1, Relaxed);
        stats.rows_padded.fetch_add((PREDICT_BATCH - rows.len() % PREDICT_BATCH) as u64 % PREDICT_BATCH as u64, Relaxed);

        match runtime.predict(&rows, &hw) {
            Ok(out) => {
                for (req, row) in pending.into_iter().zip(out) {
                    let _ = req.resp.send(BatchPrediction::from_row(row));
                }
            }
            Err(e) => {
                // Drop the response senders: clients see RecvError.
                eprintln!("batch execution failed: {e:#}");
            }
        }
    }
}

impl BatchServer {
    /// Start the service worker with the default artifacts directory.
    pub fn start_default(
        hw: [f32; N_HW_PARAMS],
        max_wait: Duration,
    ) -> Result<(Self, JoinHandle<()>)> {
        Self::start(Runtime::load_default, hw, max_wait)
    }

    /// Start the service worker. The PJRT client is not `Send` (it holds
    /// an `Rc` internally), so the worker thread constructs the Runtime
    /// itself via `factory`; init errors are surfaced here synchronously.
    /// `hw` is the micro-benchmarked hardware parameter vector the
    /// artifact consumes.
    pub fn start<F>(
        factory: F,
        hw: [f32; N_HW_PARAMS],
        max_wait: Duration,
    ) -> Result<(Self, JoinHandle<()>)>
    where
        F: FnOnce() -> Result<Runtime> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let stats = Arc::new(ServerStats::default());
        let worker_stats = stats.clone();
        let (init_tx, init_rx) = mpsc::channel::<Result<String>>();
        let handle = std::thread::spawn(move || {
            let runtime = match factory() {
                Ok(rt) => {
                    let _ = init_tx.send(Ok(rt.platform()));
                    rt
                }
                Err(e) => {
                    let _ = init_tx.send(Err(e));
                    return;
                }
            };
            worker_loop(runtime, hw, rx, max_wait, worker_stats);
        });
        let platform = init_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("batch worker died during init"))??;
        Ok((BatchServer { tx, stats, platform }, handle))
    }

    /// PJRT platform name the worker runs on.
    pub fn platform(&self) -> &str {
        &self.platform
    }

    /// Blocking single prediction (latency path).
    pub fn predict(&self, counters: &KernelCounters, core_mhz: f64, mem_mhz: f64) -> Result<BatchPrediction> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Request { features: counters.to_features(core_mhz, mem_mhz), resp })
            .map_err(|_| anyhow::anyhow!("batch server stopped"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("batch execution failed"))
    }

    /// Blocking many-point prediction (throughput path): enqueues all
    /// rows before draining responses, so they share batches.
    pub fn predict_grid(
        &self,
        counters: &KernelCounters,
        pairs: &[(f64, f64)],
    ) -> Result<Vec<BatchPrediction>> {
        let mut rxs = Vec::with_capacity(pairs.len());
        for &(cf, mf) in pairs {
            let (resp, rx) = mpsc::channel();
            self.tx
                .send(Request { features: counters.to_features(cf, mf), resp })
                .map_err(|_| anyhow::anyhow!("batch server stopped"))?;
            rxs.push(rx);
        }
        rxs.into_iter()
            .map(|rx| rx.recv().map_err(|_| anyhow::anyhow!("batch execution failed")))
            .collect()
    }

    pub fn stats(&self) -> &ServerStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{self, HwParams};

    fn counters() -> KernelCounters {
        KernelCounters {
            l2_hr: 0.1,
            gld_trans: 6.0,
            avr_inst: 1.5,
            n_blocks: 128.0,
            wpb: 8.0,
            aw: 64.0,
            n_sm: 16.0,
            o_itrs: 8.0,
            i_itrs: 0.0,
            uses_smem: false,
            smem_conflict: 1.0,
            gld_body: 6.0,
            gld_edge: 0.0,
            mem_ops: 2.0,
            l1_hr: 0.0,
        }
    }

    #[test]
    fn single_and_grid_predictions_match_native() {
        let hw = HwParams::paper_defaults();
        let (server, _h) =
            BatchServer::start_default(hw.to_f32(), Duration::from_millis(2)).unwrap();
        assert!(server.platform().to_lowercase().contains("cpu"));
        let c = counters();

        let one = server.predict(&c, 700.0, 700.0).unwrap();
        let native = model::predict(&c, &hw, 700.0, 700.0);
        assert!((one.time_us - native.time_us).abs() / native.time_us < 1e-4);
        assert_eq!(one.regime, Some(native.regime));

        let grid = crate::microbench::standard_grid();
        let out = server.predict_grid(&c, &grid).unwrap();
        assert_eq!(out.len(), 49);
        for (p, &(cf, mf)) in out.iter().zip(&grid) {
            let n = model::predict(&c, &hw, cf, mf);
            assert!(
                (p.time_us - n.time_us).abs() / n.time_us < 1e-4,
                "({cf},{mf}): {} vs {}",
                p.time_us,
                n.time_us
            );
        }
        assert!(server.stats().requests() >= 50);
        assert!(server.stats().batches() >= 1);
        assert!(server.stats().mean_occupancy() > 0.0);
    }

    #[test]
    fn concurrent_clients_share_batches() {
        let hw = HwParams::paper_defaults();
        let (server, _h) =
            BatchServer::start_default(hw.to_f32(), Duration::from_millis(5)).unwrap();
        let mut joins = Vec::new();
        for t in 0..8 {
            let s = server.clone();
            let c = counters();
            joins.push(std::thread::spawn(move || {
                let cf = 400.0 + (t as f64) * 50.0;
                let p = s.predict(&c, cf, 700.0).unwrap();
                assert!(p.time_us > 0.0);
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let st = server.stats();
        assert_eq!(st.requests(), 8);
        // With a 5 ms window the 8 requests should not need 8 batches.
        assert!(st.batches() <= 8);
    }
}
