//! Compatibility re-export: the batched prediction service moved into
//! the unified engine layer (`engine::pjrt`), where it gained N drain
//! workers over sharded request queues. Existing imports of
//! `coordinator::batcher::{BatchServer, BatchPrediction, ServerStats}`
//! keep working; new code should use `engine::Engine` with the PJRT
//! backend instead of talking to the server directly.

pub use crate::engine::pjrt::{BatchPrediction, BatchServer, ServerStats};
