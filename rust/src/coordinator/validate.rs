//! Model-vs-ground-truth validation: the machinery behind the paper's
//! Figs. 13/14 and the 3.5 % MAPE headline.
//!
//! Ground truth = the simulator run at each frequency pair. Prediction =
//! one baseline profile + the analytical model (or any `Predictor`
//! baseline, for the ablation bench).

use anyhow::Result;

use crate::baselines::Predictor;
use crate::engine::Engine;
use crate::profiler::{self, Profile};
use crate::sim::engine::simulate;
use crate::sim::isa::Kernel;
use crate::sim::{Clocks, GpuSpec};

/// One (kernel, frequency-pair) validation sample.
#[derive(Debug, Clone)]
pub struct SamplePoint {
    pub kernel: String,
    pub core_mhz: f64,
    pub mem_mhz: f64,
    /// Simulator ground truth, µs.
    pub truth_us: f64,
    /// Model prediction, µs.
    pub pred_us: f64,
}

impl SamplePoint {
    /// Signed relative error (negative = under-estimation), as plotted
    /// in the paper's Fig. 13.
    pub fn signed_err(&self) -> f64 {
        (self.pred_us - self.truth_us) / self.truth_us
    }

    pub fn abs_err(&self) -> f64 {
        self.signed_err().abs()
    }
}

/// Validation summary for one kernel (a Fig. 14 bar).
#[derive(Debug, Clone)]
pub struct KernelValidation {
    pub kernel: String,
    pub points: Vec<SamplePoint>,
}

impl KernelValidation {
    /// Mean absolute percentage error over the kernel's pairs.
    pub fn mape(&self) -> f64 {
        self.points.iter().map(|p| p.abs_err()).sum::<f64>() / self.points.len().max(1) as f64
    }

    pub fn max_abs_err(&self) -> f64 {
        self.points.iter().map(|p| p.abs_err()).fold(0.0, f64::max)
    }
}

/// Whole-suite validation (Fig. 14 + the headline number).
#[derive(Debug, Clone)]
pub struct Validation {
    pub per_kernel: Vec<KernelValidation>,
}

impl Validation {
    /// MAPE across every (kernel, pair) sample — the paper's 3.5 %.
    pub fn overall_mape(&self) -> f64 {
        let (sum, n) = self
            .per_kernel
            .iter()
            .flat_map(|k| &k.points)
            .fold((0.0, 0usize), |(s, n), p| (s + p.abs_err(), n + 1));
        sum / n.max(1) as f64
    }

    /// Fraction of samples with error below `thresh` (paper: 90 % < 10 %).
    pub fn fraction_below(&self, thresh: f64) -> f64 {
        let pts: Vec<f64> = self
            .per_kernel
            .iter()
            .flat_map(|k| k.points.iter().map(|p| p.abs_err()))
            .collect();
        if pts.is_empty() {
            return 0.0;
        }
        pts.iter().filter(|e| **e < thresh).count() as f64 / pts.len() as f64
    }

    pub fn max_abs_err(&self) -> f64 {
        self.per_kernel.iter().map(|k| k.max_abs_err()).fold(0.0, f64::max)
    }
}

/// Ground truth for one kernel at one pair, µs.
pub fn ground_truth_us(spec: &GpuSpec, kernel: &Kernel, clocks: Clocks) -> f64 {
    simulate(spec, clocks, kernel).stats.elapsed_ns / 1e3
}

/// Validate one kernel with an arbitrary predictor over `pairs`.
pub fn validate_kernel_with(
    spec: &GpuSpec,
    kernel: &Kernel,
    profile: &Profile,
    predictor: &dyn Predictor,
    pairs: &[(f64, f64)],
) -> KernelValidation {
    let points = pairs
        .iter()
        .map(|&(cf, mf)| SamplePoint {
            kernel: kernel.name.clone(),
            core_mhz: cf,
            mem_mhz: mf,
            truth_us: ground_truth_us(spec, kernel, Clocks::new(cf, mf)),
            pred_us: predictor.predict_us(&profile.counters, cf, mf),
        })
        .collect();
    KernelValidation { kernel: kernel.name.clone(), points }
}

/// Full-suite validation with an arbitrary predictor.
pub fn validate_with(
    spec: &GpuSpec,
    kernels: &[Kernel],
    predictor: &dyn Predictor,
    pairs: &[(f64, f64)],
) -> Validation {
    let per_kernel = kernels
        .iter()
        .map(|k| {
            let profile = profiler::profile(spec, k);
            validate_kernel_with(spec, k, &profile, predictor, pairs)
        })
        .collect();
    Validation { per_kernel }
}

/// Validate one kernel through the prediction [`Engine`]: ground truth
/// from the simulator, predictions from one batched `predict_grid`
/// call (cache-served on repeats).
pub fn validate_kernel_with_engine(
    spec: &GpuSpec,
    kernel: &Kernel,
    profile: &Profile,
    engine: &Engine,
    pairs: &[(f64, f64)],
) -> Result<KernelValidation> {
    let ests = engine.predict_grid(&profile.counters, pairs)?;
    let points = pairs
        .iter()
        .zip(ests)
        .map(|(&(cf, mf), est)| SamplePoint {
            kernel: kernel.name.clone(),
            core_mhz: cf,
            mem_mhz: mf,
            truth_us: ground_truth_us(spec, kernel, Clocks::new(cf, mf)),
            pred_us: est.time_us,
        })
        .collect();
    Ok(KernelValidation { kernel: kernel.name.clone(), points })
}

/// Full-suite validation through the prediction [`Engine`] — the path
/// the CLI's `validate` / `report fig13|fig14` commands use.
pub fn validate_with_engine(
    spec: &GpuSpec,
    kernels: &[Kernel],
    engine: &Engine,
    pairs: &[(f64, f64)],
) -> Result<Validation> {
    let per_kernel = kernels
        .iter()
        .map(|k| {
            let profile = profiler::profile(spec, k);
            validate_kernel_with_engine(spec, k, &profile, engine, pairs)
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(Validation { per_kernel })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::PaperModel;
    use crate::kernels;
    use crate::model::HwParams;

    #[test]
    fn sample_point_errors() {
        let p = SamplePoint {
            kernel: "x".into(),
            core_mhz: 700.0,
            mem_mhz: 700.0,
            truth_us: 100.0,
            pred_us: 90.0,
        };
        assert!((p.signed_err() + 0.1).abs() < 1e-12);
        assert!((p.abs_err() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn validation_aggregates() {
        let mk = |e: f64| SamplePoint {
            kernel: "k".into(),
            core_mhz: 0.0,
            mem_mhz: 0.0,
            truth_us: 1.0,
            pred_us: 1.0 + e,
        };
        let v = Validation {
            per_kernel: vec![
                KernelValidation { kernel: "a".into(), points: vec![mk(0.02), mk(-0.04)] },
                KernelValidation { kernel: "b".into(), points: vec![mk(0.2), mk(0.0)] },
            ],
        };
        assert!((v.overall_mape() - 0.065).abs() < 1e-12);
        assert!((v.fraction_below(0.10) - 0.75).abs() < 1e-12);
        assert!((v.max_abs_err() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn engine_validation_matches_predictor_validation() {
        let spec = GpuSpec::default();
        let k = kernels::vector_add();
        let prof = profiler::profile(&spec, &k);
        let hw = HwParams::paper_defaults();
        let pairs = [(700.0, 700.0), (400.0, 1000.0)];
        let direct =
            validate_kernel_with(&spec, &k, &prof, &PaperModel { hw }, &pairs);
        let engine = Engine::native(hw);
        let via_engine =
            validate_kernel_with_engine(&spec, &k, &prof, &engine, &pairs).unwrap();
        for (a, b) in direct.points.iter().zip(&via_engine.points) {
            assert_eq!(a.pred_us.to_bits(), b.pred_us.to_bits());
            assert_eq!(a.truth_us.to_bits(), b.truth_us.to_bits());
        }
    }

    #[test]
    fn baseline_pair_prediction_is_close_for_va() {
        // At the profiling baseline itself the model should be close
        // (this is the easiest point: no extrapolation).
        let spec = GpuSpec::default();
        let k = kernels::vector_add();
        let prof = profiler::profile(&spec, &k);
        let model = PaperModel { hw: HwParams::paper_defaults() };
        let v = validate_kernel_with(&spec, &k, &prof, &model, &[(700.0, 700.0)]);
        assert!(v.mape() < 0.25, "VA baseline-point error {}", v.mape());
    }
}
