//! L3 coordination: sweep orchestration and model validation. The
//! request-batching service that used to live here (`batcher`) moved
//! into the unified prediction engine — use `engine::pjrt::BatchServer`
//! (re-exported as `engine::BatchServer`).

pub mod sweep;
pub mod validate;

pub use sweep::{predicted_sweep, run_sweep, Sweep, SweepPoint};
pub use validate::{validate_with, validate_with_engine, Validation};
