//! L3 coordination: sweep orchestration, model validation, and the
//! batched PJRT prediction service.
pub mod batcher;
pub mod sweep;
pub mod validate;

pub use batcher::{BatchPrediction, BatchServer};
pub use sweep::{run_sweep, Sweep, SweepPoint};
pub use validate::{validate_with, Validation};
