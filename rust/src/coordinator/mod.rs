//! L3 coordination: sweep orchestration and model validation. The
//! request-batching service that used to live here moved into the
//! unified prediction engine (`engine::pjrt`); `batcher` remains as a
//! compatibility re-export.

pub mod batcher;
pub mod sweep;
pub mod validate;

pub use batcher::{BatchPrediction, BatchServer};
pub use sweep::{predicted_sweep, run_sweep, Sweep, SweepPoint};
pub use validate::{validate_with, validate_with_engine, Validation};
