//! Frequency-sweep orchestration: run kernels across the DVFS grid on
//! worker threads (tokio is not in the offline vendor set; the paper's
//! sweep is embarrassingly parallel, so a scoped thread pool is the
//! right tool — DESIGN.md "Offline substitutions").

use std::sync::mpsc;
use std::thread;

use crate::sim::engine::simulate;
use crate::sim::isa::Kernel;
use crate::sim::{Clocks, GpuSpec};

/// One measured grid point.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub kernel: String,
    pub core_mhz: f64,
    pub mem_mhz: f64,
    pub time_us: f64,
    pub l2_hr: f64,
    pub dram_txns: u64,
}

/// Result of a full sweep.
#[derive(Debug, Clone, Default)]
pub struct Sweep {
    pub points: Vec<SweepPoint>,
}

impl Sweep {
    /// Time at a grid point, if measured.
    pub fn time_us(&self, kernel: &str, cf: f64, mf: f64) -> Option<f64> {
        self.points
            .iter()
            .find(|p| p.kernel == kernel && p.core_mhz == cf && p.mem_mhz == mf)
            .map(|p| p.time_us)
    }

    /// Speedup of (cf, mf) relative to a reference pair — the quantity
    /// the paper's Fig. 2 plots.
    pub fn speedup(&self, kernel: &str, from: (f64, f64), to: (f64, f64)) -> Option<f64> {
        Some(self.time_us(kernel, from.0, from.1)? / self.time_us(kernel, to.0, to.1)?)
    }
}

/// Sweep `kernels` over `pairs`, running up to `workers` simulations in
/// parallel. Results are returned in deterministic (kernel, pair) order
/// regardless of completion order.
pub fn run_sweep(
    spec: &GpuSpec,
    kernels: &[Kernel],
    pairs: &[(f64, f64)],
    workers: usize,
) -> Sweep {
    let jobs: Vec<(usize, &Kernel, f64, f64)> = kernels
        .iter()
        .flat_map(|k| pairs.iter().map(move |&(cf, mf)| (k, cf, mf)))
        .enumerate()
        .map(|(i, (k, cf, mf))| (i, k, cf, mf))
        .collect();
    let n_jobs = jobs.len();
    let workers = workers.max(1).min(n_jobs.max(1));

    let mut results: Vec<Option<SweepPoint>> = vec![None; n_jobs];
    thread::scope(|scope| {
        let (tx, rx) = mpsc::channel();
        let chunks: Vec<Vec<(usize, &Kernel, f64, f64)>> = (0..workers)
            .map(|w| jobs.iter().skip(w).step_by(workers).cloned().collect())
            .collect();
        for chunk in chunks {
            let tx = tx.clone();
            let spec = spec.clone();
            scope.spawn(move || {
                for (i, k, cf, mf) in chunk {
                    let r = simulate(&spec, Clocks::new(cf, mf), k);
                    let point = SweepPoint {
                        kernel: k.name.clone(),
                        core_mhz: cf,
                        mem_mhz: mf,
                        time_us: r.stats.elapsed_ns / 1e3,
                        l2_hr: r.stats.l2_hit_rate(),
                        dram_txns: r.stats.dram_txns,
                    };
                    // Receiver outlives senders; ignore send errors on
                    // shutdown races (cannot happen inside scope).
                    let _ = tx.send((i, point));
                }
            });
        }
        drop(tx);
        while let Ok((i, p)) = rx.recv() {
            results[i] = Some(p);
        }
    });

    Sweep { points: results.into_iter().map(|p| p.expect("job completed")).collect() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels;

    #[test]
    fn sweep_covers_grid_in_order() {
        let spec = GpuSpec::default();
        let ks = vec![kernels::vector_add()];
        let pairs = vec![(400.0, 400.0), (400.0, 700.0), (700.0, 400.0)];
        let s = run_sweep(&spec, &ks, &pairs, 2);
        assert_eq!(s.points.len(), 3);
        assert_eq!(s.points[0].core_mhz, 400.0);
        assert_eq!(s.points[0].mem_mhz, 400.0);
        assert_eq!(s.points[2].mem_mhz, 400.0);
    }

    #[test]
    fn sweep_matches_direct_simulation() {
        let spec = GpuSpec::default();
        let k = kernels::transpose();
        let s = run_sweep(&spec, &[k.clone()], &[(500.0, 900.0)], 4);
        let direct = simulate(&spec, Clocks::new(500.0, 900.0), &k);
        assert_eq!(s.points[0].time_us, direct.stats.elapsed_ns / 1e3);
    }

    #[test]
    fn speedup_lookup() {
        let spec = GpuSpec::default();
        let ks = vec![kernels::vector_add()];
        let pairs = vec![(1000.0, 400.0), (1000.0, 1000.0)];
        let s = run_sweep(&spec, &ks, &pairs, 2);
        let sp = s.speedup("VA", (1000.0, 400.0), (1000.0, 1000.0)).unwrap();
        assert!(sp > 1.5, "{sp}");
        assert!(s.speedup("nope", (0.0, 0.0), (1.0, 1.0)).is_none());
    }

    #[test]
    fn single_worker_equals_many_workers() {
        let spec = GpuSpec::default();
        let ks = vec![kernels::scalar_prod()];
        let pairs = vec![(400.0, 1000.0), (800.0, 600.0)];
        let a = run_sweep(&spec, &ks, &pairs, 1);
        let b = run_sweep(&spec, &ks, &pairs, 8);
        for (x, y) in a.points.iter().zip(&b.points) {
            assert_eq!(x.time_us, y.time_us);
        }
    }
}
