//! Frequency-sweep orchestration: run kernels across the DVFS grid on
//! worker threads (tokio is not in the offline vendor set; the paper's
//! sweep is embarrassingly parallel, so a scoped thread pool is the
//! right tool — DESIGN.md "Offline substitutions").
//!
//! Output ordering is part of the contract: worker threads complete in
//! arbitrary interleavings, so results are canonicalized to
//! `(kernel, core_mhz, mem_mhz)` order before returning — two sweeps of
//! the same inputs are byte-for-byte identical regardless of worker
//! count or scheduling.

use std::cmp::Ordering;
use std::sync::mpsc;
use std::thread;

use anyhow::Result;

use crate::engine::Engine;
use crate::profiler::Profile;
use crate::sim::engine::simulate;
use crate::sim::isa::Kernel;
use crate::sim::{Clocks, GpuSpec};

/// One measured grid point.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub kernel: String,
    pub core_mhz: f64,
    pub mem_mhz: f64,
    pub time_us: f64,
    pub l2_hr: f64,
    pub dram_txns: u64,
}

fn canonical_order(a: &SweepPoint, b: &SweepPoint) -> Ordering {
    a.kernel
        .cmp(&b.kernel)
        .then(a.core_mhz.total_cmp(&b.core_mhz))
        .then(a.mem_mhz.total_cmp(&b.mem_mhz))
}

/// Result of a full sweep. Points are sorted by
/// `(kernel, core_mhz, mem_mhz)`.
#[derive(Debug, Clone, Default)]
pub struct Sweep {
    pub points: Vec<SweepPoint>,
}

impl Sweep {
    /// Time at a grid point, if measured.
    pub fn time_us(&self, kernel: &str, cf: f64, mf: f64) -> Option<f64> {
        self.points
            .iter()
            .find(|p| p.kernel == kernel && p.core_mhz == cf && p.mem_mhz == mf)
            .map(|p| p.time_us)
    }

    /// Speedup of (cf, mf) relative to a reference pair — the quantity
    /// the paper's Fig. 2 plots.
    pub fn speedup(&self, kernel: &str, from: (f64, f64), to: (f64, f64)) -> Option<f64> {
        Some(self.time_us(kernel, from.0, from.1)? / self.time_us(kernel, to.0, to.1)?)
    }
}

/// Sweep `kernels` over `pairs`, running up to `workers` simulations in
/// parallel. Results are returned in canonical (kernel, core, mem)
/// order regardless of completion order.
pub fn run_sweep(
    spec: &GpuSpec,
    kernels: &[Kernel],
    pairs: &[(f64, f64)],
    workers: usize,
) -> Sweep {
    let jobs: Vec<(&Kernel, f64, f64)> = kernels
        .iter()
        .flat_map(|k| pairs.iter().map(move |&(cf, mf)| (k, cf, mf)))
        .collect();
    let n_jobs = jobs.len();
    let workers = workers.max(1).min(n_jobs.max(1));

    let mut points: Vec<SweepPoint> = Vec::with_capacity(n_jobs);
    thread::scope(|scope| {
        let (tx, rx) = mpsc::channel();
        let chunks: Vec<Vec<(&Kernel, f64, f64)>> = (0..workers)
            .map(|w| jobs.iter().skip(w).step_by(workers).cloned().collect())
            .collect();
        for chunk in chunks {
            let tx = tx.clone();
            let spec = spec.clone();
            scope.spawn(move || {
                for (k, cf, mf) in chunk {
                    let r = simulate(&spec, Clocks::new(cf, mf), k);
                    let point = SweepPoint {
                        kernel: k.name.clone(),
                        core_mhz: cf,
                        mem_mhz: mf,
                        time_us: r.stats.elapsed_ns / 1e3,
                        l2_hr: r.stats.l2_hit_rate(),
                        dram_txns: r.stats.dram_txns,
                    };
                    // Receiver outlives senders; ignore send errors on
                    // shutdown races (cannot happen inside scope).
                    let _ = tx.send(point);
                }
            });
        }
        drop(tx);
        while let Ok(p) = rx.recv() {
            points.push(p);
        }
    });
    assert_eq!(points.len(), n_jobs, "every sweep job completed");

    points.sort_by(canonical_order);
    Sweep { points }
}

/// A *predicted* sweep: the same grid, but every point comes from the
/// prediction [`Engine`] instead of the simulator — the paper's value
/// proposition (profile once, predict everywhere) expressed in the
/// sweep's own shape, so Fig. 2-style speedup tables can be emitted
/// from predictions alone. `dram_txns` is 0 (predictions carry no
/// transaction counts); `l2_hr` echoes the profiled baseline counter.
pub fn predicted_sweep(
    engine: &Engine,
    profiles: &[Profile],
    pairs: &[(f64, f64)],
) -> Result<Sweep> {
    // The grid is shared by every profile: split it into frequency
    // slabs once and hand each profile to the engine's SoA slab path
    // ([`Engine::predict_slabs`]) instead of rebuilding pair tuples.
    let core: Vec<f64> = pairs.iter().map(|&(cf, _)| cf).collect();
    let mem: Vec<f64> = pairs.iter().map(|&(_, mf)| mf).collect();
    let mut points = Vec::with_capacity(profiles.len() * pairs.len());
    for p in profiles {
        let ests = engine.predict_slabs(&p.counters, &core, &mem)?;
        for (est, &(cf, mf)) in ests.iter().zip(pairs) {
            points.push(SweepPoint {
                kernel: p.kernel.clone(),
                core_mhz: cf,
                mem_mhz: mf,
                time_us: est.time_us,
                l2_hr: p.counters.l2_hr,
                dram_txns: 0,
            });
        }
    }
    points.sort_by(canonical_order);
    Ok(Sweep { points })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels;
    use crate::model::HwParams;

    #[test]
    fn sweep_covers_grid_in_order() {
        let spec = GpuSpec::default();
        let ks = vec![kernels::vector_add()];
        let pairs = vec![(400.0, 400.0), (400.0, 700.0), (700.0, 400.0)];
        let s = run_sweep(&spec, &ks, &pairs, 2);
        assert_eq!(s.points.len(), 3);
        assert_eq!(s.points[0].core_mhz, 400.0);
        assert_eq!(s.points[0].mem_mhz, 400.0);
        assert_eq!(s.points[2].mem_mhz, 400.0);
    }

    #[test]
    fn sweep_matches_direct_simulation() {
        let spec = GpuSpec::default();
        let k = kernels::transpose();
        let s = run_sweep(&spec, &[k.clone()], &[(500.0, 900.0)], 4);
        let direct = simulate(&spec, Clocks::new(500.0, 900.0), &k);
        assert_eq!(s.points[0].time_us, direct.stats.elapsed_ns / 1e3);
    }

    #[test]
    fn speedup_lookup() {
        let spec = GpuSpec::default();
        let ks = vec![kernels::vector_add()];
        let pairs = vec![(1000.0, 400.0), (1000.0, 1000.0)];
        let s = run_sweep(&spec, &ks, &pairs, 2);
        let sp = s.speedup("VA", (1000.0, 400.0), (1000.0, 1000.0)).unwrap();
        assert!(sp > 1.5, "{sp}");
        assert!(s.speedup("nope", (0.0, 0.0), (1.0, 1.0)).is_none());
    }

    #[test]
    fn single_worker_equals_many_workers() {
        let spec = GpuSpec::default();
        let ks = vec![kernels::scalar_prod()];
        let pairs = vec![(400.0, 1000.0), (800.0, 600.0)];
        let a = run_sweep(&spec, &ks, &pairs, 1);
        let b = run_sweep(&spec, &ks, &pairs, 8);
        for (x, y) in a.points.iter().zip(&b.points) {
            assert_eq!(x.time_us, y.time_us);
        }
    }

    #[test]
    fn order_is_deterministic_across_worker_counts() {
        // The mpsc completion order varies with thread interleaving;
        // the canonical sort must erase that entirely.
        let spec = GpuSpec::default();
        let ks = vec![kernels::transpose(), kernels::vector_add()];
        let pairs = vec![(400.0, 700.0), (1000.0, 400.0), (700.0, 700.0), (400.0, 400.0)];
        let a = run_sweep(&spec, &ks, &pairs, 1);
        let b = run_sweep(&spec, &ks, &pairs, 7);
        assert_eq!(a.points.len(), b.points.len());
        for (x, y) in a.points.iter().zip(&b.points) {
            assert_eq!(x.kernel, y.kernel);
            assert_eq!(x.core_mhz, y.core_mhz);
            assert_eq!(x.mem_mhz, y.mem_mhz);
            assert_eq!(x.time_us.to_bits(), y.time_us.to_bits());
            assert_eq!(x.dram_txns, y.dram_txns);
        }
        // And the canonical order itself holds.
        for w in a.points.windows(2) {
            assert!(canonical_order(&w[0], &w[1]) != std::cmp::Ordering::Greater);
        }
    }

    #[test]
    fn predicted_sweep_routes_through_engine() {
        let spec = GpuSpec::default();
        let k = kernels::vector_add();
        let profile = crate::profiler::profile(&spec, &k);
        let engine = Engine::native(HwParams::paper_defaults());
        let pairs = vec![(700.0, 700.0), (400.0, 1000.0)];
        let s = predicted_sweep(&engine, &[profile.clone()], &pairs).unwrap();
        assert_eq!(s.points.len(), 2);
        for p in &s.points {
            let want = crate::model::predict(
                &profile.counters,
                &HwParams::paper_defaults(),
                p.core_mhz,
                p.mem_mhz,
            );
            assert_eq!(p.time_us.to_bits(), want.time_us.to_bits());
        }
        // Cache warmed: a second predicted sweep is pure hits.
        predicted_sweep(&engine, &[profile], &pairs).unwrap();
        assert!(engine.cache_stats().hits >= 2);
    }
}
