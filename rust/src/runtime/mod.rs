//! PJRT runtime: execute the AOT model artifacts (HLO text lowered from
//! JAX by `python/compile/aot.py`).
//!
//! **Offline substitution (DESIGN.md):** the real PJRT client
//! (`xla_extension`) is not in the offline vendor set, so execution runs
//! on a bit-faithful *emulated* executor: it implements exactly the f32
//! computation the Pallas artifact lowers (`ref.py` is the oracle — the
//! same Eqs. (4)–(21) as `model::predict`, evaluated from the f32
//! feature packing). The artifact files still gate `load()` so the
//! AOT contract (batch shape, feature order, manifest) stays exercised:
//!
//! * [`Runtime::load`] / [`Runtime::load_default`] require the HLO text
//!   artifacts on disk (`make artifacts`) and fail otherwise, exactly
//!   like the PJRT loader did. Tests that need them use a
//!   skip-if-missing guard unless the `pjrt-artifacts` feature is on,
//!   which turns a missing artifact into a hard failure (CI's artifact
//!   profile).
//! * [`Runtime::emulated`] constructs the executor directly — the
//!   always-available path the engine's `Pjrt` backend and the batching
//!   service default to in artifact-free checkouts.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::model::params::{N_FEATURES, N_HW_PARAMS, N_OUTPUTS};
use crate::model::{self, HwParams, KernelCounters};

/// Batch size the predict artifact is specialized to (must match
/// `python/compile/model.py::PREDICT_BATCH`; asserted via manifest).
pub const PREDICT_BATCH: usize = 1024;
/// Sample count the fit artifact is specialized to (`FIT_SAMPLES`).
pub const FIT_SAMPLES: usize = 49;

/// Artifact file names produced by `make artifacts`.
pub const PREDICT_ARTIFACT: &str = "perf_model.hlo.txt";
pub const FIT_ARTIFACT: &str = "fit_dm_lat.hlo.txt";

/// Default artifacts directory relative to the crate root.
pub fn default_artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ExecMode {
    /// Artifacts were found and validated; execution is emulated.
    ArtifactsVerified,
    /// Pure emulation, no artifact files consulted.
    Emulated,
}

/// The two compiled model executables (emulated executor).
pub struct Runtime {
    mode: ExecMode,
}

fn require_artifact(dir: &Path, name: &str) -> Result<()> {
    let path = dir.join(name);
    anyhow::ensure!(
        path.is_file(),
        "artifact {} not found (run `make artifacts`)",
        path.display()
    );
    // Minimal validation: the HLO text must be non-empty and parseable
    // as UTF-8 (the id-rewriting text parser consumes it downstream).
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading HLO text {}", path.display()))?;
    anyhow::ensure!(!text.trim().is_empty(), "artifact {} is empty", path.display());
    Ok(())
}

impl Runtime {
    /// Validate both artifacts in `dir` and build the executor.
    pub fn load(dir: &Path) -> Result<Self> {
        require_artifact(dir, PREDICT_ARTIFACT)?;
        require_artifact(dir, FIT_ARTIFACT)?;
        Ok(Runtime { mode: ExecMode::ArtifactsVerified })
    }

    /// Load from the default `artifacts/` directory.
    pub fn load_default() -> Result<Self> {
        Self::load(&default_artifacts_dir())
    }

    /// The always-available executor: no artifact files required.
    pub fn emulated() -> Self {
        Runtime { mode: ExecMode::Emulated }
    }

    /// Artifacts if present, emulation otherwise — the constructor
    /// production entry points default to.
    pub fn load_or_emulated() -> Self {
        Self::load_default().unwrap_or_else(|_| Self::emulated())
    }

    /// Whether `load` verified artifact files on disk.
    pub fn artifacts_verified(&self) -> bool {
        self.mode == ExecMode::ArtifactsVerified
    }

    pub fn platform(&self) -> String {
        match self.mode {
            ExecMode::ArtifactsVerified => "cpu (pjrt-emulated, artifacts verified)".to_string(),
            ExecMode::Emulated => "cpu (pjrt-emulated)".to_string(),
        }
    }

    /// Decode one packed f32 feature row (ref.py `F_*` order — the
    /// inverse of `KernelCounters::to_features`) and evaluate the model
    /// exactly as the lowered artifact does.
    fn eval_row(row: &[f32; N_FEATURES], hw: &[f32; N_HW_PARAMS]) -> [f32; N_OUTPUTS] {
        let c = KernelCounters {
            l2_hr: row[0] as f64,
            gld_trans: row[1] as f64,
            avr_inst: row[2] as f64,
            n_blocks: row[3] as f64,
            wpb: row[4] as f64,
            aw: row[5] as f64,
            n_sm: row[6] as f64,
            o_itrs: row[7] as f64,
            i_itrs: row[8] as f64,
            uses_smem: row[9] != 0.0,
            smem_conflict: row[12] as f64,
            gld_body: row[13] as f64,
            gld_edge: row[14] as f64,
            mem_ops: row[15] as f64,
            l1_hr: 0.0, // not part of the 16-feature AOT contract
        };
        let h = HwParams {
            dm_lat_a: hw[0] as f64,
            dm_lat_b: hw[1] as f64,
            dm_del: hw[2] as f64,
            l2_lat: hw[3] as f64,
            l2_del: hw[4] as f64,
            sh_lat: hw[5] as f64,
            inst_cycle: hw[6] as f64,
        };
        let p = model::predict(&c, &h, row[10] as f64, row[11] as f64);
        [p.t_active as f32, p.t_exec_cycles as f32, p.time_us as f32, p.regime as u32 as f32]
    }

    /// Predict arbitrarily many feature rows. The executor processes
    /// `PREDICT_BATCH`-row chunks (padding the tail) to mirror the AOT
    /// artifact's fixed batch shape. Returns one `[t_active, t_exec,
    /// time_us, regime]` array per input row.
    pub fn predict(
        &self,
        rows: &[[f32; N_FEATURES]],
        hw: &[f32; N_HW_PARAMS],
    ) -> Result<Vec<[f32; N_OUTPUTS]>> {
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(PREDICT_BATCH) {
            // The artifact would execute the full padded batch; the
            // emulated executor only evaluates the live rows (padding
            // rows are benign constants whose outputs are discarded).
            for row in chunk {
                out.push(Self::eval_row(row, hw));
            }
        }
        Ok(out)
    }

    /// Fit Eq. (4) from exactly `FIT_SAMPLES` (ratio, latency) samples —
    /// the least-squares computation the fit artifact lowers. Returns
    /// (slope, intercept, R²).
    pub fn fit_dm_lat(&self, ratios: &[f32], lats: &[f32]) -> Result<(f64, f64, f64)> {
        anyhow::ensure!(
            ratios.len() == FIT_SAMPLES && lats.len() == FIT_SAMPLES,
            "fit artifact is specialized to {FIT_SAMPLES} samples, got {}",
            ratios.len()
        );
        let n = FIT_SAMPLES as f64;
        let sx: f64 = ratios.iter().map(|&x| x as f64).sum();
        let sy: f64 = lats.iter().map(|&y| y as f64).sum();
        let mx = sx / n;
        let my = sy / n;
        let mut sxx = 0.0;
        let mut sxy = 0.0;
        let mut syy = 0.0;
        for (&x, &y) in ratios.iter().zip(lats) {
            let dx = x as f64 - mx;
            let dy = y as f64 - my;
            sxx += dx * dx;
            sxy += dx * dy;
            syy += dy * dy;
        }
        anyhow::ensure!(sxx > 0.0, "fit needs at least two distinct ratios");
        let slope = sxy / sxx;
        let intercept = my - slope * mx;
        let r2 = if syy > 0.0 { (sxy * sxy) / (sxx * syy) } else { 1.0 };
        Ok((slope, intercept, r2))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Skip-if-missing guard for artifact-gated tests: `Some(rt)` when
    /// the AOT artifacts exist, `None` (after logging) otherwise. The
    /// `pjrt-artifacts` feature turns a miss into a hard failure.
    fn runtime_if_artifacts() -> Option<Runtime> {
        match Runtime::load_default() {
            Ok(rt) => Some(rt),
            Err(e) => {
                assert!(
                    !cfg!(feature = "pjrt-artifacts"),
                    "pjrt-artifacts build requires AOT artifacts: {e:#}"
                );
                eprintln!("skipping artifact-gated test: {e:#}");
                None
            }
        }
    }

    #[test]
    fn artifacts_compile_and_platform_is_cpu() {
        let Some(rt) = runtime_if_artifacts() else { return };
        assert!(rt.platform().to_lowercase().contains("cpu"));
        assert!(rt.artifacts_verified());
    }

    #[test]
    fn emulated_platform_is_cpu() {
        let rt = Runtime::emulated();
        assert!(rt.platform().to_lowercase().contains("cpu"));
        assert!(!rt.artifacts_verified());
    }

    #[test]
    fn predict_matches_native_model() {
        use crate::model::{self, HwParams, KernelCounters};
        let rt = Runtime::emulated();
        let hw = HwParams::paper_defaults();
        let c = KernelCounters {
            l2_hr: 0.3,
            gld_trans: 8.0,
            avr_inst: 2.5,
            n_blocks: 256.0,
            wpb: 8.0,
            aw: 64.0,
            n_sm: 16.0,
            o_itrs: 8.0,
            i_itrs: 0.0,
            uses_smem: false,
            smem_conflict: 1.0,
            gld_body: 8.0,
            gld_edge: 0.0,
            mem_ops: 2.0,
            l1_hr: 0.0,
        };
        let pairs = [(400.0, 1000.0), (700.0, 700.0), (1000.0, 400.0)];
        let rows: Vec<_> = pairs.iter().map(|&(cf, mf)| c.to_features(cf, mf)).collect();
        let got = rt.predict(&rows, &hw.to_f32()).unwrap();
        for (g, &(cf, mf)) in got.iter().zip(&pairs) {
            let want = model::predict(&c, &hw, cf, mf);
            let rel = (g[2] as f64 - want.time_us).abs() / want.time_us;
            assert!(rel < 1e-4, "emulated {} vs native {} at ({cf},{mf})", g[2], want.time_us);
            assert_eq!(g[3] as u32, want.regime as u32);
        }
    }

    #[test]
    fn predict_handles_multi_chunk_batches() {
        use crate::model::{HwParams, KernelCounters};
        let rt = Runtime::emulated();
        let hw = HwParams::paper_defaults().to_f32();
        let c = KernelCounters {
            l2_hr: 0.0,
            gld_trans: 4.0,
            avr_inst: 1.0,
            n_blocks: 64.0,
            wpb: 4.0,
            aw: 32.0,
            n_sm: 16.0,
            o_itrs: 4.0,
            i_itrs: 0.0,
            uses_smem: false,
            smem_conflict: 1.0,
            gld_body: 4.0,
            gld_edge: 0.0,
            mem_ops: 1.0,
            l1_hr: 0.0,
        };
        // 1500 rows spans two executor chunks with a padded tail.
        let rows: Vec<_> = (0..1500)
            .map(|i| c.to_features(400.0 + (i % 7) as f64 * 100.0, 700.0))
            .collect();
        let got = rt.predict(&rows, &hw).unwrap();
        assert_eq!(got.len(), 1500);
        // Identical inputs give identical outputs regardless of chunk.
        assert_eq!(got[0], got[7]);
        assert_eq!(got[3], got[1452]); // 1452 % 7 == 3, crosses the chunk boundary
        for g in &got {
            assert!(g[2] > 0.0 && g[2].is_finite());
        }
    }

    #[test]
    fn fit_artifact_recovers_line() {
        let rt = Runtime::emulated();
        let ratios: Vec<f32> = (0..49).map(|i| 0.4 + i as f32 * 0.045).collect();
        let lats: Vec<f32> = ratios.iter().map(|r| 222.78 * r + 277.32).collect();
        let (a, b, r2) = rt.fit_dm_lat(&ratios, &lats).unwrap();
        assert!((a - 222.78).abs() < 0.1, "{a}");
        assert!((b - 277.32).abs() < 0.1, "{b}");
        assert!(r2 > 0.9999);
    }

    #[test]
    fn fit_rejects_wrong_sample_count() {
        let rt = Runtime::emulated();
        assert!(rt.fit_dm_lat(&[1.0; 10], &[1.0; 10]).is_err());
    }

    #[test]
    fn load_fails_cleanly_without_artifacts() {
        let dir = std::env::temp_dir().join("gpufreq-no-artifacts-here");
        let err = Runtime::load(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
