//! PJRT runtime: load the AOT artifacts (HLO text lowered from JAX by
//! `python/compile/aot.py`) and execute them on the CPU PJRT client.
//!
//! Python never runs here — the artifacts are self-contained HLO. The
//! interchange format is HLO *text* (not serialized protos): jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::model::params::{N_FEATURES, N_HW_PARAMS, N_OUTPUTS};

/// Batch size the predict artifact is specialized to (must match
/// `python/compile/model.py::PREDICT_BATCH`; asserted via manifest).
pub const PREDICT_BATCH: usize = 1024;
/// Sample count the fit artifact is specialized to (`FIT_SAMPLES`).
pub const FIT_SAMPLES: usize = 49;

/// Artifact file names produced by `make artifacts`.
pub const PREDICT_ARTIFACT: &str = "perf_model.hlo.txt";
pub const FIT_ARTIFACT: &str = "fit_dm_lat.hlo.txt";

/// Default artifacts directory relative to the crate root.
pub fn default_artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// A PJRT CPU client with the two compiled model executables.
pub struct Runtime {
    client: xla::PjRtClient,
    predict_exe: xla::PjRtLoadedExecutable,
    fit_exe: xla::PjRtLoadedExecutable,
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("artifact path is not UTF-8")?,
    )
    .with_context(|| format!("parsing HLO text {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {}", path.display()))
}

impl Runtime {
    /// Create a CPU PJRT client and compile both artifacts from `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let predict_exe = compile(&client, &dir.join(PREDICT_ARTIFACT))?;
        let fit_exe = compile(&client, &dir.join(FIT_ARTIFACT))?;
        Ok(Runtime { client, predict_exe, fit_exe })
    }

    /// Load from the default `artifacts/` directory.
    pub fn load_default() -> Result<Self> {
        Self::load(&default_artifacts_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute one full batch: `features` is row-major
    /// (PREDICT_BATCH, N_FEATURES); returns (PREDICT_BATCH, N_OUTPUTS)
    /// row-major.
    fn execute_batch(&self, features: &[f32], hw: &[f32; N_HW_PARAMS]) -> Result<Vec<f32>> {
        debug_assert_eq!(features.len(), PREDICT_BATCH * N_FEATURES);
        let f = xla::Literal::vec1(features)
            .reshape(&[PREDICT_BATCH as i64, N_FEATURES as i64])
            .context("reshaping feature literal")?;
        let h = xla::Literal::vec1(hw.as_slice());
        let result = self
            .predict_exe
            .execute::<xla::Literal>(&[f, h])
            .context("executing perf_model")?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().context("unwrapping result tuple")?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Predict arbitrarily many feature rows, padding the tail chunk
    /// with benign rows. Returns one `[t_active, t_exec, time_us,
    /// regime]` array per input row.
    pub fn predict(
        &self,
        rows: &[[f32; N_FEATURES]],
        hw: &[f32; N_HW_PARAMS],
    ) -> Result<Vec<[f32; N_OUTPUTS]>> {
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(PREDICT_BATCH) {
            let mut flat = vec![1.0f32; PREDICT_BATCH * N_FEATURES];
            for (i, row) in chunk.iter().enumerate() {
                flat[i * N_FEATURES..(i + 1) * N_FEATURES].copy_from_slice(row);
            }
            let res = self.execute_batch(&flat, hw)?;
            for i in 0..chunk.len() {
                let mut r = [0f32; N_OUTPUTS];
                r.copy_from_slice(&res[i * N_OUTPUTS..(i + 1) * N_OUTPUTS]);
                out.push(r);
            }
        }
        Ok(out)
    }

    /// Fit Eq. (4) from exactly `FIT_SAMPLES` (ratio, latency) samples
    /// through the AOT fit artifact. Returns (slope, intercept, R²).
    pub fn fit_dm_lat(&self, ratios: &[f32], lats: &[f32]) -> Result<(f64, f64, f64)> {
        anyhow::ensure!(
            ratios.len() == FIT_SAMPLES && lats.len() == FIT_SAMPLES,
            "fit artifact is specialized to {FIT_SAMPLES} samples, got {}",
            ratios.len()
        );
        let x = xla::Literal::vec1(ratios);
        let y = xla::Literal::vec1(lats);
        let result = self
            .fit_exe
            .execute::<xla::Literal>(&[x, y])
            .context("executing fit_dm_lat")?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?.to_vec::<f32>()?;
        anyhow::ensure!(out.len() == 3, "fit output must be (3,)");
        Ok((out[0] as f64, out[1] as f64, out[2] as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests require `make artifacts` to have run; the Makefile's
    // `test` target guarantees that ordering.

    #[test]
    fn artifacts_compile_and_platform_is_cpu() {
        let rt = Runtime::load_default().expect("artifacts present (run `make artifacts`)");
        assert!(rt.platform().to_lowercase().contains("cpu"));
    }

    #[test]
    fn predict_matches_native_model() {
        use crate::model::{self, HwParams, KernelCounters};
        let rt = Runtime::load_default().unwrap();
        let hw = HwParams::paper_defaults();
        let c = KernelCounters {
            l2_hr: 0.3,
            gld_trans: 8.0,
            avr_inst: 2.5,
            n_blocks: 256.0,
            wpb: 8.0,
            aw: 64.0,
            n_sm: 16.0,
            o_itrs: 8.0,
            i_itrs: 0.0,
            uses_smem: false,
            smem_conflict: 1.0,
            gld_body: 8.0,
            gld_edge: 0.0,
            mem_ops: 2.0,
            l1_hr: 0.0,
        };
        let pairs = [(400.0, 1000.0), (700.0, 700.0), (1000.0, 400.0)];
        let rows: Vec<_> = pairs.iter().map(|&(cf, mf)| c.to_features(cf, mf)).collect();
        let got = rt.predict(&rows, &hw.to_f32()).unwrap();
        for (g, &(cf, mf)) in got.iter().zip(&pairs) {
            let want = model::predict(&c, &hw, cf, mf);
            let rel = (g[2] as f64 - want.time_us).abs() / want.time_us;
            assert!(rel < 1e-4, "pjrt {} vs native {} at ({cf},{mf})", g[2], want.time_us);
            assert_eq!(g[3] as u32, want.regime as u32);
        }
    }

    #[test]
    fn predict_handles_multi_chunk_batches() {
        use crate::model::{HwParams, KernelCounters};
        let rt = Runtime::load_default().unwrap();
        let hw = HwParams::paper_defaults().to_f32();
        let c = KernelCounters {
            l2_hr: 0.0,
            gld_trans: 4.0,
            avr_inst: 1.0,
            n_blocks: 64.0,
            wpb: 4.0,
            aw: 32.0,
            n_sm: 16.0,
            o_itrs: 4.0,
            i_itrs: 0.0,
            uses_smem: false,
            smem_conflict: 1.0,
            gld_body: 4.0,
            gld_edge: 0.0,
            mem_ops: 1.0,
            l1_hr: 0.0,
        };
        // 1500 rows spans two PJRT batches with a padded tail.
        let rows: Vec<_> = (0..1500)
            .map(|i| c.to_features(400.0 + (i % 7) as f64 * 100.0, 700.0))
            .collect();
        let got = rt.predict(&rows, &hw).unwrap();
        assert_eq!(got.len(), 1500);
        // Identical inputs give identical outputs regardless of chunk.
        assert_eq!(got[0], got[7]);
        assert_eq!(got[3], got[1452]); // 1452 % 7 == 3, crosses the chunk boundary
        for g in &got {
            assert!(g[2] > 0.0 && g[2].is_finite());
        }
    }

    #[test]
    fn fit_artifact_recovers_line() {
        let rt = Runtime::load_default().unwrap();
        let ratios: Vec<f32> = (0..49).map(|i| 0.4 + i as f32 * 0.045).collect();
        let lats: Vec<f32> = ratios.iter().map(|r| 222.78 * r + 277.32).collect();
        let (a, b, r2) = rt.fit_dm_lat(&ratios, &lats).unwrap();
        assert!((a - 222.78).abs() < 0.1, "{a}");
        assert!((b - 277.32).abs() < 0.1, "{b}");
        assert!(r2 > 0.9999);
    }

    #[test]
    fn fit_rejects_wrong_sample_count() {
        let rt = Runtime::load_default().unwrap();
        assert!(rt.fit_dm_lat(&[1.0; 10], &[1.0; 10]).is_err());
    }
}
