//! Report emitters: one function per paper table/figure, producing both
//! human-readable ASCII and machine-readable CSV (DESIGN.md §5 maps
//! each experiment id to its emitter).

pub mod tables;

use std::fmt::Write as _;

/// A simple column-aligned ASCII table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as aligned ASCII.
    pub fn ascii(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.title);
        let line: String =
            w.iter().map(|n| "-".repeat(n + 2)).collect::<Vec<_>>().join("+");
        let _ = writeln!(out, "+{line}+");
        let fmt_row = |cells: &[String]| -> String {
            let body = cells
                .iter()
                .zip(&w)
                .map(|(c, n)| format!(" {c:>n$} "))
                .collect::<Vec<_>>()
                .join("|");
            format!("|{body}|")
        };
        let _ = writeln!(out, "{}", fmt_row(&self.header));
        let _ = writeln!(out, "+{line}+");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row));
        }
        let _ = writeln!(out, "+{line}+");
        out
    }

    /// Render as CSV (header + rows).
    pub fn csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.header.iter().map(esc).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(esc).collect::<Vec<_>>().join(","));
        }
        out
    }
}

/// An ASCII horizontal bar chart (for Fig. 14-style per-kernel bars).
pub fn bar_chart(title: &str, items: &[(String, f64)], unit: &str, width: usize) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "{title}");
    let max = items.iter().map(|(_, v)| *v).fold(0.0f64, f64::max).max(1e-12);
    let name_w = items.iter().map(|(n, _)| n.len()).max().unwrap_or(4);
    for (name, v) in items {
        let n = ((v / max) * width as f64).round() as usize;
        let _ = writeln!(out, "{name:>name_w$} | {:<width$} {v:.2}{unit}", "#".repeat(n));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["10".into(), "20000".into()]);
        let s = t.ascii();
        assert!(s.contains("T\n"));
        assert!(s.lines().count() >= 6);
        // All body lines same width.
        let widths: Vec<usize> = s.lines().skip(1).map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn row_arity_checked() {
        Table::new("x", &["a", "b"]).row(vec!["1".into()]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    fn bar_chart_scales() {
        let s = bar_chart(
            "B",
            &[("k1".to_string(), 2.0), ("k2".to_string(), 4.0)],
            "%",
            10,
        );
        assert!(s.contains("##########")); // max bar is full width
        assert!(s.contains("#####"));
    }
}
