//! Per-experiment emitters (DESIGN.md §5): each regenerates one table
//! or figure from the paper, printing the same rows/series the paper
//! reports.

use crate::baselines::Predictor;
use crate::coordinator::sweep::Sweep;
use crate::coordinator::validate::Validation;
use crate::model::HwParams;
use crate::microbench::{self, BandwidthProbe};
use crate::profiler::Profile;
use crate::sim::engine::{Engine, SampleCfg};
use crate::sim::isa::{Addressing, Kernel, Launch, MemPat, Op, Program};
use crate::sim::{Clocks, GpuSpec};

use super::{bar_chart, Table};

/// Table I: component → dominating frequency domain (static knowledge
/// the simulator implements; emitted for completeness).
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table I: dominating frequency for different components",
        &["Component", "Dominating frequency"],
    );
    for (c, f) in [
        ("DRAM", "memory frequency"),
        ("L2 Cache", "core frequency"),
        ("Shared Memory", "core frequency"),
        ("Texture Cache", "core frequency"),
        ("Register", "core frequency"),
    ] {
        t.row(vec![c.into(), f.into()]);
    }
    t
}

/// Table II: minimum DRAM latency vs frequency, measured by the P-chase
/// probe, plus the Eq. (4) fit line.
pub fn table2(spec: &GpuSpec) -> (Table, String) {
    let pairs: Vec<(f64, f64)> = (4..=10).map(|i| (i as f64 * 100.0, i as f64 * 100.0)).collect();
    let mut t = Table::new(
        "Table II: minimum DRAM latency under different frequencies (measured)",
        &["Memory MHz", "Core MHz", "Cycles"],
    );
    for &(cf, mf) in &pairs {
        let lat = microbench::dram_latency_probe(spec, Clocks::new(cf, mf));
        t.row(vec![format!("{mf:.0}"), format!("{cf:.0}"), format!("{lat:.1}")]);
    }
    // Fit over the full 49-pair grid, like the paper's Eq. (4).
    let (ratios, lats) = microbench::dm_lat_sweep(spec, &microbench::standard_grid());
    let fit = crate::model::fit::fit_line(&ratios, &lats);
    let note = format!(
        "Eq. (4) fit: dm_lat = {:.2} * (core_f/mem_f) + {:.2}   (R^2 = {:.4}; paper: 222.78/277.32, R^2 0.9959)\n\
         NOTE (DESIGN.md #2): the paper's printed Table II decreases along the equal-frequency diagonal,\n\
         which contradicts its own Eq. (4); our substrate implements Eq. (4), so the diagonal is flat and\n\
         the latency-vs-ratio behaviour (the quantity the model consumes) matches the paper's fit exactly.",
        fit.slope, fit.intercept, fit.r_squared
    );
    (t, note)
}

/// Table III: DRAM read delay + bandwidth efficiency vs frequency.
pub fn table3(spec: &GpuSpec) -> Table {
    let mut t = Table::new(
        "Table III: DRAM read delay under different frequencies (measured)",
        &["Memory MHz", "Core MHz", "dm_del (mem cycles)", "Bandwidth efficiency"],
    );
    for i in 4..=10 {
        let f = i as f64 * 100.0;
        let bw: BandwidthProbe = microbench::bandwidth_probe(spec, Clocks::new(f, f));
        t.row(vec![
            format!("{f:.0}"),
            format!("{f:.0}"),
            format!("{:.2}", bw.dm_del_mem_cycles),
            format!("{:.1}%", bw.efficiency * 100.0),
        ]);
    }
    t
}

/// Fig. 2: speedup series for the six motivation kernels. `fixed_core`
/// selects panels (a)/(b) (sweep memory) vs (c)/(d) (sweep core).
pub fn fig2(sweep: &Sweep, kernels: &[Kernel], fixed_mhz: f64, sweep_memory: bool) -> Table {
    let (title, sweep_label) = if sweep_memory {
        let t = format!("Fig. 2: speedup vs memory frequency (core fixed at {fixed_mhz:.0} MHz)");
        (t, "Mem MHz")
    } else {
        let t = format!("Fig. 2: speedup vs core frequency (memory fixed at {fixed_mhz:.0} MHz)");
        (t, "Core MHz")
    };
    let mut header = vec![sweep_label.to_string()];
    header.extend(kernels.iter().map(|k| k.name.clone()));
    let mut t = Table { title, header: header.clone(), rows: Vec::new() };
    for i in 4..=10 {
        let f = i as f64 * 100.0;
        let mut row = vec![format!("{f:.0}")];
        for k in kernels {
            let (from, to) = if sweep_memory {
                ((fixed_mhz, 400.0), (fixed_mhz, f))
            } else {
                ((400.0, fixed_mhz), (f, fixed_mhz))
            };
            let sp = sweep.speedup(&k.name, from, to).unwrap_or(f64::NAN);
            row.push(format!("{sp:.2}x"));
        }
        t.rows.push(row);
    }
    t
}

/// Fig. 5: per-warp memory latency under an intensive workload —
/// (a) samples ordered by issue time, (b) latencies sorted ascending.
pub fn fig5(spec: &GpuSpec, clocks: Clocks, max_samples: usize) -> (Table, Table) {
    let kernel = Kernel::new(
        "fig5-probe",
        Launch::new(spec.n_sm * 4, 256),
        Program {
            prologue: vec![],
            body: vec![Op::Load(MemPat::new(4, Addressing::OwnLinear, 9))],
            o_itrs: 8,
            epilogue: vec![],
        },
    );
    let r = Engine::new(spec.clone(), clocks, &kernel)
        .with_samples(SampleCfg { max_samples })
        .run();
    let mut samples = r.stats.latency_samples.clone();

    samples.sort_by(|a, b| a.issue_ns.total_cmp(&b.issue_ns));
    let mut by_issue = Table::new(
        "Fig. 5a: first-request latency by issue order (cycles @ core clock)",
        &["#", "warp", "issue (ns)", "latency (core cycles)"],
    );
    for (i, s) in samples.iter().enumerate().step_by((samples.len() / 32).max(1)) {
        by_issue.row(vec![
            format!("{i}"),
            format!("{}", s.warp),
            format!("{:.1}", s.issue_ns),
            format!("{:.0}", s.latency_ns * clocks.core_mhz / 1e3),
        ]);
    }

    samples.sort_by(|a, b| a.latency_ns.total_cmp(&b.latency_ns));
    let mut sorted = Table::new(
        "Fig. 5b: per-warp latency, ascending (queueing ramp)",
        &["rank", "latency (core cycles)"],
    );
    for (i, s) in samples.iter().enumerate().step_by((samples.len() / 32).max(1)) {
        sorted.row(vec![format!("{i}"), format!("{:.0}", s.latency_ns * clocks.core_mhz / 1e3)]);
    }
    (by_issue, sorted)
}

/// Fig. 12: instruction-type breakdown per kernel.
pub fn fig12(profiles: &[Profile]) -> Table {
    let mut t = Table::new(
        "Fig. 12: breakdown of instruction types (dynamic, % of warp instructions)",
        &["Kernel", "Compute", "Global", "Shared", "Sync"],
    );
    for p in profiles {
        let m = p.mix_breakdown();
        t.row(vec![
            p.kernel.clone(),
            format!("{:.1}%", m.compute * 100.0),
            format!("{:.1}%", m.global * 100.0),
            format!("{:.1}%", m.shared * 100.0),
            format!("{:.1}%", m.sync * 100.0),
        ]);
    }
    t
}

/// Fig. 13: signed prediction error while sweeping one domain with the
/// other fixed (panels a-d of the paper).
pub fn fig13(v: &Validation, fixed_core: Option<f64>, fixed_mem: Option<f64>) -> Table {
    let (title, label) = match (fixed_core, fixed_mem) {
        (Some(cf), None) => {
            (format!("Fig. 13: error vs memory frequency (core = {cf:.0} MHz)"), "Mem MHz")
        }
        (None, Some(mf)) => {
            (format!("Fig. 13: error vs core frequency (memory = {mf:.0} MHz)"), "Core MHz")
        }
        _ => panic!("fix exactly one domain"),
    };
    let mut header = vec![label.to_string()];
    header.extend(v.per_kernel.iter().map(|k| k.kernel.clone()));
    let mut t = Table { title, header, rows: Vec::new() };
    for i in 4..=10 {
        let f = i as f64 * 100.0;
        let mut row = vec![format!("{f:.0}")];
        for k in &v.per_kernel {
            let p = k.points.iter().find(|p| match (fixed_core, fixed_mem) {
                (Some(cf), None) => p.core_mhz == cf && p.mem_mhz == f,
                (None, Some(mf)) => p.mem_mhz == mf && p.core_mhz == f,
                _ => unreachable!(),
            });
            row.push(match p {
                Some(p) => format!("{:+.1}%", p.signed_err() * 100.0),
                None => "-".to_string(),
            });
        }
        t.rows.push(row);
    }
    t
}

/// Fig. 14: per-kernel MAPE bars + the overall headline.
pub fn fig14(v: &Validation) -> (String, Table) {
    let items: Vec<(String, f64)> =
        v.per_kernel.iter().map(|k| (k.kernel.clone(), k.mape() * 100.0)).collect();
    let chart = bar_chart(
        "Fig. 14: mean absolute percentage error across all frequency pairs",
        &items,
        "%",
        48,
    );
    let mut t = Table::new("Fig. 14 summary", &["Metric", "Value", "Paper"]);
    t.row(vec![
        "overall MAPE".into(),
        format!("{:.2}%", v.overall_mape() * 100.0),
        "3.5%".into(),
    ]);
    t.row(vec![
        "per-kernel MAPE range".into(),
        format!(
            "{:.1}% - {:.1}%",
            items.iter().map(|(_, v)| *v).fold(f64::INFINITY, f64::min),
            items.iter().map(|(_, v)| *v).fold(0.0, f64::max)
        ),
        "0.7% - 6.9%".into(),
    ]);
    t.row(vec![
        "samples under 10% error".into(),
        format!("{:.0}%", v.fraction_below(0.10) * 100.0),
        "90%".into(),
    ]);
    t.row(vec![
        "max single error".into(),
        format!("{:.1}%", v.max_abs_err() * 100.0),
        "<16%".into(),
    ]);
    (chart, t)
}

/// Table VI: the workload list.
pub fn table6(kernels: &[Kernel]) -> Table {
    let mut t = Table::new(
        "Table VI: tested applications",
        &["abbr.", "blocks", "threads/block", "o_itrs", "uses smem"],
    );
    for k in kernels {
        t.row(vec![
            k.name.clone(),
            format!("{}", k.launch.blocks),
            format!("{}", k.launch.threads_per_block),
            format!("{}", k.program.o_itrs),
            format!("{}", k.program.uses_smem()),
        ]);
    }
    t
}

/// Ablation: MAPE per predictor (paper model vs baselines).
pub fn ablation(rows: &[(String, f64, f64)]) -> Table {
    let mut t = Table::new(
        "Ablation: predictor MAPE over the full grid",
        &["Predictor", "MAPE", "max error"],
    );
    for (name, mape, max) in rows {
        t.row(vec![name.clone(), format!("{:.2}%", mape * 100.0), format!("{:.1}%", max * 100.0)]);
    }
    t
}

/// Predictor-vs-predictor convenience for the ablation bench/CLI.
/// Every predictor runs behind the engine facade
/// (`Predictor` → `Backend` adapter), so each gets its own grid cache
/// and the same batched prediction path as the production model.
/// `hw` is the calibration the predictors were built with (it seeds
/// each engine's cache key and `Engine::hw()` reporting).
pub fn run_ablation(
    spec: &GpuSpec,
    kernels: &[Kernel],
    hw: HwParams,
    predictors: Vec<Box<dyn Predictor>>,
    pairs: &[(f64, f64)],
) -> Vec<(String, f64, f64)> {
    predictors
        .into_iter()
        .map(|p| {
            let name = p.name().to_string();
            let engine = crate::engine::Engine::from_predictor(hw, p);
            let v =
                crate::coordinator::validate::validate_with_engine(spec, kernels, &engine, pairs)
                    .expect("native ablation backends are infallible");
            (name, v.overall_mape(), v.max_abs_err())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels;
    use crate::profiler;

    #[test]
    fn table1_is_static() {
        let t = table1();
        assert_eq!(t.rows.len(), 5);
        assert!(t.ascii().contains("memory frequency"));
    }

    #[test]
    fn table2_tracks_eq4_fit() {
        let spec = GpuSpec::default();
        let (t, note) = table2(&spec);
        assert_eq!(t.rows.len(), 7);
        assert!(note.contains("R^2"));
    }

    #[test]
    fn fig5_produces_monotone_sorted_panel() {
        let spec = GpuSpec::default();
        let (a, b) = fig5(&spec, Clocks::new(700.0, 700.0), 512);
        assert!(!a.rows.is_empty());
        let lats: Vec<f64> =
            b.rows.iter().map(|r| r[1].parse::<f64>().unwrap()).collect();
        assert!(lats.windows(2).all(|w| w[0] <= w[1]));
        // Queueing diversity: max latency well above the unloaded Eq. (4).
        assert!(lats.last().unwrap() / lats.first().unwrap() > 1.5);
    }

    #[test]
    fn fig12_covers_all_kernels() {
        let spec = GpuSpec::default();
        let profiles: Vec<_> =
            kernels::all().iter().map(|k| profiler::profile(&spec, k)).collect();
        let t = fig12(&profiles);
        assert_eq!(t.rows.len(), 12);
        // SN is smem-heavy; VA is global-heavy.
        let sn = t.rows.iter().find(|r| r[0] == "SN").unwrap();
        let va = t.rows.iter().find(|r| r[0] == "VA").unwrap();
        let pct = |s: &str| s.trim_end_matches('%').parse::<f64>().unwrap();
        assert!(pct(&sn[3]) > 20.0, "SN shared {}", sn[3]);
        assert!(pct(&va[2]) > 35.0, "VA global {}", va[2]);
    }

    #[test]
    fn table6_lists_twelve() {
        assert_eq!(table6(&kernels::all()).rows.len(), 12);
    }
}
