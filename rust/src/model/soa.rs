//! Struct-of-arrays batch evaluation of the analytical model.
//!
//! The scalar reference ([`super::predict`]) walks `KernelCounters` /
//! `HwParams` structs per point. Every consumer that matters — the
//! planner's K×D×P candidate table, `/v2/predict` batches, grid sweeps
//! — evaluates *one* (device, kernel) pair over *many* frequency
//! points, so all counter-derived subexpressions are loop-invariant.
//! [`SoaKernel::new`] hoists them once; [`SoaKernel::fill`] then runs a
//! tight loop over frequency slabs (`&[f64]` core / `&[f64]` mem) with
//! no struct indirection, branch-minimal regime selection (all
//! candidate times are computed, then selected), and slab outputs.
//!
//! **Bit-identity contract**: only subexpressions whose floating-point
//! expression *tree* is unchanged are hoisted (e.g. `l2_lat * l2_hr` is
//! computed once; `(a*r + b) * m` is *never* reassociated into an
//! affine form). Every per-point expression below reproduces the exact
//! association order of the scalar code, so outputs are bit-for-bit
//! equal to [`super::predict`] — not merely within an ULP. The property
//! test `tests/model_soa.rs` asserts `to_bits()` equality across all
//! six regimes.

use super::{HwParams, KernelCounters, Prediction, Regime};

/// Output slabs for one `(kernel, device)` pair over a frequency slab.
#[derive(Debug, Clone, Default)]
pub struct SlabOut {
    /// Cycles for one round of active warps (`T_active`).
    pub t_active: Vec<f64>,
    /// Total kernel cycles in the core domain (`T_exec`).
    pub t_exec_cycles: Vec<f64>,
    /// Wall-clock microseconds at the point's core frequency.
    pub time_us: Vec<f64>,
    /// Selected pipeline regime per point.
    pub regime: Vec<Regime>,
}

impl SlabOut {
    /// Pre-size all four slabs for `n` points.
    pub fn with_capacity(n: usize) -> SlabOut {
        SlabOut {
            t_active: Vec::with_capacity(n),
            t_exec_cycles: Vec::with_capacity(n),
            time_us: Vec::with_capacity(n),
            regime: Vec::with_capacity(n),
        }
    }

    /// Number of evaluated points.
    pub fn len(&self) -> usize {
        self.t_active.len()
    }

    /// True when no points have been evaluated.
    pub fn is_empty(&self) -> bool {
        self.t_active.is_empty()
    }

    /// Reassemble point `i` as a scalar [`Prediction`].
    pub fn get(&self, i: usize) -> Prediction {
        Prediction {
            t_active: self.t_active[i],
            t_exec_cycles: self.t_exec_cycles[i],
            time_us: self.time_us[i],
            regime: self.regime[i],
        }
    }

    fn clear_and_reserve(&mut self, n: usize) {
        self.t_active.clear();
        self.t_exec_cycles.clear();
        self.time_us.clear();
        self.regime.clear();
        self.t_active.reserve(n);
        self.t_exec_cycles.reserve(n);
        self.time_us.reserve(n);
        self.regime.reserve(n);
    }
}

/// All per-kernel loop invariants of Eqs. (4)–(21), hoisted once.
///
/// Fields mirror the scalar code's intermediates; names note the
/// originating expression. Everything that depends on the frequency
/// ratio stays in the per-point loop.
#[derive(Debug, Clone, Copy)]
pub struct SoaKernel {
    // Eq. (4)/(5) frequency-dependent terms' constant factors.
    dm_lat_a: f64,
    dm_lat_b: f64,
    dm_del: f64,
    /// `1.0 - l2_hr`
    miss: f64,
    /// `l2_lat * l2_hr` (Eq. 5a hit half)
    l2h_lat: f64,
    /// `l2_del * l2_hr` (Eq. 5b hit half)
    l2h_del: f64,
    /// `inst_cycle * avr_inst` (Eq. 7b)
    avr_comp: f64,
    /// `avr_comp * gld_trans` ("C" per body iteration)
    comp_iter: f64,
    gld_trans: f64,
    aw: f64,
    o: f64,
    /// `aw - 1.0`
    aw1: f64,
    /// `o - 1.0`
    o1: f64,
    /// `mem_ops.max(1.0)`
    mo: f64,
    /// `comp_iter * (aw - 1.0)` (Eq. 15 head / Eq. 12 condition)
    caw1: f64,
    /// `comp_iter * aw * o` (Eq. 9 head)
    ciawo: f64,
    // Shared-memory path invariants.
    uses_smem: bool,
    gld_body: f64,
    gld_edge: f64,
    sh_lat: f64,
    /// `avr_comp + sh_lat` (Eq. 16 condition LHS)
    acs: f64,
    /// `aw - wpb` (Eq. 16 condition window)
    awpb: f64,
    /// `max(comp_iter * aw, i_itrs * smem_conflict * aw)` (Eq. 19)
    ap: f64,
    /// `sh_lat * i_itrs` (Eq. 19 latency chain)
    chain: f64,
    /// `(wpb * n_blocks / (aw * n_sm)).max(1.0)` (Eq. 6)
    rounds: f64,
}

impl SoaKernel {
    /// Hoist every counter-only subexpression of the model.
    pub fn new(c: &KernelCounters, hw: &HwParams) -> SoaKernel {
        let avr_comp = hw.inst_cycle * c.avr_inst;
        let comp_iter = avr_comp * c.gld_trans;
        let aw = c.aw;
        let o = c.o_itrs;
        let alu = comp_iter * aw;
        let port = c.i_itrs * c.smem_conflict * aw;
        SoaKernel {
            dm_lat_a: hw.dm_lat_a,
            dm_lat_b: hw.dm_lat_b,
            dm_del: hw.dm_del,
            miss: 1.0 - c.l2_hr,
            l2h_lat: hw.l2_lat * c.l2_hr,
            l2h_del: hw.l2_del * c.l2_hr,
            avr_comp,
            comp_iter,
            gld_trans: c.gld_trans,
            aw,
            o,
            aw1: aw - 1.0,
            o1: o - 1.0,
            mo: c.mem_ops.max(1.0),
            caw1: comp_iter * (aw - 1.0),
            ciawo: comp_iter * aw * o,
            uses_smem: c.uses_smem,
            gld_body: c.gld_body,
            gld_edge: c.gld_edge,
            sh_lat: hw.sh_lat,
            acs: avr_comp + hw.sh_lat,
            awpb: aw - c.wpb,
            ap: alu.max(port),
            chain: hw.sh_lat * c.i_itrs,
            rounds: (c.wpb * c.n_blocks / (aw * c.n_sm)).max(1.0),
        }
    }

    /// Evaluate the slab, appending to `out` (cleared first).
    ///
    /// Panics if the slabs differ in length or any frequency is not
    /// strictly positive (same contract as the scalar `predict`).
    pub fn fill(&self, core_mhz: &[f64], mem_mhz: &[f64], out: &mut SlabOut) {
        assert_eq!(
            core_mhz.len(),
            mem_mhz.len(),
            "core and mem frequency slabs must have equal length"
        );
        // Validate up front so the hot loop carries no panic edges.
        for (&cf, &mf) in core_mhz.iter().zip(mem_mhz) {
            assert!(cf > 0.0 && mf > 0.0);
        }
        out.clear_and_reserve(core_mhz.len());
        if self.uses_smem {
            self.fill_smem(core_mhz, mem_mhz, out);
        } else {
            self.fill_plain(core_mhz, mem_mhz, out);
        }
    }

    /// Convenience wrapper allocating a fresh [`SlabOut`].
    pub fn predict(&self, core_mhz: &[f64], mem_mhz: &[f64]) -> SlabOut {
        let mut out = SlabOut::with_capacity(core_mhz.len());
        self.fill(core_mhz, mem_mhz, &mut out);
        out
    }

    /// Eqs. (9)/(11)/(13)/(15): the four non-smem pipeline cases. All
    /// candidate times are computed unconditionally so the compiler can
    /// lower the selection to branchless `select`s and vectorize.
    fn fill_plain(&self, core_mhz: &[f64], mem_mhz: &[f64], out: &mut SlabOut) {
        let s = self;
        for (&cf, &mf) in core_mhz.iter().zip(mem_mhz) {
            let ratio = cf / mf;
            let dm_lat = s.dm_lat_a * ratio + s.dm_lat_b; // Eq. (4)
            let agl_lat = s.l2h_lat + dm_lat * s.miss; // Eq. (5a)
            let agl_del = s.l2h_del + s.dm_del * ratio * s.miss; // Eq. (5b)
            let q = agl_del * s.gld_trans;
            let lat_iter = agl_lat * s.mo;
            // Candidates (exact scalar expression trees).
            let t_compute = s.ciawo + agl_lat; // Eq. (9)
            let t_few_long = s.caw1 + (s.comp_iter + lat_iter) * s.o; // Eq. (15)
            let t_memory = agl_lat + s.comp_iter + q * s.aw * s.o; // Eq. (11)
            let t_few_short =
                q * s.aw + agl_lat + s.comp_iter + (s.comp_iter + lat_iter) * s.o1; // Eq. (13)
            // Conditions (Eq. 8/12 and the corrected 10b/12b direction).
            let long = s.avr_comp >= agl_del;
            let hidden = s.caw1 >= lat_iter;
            let saturated = (s.comp_iter + agl_lat) <= q * s.aw1;
            let (t_active, regime) = if long {
                if hidden {
                    (t_compute, Regime::Compute)
                } else {
                    (t_few_long, Regime::FewWarpsLongCompute)
                }
            } else if saturated {
                (t_memory, Regime::Memory)
            } else {
                (t_few_short, Regime::FewWarpsShortCompute)
            };
            let t_exec = t_active * s.rounds; // Eq. (6)
            out.t_active.push(t_active);
            out.t_exec_cycles.push(t_exec);
            out.time_us.push(t_exec / cf);
            out.regime.push(regime);
        }
    }

    /// Eqs. (16)–(21): the two shared-memory pipeline cases.
    fn fill_smem(&self, core_mhz: &[f64], mem_mhz: &[f64], out: &mut SlabOut) {
        let s = self;
        for (&cf, &mf) in core_mhz.iter().zip(mem_mhz) {
            let ratio = cf / mf;
            let dm_lat = s.dm_lat_a * ratio + s.dm_lat_b; // Eq. (4)
            let agl_lat = s.l2h_lat + dm_lat * s.miss; // Eq. (5a)
            let agl_del = s.l2h_del + s.dm_del * ratio * s.miss; // Eq. (5b)
            let q = agl_del * s.gld_trans;
            let q_body = agl_del * s.gld_body;
            let t_light = s.comp_iter + agl_lat + q * s.aw * s.o; // Eq. (17)
            let mem_iter = q_body * s.aw; // Eq. (20)
            let body = (s.ap.max(mem_iter) + s.chain) * s.o; // Eq. (19)
            let edge = agl_del * s.gld_edge * s.aw; // Eq. (18)
            let t_intense = body.max(edge) + agl_lat + s.sh_lat; // Eq. (21)
            let light = s.avr_comp <= agl_del && s.acs < q_body * s.awpb; // Eq. (16)
            let (t_active, regime) = if light {
                (t_light, Regime::SmemLight)
            } else {
                (t_intense, Regime::SmemIntense)
            };
            let t_exec = t_active * s.rounds; // Eq. (6)
            out.t_active.push(t_active);
            out.t_exec_cycles.push(t_exec);
            out.time_us.push(t_exec / cf);
            out.regime.push(regime);
        }
    }
}

/// One-shot slab evaluation: hoist invariants, evaluate, return slabs.
pub fn predict_slab(
    c: &KernelCounters,
    hw: &HwParams,
    core_mhz: &[f64],
    mem_mhz: &[f64],
) -> SlabOut {
    SoaKernel::new(c, hw).predict(core_mhz, mem_mhz)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model;

    fn counters() -> KernelCounters {
        KernelCounters {
            l2_hr: 0.2,
            gld_trans: 4.0,
            avr_inst: 20.0,
            n_blocks: 128.0,
            wpb: 8.0,
            aw: 32.0,
            n_sm: 16.0,
            o_itrs: 16.0,
            i_itrs: 0.0,
            uses_smem: false,
            smem_conflict: 1.0,
            gld_body: 4.0,
            gld_edge: 0.0,
            mem_ops: 1.0,
            l1_hr: 0.0,
        }
    }

    #[test]
    fn slab_matches_scalar_bit_for_bit_on_a_grid() {
        let c = counters();
        let hw = HwParams::paper_defaults();
        let mut core = Vec::new();
        let mut mem = Vec::new();
        for ci in 0..13 {
            for mi in 0..13 {
                core.push(400.0 + 75.0 * ci as f64);
                mem.push(300.0 + 60.0 * mi as f64);
            }
        }
        let slab = predict_slab(&c, &hw, &core, &mem);
        assert_eq!(slab.len(), core.len());
        for i in 0..core.len() {
            let want = model::predict(&c, &hw, core[i], mem[i]);
            assert_eq!(slab.t_active[i].to_bits(), want.t_active.to_bits());
            assert_eq!(slab.t_exec_cycles[i].to_bits(), want.t_exec_cycles.to_bits());
            assert_eq!(slab.time_us[i].to_bits(), want.time_us.to_bits());
            assert_eq!(slab.regime[i], want.regime);
            assert_eq!(slab.get(i), want);
        }
    }

    #[test]
    fn smem_slab_matches_scalar() {
        let c = KernelCounters {
            uses_smem: true,
            avr_inst: 40.0,
            i_itrs: 32.0,
            aw: 16.0,
            gld_body: 4.0,
            gld_edge: 2.0,
            ..counters()
        };
        let hw = HwParams::paper_defaults();
        let core = [400.0, 700.0, 1000.0, 1300.0];
        let mem = [500.0, 500.0, 900.0, 300.0];
        let slab = predict_slab(&c, &hw, &core, &mem);
        for i in 0..core.len() {
            let want = model::predict(&c, &hw, core[i], mem[i]);
            assert_eq!(slab.time_us[i].to_bits(), want.time_us.to_bits());
            assert_eq!(slab.regime[i], want.regime);
        }
    }

    #[test]
    fn empty_slab_is_fine() {
        let slab = predict_slab(&counters(), &HwParams::paper_defaults(), &[], &[]);
        assert!(slab.is_empty());
        assert_eq!(slab.len(), 0);
    }

    #[test]
    #[should_panic]
    fn mismatched_slab_lengths_panic() {
        predict_slab(&counters(), &HwParams::paper_defaults(), &[700.0], &[]);
    }

    #[test]
    #[should_panic]
    fn nonpositive_frequency_panics_like_scalar() {
        predict_slab(&counters(), &HwParams::paper_defaults(), &[0.0], &[700.0]);
    }
}
