//! Model parameters (the paper's Table IV), plus packing into the
//! feature layout the AOT Pallas artifact expects.
//!
//! The feature/parameter column order is the contract with
//! `python/compile/kernels/ref.py` (`F_*` / `H_*` constants) and is
//! additionally carried in `artifacts/manifest.json`.

/// Hardware parameters, extracted once by micro-benchmarks (§IV).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HwParams {
    /// Eq. (4) slope: memory-clocked DRAM segment, core cycles per cf/mf.
    pub dm_lat_a: f64,
    /// Eq. (4) intercept: core-clocked path segment, core cycles.
    pub dm_lat_b: f64,
    /// DRAM service per transaction (per-SM channel), memory cycles.
    pub dm_del: f64,
    /// L2 hit latency, core cycles.
    pub l2_lat: f64,
    /// L2 service per transaction, core cycles.
    pub l2_del: f64,
    /// Shared-memory latency, core cycles.
    pub sh_lat: f64,
    /// Cycles per compute instruction (`inst_cycle`, Table IV).
    pub inst_cycle: f64,
}

impl HwParams {
    /// The constants the paper reports for its GTX 980 (Eq. 4, §IV-B/C),
    /// which are also the defaults `GpuSpec` is calibrated to.
    pub fn paper_defaults() -> Self {
        HwParams {
            dm_lat_a: 222.78,
            dm_lat_b: 277.32,
            dm_del: 9.0,
            l2_lat: 222.0,
            l2_del: 1.0,
            sh_lat: 28.0,
            inst_cycle: 2.0,
        }
    }

    /// Pack into the artifact's (7,) f32 layout (ref.py `H_*` order).
    pub fn to_f32(&self) -> [f32; 7] {
        [
            self.dm_lat_a as f32,
            self.dm_lat_b as f32,
            self.dm_del as f32,
            self.l2_lat as f32,
            self.l2_del as f32,
            self.sh_lat as f32,
            self.inst_cycle as f32,
        ]
    }
}

/// Per-kernel performance counters, collected once at the baseline
/// frequency by the profiler (the paper's Nsight pass, Table IV).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelCounters {
    /// L2 hit rate over all global transactions (`l2_hr`).
    pub l2_hr: f64,
    /// Global transactions per warp per outer iteration (`gld_trans`).
    pub gld_trans: f64,
    /// Compute instructions per global transaction (`avr_inst`, Eq. 7a).
    pub avr_inst: f64,
    /// `#B` total blocks.
    pub n_blocks: f64,
    /// `#Wpb` warps per block.
    pub wpb: f64,
    /// `#Aw` active warps per SM.
    pub aw: f64,
    /// `#SM` active SMs.
    pub n_sm: f64,
    /// First-level iterations per thread (`o_itrs`, source analysis).
    pub o_itrs: f64,
    /// Shared-memory transactions inside one iteration (`i_itrs`).
    pub i_itrs: f64,
    /// Whether the kernel touches shared memory (§V-B vs §V-A).
    pub uses_smem: bool,
    /// Average shared-memory bank-conflict degree (1 = conflict-free);
    /// measured as smem bank transactions / smem accesses.
    pub smem_conflict: f64,
    /// Global transactions per warp per iteration issued *inside* the
    /// body loop (source analysis, like `o_itrs`). Zero for tree-style
    /// smem kernels whose global traffic is all prologue/epilogue.
    pub gld_body: f64,
    /// Global transactions per warp in prologue + epilogue combined.
    pub gld_edge: f64,
    /// Global-memory *instructions* (dependent ops) per warp per body
    /// iteration. Each op exposes one full `agl_lat` when latency is not
    /// hidden; transactions within an op pipeline through the LSU.
    pub mem_ops: f64,
    /// Texture/L1 hit rate over all global transactions. The published
    /// model ignores it (paper §VII future work); only the
    /// `L1ExtendedModel` consumes it. Not part of the 16-feature AOT
    /// contract.
    pub l1_hr: f64,
}

/// Number of feature columns in the AOT artifact (ref.py `N_FEATURES`).
pub const N_FEATURES: usize = 16;
/// Number of output columns (ref.py `N_OUTPUTS`).
pub const N_OUTPUTS: usize = 4;
/// Number of hardware-parameter entries (ref.py `N_HW_PARAMS`).
pub const N_HW_PARAMS: usize = 7;

impl KernelCounters {
    /// Pack one (counters, frequency-pair) sample into the artifact's
    /// (12,) f32 feature row (ref.py `F_*` order).
    pub fn to_features(&self, core_mhz: f64, mem_mhz: f64) -> [f32; N_FEATURES] {
        [
            self.l2_hr as f32,
            self.gld_trans as f32,
            self.avr_inst as f32,
            self.n_blocks as f32,
            self.wpb as f32,
            self.aw as f32,
            self.n_sm as f32,
            self.o_itrs as f32,
            self.i_itrs as f32,
            if self.uses_smem { 1.0 } else { 0.0 },
            core_mhz as f32,
            mem_mhz as f32,
            self.smem_conflict as f32,
            self.gld_body as f32,
            self.gld_edge as f32,
            self.mem_ops as f32,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packing_order_matches_ref_py() {
        let c = KernelCounters {
            l2_hr: 0.5,
            gld_trans: 4.0,
            avr_inst: 10.0,
            n_blocks: 128.0,
            wpb: 8.0,
            aw: 32.0,
            n_sm: 16.0,
            o_itrs: 7.0,
            i_itrs: 3.0,
            uses_smem: true,
            smem_conflict: 1.5,
            gld_body: 3.5,
            gld_edge: 4.5,
            mem_ops: 2.0,
            l1_hr: 0.0,
        };
        let f = c.to_features(700.0, 500.0);
        assert_eq!(f.len(), N_FEATURES);
        assert_eq!(f[0], 0.5); // F_L2_HR
        assert_eq!(f[9], 1.0); // F_USES_SMEM
        assert_eq!(f[10], 700.0); // F_CORE_F
        assert_eq!(f[11], 500.0); // F_MEM_F
        assert_eq!(f[12], 1.5); // F_SMEM_CONFLICT
        assert_eq!(f[13], 3.5); // F_GLD_BODY
        assert_eq!(f[14], 4.5); // F_GLD_EDGE
        assert_eq!(f[15], 2.0); // F_MEM_OPS
    }

    #[test]
    fn hw_packing() {
        let h = HwParams::paper_defaults();
        let v = h.to_f32();
        assert_eq!(v[0], 222.78);
        assert_eq!(v[1], 277.32);
        assert_eq!(v[6], 2.0);
    }
}
