//! Least-squares fit of the paper's Eq. (4) from micro-benchmark
//! samples, with R² — the Rust twin of `model.fit_dm_lat` in the AOT
//! path (cross-checked by an integration test).

/// Result of fitting `lat = a * ratio + b`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LineFit {
    pub slope: f64,
    pub intercept: f64,
    pub r_squared: f64,
}

/// Ordinary least squares over (ratio, latency) samples.
///
/// Panics if fewer than two samples or zero variance in x.
pub fn fit_line(xs: &[f64], ys: &[f64]) -> LineFit {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need at least two samples");
    let n = xs.len() as f64;
    let xm = xs.iter().sum::<f64>() / n;
    let ym = ys.iter().sum::<f64>() / n;
    let sxx: f64 = xs.iter().map(|x| (x - xm) * (x - xm)).sum();
    assert!(sxx > 0.0, "x has zero variance");
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - xm) * (y - ym)).sum();
    let slope = sxy / sxx;
    let intercept = ym - slope * xm;
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (slope * x + intercept);
            e * e
        })
        .sum();
    let ss_tot: f64 = ys.iter().map(|y| (y - ym) * (y - ym)).sum();
    let r_squared = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
    LineFit { slope, intercept, r_squared }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line_recovered() {
        let xs: Vec<f64> = (1..50).map(|i| 0.4 + i as f64 * 0.05).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 222.78 * x + 277.32).collect();
        let f = fit_line(&xs, &ys);
        assert!((f.slope - 222.78).abs() < 1e-9);
        assert!((f.intercept - 277.32).abs() < 1e-9);
        assert!((f.r_squared - 1.0).abs() < 1e-12);
    }

    #[test]
    fn noise_lowers_r2() {
        let xs: Vec<f64> = (0..49).map(|i| 0.4 + i as f64 * 0.045).collect();
        // Deterministic pseudo-noise.
        let ys: Vec<f64> = xs
            .iter()
            .enumerate()
            .map(|(i, x)| 222.78 * x + 277.32 + ((i * 2654435761) % 17) as f64 - 8.0)
            .collect();
        let f = fit_line(&xs, &ys);
        assert!(f.r_squared > 0.98 && f.r_squared < 1.0, "{}", f.r_squared);
        assert!((f.slope - 222.78).abs() < 15.0);
    }

    #[test]
    #[should_panic]
    fn rejects_single_sample() {
        fit_line(&[1.0], &[2.0]);
    }

    #[test]
    #[should_panic]
    fn rejects_zero_variance() {
        fit_line(&[1.0, 1.0], &[2.0, 3.0]);
    }
}
