//! Least-squares fit of the paper's Eq. (4) from micro-benchmark
//! samples, with R² — the Rust twin of `model.fit_dm_lat` in the AOT
//! path (cross-checked by an integration test) — plus the power v2
//! sweep fitter (DESIGN.md §15): given the device's V/f curves and
//! the leakage shape constants, board power is *linear* in
//! (static_w, leak_w, core_coeff, mem_coeff), so the same normal-
//! equations machinery recovers all four from a (frequency point,
//! measured watts) sweep.

use crate::dvfs::{DynamicParams, LeakageParams, PowerModel, VfCurve};

/// Result of fitting `lat = a * ratio + b`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LineFit {
    pub slope: f64,
    pub intercept: f64,
    pub r_squared: f64,
}

/// Ordinary least squares over (ratio, latency) samples.
///
/// Panics if fewer than two samples or zero variance in x.
pub fn fit_line(xs: &[f64], ys: &[f64]) -> LineFit {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need at least two samples");
    let n = xs.len() as f64;
    let xm = xs.iter().sum::<f64>() / n;
    let ym = ys.iter().sum::<f64>() / n;
    let sxx: f64 = xs.iter().map(|x| (x - xm) * (x - xm)).sum();
    assert!(sxx > 0.0, "x has zero variance");
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - xm) * (y - ym)).sum();
    let slope = sxy / sxx;
    let intercept = ym - slope * xm;
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (slope * x + intercept);
            e * e
        })
        .sum();
    let ss_tot: f64 = ys.iter().map(|y| (y - ym) * (y - ym)).sum();
    let r_squared = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
    LineFit { slope, intercept, r_squared }
}

/// Multi-regressor ordinary least squares: minimise ‖X·β − y‖² via
/// the normal equations (XᵀX·β = Xᵀy), solved by Gauss–Jordan
/// elimination with partial pivoting. `columns` are the regressor
/// columns of X, each `ys.len()` long. Returns `(β, R²)`; `Err` when
/// the normal matrix is singular (collinear regressors).
pub fn fit_least_squares(columns: &[Vec<f64>], ys: &[f64]) -> Result<(Vec<f64>, f64), String> {
    let k = columns.len();
    let n = ys.len();
    assert!(k >= 1, "need at least one regressor");
    assert!(columns.iter().all(|c| c.len() == n), "column length mismatch");
    assert!(n >= k, "need at least as many samples as regressors");
    let dot = |a: &[f64], b: &[f64]| a.iter().zip(b).map(|(x, y)| x * y).sum::<f64>();
    // Augmented normal system [XᵀX | Xᵀy].
    let mut a = vec![vec![0.0; k + 1]; k];
    for i in 0..k {
        for j in 0..k {
            a[i][j] = dot(&columns[i], &columns[j]);
        }
        a[i][k] = dot(&columns[i], ys);
    }
    let scale = a
        .iter()
        .flat_map(|row| row[..k].iter())
        .fold(0.0f64, |m, v| m.max(v.abs()))
        .max(1.0);
    for col in 0..k {
        let pivot_row = (col..k)
            .max_by(|&r, &s| a[r][col].abs().total_cmp(&a[s][col].abs()))
            .unwrap();
        if a[pivot_row][col].abs() <= 1e-12 * scale {
            return Err(format!(
                "normal equations singular at regressor {col} (collinear columns)"
            ));
        }
        a.swap(col, pivot_row);
        for r in 0..k {
            if r == col {
                continue;
            }
            let f = a[r][col] / a[col][col];
            for c in col..=k {
                a[r][c] -= f * a[col][c];
            }
        }
    }
    let beta: Vec<f64> = (0..k).map(|i| a[i][k] / a[i][i]).collect();
    let ym = ys.iter().sum::<f64>() / n as f64;
    let (mut ss_res, mut ss_tot) = (0.0, 0.0);
    for (row, y) in ys.iter().enumerate() {
        let yhat: f64 = beta.iter().zip(columns).map(|(b, c)| b * c[row]).sum();
        ss_res += (y - yhat) * (y - yhat);
        ss_tot += (y - ym) * (y - ym);
    }
    let r_squared = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
    Ok((beta, r_squared))
}

/// A fitted v2 power model plus its goodness of fit (R² of the
/// *returned* model against the sweep — after any clamping).
#[derive(Debug, Clone)]
pub struct PowerFit {
    pub model: PowerModel,
    pub r_squared: f64,
}

/// Fit the v2 power split from `((core_mhz, mem_mhz), measured_w)`
/// sweep samples, given the device's V/f curves and the leakage shape
/// constants. The regressors are `[1, g(V_core), cf·V_core²,
/// mf·V_mem²]` with `g(v) = (v/v_ref)·10^((v − v_ref)/v_slope)`, so
/// the fit is a single linear solve. When the core curve is flat,
/// `g(V_core)` is constant — collinear with the intercept — and the
/// fit falls back to the frequency-only v1 form with `leak_w = 0`.
/// Negative fitted parameters (possible under noise) clamp to zero so
/// the returned model stays physical.
pub fn fit_power_model(
    core_curve: &VfCurve,
    mem_curve: &VfCurve,
    samples: &[((f64, f64), f64)],
    v_ref: f64,
    v_slope: f64,
) -> Result<PowerFit, String> {
    if samples.len() < 4 {
        return Err(format!("need at least 4 sweep samples, got {}", samples.len()));
    }
    if !(v_ref > 0.0 && v_ref.is_finite() && v_slope > 0.0 && v_slope.is_finite()) {
        return Err(format!("leakage shape v_ref={v_ref} v_slope={v_slope} must be positive"));
    }
    let shape = LeakageParams { static_w: 0.0, leak_w: 1.0, v_ref, v_slope };
    let n = samples.len();
    let (ones, mut leak, mut core, mut mem) =
        (vec![1.0; n], Vec::with_capacity(n), Vec::with_capacity(n), Vec::with_capacity(n));
    let mut ys = Vec::with_capacity(n);
    for &((cf, mf), watts) in samples {
        let vc = core_curve.volts(cf);
        let vm = mem_curve.volts(mf);
        leak.push(shape.excess_w(vc));
        core.push(cf * vc * vc);
        mem.push(mf * vm * vm);
        ys.push(watts);
    }
    let nonneg = |x: f64| if x < 0.0 { 0.0 } else { x };
    let (static_w, leak_w, core_coeff, mem_coeff) =
        match fit_least_squares(&[ones.clone(), leak, core.clone(), mem.clone()], &ys) {
            Ok((beta, _)) => (nonneg(beta[0]), nonneg(beta[1]), nonneg(beta[2]), nonneg(beta[3])),
            Err(_) => {
                // Flat core curve: leakage indistinguishable from the
                // static floor — fold it in and report leak_w = 0.
                let (beta, _) = fit_least_squares(&[ones, core, mem], &ys)?;
                (nonneg(beta[0]), 0.0, nonneg(beta[1]), nonneg(beta[2]))
            }
        };
    let model = PowerModel {
        core_curve: core_curve.clone(),
        mem_curve: mem_curve.clone(),
        dynamic: DynamicParams { core_coeff, mem_coeff },
        leakage: LeakageParams { static_w, leak_w, v_ref, v_slope },
    };
    // R² of the model actually returned (clamping included).
    let ym = ys.iter().sum::<f64>() / n as f64;
    let (mut ss_res, mut ss_tot) = (0.0, 0.0);
    for &((cf, mf), watts) in samples {
        let e = watts - model.power_w(cf, mf);
        ss_res += e * e;
        ss_tot += (watts - ym) * (watts - ym);
    }
    let r_squared = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
    Ok(PowerFit { model, r_squared })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line_recovered() {
        let xs: Vec<f64> = (1..50).map(|i| 0.4 + i as f64 * 0.05).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 222.78 * x + 277.32).collect();
        let f = fit_line(&xs, &ys);
        assert!((f.slope - 222.78).abs() < 1e-9);
        assert!((f.intercept - 277.32).abs() < 1e-9);
        assert!((f.r_squared - 1.0).abs() < 1e-12);
    }

    #[test]
    fn noise_lowers_r2() {
        let xs: Vec<f64> = (0..49).map(|i| 0.4 + i as f64 * 0.045).collect();
        // Deterministic pseudo-noise.
        let ys: Vec<f64> = xs
            .iter()
            .enumerate()
            .map(|(i, x)| 222.78 * x + 277.32 + ((i * 2654435761) % 17) as f64 - 8.0)
            .collect();
        let f = fit_line(&xs, &ys);
        assert!(f.r_squared > 0.98 && f.r_squared < 1.0, "{}", f.r_squared);
        assert!((f.slope - 222.78).abs() < 15.0);
    }

    #[test]
    #[should_panic]
    fn rejects_single_sample() {
        fit_line(&[1.0], &[2.0]);
    }

    #[test]
    #[should_panic]
    fn rejects_zero_variance() {
        fit_line(&[1.0, 1.0], &[2.0, 3.0]);
    }

    #[test]
    fn least_squares_matches_fit_line_on_two_columns() {
        // [1, x] regression must agree with the dedicated line fitter.
        let xs: Vec<f64> = (1..30).map(|i| 0.3 + i as f64 * 0.07).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 31.5 * x - 4.25).collect();
        let line = fit_line(&xs, &ys);
        let (beta, r2) =
            fit_least_squares(&[vec![1.0; xs.len()], xs.clone()], &ys).unwrap();
        assert!((beta[0] - line.intercept).abs() < 1e-9);
        assert!((beta[1] - line.slope).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn least_squares_rejects_collinear_columns() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let doubled: Vec<f64> = xs.iter().map(|x| 2.0 * x).collect();
        let ys = vec![1.0; 10];
        let err = fit_least_squares(&[xs, doubled], &ys).unwrap_err();
        assert!(err.contains("singular"), "{err}");
    }

    #[test]
    fn power_fit_recovers_planted_params_exactly_from_clean_sweep() {
        let truth = PowerModel::gtx980();
        let mut samples = Vec::new();
        let mut c = 400.0;
        while c <= 1000.0 {
            let mut m = 400.0;
            while m <= 1000.0 {
                samples.push(((c, m), truth.power_w(c, m)));
                m += 100.0;
            }
            c += 100.0;
        }
        let fit = fit_power_model(
            &truth.core_curve,
            &truth.mem_curve,
            &samples,
            truth.leakage.v_ref,
            truth.leakage.v_slope,
        )
        .unwrap();
        let (got, want) = (&fit.model, &truth);
        assert!((got.leakage.static_w - want.leakage.static_w).abs() < 1e-6);
        assert!((got.leakage.leak_w - want.leakage.leak_w).abs() < 1e-6);
        assert!((got.dynamic.core_coeff - want.dynamic.core_coeff).abs() < 1e-9);
        assert!((got.dynamic.mem_coeff - want.dynamic.mem_coeff).abs() < 1e-9);
        assert!(fit.r_squared > 1.0 - 1e-12);
    }

    #[test]
    fn power_fit_flat_core_curve_falls_back_to_v1_form() {
        // A flat core curve makes g(V_core) constant — collinear with
        // the intercept — so the fitter must drop the leakage column
        // and still nail the sweep.
        let flat_core = VfCurve::try_from_points(vec![(400.0, 1.0), (1000.0, 1.0)]).unwrap();
        let flat_mem = VfCurve::try_from_points(vec![(400.0, 1.35), (1000.0, 1.35)]).unwrap();
        let truth = PowerModel {
            core_curve: flat_core.clone(),
            mem_curve: flat_mem.clone(),
            dynamic: DynamicParams { core_coeff: 0.06, mem_coeff: 0.02 },
            leakage: LeakageParams::flat(25.0),
        };
        let samples: Vec<((f64, f64), f64)> = [
            (400.0, 400.0),
            (400.0, 1000.0),
            (600.0, 700.0),
            (800.0, 500.0),
            (1000.0, 1000.0),
            (1000.0, 400.0),
        ]
        .iter()
        .map(|&(c, m)| ((c, m), truth.power_w(c, m)))
        .collect();
        let fit = fit_power_model(&flat_core, &flat_mem, &samples, 1.0, 0.8).unwrap();
        assert_eq!(fit.model.leakage.leak_w, 0.0);
        assert!((fit.model.leakage.static_w - 25.0).abs() < 1e-6);
        assert!((fit.model.dynamic.core_coeff - 0.06).abs() < 1e-9);
        assert!((fit.model.dynamic.mem_coeff - 0.02).abs() < 1e-9);
    }

    #[test]
    fn power_fit_rejects_tiny_or_misshapen_input() {
        let m = PowerModel::gtx980();
        let s = vec![((400.0, 400.0), 50.0); 3];
        assert!(fit_power_model(&m.core_curve, &m.mem_curve, &s, 1.0, 0.8).is_err());
        let s4 = vec![((400.0, 400.0), 50.0); 4];
        assert!(fit_power_model(&m.core_curve, &m.mem_curve, &s4, -1.0, 0.8).is_err());
        assert!(fit_power_model(&m.core_curve, &m.mem_curve, &s4, 1.0, f64::NAN).is_err());
    }
}
