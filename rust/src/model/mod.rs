//! The paper's analytical performance model (Eqs. 2–21), scalar Rust
//! reference implementation.
//!
//! This mirrors `python/compile/kernels/ref.py` equation-for-equation;
//! an integration test executes the AOT-lowered Pallas artifact through
//! PJRT and cross-checks it against this module. The deviations from the
//! paper as printed (Eq. 5a composition, `gld_trans` folding, Eq. 11's
//! `#Wpb`) are documented in ref.py and DESIGN.md §2.

pub mod fit;
pub mod params;
pub mod soa;

pub use params::{HwParams, KernelCounters};

/// Which pipeline case (paper Figs. 6–11) a sample falls into.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Regime {
    /// Eq. (9): enough compute to hide memory latency.
    Compute = 0,
    /// Eq. (15): long compute, too few warps to hide latency.
    FewWarpsLongCompute = 1,
    /// Eq. (11): saturated memory queue.
    Memory = 2,
    /// Eq. (13): few warps, short compute, exposed queue.
    FewWarpsShortCompute = 3,
    /// Eq. (17): shared memory present but hidden behind the queue.
    SmemLight = 4,
    /// Eq. (21): shared-memory-intensive three-phase pipeline.
    SmemIntense = 5,
}

impl Regime {
    pub fn from_id(id: u32) -> Option<Regime> {
        Some(match id {
            0 => Regime::Compute,
            1 => Regime::FewWarpsLongCompute,
            2 => Regime::Memory,
            3 => Regime::FewWarpsShortCompute,
            4 => Regime::SmemLight,
            5 => Regime::SmemIntense,
            _ => return None,
        })
    }
}

/// Model output for one (kernel, frequency) sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Prediction {
    /// Cycles for one round of active warps (`T_active`, Eq. 9–21).
    pub t_active: f64,
    /// Total kernel cycles in the core domain (`T_exec`, Eq. 6).
    pub t_exec_cycles: f64,
    /// Wall-clock microseconds at `core_mhz`.
    pub time_us: f64,
    pub regime: Regime,
}

/// Intermediate AMAT quantities (Eq. 5), exposed for tests/reports.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Amat {
    pub dm_lat: f64,
    pub agl_lat: f64,
    pub agl_del: f64,
}

/// Eq. (4) + Eq. (5): frequency-adjusted average global latency/delay.
pub fn amat(c: &KernelCounters, hw: &HwParams, core_mhz: f64, mem_mhz: f64) -> Amat {
    let ratio = core_mhz / mem_mhz;
    let dm_lat = hw.dm_lat_a * ratio + hw.dm_lat_b; // Eq. (4)
    let miss = 1.0 - c.l2_hr;
    Amat {
        dm_lat,
        agl_lat: hw.l2_lat * c.l2_hr + dm_lat * miss, // Eq. (5a)
        agl_del: hw.l2_del * c.l2_hr + hw.dm_del * ratio * miss, // Eq. (5b)
    }
}

/// Full model: Eqs. (4)–(21) then Eq. (6).
///
/// Two clarifications relative to the paper as printed (beyond the
/// condition-direction fix documented at `Regime`):
///
/// * The paper normalizes compute per *transaction* (`avr_comp`,
///   Eq. 7) and its `o_itrs` counts (compute, one-transaction) periods.
///   Our counters keep `o_itrs` = source-level loop iterations, so the
///   per-iteration compute period is `C = avr_comp * gld_trans` — the
///   two bookkeepings coincide when `gld_trans = 1`, the case the
///   paper's pipeline figures draw.
/// * Eq. (19) models phase 2 of the smem-intensive case as a single
///   block pipelining through the SM. With several resident blocks the
///   ALU, the smem ports and the MC all serialize *across* blocks, so
///   we take the binding resource: `max(ALU, smem-port, latency chain)`
///   — which reduces to the paper's form when one block dominates.
pub fn predict(c: &KernelCounters, hw: &HwParams, core_mhz: f64, mem_mhz: f64) -> Prediction {
    let a = amat(c, hw, core_mhz, mem_mhz);
    predict_with_amat(c, hw, a, core_mhz, mem_mhz)
}

/// The regime/time machinery with an externally supplied AMAT — lets
/// extensions (e.g. the texture/L1 level, `baselines::L1Extended`)
/// adjust the average latency/delay without duplicating Eqs. (6)-(21).
pub fn predict_with_amat(
    c: &KernelCounters,
    hw: &HwParams,
    a: Amat,
    core_mhz: f64,
    mem_mhz: f64,
) -> Prediction {
    assert!(core_mhz > 0.0 && mem_mhz > 0.0);
    let avr_comp = hw.inst_cycle * c.avr_inst; // Eq. (7b), per transaction
    let comp_iter = avr_comp * c.gld_trans; // per body iteration ("C")
    let q = a.agl_del * c.gld_trans;
    let aw = c.aw;
    let o = c.o_itrs;

    let (t_active, regime) = if c.uses_smem {
        // Eq. (16) with the queue-drain window scaled by the *body*
        // transaction count (the paper's form assumes gld = 1/iter).
        let q_body = a.agl_del * c.gld_body;
        let smem_light =
            avr_comp <= a.agl_del && (avr_comp + hw.sh_lat) < q_body * (aw - c.wpb);
        if smem_light {
            (comp_iter + a.agl_lat + q * aw * o, Regime::SmemLight) // Eq. (17)
        } else {
            // Refined Eqs. (18)-(21); see function docs. The body work
            // overlaps the boundary drain across blocks (blocks whose
            // prologue loads return early start their smem phase while
            // later blocks still drain), hence the max().
            let alu = comp_iter * aw;
            let port = c.i_itrs * c.smem_conflict * aw;
            let mem_iter = q_body * aw; // Eq. (20): body queue drain
            let chain = hw.sh_lat * c.i_itrs; // barrier-exposed latency
            let body = (alu.max(port).max(mem_iter) + chain) * o; // Eq. (19)
            let edge = a.agl_del * c.gld_edge * aw; // Eq. (18) drain
            (body.max(edge) + a.agl_lat + hw.sh_lat, Regime::SmemIntense) // Eq. (21)
        }
    } else {
        // Per-iteration exposed latency: each of the `mem_ops` dependent
        // memory instructions pays agl_lat when nothing hides it.
        let lat_iter = a.agl_lat * c.mem_ops.max(1.0);
        if avr_comp >= a.agl_del {
            if comp_iter * (aw - 1.0) >= lat_iter {
                (comp_iter * aw * o + a.agl_lat, Regime::Compute) // Eq. (9)
            } else {
                (
                    comp_iter * (aw - 1.0) + (comp_iter + lat_iter) * o, // Eq. (15)
                    Regime::FewWarpsLongCompute,
                )
            }
        } else if (comp_iter + a.agl_lat) <= q * (aw - 1.0) {
            // Queue stays saturated when warp turnaround < other-warp
            // drain time (direction per Figs. 7/8; the paper's printed
            // (10b)/(12b) are swapped — see ref.py and DESIGN.md §2).
            (a.agl_lat + comp_iter + q * aw * o, Regime::Memory) // Eq. (11)
        } else {
            (
                q * aw + a.agl_lat + comp_iter + (comp_iter + lat_iter) * (o - 1.0), // Eq. (13)
                Regime::FewWarpsShortCompute,
            )
        }
    };

    let rounds = (c.wpb * c.n_blocks / (aw * c.n_sm)).max(1.0); // Eq. (6)
    let t_exec_cycles = t_active * rounds;
    Prediction {
        t_active,
        t_exec_cycles,
        time_us: t_exec_cycles / core_mhz,
        regime,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hw() -> HwParams {
        HwParams::paper_defaults()
    }

    fn counters() -> KernelCounters {
        KernelCounters {
            l2_hr: 0.2,
            gld_trans: 4.0,
            avr_inst: 20.0,
            n_blocks: 128.0,
            wpb: 8.0,
            aw: 32.0,
            n_sm: 16.0,
            o_itrs: 16.0,
            i_itrs: 0.0,
            uses_smem: false,
            smem_conflict: 1.0,
            gld_body: 4.0,
            gld_edge: 0.0,
            mem_ops: 1.0,
            l1_hr: 0.0,
        }
    }

    #[test]
    fn amat_endpoints_match_eq4() {
        let c = counters();
        let h = hw();
        let a = amat(&KernelCounters { l2_hr: 0.0, ..c }, &h, 400.0, 400.0);
        assert!((a.dm_lat - 500.1).abs() < 0.01);
        assert!((a.agl_lat - a.dm_lat).abs() < 1e-12);
        let a = amat(&KernelCounters { l2_hr: 0.0, ..c }, &h, 1000.0, 400.0);
        assert!((a.dm_lat - 834.27).abs() < 0.01);
    }

    #[test]
    fn full_l2_hit_rate_ignores_dram() {
        let c = KernelCounters { l2_hr: 1.0, ..counters() };
        let h = hw();
        let a1 = amat(&c, &h, 700.0, 400.0);
        let a2 = amat(&c, &h, 700.0, 1000.0);
        assert_eq!(a1.agl_lat, a2.agl_lat);
        assert_eq!(a1.agl_del, a2.agl_del);
        assert!((a1.agl_lat - h.l2_lat).abs() < 1e-12);
    }

    #[test]
    fn compute_regime_selected_and_timed() {
        let c = KernelCounters { avr_inst: 500.0, l2_hr: 0.9, ..counters() };
        let h = hw();
        let p = predict(&c, &h, 700.0, 700.0);
        assert_eq!(p.regime, Regime::Compute);
        let comp_iter = h.inst_cycle * c.avr_inst * c.gld_trans;
        let a = amat(&c, &h, 700.0, 700.0);
        let want = comp_iter * c.aw * c.o_itrs + a.agl_lat;
        assert!((p.t_active - want).abs() < 1e-9);
    }

    #[test]
    fn memory_regime_scales_with_ratio() {
        let c = KernelCounters {
            avr_inst: 1.0,
            gld_trans: 16.0,
            aw: 64.0,
            l2_hr: 0.0,
            o_itrs: 64.0,
            ..counters()
        };
        let h = hw();
        let p_lo = predict(&c, &h, 1000.0, 400.0);
        let p_hi = predict(&c, &h, 1000.0, 1000.0);
        assert_eq!(p_lo.regime, Regime::Memory);
        let speedup = p_lo.time_us / p_hi.time_us;
        assert!(speedup > 2.0 && speedup < 2.6, "{speedup}");
    }

    #[test]
    fn smem_selection() {
        let h = hw();
        let light = KernelCounters {
            uses_smem: true,
            smem_conflict: 1.0,
            gld_body: 4.0,
            gld_edge: 0.0,
            mem_ops: 1.0,
            l1_hr: 0.0,
            avr_inst: 1.0,
            gld_trans: 8.0,
            aw: 64.0,
            wpb: 8.0,
            l2_hr: 0.0,
            ..counters()
        };
        assert_eq!(predict(&light, &h, 700.0, 700.0).regime, Regime::SmemLight);
        let intense = KernelCounters {
            uses_smem: true,
            smem_conflict: 1.0,
            gld_body: 4.0,
            gld_edge: 0.0,
            mem_ops: 1.0,
            l1_hr: 0.0,
            avr_inst: 40.0,
            i_itrs: 32.0,
            aw: 16.0,
            wpb: 8.0,
            ..counters()
        };
        assert_eq!(predict(&intense, &h, 700.0, 700.0).regime, Regime::SmemIntense);
    }

    #[test]
    fn rounds_floor() {
        let c = KernelCounters { n_blocks: 1.0, wpb: 2.0, aw: 32.0, n_sm: 16.0, ..counters() };
        let p = predict(&c, &hw(), 700.0, 700.0);
        assert!((p.t_exec_cycles - p.t_active).abs() < 1e-12);
    }

    #[test]
    fn time_consistent_with_cycles() {
        let p = predict(&counters(), &hw(), 800.0, 600.0);
        assert!((p.time_us - p.t_exec_cycles / 800.0).abs() < 1e-12);
    }

    #[test]
    fn regime_ids_roundtrip() {
        for id in 0..6 {
            assert_eq!(Regime::from_id(id).unwrap() as u32, id);
        }
        assert!(Regime::from_id(6).is_none());
    }
}
