//! Minimal blocking HTTP/1.1 client for the prediction service: the
//! load harness (`benches/service_load.rs`), the smoke test and CI all
//! drive the server through this, so no `curl` is needed anywhere.
//!
//! One [`Client`] owns one keep-alive connection and issues one request
//! at a time — exactly the closed-loop shape the load harness measures.
//!
//! # Example
//!
//! ```no_run
//! use gpufreq::service::Client;
//!
//! let addr = "127.0.0.1:8077".parse().unwrap();
//! let mut client = Client::connect(&addr)?;
//! let health = client.get("/healthz")?;
//! assert_eq!(health.status, 200);
//! let plan = client.post(
//!     "/v2/plan",
//!     r#"{"jobs":[{"kernel":"VA","scale":2,"deadline_us":1e6}]}"#,
//! )?;
//! println!("{}", plan.body);
//! # Ok::<(), std::io::Error>(())
//! ```

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use super::json::{ParseError, Value};

/// A parsed response.
#[derive(Debug, Clone)]
pub struct ClientResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: String,
}

impl ClientResponse {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// Parse the body as JSON.
    pub fn json(&self) -> Result<Value, ParseError> {
        Value::parse(&self.body)
    }
}

fn bad_data(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string())
}

/// One keep-alive connection to the service.
pub struct Client {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl Client {
    pub fn connect(addr: &SocketAddr) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client { stream, buf: Vec::with_capacity(4096) })
    }

    /// Bound how long [`read_response`](Self::read_response) waits.
    pub fn set_read_timeout(&mut self, t: Option<Duration>) -> std::io::Result<()> {
        self.stream.set_read_timeout(t)
    }

    pub fn get(&mut self, path: &str) -> std::io::Result<ClientResponse> {
        self.request("GET", path, None)
    }

    pub fn post(&mut self, path: &str, body: &str) -> std::io::Result<ClientResponse> {
        self.request("POST", path, Some(body))
    }

    /// POST a built JSON value (the typed-v2 convenience: render once,
    /// send, no string templating at call sites).
    pub fn post_json(&mut self, path: &str, body: &Value) -> std::io::Result<ClientResponse> {
        self.post(path, &body.render())
    }

    /// Send one request and block for its response.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> std::io::Result<ClientResponse> {
        let body = body.unwrap_or("");
        let head = format!(
            "{method} {path} HTTP/1.1\r\nHost: gpufreq\r\nContent-Length: {}\r\nContent-Type: application/json\r\n\r\n",
            body.len()
        );
        self.stream.write_all(head.as_bytes())?;
        if !body.is_empty() {
            self.stream.write_all(body.as_bytes())?;
        }
        self.stream.flush()?;
        self.read_response()
    }

    /// Read one response without sending anything first — used to probe
    /// admission control, where the server answers 429 at accept time.
    pub fn read_response(&mut self) -> std::io::Result<ClientResponse> {
        let mut chunk = [0u8; 4096];
        loop {
            if let Some((resp, consumed)) = try_parse_response(&self.buf)? {
                self.buf.drain(..consumed);
                return Ok(resp);
            }
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "connection closed before a complete response",
                    ))
                }
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
    }
}

/// Incremental response parse, mirroring `http::try_parse` for the
/// response direction.
fn try_parse_response(buf: &[u8]) -> std::io::Result<Option<(ClientResponse, usize)>> {
    let Some(head_end) = buf.windows(4).position(|w| w == b"\r\n\r\n") else {
        return Ok(None);
    };
    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| bad_data("response head is not valid UTF-8"))?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().ok_or_else(|| bad_data("empty response"))?;
    let mut parts = status_line.split_ascii_whitespace();
    let version = parts.next().ok_or_else(|| bad_data("missing version"))?;
    if !version.starts_with("HTTP/1.") {
        return Err(bad_data("unsupported HTTP version in response"));
    }
    let status: u16 = parts
        .next()
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| bad_data("bad status code"))?;
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let (name, value) =
            line.split_once(':').ok_or_else(|| bad_data("malformed response header"))?;
        headers.push((name.trim().to_string(), value.trim().to_string()));
    }
    let content_length = match headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
    {
        Some((_, v)) => v.parse::<usize>().map_err(|_| bad_data("bad Content-Length"))?,
        None => 0,
    };
    let body_start = head_end + 4;
    if buf.len() < body_start + content_length {
        return Ok(None);
    }
    let body = String::from_utf8(buf[body_start..body_start + content_length].to_vec())
        .map_err(|_| bad_data("response body is not valid UTF-8"))?;
    Ok(Some((ClientResponse { status, headers, body }, body_start + content_length)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_response_with_body() {
        let raw = b"HTTP/1.1 429 Too Many Requests\r\nContent-Type: application/json\r\nContent-Length: 11\r\nRetry-After: 1\r\nConnection: close\r\n\r\n{\"error\":1}";
        let (resp, consumed) = try_parse_response(raw).unwrap().unwrap();
        assert_eq!(consumed, raw.len());
        assert_eq!(resp.status, 429);
        assert_eq!(resp.header("retry-after"), Some("1"));
        assert_eq!(resp.header("RETRY-AFTER"), Some("1"));
        assert_eq!(resp.body, "{\"error\":1}");
        assert_eq!(resp.json().unwrap().get("error").and_then(Value::as_f64), Some(1.0));
    }

    #[test]
    fn incomplete_responses_wait() {
        let raw = b"HTTP/1.1 200 OK\r\nContent-Length: 5\r\n\r\nhel";
        assert!(try_parse_response(raw).unwrap().is_none());
        assert!(try_parse_response(b"HTTP/1.1 200").unwrap().is_none());
    }

    #[test]
    fn malformed_responses_error() {
        assert!(try_parse_response(b"ICMP nope\r\n\r\n").is_err());
        assert!(try_parse_response(b"HTTP/1.1 soup\r\n\r\n").is_err());
    }
}
