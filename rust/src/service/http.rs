//! Minimal HTTP/1.1 wire handling (DESIGN.md §9): request parsing from
//! a growable byte buffer and response serialization. `std`-only — no
//! hyper/tiny_http in the offline vendor set.
//!
//! The parser is incremental: [`try_parse`] returns `Ok(None)` until a
//! complete head (+ `Content-Length` body) is buffered, so the server's
//! read loop can append chunks and re-try, and pipelined requests fall
//! out naturally (the consumed byte count lets the caller drain exactly
//! one request).

use std::io::Write;
use std::net::TcpStream;

/// Largest accepted request head (start line + headers).
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Largest accepted request body.
pub const MAX_BODY_BYTES: usize = 1024 * 1024;

/// One parsed request.
#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    /// Path only (any `?query` suffix is split off and kept verbatim).
    pub path: String,
    pub query: Option<String>,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// HTTP/1.1 default is keep-alive unless `Connection: close`.
    pub fn keep_alive(&self) -> bool {
        !matches!(self.header("connection"), Some(v) if v.eq_ignore_ascii_case("close"))
    }

    pub fn body_str(&self) -> Result<&str, HttpError> {
        std::str::from_utf8(&self.body).map_err(|_| HttpError::new("body is not valid UTF-8"))
    }
}

/// A malformed-request error; the server answers 400 and closes.
#[derive(Debug, Clone)]
pub struct HttpError {
    pub message: String,
}

impl HttpError {
    pub fn new(message: &str) -> Self {
        HttpError { message: message.to_string() }
    }
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for HttpError {}

/// Try to parse one complete request from the front of `buf`.
///
/// * `Ok(Some((req, consumed)))` — a full request; the caller drains
///   `consumed` bytes (pipelining keeps any following request intact).
/// * `Ok(None)` — incomplete; read more bytes and retry.
/// * `Err(_)` — malformed or over-limit; the connection is poisoned.
pub fn try_parse(buf: &[u8]) -> Result<Option<(HttpRequest, usize)>, HttpError> {
    let Some(head_end) = find_head_end(buf) else {
        if buf.len() > MAX_HEAD_BYTES {
            return Err(HttpError::new("request head exceeds 16 KiB"));
        }
        return Ok(None);
    };
    if head_end > MAX_HEAD_BYTES {
        return Err(HttpError::new("request head exceeds 16 KiB"));
    }
    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| HttpError::new("request head is not valid UTF-8"))?;
    let mut lines = head.split("\r\n");
    let start = lines.next().ok_or_else(|| HttpError::new("empty request"))?;
    let mut parts = start.split_ascii_whitespace();
    let method = parts.next().ok_or_else(|| HttpError::new("missing method"))?;
    let target = parts.next().ok_or_else(|| HttpError::new("missing request target"))?;
    let version = parts.next().ok_or_else(|| HttpError::new("missing HTTP version"))?;
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::new("unsupported HTTP version"));
    }
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| HttpError::new("malformed header line"))?;
        headers.push((name.trim().to_string(), value.trim().to_string()));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target.to_string(), None),
    };
    // Only Content-Length framing is implemented; silently ignoring a
    // Transfer-Encoding would desync the connection (the chunk stream
    // would be parsed as the next pipelined request).
    if headers.iter().any(|(k, _)| k.eq_ignore_ascii_case("transfer-encoding")) {
        return Err(HttpError::new("Transfer-Encoding is not supported; use Content-Length"));
    }
    let content_length = match headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
    {
        Some((_, v)) => v
            .parse::<usize>()
            .map_err(|_| HttpError::new("bad Content-Length"))?,
        None => 0,
    };
    if content_length > MAX_BODY_BYTES {
        return Err(HttpError::new("body exceeds 1 MiB"));
    }
    let body_start = head_end + 4; // past \r\n\r\n
    if buf.len() < body_start + content_length {
        return Ok(None);
    }
    let req = HttpRequest {
        method: method.to_string(),
        path,
        query,
        headers,
        body: buf[body_start..body_start + content_length].to_vec(),
    };
    Ok(Some((req, body_start + content_length)))
}

/// Byte offset of the `\r\n\r\n` head terminator, if buffered.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// A response ready to serialize.
#[derive(Debug, Clone)]
pub struct HttpResponse {
    pub status: u16,
    pub content_type: &'static str,
    pub body: String,
    /// Extra headers (e.g. `Retry-After`), appended verbatim.
    pub extra_headers: Vec<(String, String)>,
    /// Ask the peer (and the server loop) to close after this response.
    pub close: bool,
}

impl HttpResponse {
    pub fn json(status: u16, body: String) -> Self {
        HttpResponse {
            status,
            content_type: "application/json",
            body,
            extra_headers: Vec::new(),
            close: false,
        }
    }

    pub fn text(status: u16, body: String) -> Self {
        HttpResponse {
            status,
            content_type: "text/plain; charset=utf-8",
            body,
            extra_headers: Vec::new(),
            close: false,
        }
    }

    pub fn with_header(mut self, name: &str, value: String) -> Self {
        self.extra_headers.push((name.to_string(), value));
        self
    }

    pub fn closing(mut self) -> Self {
        self.close = true;
        self
    }
}

/// Canonical reason phrases for the statuses the service emits.
pub fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        422 => "Unprocessable Entity",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Serialize one response (head + body, always with `Content-Length`)
/// into a byte buffer — the unit the nonblocking server core appends to
/// a per-connection write buffer and drains on writability.
pub fn encode_response_into(resp: &HttpResponse, out: &mut Vec<u8>) {
    out.reserve(resp.body.len() + 160);
    // write! to a Vec<u8> is infallible.
    let _ = write!(
        out,
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n",
        resp.status,
        status_text(resp.status),
        resp.content_type,
        resp.body.len()
    );
    for (k, v) in &resp.extra_headers {
        out.extend_from_slice(k.as_bytes());
        out.extend_from_slice(b": ");
        out.extend_from_slice(v.as_bytes());
        out.extend_from_slice(b"\r\n");
    }
    out.extend_from_slice(if resp.close {
        b"Connection: close\r\n\r\n".as_slice()
    } else {
        b"Connection: keep-alive\r\n\r\n".as_slice()
    });
    out.extend_from_slice(resp.body.as_bytes());
}

/// [`encode_response_into`] into a fresh buffer.
pub fn encode_response(resp: &HttpResponse) -> Vec<u8> {
    let mut out = Vec::with_capacity(resp.body.len() + 160);
    encode_response_into(resp, &mut out);
    out
}

/// Serialize and send one response over a blocking stream (CLI-side and
/// test helpers; the server core uses [`encode_response_into`]).
pub fn write_response(stream: &mut TcpStream, resp: &HttpResponse) -> std::io::Result<()> {
    stream.write_all(&encode_response(resp))?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn raw(method: &str, path: &str, body: &str) -> Vec<u8> {
        format!(
            "{method} {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .into_bytes()
    }

    #[test]
    fn parses_a_complete_post() {
        let buf = raw("POST", "/v1/predict", "{\"a\":1}");
        let (req, consumed) = try_parse(&buf).unwrap().unwrap();
        assert_eq!(consumed, buf.len());
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/predict");
        assert_eq!(req.query, None);
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("HOST"), Some("x"));
        assert_eq!(req.body_str().unwrap(), "{\"a\":1}");
        assert!(req.keep_alive());
    }

    #[test]
    fn incremental_parse_waits_for_body() {
        let buf = raw("POST", "/v1/grid", "{\"kernel\":\"VA\"}");
        for cut in [0, 5, 20, buf.len() - 1] {
            assert!(try_parse(&buf[..cut]).unwrap().is_none(), "cut at {cut}");
        }
        assert!(try_parse(&buf).unwrap().is_some());
    }

    #[test]
    fn pipelined_requests_consume_one_at_a_time() {
        let mut buf = raw("GET", "/healthz", "");
        let second = raw("GET", "/metrics", "");
        buf.extend_from_slice(&second);
        let (first, consumed) = try_parse(&buf).unwrap().unwrap();
        assert_eq!(first.path, "/healthz");
        let rest = &buf[consumed..];
        let (next, consumed2) = try_parse(rest).unwrap().unwrap();
        assert_eq!(next.path, "/metrics");
        assert_eq!(consumed2, rest.len());
    }

    #[test]
    fn query_split_and_connection_close() {
        let buf = "GET /metrics?verbose=1 HTTP/1.1\r\nConnection: close\r\n\r\n";
        let (req, _) = try_parse(buf.as_bytes()).unwrap().unwrap();
        assert_eq!(req.path, "/metrics");
        assert_eq!(req.query.as_deref(), Some("verbose=1"));
        assert!(!req.keep_alive());
    }

    #[test]
    fn rejects_malformed_heads() {
        assert!(try_parse(b"BROKEN\r\n\r\n").is_err());
        assert!(try_parse(b"GET / SPDY/3\r\n\r\n").is_err());
        assert!(try_parse(b"GET / HTTP/1.1\r\nbadheader\r\n\r\n").is_err());
        assert!(try_parse(b"GET / HTTP/1.1\r\nContent-Length: soup\r\n\r\n").is_err());
        // Unsupported framing must be rejected, not silently desynced.
        assert!(try_parse(b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n").is_err());
    }

    #[test]
    fn enforces_size_limits() {
        let huge_head = format!("GET / HTTP/1.1\r\nX: {}\r\n\r\n", "a".repeat(MAX_HEAD_BYTES));
        assert!(try_parse(huge_head.as_bytes()).is_err());
        // An over-limit head that never terminates is rejected once the
        // buffer alone exceeds the cap (no unbounded buffering).
        let unterminated = vec![b'a'; MAX_HEAD_BYTES + 1];
        assert!(try_parse(&unterminated).is_err());
        let big_body = format!(
            "POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        );
        assert!(try_parse(big_body.as_bytes()).is_err());
    }

    #[test]
    fn encode_response_carries_headers_and_body() {
        let resp = HttpResponse::json(429, "{\"error\":\"overloaded\"}".to_string())
            .with_header("Retry-After", "1".to_string())
            .closing();
        let bytes = encode_response(&resp);
        let text = String::from_utf8(bytes).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.contains("Content-Type: application/json\r\n"));
        assert!(text.contains("Content-Length: 22\r\n"));
        assert!(text.contains("Retry-After: 1\r\n"));
        assert!(text.contains("Connection: close\r\n\r\n"));
        assert!(text.ends_with("{\"error\":\"overloaded\"}"));
        // Keep-alive default.
        let ka = encode_response(&HttpResponse::text(200, "ok".into()));
        assert!(String::from_utf8(ka).unwrap().contains("Connection: keep-alive\r\n\r\n"));
    }

    #[test]
    fn status_lines_cover_service_codes() {
        for code in [200, 400, 404, 405, 429, 500, 503] {
            assert_ne!(status_text(code), "Unknown");
        }
    }
}
