//! Hand-rolled JSON (DESIGN.md §9): the service's wire format, written
//! against `std` only like the rest of the crate (serde is not in the
//! offline vendor set — DESIGN.md "Offline substitutions").
//!
//! One [`Value`] tree covers both directions: a recursive-descent
//! parser for request bodies and a renderer for responses. Numbers are
//! `f64` (JSON's own number model); non-finite floats render as `null`
//! because JSON has no NaN/Infinity and a serving layer must never emit
//! unparseable bytes.

use std::fmt::Write as _;

/// A parsed or to-be-rendered JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Insertion-ordered key/value pairs (no map: bodies are small and
    /// render order should match build order).
    Obj(Vec<(String, Value)>),
}

/// Parse failure with a byte offset into the input.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

impl Value {
    /// Parse a complete JSON document (trailing whitespace allowed,
    /// trailing garbage rejected).
    pub fn parse(text: &str) -> Result<Value, ParseError> {
        let bytes = text.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Object field lookup (`None` for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(items) => Some(items.as_slice()),
            _ => None,
        }
    }

    /// Render to a compact JSON string.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    /// Render to a compact JSON string, pre-sizing the buffer. Route
    /// handlers that can estimate response cardinality (e.g. one array
    /// element per request tuple) use this to avoid the doubling
    /// reallocations `render` incurs on large batch responses.
    pub fn render_sized(&self, capacity: usize) -> String {
        let mut out = String::with_capacity(capacity);
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(n) => {
                if n.is_finite() {
                    // Rust's shortest-roundtrip `{}` for finite f64 is
                    // valid JSON (no exponent quirks, no trailing dot).
                    let _ = write!(out, "{n}");
                } else {
                    out.push_str("null");
                }
            }
            Value::Str(s) => escape_into(s, out),
            Value::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.render_into(out);
                }
                out.push(']');
            }
            Value::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape_into(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }

    // ---- builder conveniences (keep route handlers terse) ----

    pub fn num(n: f64) -> Value {
        Value::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    pub fn obj(fields: Vec<(&str, Value)>) -> Value {
        Value::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: Vec<Value>) -> Value {
        Value::Arr(items)
    }
}

/// Escape a string per RFC 8259 (quotes, backslash, control chars).
fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, message: &str) -> ParseError {
        ParseError { offset: self.pos, message: message.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{lit}`")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            fields.push((key, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(fields));
                }
                _ => return Err(self.err("expected `,` or `}` in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]` in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("truncated escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => out.push(self.unicode_escape()?),
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input is a &str, so the
                    // byte stream is valid UTF-8 by construction).
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = s.chars().next().ok_or_else(|| self.err("empty"))?;
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let n = u32::from_str_radix(s, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(n)
    }

    fn unicode_escape(&mut self) -> Result<char, ParseError> {
        let hi = self.hex4()?;
        // Surrogate pair: \uD800-\uDBFF must be followed by \uDC00-\uDFFF.
        if (0xD800..=0xDBFF).contains(&hi) {
            if self.bytes[self.pos..].starts_with(b"\\u") {
                self.pos += 2;
                let lo = self.hex4()?;
                if (0xDC00..=0xDFFF).contains(&lo) {
                    let cp = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                    return char::from_u32(cp).ok_or_else(|| self.err("bad surrogate pair"));
                }
            }
            return Err(self.err("unpaired surrogate"));
        }
        if (0xDC00..=0xDFFF).contains(&hi) {
            return Err(self.err("unpaired low surrogate"));
        }
        char::from_u32(hi).ok_or_else(|| self.err("bad \\u code point"))
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>().map(Value::Num).map_err(|_| ParseError {
            offset: start,
            message: format!("bad number `{s}`"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_a_request_body() {
        let text = r#"{"kernel":"VA","core_mhz":700,"mem_mhz":550.5,"pairs":[[400,400],[1000,1000]],"deep":{"a":true,"b":null}}"#;
        let v = Value::parse(text).unwrap();
        assert_eq!(v.get("kernel").and_then(Value::as_str), Some("VA"));
        assert_eq!(v.get("core_mhz").and_then(Value::as_f64), Some(700.0));
        assert_eq!(v.get("mem_mhz").and_then(Value::as_f64), Some(550.5));
        let pairs = v.get("pairs").and_then(Value::as_array).unwrap();
        assert_eq!(pairs.len(), 2);
        assert_eq!(pairs[1].as_array().unwrap()[0].as_f64(), Some(1000.0));
        assert_eq!(v.get("deep").unwrap().get("a").and_then(Value::as_bool), Some(true));
        assert_eq!(v.get("deep").unwrap().get("b"), Some(&Value::Null));
        // Re-render then re-parse: stable fixpoint.
        assert_eq!(Value::parse(&v.render()).unwrap(), v);
    }

    #[test]
    fn whitespace_and_nesting() {
        let v = Value::parse(" {\n\t\"a\" : [ 1 , -2.5e2 , \"x\" ] }\r\n").unwrap();
        let a = v.get("a").and_then(Value::as_array).unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[1].as_f64(), Some(-250.0));
        assert_eq!(a[2].as_str(), Some("x"));
    }

    #[test]
    fn string_escapes_round_trip() {
        let v = Value::Str("quote \" backslash \\ tab \t nul \u{0001} é".to_string());
        let rendered = v.render();
        assert_eq!(Value::parse(&rendered).unwrap(), v);
        // Parser also accepts \u escapes, incl. surrogate pairs.
        let parsed = Value::parse(r#""\u00e9 \ud83d\ude00""#).unwrap();
        assert_eq!(parsed.as_str(), Some("é 😀"));
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "{\"a\" 1}", "tru", "nul", "1 2", "{]",
            "\"unterminated", "\"\\q\"", "\"\\ud800\"", "--1", "{\"a\":1,}",
        ] {
            assert!(Value::parse(bad).is_err(), "should reject `{bad}`");
        }
    }

    #[test]
    fn non_finite_numbers_render_null() {
        let v = Value::obj(vec![("inf", Value::num(f64::INFINITY)), ("nan", Value::num(f64::NAN))]);
        assert_eq!(v.render(), r#"{"inf":null,"nan":null}"#);
    }

    #[test]
    fn get_on_non_object_is_none() {
        assert_eq!(Value::Num(1.0).get("x"), None);
        assert_eq!(Value::parse("[1]").unwrap().get("x"), None);
    }
}
