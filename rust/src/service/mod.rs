//! The standing prediction service (DESIGN.md §9): the paper's model
//! behind a network socket.
//!
//! The paper closes (§VII) by proposing "a real-time voltage and
//! frequency controller" built on the model; the model is cheap enough
//! (microseconds per estimate, counters + a handful of hardware
//! parameters) that the natural deployment is a standing oracle that
//! cluster schedulers query online. This module is that layer — written
//! against `std` only, like every other offline substitution in the
//! crate (no hyper, no serde, no tokio):
//!
//! ```text
//!            TCP clients (schedulers, load harness, CI)
//!                            │ client.rs
//!   ┌────────────────────────▼─────────────────────────┐
//!   │ server.rs   poll(2) readiness loop → exec pool   │
//!   │             (429 + Retry-After past the credit)  │
//!   │ http.rs     HTTP/1.1 parse / serialize           │
//!   │ routes.rs   /healthz /metrics /debug/{traces,    │
//!   │             plans, drift}                        │
//!   │             /v1/{predict, grid, advise}  (shim)  │
//!   │             /v2/{devices, kernels, predict,      │
//!   │             advise, plan, jobs, observations}    │
//!   │ json.rs     hand-rolled JSON both directions     │
//!   │ metrics.rs  counters + latency histograms        │
//!   └────────────────────────┬─────────────────────────┘
//!                            │
//!            engine::Engine + registry::{DeviceRegistry,
//!            KernelCatalog}          (DESIGN.md §8, §10)
//!              dvfs::{PowerModel, advise}  (§VII)
//!              planner::plan  (fleet DVFS, §11)
//!              scheduler::SchedulerCore  (streaming jobs, §14)
//!              obs::{TraceRing, AccuracyTracker}  (§13)
//! ```
//!
//! `/v2` is the typed, handle-based protocol (DESIGN.md §10): register
//! devices and kernels once, then predict/advise by
//! `(device, kernel, frequency)` handles — batch-first — or hand the
//! whole fleet to `POST /v2/plan` (DESIGN.md §11) for a deadline-aware,
//! energy-minimal job→(device, frequency) assignment. `/v1` remains
//! as a compatibility shim interpreted against the boot GPU.
//!
//! Start one with [`Service::start`] (the CLI's `serve` subcommand does
//! exactly this after profiling the Table VI kernels), drive it with
//! [`Client`], and read live counters at `GET /metrics`.
//!
//! Every admitted request is traced (DESIGN.md §13): the response
//! carries an `X-Request-Id` header, per-stage latency lands in the
//! `service_stage_latency_us` histograms, and traces slower than
//! `--slow-us` are retained in a lock-free ring behind
//! `GET /debug/traces`. Measured runtimes posted to
//! `POST /v2/observations` are scored against the model live and
//! surface as `model_mape{device,kernel}` in `/metrics`, with an EWMA
//! drift state machine behind `GET /debug/drift`. Every `/v2/plan`
//! solve carries a `plan_id`, solver telemetry, and per-job
//! explanations, retained in a provenance ring behind
//! `GET /debug/plans`; `--event-log PATH` appends the whole story as
//! correlated JSONL records (docs/OBSERVABILITY.md).
//!
//! `POST /v2/jobs` turns the one-shot planner into a streaming
//! scheduler (DESIGN.md §14): jobs are admitted with a provable
//! deadline check (422 `infeasible_at_submit` otherwise), placed by
//! incremental repair, re-planned each `--replan-interval` over a
//! rolling `--horizon`, and observable as a
//! Queued → Scheduled → Running → Done/Missed/Cancelled state machine
//! via `GET /v2/jobs/{id}`, `scheduler_*` metrics and `job_transition`
//! log events.

pub mod client;
pub mod http;
pub mod json;
pub mod metrics;
pub mod routes;
pub mod server;

pub use client::{Client, ClientResponse};
pub use metrics::{Histogram, Metrics, Route};
pub use routes::{PlanRecord, ServiceState, DEFAULT_DEVICE_NAME, DEFAULT_PLAN_RING};
pub use server::{Service, ServiceConfig};
