//! Route handlers (DESIGN.md §9): pure functions from a parsed
//! [`HttpRequest`] to an [`HttpResponse`], with no socket handling —
//! the server loop owns I/O, this module owns the wire protocol.
//!
//! | route            | method | body                                          |
//! |------------------|--------|-----------------------------------------------|
//! | `/healthz`       | GET    | —                                             |
//! | `/metrics`       | GET    | —                                             |
//! | `/v1/predict`    | POST   | `{kernel|counters, core_mhz, mem_mhz}`        |
//! | `/v1/grid`       | POST   | `{kernel|counters, pairs?}`                   |
//! | `/v1/advise`     | POST   | `{kernel|counters, objective?, deadline_us?, pairs?, include_points?}` |
//!
//! Kernels are resolved against profiles registered at startup (the
//! `serve` subcommand profiles the Table VI workloads once at the
//! baseline, exactly like the paper's one-shot counter pass); callers
//! with their own profiler pass raw `counters` instead.

use std::time::Instant;

use crate::dvfs::{ConfigPoint, Objective, PowerModel};
use crate::engine::{Engine, Estimate};
use crate::model::KernelCounters;

use super::http::{HttpRequest, HttpResponse};
use super::json::Value;
use super::metrics::{Metrics, Route};

/// Everything the handlers read: the shared engine, the power model and
/// the kernel-profile registry. Built once, shared (`Arc`) across the
/// worker pool.
pub struct ServiceState {
    pub engine: Engine,
    pub power: PowerModel,
    /// Grid used when a request omits `pairs` (the paper's 49 pairs).
    pub default_pairs: Vec<(f64, f64)>,
    profiles: Vec<(String, KernelCounters)>,
    pub started: Instant,
}

impl ServiceState {
    pub fn new(engine: Engine, power: PowerModel, default_pairs: Vec<(f64, f64)>) -> Self {
        ServiceState {
            engine,
            power,
            default_pairs,
            profiles: Vec::new(),
            started: Instant::now(),
        }
    }

    /// Register a profiled kernel for `{"kernel": name}` requests.
    pub fn register_kernel(&mut self, name: &str, counters: KernelCounters) {
        match self.profiles.iter_mut().find(|(n, _)| n == name) {
            Some((_, c)) => *c = counters,
            None => self.profiles.push((name.to_string(), counters)),
        }
    }

    pub fn counters_for(&self, name: &str) -> Option<KernelCounters> {
        self.profiles.iter().find(|(n, _)| n == name).map(|(_, c)| *c)
    }

    pub fn kernel_names(&self) -> Vec<&str> {
        self.profiles.iter().map(|(n, _)| n.as_str()).collect()
    }

    pub fn kernel_count(&self) -> usize {
        self.profiles.len()
    }
}

fn error_json(status: u16, message: &str) -> HttpResponse {
    HttpResponse::json(
        status,
        Value::obj(vec![("error", Value::str(message))]).render(),
    )
}

/// Dispatch one request. Handler panics become 500s — a worker thread
/// must survive any single bad request.
pub fn handle(state: &ServiceState, metrics: &Metrics, req: &HttpRequest) -> HttpResponse {
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        dispatch(state, metrics, req)
    }));
    match result {
        Ok(resp) => resp,
        Err(_) => error_json(500, "internal error (handler panicked)"),
    }
}

fn dispatch(state: &ServiceState, metrics: &Metrics, req: &HttpRequest) -> HttpResponse {
    match (req.method.as_str(), Route::of_path(&req.path)) {
        ("GET", Route::Healthz) => healthz(state),
        ("GET", Route::Metrics) => metrics_route(state, metrics),
        ("POST", Route::Predict) => predict(state, req),
        ("POST", Route::Grid) => grid(state, req),
        ("POST", Route::Advise) => advise(state, req),
        (_, Route::Other) => error_json(404, "unknown route"),
        _ => error_json(405, "method not allowed for this route"),
    }
}

fn healthz(state: &ServiceState) -> HttpResponse {
    let body = Value::obj(vec![
        ("status", Value::str("ok")),
        ("backend", Value::str(state.engine.backend_name())),
        ("kernels", Value::num(state.kernel_count() as f64)),
        (
            "uptime_ms",
            Value::num(state.started.elapsed().as_secs_f64() * 1e3),
        ),
    ]);
    HttpResponse::json(200, body.render())
}

fn metrics_route(state: &ServiceState, metrics: &Metrics) -> HttpResponse {
    let text = metrics.render(
        &state.engine.cache_stats(),
        state.started.elapsed(),
        state.engine.backend_name(),
    );
    HttpResponse::text(200, text)
}

/// Resolve the request's kernel: a registered profile name or an
/// inline `counters` object.
fn resolve_counters(state: &ServiceState, body: &Value) -> Result<KernelCounters, String> {
    if let Some(name) = body.get("kernel").and_then(Value::as_str) {
        return state.counters_for(name).ok_or_else(|| {
            format!(
                "unknown kernel `{name}` (registered: {})",
                state.kernel_names().join(", ")
            )
        });
    }
    let Some(c) = body.get("counters") else {
        return Err("body needs `kernel` (string) or `counters` (object)".to_string());
    };
    counters_from_json(c)
}

/// Strict-ish counters decoding: the fields the model always reads are
/// required; the rest default like a simple global-memory kernel.
fn counters_from_json(v: &Value) -> Result<KernelCounters, String> {
    let req = |key: &str| -> Result<f64, String> {
        v.get(key)
            .and_then(Value::as_f64)
            .ok_or_else(|| format!("counters.{key} must be a number"))
    };
    let opt = |key: &str, default: f64| -> Result<f64, String> {
        match v.get(key) {
            None => Ok(default),
            Some(x) => x
                .as_f64()
                .ok_or_else(|| format!("counters.{key} must be a number")),
        }
    };
    let gld_trans = req("gld_trans")?;
    Ok(KernelCounters {
        l2_hr: req("l2_hr")?,
        gld_trans,
        avr_inst: req("avr_inst")?,
        n_blocks: req("n_blocks")?,
        wpb: req("wpb")?,
        aw: req("aw")?,
        n_sm: req("n_sm")?,
        o_itrs: req("o_itrs")?,
        i_itrs: opt("i_itrs", 0.0)?,
        uses_smem: match v.get("uses_smem") {
            None => false,
            Some(b) => b
                .as_bool()
                .ok_or_else(|| "counters.uses_smem must be a bool".to_string())?,
        },
        smem_conflict: opt("smem_conflict", 1.0)?,
        gld_body: opt("gld_body", gld_trans)?,
        gld_edge: opt("gld_edge", 0.0)?,
        mem_ops: opt("mem_ops", 1.0)?,
        l1_hr: opt("l1_hr", 0.0)?,
    })
}

/// Decode an optional `pairs` array; fall back to the default grid.
fn resolve_pairs(state: &ServiceState, body: &Value) -> Result<Vec<(f64, f64)>, String> {
    let Some(raw) = body.get("pairs") else {
        return Ok(state.default_pairs.clone());
    };
    let items = raw
        .as_array()
        .ok_or_else(|| "`pairs` must be an array of [core_mhz, mem_mhz]".to_string())?;
    if items.is_empty() {
        return Err("`pairs` must not be empty".to_string());
    }
    let mut out = Vec::with_capacity(items.len());
    for (i, item) in items.iter().enumerate() {
        let pair = item.as_array().ok_or_else(|| format!("pairs[{i}] must be [core, mem]"))?;
        let (Some(cf), Some(mf)) = (
            pair.first().and_then(Value::as_f64),
            pair.get(1).and_then(Value::as_f64),
        ) else {
            return Err(format!("pairs[{i}] must be two numbers"));
        };
        if !(cf.is_finite() && mf.is_finite() && cf > 0.0 && mf > 0.0) || pair.len() != 2 {
            return Err(format!("pairs[{i}] must be two positive finite frequencies"));
        }
        out.push((cf, mf));
    }
    Ok(out)
}

fn parse_body(req: &HttpRequest) -> Result<Value, HttpResponse> {
    let text = req
        .body_str()
        .map_err(|e| error_json(400, &e.message))?;
    if text.trim().is_empty() {
        return Err(error_json(400, "request body must be a JSON object"));
    }
    Value::parse(text).map_err(|e| error_json(400, &e.to_string()))
}

fn estimate_json(cf: f64, mf: f64, e: &Estimate) -> Value {
    Value::obj(vec![
        ("core_mhz", Value::num(cf)),
        ("mem_mhz", Value::num(mf)),
        ("time_us", Value::num(e.time_us)),
        ("t_active", Value::num(e.t_active)),
        ("t_exec_cycles", Value::num(e.t_exec_cycles)),
        (
            "regime",
            match e.regime {
                Some(r) => Value::str(format!("{r:?}")),
                None => Value::Null,
            },
        ),
    ])
}

fn config_point_json(p: &ConfigPoint) -> Value {
    Value::obj(vec![
        ("core_mhz", Value::num(p.core_mhz)),
        ("mem_mhz", Value::num(p.mem_mhz)),
        ("time_us", Value::num(p.time_us)),
        ("power_w", Value::num(p.power_w)),
        ("energy_mj", Value::num(p.energy_mj)),
        ("edp", Value::num(p.edp)),
    ])
}

/// `POST /v1/predict` — one estimate at one frequency pair.
fn predict(state: &ServiceState, req: &HttpRequest) -> HttpResponse {
    let body = match parse_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let counters = match resolve_counters(state, &body) {
        Ok(c) => c,
        Err(m) => return error_json(400, &m),
    };
    let (Some(cf), Some(mf)) = (
        body.get("core_mhz").and_then(Value::as_f64),
        body.get("mem_mhz").and_then(Value::as_f64),
    ) else {
        return error_json(400, "body needs numeric `core_mhz` and `mem_mhz`");
    };
    if !(cf.is_finite() && mf.is_finite() && cf > 0.0 && mf > 0.0) {
        return error_json(400, "frequencies must be positive finite MHz");
    }
    match state.engine.predict_one(&counters, cf, mf) {
        Ok(e) => HttpResponse::json(200, estimate_json(cf, mf, &e).render()),
        Err(e) => error_json(500, &format!("prediction failed: {e:#}")),
    }
}

/// `POST /v1/grid` — a whole frequency-grid sweep (cache-served on
/// repeats; the response carries the engine's cache counters).
fn grid(state: &ServiceState, req: &HttpRequest) -> HttpResponse {
    let body = match parse_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let counters = match resolve_counters(state, &body) {
        Ok(c) => c,
        Err(m) => return error_json(400, &m),
    };
    let pairs = match resolve_pairs(state, &body) {
        Ok(p) => p,
        Err(m) => return error_json(400, &m),
    };
    let ests = match state.engine.predict_grid(&counters, &pairs) {
        Ok(v) => v,
        Err(e) => return error_json(500, &format!("prediction failed: {e:#}")),
    };
    let cache = state.engine.cache_stats();
    let points: Vec<Value> = pairs
        .iter()
        .zip(&ests)
        .map(|(&(cf, mf), e)| estimate_json(cf, mf, e))
        .collect();
    let resp = Value::obj(vec![
        ("points", Value::arr(points)),
        (
            "cache",
            Value::obj(vec![
                ("hits", Value::num(cache.hits as f64)),
                ("misses", Value::num(cache.misses as f64)),
                ("entries", Value::num(cache.entries as f64)),
                ("evictions", Value::num(cache.evictions as f64)),
            ]),
        ),
    ]);
    HttpResponse::json(200, resp.render())
}

fn parse_objective(body: &Value) -> Result<Objective, String> {
    match body.get("objective") {
        None => Ok(Objective::Energy),
        Some(Value::Str(s)) => match s.as_str() {
            "energy" => Ok(Objective::Energy),
            "edp" => Ok(Objective::Edp),
            other => Err(format!("unknown objective `{other}` (energy | edp | {{\"slack\": f}})")),
        },
        Some(obj) => obj
            .get("slack")
            .and_then(Value::as_f64)
            .map(Objective::EnergyWithSlack)
            .ok_or_else(|| "objective must be \"energy\", \"edp\" or {\"slack\": f}".to_string()),
    }
}

/// `POST /v1/advise` — the DVFS oracle: energy-optimal (core, mem)
/// under an optional absolute deadline (the paper's §VII real-time
/// controller application).
fn advise(state: &ServiceState, req: &HttpRequest) -> HttpResponse {
    let body = match parse_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let counters = match resolve_counters(state, &body) {
        Ok(c) => c,
        Err(m) => return error_json(400, &m),
    };
    let pairs = match resolve_pairs(state, &body) {
        Ok(p) => p,
        Err(m) => return error_json(400, &m),
    };
    let objective = match parse_objective(&body) {
        Ok(o) => o,
        Err(m) => return error_json(400, &m),
    };
    let deadline_us = match body.get("deadline_us") {
        None => None,
        Some(v) => match v.as_f64() {
            Some(d) if d > 0.0 && d.is_finite() => Some(d),
            _ => return error_json(400, "`deadline_us` must be a positive finite number"),
        },
    };
    let (best, points) =
        match crate::dvfs::advise_with_engine(&counters, &state.engine, &state.power, &pairs, objective)
        {
            Ok(r) => r,
            Err(e) => return error_json(500, &format!("advisor failed: {e:#}")),
        };
    let fastest = *points
        .iter()
        .min_by(|a, b| a.time_us.total_cmp(&b.time_us))
        .expect("non-empty grid");
    // Absolute deadline: re-select among points meeting it. If nothing
    // does, report infeasible and fall back to the fastest point — a
    // real-time controller still needs *a* setting to apply.
    let (best, feasible) = match deadline_us {
        None => (best, true),
        Some(deadline) => {
            let key = |p: &ConfigPoint| match objective {
                Objective::Edp => p.edp,
                _ => p.energy_mj,
            };
            let within = points
                .iter()
                .filter(|p| p.time_us <= deadline)
                .min_by(|a, b| key(a).total_cmp(&key(b)));
            match within {
                Some(p) => (*p, true),
                None => (fastest, false),
            }
        }
    };
    let mut fields = vec![
        (
            "objective",
            Value::str(match objective {
                Objective::Energy => "energy".to_string(),
                Objective::Edp => "edp".to_string(),
                Objective::EnergyWithSlack(s) => format!("slack:{s}"),
            }),
        ),
        ("feasible", Value::Bool(feasible)),
        ("best", config_point_json(&best)),
        ("fastest", config_point_json(&fastest)),
        ("points_evaluated", Value::num(points.len() as f64)),
    ];
    if let Some(d) = deadline_us {
        fields.push(("deadline_us", Value::num(d)));
    }
    if body.get("include_points").and_then(Value::as_bool) == Some(true) {
        fields.push((
            "points",
            Value::arr(points.iter().map(config_point_json).collect()),
        ));
    }
    HttpResponse::json(200, Value::obj(fields).render())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Engine;
    use crate::model::HwParams;

    fn counters() -> KernelCounters {
        KernelCounters {
            l2_hr: 0.1,
            gld_trans: 6.0,
            avr_inst: 1.5,
            n_blocks: 128.0,
            wpb: 8.0,
            aw: 64.0,
            n_sm: 16.0,
            o_itrs: 8.0,
            i_itrs: 0.0,
            uses_smem: false,
            smem_conflict: 1.0,
            gld_body: 6.0,
            gld_edge: 0.0,
            mem_ops: 2.0,
            l1_hr: 0.0,
        }
    }

    fn state() -> ServiceState {
        let hw = HwParams::paper_defaults();
        let mut s = ServiceState::new(
            Engine::native(hw),
            PowerModel::gtx980(),
            crate::microbench::standard_grid(),
        );
        s.register_kernel("VA", counters());
        s
    }

    fn post(path: &str, body: &str) -> HttpRequest {
        HttpRequest {
            method: "POST".to_string(),
            path: path.to_string(),
            query: None,
            headers: Vec::new(),
            body: body.as_bytes().to_vec(),
        }
    }

    fn get(path: &str) -> HttpRequest {
        HttpRequest {
            method: "GET".to_string(),
            path: path.to_string(),
            query: None,
            headers: Vec::new(),
            body: Vec::new(),
        }
    }

    #[test]
    fn predict_round_trip_matches_engine() {
        let st = state();
        let m = Metrics::default();
        let resp = handle(
            &st,
            &m,
            &post("/v1/predict", r#"{"kernel":"VA","core_mhz":700,"mem_mhz":700}"#),
        );
        assert_eq!(resp.status, 200);
        let v = Value::parse(&resp.body).unwrap();
        let want = st.engine.predict_one(&counters(), 700.0, 700.0).unwrap();
        let got = v.get("time_us").and_then(Value::as_f64).unwrap();
        // JSON round-trips f64 via shortest-representation `{}`: exact.
        assert_eq!(got.to_bits(), want.time_us.to_bits());
        assert!(v.get("regime").and_then(Value::as_str).is_some());
    }

    #[test]
    fn predict_accepts_inline_counters() {
        let st = state();
        let m = Metrics::default();
        let body = r#"{"counters":{"l2_hr":0.1,"gld_trans":6,"avr_inst":1.5,"n_blocks":128,
            "wpb":8,"aw":64,"n_sm":16,"o_itrs":8,"mem_ops":2},
            "core_mhz":500,"mem_mhz":900}"#;
        let resp = handle(&st, &m, &post("/v1/predict", body));
        assert_eq!(resp.status, 200, "{}", resp.body);
        let v = Value::parse(&resp.body).unwrap();
        let want = st.engine.predict_one(&counters(), 500.0, 900.0).unwrap();
        assert_eq!(
            v.get("time_us").and_then(Value::as_f64).unwrap().to_bits(),
            want.time_us.to_bits()
        );
    }

    #[test]
    fn predict_errors_are_400_with_json_bodies() {
        let st = state();
        let m = Metrics::default();
        for body in [
            "",
            "not json",
            r#"{"kernel":"NOPE","core_mhz":700,"mem_mhz":700}"#,
            r#"{"kernel":"VA"}"#,
            r#"{"kernel":"VA","core_mhz":-1,"mem_mhz":700}"#,
            r#"{"kernel":"VA","core_mhz":1e999,"mem_mhz":700}"#,
            r#"{"counters":{"l2_hr":0.1},"core_mhz":700,"mem_mhz":700}"#,
        ] {
            let resp = handle(&st, &m, &post("/v1/predict", body));
            assert_eq!(resp.status, 400, "body `{body}` -> {}", resp.body);
            assert!(Value::parse(&resp.body).unwrap().get("error").is_some());
        }
    }

    #[test]
    fn grid_defaults_to_standard_pairs_and_reports_cache() {
        let st = state();
        let m = Metrics::default();
        let resp = handle(&st, &m, &post("/v1/grid", r#"{"kernel":"VA"}"#));
        assert_eq!(resp.status, 200);
        let v = Value::parse(&resp.body).unwrap();
        assert_eq!(v.get("points").and_then(Value::as_array).unwrap().len(), 49);
        let cache = v.get("cache").unwrap();
        assert_eq!(cache.get("misses").and_then(Value::as_f64), Some(49.0));
        // Second call is fully cache-served.
        let resp2 = handle(&st, &m, &post("/v1/grid", r#"{"kernel":"VA"}"#));
        let v2 = Value::parse(&resp2.body).unwrap();
        assert!(v2.get("cache").unwrap().get("hits").and_then(Value::as_f64).unwrap() >= 49.0);
    }

    #[test]
    fn grid_accepts_explicit_pairs() {
        let st = state();
        let m = Metrics::default();
        let resp = handle(
            &st,
            &m,
            &post("/v1/grid", r#"{"kernel":"VA","pairs":[[400,400],[1000,1000]]}"#),
        );
        assert_eq!(resp.status, 200);
        let v = Value::parse(&resp.body).unwrap();
        let pts = v.get("points").and_then(Value::as_array).unwrap();
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[1].get("core_mhz").and_then(Value::as_f64), Some(1000.0));
        for bad in [
            r#"{"kernel":"VA","pairs":[]}"#,
            r#"{"kernel":"VA","pairs":[[400]]}"#,
            r#"{"kernel":"VA","pairs":[[400,0]]}"#,
            r#"{"kernel":"VA","pairs":[[400,400,400]]}"#,
            r#"{"kernel":"VA","pairs":"all"}"#,
        ] {
            assert_eq!(handle(&st, &m, &post("/v1/grid", bad)).status, 400, "{bad}");
        }
    }

    #[test]
    fn advise_energy_matches_dvfs_module() {
        let st = state();
        let m = Metrics::default();
        let resp = handle(&st, &m, &post("/v1/advise", r#"{"kernel":"VA"}"#));
        assert_eq!(resp.status, 200);
        let v = Value::parse(&resp.body).unwrap();
        assert_eq!(v.get("feasible").and_then(Value::as_bool), Some(true));
        let (want, _) = crate::dvfs::advise_with_engine(
            &counters(),
            &st.engine,
            &st.power,
            &st.default_pairs,
            Objective::Energy,
        )
        .unwrap();
        let best = v.get("best").unwrap();
        assert_eq!(best.get("core_mhz").and_then(Value::as_f64), Some(want.core_mhz));
        assert_eq!(best.get("mem_mhz").and_then(Value::as_f64), Some(want.mem_mhz));
    }

    #[test]
    fn advise_deadline_constrains_and_falls_back() {
        let st = state();
        let m = Metrics::default();
        // A generous deadline: feasible, best meets it.
        let resp = handle(
            &st,
            &m,
            &post("/v1/advise", r#"{"kernel":"VA","deadline_us":1e9,"include_points":true}"#),
        );
        let v = Value::parse(&resp.body).unwrap();
        assert_eq!(v.get("feasible").and_then(Value::as_bool), Some(true));
        assert_eq!(v.get("points").and_then(Value::as_array).unwrap().len(), 49);
        // An impossible deadline: infeasible, falls back to fastest.
        let resp = handle(
            &st,
            &m,
            &post("/v1/advise", r#"{"kernel":"VA","deadline_us":0.001}"#),
        );
        let v = Value::parse(&resp.body).unwrap();
        assert_eq!(v.get("feasible").and_then(Value::as_bool), Some(false));
        let best = v.get("best").unwrap().get("time_us").and_then(Value::as_f64).unwrap();
        let fastest = v.get("fastest").unwrap().get("time_us").and_then(Value::as_f64).unwrap();
        assert_eq!(best.to_bits(), fastest.to_bits());
        // Tight-but-possible deadline: the chosen point meets it.
        let loose = handle(&st, &m, &post("/v1/advise", r#"{"kernel":"VA"}"#));
        let unconstrained = Value::parse(&loose.body)
            .unwrap()
            .get("best")
            .unwrap()
            .get("time_us")
            .and_then(Value::as_f64)
            .unwrap();
        let deadline = (unconstrained + fastest) / 2.0;
        let resp = handle(
            &st,
            &m,
            &post("/v1/advise", &format!(r#"{{"kernel":"VA","deadline_us":{deadline}}}"#)),
        );
        let v = Value::parse(&resp.body).unwrap();
        assert_eq!(v.get("feasible").and_then(Value::as_bool), Some(true));
        assert!(
            v.get("best").unwrap().get("time_us").and_then(Value::as_f64).unwrap() <= deadline
        );
    }

    #[test]
    fn advise_objectives_parse() {
        let st = state();
        let m = Metrics::default();
        for body in [
            r#"{"kernel":"VA","objective":"edp"}"#,
            r#"{"kernel":"VA","objective":{"slack":0.05}}"#,
        ] {
            assert_eq!(handle(&st, &m, &post("/v1/advise", body)).status, 200, "{body}");
        }
        assert_eq!(
            handle(&st, &m, &post("/v1/advise", r#"{"kernel":"VA","objective":"speed"}"#)).status,
            400
        );
    }

    #[test]
    fn health_metrics_and_routing() {
        let st = state();
        let m = Metrics::default();
        let h = handle(&st, &m, &get("/healthz"));
        assert_eq!(h.status, 200);
        let v = Value::parse(&h.body).unwrap();
        assert_eq!(v.get("status").and_then(Value::as_str), Some("ok"));
        assert_eq!(v.get("kernels").and_then(Value::as_f64), Some(1.0));

        let mx = handle(&st, &m, &get("/metrics"));
        assert_eq!(mx.status, 200);
        assert!(mx.body.contains("service_cache_hits"));

        assert_eq!(handle(&st, &m, &get("/nope")).status, 404);
        assert_eq!(handle(&st, &m, &get("/v1/predict")).status, 405);
        assert_eq!(handle(&st, &m, &post("/healthz", "{}")).status, 405);
    }

    #[test]
    fn register_kernel_overwrites_by_name() {
        let mut st = state();
        let mut c = counters();
        c.avr_inst = 99.0;
        st.register_kernel("VA", c);
        assert_eq!(st.kernel_count(), 1);
        assert_eq!(st.counters_for("VA").unwrap().avr_inst, 99.0);
    }
}
