//! Route handlers (DESIGN.md §9–§10): pure functions from a parsed
//! [`HttpRequest`] to an [`HttpResponse`], with no socket handling —
//! the server loop owns I/O, this module owns the wire protocol.
//!
//! | route            | method   | body                                        |
//! |------------------|----------|---------------------------------------------|
//! | `/healthz`       | GET      | —                                           |
//! | `/metrics`       | GET      | —                                           |
//! | `/v1/predict`    | POST     | `{kernel\|counters, core_mhz, mem_mhz}`     |
//! | `/v1/grid`       | POST     | `{kernel\|counters, pairs?}`                |
//! | `/v1/advise`     | POST     | `{kernel\|counters, objective?, deadline_us?, pairs?, include_points?}` |
//! | `/v2/devices`    | POST/GET | `{name, hw?, power?}` / —                   |
//! | `/v2/kernels`    | POST/GET | `{name, counters}` / —                      |
//! | `/v2/predict`    | POST     | `{requests: [{device, kernel, core_mhz, mem_mhz}]}` (batch-first) |
//! | `/v2/advise`     | POST     | `{device, kernel, objective?, deadline_us?, pairs?, include_points?}` |
//! | `/v2/plan`       | POST     | `{jobs: [{kernel, scale?, deadline_us?, name?}], devices?, objective?, device_cap?, pairs?}` |
//! | `/v2/jobs`       | POST/GET | `{kernel, scale?, deadline_us?, name?}` / —  |
//! | `/v2/jobs/{id}`  | GET/DELETE | —                                         |
//! | `/v2/observations` | POST   | `{observations: [{device, kernel, core_mhz, mem_mhz, measured_us\|measured_ms}]}` |
//! | `/debug/traces`  | GET      | —                                           |
//! | `/debug/plans`   | GET      | —                                           |
//! | `/debug/drift`   | GET      | —                                           |
//!
//! **v2 is the handle-based protocol** (DESIGN.md §10): devices and
//! kernels are registered once and addressed by stable `dev-<n>` /
//! `krn-<n>` handles (names also resolve), so requests never re-ship
//! `HwParams`/`KernelCounters` blobs. **v1 is a compatibility shim**:
//! every v1 request is interpreted against the service's *default
//! device* (the GPU the server booted with, `dev-1`); named kernels
//! resolve through the same catalog v2 registers into, and inline
//! `counters` run as an anonymous, uncatalogued kernel. Both paths
//! produce byte-identical predictions for the same inputs — the shim
//! is routing, not arithmetic.
//!
//! Every error body is structured JSON `{error, code}` with a stable
//! machine-readable `code`: `bad_json`, `bad_request`,
//! `unknown_kernel`, `unknown_device`, `unknown_route`, `unknown_job`,
//! `method_not_allowed`, `registry_full`, `infeasible` (422, from the
//! fleet planner), `infeasible_at_submit` (422, from the streaming
//! scheduler's admission control), `internal` (plus `overloaded` and
//! `bad_http` from the server loop).

use std::sync::Arc;
use std::time::Instant;

use crate::dvfs::{ConfigPoint, DynamicParams, LeakageParams, Objective, PowerModel, VfCurve};
use crate::engine::{Engine, Estimate};
use crate::model::{HwParams, KernelCounters};
use crate::obs::{
    AccuracyTracker, EventSink, Ring, Stage, TraceRecord, TraceRing, DEFAULT_TRACE_CAPACITY,
};
use crate::planner::{
    self, Explain, Job, PlanError, PlanObjective, PlannerConfig, RunnerUp, SolveReport,
};
use crate::registry::{
    DeviceId, DeviceRecord, DeviceRegistry, FreqPoint, KernelCatalog, KernelId, RegisterError,
};
use crate::scheduler::{JobRecord, JobSpec, SchedulerConfig, SchedulerHandle};

use super::http::{HttpRequest, HttpResponse};
use super::json::Value;
use super::metrics::{Metrics, Route};

/// Name the boot GPU is registered under in the device registry.
pub const DEFAULT_DEVICE_NAME: &str = "default";

/// Default capacity of the plan-provenance ring (`--plan-ring`).
pub const DEFAULT_PLAN_RING: usize = 64;

/// One retained solve: the provenance record `GET /debug/plans` dumps.
/// Carries everything needed to answer "why did plan-N look like that"
/// after the response is gone — the full [`SolveReport`] (spans,
/// counters, per-job explains) plus the correlation keys.
#[derive(Debug, Clone)]
pub struct PlanRecord {
    /// `X-Request-Id` of the request that ran the solve, when known.
    pub request_id: Option<String>,
    pub objective: &'static str,
    /// Job names, indexed by the report's `Explain::job`.
    pub jobs: Vec<String>,
    pub total_energy_mj: f64,
    pub max_time_us: f64,
    /// Savings vs the max-frequency baseline (absent when the baseline
    /// itself was infeasible).
    pub energy_savings_pct: Option<f64>,
    pub report: SolveReport,
}

/// Everything the handlers read: the shared engine (with its device
/// registry and kernel catalog attached) and the default frequency
/// grid. Built once, shared (`Arc`) across the worker pool.
pub struct ServiceState {
    pub engine: Engine,
    /// The default device's power model (kept for v1 compatibility;
    /// v2 devices each carry their own).
    pub power: PowerModel,
    /// Grid used when a request omits `pairs` (the paper's 49 pairs).
    pub default_pairs: Vec<(f64, f64)>,
    pub registry: Arc<DeviceRegistry>,
    pub catalog: Arc<KernelCatalog>,
    /// Handle of the boot GPU every v1 request resolves to.
    pub default_device: DeviceId,
    pub started: Instant,
    /// Slow-trace ring behind `GET /debug/traces` (DESIGN.md §13).
    /// `Service::start` rebuilds it from `ServiceConfig`
    /// (`--trace-capacity`, `--slow-us`) before serving.
    pub traces: Arc<TraceRing>,
    /// Rolling model-error windows fed by `POST /v2/observations` and
    /// surfaced as `model_mape{device,kernel}` in `/metrics`.
    pub accuracy: Arc<AccuracyTracker>,
    /// Plan-provenance ring behind `GET /debug/plans` (`--plan-ring`;
    /// `Service::start` resizes it from `ServiceConfig`).
    pub plans: Arc<Ring<PlanRecord>>,
    /// Structured event-log sink (`--event-log`); `None` when the log
    /// is not enabled.
    pub events: Option<Arc<EventSink>>,
    /// Streaming job scheduler behind `/v2/jobs` (DESIGN.md §14).
    /// `Service::start` rebuilds it from `ServiceConfig`
    /// (`--replan-interval`, `--horizon`) before serving.
    pub scheduler: Arc<SchedulerHandle>,
}

impl ServiceState {
    pub fn new(engine: Engine, power: PowerModel, default_pairs: Vec<(f64, f64)>) -> Self {
        let registry = Arc::new(DeviceRegistry::new());
        let default_device =
            registry.register(DEFAULT_DEVICE_NAME, *engine.hw(), power.clone());
        let catalog = Arc::new(KernelCatalog::new());
        let engine = engine
            .with_handles(Arc::clone(&registry), Arc::clone(&catalog), default_device)
            .expect("default device is freshly registered with the engine's parameters");
        ServiceState {
            engine,
            power,
            default_pairs,
            registry,
            catalog,
            default_device,
            started: Instant::now(),
            traces: Arc::new(TraceRing::new(DEFAULT_TRACE_CAPACITY, 0.0)),
            accuracy: Arc::new(AccuracyTracker::default()),
            plans: Arc::new(Ring::new(DEFAULT_PLAN_RING)),
            events: None,
            scheduler: Arc::new(SchedulerHandle::new(SchedulerConfig::default())),
        }
    }

    /// Register a profiled kernel for `{"kernel": name}` requests
    /// (v1) and handle resolution (v2).
    pub fn register_kernel(&mut self, name: &str, counters: KernelCounters) {
        self.catalog.register(name, counters);
    }

    pub fn counters_for(&self, name: &str) -> Option<KernelCounters> {
        self.catalog.by_name(name).map(|e| e.counters)
    }

    pub fn kernel_names(&self) -> Vec<String> {
        self.catalog.names()
    }

    pub fn kernel_count(&self) -> usize {
        self.catalog.len()
    }
}

fn error_json(status: u16, code: &str, message: &str) -> HttpResponse {
    HttpResponse::json(
        status,
        Value::obj(vec![("error", Value::str(message)), ("code", Value::str(code))]).render(),
    )
}

/// Dispatch one request. Handler panics become 500s — a worker thread
/// must survive any single bad request.
pub fn handle(state: &ServiceState, metrics: &Metrics, req: &HttpRequest) -> HttpResponse {
    handle_traced(state, metrics, req, None)
}

/// [`handle`] with the request's `X-Request-Id` attached, so solve and
/// observation events in the structured log carry the correlation key
/// the matching `request_span` event has. The server loop calls this;
/// `handle` (tests, embedders) passes no id.
pub fn handle_traced(
    state: &ServiceState,
    metrics: &Metrics,
    req: &HttpRequest,
    request_id: Option<&str>,
) -> HttpResponse {
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        dispatch(state, metrics, req, request_id)
    }));
    match result {
        Ok(resp) => resp,
        Err(_) => error_json(500, "internal", "internal error (handler panicked)"),
    }
}

fn dispatch(
    state: &ServiceState,
    metrics: &Metrics,
    req: &HttpRequest,
    rid: Option<&str>,
) -> HttpResponse {
    match (req.method.as_str(), Route::of_path(&req.path)) {
        ("GET", Route::Healthz) => healthz(state),
        ("GET", Route::Metrics) => metrics_route(state, metrics),
        ("POST", Route::Predict) => predict(state, req),
        ("POST", Route::Grid) => grid(state, req),
        ("POST", Route::Advise) => advise(state, req),
        ("POST", Route::DevicesV2) => v2_register_device(state, req),
        ("GET", Route::DevicesV2) => v2_list_devices(state),
        ("POST", Route::KernelsV2) => v2_register_kernel(state, req),
        ("GET", Route::KernelsV2) => v2_list_kernels(state),
        ("POST", Route::PredictV2) => v2_predict(state, req),
        ("POST", Route::AdviseV2) => v2_advise(state, req),
        ("POST", Route::PlanV2) => v2_plan(state, metrics, req, rid),
        ("POST", Route::JobsV2) => v2_submit_job(state, metrics, req, rid),
        ("GET", Route::JobsV2) => v2_list_jobs(state, metrics),
        ("GET", Route::JobV2) => v2_get_job(state, metrics, req),
        ("DELETE", Route::JobV2) => v2_cancel_job(state, metrics, req, rid),
        ("POST", Route::ObservationsV2) => v2_observations(state, req, rid),
        ("GET", Route::DebugTraces) => debug_traces(state),
        ("GET", Route::DebugPlans) => debug_plans(state),
        ("GET", Route::DebugDrift) => debug_drift(state),
        (_, Route::Other) => error_json(404, "unknown_route", "unknown route"),
        _ => error_json(405, "method_not_allowed", "method not allowed for this route"),
    }
}

fn healthz(state: &ServiceState) -> HttpResponse {
    let body = Value::obj(vec![
        ("status", Value::str("ok")),
        ("backend", Value::str(state.engine.backend_name())),
        ("devices", Value::num(state.registry.len() as f64)),
        ("kernels", Value::num(state.kernel_count() as f64)),
        (
            "uptime_ms",
            Value::num(state.started.elapsed().as_secs_f64() * 1e3),
        ),
    ]);
    HttpResponse::json(200, body.render())
}

fn metrics_route(state: &ServiceState, metrics: &Metrics) -> HttpResponse {
    let scheduler = state.scheduler.lock().stats();
    let text = metrics.render(
        &state.engine.cache_stats(),
        state.started.elapsed(),
        state.engine.backend_name(),
        &state.accuracy.snapshot(),
        state.accuracy.dropped_total(),
        state.events.as_ref().map(|e| (e.emitted_total(), e.dropped_total())),
        &scheduler,
    );
    HttpResponse::text(200, text)
}

/// `POST /v2/observations`: ingest measured runtimes, score each one
/// against the model's prediction at the same frequency point, and fold
/// the absolute percent error into the per-(device, kernel) rolling
/// window that `/metrics` reports as `model_mape`.
///
/// Items are validated and resolved in full before any window is
/// touched, so a malformed batch leaves the accuracy state untouched.
fn v2_observations(state: &ServiceState, req: &HttpRequest, rid: Option<&str>) -> HttpResponse {
    let body = match parse_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let Some(items) = body.get("observations").and_then(Value::as_array) else {
        return error_json(400, "bad_request", "body needs `observations` (non-empty array)");
    };
    if items.is_empty() {
        return error_json(400, "bad_request", "`observations` must not be empty");
    }

    // Pass 1: resolve + validate everything, mutate nothing.
    let mut resolved: Vec<(DeviceId, KernelId, FreqPoint, f64)> = Vec::with_capacity(items.len());
    for (i, item) in items.iter().enumerate() {
        let ctx = format!("observations[{i}]");
        let (did, kid) = match resolve_item(state, item, &ctx) {
            Ok(pair) => pair,
            Err(resp) => return resp,
        };
        let num = |key: &str| item.get(key).and_then(Value::as_f64);
        let (Some(core), Some(mem)) = (num("core_mhz"), num("mem_mhz")) else {
            return error_json(
                400,
                "bad_request",
                &format!("{ctx} needs numeric `core_mhz` and `mem_mhz`"),
            );
        };
        let point = FreqPoint::new(core, mem);
        if !point.is_valid() {
            return error_json(
                400,
                "bad_request",
                &format!("{ctx}: frequencies must be positive and finite"),
            );
        }
        let measured_us = match (num("measured_us"), num("measured_ms")) {
            (Some(us), None) => us,
            (None, Some(ms)) => ms * 1e3,
            (Some(_), Some(_)) => {
                return error_json(
                    400,
                    "bad_request",
                    &format!("{ctx} has both `measured_us` and `measured_ms`; send one"),
                );
            }
            (None, None) => {
                return error_json(
                    400,
                    "bad_request",
                    &format!("{ctx} needs `measured_us` or `measured_ms`"),
                );
            }
        };
        if !(measured_us.is_finite() && measured_us > 0.0) {
            return error_json(
                400,
                "bad_request",
                &format!("{ctx}: measured runtime must be positive and finite"),
            );
        }
        resolved.push((did, kid, point, measured_us));
    }

    // Pass 2: predict and fold into the rolling windows. Labels are the
    // canonical handle forms ("dev-<n>"/"krn-<n>") so the same physical
    // series accumulates no matter how the client named the pair.
    let mut results = Vec::with_capacity(resolved.len());
    let mut dropped = 0u64;
    for (did, kid, point, measured_us) in resolved {
        let est = match state.engine.predict_handle(did, kid, point) {
            Ok(est) => est,
            Err(e) => return error_json(500, "internal", &format!("prediction failed: {e}")),
        };
        let obs = state.accuracy.observe_detailed(
            &did.to_string(),
            &kid.to_string(),
            est.time_us,
            measured_us,
        );
        if obs.is_none() {
            dropped += 1;
        }
        let fallback_pct = ((est.time_us - measured_us) / measured_us).abs() * 100.0;
        if let Some(sink) = &state.events {
            let mut ev = vec![("event", Value::str("observation"))];
            if let Some(rid) = rid {
                ev.push(("request_id", Value::str(rid)));
            }
            ev.push(("device", Value::str(did.to_string())));
            ev.push(("kernel", Value::str(kid.to_string())));
            ev.push(("predicted_us", Value::num(est.time_us)));
            ev.push(("measured_us", Value::num(measured_us)));
            ev.push((
                "abs_pct_error",
                Value::num(obs.map(|o| o.err_pct).unwrap_or(fallback_pct)),
            ));
            ev.push(("dropped", Value::Bool(obs.is_none())));
            sink.emit(Value::obj(ev).render());
            if let Some(o) = obs.filter(|o| o.transitioned()) {
                let mut ev = vec![("event", Value::str("drift_transition"))];
                if let Some(rid) = rid {
                    ev.push(("request_id", Value::str(rid)));
                }
                ev.push(("device", Value::str(did.to_string())));
                ev.push(("kernel", Value::str(kid.to_string())));
                ev.push(("from", Value::str(o.prev_state.name())));
                ev.push(("to", Value::str(o.state.name())));
                ev.push(("ewma_pct", Value::num(o.ewma_pct)));
                sink.emit(Value::obj(ev).render());
            }
        }
        results.push(Value::obj(vec![
            ("device", Value::str(did.to_string())),
            ("kernel", Value::str(kid.to_string())),
            ("core_mhz", Value::num(point.core_mhz)),
            ("mem_mhz", Value::num(point.mem_mhz)),
            ("predicted_us", Value::num(est.time_us)),
            ("measured_us", Value::num(measured_us)),
            ("abs_pct_error", Value::num(obs.map(|o| o.err_pct).unwrap_or(fallback_pct))),
        ]));
    }

    let count = results.len();
    let resp = Value::obj(vec![
        ("results", Value::arr(results)),
        ("count", Value::num(count as f64)),
        ("dropped", Value::num(dropped as f64)),
        ("samples_total", Value::num(state.accuracy.total_samples() as f64)),
    ]);
    HttpResponse::json(200, resp.render_sized(256 + 256 * count))
}

/// `GET /debug/traces`: dump the retained span records, newest first.
/// Intended for a human with `curl` chasing a latency report — the ring
/// is tiny and lock-free, so hitting this on a live server is safe.
fn debug_traces(state: &ServiceState) -> HttpResponse {
    let traces = state.traces.snapshot();
    let items: Vec<Value> = traces.iter().map(trace_json).collect();
    let count = items.len();
    let resp = Value::obj(vec![
        ("traces", Value::arr(items)),
        ("count", Value::num(count as f64)),
        ("capacity", Value::num(state.traces.capacity() as f64)),
        ("slow_us", Value::num(state.traces.slow_us())),
        ("recorded_total", Value::num(state.traces.recorded_total() as f64)),
        ("dropped_total", Value::num(state.traces.dropped_total() as f64)),
    ]);
    HttpResponse::json(200, resp.render_sized(256 + 512 * count))
}

fn trace_json(t: &TraceRecord) -> Value {
    let stages = Stage::ALL
        .iter()
        .map(|s| (s.name().to_string(), Value::num(t.stages_us[s.index()])))
        .collect();
    Value::obj(vec![
        ("id", Value::str(t.id.clone())),
        ("route", Value::str(t.route)),
        ("status", Value::num(t.status as f64)),
        ("total_us", Value::num(t.total_us())),
        ("stages_us", Value::Obj(stages)),
        (
            "cache",
            Value::obj(vec![
                ("hits", Value::num(t.cache_hits as f64)),
                ("misses", Value::num(t.cache_misses as f64)),
            ]),
        ),
        ("slab_calls", Value::num(t.slab_calls as f64)),
    ])
}

/// `GET /debug/plans`: dump the retained solve provenance, newest
/// first — plan ids, correlation keys, totals and the full telemetry
/// block, so "why did plan-N place job 3 there" survives the response.
fn debug_plans(state: &ServiceState) -> HttpResponse {
    let records = state.plans.snapshot();
    let items: Vec<Value> = records.iter().map(plan_record_json).collect();
    let count = items.len();
    let resp = Value::obj(vec![
        ("plans", Value::arr(items)),
        ("count", Value::num(count as f64)),
        ("capacity", Value::num(state.plans.capacity() as f64)),
        ("recorded_total", Value::num(state.plans.recorded_total() as f64)),
        ("dropped_total", Value::num(state.plans.dropped_total() as f64)),
    ]);
    HttpResponse::json(200, resp.render_sized(256 + 1024 * count))
}

fn plan_record_json(p: &PlanRecord) -> Value {
    Value::obj(vec![
        ("plan_id", Value::str(p.report.plan_id_str())),
        (
            "request_id",
            match &p.request_id {
                Some(r) => Value::str(r.clone()),
                None => Value::Null,
            },
        ),
        ("objective", Value::str(p.objective)),
        ("jobs", Value::num(p.jobs.len() as f64)),
        ("total_energy_mj", Value::num(p.total_energy_mj)),
        ("max_time_us", Value::num(p.max_time_us)),
        (
            "energy_savings_pct",
            match p.energy_savings_pct {
                Some(s) => Value::num(s),
                None => Value::Null,
            },
        ),
        ("telemetry", telemetry_json(&p.report, &p.jobs)),
    ])
}

/// `GET /debug/drift`: every accuracy series worst-first (highest
/// drift state, then highest EWMA) — the refit worklist for the
/// calibration loop.
fn debug_drift(state: &ServiceState) -> HttpResponse {
    let series = state.accuracy.drift_snapshot();
    let items: Vec<Value> = series
        .iter()
        .map(|s| {
            Value::obj(vec![
                ("device", Value::str(s.device.clone())),
                ("kernel", Value::str(s.kernel.clone())),
                ("state", Value::str(s.state.name())),
                ("ewma_pct", Value::num(s.ewma_pct)),
                ("mape_pct", Value::num(s.mape_pct)),
                ("window", Value::num(s.window as f64)),
                ("samples", Value::num(s.samples as f64)),
            ])
        })
        .collect();
    let count = items.len();
    let resp = Value::obj(vec![
        ("series", Value::arr(items)),
        ("count", Value::num(count as f64)),
        ("samples_dropped_total", Value::num(state.accuracy.dropped_total() as f64)),
    ]);
    HttpResponse::json(200, resp.render_sized(128 + 192 * count))
}

/// The `"telemetry"` block of a `/v2/plan` response (and of each
/// `/debug/plans` record): the solve's phase spans, work counters and
/// per-assignment provenance. `names` maps `Explain::job` to job
/// names.
fn telemetry_json(r: &SolveReport, names: &[String]) -> Value {
    Value::obj(vec![
        ("plan_id", Value::str(r.plan_id_str())),
        (
            "phase_us",
            Value::obj(vec![
                ("build", Value::num(r.build_us)),
                ("greedy", Value::num(r.greedy_us)),
                ("repair", Value::num(r.repair_us)),
                ("swap", Value::num(r.swap_us)),
                ("total", Value::num(r.total_us)),
            ]),
        ),
        (
            "counters",
            Value::obj(vec![
                ("candidates_evaluated", Value::num(r.candidates_evaluated as f64)),
                ("slab_calls", Value::num(r.slab_calls as f64)),
                ("relocations_tried", Value::num(r.relocations_tried as f64)),
                ("relocations_accepted", Value::num(r.relocations_accepted as f64)),
                ("swaps_tried", Value::num(r.swaps_tried as f64)),
                ("swaps_accepted", Value::num(r.swaps_accepted as f64)),
            ]),
        ),
        (
            "explains",
            Value::arr(r.explains.iter().map(|e| explain_json(e, names)).collect()),
        ),
    ])
}

fn explain_json(e: &Explain, names: &[String]) -> Value {
    Value::obj(vec![
        ("job", Value::num(e.job as f64)),
        (
            "name",
            match names.get(e.job) {
                Some(n) => Value::str(n.clone()),
                None => Value::Null,
            },
        ),
        (
            "deadline_slack_us",
            match e.deadline_slack_us {
                Some(s) => Value::num(s),
                None => Value::Null,
            },
        ),
        ("energy_delta_vs_max_mj", Value::num(e.energy_delta_vs_max_mj)),
        (
            "runner_up",
            match &e.runner_up {
                Some(r) => runner_up_json(r),
                None => Value::Null,
            },
        ),
    ])
}

fn runner_up_json(r: &RunnerUp) -> Value {
    Value::obj(vec![
        ("core_mhz", Value::num(r.point.core_mhz)),
        ("mem_mhz", Value::num(r.point.mem_mhz)),
        ("time_us", Value::num(r.time_us)),
        ("energy_mj", Value::num(r.energy_mj)),
        ("rejected_by", Value::str(r.rejected_by)),
    ])
}

/// Resolve the v1 request's kernel: a registered profile name or an
/// inline `counters` object (the anonymous-kernel shim path).
fn resolve_counters(state: &ServiceState, body: &Value) -> Result<KernelCounters, String> {
    if let Some(name) = body.get("kernel").and_then(Value::as_str) {
        return state.counters_for(name).ok_or_else(|| {
            format!(
                "unknown kernel `{name}` (registered: {})",
                state.kernel_names().join(", ")
            )
        });
    }
    let Some(c) = body.get("counters") else {
        return Err("body needs `kernel` (string) or `counters` (object)".to_string());
    };
    counters_from_json(c)
}

/// Strict-ish counters decoding: the fields the model always reads are
/// required; the rest default like a simple global-memory kernel.
/// Every numeric field must be non-negative and finite (the catalog
/// persists these — a poisoned record would serve NaN/negative
/// predictions to every client), and the model's divisors (`aw`,
/// `n_sm`) must be positive.
fn counters_from_json(v: &Value) -> Result<KernelCounters, String> {
    let number = |key: &str, x: &Value| -> Result<f64, String> {
        match x.as_f64() {
            Some(f) if f.is_finite() && f >= 0.0 => Ok(f),
            _ => Err(format!("counters.{key} must be a non-negative finite number")),
        }
    };
    let req = |key: &str| -> Result<f64, String> {
        match v.get(key) {
            Some(x) => number(key, x),
            None => Err(format!("counters.{key} must be a number")),
        }
    };
    let opt = |key: &str, default: f64| -> Result<f64, String> {
        match v.get(key) {
            None => Ok(default),
            Some(x) => number(key, x),
        }
    };
    for key in ["aw", "n_sm"] {
        // NaN falls through here and is rejected by `number` below.
        if let Some(f) = v.get(key).and_then(Value::as_f64) {
            if f <= 0.0 {
                return Err(format!("counters.{key} must be positive (the model divides by it)"));
            }
        }
    }
    let gld_trans = req("gld_trans")?;
    Ok(KernelCounters {
        l2_hr: req("l2_hr")?,
        gld_trans,
        avr_inst: req("avr_inst")?,
        n_blocks: req("n_blocks")?,
        wpb: req("wpb")?,
        aw: req("aw")?,
        n_sm: req("n_sm")?,
        o_itrs: req("o_itrs")?,
        i_itrs: opt("i_itrs", 0.0)?,
        uses_smem: match v.get("uses_smem") {
            None => false,
            Some(b) => b
                .as_bool()
                .ok_or_else(|| "counters.uses_smem must be a bool".to_string())?,
        },
        smem_conflict: opt("smem_conflict", 1.0)?,
        gld_body: opt("gld_body", gld_trans)?,
        gld_edge: opt("gld_edge", 0.0)?,
        mem_ops: opt("mem_ops", 1.0)?,
        l1_hr: opt("l1_hr", 0.0)?,
    })
}

/// Render counters back to the wire shape `counters_from_json` accepts.
/// Exhaustive destructuring (no `..`), like the engine's cache key:
/// adding a `KernelCounters` field without extending the wire encoding
/// is a compile error, never a silently-dropped field.
fn counters_json(c: &KernelCounters) -> Value {
    let KernelCounters {
        l2_hr,
        gld_trans,
        avr_inst,
        n_blocks,
        wpb,
        aw,
        n_sm,
        o_itrs,
        i_itrs,
        uses_smem,
        smem_conflict,
        gld_body,
        gld_edge,
        mem_ops,
        l1_hr,
    } = *c;
    Value::obj(vec![
        ("l2_hr", Value::num(l2_hr)),
        ("gld_trans", Value::num(gld_trans)),
        ("avr_inst", Value::num(avr_inst)),
        ("n_blocks", Value::num(n_blocks)),
        ("wpb", Value::num(wpb)),
        ("aw", Value::num(aw)),
        ("n_sm", Value::num(n_sm)),
        ("o_itrs", Value::num(o_itrs)),
        ("i_itrs", Value::num(i_itrs)),
        ("uses_smem", Value::Bool(uses_smem)),
        ("smem_conflict", Value::num(smem_conflict)),
        ("gld_body", Value::num(gld_body)),
        ("gld_edge", Value::num(gld_edge)),
        ("mem_ops", Value::num(mem_ops)),
        ("l1_hr", Value::num(l1_hr)),
    ])
}

/// Exhaustive destructuring for the same reason as `counters_json`.
fn hw_json(hw: &HwParams) -> Value {
    let HwParams { dm_lat_a, dm_lat_b, dm_del, l2_lat, l2_del, sh_lat, inst_cycle } = *hw;
    Value::obj(vec![
        ("dm_lat_a", Value::num(dm_lat_a)),
        ("dm_lat_b", Value::num(dm_lat_b)),
        ("dm_del", Value::num(dm_del)),
        ("l2_lat", Value::num(l2_lat)),
        ("l2_del", Value::num(l2_del)),
        ("sh_lat", Value::num(sh_lat)),
        ("inst_cycle", Value::num(inst_cycle)),
    ])
}

/// Decode a partial `hw` object over `defaults` (the boot device's
/// measured parameters); every present field must be a finite number.
fn hw_from_json(v: &Value, defaults: HwParams) -> Result<HwParams, String> {
    if !matches!(v, Value::Obj(_)) {
        return Err("`hw` must be an object".to_string());
    }
    let field = |key: &str, default: f64| -> Result<f64, String> {
        match v.get(key) {
            None => Ok(default),
            Some(x) => match x.as_f64() {
                Some(f) if f.is_finite() && f >= 0.0 => Ok(f),
                _ => Err(format!("hw.{key} must be a non-negative finite number")),
            },
        }
    };
    Ok(HwParams {
        dm_lat_a: field("dm_lat_a", defaults.dm_lat_a)?,
        dm_lat_b: field("dm_lat_b", defaults.dm_lat_b)?,
        dm_del: field("dm_del", defaults.dm_del)?,
        l2_lat: field("l2_lat", defaults.l2_lat)?,
        l2_del: field("l2_del", defaults.l2_del)?,
        sh_lat: field("sh_lat", defaults.sh_lat)?,
        inst_cycle: field("inst_cycle", defaults.inst_cycle)?,
    })
}

/// Decode a `[[mhz, volts], ...]` V/f curve; validity (non-empty,
/// positive finite, strictly ascending) is enforced by the shared
/// `VfCurve::try_from_points` constructor.
fn vf_from_json(v: &Value, key: &str) -> Result<VfCurve, String> {
    let items = v
        .as_array()
        .ok_or_else(|| format!("power.{key} must be an array of [mhz, volts] pairs"))?;
    let mut points = Vec::with_capacity(items.len());
    for (i, item) in items.iter().enumerate() {
        let pair = item
            .as_array()
            .ok_or_else(|| format!("power.{key}[{i}] must be [mhz, volts]"))?;
        let (Some(f), Some(volts)) = (
            pair.first().and_then(Value::as_f64),
            pair.get(1).and_then(Value::as_f64),
        ) else {
            return Err(format!("power.{key}[{i}] must be two numbers"));
        };
        if pair.len() != 2 {
            return Err(format!("power.{key}[{i}] must be exactly [mhz, volts]"));
        }
        points.push((f, volts));
    }
    VfCurve::try_from_points(points).map_err(|m| format!("power.{key}: {m}"))
}

/// Decode a partial `power` object over `defaults` (the boot device's
/// power model — mirroring how partial `hw` inherits the boot GPU's
/// measured parameters).
fn power_from_json(v: &Value, defaults: &PowerModel) -> Result<PowerModel, String> {
    if !matches!(v, Value::Obj(_)) {
        return Err("`power` must be an object".to_string());
    }
    let d = defaults.clone();
    let coeff = |key: &str, default: f64| -> Result<f64, String> {
        match v.get(key) {
            None => Ok(default),
            Some(x) => match x.as_f64() {
                Some(f) if f.is_finite() && f >= 0.0 => Ok(f),
                _ => Err(format!("power.{key} must be a non-negative finite number")),
            },
        }
    };
    let positive = |key: &str, default: f64| -> Result<f64, String> {
        match v.get(key) {
            None => Ok(default),
            Some(x) => match x.as_f64() {
                Some(f) if f.is_finite() && f > 0.0 => Ok(f),
                _ => Err(format!("power.{key} must be a positive finite number")),
            },
        }
    };
    Ok(PowerModel {
        core_curve: match v.get("core_vf") {
            None => d.core_curve,
            Some(c) => vf_from_json(c, "core_vf")?,
        },
        mem_curve: match v.get("mem_vf") {
            None => d.mem_curve,
            Some(c) => vf_from_json(c, "mem_vf")?,
        },
        dynamic: DynamicParams {
            core_coeff: coeff("core_coeff", d.dynamic.core_coeff)?,
            mem_coeff: coeff("mem_coeff", d.dynamic.mem_coeff)?,
        },
        leakage: LeakageParams {
            static_w: coeff("static_w", d.leakage.static_w)?,
            leak_w: coeff("leak_w", d.leakage.leak_w)?,
            v_ref: positive("leak_v_ref", d.leakage.v_ref)?,
            v_slope: positive("leak_v_slope", d.leakage.v_slope)?,
        },
    })
}

/// Decode an optional `pairs` array; fall back to the default grid.
fn resolve_pairs(state: &ServiceState, body: &Value) -> Result<Vec<(f64, f64)>, String> {
    let Some(raw) = body.get("pairs") else {
        return Ok(state.default_pairs.clone());
    };
    let items = raw
        .as_array()
        .ok_or_else(|| "`pairs` must be an array of [core_mhz, mem_mhz]".to_string())?;
    if items.is_empty() {
        return Err("`pairs` must not be empty".to_string());
    }
    let mut out = Vec::with_capacity(items.len());
    for (i, item) in items.iter().enumerate() {
        let pair = item.as_array().ok_or_else(|| format!("pairs[{i}] must be [core, mem]"))?;
        let (Some(cf), Some(mf)) = (
            pair.first().and_then(Value::as_f64),
            pair.get(1).and_then(Value::as_f64),
        ) else {
            return Err(format!("pairs[{i}] must be two numbers"));
        };
        if !(cf.is_finite() && mf.is_finite() && cf > 0.0 && mf > 0.0) || pair.len() != 2 {
            return Err(format!("pairs[{i}] must be two positive finite frequencies"));
        }
        out.push((cf, mf));
    }
    Ok(out)
}

fn parse_body(req: &HttpRequest) -> Result<Value, HttpResponse> {
    let text = req
        .body_str()
        .map_err(|e| error_json(400, "bad_json", &e.message))?;
    if text.trim().is_empty() {
        return Err(error_json(400, "bad_json", "request body must be a JSON object"));
    }
    Value::parse(text).map_err(|e| error_json(400, "bad_json", &e.to_string()))
}

fn estimate_json(cf: f64, mf: f64, e: &Estimate) -> Value {
    Value::obj(vec![
        ("core_mhz", Value::num(cf)),
        ("mem_mhz", Value::num(mf)),
        ("time_us", Value::num(e.time_us)),
        ("t_active", Value::num(e.t_active)),
        ("t_exec_cycles", Value::num(e.t_exec_cycles)),
        (
            "regime",
            match e.regime {
                Some(r) => Value::str(format!("{r:?}")),
                None => Value::Null,
            },
        ),
    ])
}

fn config_point_json(p: &ConfigPoint) -> Value {
    Value::obj(vec![
        ("core_mhz", Value::num(p.core_mhz)),
        ("mem_mhz", Value::num(p.mem_mhz)),
        ("time_us", Value::num(p.time_us)),
        ("power_w", Value::num(p.power_w)),
        ("power_dynamic_w", Value::num(p.power_dynamic_w)),
        ("power_leakage_w", Value::num(p.power_leakage_w)),
        ("energy_mj", Value::num(p.energy_mj)),
        ("edp", Value::num(p.edp)),
    ])
}

/// `POST /v1/predict` — one estimate at one frequency pair on the
/// default device.
fn predict(state: &ServiceState, req: &HttpRequest) -> HttpResponse {
    let body = match parse_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let counters = match resolve_counters(state, &body) {
        Ok(c) => c,
        Err(m) => return error_json(400, v1_kernel_code(&body), &m),
    };
    let (Some(cf), Some(mf)) = (
        body.get("core_mhz").and_then(Value::as_f64),
        body.get("mem_mhz").and_then(Value::as_f64),
    ) else {
        return error_json(400, "bad_request", "body needs numeric `core_mhz` and `mem_mhz`");
    };
    if !(cf.is_finite() && mf.is_finite() && cf > 0.0 && mf > 0.0) {
        return error_json(400, "bad_request", "frequencies must be positive finite MHz");
    }
    match state.engine.predict_one(&counters, cf, mf) {
        Ok(e) => HttpResponse::json(200, estimate_json(cf, mf, &e).render()),
        Err(e) => error_json(500, "internal", &format!("prediction failed: {e:#}")),
    }
}

/// Error code for a failed v1 kernel resolution: an unknown *named*
/// kernel is `unknown_kernel`; malformed/missing counters are
/// `bad_request`.
fn v1_kernel_code(body: &Value) -> &'static str {
    if body.get("kernel").and_then(Value::as_str).is_some() {
        "unknown_kernel"
    } else {
        "bad_request"
    }
}

/// `POST /v1/grid` — a whole frequency-grid sweep on the default
/// device (cache-served on repeats; the response carries the engine's
/// cache counters).
fn grid(state: &ServiceState, req: &HttpRequest) -> HttpResponse {
    let body = match parse_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let counters = match resolve_counters(state, &body) {
        Ok(c) => c,
        Err(m) => return error_json(400, v1_kernel_code(&body), &m),
    };
    let pairs = match resolve_pairs(state, &body) {
        Ok(p) => p,
        Err(m) => return error_json(400, "bad_request", &m),
    };
    let ests = match state.engine.predict_grid(&counters, &pairs) {
        Ok(v) => v,
        Err(e) => return error_json(500, "internal", &format!("prediction failed: {e:#}")),
    };
    let cache = state.engine.cache_stats();
    let points: Vec<Value> = pairs
        .iter()
        .zip(&ests)
        .map(|(&(cf, mf), e)| estimate_json(cf, mf, e))
        .collect();
    let resp = Value::obj(vec![
        ("points", Value::arr(points)),
        (
            "cache",
            Value::obj(vec![
                ("hits", Value::num(cache.hits as f64)),
                ("misses", Value::num(cache.misses as f64)),
                ("entries", Value::num(cache.entries as f64)),
                ("evictions", Value::num(cache.evictions as f64)),
            ]),
        ),
    ]);
    HttpResponse::json(200, resp.render())
}

fn parse_objective(body: &Value) -> Result<Objective, String> {
    match body.get("objective") {
        None => Ok(Objective::Energy),
        Some(Value::Str(s)) => match s.as_str() {
            "energy" => Ok(Objective::Energy),
            "edp" => Ok(Objective::Edp),
            other => Err(format!("unknown objective `{other}` (energy | edp | {{\"slack\": f}})")),
        },
        Some(obj) => obj
            .get("slack")
            .and_then(Value::as_f64)
            .map(Objective::EnergyWithSlack)
            .ok_or_else(|| "objective must be \"energy\", \"edp\" or {\"slack\": f}".to_string()),
    }
}

fn parse_deadline(body: &Value) -> Result<Option<f64>, String> {
    match body.get("deadline_us") {
        None => Ok(None),
        Some(v) => match v.as_f64() {
            Some(d) if d > 0.0 && d.is_finite() => Ok(Some(d)),
            _ => Err("`deadline_us` must be a positive finite number".to_string()),
        },
    }
}

/// Shared v1/v2 advise response assembly: apply the absolute-deadline
/// re-selection (fall back to the fastest point with `feasible:false`
/// when nothing meets it — a real-time controller still needs *a*
/// setting to apply), then render. `extra` fields lead the object
/// (the v2 handlers echo the resolved handles there).
fn advise_payload(
    best: ConfigPoint,
    points: &[ConfigPoint],
    objective: Objective,
    deadline_us: Option<f64>,
    include_points: bool,
    extra: Vec<(&str, Value)>,
) -> Value {
    let fastest = *points
        .iter()
        .min_by(|a, b| a.time_us.total_cmp(&b.time_us))
        .expect("non-empty grid");
    let (best, feasible) = match deadline_us {
        None => (best, true),
        Some(deadline) => {
            let key = |p: &ConfigPoint| match objective {
                Objective::Edp => p.edp,
                _ => p.energy_mj,
            };
            let within = points
                .iter()
                .filter(|p| p.time_us <= deadline)
                .min_by(|a, b| key(a).total_cmp(&key(b)));
            match within {
                Some(p) => (*p, true),
                None => (fastest, false),
            }
        }
    };
    let mut fields = extra;
    fields.push((
        "objective",
        Value::str(match objective {
            Objective::Energy => "energy".to_string(),
            Objective::Edp => "edp".to_string(),
            Objective::EnergyWithSlack(s) => format!("slack:{s}"),
        }),
    ));
    fields.push(("feasible", Value::Bool(feasible)));
    fields.push(("best", config_point_json(&best)));
    fields.push(("fastest", config_point_json(&fastest)));
    fields.push(("points_evaluated", Value::num(points.len() as f64)));
    if let Some(d) = deadline_us {
        fields.push(("deadline_us", Value::num(d)));
    }
    if include_points {
        fields.push((
            "points",
            Value::arr(points.iter().map(config_point_json).collect()),
        ));
    }
    Value::obj(fields)
}

/// `POST /v1/advise` — the DVFS oracle on the default device:
/// energy-optimal (core, mem) under an optional absolute deadline (the
/// paper's §VII real-time controller application).
fn advise(state: &ServiceState, req: &HttpRequest) -> HttpResponse {
    let body = match parse_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let counters = match resolve_counters(state, &body) {
        Ok(c) => c,
        Err(m) => return error_json(400, v1_kernel_code(&body), &m),
    };
    let pairs = match resolve_pairs(state, &body) {
        Ok(p) => p,
        Err(m) => return error_json(400, "bad_request", &m),
    };
    let objective = match parse_objective(&body) {
        Ok(o) => o,
        Err(m) => return error_json(400, "bad_request", &m),
    };
    let deadline_us = match parse_deadline(&body) {
        Ok(d) => d,
        Err(m) => return error_json(400, "bad_request", &m),
    };
    let (best, points) =
        match crate::dvfs::advise_with_engine(&counters, &state.engine, &state.power, &pairs, objective)
        {
            Ok(r) => r,
            Err(e) => return error_json(500, "internal", &format!("advisor failed: {e:#}")),
        };
    let include_points = body.get("include_points").and_then(Value::as_bool) == Some(true);
    let payload =
        advise_payload(best, &points, objective, deadline_us, include_points, Vec::new());
    HttpResponse::json(200, payload.render())
}

/// Registration bounds: records are immutable and never evicted (that
/// is what makes the handles stable), so a public service must bound
/// how many an unauthenticated client can create. Past the bound,
/// registration answers 429 `registry_full`; prediction routes are
/// unaffected.
const MAX_DEVICES: usize = 1024;
const MAX_KERNELS: usize = 4096;

/// `POST /v2/devices` — register a GPU: a name plus (optionally
/// partial) measured `hw` parameters and a `power` model (both
/// defaulting field-wise to the boot device's). Returns the fresh
/// `dev-<n>` handle. Re-registering a name mints a new handle; the
/// name resolves to the newest record.
fn v2_register_device(state: &ServiceState, req: &HttpRequest) -> HttpResponse {
    let body = match parse_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let Some(name) = body.get("name").and_then(Value::as_str).filter(|n| !n.is_empty()) else {
        return error_json(400, "bad_request", "body needs a non-empty `name` string");
    };
    let hw = match body.get("hw") {
        None => *state.engine.hw(),
        Some(o) => match hw_from_json(o, *state.engine.hw()) {
            Ok(hw) => hw,
            Err(m) => return error_json(400, "bad_request", &m),
        },
    };
    let power = match body.get("power") {
        None => state.power.clone(),
        Some(o) => match power_from_json(o, &state.power) {
            Ok(p) => p,
            Err(m) => return error_json(400, "bad_request", &m),
        },
    };
    // Name validity and the bound are enforced by the registry itself
    // (the bound inside its write lock, so concurrent workers cannot
    // overshoot it).
    let id = match state.registry.try_register(name, hw, power, MAX_DEVICES) {
        Ok(id) => id,
        Err(RegisterError::Full) => {
            return error_json(429, "registry_full", "device registry is full")
        }
        Err(e) => return error_json(400, "bad_request", &e.to_string()),
    };
    let resp = Value::obj(vec![
        ("device", Value::str(id.to_string())),
        ("name", Value::str(name)),
        ("hw", hw_json(&hw)),
    ]);
    HttpResponse::json(200, resp.render())
}

fn device_json(r: &DeviceRecord) -> Value {
    Value::obj(vec![
        ("device", Value::str(r.id.to_string())),
        ("name", Value::str(r.name.clone())),
        ("hw", hw_json(&r.hw)),
    ])
}

/// `GET /v2/devices` — every registered device, in registration order.
fn v2_list_devices(state: &ServiceState) -> HttpResponse {
    let records = state.registry.list();
    let resp = Value::obj(vec![
        ("devices", Value::arr(records.iter().map(device_json).collect())),
        ("count", Value::num(records.len() as f64)),
    ]);
    HttpResponse::json(200, resp.render())
}

/// `POST /v2/kernels` — catalogue a kernel's baseline-profiled
/// counters under a name. Returns the `krn-<n>` handle; re-registering
/// a known name keeps its handle and updates the counters.
fn v2_register_kernel(state: &ServiceState, req: &HttpRequest) -> HttpResponse {
    let body = match parse_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let Some(name) = body.get("name").and_then(Value::as_str).filter(|n| !n.is_empty()) else {
        return error_json(400, "bad_request", "body needs a non-empty `name` string");
    };
    let Some(raw) = body.get("counters") else {
        return error_json(400, "bad_request", "body needs a `counters` object");
    };
    let counters = match counters_from_json(raw) {
        Ok(c) => c,
        Err(m) => return error_json(400, "bad_request", &m),
    };
    // Re-profiling a known name updates in place; only NEW names grow
    // the catalog, so only they hit the bound (checked inside the
    // catalog's write lock — concurrency-safe).
    let id = match state.catalog.try_register(name, counters, MAX_KERNELS) {
        Ok(id) => id,
        Err(RegisterError::Full) => {
            return error_json(429, "registry_full", "kernel catalog is full")
        }
        Err(e) => return error_json(400, "bad_request", &e.to_string()),
    };
    let resp = Value::obj(vec![
        ("kernel", Value::str(id.to_string())),
        ("name", Value::str(name)),
    ]);
    HttpResponse::json(200, resp.render())
}

/// `GET /v2/kernels` — the catalogue, counters included.
fn v2_list_kernels(state: &ServiceState) -> HttpResponse {
    let entries = state.catalog.list();
    let kernels: Vec<Value> = entries
        .iter()
        .map(|e| {
            Value::obj(vec![
                ("kernel", Value::str(e.id.to_string())),
                ("name", Value::str(e.name.clone())),
                ("counters", counters_json(&e.counters)),
            ])
        })
        .collect();
    let resp = Value::obj(vec![
        ("kernels", Value::arr(kernels)),
        ("count", Value::num(entries.len() as f64)),
    ]);
    HttpResponse::json(200, resp.render())
}

/// Resolve one v2 request item's handles to ids (no record clones —
/// consumers that need the full record fetch it through the engine),
/// or answer with the right structured error (404
/// `unknown_device`/`unknown_kernel`, 400 `bad_request`).
fn resolve_item(
    state: &ServiceState,
    item: &Value,
    ctx: &str,
) -> Result<(DeviceId, KernelId), HttpResponse> {
    let Some(device) = item.get("device").and_then(Value::as_str) else {
        return Err(error_json(
            400,
            "bad_request",
            &format!("{ctx}: `device` must be a handle string (dev-<n> or a name)"),
        ));
    };
    let Some(kernel) = item.get("kernel").and_then(Value::as_str) else {
        return Err(error_json(
            400,
            "bad_request",
            &format!("{ctx}: `kernel` must be a handle string (krn-<n> or a name)"),
        ));
    };
    let Some(did) = state.registry.resolve_id(device) else {
        return Err(error_json(
            404,
            "unknown_device",
            &format!("{ctx}: unknown device `{device}`"),
        ));
    };
    let Some(kid) = state.catalog.resolve_id(kernel) else {
        return Err(error_json(
            404,
            "unknown_kernel",
            &format!("{ctx}: unknown kernel `{kernel}`"),
        ));
    };
    Ok((did, kid))
}

/// `POST /v2/predict` — the batch-first handle path: many
/// `(device, kernel, frequency)` tuples per request, answered in
/// order. The whole batch resolves before anything is predicted, so a
/// single bad tuple fails the request without partial work.
fn v2_predict(state: &ServiceState, req: &HttpRequest) -> HttpResponse {
    let body = match parse_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let Some(items) = body.get("requests").and_then(Value::as_array) else {
        return error_json(400, "bad_request", "body needs a `requests` array");
    };
    if items.is_empty() {
        return error_json(400, "bad_request", "`requests` must not be empty");
    }
    let mut tuples = Vec::with_capacity(items.len());
    for (i, item) in items.iter().enumerate() {
        let ctx = format!("requests[{i}]");
        // resolve_item is id-only (no record clones); the engine
        // memoizes the actual record fetch per distinct handle.
        let (did, kid) = match resolve_item(state, item, &ctx) {
            Ok(pair) => pair,
            Err(resp) => return resp,
        };
        let (Some(cf), Some(mf)) = (
            item.get("core_mhz").and_then(Value::as_f64),
            item.get("mem_mhz").and_then(Value::as_f64),
        ) else {
            return error_json(
                400,
                "bad_request",
                &format!("{ctx}: needs numeric `core_mhz` and `mem_mhz`"),
            );
        };
        let point = FreqPoint::new(cf, mf);
        if !point.is_valid() {
            return error_json(
                400,
                "bad_request",
                &format!("{ctx}: frequencies must be positive finite MHz"),
            );
        }
        tuples.push((did, kid, point));
    }
    let estimates = match state.engine.predict_tuples(&tuples) {
        Ok(v) => v,
        Err(e) => return error_json(500, "internal", &format!("prediction failed: {e:#}")),
    };
    let results: Vec<Value> = estimates
        .iter()
        .zip(&tuples)
        .map(|(e, &(d, k, p))| {
            let mut fields = vec![
                ("device".to_string(), Value::str(d.to_string())),
                ("kernel".to_string(), Value::str(k.to_string())),
            ];
            if let Value::Obj(rest) = estimate_json(p.core_mhz, p.mem_mhz, e) {
                fields.extend(rest);
            }
            Value::Obj(fields)
        })
        .collect();
    let resp = Value::obj(vec![
        ("results", Value::arr(results)),
        ("count", Value::num(tuples.len() as f64)),
    ]);
    // One result object per tuple at ~200 bytes (two handle strings,
    // five numeric fields) plus envelope — sized up front so large
    // batches serialize without doubling reallocations.
    HttpResponse::json(200, resp.render_sized(48 + 200 * tuples.len()))
}

/// `POST /v2/advise` — the DVFS oracle through handles: the device's
/// own registered power model drives the energy arithmetic.
fn v2_advise(state: &ServiceState, req: &HttpRequest) -> HttpResponse {
    let body = match parse_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let (did, kid) = match resolve_item(state, &body, "body") {
        Ok(pair) => pair,
        Err(resp) => return resp,
    };
    let pairs = match resolve_pairs(state, &body) {
        Ok(p) => p,
        Err(m) => return error_json(400, "bad_request", &m),
    };
    let objective = match parse_objective(&body) {
        Ok(o) => o,
        Err(m) => return error_json(400, "bad_request", &m),
    };
    let deadline_us = match parse_deadline(&body) {
        Ok(d) => d,
        Err(m) => return error_json(400, "bad_request", &m),
    };
    let (best, points) =
        match crate::dvfs::advise_with_handles(&state.engine, did, kid, &pairs, objective) {
            Ok(r) => r,
            Err(e) => return error_json(500, "internal", &format!("advisor failed: {e:#}")),
        };
    let include_points = body.get("include_points").and_then(Value::as_bool) == Some(true);
    let extra = vec![
        ("device", Value::str(did.to_string())),
        ("kernel", Value::str(kid.to_string())),
    ];
    let payload = advise_payload(best, &points, objective, deadline_us, include_points, extra);
    HttpResponse::json(200, payload.render())
}

/// Map a typed [`PlanError`] onto the service's `{error, code}`
/// taxonomy. Infeasibility is its own 422 code — the request was
/// well-formed, the constraints just cannot be satisfied, and a
/// scheduler must tell those apart from malformed input.
fn plan_error(e: &PlanError) -> HttpResponse {
    match e {
        PlanError::Invalid(_) => error_json(400, "bad_request", &e.to_string()),
        PlanError::UnknownKernel { .. } => error_json(404, "unknown_kernel", &e.to_string()),
        PlanError::UnknownDevice { .. } => error_json(404, "unknown_device", &e.to_string()),
        PlanError::Infeasible { .. } => error_json(422, "infeasible", &e.to_string()),
        PlanError::Engine(_) => error_json(500, "internal", &e.to_string()),
    }
}

/// `POST /v2/plan` — the fleet-level DVFS planner (DESIGN.md §11):
/// assign a batch of jobs to registered devices and per-job
/// (core, mem) operating points, minimizing total energy (or EDP)
/// while meeting every per-job deadline. The response carries the
/// max-frequency baseline for the same fleet so callers can see what
/// the plan saves. Every response carries a fresh `plan_id` and the
/// solve's `"telemetry"` block; the solve is retained in the
/// provenance ring (`GET /debug/plans`) and folded into the
/// `planner_*` series in `/metrics`.
fn v2_plan(
    state: &ServiceState,
    metrics: &Metrics,
    req: &HttpRequest,
    rid: Option<&str>,
) -> HttpResponse {
    let body = match parse_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let Some(items) = body.get("jobs").and_then(Value::as_array) else {
        return error_json(400, "bad_request", "body needs a `jobs` array");
    };
    if items.is_empty() {
        return error_json(400, "bad_request", "`jobs` must not be empty");
    }
    // Early refusal with the solver's own bound — one source of truth
    // — so an oversized request is rejected before every job parses.
    if items.len() > planner::MAX_JOBS {
        return error_json(
            400,
            "bad_request",
            &format!("`jobs` is limited to {} per request", planner::MAX_JOBS),
        );
    }
    let mut jobs = Vec::with_capacity(items.len());
    for (i, item) in items.iter().enumerate() {
        let ctx = format!("jobs[{i}]");
        let Some(kernel) = item.get("kernel").and_then(Value::as_str) else {
            return error_json(
                400,
                "bad_request",
                &format!("{ctx}: `kernel` must be a handle string (krn-<n> or a name)"),
            );
        };
        let Some(kid) = state.catalog.resolve_id(kernel) else {
            return error_json(
                404,
                "unknown_kernel",
                &format!("{ctx}: unknown kernel `{kernel}`"),
            );
        };
        let scale = match item.get("scale") {
            None => 1.0,
            Some(v) => match v.as_f64() {
                Some(s) if s.is_finite() && s > 0.0 => s,
                _ => {
                    return error_json(
                        400,
                        "bad_request",
                        &format!("{ctx}: `scale` must be a positive finite number"),
                    )
                }
            },
        };
        let name = item
            .get("name")
            .and_then(Value::as_str)
            .map(str::to_string)
            .unwrap_or_else(|| format!("job-{i}"));
        let mut job = Job::new(name, kid, scale);
        match item.get("deadline_us") {
            None => {}
            Some(v) => match v.as_f64() {
                Some(d) if d.is_finite() && d > 0.0 => job = job.with_deadline(d),
                _ => {
                    return error_json(
                        400,
                        "bad_request",
                        &format!("{ctx}: `deadline_us` must be a positive finite number"),
                    )
                }
            },
        }
        jobs.push(job);
    }
    let devices = match body.get("devices") {
        None => None,
        Some(v) => {
            let Some(handles) = v.as_array() else {
                return error_json(
                    400,
                    "bad_request",
                    "`devices` must be an array of handle strings",
                );
            };
            if handles.is_empty() {
                return error_json(400, "bad_request", "`devices` must not be empty");
            }
            let mut ids = Vec::with_capacity(handles.len());
            for (i, h) in handles.iter().enumerate() {
                let Some(s) = h.as_str() else {
                    return error_json(
                        400,
                        "bad_request",
                        &format!("devices[{i}] must be a handle string (dev-<n> or a name)"),
                    );
                };
                let Some(id) = state.registry.resolve_id(s) else {
                    return error_json(
                        404,
                        "unknown_device",
                        &format!("devices[{i}]: unknown device `{s}`"),
                    );
                };
                ids.push(id);
            }
            Some(ids)
        }
    };
    let objective = match body.get("objective") {
        None => PlanObjective::Energy,
        Some(Value::Str(s)) => match s.as_str() {
            "energy" => PlanObjective::Energy,
            "edp" => PlanObjective::Edp,
            other => {
                return error_json(
                    400,
                    "bad_request",
                    &format!("unknown objective `{other}` (energy | edp)"),
                )
            }
        },
        Some(_) => {
            return error_json(400, "bad_request", "objective must be \"energy\" or \"edp\"")
        }
    };
    let device_cap = match body.get("device_cap") {
        None => usize::MAX,
        Some(v) => match v.as_f64() {
            Some(c) if c.is_finite() && c >= 1.0 && c.fract() == 0.0 && c <= 1e9 => c as usize,
            _ => {
                return error_json(
                    400,
                    "bad_request",
                    "`device_cap` must be a positive integer",
                )
            }
        },
    };
    let pairs = match body.get("pairs") {
        None => None,
        Some(_) => match resolve_pairs(state, &body) {
            Ok(p) => Some(p),
            Err(m) => return error_json(400, "bad_request", &m),
        },
    };
    let cfg = PlannerConfig {
        objective,
        devices,
        device_cap,
        pairs,
        ..PlannerConfig::default()
    };
    // One evaluation pass produces both the plan and the advisory
    // max-frequency baseline — the candidate table is the dominant
    // cost and must not be paid twice per request.
    let (planned, baseline) = match planner::plan_with_baseline(&state.engine, &jobs, &cfg) {
        Ok(pair) => pair,
        Err(e) => return plan_error(&e),
    };

    metrics.record_solve(&planned.report);
    let names: Vec<String> = jobs.iter().map(|j| j.name.clone()).collect();
    let savings = baseline.as_ref().map(|b| planned.energy_savings_pct_vs(b));
    state.plans.record(PlanRecord {
        request_id: rid.map(str::to_string),
        objective: planned.objective.name(),
        jobs: names.clone(),
        total_energy_mj: planned.total_energy_mj,
        max_time_us: planned.max_time_us,
        energy_savings_pct: savings,
        report: planned.report.clone(),
    });
    if let Some(sink) = &state.events {
        let mut ev = vec![
            ("event", Value::str("solve")),
            ("plan_id", Value::str(planned.report.plan_id_str())),
        ];
        if let Some(rid) = rid {
            ev.push(("request_id", Value::str(rid)));
        }
        ev.push(("objective", Value::str(planned.objective.name())));
        ev.push(("jobs", Value::num(names.len() as f64)));
        ev.push(("total_energy_mj", Value::num(planned.total_energy_mj)));
        ev.push(("max_time_us", Value::num(planned.max_time_us)));
        ev.push(("solve_us", Value::num(planned.report.total_us)));
        sink.emit(Value::obj(ev).render());
    }

    let assignments: Vec<Value> = planned
        .assignments
        .iter()
        .map(|a| {
            let job = &jobs[a.job];
            let mut fields = vec![
                ("job", Value::num(a.job as f64)),
                ("name", Value::str(job.name.clone())),
                ("kernel", Value::str(job.kernel.to_string())),
                ("device", Value::str(a.device.to_string())),
                ("core_mhz", Value::num(a.point.core_mhz)),
                ("mem_mhz", Value::num(a.point.mem_mhz)),
                ("time_us", Value::num(a.time_us)),
                ("power_w", Value::num(a.power_w)),
                ("power_dynamic_w", Value::num(a.power_dynamic_w)),
                ("power_leakage_w", Value::num(a.power_leakage_w)),
                ("energy_mj", Value::num(a.energy_mj)),
                ("edp", Value::num(a.edp)),
            ];
            if let Some(d) = job.deadline_us {
                fields.push(("deadline_us", Value::num(d)));
            }
            Value::obj(fields)
        })
        .collect();
    let mut fields = vec![
        ("plan_id", Value::str(planned.report.plan_id_str())),
        ("objective", Value::str(planned.objective.name())),
        ("assignments", Value::arr(assignments)),
        ("count", Value::num(planned.assignments.len() as f64)),
        ("total_energy_mj", Value::num(planned.total_energy_mj)),
        ("total_edp", Value::num(planned.total_edp)),
        ("max_time_us", Value::num(planned.max_time_us)),
        ("swaps_applied", Value::num(planned.swaps_applied as f64)),
    ];
    if let Some(b) = baseline {
        fields.push((
            "baseline",
            Value::obj(vec![
                ("total_energy_mj", Value::num(b.total_energy_mj)),
                ("max_time_us", Value::num(b.max_time_us)),
                (
                    "deadline_violations",
                    Value::num(b.deadline_violations(&jobs) as f64),
                ),
            ]),
        ));
        fields.push((
            "energy_savings_pct",
            Value::num(savings.expect("savings computed alongside the baseline")),
        ));
    }
    fields.push(("telemetry", telemetry_json(&planned.report, &names)));
    // ~240 bytes per assignment (ten named numeric/string fields) plus
    // envelope, baseline block and telemetry (explains add ~150 bytes
    // per job) — pre-sized for fleet-sized plans.
    let n_assigned = planned.assignments.len();
    HttpResponse::json(200, Value::obj(fields).render_sized(600 + 400 * n_assigned))
}

/// Parse the job handle out of a `/v2/jobs/{id}` path. Accepts the
/// canonical `job-<n>` handle and the bare numeric id.
fn parse_job_id(path: &str) -> Option<u64> {
    let rest = path.strip_prefix("/v2/jobs/")?;
    let rest = rest.strip_prefix("job-").unwrap_or(rest);
    if rest.is_empty() || !rest.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    rest.parse().ok()
}

/// One job rendered for the wire: identity, lifecycle state, placement
/// (once scheduled), predicted/observed timing, and the terminal cause
/// for `missed`/`cancelled`/displaced jobs.
fn job_json(r: &JobRecord) -> Value {
    let mut fields = vec![
        ("id", Value::str(r.id_str())),
        ("name", Value::str(r.name.clone())),
        ("kernel", Value::str(r.kernel.to_string())),
        ("scale", Value::num(r.scale)),
        ("state", Value::str(r.state.name())),
        ("submitted_at_us", Value::num(r.submitted_at_us)),
    ];
    if let Some(d) = r.deadline_at_us {
        fields.push(("deadline_at_us", Value::num(d)));
    }
    if let Some(d) = r.device {
        fields.push(("device", Value::str(d.to_string())));
    }
    if let Some(p) = r.point {
        fields.push(("core_mhz", Value::num(p.core_mhz)));
        fields.push(("mem_mhz", Value::num(p.mem_mhz)));
    }
    if let Some(t) = r.predicted_us {
        fields.push(("predicted_us", Value::num(t)));
    }
    if let Some(w) = r.power_w {
        fields.push(("power_w", Value::num(w)));
    }
    if let Some(w) = r.power_dynamic_w {
        fields.push(("power_dynamic_w", Value::num(w)));
    }
    if let Some(w) = r.power_leakage_w {
        fields.push(("power_leakage_w", Value::num(w)));
    }
    if let Some(t) = r.started_at_us {
        fields.push(("started_at_us", Value::num(t)));
    }
    if let Some(t) = r.finished_at_us {
        fields.push(("finished_at_us", Value::num(t)));
    }
    if let Some(p) = r.plan_id {
        fields.push(("plan_id", Value::str(format!("plan-{p}"))));
    }
    if let Some(c) = &r.cause {
        fields.push(("cause", Value::str(c.clone())));
    }
    Value::obj(fields)
}

/// Drain the scheduler's outbox into the observability surfaces
/// (DESIGN.md §14): every epoch solve feeds the `planner_*` metrics
/// and the plan-provenance ring exactly like a `/v2/plan` solve, and
/// every job state change becomes a `job_transition` event in the
/// structured log, correlated by `X-Request-Id` where one applies.
/// The server's scheduler ticker calls this too, so transitions that
/// happen between requests still reach the log.
pub(super) fn drain_scheduler(state: &ServiceState, metrics: &Metrics, rid: Option<&str>) {
    let (transitions, solves, objective) = {
        let mut core = state.scheduler.lock();
        let (t, s) = core.drain_outbox();
        (t, s, core.config().planner.objective.name())
    };
    for s in &solves {
        metrics.record_solve(&s.report);
        state.plans.record(PlanRecord {
            request_id: rid.map(str::to_string),
            objective,
            jobs: s.job_names.clone(),
            total_energy_mj: s.total_energy_mj,
            max_time_us: s.max_time_us,
            energy_savings_pct: None,
            report: s.report.clone(),
        });
    }
    let Some(sink) = &state.events else { return };
    for s in &solves {
        let mut ev = vec![
            ("event", Value::str("solve")),
            ("plan_id", Value::str(s.report.plan_id_str())),
        ];
        if let Some(rid) = rid {
            ev.push(("request_id", Value::str(rid)));
        }
        ev.push(("kind", Value::str(s.kind.name())));
        ev.push(("trigger", Value::str(s.trigger)));
        ev.push(("objective", Value::str(objective)));
        ev.push(("jobs", Value::num(s.jobs as f64)));
        ev.push(("total_energy_mj", Value::num(s.total_energy_mj)));
        ev.push(("max_time_us", Value::num(s.max_time_us)));
        ev.push(("solve_us", Value::num(s.report.total_us)));
        sink.emit(Value::obj(ev).render());
    }
    for t in &transitions {
        let mut ev = vec![
            ("event", Value::str("job_transition")),
            ("job", Value::str(format!("job-{}", t.job))),
            ("name", Value::str(t.name.clone())),
        ];
        if let Some(f) = t.from {
            ev.push(("from", Value::str(f.name())));
        }
        ev.push(("to", Value::str(t.to.name())));
        ev.push(("at_us", Value::num(t.at_us)));
        if let Some(p) = t.plan_id {
            ev.push(("plan_id", Value::str(format!("plan-{p}"))));
        }
        if let Some(c) = &t.cause {
            ev.push(("cause", Value::str(c.clone())));
        }
        if let Some(r) = &t.request_id {
            ev.push(("request_id", Value::str(r.clone())));
        }
        sink.emit(Value::obj(ev).render());
    }
}

/// `POST /v2/jobs` — submit one streaming job to the scheduler
/// (DESIGN.md §14). Malformed fields are parse-layer 400s that never
/// reach the solver; a provably unmeetable deadline is a 422
/// `infeasible_at_submit` carrying the admission proof in `error`; an
/// admitted job returns `202 Accepted` with its initial record (the
/// dispatcher may already have it `running`).
fn v2_submit_job(
    state: &ServiceState,
    metrics: &Metrics,
    req: &HttpRequest,
    rid: Option<&str>,
) -> HttpResponse {
    let body = match parse_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let Some(kernel) = body.get("kernel").and_then(Value::as_str) else {
        return error_json(400, "bad_request", "body needs `kernel` (krn-<n> handle or name)");
    };
    let Some(kid) = state.catalog.resolve_id(kernel) else {
        return error_json(404, "unknown_kernel", &format!("unknown kernel `{kernel}`"));
    };
    let scale = match body.get("scale") {
        None => 1.0,
        Some(v) => match v.as_f64() {
            Some(s) if s.is_finite() && s > 0.0 => s,
            _ => return error_json(400, "bad_request", "`scale` must be a positive finite number"),
        },
    };
    let name = body
        .get("name")
        .and_then(Value::as_str)
        .map(str::to_string)
        .unwrap_or_default();
    let mut spec = JobSpec::new(name, kid, scale);
    match body.get("deadline_us") {
        None => {}
        Some(v) => match v.as_f64() {
            Some(d) if d.is_finite() && d > 0.0 => spec = spec.with_deadline(d),
            _ => {
                return error_json(
                    400,
                    "bad_request",
                    "`deadline_us` must be a positive finite number",
                )
            }
        },
    }

    let now = state.scheduler.now_us();
    let submitted = {
        let mut core = state.scheduler.lock();
        core.run_until(&state.engine, now);
        core.set_request_id(rid.map(str::to_string));
        let out = core.submit(&state.engine, spec);
        core.set_request_id(None);
        out
    };
    drain_scheduler(state, metrics, rid);
    let id = match submitted {
        Ok(id) => id,
        Err(e @ PlanError::Infeasible { .. }) => {
            return error_json(422, "infeasible_at_submit", &e.to_string());
        }
        Err(e) => return plan_error(&e),
    };
    let core = state.scheduler.lock();
    let rec = core.job(id).expect("record exists for a just-admitted job");
    HttpResponse::json(202, job_json(rec).render_sized(600))
}

/// `GET /v2/jobs` — the full retained job table plus the scheduler's
/// lifecycle counters. Ticks the virtual clock first so states reflect
/// wall-clock progress at the moment of the poll.
fn v2_list_jobs(state: &ServiceState, metrics: &Metrics) -> HttpResponse {
    state.scheduler.tick(&state.engine);
    drain_scheduler(state, metrics, None);
    let core = state.scheduler.lock();
    let jobs: Vec<Value> = core.jobs().iter().map(job_json).collect();
    let s = core.stats();
    drop(core);
    let n = jobs.len();
    let body = Value::obj(vec![
        ("count", Value::num(n as f64)),
        ("jobs", Value::arr(jobs)),
        (
            "stats",
            Value::obj(vec![
                ("submitted", Value::num(s.submitted as f64)),
                ("admitted", Value::num(s.admitted as f64)),
                ("rejected", Value::num(s.rejected as f64)),
                ("completed", Value::num(s.completed as f64)),
                ("missed", Value::num(s.missed as f64)),
                ("cancelled", Value::num(s.cancelled as f64)),
                ("active", Value::num(s.active as f64)),
                ("repairs", Value::num(s.repairs as f64)),
                ("full_solves", Value::num(s.full_solves as f64)),
            ]),
        ),
    ]);
    HttpResponse::json(200, body.render_sized(400 + 400 * n))
}

/// `GET /v2/jobs/{id}` — poll one job by handle (`job-<n>` or bare
/// numeric id). Unknown or unparsable handles are 404 `unknown_job`.
fn v2_get_job(state: &ServiceState, metrics: &Metrics, req: &HttpRequest) -> HttpResponse {
    state.scheduler.tick(&state.engine);
    drain_scheduler(state, metrics, None);
    let Some(id) = parse_job_id(&req.path) else {
        return error_json(404, "unknown_job", &format!("no job at `{}`", req.path));
    };
    let core = state.scheduler.lock();
    match core.job(id) {
        Some(r) => HttpResponse::json(200, job_json(r).render_sized(600)),
        None => error_json(404, "unknown_job", &format!("no such job `job-{id}`")),
    }
}

/// `DELETE /v2/jobs/{id}` — cancel a job. Cancelling a terminal job is
/// a no-op that returns the record unchanged; an unknown handle is a
/// 404 `unknown_job`.
fn v2_cancel_job(
    state: &ServiceState,
    metrics: &Metrics,
    req: &HttpRequest,
    rid: Option<&str>,
) -> HttpResponse {
    let Some(id) = parse_job_id(&req.path) else {
        return error_json(404, "unknown_job", &format!("no job at `{}`", req.path));
    };
    let now = state.scheduler.now_us();
    let cancelled = {
        let mut core = state.scheduler.lock();
        core.run_until(&state.engine, now);
        core.set_request_id(rid.map(str::to_string));
        let out = core.cancel(&state.engine, id);
        core.set_request_id(None);
        out
    };
    drain_scheduler(state, metrics, rid);
    match cancelled {
        Some(r) => HttpResponse::json(200, job_json(&r).render_sized(600)),
        None => error_json(404, "unknown_job", &format!("no such job `job-{id}`")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Engine;
    use crate::model::HwParams;

    fn counters() -> KernelCounters {
        KernelCounters {
            l2_hr: 0.1,
            gld_trans: 6.0,
            avr_inst: 1.5,
            n_blocks: 128.0,
            wpb: 8.0,
            aw: 64.0,
            n_sm: 16.0,
            o_itrs: 8.0,
            i_itrs: 0.0,
            uses_smem: false,
            smem_conflict: 1.0,
            gld_body: 6.0,
            gld_edge: 0.0,
            mem_ops: 2.0,
            l1_hr: 0.0,
        }
    }

    fn state() -> ServiceState {
        let hw = HwParams::paper_defaults();
        let mut s = ServiceState::new(
            Engine::native(hw),
            PowerModel::gtx980(),
            crate::microbench::standard_grid(),
        );
        s.register_kernel("VA", counters());
        s
    }

    fn post(path: &str, body: &str) -> HttpRequest {
        HttpRequest {
            method: "POST".to_string(),
            path: path.to_string(),
            query: None,
            headers: Vec::new(),
            body: body.as_bytes().to_vec(),
        }
    }

    fn get(path: &str) -> HttpRequest {
        HttpRequest {
            method: "GET".to_string(),
            path: path.to_string(),
            query: None,
            headers: Vec::new(),
            body: Vec::new(),
        }
    }

    fn delete(path: &str) -> HttpRequest {
        HttpRequest {
            method: "DELETE".to_string(),
            path: path.to_string(),
            query: None,
            headers: Vec::new(),
            body: Vec::new(),
        }
    }

    /// The stable error code carried in an error response's body.
    fn code_of(r: &HttpResponse) -> String {
        Value::parse(&r.body).unwrap().get("code").and_then(Value::as_str).unwrap().to_string()
    }

    #[test]
    fn predict_round_trip_matches_engine() {
        let st = state();
        let m = Metrics::default();
        let resp = handle(
            &st,
            &m,
            &post("/v1/predict", r#"{"kernel":"VA","core_mhz":700,"mem_mhz":700}"#),
        );
        assert_eq!(resp.status, 200);
        let v = Value::parse(&resp.body).unwrap();
        let want = st.engine.predict_one(&counters(), 700.0, 700.0).unwrap();
        let got = v.get("time_us").and_then(Value::as_f64).unwrap();
        // JSON round-trips f64 via shortest-representation `{}`: exact.
        assert_eq!(got.to_bits(), want.time_us.to_bits());
        assert!(v.get("regime").and_then(Value::as_str).is_some());
    }

    #[test]
    fn predict_accepts_inline_counters() {
        let st = state();
        let m = Metrics::default();
        let body = r#"{"counters":{"l2_hr":0.1,"gld_trans":6,"avr_inst":1.5,"n_blocks":128,
            "wpb":8,"aw":64,"n_sm":16,"o_itrs":8,"mem_ops":2},
            "core_mhz":500,"mem_mhz":900}"#;
        let resp = handle(&st, &m, &post("/v1/predict", body));
        assert_eq!(resp.status, 200, "{}", resp.body);
        let v = Value::parse(&resp.body).unwrap();
        let want = st.engine.predict_one(&counters(), 500.0, 900.0).unwrap();
        assert_eq!(
            v.get("time_us").and_then(Value::as_f64).unwrap().to_bits(),
            want.time_us.to_bits()
        );
    }

    #[test]
    fn predict_errors_are_400_with_json_bodies() {
        let st = state();
        let m = Metrics::default();
        for body in [
            "",
            "not json",
            r#"{"kernel":"NOPE","core_mhz":700,"mem_mhz":700}"#,
            r#"{"kernel":"VA"}"#,
            r#"{"kernel":"VA","core_mhz":-1,"mem_mhz":700}"#,
            r#"{"kernel":"VA","core_mhz":1e999,"mem_mhz":700}"#,
            r#"{"counters":{"l2_hr":0.1},"core_mhz":700,"mem_mhz":700}"#,
        ] {
            let resp = handle(&st, &m, &post("/v1/predict", body));
            assert_eq!(resp.status, 400, "body `{body}` -> {}", resp.body);
            let v = Value::parse(&resp.body).unwrap();
            assert!(v.get("error").is_some());
            assert!(v.get("code").and_then(Value::as_str).is_some(), "{}", resp.body);
        }
        // The unknown-named-kernel case carries its specific code.
        let resp = handle(
            &st,
            &m,
            &post("/v1/predict", r#"{"kernel":"NOPE","core_mhz":700,"mem_mhz":700}"#),
        );
        let v = Value::parse(&resp.body).unwrap();
        assert_eq!(v.get("code").and_then(Value::as_str), Some("unknown_kernel"));
    }

    #[test]
    fn grid_defaults_to_standard_pairs_and_reports_cache() {
        let st = state();
        let m = Metrics::default();
        let resp = handle(&st, &m, &post("/v1/grid", r#"{"kernel":"VA"}"#));
        assert_eq!(resp.status, 200);
        let v = Value::parse(&resp.body).unwrap();
        assert_eq!(v.get("points").and_then(Value::as_array).unwrap().len(), 49);
        let cache = v.get("cache").unwrap();
        assert_eq!(cache.get("misses").and_then(Value::as_f64), Some(49.0));
        // Second call is fully cache-served.
        let resp2 = handle(&st, &m, &post("/v1/grid", r#"{"kernel":"VA"}"#));
        let v2 = Value::parse(&resp2.body).unwrap();
        assert!(v2.get("cache").unwrap().get("hits").and_then(Value::as_f64).unwrap() >= 49.0);
    }

    #[test]
    fn grid_accepts_explicit_pairs() {
        let st = state();
        let m = Metrics::default();
        let resp = handle(
            &st,
            &m,
            &post("/v1/grid", r#"{"kernel":"VA","pairs":[[400,400],[1000,1000]]}"#),
        );
        assert_eq!(resp.status, 200);
        let v = Value::parse(&resp.body).unwrap();
        let pts = v.get("points").and_then(Value::as_array).unwrap();
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[1].get("core_mhz").and_then(Value::as_f64), Some(1000.0));
        for bad in [
            r#"{"kernel":"VA","pairs":[]}"#,
            r#"{"kernel":"VA","pairs":[[400]]}"#,
            r#"{"kernel":"VA","pairs":[[400,0]]}"#,
            r#"{"kernel":"VA","pairs":[[400,400,400]]}"#,
            r#"{"kernel":"VA","pairs":"all"}"#,
        ] {
            assert_eq!(handle(&st, &m, &post("/v1/grid", bad)).status, 400, "{bad}");
        }
    }

    #[test]
    fn advise_energy_matches_dvfs_module() {
        let st = state();
        let m = Metrics::default();
        let resp = handle(&st, &m, &post("/v1/advise", r#"{"kernel":"VA"}"#));
        assert_eq!(resp.status, 200);
        let v = Value::parse(&resp.body).unwrap();
        assert_eq!(v.get("feasible").and_then(Value::as_bool), Some(true));
        let (want, _) = crate::dvfs::advise_with_engine(
            &counters(),
            &st.engine,
            &st.power,
            &st.default_pairs,
            Objective::Energy,
        )
        .unwrap();
        let best = v.get("best").unwrap();
        assert_eq!(best.get("core_mhz").and_then(Value::as_f64), Some(want.core_mhz));
        assert_eq!(best.get("mem_mhz").and_then(Value::as_f64), Some(want.mem_mhz));
    }

    #[test]
    fn advise_deadline_constrains_and_falls_back() {
        let st = state();
        let m = Metrics::default();
        // A generous deadline: feasible, best meets it.
        let resp = handle(
            &st,
            &m,
            &post("/v1/advise", r#"{"kernel":"VA","deadline_us":1e9,"include_points":true}"#),
        );
        let v = Value::parse(&resp.body).unwrap();
        assert_eq!(v.get("feasible").and_then(Value::as_bool), Some(true));
        assert_eq!(v.get("points").and_then(Value::as_array).unwrap().len(), 49);
        // An impossible deadline: infeasible, falls back to fastest.
        let resp = handle(
            &st,
            &m,
            &post("/v1/advise", r#"{"kernel":"VA","deadline_us":0.001}"#),
        );
        let v = Value::parse(&resp.body).unwrap();
        assert_eq!(v.get("feasible").and_then(Value::as_bool), Some(false));
        let best = v.get("best").unwrap().get("time_us").and_then(Value::as_f64).unwrap();
        let fastest = v.get("fastest").unwrap().get("time_us").and_then(Value::as_f64).unwrap();
        assert_eq!(best.to_bits(), fastest.to_bits());
        // Tight-but-possible deadline: the chosen point meets it.
        let loose = handle(&st, &m, &post("/v1/advise", r#"{"kernel":"VA"}"#));
        let unconstrained = Value::parse(&loose.body)
            .unwrap()
            .get("best")
            .unwrap()
            .get("time_us")
            .and_then(Value::as_f64)
            .unwrap();
        let deadline = (unconstrained + fastest) / 2.0;
        let resp = handle(
            &st,
            &m,
            &post("/v1/advise", &format!(r#"{{"kernel":"VA","deadline_us":{deadline}}}"#)),
        );
        let v = Value::parse(&resp.body).unwrap();
        assert_eq!(v.get("feasible").and_then(Value::as_bool), Some(true));
        assert!(
            v.get("best").unwrap().get("time_us").and_then(Value::as_f64).unwrap() <= deadline
        );
    }

    #[test]
    fn advise_objectives_parse() {
        let st = state();
        let m = Metrics::default();
        for body in [
            r#"{"kernel":"VA","objective":"edp"}"#,
            r#"{"kernel":"VA","objective":{"slack":0.05}}"#,
        ] {
            assert_eq!(handle(&st, &m, &post("/v1/advise", body)).status, 200, "{body}");
        }
        assert_eq!(
            handle(&st, &m, &post("/v1/advise", r#"{"kernel":"VA","objective":"speed"}"#)).status,
            400
        );
    }

    #[test]
    fn health_metrics_and_routing() {
        let st = state();
        let m = Metrics::default();
        let h = handle(&st, &m, &get("/healthz"));
        assert_eq!(h.status, 200);
        let v = Value::parse(&h.body).unwrap();
        assert_eq!(v.get("status").and_then(Value::as_str), Some("ok"));
        assert_eq!(v.get("kernels").and_then(Value::as_f64), Some(1.0));
        // The boot GPU is always registered as the default device.
        assert_eq!(v.get("devices").and_then(Value::as_f64), Some(1.0));

        let mx = handle(&st, &m, &get("/metrics"));
        assert_eq!(mx.status, 200);
        assert!(mx.body.contains("service_cache_hits"));

        assert_eq!(handle(&st, &m, &get("/nope")).status, 404);
        assert_eq!(handle(&st, &m, &get("/v1/predict")).status, 405);
        assert_eq!(handle(&st, &m, &post("/healthz", "{}")).status, 405);
        assert_eq!(handle(&st, &m, &get("/v2/predict")).status, 405);
    }

    #[test]
    fn register_kernel_overwrites_by_name() {
        let mut st = state();
        let mut c = counters();
        c.avr_inst = 99.0;
        st.register_kernel("VA", c);
        assert_eq!(st.kernel_count(), 1);
        assert_eq!(st.counters_for("VA").unwrap().avr_inst, 99.0);
    }

    // ---- /v2 ----

    #[test]
    fn v2_device_lifecycle_register_list_resolve() {
        let st = state();
        let m = Metrics::default();
        // The boot device pre-exists as dev-1 "default".
        let r = handle(&st, &m, &get("/v2/devices"));
        assert_eq!(r.status, 200);
        let v = Value::parse(&r.body).unwrap();
        assert_eq!(v.get("count").and_then(Value::as_f64), Some(1.0));
        let first = &v.get("devices").and_then(Value::as_array).unwrap()[0];
        assert_eq!(first.get("device").and_then(Value::as_str), Some("dev-1"));
        assert_eq!(first.get("name").and_then(Value::as_str), Some(DEFAULT_DEVICE_NAME));

        // Register a second GPU with partially-overridden hw + power.
        let body = r#"{"name":"gtx960","hw":{"dm_lat_a":240.0,"l2_lat":210.0},
            "power":{"static_w":18.0,"core_vf":[[400,0.8],[1000,1.15]]}}"#;
        let r = handle(&st, &m, &post("/v2/devices", body));
        assert_eq!(r.status, 200, "{}", r.body);
        let v = Value::parse(&r.body).unwrap();
        assert_eq!(v.get("device").and_then(Value::as_str), Some("dev-2"));
        let hw = v.get("hw").unwrap();
        assert_eq!(hw.get("dm_lat_a").and_then(Value::as_f64), Some(240.0));
        // Unspecified hw fields inherit the boot device's parameters.
        assert_eq!(
            hw.get("dm_lat_b").and_then(Value::as_f64),
            Some(HwParams::paper_defaults().dm_lat_b)
        );
        let rec = st.registry.resolve("gtx960").unwrap();
        assert_eq!(rec.power.leakage.static_w, 18.0);
        assert_eq!(rec.power.core_curve.points, vec![(400.0, 0.8), (1000.0, 1.15)]);
        assert_eq!(st.registry.len(), 2);
    }

    #[test]
    fn v2_kernel_register_and_list_round_trip() {
        let st = state();
        let m = Metrics::default();
        let body = r#"{"name":"MMS","counters":{"l2_hr":0.4,"gld_trans":4,"avr_inst":12,
            "n_blocks":64,"wpb":8,"aw":48,"n_sm":16,"o_itrs":16,"uses_smem":true,
            "smem_conflict":1.5,"mem_ops":1}}"#;
        let r = handle(&st, &m, &post("/v2/kernels", body));
        assert_eq!(r.status, 200, "{}", r.body);
        let v = Value::parse(&r.body).unwrap();
        // "VA" took krn-1 at boot.
        assert_eq!(v.get("kernel").and_then(Value::as_str), Some("krn-2"));
        let r = handle(&st, &m, &get("/v2/kernels"));
        let v = Value::parse(&r.body).unwrap();
        assert_eq!(v.get("count").and_then(Value::as_f64), Some(2.0));
        let listed = v.get("kernels").and_then(Value::as_array).unwrap();
        let mms = listed.iter().find(|k| k.get("name").and_then(Value::as_str) == Some("MMS"));
        let c = mms.unwrap().get("counters").unwrap();
        assert_eq!(c.get("uses_smem").and_then(Value::as_bool), Some(true));
        assert_eq!(c.get("avr_inst").and_then(Value::as_f64), Some(12.0));
    }

    #[test]
    fn v2_predict_batch_matches_raw_struct_path() {
        let st = state();
        let m = Metrics::default();
        let body = r#"{"requests":[
            {"device":"dev-1","kernel":"krn-1","core_mhz":700,"mem_mhz":700},
            {"device":"default","kernel":"VA","core_mhz":400,"mem_mhz":1000},
            {"device":"dev-1","kernel":"krn-1","core_mhz":1000,"mem_mhz":400}]}"#;
        let r = handle(&st, &m, &post("/v2/predict", body));
        assert_eq!(r.status, 200, "{}", r.body);
        let v = Value::parse(&r.body).unwrap();
        assert_eq!(v.get("count").and_then(Value::as_f64), Some(3.0));
        let results = v.get("results").and_then(Value::as_array).unwrap();
        for (res, (cf, mf)) in
            results.iter().zip([(700.0, 700.0), (400.0, 1000.0), (1000.0, 400.0)])
        {
            // Handles echo back resolved, and predictions are
            // byte-identical to the raw-struct path.
            assert_eq!(res.get("device").and_then(Value::as_str), Some("dev-1"));
            assert_eq!(res.get("kernel").and_then(Value::as_str), Some("krn-1"));
            let want = st.engine.predict_one(&counters(), cf, mf).unwrap();
            assert_eq!(
                res.get("time_us").and_then(Value::as_f64).unwrap().to_bits(),
                want.time_us.to_bits()
            );
        }
    }

    #[test]
    fn v2_errors_carry_stable_codes() {
        let st = state();
        let m = Metrics::default();
        let code_of = |r: &HttpResponse| {
            Value::parse(&r.body)
                .unwrap()
                .get("code")
                .and_then(Value::as_str)
                .map(str::to_string)
                .unwrap()
        };
        let r = handle(
            &st,
            &m,
            &post(
                "/v2/predict",
                r#"{"requests":[{"device":"dev-9","kernel":"krn-1","core_mhz":700,"mem_mhz":700}]}"#,
            ),
        );
        assert_eq!((r.status, code_of(&r).as_str()), (404, "unknown_device"), "{}", r.body);
        let r = handle(
            &st,
            &m,
            &post(
                "/v2/predict",
                r#"{"requests":[{"device":"dev-1","kernel":"krn-9","core_mhz":700,"mem_mhz":700}]}"#,
            ),
        );
        assert_eq!((r.status, code_of(&r).as_str()), (404, "unknown_kernel"));
        let r = handle(&st, &m, &post("/v2/predict", r#"{"requests":[]}"#));
        assert_eq!((r.status, code_of(&r).as_str()), (400, "bad_request"));
        let r = handle(&st, &m, &post("/v2/predict", "{nope"));
        assert_eq!((r.status, code_of(&r).as_str()), (400, "bad_json"));
        let r = handle(
            &st,
            &m,
            &post(
                "/v2/predict",
                r#"{"requests":[{"device":"dev-1","kernel":"krn-1","core_mhz":-5,"mem_mhz":700}]}"#,
            ),
        );
        assert_eq!((r.status, code_of(&r).as_str()), (400, "bad_request"));
        let r = handle(&st, &m, &post("/v2/advise", r#"{"device":"dev-1","kernel":"nope"}"#));
        assert_eq!((r.status, code_of(&r).as_str()), (404, "unknown_kernel"));
        let r = handle(&st, &m, &get("/v2/nope"));
        assert_eq!((r.status, code_of(&r).as_str()), (404, "unknown_route"));
        let r = handle(&st, &m, &get("/v2/advise"));
        assert_eq!((r.status, code_of(&r).as_str()), (405, "method_not_allowed"));
        for bad_device in [
            r#"{"name":"","hw":{}}"#,
            r#"{"name":"x","hw":{"dm_del":"soup"}}"#,
            r#"{"name":"x","hw":{"dm_lat_a":-500}}"#,
            r#"{"name":"x","power":{"core_vf":[[1000,1.2],[400,0.8]]}}"#,
            // Handle-shaped names would be shadowed by real ids.
            r#"{"name":"dev-7"}"#,
            r#"{"name":"krn-7"}"#,
        ] {
            let r = handle(&st, &m, &post("/v2/devices", bad_device));
            assert_eq!((r.status, code_of(&r).as_str()), (400, "bad_request"), "{bad_device}");
        }
        // Reserved kernel names are refused by the catalog itself, and
        // negative counters never poison a persistent record.
        for bad_kernel in [
            r#"{"name":"krn-7","counters":{"l2_hr":0.1,"gld_trans":6,"avr_inst":1.5,
                "n_blocks":128,"wpb":8,"aw":64,"n_sm":16,"o_itrs":8}}"#,
            r#"{"name":"neg","counters":{"l2_hr":0.1,"gld_trans":-6,"avr_inst":1.5,
                "n_blocks":128,"wpb":8,"aw":64,"n_sm":16,"o_itrs":8}}"#,
            r#"{"name":"zero-sm","counters":{"l2_hr":0.1,"gld_trans":6,"avr_inst":1.5,
                "n_blocks":128,"wpb":8,"aw":64,"n_sm":0,"o_itrs":8}}"#,
        ] {
            let r = handle(&st, &m, &post("/v2/kernels", bad_kernel));
            assert_eq!((r.status, code_of(&r).as_str()), (400, "bad_request"), "{bad_kernel}");
        }
    }

    #[test]
    fn registration_is_bounded() {
        let st = state();
        let m = Metrics::default();
        // Fill the registry up to the bound directly (dev-1 exists).
        for i in 0..(MAX_DEVICES - 1) {
            st.registry.register(
                &format!("fill-{i}"),
                HwParams::paper_defaults(),
                PowerModel::gtx980(),
            );
        }
        let r = handle(&st, &m, &post("/v2/devices", r#"{"name":"one-too-many"}"#));
        assert_eq!(r.status, 429, "{}", r.body);
        let v = Value::parse(&r.body).unwrap();
        assert_eq!(v.get("code").and_then(Value::as_str), Some("registry_full"));
        // Prediction on existing handles still works at the bound.
        let r = handle(
            &st,
            &m,
            &post(
                "/v2/predict",
                r#"{"requests":[{"device":"dev-1","kernel":"krn-1","core_mhz":700,"mem_mhz":700}]}"#,
            ),
        );
        assert_eq!(r.status, 200, "{}", r.body);
        // Re-profiling a known kernel name never hits the catalog bound.
        for i in 0..(MAX_KERNELS - 1) {
            st.catalog.register(&format!("fill-{i}"), counters());
        }
        let reprofile = r#"{"name":"VA","counters":{"l2_hr":0.2,"gld_trans":6,"avr_inst":1.5,
            "n_blocks":128,"wpb":8,"aw":64,"n_sm":16,"o_itrs":8}}"#;
        assert_eq!(handle(&st, &m, &post("/v2/kernels", reprofile)).status, 200);
        let fresh = r#"{"name":"brand-new","counters":{"l2_hr":0.2,"gld_trans":6,"avr_inst":1.5,
            "n_blocks":128,"wpb":8,"aw":64,"n_sm":16,"o_itrs":8}}"#;
        let r = handle(&st, &m, &post("/v2/kernels", fresh));
        assert_eq!(r.status, 429, "{}", r.body);
    }

    #[test]
    fn v2_device_defaults_inherit_the_boot_power_model() {
        // A service booted with a non-default power model: devices
        // registered without (or with partial) `power` inherit IT, not
        // the GTX 980 calibration — same contract as partial `hw`.
        let hw = HwParams::paper_defaults();
        let mut boot_power = PowerModel::gtx980();
        boot_power.leakage.static_w = 77.0;
        let st = ServiceState::new(
            Engine::native(hw),
            boot_power,
            crate::microbench::standard_grid(),
        );
        let m = Metrics::default();
        let r = handle(&st, &m, &post("/v2/devices", r#"{"name":"plain"}"#));
        assert_eq!(r.status, 200, "{}", r.body);
        assert_eq!(st.registry.resolve("plain").unwrap().power.leakage.static_w, 77.0);
        let r = handle(
            &st,
            &m,
            &post("/v2/devices", r#"{"name":"partial","power":{"core_coeff":0.05}}"#),
        );
        assert_eq!(r.status, 200, "{}", r.body);
        let rec = st.registry.resolve("partial").unwrap();
        assert_eq!(rec.power.dynamic.core_coeff, 0.05);
        assert_eq!(
            rec.power.leakage.static_w,
            77.0,
            "unspecified power fields inherit boot model"
        );
        // Negative hardware parameters are rejected outright.
        let r = handle(
            &st,
            &m,
            &post("/v2/devices", r#"{"name":"bad","hw":{"dm_lat_a":-500}}"#),
        );
        assert_eq!(r.status, 400, "{}", r.body);
    }

    #[test]
    fn v2_advise_uses_the_devices_own_power_model() {
        let st = state();
        let m = Metrics::default();
        // A device with enormous static power shifts the energy optimum
        // toward faster (shorter) configurations.
        let r = handle(
            &st,
            &m,
            &post("/v2/devices", r#"{"name":"hot","power":{"static_w":5000}}"#),
        );
        assert_eq!(r.status, 200);
        let r1 = handle(&st, &m, &post("/v2/advise", r#"{"device":"dev-1","kernel":"VA"}"#));
        let r2 = handle(&st, &m, &post("/v2/advise", r#"{"device":"hot","kernel":"VA"}"#));
        assert_eq!(r1.status, 200, "{}", r1.body);
        assert_eq!(r2.status, 200, "{}", r2.body);
        let v1 = Value::parse(&r1.body).unwrap();
        let v2 = Value::parse(&r2.body).unwrap();
        assert_eq!(v2.get("device").and_then(Value::as_str), Some("dev-2"));
        let t1 = v1.get("best").unwrap().get("time_us").and_then(Value::as_f64).unwrap();
        let t2 = v2.get("best").unwrap().get("time_us").and_then(Value::as_f64).unwrap();
        assert!(
            t2 <= t1,
            "static-power-dominated device must not pick a slower point ({t2} vs {t1})"
        );
        // And the default-device v2 advice matches v1 advice exactly.
        let rv1 = handle(&st, &m, &post("/v1/advise", r#"{"kernel":"VA"}"#));
        let vv1 = Value::parse(&rv1.body).unwrap();
        assert_eq!(
            vv1.get("best").unwrap().get("energy_mj").and_then(Value::as_f64),
            v1.get("best").unwrap().get("energy_mj").and_then(Value::as_f64),
        );
    }

    #[test]
    fn v2_plan_assigns_every_job_and_reports_the_baseline() {
        let st = state();
        let m = Metrics::default();
        // A second device so the fleet actually has a choice.
        let r = handle(
            &st,
            &m,
            &post("/v2/devices", r#"{"name":"aux","power":{"static_w":15.0}}"#),
        );
        assert_eq!(r.status, 200, "{}", r.body);
        let body = r#"{"jobs":[
            {"kernel":"VA","scale":2,"deadline_us":1e9,"name":"nightly"},
            {"kernel":"krn-1"},
            {"kernel":"VA","scale":4}],
            "device_cap":2}"#;
        let r = handle(&st, &m, &post("/v2/plan", body));
        assert_eq!(r.status, 200, "{}", r.body);
        let v = Value::parse(&r.body).unwrap();
        assert_eq!(v.get("objective").and_then(Value::as_str), Some("energy"));
        assert_eq!(v.get("count").and_then(Value::as_f64), Some(3.0));
        let assignments = v.get("assignments").and_then(Value::as_array).unwrap();
        assert_eq!(assignments.len(), 3);
        let mut total = 0.0;
        for (i, a) in assignments.iter().enumerate() {
            assert_eq!(a.get("job").and_then(Value::as_f64), Some(i as f64));
            assert_eq!(a.get("kernel").and_then(Value::as_str), Some("krn-1"));
            let dev = a.get("device").and_then(Value::as_str).unwrap();
            assert!(dev == "dev-1" || dev == "dev-2", "{dev}");
            let e = a.get("energy_mj").and_then(Value::as_f64).unwrap();
            let p = a.get("power_w").and_then(Value::as_f64).unwrap();
            let t = a.get("time_us").and_then(Value::as_f64).unwrap();
            assert!((e - p * t * 1e-3).abs() <= 1e-9 * e.max(1.0), "E != P*T on the wire");
            total += e;
        }
        assert_eq!(assignments[0].get("name").and_then(Value::as_str), Some("nightly"));
        assert_eq!(assignments[0].get("deadline_us").and_then(Value::as_f64), Some(1e9));
        assert_eq!(assignments[1].get("name").and_then(Value::as_str), Some("job-1"));
        let reported = v.get("total_energy_mj").and_then(Value::as_f64).unwrap();
        assert!((reported - total).abs() <= 1e-9 * total.max(1.0));
        // The baseline block reports what the naive max-frequency
        // fleet would cost — and the plan never costs more.
        let baseline = v.get("baseline").expect("baseline present");
        let base_e = baseline.get("total_energy_mj").and_then(Value::as_f64).unwrap();
        assert!(reported <= base_e, "plan {reported} vs baseline {base_e}");
        assert!(v.get("energy_savings_pct").and_then(Value::as_f64).unwrap() >= 0.0);
    }

    #[test]
    fn v2_plan_errors_carry_stable_codes() {
        let st = state();
        let m = Metrics::default();
        let code_of = |r: &HttpResponse| {
            Value::parse(&r.body)
                .unwrap()
                .get("code")
                .and_then(Value::as_str)
                .map(str::to_string)
                .unwrap()
        };
        // An impossible deadline is 422 `infeasible`, naming the job.
        let r = handle(
            &st,
            &m,
            &post(
                "/v2/plan",
                r#"{"jobs":[{"kernel":"VA","deadline_us":1e-4,"name":"doomed"}]}"#,
            ),
        );
        assert_eq!((r.status, code_of(&r).as_str()), (422, "infeasible"), "{}", r.body);
        assert!(r.body.contains("doomed"), "{}", r.body);
        // Malformed inputs are 400s; unknown handles are 404s.
        for bad in [
            r#"{}"#,
            r#"{"jobs":[]}"#,
            r#"{"jobs":[{"kernel":"VA","scale":0}]}"#,
            r#"{"jobs":[{"kernel":"VA","scale":-2}]}"#,
            r#"{"jobs":[{"kernel":"VA","deadline_us":0}]}"#,
            r#"{"jobs":[{"kernel":"VA"}],"objective":"speed"}"#,
            r#"{"jobs":[{"kernel":"VA"}],"device_cap":0}"#,
            r#"{"jobs":[{"kernel":"VA"}],"device_cap":1.5}"#,
            r#"{"jobs":[{"kernel":"VA"}],"devices":[]}"#,
            r#"{"jobs":[{"kernel":"VA"}],"pairs":[]}"#,
        ] {
            let r = handle(&st, &m, &post("/v2/plan", bad));
            assert_eq!((r.status, code_of(&r).as_str()), (400, "bad_request"), "{bad}");
        }
        let r = handle(&st, &m, &post("/v2/plan", r#"{"jobs":[{"kernel":"ghost"}]}"#));
        assert_eq!((r.status, code_of(&r).as_str()), (404, "unknown_kernel"));
        let r = handle(
            &st,
            &m,
            &post("/v2/plan", r#"{"jobs":[{"kernel":"VA"}],"devices":["dev-99"]}"#),
        );
        assert_eq!((r.status, code_of(&r).as_str()), (404, "unknown_device"));
        // Capacity that cannot hold the fleet is infeasible, not 500.
        let r = handle(
            &st,
            &m,
            &post(
                "/v2/plan",
                r#"{"jobs":[{"kernel":"VA"},{"kernel":"VA"}],"device_cap":1}"#,
            ),
        );
        assert_eq!((r.status, code_of(&r).as_str()), (422, "infeasible"), "{}", r.body);
    }

    #[test]
    fn v2_plan_respects_explicit_pairs_and_objective() {
        let st = state();
        let m = Metrics::default();
        let r = handle(
            &st,
            &m,
            &post(
                "/v2/plan",
                r#"{"jobs":[{"kernel":"VA"}],"pairs":[[700,700]],"objective":"edp"}"#,
            ),
        );
        assert_eq!(r.status, 200, "{}", r.body);
        let v = Value::parse(&r.body).unwrap();
        assert_eq!(v.get("objective").and_then(Value::as_str), Some("edp"));
        let a = &v.get("assignments").and_then(Value::as_array).unwrap()[0];
        assert_eq!(a.get("core_mhz").and_then(Value::as_f64), Some(700.0));
        assert_eq!(a.get("mem_mhz").and_then(Value::as_f64), Some(700.0));
    }

    #[test]
    fn v2_observations_scores_samples_and_feeds_metrics() {
        let st = state();
        let m = Metrics::default();
        // Feed back the model's own prediction as the "measurement":
        // a perfectly calibrated sample, so MAPE must be exactly zero.
        let want = st.engine.predict_one(&counters(), 700.0, 700.0).unwrap();
        let body = format!(
            r#"{{"observations":[{{"device":"dev-1","kernel":"VA","core_mhz":700,"mem_mhz":700,"measured_us":{}}}]}}"#,
            want.time_us
        );
        let r = handle(&st, &m, &post("/v2/observations", &body));
        assert_eq!(r.status, 200, "{}", r.body);
        let v = Value::parse(&r.body).unwrap();
        assert_eq!(v.get("count").and_then(Value::as_f64), Some(1.0));
        assert_eq!(v.get("dropped").and_then(Value::as_f64), Some(0.0));
        let item = &v.get("results").and_then(Value::as_array).unwrap()[0];
        // Labels come back canonical even though the kernel was named.
        assert_eq!(item.get("kernel").and_then(Value::as_str), Some("krn-1"));
        assert_eq!(item.get("abs_pct_error").and_then(Value::as_f64), Some(0.0));

        // A 2x-slower measurement lands a 50% error in the same series.
        let body = format!(
            r#"{{"observations":[{{"device":"dev-1","kernel":"krn-1","core_mhz":700,"mem_mhz":700,"measured_ms":{}}}]}}"#,
            2.0 * want.time_us / 1e3
        );
        let r = handle(&st, &m, &post("/v2/observations", &body));
        assert_eq!(r.status, 200, "{}", r.body);
        let series = st.accuracy.snapshot();
        assert_eq!(series.len(), 1);
        assert_eq!(series[0].samples, 2);
        assert!((series[0].mape_pct - 25.0).abs() < 1e-9, "{}", series[0].mape_pct);

        // ... and /metrics now carries the live MAPE gauge.
        let r = handle(&st, &m, &get("/metrics"));
        let needle = "model_mape{device=\"dev-1\",kernel=\"krn-1\"} 25.000";
        assert!(r.body.contains(needle), "{}", r.body);
        assert!(r.body.contains("model_samples_total{device=\"dev-1\",kernel=\"krn-1\"} 2"));
    }

    #[test]
    fn v2_observations_rejects_malformed_batches_atomically() {
        let st = state();
        let m = Metrics::default();
        for (body, status, code) in [
            (r#"{}"#, 400, "bad_request"),
            (r#"{"observations":[]}"#, 400, "bad_request"),
            // Missing measurement field.
            (
                r#"{"observations":[{"device":"dev-1","kernel":"VA","core_mhz":700,"mem_mhz":700}]}"#,
                400,
                "bad_request",
            ),
            // Both measurement fields.
            (
                r#"{"observations":[{"device":"dev-1","kernel":"VA","core_mhz":700,"mem_mhz":700,"measured_us":1,"measured_ms":1}]}"#,
                400,
                "bad_request",
            ),
            // Non-positive measurement and bad frequency.
            (
                r#"{"observations":[{"device":"dev-1","kernel":"VA","core_mhz":700,"mem_mhz":700,"measured_us":0}]}"#,
                400,
                "bad_request",
            ),
            (
                r#"{"observations":[{"device":"dev-1","kernel":"VA","core_mhz":-5,"mem_mhz":700,"measured_us":1}]}"#,
                400,
                "bad_request",
            ),
            (
                r#"{"observations":[{"device":"dev-9","kernel":"VA","core_mhz":700,"mem_mhz":700,"measured_us":1}]}"#,
                404,
                "unknown_device",
            ),
            (
                r#"{"observations":[{"device":"dev-1","kernel":"ghost","core_mhz":700,"mem_mhz":700,"measured_us":1}]}"#,
                404,
                "unknown_kernel",
            ),
            // A good first item must not be ingested when a later item
            // is broken: validation is all-or-nothing.
            (
                r#"{"observations":[
                    {"device":"dev-1","kernel":"VA","core_mhz":700,"mem_mhz":700,"measured_us":100},
                    {"device":"dev-1","kernel":"ghost","core_mhz":700,"mem_mhz":700,"measured_us":100}]}"#,
                404,
                "unknown_kernel",
            ),
        ] {
            let r = handle(&st, &m, &post("/v2/observations", body));
            assert_eq!((r.status, code_of(&r).as_str()), (status, code), "{body} -> {}", r.body);
        }
        assert_eq!(st.accuracy.total_samples(), 0, "rejected batches must not ingest");
        // Method check: observations are POST-only.
        let r = handle(&st, &m, &get("/v2/observations"));
        assert_eq!((r.status, code_of(&r).as_str()), (405, "method_not_allowed"));
    }

    #[test]
    fn debug_traces_dumps_ring_contents_newest_first() {
        let st = state();
        let m = Metrics::default();
        // The handler renders whatever the ring retained; feed it two
        // synthetic records directly (the server integration test covers
        // end-to-end capture).
        for (id, status) in [("req-1", 200u16), ("req-2", 404u16)] {
            let mut stages_us = [0.0; Stage::COUNT];
            stages_us[Stage::Compute.index()] = 42.0;
            st.traces.record(TraceRecord {
                id: id.to_string(),
                route: "/v1/predict",
                status,
                stages_us,
                cache_hits: 3,
                cache_misses: 1,
                slab_calls: 1,
            });
        }
        let r = handle(&st, &m, &get("/debug/traces"));
        assert_eq!(r.status, 200, "{}", r.body);
        let v = Value::parse(&r.body).unwrap();
        assert_eq!(v.get("count").and_then(Value::as_f64), Some(2.0));
        assert_eq!(v.get("recorded_total").and_then(Value::as_f64), Some(2.0));
        let traces = v.get("traces").and_then(Value::as_array).unwrap();
        assert_eq!(traces[0].get("id").and_then(Value::as_str), Some("req-2"));
        assert_eq!(traces[1].get("id").and_then(Value::as_str), Some("req-1"));
        assert_eq!(traces[0].get("status").and_then(Value::as_f64), Some(404.0));
        let stages = traces[0].get("stages_us").unwrap();
        assert_eq!(stages.get("compute").and_then(Value::as_f64), Some(42.0));
        assert_eq!(stages.get("queue").and_then(Value::as_f64), Some(0.0));
        assert_eq!(traces[0].get("total_us").and_then(Value::as_f64), Some(42.0));
        let cache = traces[0].get("cache").unwrap();
        assert_eq!(cache.get("hits").and_then(Value::as_f64), Some(3.0));
        // Traces are GET-only.
        let r = handle(&st, &m, &post("/debug/traces", ""));
        assert_eq!((r.status, code_of(&r).as_str()), (405, "method_not_allowed"));
    }

    #[test]
    fn v2_plan_carries_plan_id_and_telemetry() {
        let st = state();
        let m = Metrics::default();
        let body = r#"{"jobs":[{"kernel":"VA","name":"one"},{"kernel":"VA","scale":2}]}"#;
        let r = handle(&st, &m, &post("/v2/plan", body));
        assert_eq!(r.status, 200, "{}", r.body);
        let v = Value::parse(&r.body).unwrap();
        let plan_id = v.get("plan_id").and_then(Value::as_str).unwrap().to_string();
        assert!(plan_id.starts_with("plan-"), "{plan_id}");
        let t = v.get("telemetry").expect("telemetry block");
        assert_eq!(t.get("plan_id").and_then(Value::as_str), Some(plan_id.as_str()));
        // One kernel on one device over the 49-pair default grid.
        let c = t.get("counters").unwrap();
        assert_eq!(c.get("candidates_evaluated").and_then(Value::as_f64), Some(49.0));
        assert_eq!(c.get("slab_calls").and_then(Value::as_f64), Some(1.0));
        let phases = t.get("phase_us").unwrap();
        let total = phases.get("total").and_then(Value::as_f64).unwrap();
        assert!(total > 0.0);
        for key in ["build", "greedy", "repair", "swap"] {
            assert!(phases.get(key).and_then(Value::as_f64).unwrap() >= 0.0, "{key}");
        }
        let explains = t.get("explains").and_then(Value::as_array).unwrap();
        assert_eq!(explains.len(), 2);
        assert_eq!(explains[0].get("name").and_then(Value::as_str), Some("one"));
        assert_eq!(explains[1].get("name").and_then(Value::as_str), Some("job-1"));
        // The solve landed in the provenance ring and the /metrics
        // planner series.
        assert_eq!(st.plans.snapshot().len(), 1);
        let mx = handle(&st, &m, &get("/metrics"));
        assert!(mx.body.contains("planner_solves_total 1"), "{}", mx.body);
        assert!(mx.body.contains("planner_candidates_evaluated_total 49"));
        assert!(mx.body.contains("planner_phase_us_count{phase=\"total\"} 1"));
    }

    #[test]
    fn debug_plans_round_trips_the_provenance_ring() {
        let st = state();
        let m = Metrics::default();
        // No solves yet: an empty, well-formed dump.
        let r = handle(&st, &m, &get("/debug/plans"));
        assert_eq!(r.status, 200, "{}", r.body);
        let v = Value::parse(&r.body).unwrap();
        assert_eq!(v.get("count").and_then(Value::as_f64), Some(0.0));
        assert_eq!(v.get("capacity").and_then(Value::as_f64), Some(DEFAULT_PLAN_RING as f64));

        // Two solves; the dump is newest-first and carries correlation
        // keys and full telemetry. The second request has a request id.
        let body = r#"{"jobs":[{"kernel":"VA","name":"alpha"}]}"#;
        assert_eq!(handle(&st, &m, &post("/v2/plan", body)).status, 200);
        let r2 = handle_traced(&st, &m, &post("/v2/plan", body), Some("req-42"));
        let plan2 =
            Value::parse(&r2.body).unwrap().get("plan_id").and_then(Value::as_str).unwrap().to_string();
        let r = handle(&st, &m, &get("/debug/plans"));
        let v = Value::parse(&r.body).unwrap();
        assert_eq!(v.get("count").and_then(Value::as_f64), Some(2.0));
        let plans = v.get("plans").and_then(Value::as_array).unwrap();
        assert_eq!(plans[0].get("plan_id").and_then(Value::as_str), Some(plan2.as_str()));
        assert_eq!(plans[0].get("request_id").and_then(Value::as_str), Some("req-42"));
        assert!(matches!(plans[1].get("request_id"), Some(Value::Null)));
        assert_eq!(plans[0].get("jobs").and_then(Value::as_f64), Some(1.0));
        let t = plans[0].get("telemetry").expect("telemetry retained");
        let explains = t.get("explains").and_then(Value::as_array).unwrap();
        assert_eq!(explains[0].get("name").and_then(Value::as_str), Some("alpha"));
        // Plans are GET-only.
        let r = handle(&st, &m, &post("/debug/plans", ""));
        assert_eq!((r.status, code_of(&r).as_str()), (405, "method_not_allowed"));
    }

    #[test]
    fn debug_drift_lists_series_worst_first() {
        let st = state();
        let m = Metrics::default();
        let want = st.engine.predict_one(&counters(), 700.0, 700.0).unwrap();
        // One calibrated series and one badly drifted series (50% err).
        let ok_body = format!(
            r#"{{"observations":[{{"device":"dev-1","kernel":"VA","core_mhz":700,"mem_mhz":700,"measured_us":{}}}]}}"#,
            want.time_us
        );
        assert_eq!(handle(&st, &m, &post("/v2/observations", &ok_body)).status, 200);
        st.register_kernel("drifty", counters());
        let bad_body = format!(
            r#"{{"observations":[{{"device":"dev-1","kernel":"drifty","core_mhz":700,"mem_mhz":700,"measured_us":{}}}]}}"#,
            2.0 * want.time_us
        );
        assert_eq!(handle(&st, &m, &post("/v2/observations", &bad_body)).status, 200);
        let r = handle(&st, &m, &get("/debug/drift"));
        assert_eq!(r.status, 200, "{}", r.body);
        let v = Value::parse(&r.body).unwrap();
        assert_eq!(v.get("count").and_then(Value::as_f64), Some(2.0));
        assert_eq!(v.get("samples_dropped_total").and_then(Value::as_f64), Some(0.0));
        let series = v.get("series").and_then(Value::as_array).unwrap();
        // Worst first: the 50%-error series leads in Critical.
        assert_eq!(series[0].get("kernel").and_then(Value::as_str), Some("krn-2"));
        assert_eq!(series[0].get("state").and_then(Value::as_str), Some("critical"));
        assert_eq!(series[1].get("state").and_then(Value::as_str), Some("ok"));
        assert!(series[0].get("ewma_pct").and_then(Value::as_f64).unwrap() > 25.0);
        // ... and /metrics carries the matching gauges.
        let mx = handle(&st, &m, &get("/metrics"));
        assert!(mx.body.contains("model_drift_state{device=\"dev-1\",kernel=\"krn-2\"} 2"));
        assert!(mx.body.contains("model_drift_state{device=\"dev-1\",kernel=\"krn-1\"} 0"));
        assert!(mx.body.contains("model_samples_dropped_total 0"));
        // Drift is GET-only.
        let r = handle(&st, &m, &post("/debug/drift", ""));
        assert_eq!((r.status, code_of(&r).as_str()), (405, "method_not_allowed"));
    }

    #[test]
    fn event_log_captures_solves_observations_and_drift_transitions() {
        let mut path = std::env::temp_dir();
        path.push(format!("gpufreq-routes-events-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let mut st = state();
            st.events = Some(Arc::new(crate::obs::EventSink::to_path(&path).unwrap()));
            let m = Metrics::default();
            let r = handle_traced(
                &st,
                &m,
                &post("/v2/plan", r#"{"jobs":[{"kernel":"VA"}]}"#),
                Some("req-ev"),
            );
            assert_eq!(r.status, 200, "{}", r.body);
            let want = st.engine.predict_one(&counters(), 700.0, 700.0).unwrap();
            let body = format!(
                r#"{{"observations":[{{"device":"dev-1","kernel":"VA","core_mhz":700,"mem_mhz":700,"measured_us":{}}}]}}"#,
                2.0 * want.time_us
            );
            assert_eq!(handle_traced(&st, &m, &post("/v2/observations", &body), Some("req-ev")).status, 200);
            // The event-log counters surface in /metrics.
            let mx = handle(&st, &m, &get("/metrics"));
            assert!(mx.body.contains("service_event_log_enabled 1"), "{}", mx.body);
            assert!(mx.body.contains("service_events_emitted_total 3"), "{}", mx.body);
            // Dropping the state drops the sink: flush + join.
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<Value> = text.lines().map(|l| Value::parse(l).unwrap()).collect();
        assert_eq!(lines.len(), 3, "{text}");
        assert_eq!(lines[0].get("event").and_then(Value::as_str), Some("solve"));
        assert!(lines[0].get("plan_id").and_then(Value::as_str).unwrap().starts_with("plan-"));
        assert_eq!(lines[0].get("request_id").and_then(Value::as_str), Some("req-ev"));
        assert_eq!(lines[1].get("event").and_then(Value::as_str), Some("observation"));
        assert!((lines[1].get("abs_pct_error").and_then(Value::as_f64).unwrap() - 50.0).abs() < 1e-9);
        // A 50% seed EWMA escalates Ok → Critical on the first sample.
        assert_eq!(lines[2].get("event").and_then(Value::as_str), Some("drift_transition"));
        assert_eq!(lines[2].get("from").and_then(Value::as_str), Some("ok"));
        assert_eq!(lines[2].get("to").and_then(Value::as_str), Some("critical"));
        assert_eq!(lines[2].get("request_id").and_then(Value::as_str), Some("req-ev"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn jobs_lifecycle_over_http_submit_poll_cancel() {
        let st = state();
        let m = Metrics::default();
        // A huge scale keeps the job running across the assertions
        // (predicted completion is far in wall-clock terms), making
        // every state below deterministic.
        let r = handle(
            &st,
            &m,
            &post("/v2/jobs", r#"{"kernel":"VA","name":"steady","scale":1e9}"#),
        );
        assert_eq!(r.status, 202, "{}", r.body);
        let v = Value::parse(&r.body).unwrap();
        let id = v.get("id").and_then(Value::as_str).unwrap().to_string();
        assert!(id.starts_with("job-"), "{id}");
        // submit() dispatches before returning: one idle device means
        // the job is already running, with a concrete placement.
        assert_eq!(v.get("state").and_then(Value::as_str), Some("running"));
        assert!(v.get("device").and_then(Value::as_str).is_some(), "{}", r.body);
        assert!(v.get("core_mhz").and_then(Value::as_f64).is_some());

        // Poll by canonical handle and by bare id.
        let g = handle(&st, &m, &get(&format!("/v2/jobs/{id}")));
        assert_eq!(g.status, 200, "{}", g.body);
        let v = Value::parse(&g.body).unwrap();
        assert_eq!(v.get("id").and_then(Value::as_str), Some(id.as_str()));
        let bare = id.trim_start_matches("job-");
        assert_eq!(handle(&st, &m, &get(&format!("/v2/jobs/{bare}"))).status, 200);

        // The list surface carries the table and the counters.
        let l = handle(&st, &m, &get("/v2/jobs"));
        let v = Value::parse(&l.body).unwrap();
        assert_eq!(v.get("count").and_then(Value::as_f64), Some(1.0));
        let stats = v.get("stats").unwrap();
        assert_eq!(stats.get("admitted").and_then(Value::as_f64), Some(1.0));
        assert_eq!(stats.get("active").and_then(Value::as_f64), Some(1.0));

        // Cancel is terminal; cancelling again is a 200 no-op.
        let d = handle(&st, &m, &delete(&format!("/v2/jobs/{id}")));
        assert_eq!(d.status, 200, "{}", d.body);
        let v = Value::parse(&d.body).unwrap();
        assert_eq!(v.get("state").and_then(Value::as_str), Some("cancelled"));
        let d2 = handle(&st, &m, &delete(&format!("/v2/jobs/{id}")));
        assert_eq!(d2.status, 200);
        let v = Value::parse(&d2.body).unwrap();
        assert_eq!(v.get("state").and_then(Value::as_str), Some("cancelled"));

        // The scheduler gauges surface in /metrics.
        let mx = handle(&st, &m, &get("/metrics"));
        assert!(mx.body.contains("scheduler_jobs_admitted_total 1"), "{}", mx.body);
        assert!(mx.body.contains("scheduler_jobs_cancelled_total 1"), "{}", mx.body);
    }

    #[test]
    fn job_submit_validation_rejects_before_the_solver() {
        let st = state();
        let m = Metrics::default();
        for (body, code) in [
            (r#"{"scale":1.0}"#, "bad_request"),
            (r#"{"kernel":"NOPE"}"#, "unknown_kernel"),
            (r#"{"kernel":"VA","scale":0}"#, "bad_request"),
            (r#"{"kernel":"VA","scale":-1}"#, "bad_request"),
            (r#"{"kernel":"VA","scale":"big"}"#, "bad_request"),
            (r#"{"kernel":"VA","deadline_us":0}"#, "bad_request"),
            (r#"{"kernel":"VA","deadline_us":-5}"#, "bad_request"),
            (r#"{"kernel":"VA","deadline_us":1e999}"#, "bad_request"),
        ] {
            let resp = handle(&st, &m, &post("/v2/jobs", body));
            assert_eq!(code_of(&resp), code, "{body} -> {}", resp.body);
        }
        // None of those reached admission: the job table stays empty.
        let l = handle(&st, &m, &get("/v2/jobs"));
        let v = Value::parse(&l.body).unwrap();
        assert_eq!(v.get("count").and_then(Value::as_f64), Some(0.0));
        assert_eq!(
            v.get("stats").unwrap().get("submitted").and_then(Value::as_f64),
            Some(0.0)
        );
    }

    #[test]
    fn provably_unmeetable_deadline_is_a_422_at_submit() {
        let st = state();
        let m = Metrics::default();
        let r = handle(&st, &m, &post("/v2/jobs", r#"{"kernel":"VA","deadline_us":1e-6}"#));
        assert_eq!(r.status, 422, "{}", r.body);
        assert_eq!(code_of(&r), "infeasible_at_submit");
        let v = Value::parse(&r.body).unwrap();
        assert!(
            v.get("error").and_then(Value::as_str).unwrap().contains("provably unmeetable"),
            "{}",
            r.body
        );
        // The rejection is counted but leaves no job record behind.
        let l = handle(&st, &m, &get("/v2/jobs"));
        let v = Value::parse(&l.body).unwrap();
        assert_eq!(v.get("count").and_then(Value::as_f64), Some(0.0));
        assert_eq!(
            v.get("stats").unwrap().get("rejected").and_then(Value::as_f64),
            Some(1.0)
        );
    }

    #[test]
    fn unknown_job_handles_are_404s() {
        let st = state();
        let m = Metrics::default();
        for req in [
            get("/v2/jobs/job-7"),
            get("/v2/jobs/banana"),
            get("/v2/jobs/7/extra"),
            delete("/v2/jobs/7"),
        ] {
            let resp = handle(&st, &m, &req);
            assert_eq!(resp.status, 404, "{} -> {}", req.path, resp.body);
            assert_eq!(code_of(&resp), "unknown_job");
        }
    }

    #[test]
    fn job_transitions_reach_the_event_log() {
        let mut path = std::env::temp_dir();
        path.push(format!("gpufreq-routes-jobs-events-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let mut st = state();
            st.events = Some(Arc::new(crate::obs::EventSink::to_path(&path).unwrap()));
            let m = Metrics::default();
            let r = handle_traced(
                &st,
                &m,
                &post("/v2/jobs", r#"{"kernel":"VA","name":"traced","scale":1e9}"#),
                Some("req-job"),
            );
            assert_eq!(r.status, 202, "{}", r.body);
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<Value> = text.lines().map(|l| Value::parse(l).unwrap()).collect();
        // One repair solve plus the queued -> scheduled -> running
        // transition trail, all correlated with the request id.
        let solves: Vec<&Value> = lines
            .iter()
            .filter(|l| l.get("event").and_then(Value::as_str) == Some("solve"))
            .collect();
        assert_eq!(solves.len(), 1, "{text}");
        assert_eq!(solves[0].get("kind").and_then(Value::as_str), Some("repair"));
        assert_eq!(solves[0].get("trigger").and_then(Value::as_str), Some("job_arrival"));
        let trans: Vec<&Value> = lines
            .iter()
            .filter(|l| l.get("event").and_then(Value::as_str) == Some("job_transition"))
            .collect();
        let states: Vec<&str> =
            trans.iter().map(|t| t.get("to").and_then(Value::as_str).unwrap()).collect();
        assert_eq!(states, ["queued", "scheduled", "running"], "{text}");
        assert!(trans[0].get("from").is_none(), "admission has no prior state: {text}");
        assert_eq!(trans[1].get("from").and_then(Value::as_str), Some("queued"));
        for t in &trans {
            assert_eq!(t.get("job").and_then(Value::as_str), Some("job-1"));
            assert_eq!(t.get("request_id").and_then(Value::as_str), Some("req-job"));
        }
        let _ = std::fs::remove_file(&path);
    }
}
