//! Service observability (DESIGN.md §9): lock-free request counters,
//! per-route latency histograms and the `/metrics` text exposition.
//!
//! The histogram uses fixed log-linear bucket bounds (1-2-5 decades
//! from 1 µs to 100 s), so recording is one atomic increment and
//! quantile queries never allocate. Bounds are coarse (≤ 2.5× between
//! neighbours) — exact percentiles for benchmarking come from the load
//! harness's client-side samples; the histogram is for live gauges.
//! External scrapers get the raw cumulative counts too: every
//! histogram also renders Prometheus-convention
//! `…_bucket{…,le="<bound>"}` / `…_sum` / `…_count` lines, with the
//! overflow tail exposed as the `le="+Inf"` bucket.
//!
//! Besides the per-route histograms, `/metrics` exposes per-[`Stage`]
//! request-lifecycle histograms (`service_stage_latency_us…`, fed by
//! the span capture in `server.rs` — DESIGN.md §13) and the live
//! model-accuracy gauges (`model_mape{device,kernel}`,
//! `model_samples_total{device,kernel}`) fed by `POST
//! /v2/observations`.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering::Relaxed};
use std::time::Duration;

use crate::engine::CacheStats;
use crate::obs::{AccuracySeries, Stage};

/// Histogram bucket upper bounds, microseconds.
const BUCKET_BOUNDS_US: [f64; 24] = [
    1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0, 1e3, 2e3, 5e3, 1e4, 2e4, 5e4, 1e5,
    2e5, 5e5, 1e6, 2e6, 5e6, 1e7, 2e7, 5e7,
];

/// A fixed-bound latency histogram with atomic buckets.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKET_BOUNDS_US.len()],
    /// Samples above the last bound.
    overflow: AtomicU64,
    count: AtomicU64,
    /// Nanosecond accumulation — sub-microsecond handler times (cache
    /// hits, /healthz) must not truncate the mean to zero.
    sum_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            overflow: AtomicU64::new(0),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn record(&self, d: Duration) {
        let us = d.as_secs_f64() * 1e6;
        match BUCKET_BOUNDS_US.iter().position(|&b| us <= b) {
            Some(i) => self.buckets[i].fetch_add(1, Relaxed),
            None => self.overflow.fetch_add(1, Relaxed),
        };
        self.count.fetch_add(1, Relaxed);
        self.sum_ns.fetch_add(d.as_nanos().min(u64::MAX as u128) as u64, Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Relaxed)
    }

    /// Mean latency in microseconds (0 when empty).
    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_ns.load(Relaxed) as f64 / 1e3 / n as f64
    }

    /// Approximate quantile (`q` in [0, 1]): the upper bound of the
    /// bucket where the cumulative count crosses `q·total`, or
    /// `+Inf` when the target sits in the overflow tail — a 120 s
    /// sample must not masquerade as the 50 s top bound.
    pub fn quantile_us(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil().max(1.0) as u64;
        let mut cumulative = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cumulative += b.load(Relaxed);
            if cumulative >= target {
                return BUCKET_BOUNDS_US[i];
            }
        }
        // Target sits in the overflow (+Inf) bucket.
        f64::INFINITY
    }

    /// Total microseconds recorded (Prometheus `…_sum`).
    pub fn sum_us(&self) -> f64 {
        self.sum_ns.load(Relaxed) as f64 / 1e3
    }

    /// Samples above the last finite bound (the `le="+Inf"` tail).
    pub fn overflow(&self) -> u64 {
        self.overflow.load(Relaxed)
    }

    /// Cumulative (bound, count) pairs, Prometheus histogram
    /// convention: entry `i` counts every sample ≤ `BUCKET_BOUNDS_US[i]`.
    /// The `+Inf` bucket is [`Histogram::count`]. Reads race recording
    /// benignly (counts are monotone; a scrape may be one sample
    /// stale per bucket).
    pub fn cumulative_buckets(&self) -> [(f64, u64); BUCKET_BOUNDS_US.len()] {
        let mut cumulative = 0u64;
        std::array::from_fn(|i| {
            cumulative += self.buckets[i].load(Relaxed);
            (BUCKET_BOUNDS_US[i], cumulative)
        })
    }
}

/// The routes the service meters. `Other` absorbs 404 traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    Healthz,
    Metrics,
    Predict,
    Grid,
    Advise,
    DevicesV2,
    KernelsV2,
    PredictV2,
    AdviseV2,
    PlanV2,
    ObservationsV2,
    DebugTraces,
    Other,
}

impl Route {
    pub const ALL: [Route; 13] = [
        Route::Healthz,
        Route::Metrics,
        Route::Predict,
        Route::Grid,
        Route::Advise,
        Route::DevicesV2,
        Route::KernelsV2,
        Route::PredictV2,
        Route::AdviseV2,
        Route::PlanV2,
        Route::ObservationsV2,
        Route::DebugTraces,
        Route::Other,
    ];

    pub fn of_path(path: &str) -> Route {
        match path {
            "/healthz" => Route::Healthz,
            "/metrics" => Route::Metrics,
            "/v1/predict" => Route::Predict,
            "/v1/grid" => Route::Grid,
            "/v1/advise" => Route::Advise,
            "/v2/devices" => Route::DevicesV2,
            "/v2/kernels" => Route::KernelsV2,
            "/v2/predict" => Route::PredictV2,
            "/v2/advise" => Route::AdviseV2,
            "/v2/plan" => Route::PlanV2,
            "/v2/observations" => Route::ObservationsV2,
            "/debug/traces" => Route::DebugTraces,
            _ => Route::Other,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Route::Healthz => "/healthz",
            Route::Metrics => "/metrics",
            Route::Predict => "/v1/predict",
            Route::Grid => "/v1/grid",
            Route::Advise => "/v1/advise",
            Route::DevicesV2 => "/v2/devices",
            Route::KernelsV2 => "/v2/kernels",
            Route::PredictV2 => "/v2/predict",
            Route::AdviseV2 => "/v2/advise",
            Route::PlanV2 => "/v2/plan",
            Route::ObservationsV2 => "/v2/observations",
            Route::DebugTraces => "/debug/traces",
            Route::Other => "other",
        }
    }

    fn index(self) -> usize {
        match self {
            Route::Healthz => 0,
            Route::Metrics => 1,
            Route::Predict => 2,
            Route::Grid => 3,
            Route::Advise => 4,
            Route::DevicesV2 => 5,
            Route::KernelsV2 => 6,
            Route::PredictV2 => 7,
            Route::AdviseV2 => 8,
            Route::PlanV2 => 9,
            Route::ObservationsV2 => 10,
            Route::DebugTraces => 11,
            Route::Other => 12,
        }
    }
}

/// Per-route counters + latency.
#[derive(Debug, Default)]
pub struct RouteMetrics {
    pub requests: AtomicU64,
    pub ok: AtomicU64,
    pub client_errors: AtomicU64,
    pub server_errors: AtomicU64,
    pub latency: Histogram,
}

/// Everything `/metrics` exposes. Shared (`Arc`) between the poll
/// loop, the executors and the `Service` handle; all counters are
/// atomics.
#[derive(Debug, Default)]
pub struct Metrics {
    routes: [RouteMetrics; Route::ALL.len()],
    /// Request-lifecycle latency per [`Stage`] (DESIGN.md §13), fed by
    /// the server's span capture across every route.
    stages: [Histogram; Stage::COUNT],
    /// Connections accepted (admitted or shed).
    pub connections_total: AtomicU64,
    /// Connections answered 429 at admission.
    pub shed_total: AtomicU64,
    /// Parsed requests waiting for an executor thread (gauge). With
    /// the readiness-driven core, idle keep-alive connections cost
    /// nothing here — only requests that have fully arrived and are
    /// queued for compute show up.
    pub queue_depth: AtomicUsize,
    /// Admission-credit component: up to `workers + queue_capacity`
    /// connections are live before new ones are shed with 429.
    pub queue_capacity: AtomicUsize,
}

impl Metrics {
    pub fn route(&self, r: Route) -> &RouteMetrics {
        &self.routes[r.index()]
    }

    pub fn stage(&self, s: Stage) -> &Histogram {
        &self.stages[s.index()]
    }

    /// Record one lifecycle-stage duration (span capture, server.rs).
    pub fn record_stage(&self, s: Stage, elapsed: Duration) {
        self.stages[s.index()].record(elapsed);
    }

    /// Record one handled request.
    pub fn record(&self, r: Route, status: u16, elapsed: Duration) {
        let m = self.route(r);
        m.requests.fetch_add(1, Relaxed);
        match status {
            200..=299 => m.ok.fetch_add(1, Relaxed),
            400..=499 => m.client_errors.fetch_add(1, Relaxed),
            _ => m.server_errors.fetch_add(1, Relaxed),
        };
        m.latency.record(elapsed);
    }

    /// Total requests over every route.
    pub fn requests_total(&self) -> u64 {
        self.routes.iter().map(|r| r.requests.load(Relaxed)).sum()
    }

    /// Render the text exposition (`GET /metrics`). Cache counters come
    /// from the engine — zeroed when the cache is disabled, so the
    /// lines are always present and scrapers never see a gap.
    /// `accuracy` is the live model-error snapshot from the
    /// [`crate::obs::AccuracyTracker`] (empty until the first
    /// `POST /v2/observations`).
    pub fn render(
        &self,
        cache: &CacheStats,
        uptime: Duration,
        backend: &str,
        accuracy: &[AccuracySeries],
    ) -> String {
        use std::fmt::Write as _;
        let mut out = String::with_capacity(16 * 1024);
        let _ = writeln!(out, "# gpufreq prediction service");
        let _ = writeln!(out, "service_uptime_seconds {:.3}", uptime.as_secs_f64());
        let _ = writeln!(out, "service_backend_info{{backend=\"{backend}\"}} 1");
        let _ = writeln!(
            out,
            "service_connections_total {}",
            self.connections_total.load(Relaxed)
        );
        let _ = writeln!(out, "service_shed_total {}", self.shed_total.load(Relaxed));
        let _ = writeln!(out, "service_queue_depth {}", self.queue_depth.load(Relaxed));
        let _ = writeln!(
            out,
            "service_queue_capacity {}",
            self.queue_capacity.load(Relaxed)
        );
        let _ = writeln!(out, "service_cache_hits {}", cache.hits);
        let _ = writeln!(out, "service_cache_misses {}", cache.misses);
        let _ = writeln!(out, "service_cache_entries {}", cache.entries);
        let _ = writeln!(out, "service_cache_evictions {}", cache.evictions);
        for r in Route::ALL {
            let m = self.route(r);
            let n = m.requests.load(Relaxed);
            if n == 0 && r == Route::Other {
                // Real routes emit zeros so dashboards see the series
                // immediately; the catch-all stays silent until it fires.
                continue;
            }
            let name = r.name();
            let _ = writeln!(out, "service_requests_total{{route=\"{name}\"}} {n}");
            let _ = writeln!(
                out,
                "service_responses_total{{route=\"{name}\",class=\"2xx\"}} {}",
                m.ok.load(Relaxed)
            );
            let _ = writeln!(
                out,
                "service_responses_total{{route=\"{name}\",class=\"4xx\"}} {}",
                m.client_errors.load(Relaxed)
            );
            let _ = writeln!(
                out,
                "service_responses_total{{route=\"{name}\",class=\"5xx\"}} {}",
                m.server_errors.load(Relaxed)
            );
            write_histogram(
                &mut out,
                "service_latency_us",
                &format!("route=\"{name}\""),
                &m.latency,
            );
        }
        // Request-lifecycle stages (DESIGN.md §13). Always present —
        // zeros until the server's span capture fires.
        for s in Stage::ALL {
            write_histogram(
                &mut out,
                "service_stage_latency_us",
                &format!("stage=\"{}\"", s.name()),
                self.stage(s),
            );
        }
        // Live model accuracy, one series per observed (device, kernel).
        let _ = writeln!(out, "model_observation_series {}", accuracy.len());
        for a in accuracy {
            let labels = format!("device=\"{}\",kernel=\"{}\"", a.device, a.kernel);
            let _ = writeln!(out, "model_samples_total{{{labels}}} {}", a.samples);
            let _ = writeln!(out, "model_mape{{{labels}}} {:.3}", a.mape_pct);
        }
        out
    }
}

/// `+Inf`-aware gauge formatting: overflow-tail quantiles are infinite.
fn fmt_us(v: f64) -> String {
    if v.is_infinite() {
        "+Inf".to_string()
    } else {
        format!("{v:.1}")
    }
}

/// One histogram's full exposition: the mean/p50/p99/p999 gauges plus
/// the Prometheus-convention cumulative `_bucket`/`_sum`/`_count`
/// lines (the overflow tail is the `le="+Inf"` bucket).
fn write_histogram(out: &mut String, metric: &str, labels: &str, h: &Histogram) {
    use std::fmt::Write as _;
    let _ = writeln!(out, "{metric}{{{labels},stat=\"mean\"}} {:.1}", h.mean_us());
    for (q, label) in [(0.5, "p50"), (0.99, "p99"), (0.999, "p999")] {
        let _ = writeln!(out, "{metric}{{{labels},stat=\"{label}\"}} {}", fmt_us(h.quantile_us(q)));
    }
    for (bound, cumulative) in h.cumulative_buckets() {
        let _ = writeln!(out, "{metric}_bucket{{{labels},le=\"{bound}\"}} {cumulative}");
    }
    let _ = writeln!(out, "{metric}_bucket{{{labels},le=\"+Inf\"}} {}", h.count());
    let _ = writeln!(out, "{metric}_sum{{{labels}}} {:.1}", h.sum_us());
    let _ = writeln!(out, "{metric}_count{{{labels}}} {}", h.count());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_bracket_the_samples() {
        let h = Histogram::default();
        // 99 fast samples at ~3 µs, one slow at ~40 ms.
        for _ in 0..99 {
            h.record(Duration::from_micros(3));
        }
        h.record(Duration::from_millis(40));
        assert_eq!(h.count(), 100);
        assert_eq!(h.quantile_us(0.5), 5.0); // 3 µs falls in the ≤5 bucket
        assert_eq!(h.quantile_us(0.99), 5.0);
        assert_eq!(h.quantile_us(1.0), 5e4); // 40 ms falls in the ≤50 ms bucket
        assert!(h.mean_us() > 3.0 && h.mean_us() < 1000.0);
    }

    #[test]
    fn empty_histogram_is_all_zero() {
        let h = Histogram::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile_us(0.5), 0.0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn sub_microsecond_samples_keep_a_nonzero_mean() {
        let h = Histogram::default();
        for _ in 0..10 {
            h.record(Duration::from_nanos(300));
        }
        assert!((h.mean_us() - 0.3).abs() < 1e-9, "mean {}", h.mean_us());
        assert_eq!(h.quantile_us(0.5), 1.0); // ≤ 1 µs bucket
    }

    #[test]
    fn overflow_samples_report_the_inf_bucket() {
        // A 120 s sample is beyond the 50 s top bound: it must report
        // +Inf, not masquerade as the top bound.
        let h = Histogram::default();
        h.record(Duration::from_secs(120));
        assert_eq!(h.quantile_us(0.5), f64::INFINITY);
        assert_eq!(h.overflow(), 1);
        // Every finite cumulative bucket is empty; the sample only
        // exists in the +Inf tail (i.e. in `count`).
        assert!(h.cumulative_buckets().iter().all(|&(_, n)| n == 0));
        assert_eq!(h.count(), 1);
        // A fast sample alongside keeps the low quantiles finite while
        // the max stays +Inf.
        h.record(Duration::from_micros(3));
        assert_eq!(h.quantile_us(0.25), 5.0);
        assert_eq!(h.quantile_us(1.0), f64::INFINITY);
    }

    #[test]
    fn cumulative_buckets_follow_prometheus_convention() {
        let h = Histogram::default();
        h.record(Duration::from_micros(3)); // ≤ 5
        h.record(Duration::from_micros(4)); // ≤ 5
        h.record(Duration::from_micros(40)); // ≤ 50
        let buckets = h.cumulative_buckets();
        let at = |bound: f64| buckets.iter().find(|&&(b, _)| b == bound).unwrap().1;
        assert_eq!(at(2.0), 0);
        assert_eq!(at(5.0), 2);
        assert_eq!(at(20.0), 2);
        assert_eq!(at(50.0), 3); // cumulative, not per-bucket
        assert_eq!(at(5e7), 3);
        assert!((h.sum_us() - 47.0).abs() < 1e-9, "sum {}", h.sum_us());
    }

    #[test]
    fn route_mapping_is_total() {
        assert_eq!(Route::of_path("/healthz"), Route::Healthz);
        assert_eq!(Route::of_path("/v1/predict"), Route::Predict);
        assert_eq!(Route::of_path("/v2/predict"), Route::PredictV2);
        assert_eq!(Route::of_path("/v2/devices"), Route::DevicesV2);
        assert_eq!(Route::of_path("/v2/plan"), Route::PlanV2);
        assert_eq!(Route::of_path("/v2/observations"), Route::ObservationsV2);
        assert_eq!(Route::of_path("/debug/traces"), Route::DebugTraces);
        assert_eq!(Route::of_path("/nope"), Route::Other);
        for r in Route::ALL {
            assert_eq!(Route::of_path(r.name()), if r == Route::Other { Route::Other } else { r });
        }
    }

    #[test]
    fn render_contains_all_core_series() {
        let m = Metrics::default();
        m.record(Route::Predict, 200, Duration::from_micros(10));
        m.record(Route::Predict, 400, Duration::from_micros(12));
        m.record(Route::Advise, 500, Duration::from_micros(15));
        m.record_stage(Stage::Compute, Duration::from_micros(8));
        let accuracy = [AccuracySeries {
            device: "dev-1".into(),
            kernel: "krn-1".into(),
            mape_pct: 3.5,
            window: 2,
            samples: 2,
        }];
        let text =
            m.render(&CacheStats::default(), Duration::from_secs(2), "native-scalar", &accuracy);
        for needle in [
            "service_uptime_seconds",
            "service_queue_depth 0",
            "service_cache_hits 0",
            "service_requests_total{route=\"/v1/predict\"} 2",
            "service_responses_total{route=\"/v1/predict\",class=\"2xx\"} 1",
            "service_responses_total{route=\"/v1/predict\",class=\"4xx\"} 1",
            "service_responses_total{route=\"/v1/advise\",class=\"5xx\"} 1",
            "service_latency_us{route=\"/v1/predict\",stat=\"p50\"}",
            // Prometheus-convention cumulative histogram (satellite):
            // both samples sit at or under the 20 µs bound.
            "service_latency_us_bucket{route=\"/v1/predict\",le=\"10\"} 1",
            "service_latency_us_bucket{route=\"/v1/predict\",le=\"20\"} 2",
            "service_latency_us_bucket{route=\"/v1/predict\",le=\"+Inf\"} 2",
            "service_latency_us_sum{route=\"/v1/predict\"}",
            "service_latency_us_count{route=\"/v1/predict\"} 2",
            // New typed routes emit zeros immediately like every real route.
            "service_requests_total{route=\"/v2/observations\"} 0",
            "service_requests_total{route=\"/debug/traces\"} 0",
            // Request-lifecycle stage histograms (DESIGN.md §13).
            "service_stage_latency_us{stage=\"compute\",stat=\"p50\"}",
            "service_stage_latency_us_bucket{stage=\"compute\",le=\"10\"} 1",
            "service_stage_latency_us_count{stage=\"queue\"} 0",
            // Live model accuracy fed by POST /v2/observations.
            "model_observation_series 1",
            "model_samples_total{device=\"dev-1\",kernel=\"krn-1\"} 2",
            "model_mape{device=\"dev-1\",kernel=\"krn-1\"} 3.500",
        ] {
            assert!(text.contains(needle), "missing `{needle}` in:\n{text}");
        }
        // The catch-all stays silent until it fires.
        assert!(!text.contains("route=\"other\""));
    }

    #[test]
    fn infinite_quantile_gauges_render_as_inf() {
        let m = Metrics::default();
        m.record(Route::Healthz, 200, Duration::from_secs(120));
        let text = m.render(&CacheStats::default(), Duration::from_secs(1), "native-scalar", &[]);
        assert!(
            text.contains("service_latency_us{route=\"/healthz\",stat=\"p50\"} +Inf"),
            "overflow quantile must render +Inf:\n{text}"
        );
        assert!(text.contains("service_latency_us_bucket{route=\"/healthz\",le=\"50000000\"} 0"));
        assert!(text.contains("service_latency_us_bucket{route=\"/healthz\",le=\"+Inf\"} 1"));
    }
}
