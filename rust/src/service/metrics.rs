//! Service observability (DESIGN.md §9): lock-free request counters,
//! per-route latency histograms and the `/metrics` text exposition.
//!
//! The histogram uses fixed log-linear bucket bounds (1-2-5 decades
//! from 1 µs to 100 s), so recording is one atomic increment and
//! quantile queries never allocate. Bounds are coarse (≤ 2.5× between
//! neighbours) — exact percentiles for benchmarking come from the load
//! harness's client-side samples; the histogram is for live gauges.
//! External scrapers get the raw cumulative counts too: every
//! histogram also renders Prometheus-convention
//! `…_bucket{…,le="<bound>"}` / `…_sum` / `…_count` lines, with the
//! overflow tail exposed as the `le="+Inf"` bucket.
//!
//! Besides the per-route histograms, `/metrics` exposes per-[`Stage`]
//! request-lifecycle histograms (`service_stage_latency_us…`, fed by
//! the span capture in `server.rs` — DESIGN.md §13) and the live
//! model-accuracy gauges (`model_mape{device,kernel}`,
//! `model_samples_total{device,kernel}`) fed by `POST
//! /v2/observations`.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering::Relaxed};
use std::time::Duration;

use crate::engine::CacheStats;
use crate::obs::{AccuracySeries, Stage};
use crate::planner::SolveReport;
use crate::scheduler::SchedulerStats;

/// Histogram bucket upper bounds, microseconds.
const BUCKET_BOUNDS_US: [f64; 24] = [
    1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0, 1e3, 2e3, 5e3, 1e4, 2e4, 5e4, 1e5,
    2e5, 5e5, 1e6, 2e6, 5e6, 1e7, 2e7, 5e7,
];

/// A fixed-bound latency histogram with atomic buckets.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKET_BOUNDS_US.len()],
    /// Samples above the last bound.
    overflow: AtomicU64,
    count: AtomicU64,
    /// Nanosecond accumulation — sub-microsecond handler times (cache
    /// hits, /healthz) must not truncate the mean to zero.
    sum_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            overflow: AtomicU64::new(0),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn record(&self, d: Duration) {
        let us = d.as_secs_f64() * 1e6;
        match BUCKET_BOUNDS_US.iter().position(|&b| us <= b) {
            Some(i) => self.buckets[i].fetch_add(1, Relaxed),
            None => self.overflow.fetch_add(1, Relaxed),
        };
        self.count.fetch_add(1, Relaxed);
        self.sum_ns.fetch_add(d.as_nanos().min(u64::MAX as u128) as u64, Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Relaxed)
    }

    /// Mean latency in microseconds (0 when empty).
    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_ns.load(Relaxed) as f64 / 1e3 / n as f64
    }

    /// Approximate quantile (`q` in [0, 1]): the upper bound of the
    /// bucket where the cumulative count crosses `q·total`, or
    /// `+Inf` when the target sits in the overflow tail — a 120 s
    /// sample must not masquerade as the 50 s top bound.
    pub fn quantile_us(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil().max(1.0) as u64;
        let mut cumulative = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cumulative += b.load(Relaxed);
            if cumulative >= target {
                return BUCKET_BOUNDS_US[i];
            }
        }
        // Target sits in the overflow (+Inf) bucket.
        f64::INFINITY
    }

    /// Total microseconds recorded (Prometheus `…_sum`).
    pub fn sum_us(&self) -> f64 {
        self.sum_ns.load(Relaxed) as f64 / 1e3
    }

    /// Samples above the last finite bound (the `le="+Inf"` tail).
    pub fn overflow(&self) -> u64 {
        self.overflow.load(Relaxed)
    }

    /// Cumulative (bound, count) pairs, Prometheus histogram
    /// convention: entry `i` counts every sample ≤ `BUCKET_BOUNDS_US[i]`.
    /// The `+Inf` bucket is [`Histogram::count`]. Reads race recording
    /// benignly (counts are monotone; a scrape may be one sample
    /// stale per bucket).
    pub fn cumulative_buckets(&self) -> [(f64, u64); BUCKET_BOUNDS_US.len()] {
        let mut cumulative = 0u64;
        std::array::from_fn(|i| {
            cumulative += self.buckets[i].load(Relaxed);
            (BUCKET_BOUNDS_US[i], cumulative)
        })
    }
}

/// The routes the service meters. `Other` absorbs 404 traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    Healthz,
    Metrics,
    Predict,
    Grid,
    Advise,
    DevicesV2,
    KernelsV2,
    PredictV2,
    AdviseV2,
    PlanV2,
    /// `POST /v2/jobs` (submit) and `GET /v2/jobs` (list).
    JobsV2,
    /// `GET`/`DELETE /v2/jobs/{id}` — one metered route for every id.
    JobV2,
    ObservationsV2,
    DebugTraces,
    DebugPlans,
    DebugDrift,
    Other,
}

impl Route {
    pub const ALL: [Route; 17] = [
        Route::Healthz,
        Route::Metrics,
        Route::Predict,
        Route::Grid,
        Route::Advise,
        Route::DevicesV2,
        Route::KernelsV2,
        Route::PredictV2,
        Route::AdviseV2,
        Route::PlanV2,
        Route::JobsV2,
        Route::JobV2,
        Route::ObservationsV2,
        Route::DebugTraces,
        Route::DebugPlans,
        Route::DebugDrift,
        Route::Other,
    ];

    pub fn of_path(path: &str) -> Route {
        match path {
            "/healthz" => Route::Healthz,
            "/metrics" => Route::Metrics,
            "/v1/predict" => Route::Predict,
            "/v1/grid" => Route::Grid,
            "/v1/advise" => Route::Advise,
            "/v2/devices" => Route::DevicesV2,
            "/v2/kernels" => Route::KernelsV2,
            "/v2/predict" => Route::PredictV2,
            "/v2/advise" => Route::AdviseV2,
            "/v2/plan" => Route::PlanV2,
            "/v2/jobs" => Route::JobsV2,
            "/v2/observations" => Route::ObservationsV2,
            "/debug/traces" => Route::DebugTraces,
            "/debug/plans" => Route::DebugPlans,
            "/debug/drift" => Route::DebugDrift,
            p if p.starts_with("/v2/jobs/") => Route::JobV2,
            _ => Route::Other,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Route::Healthz => "/healthz",
            Route::Metrics => "/metrics",
            Route::Predict => "/v1/predict",
            Route::Grid => "/v1/grid",
            Route::Advise => "/v1/advise",
            Route::DevicesV2 => "/v2/devices",
            Route::KernelsV2 => "/v2/kernels",
            Route::PredictV2 => "/v2/predict",
            Route::AdviseV2 => "/v2/advise",
            Route::PlanV2 => "/v2/plan",
            Route::JobsV2 => "/v2/jobs",
            Route::JobV2 => "/v2/jobs/{id}",
            Route::ObservationsV2 => "/v2/observations",
            Route::DebugTraces => "/debug/traces",
            Route::DebugPlans => "/debug/plans",
            Route::DebugDrift => "/debug/drift",
            Route::Other => "other",
        }
    }

    fn index(self) -> usize {
        match self {
            Route::Healthz => 0,
            Route::Metrics => 1,
            Route::Predict => 2,
            Route::Grid => 3,
            Route::Advise => 4,
            Route::DevicesV2 => 5,
            Route::KernelsV2 => 6,
            Route::PredictV2 => 7,
            Route::AdviseV2 => 8,
            Route::PlanV2 => 9,
            Route::JobsV2 => 10,
            Route::JobV2 => 11,
            Route::ObservationsV2 => 12,
            Route::DebugTraces => 13,
            Route::DebugPlans => 14,
            Route::DebugDrift => 15,
            Route::Other => 16,
        }
    }
}

/// Per-route counters + latency.
#[derive(Debug, Default)]
pub struct RouteMetrics {
    pub requests: AtomicU64,
    pub ok: AtomicU64,
    pub client_errors: AtomicU64,
    pub server_errors: AtomicU64,
    pub latency: Histogram,
}

/// Solver-phase labels for the `planner_phase_us` histograms, in the
/// order the phases run (`total` is the whole solve, explains
/// included).
pub const PLANNER_PHASES: [&str; 5] = ["build", "greedy", "repair", "swap", "total"];

/// Solver telemetry aggregated across every `/v2/plan` solve
/// (DESIGN.md §13): per-phase latency histograms plus the work
/// counters a [`SolveReport`] carries.
#[derive(Debug, Default)]
pub struct PlannerMetrics {
    /// One histogram per [`PLANNER_PHASES`] entry.
    phases: [Histogram; PLANNER_PHASES.len()],
    pub solves_total: AtomicU64,
    pub candidates_total: AtomicU64,
    pub slab_calls_total: AtomicU64,
    pub relocations_tried_total: AtomicU64,
    pub relocations_accepted_total: AtomicU64,
    pub swaps_tried_total: AtomicU64,
    pub swaps_accepted_total: AtomicU64,
}

impl PlannerMetrics {
    /// The histogram for one phase label index (see [`PLANNER_PHASES`]).
    pub fn phase(&self, i: usize) -> &Histogram {
        &self.phases[i]
    }
}

/// Everything `/metrics` exposes. Shared (`Arc`) between the poll
/// loop, the executors and the `Service` handle; all counters are
/// atomics.
#[derive(Debug, Default)]
pub struct Metrics {
    routes: [RouteMetrics; Route::ALL.len()],
    /// Request-lifecycle latency per [`Stage`] (DESIGN.md §13), fed by
    /// the server's span capture across every route.
    stages: [Histogram; Stage::COUNT],
    /// Connections accepted (admitted or shed).
    pub connections_total: AtomicU64,
    /// Connections answered 429 at admission.
    pub shed_total: AtomicU64,
    /// Parsed requests waiting for an executor thread (gauge). With
    /// the readiness-driven core, idle keep-alive connections cost
    /// nothing here — only requests that have fully arrived and are
    /// queued for compute show up.
    pub queue_depth: AtomicUsize,
    /// Admission-credit component: up to `workers + queue_capacity`
    /// connections are live before new ones are shed with 429.
    pub queue_capacity: AtomicUsize,
    /// Solver telemetry aggregated over `/v2/plan` (DESIGN.md §13).
    pub planner: PlannerMetrics,
}

impl Metrics {
    pub fn route(&self, r: Route) -> &RouteMetrics {
        &self.routes[r.index()]
    }

    pub fn stage(&self, s: Stage) -> &Histogram {
        &self.stages[s.index()]
    }

    /// Record one lifecycle-stage duration (span capture, server.rs).
    pub fn record_stage(&self, s: Stage, elapsed: Duration) {
        self.stages[s.index()].record(elapsed);
    }

    /// Record one handled request.
    pub fn record(&self, r: Route, status: u16, elapsed: Duration) {
        let m = self.route(r);
        m.requests.fetch_add(1, Relaxed);
        match status {
            200..=299 => m.ok.fetch_add(1, Relaxed),
            400..=499 => m.client_errors.fetch_add(1, Relaxed),
            _ => m.server_errors.fetch_add(1, Relaxed),
        };
        m.latency.record(elapsed);
    }

    /// Total requests over every route.
    pub fn requests_total(&self) -> u64 {
        self.routes.iter().map(|r| r.requests.load(Relaxed)).sum()
    }

    /// Fold one solve's [`SolveReport`] into the planner aggregates.
    /// Work counters always accumulate; the phase histograms only
    /// record when the report carries spans (telemetry on), so a
    /// telemetry-off solve never pollutes the latency series with
    /// zeros.
    pub fn record_solve(&self, report: &SolveReport) {
        let p = &self.planner;
        p.solves_total.fetch_add(1, Relaxed);
        p.candidates_total.fetch_add(report.candidates_evaluated, Relaxed);
        p.slab_calls_total.fetch_add(report.slab_calls, Relaxed);
        p.relocations_tried_total.fetch_add(report.relocations_tried, Relaxed);
        p.relocations_accepted_total.fetch_add(report.relocations_accepted, Relaxed);
        p.swaps_tried_total.fetch_add(report.swaps_tried, Relaxed);
        p.swaps_accepted_total.fetch_add(report.swaps_accepted, Relaxed);
        if report.total_us > 0.0 {
            let spans = [
                report.build_us,
                report.greedy_us,
                report.repair_us,
                report.swap_us,
                report.total_us,
            ];
            for (h, us) in p.phases.iter().zip(spans) {
                h.record(Duration::from_secs_f64(us.max(0.0) / 1e6));
            }
        }
    }

    /// Render the text exposition (`GET /metrics`). Cache counters come
    /// from the engine — zeroed when the cache is disabled, so the
    /// lines are always present and scrapers never see a gap.
    /// `accuracy` is the live model-error snapshot from the
    /// [`crate::obs::AccuracyTracker`] (empty until the first
    /// `POST /v2/observations`); `samples_dropped` is its count of
    /// observations refused at the series-table bound; `events` is the
    /// `(emitted, dropped)` pair from the optional `--event-log` sink
    /// (`None` renders the series as disabled-with-zeros so scrapers
    /// never see a gap); `scheduler` is the streaming scheduler's
    /// counter snapshot ([`SchedulerCore::stats`]).
    ///
    /// [`SchedulerCore::stats`]: crate::scheduler::SchedulerCore::stats
    pub fn render(
        &self,
        cache: &CacheStats,
        uptime: Duration,
        backend: &str,
        accuracy: &[AccuracySeries],
        samples_dropped: u64,
        events: Option<(u64, u64)>,
        scheduler: &SchedulerStats,
    ) -> String {
        use std::fmt::Write as _;
        let mut out = String::with_capacity(16 * 1024);
        let _ = writeln!(out, "# gpufreq prediction service");
        let _ = writeln!(out, "service_uptime_seconds {:.3}", uptime.as_secs_f64());
        let _ = writeln!(out, "service_backend_info{{backend=\"{backend}\"}} 1");
        let _ = writeln!(
            out,
            "service_connections_total {}",
            self.connections_total.load(Relaxed)
        );
        let _ = writeln!(out, "service_shed_total {}", self.shed_total.load(Relaxed));
        let _ = writeln!(out, "service_queue_depth {}", self.queue_depth.load(Relaxed));
        let _ = writeln!(
            out,
            "service_queue_capacity {}",
            self.queue_capacity.load(Relaxed)
        );
        let _ = writeln!(out, "service_cache_hits {}", cache.hits);
        let _ = writeln!(out, "service_cache_misses {}", cache.misses);
        let _ = writeln!(out, "service_cache_entries {}", cache.entries);
        let _ = writeln!(out, "service_cache_evictions {}", cache.evictions);
        for r in Route::ALL {
            let m = self.route(r);
            let n = m.requests.load(Relaxed);
            if n == 0 && r == Route::Other {
                // Real routes emit zeros so dashboards see the series
                // immediately; the catch-all stays silent until it fires.
                continue;
            }
            let name = r.name();
            let _ = writeln!(out, "service_requests_total{{route=\"{name}\"}} {n}");
            let _ = writeln!(
                out,
                "service_responses_total{{route=\"{name}\",class=\"2xx\"}} {}",
                m.ok.load(Relaxed)
            );
            let _ = writeln!(
                out,
                "service_responses_total{{route=\"{name}\",class=\"4xx\"}} {}",
                m.client_errors.load(Relaxed)
            );
            let _ = writeln!(
                out,
                "service_responses_total{{route=\"{name}\",class=\"5xx\"}} {}",
                m.server_errors.load(Relaxed)
            );
            write_histogram(
                &mut out,
                "service_latency_us",
                &format!("route=\"{name}\""),
                &m.latency,
            );
        }
        // Request-lifecycle stages (DESIGN.md §13). Always present —
        // zeros until the server's span capture fires.
        for s in Stage::ALL {
            write_histogram(
                &mut out,
                "service_stage_latency_us",
                &format!("stage=\"{}\"", s.name()),
                self.stage(s),
            );
        }
        // Solver telemetry (DESIGN.md §13) — always present, zeros
        // until the first `/v2/plan` solve.
        let p = &self.planner;
        let _ = writeln!(out, "planner_solves_total {}", p.solves_total.load(Relaxed));
        let _ = writeln!(
            out,
            "planner_candidates_evaluated_total {}",
            p.candidates_total.load(Relaxed)
        );
        let _ = writeln!(out, "planner_slab_calls_total {}", p.slab_calls_total.load(Relaxed));
        let _ = writeln!(
            out,
            "planner_relocations_tried_total {}",
            p.relocations_tried_total.load(Relaxed)
        );
        let _ = writeln!(
            out,
            "planner_relocations_accepted_total {}",
            p.relocations_accepted_total.load(Relaxed)
        );
        let _ = writeln!(out, "planner_swaps_tried_total {}", p.swaps_tried_total.load(Relaxed));
        let _ = writeln!(
            out,
            "planner_swaps_accepted_total {}",
            p.swaps_accepted_total.load(Relaxed)
        );
        for (i, phase) in PLANNER_PHASES.iter().enumerate() {
            write_histogram(
                &mut out,
                "planner_phase_us",
                &format!("phase=\"{phase}\""),
                p.phase(i),
            );
        }
        // Live model accuracy, one series per observed (device, kernel).
        let _ = writeln!(out, "model_observation_series {}", accuracy.len());
        let _ = writeln!(out, "model_samples_dropped_total {samples_dropped}");
        for a in accuracy {
            let labels = format!("device=\"{}\",kernel=\"{}\"", a.device, a.kernel);
            let _ = writeln!(out, "model_samples_total{{{labels}}} {}", a.samples);
            let _ = writeln!(out, "model_mape{{{labels}}} {:.3}", a.mape_pct);
            let _ = writeln!(out, "model_error_ewma{{{labels}}} {:.3}", a.ewma_pct);
            let _ = writeln!(out, "model_drift_state{{{labels}}} {}", a.state.gauge());
        }
        // Structured event log (`--event-log`): zeros when disabled so
        // the series are always scrapeable.
        let (enabled, emitted, dropped) = match events {
            Some((e, d)) => (1, e, d),
            None => (0, 0, 0),
        };
        let _ = writeln!(out, "service_event_log_enabled {enabled}");
        let _ = writeln!(out, "service_events_emitted_total {emitted}");
        let _ = writeln!(out, "service_events_dropped_total {dropped}");
        // Streaming scheduler lifecycle counters — always present,
        // zeros until the first `POST /v2/jobs`.
        let s = scheduler;
        let _ = writeln!(out, "scheduler_jobs_submitted_total {}", s.submitted);
        let _ = writeln!(out, "scheduler_jobs_admitted_total {}", s.admitted);
        let _ = writeln!(out, "scheduler_jobs_rejected_total {}", s.rejected);
        let _ = writeln!(out, "scheduler_jobs_completed_total {}", s.completed);
        let _ = writeln!(out, "scheduler_jobs_missed_total {}", s.missed);
        let _ = writeln!(out, "scheduler_jobs_cancelled_total {}", s.cancelled);
        let _ = writeln!(out, "scheduler_jobs_active {}", s.active);
        let _ = writeln!(out, "scheduler_repairs_total {}", s.repairs);
        let _ = writeln!(out, "scheduler_full_solves_total {}", s.full_solves);
        let _ = writeln!(out, "scheduler_repair_fallbacks_total {}", s.repair_fallbacks);
        let _ = writeln!(out, "scheduler_events_processed_total {}", s.events_processed);
        out
    }
}

/// `+Inf`-aware gauge formatting: overflow-tail quantiles are infinite.
fn fmt_us(v: f64) -> String {
    if v.is_infinite() {
        "+Inf".to_string()
    } else {
        format!("{v:.1}")
    }
}

/// One histogram's full exposition: the mean/p50/p99/p999 gauges plus
/// the Prometheus-convention cumulative `_bucket`/`_sum`/`_count`
/// lines (the overflow tail is the `le="+Inf"` bucket).
fn write_histogram(out: &mut String, metric: &str, labels: &str, h: &Histogram) {
    use std::fmt::Write as _;
    let _ = writeln!(out, "{metric}{{{labels},stat=\"mean\"}} {:.1}", h.mean_us());
    for (q, label) in [(0.5, "p50"), (0.99, "p99"), (0.999, "p999")] {
        let _ = writeln!(out, "{metric}{{{labels},stat=\"{label}\"}} {}", fmt_us(h.quantile_us(q)));
    }
    for (bound, cumulative) in h.cumulative_buckets() {
        let _ = writeln!(out, "{metric}_bucket{{{labels},le=\"{bound}\"}} {cumulative}");
    }
    let _ = writeln!(out, "{metric}_bucket{{{labels},le=\"+Inf\"}} {}", h.count());
    let _ = writeln!(out, "{metric}_sum{{{labels}}} {:.1}", h.sum_us());
    let _ = writeln!(out, "{metric}_count{{{labels}}} {}", h.count());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_bracket_the_samples() {
        let h = Histogram::default();
        // 99 fast samples at ~3 µs, one slow at ~40 ms.
        for _ in 0..99 {
            h.record(Duration::from_micros(3));
        }
        h.record(Duration::from_millis(40));
        assert_eq!(h.count(), 100);
        assert_eq!(h.quantile_us(0.5), 5.0); // 3 µs falls in the ≤5 bucket
        assert_eq!(h.quantile_us(0.99), 5.0);
        assert_eq!(h.quantile_us(1.0), 5e4); // 40 ms falls in the ≤50 ms bucket
        assert!(h.mean_us() > 3.0 && h.mean_us() < 1000.0);
    }

    #[test]
    fn empty_histogram_is_all_zero() {
        let h = Histogram::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile_us(0.5), 0.0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn sub_microsecond_samples_keep_a_nonzero_mean() {
        let h = Histogram::default();
        for _ in 0..10 {
            h.record(Duration::from_nanos(300));
        }
        assert!((h.mean_us() - 0.3).abs() < 1e-9, "mean {}", h.mean_us());
        assert_eq!(h.quantile_us(0.5), 1.0); // ≤ 1 µs bucket
    }

    #[test]
    fn overflow_samples_report_the_inf_bucket() {
        // A 120 s sample is beyond the 50 s top bound: it must report
        // +Inf, not masquerade as the top bound.
        let h = Histogram::default();
        h.record(Duration::from_secs(120));
        assert_eq!(h.quantile_us(0.5), f64::INFINITY);
        assert_eq!(h.overflow(), 1);
        // Every finite cumulative bucket is empty; the sample only
        // exists in the +Inf tail (i.e. in `count`).
        assert!(h.cumulative_buckets().iter().all(|&(_, n)| n == 0));
        assert_eq!(h.count(), 1);
        // A fast sample alongside keeps the low quantiles finite while
        // the max stays +Inf.
        h.record(Duration::from_micros(3));
        assert_eq!(h.quantile_us(0.25), 5.0);
        assert_eq!(h.quantile_us(1.0), f64::INFINITY);
    }

    #[test]
    fn cumulative_buckets_follow_prometheus_convention() {
        let h = Histogram::default();
        h.record(Duration::from_micros(3)); // ≤ 5
        h.record(Duration::from_micros(4)); // ≤ 5
        h.record(Duration::from_micros(40)); // ≤ 50
        let buckets = h.cumulative_buckets();
        let at = |bound: f64| buckets.iter().find(|&&(b, _)| b == bound).unwrap().1;
        assert_eq!(at(2.0), 0);
        assert_eq!(at(5.0), 2);
        assert_eq!(at(20.0), 2);
        assert_eq!(at(50.0), 3); // cumulative, not per-bucket
        assert_eq!(at(5e7), 3);
        assert!((h.sum_us() - 47.0).abs() < 1e-9, "sum {}", h.sum_us());
    }

    #[test]
    fn route_mapping_is_total() {
        assert_eq!(Route::of_path("/healthz"), Route::Healthz);
        assert_eq!(Route::of_path("/v1/predict"), Route::Predict);
        assert_eq!(Route::of_path("/v2/predict"), Route::PredictV2);
        assert_eq!(Route::of_path("/v2/devices"), Route::DevicesV2);
        assert_eq!(Route::of_path("/v2/plan"), Route::PlanV2);
        assert_eq!(Route::of_path("/v2/jobs"), Route::JobsV2);
        assert_eq!(Route::of_path("/v2/jobs/job-12"), Route::JobV2);
        assert_eq!(Route::of_path("/v2/jobs/anything/else"), Route::JobV2);
        assert_eq!(Route::of_path("/v2/observations"), Route::ObservationsV2);
        assert_eq!(Route::of_path("/debug/traces"), Route::DebugTraces);
        assert_eq!(Route::of_path("/debug/plans"), Route::DebugPlans);
        assert_eq!(Route::of_path("/debug/drift"), Route::DebugDrift);
        assert_eq!(Route::of_path("/nope"), Route::Other);
        for r in Route::ALL {
            assert_eq!(Route::of_path(r.name()), if r == Route::Other { Route::Other } else { r });
        }
    }

    #[test]
    fn render_contains_all_core_series() {
        let m = Metrics::default();
        m.record(Route::Predict, 200, Duration::from_micros(10));
        m.record(Route::Predict, 400, Duration::from_micros(12));
        m.record(Route::Advise, 500, Duration::from_micros(15));
        m.record_stage(Stage::Compute, Duration::from_micros(8));
        let report = SolveReport {
            plan_id: 7,
            build_us: 40.0,
            greedy_us: 30.0,
            repair_us: 5.0,
            swap_us: 20.0,
            total_us: 110.0,
            candidates_evaluated: 32,
            slab_calls: 4,
            relocations_tried: 3,
            relocations_accepted: 1,
            swaps_tried: 6,
            swaps_accepted: 2,
            explains: Vec::new(),
        };
        m.record_solve(&report);
        let accuracy = [AccuracySeries {
            device: "dev-1".into(),
            kernel: "krn-1".into(),
            mape_pct: 3.5,
            ewma_pct: 12.25,
            state: crate::obs::DriftState::Warn,
            window: 2,
            samples: 2,
        }];
        let sched = SchedulerStats {
            submitted: 5,
            admitted: 4,
            rejected: 1,
            completed: 2,
            missed: 1,
            cancelled: 1,
            active: 0,
            repairs: 3,
            full_solves: 2,
            repair_fallbacks: 1,
            events_processed: 11,
        };
        let text = m.render(
            &CacheStats::default(),
            Duration::from_secs(2),
            "native-scalar",
            &accuracy,
            3,
            Some((9, 1)),
            &sched,
        );
        for needle in [
            "service_uptime_seconds",
            "service_queue_depth 0",
            "service_cache_hits 0",
            "service_requests_total{route=\"/v1/predict\"} 2",
            "service_responses_total{route=\"/v1/predict\",class=\"2xx\"} 1",
            "service_responses_total{route=\"/v1/predict\",class=\"4xx\"} 1",
            "service_responses_total{route=\"/v1/advise\",class=\"5xx\"} 1",
            "service_latency_us{route=\"/v1/predict\",stat=\"p50\"}",
            // Prometheus-convention cumulative histogram (satellite):
            // both samples sit at or under the 20 µs bound.
            "service_latency_us_bucket{route=\"/v1/predict\",le=\"10\"} 1",
            "service_latency_us_bucket{route=\"/v1/predict\",le=\"20\"} 2",
            "service_latency_us_bucket{route=\"/v1/predict\",le=\"+Inf\"} 2",
            "service_latency_us_sum{route=\"/v1/predict\"}",
            "service_latency_us_count{route=\"/v1/predict\"} 2",
            // New typed routes emit zeros immediately like every real route.
            "service_requests_total{route=\"/v2/observations\"} 0",
            "service_requests_total{route=\"/debug/traces\"} 0",
            // Request-lifecycle stage histograms (DESIGN.md §13).
            "service_stage_latency_us{stage=\"compute\",stat=\"p50\"}",
            "service_stage_latency_us_bucket{stage=\"compute\",le=\"10\"} 1",
            "service_stage_latency_us_count{stage=\"queue\"} 0",
            // New debug routes emit zeros immediately too.
            "service_requests_total{route=\"/debug/plans\"} 0",
            "service_requests_total{route=\"/debug/drift\"} 0",
            // Solver telemetry fed by /v2/plan solves.
            "planner_solves_total 1",
            "planner_candidates_evaluated_total 32",
            "planner_slab_calls_total 4",
            "planner_relocations_tried_total 3",
            "planner_relocations_accepted_total 1",
            "planner_swaps_tried_total 6",
            "planner_swaps_accepted_total 2",
            // 40 µs build span lands in the ≤ 50 µs bucket.
            "planner_phase_us_bucket{phase=\"build\",le=\"50\"} 1",
            "planner_phase_us_count{phase=\"total\"} 1",
            // Live model accuracy fed by POST /v2/observations.
            "model_observation_series 1",
            "model_samples_dropped_total 3",
            "model_samples_total{device=\"dev-1\",kernel=\"krn-1\"} 2",
            "model_mape{device=\"dev-1\",kernel=\"krn-1\"} 3.500",
            "model_error_ewma{device=\"dev-1\",kernel=\"krn-1\"} 12.250",
            "model_drift_state{device=\"dev-1\",kernel=\"krn-1\"} 1",
            // Structured event-log sink accounting.
            "service_event_log_enabled 1",
            "service_events_emitted_total 9",
            "service_events_dropped_total 1",
            // The /v2/jobs lifecycle routes are metered like any other.
            "service_requests_total{route=\"/v2/jobs\"} 0",
            "service_requests_total{route=\"/v2/jobs/{id}\"} 0",
            // Streaming scheduler lifecycle counters.
            "scheduler_jobs_submitted_total 5",
            "scheduler_jobs_admitted_total 4",
            "scheduler_jobs_rejected_total 1",
            "scheduler_jobs_completed_total 2",
            "scheduler_jobs_missed_total 1",
            "scheduler_jobs_cancelled_total 1",
            "scheduler_jobs_active 0",
            "scheduler_repairs_total 3",
            "scheduler_full_solves_total 2",
            "scheduler_repair_fallbacks_total 1",
            "scheduler_events_processed_total 11",
        ] {
            assert!(text.contains(needle), "missing `{needle}` in:\n{text}");
        }
        // The catch-all stays silent until it fires.
        assert!(!text.contains("route=\"other\""));
    }

    #[test]
    fn infinite_quantile_gauges_render_as_inf() {
        let m = Metrics::default();
        m.record(Route::Healthz, 200, Duration::from_secs(120));
        let text = m.render(
            &CacheStats::default(),
            Duration::from_secs(1),
            "native-scalar",
            &[],
            0,
            None,
            &SchedulerStats::default(),
        );
        assert!(
            text.contains("service_latency_us{route=\"/healthz\",stat=\"p50\"} +Inf"),
            "overflow quantile must render +Inf:\n{text}"
        );
        assert!(text.contains("service_latency_us_bucket{route=\"/healthz\",le=\"50000000\"} 0"));
        assert!(text.contains("service_latency_us_bucket{route=\"/healthz\",le=\"+Inf\"} 1"));
    }
}
