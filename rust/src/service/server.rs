//! The standing HTTP server (DESIGN.md §9, §12): a nonblocking
//! readiness-driven connection core plus a small executor pool that
//! holds a thread only while computing a response body — never while
//! waiting on a socket.
//!
//! ```text
//!   clients ──► accept (nonblocking) ──► connection table (poll loop)
//!                  │  admitted while live < workers + queue_capacity
//!                  └─► 429 + Retry-After beyond the admission credit
//!
//!   poll loop: readiness ──► per-conn read/parse ──► exec queue
//!                 ▲                                      │
//!                 └── waker ◄── Done{conn, resp} ◄── executor 0..W
//! ```
//!
//! **Sizing model:** connections are registered with the poll loop and
//! cost only their buffers while idle, so tens of thousands of
//! keep-alive connections never consume a thread each. `workers` sizes
//! the executor pool (concurrent request *bodies*), and
//! `workers + queue_capacity` is the live-connection admission credit —
//! the same shed threshold the old thread-per-connection pool enforced
//! ("workers serving + queue pending"), kept byte-compatible: past it,
//! new connections get `429 Too Many Requests` with `Retry-After` and
//! are closed. Shedding at admission costs microseconds and keeps the
//! tail latency of admitted work flat.
//!
//! **Connection state machine:** each registered connection owns a read
//! buffer, a write buffer, and an `executing` flag. Readiness drives
//! reads; complete requests dispatch to the executor queue (one in
//! flight per connection — pipelined requests are parsed from the
//! buffer as each response is delivered, preserving FIFO order);
//! responses are serialized into the write buffer and drained on
//! writability. Parse errors answer `400` and poison the connection.
//!
//! **Shutdown/drain:** `Service::shutdown` flips the flag and wakes the
//! poll loop. Idle connections close on the next tick; a connection
//! with a partial request gets [`DRAIN_POLLS`] ticks of grace; requests
//! in flight finish, are delivered with `Connection: close`, and the
//! connection closes once flushed. The poll thread exits when the table
//! is empty, then the executor queue closes and every thread joins.

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering::SeqCst};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context as _, Result};

use super::http::{self, HttpRequest, HttpResponse};
use super::json::Value;
use super::metrics::{Metrics, Route};
use super::routes::{self, ServiceState};
use crate::obs::{EventSink, Ring, Stage, TraceRecord, TraceRing, DEFAULT_TRACE_CAPACITY};
use crate::scheduler::{SchedulerConfig, SchedulerHandle};
use crate::util::fxhash::FxHashMap;

/// Tunables for [`Service::start`].
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Bind address; port 0 picks an ephemeral port.
    pub addr: String,
    /// Executor threads = concurrent request-body budget (connections
    /// themselves are free: the poll loop multiplexes them all).
    pub workers: usize,
    /// Admission credit beyond the executor pool: up to
    /// `workers + queue_capacity` connections are live at once; beyond
    /// that, new connections are shed with 429.
    pub queue_capacity: usize,
    /// `Retry-After` seconds advertised on shed responses.
    pub retry_after_secs: u32,
    /// Poll-loop tick: the granularity at which idle connections notice
    /// shutdown and timeouts (readiness events wake the loop sooner).
    pub poll_interval: Duration,
    /// Close connections idle longer than this.
    pub idle_timeout: Duration,
    /// A peer that stops reading cannot hold a half-written response
    /// (or hang the drain) past this bound without progress.
    pub write_timeout: Duration,
    /// Slow-trace retention threshold in microseconds (`--slow-us`):
    /// completed traces whose server-side total is below it are not
    /// retained for `GET /debug/traces`. 0 retains every trace.
    pub slow_us: f64,
    /// Capacity of the slow-trace ring (`--trace-capacity`). 0 disables
    /// trace retention and per-request cache/slab attribution entirely
    /// (the bench harness's untraced baseline); `X-Request-Id` echo and
    /// the per-stage `/metrics` histograms stay on either way.
    pub trace_capacity: usize,
    /// Capacity of the plan-provenance ring (`--plan-ring`): the last N
    /// `/v2/plan` solves retained for `GET /debug/plans`, telemetry and
    /// explanations included. 0 disables retention.
    pub plan_ring: usize,
    /// Opt-in structured event log (`--event-log PATH`): append JSONL
    /// records (request_span / solve / observation / drift_transition /
    /// job_transition) to this file via a bounded channel and a
    /// dedicated writer thread. `None` disables emission entirely.
    pub event_log: Option<std::path::PathBuf>,
    /// Streaming-scheduler re-plan epoch (`--replan-interval`): how
    /// often the rolling horizon re-solves the live job set in full.
    /// Between epochs, arrivals are placed by incremental repair.
    pub replan_interval: Duration,
    /// Streaming-scheduler planning horizon (`--horizon`): queued jobs
    /// whose deadline lies beyond it are left to a later epoch.
    pub horizon: Duration,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .clamp(2, 16),
            queue_capacity: 64,
            retry_after_secs: 1,
            poll_interval: Duration::from_millis(50),
            idle_timeout: Duration::from_secs(60),
            write_timeout: Duration::from_secs(10),
            slow_us: 0.0,
            trace_capacity: DEFAULT_TRACE_CAPACITY,
            plan_ring: routes::DEFAULT_PLAN_RING,
            event_log: None,
            replan_interval: Duration::from_secs(1),
            horizon: Duration::from_secs(30),
        }
    }
}

/// During drain, a connection holding a partial request gets this many
/// poll ticks to complete it before the loop closes it.
const DRAIN_POLLS: u32 = 4;

/// Per-connection read budget per readiness tick — keeps one firehose
/// peer from starving the rest of the table.
const READ_BUDGET_PER_TICK: usize = 64 * 1024;

/// Stop reading ahead once this much request data is buffered while a
/// request is executing (enough for one fully pipelined follow-up).
const PIPELINE_HIGH_WATER: usize = http::MAX_HEAD_BYTES + http::MAX_BODY_BYTES;

/// Readiness syscall shim. `std` exposes nonblocking sockets but no
/// readiness API, so on Unix this binds `poll(2)` directly (no mio /
/// tokio in the offline vendor set — the libc symbol is already linked
/// by `std` itself). Elsewhere a sleep-tick fallback reports every
/// registered socket as maybe-ready; the per-connection state machines
/// absorb spurious wakeups via `WouldBlock`, trading O(live) scans per
/// tick for portability.
#[cfg(unix)]
mod readiness {
    use std::io;
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    /// Mirrors `struct pollfd` (POSIX: int fd; short events, revents).
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    impl PollFd {
        pub fn new(fd: i32, events: i16) -> PollFd {
            PollFd { fd, events, revents: 0 }
        }
    }

    #[cfg(target_os = "macos")]
    type Nfds = u32;
    #[cfg(not(target_os = "macos"))]
    type Nfds = std::ffi::c_ulong;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: Nfds, timeout: i32) -> i32;
    }

    /// Block until readiness or `timeout_ms`; retries `EINTR`.
    pub fn wait(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
        loop {
            let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as Nfds, timeout_ms) };
            if rc >= 0 {
                return Ok(rc as usize);
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }

    pub fn stream_fd(s: &TcpStream) -> i32 {
        s.as_raw_fd()
    }

    pub fn listener_fd(l: &TcpListener) -> i32 {
        l.as_raw_fd()
    }
}

#[cfg(not(unix))]
mod readiness {
    use std::io;
    use std::net::{TcpListener, TcpStream};

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    impl PollFd {
        pub fn new(fd: i32, events: i16) -> PollFd {
            PollFd { fd, events, revents: 0 }
        }
    }

    /// Portable fallback: pace with a short sleep and echo every
    /// requested interest as ready (spurious wakeups resolve to
    /// `WouldBlock` in the state machines).
    pub fn wait(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
        std::thread::sleep(std::time::Duration::from_millis(timeout_ms.clamp(1, 2) as u64));
        for f in fds.iter_mut() {
            f.revents = f.events;
        }
        Ok(fds.len())
    }

    pub fn stream_fd(_s: &TcpStream) -> i32 {
        0
    }

    pub fn listener_fd(_l: &TcpListener) -> i32 {
        0
    }
}

/// One parsed request handed to the executor pool.
struct Work {
    conn: u64,
    route: Route,
    keep_alive: bool,
    req: HttpRequest,
    submitted: Instant,
    /// Span capture so far (DESIGN.md §13): the request id plus the
    /// accept and parse stage durations measured in the poll loop.
    spans: ReqSpans,
}

/// The poll-loop half of a request's span record.
struct ReqSpans {
    /// Echoed as `X-Request-Id` (client-supplied or `req-<n>`).
    id: String,
    /// Connection-ready (accept or previous response) → request fully
    /// buffered: mostly client/network time the server waited out.
    accept: Duration,
    /// HTTP head + body framing parse.
    parse: Duration,
}

/// A computed response on its way back to the poll loop.
struct Done {
    conn: u64,
    resp: HttpResponse,
    trace: PendingTrace,
}

/// Everything known about a request's trace before the render and
/// flush stages run in the poll loop, which completes and records it.
struct PendingTrace {
    id: String,
    route: Route,
    status: u16,
    accept: Duration,
    parse: Duration,
    queue: Duration,
    compute: Duration,
    cache_hits: u64,
    cache_misses: u64,
    slab_calls: u64,
}

struct ExecInner {
    deque: VecDeque<Work>,
    closed: bool,
}

/// The executor queue: parsed requests awaiting a worker thread. Depth
/// is naturally bounded by the admission credit (one request in flight
/// per live connection), and exported as the `service_queue_depth`
/// gauge.
struct ExecQueue {
    inner: Mutex<ExecInner>,
    ready: Condvar,
}

impl ExecQueue {
    fn new() -> Self {
        ExecQueue {
            inner: Mutex::new(ExecInner { deque: VecDeque::new(), closed: false }),
            ready: Condvar::new(),
        }
    }

    fn push(&self, w: Work, metrics: &Metrics) {
        let mut g = self.inner.lock().expect("exec queue poisoned");
        g.deque.push_back(w);
        metrics.queue_depth.store(g.deque.len(), SeqCst);
        drop(g);
        self.ready.notify_one();
    }

    /// Blocking pop; drains remaining items after close, then `None`.
    fn pop(&self, metrics: &Metrics) -> Option<Work> {
        let mut g = self.inner.lock().expect("exec queue poisoned");
        loop {
            if let Some(w) = g.deque.pop_front() {
                metrics.queue_depth.store(g.deque.len(), SeqCst);
                return Some(w);
            }
            if g.closed {
                return None;
            }
            g = self.ready.wait(g).expect("exec queue poisoned");
        }
    }

    fn close(&self) {
        self.inner.lock().expect("exec queue poisoned").closed = true;
        self.ready.notify_all();
    }
}

/// Wakes the poll loop from executor threads: a nonblocking loopback
/// socket pair (bind → connect → accept — `std` has no `pipe`); one
/// byte written to `tx` makes `rx` readable.
struct Waker {
    tx: TcpStream,
}

impl Waker {
    fn wake(&self) {
        // WouldBlock means wake bytes are already pending — good enough.
        let _ = (&self.tx).write(&[1u8]);
    }
}

fn wake_pair() -> Result<(TcpStream, TcpStream)> {
    let l = TcpListener::bind("127.0.0.1:0").context("binding waker listener")?;
    let addr = l.local_addr().context("resolving waker address")?;
    let tx = TcpStream::connect(addr).context("connecting waker")?;
    let (rx, _) = l.accept().context("accepting waker")?;
    tx.set_nonblocking(true).context("waker tx nonblocking")?;
    rx.set_nonblocking(true).context("waker rx nonblocking")?;
    let _ = tx.set_nodelay(true);
    Ok((tx, rx))
}

struct Shared {
    state: ServiceState,
    metrics: Arc<Metrics>,
    exec: ExecQueue,
    done: Mutex<Vec<Done>>,
    waker: Waker,
    shutdown: AtomicBool,
    cfg: ServiceConfig,
}

impl Shared {
    fn is_shutdown(&self) -> bool {
        self.shutdown.load(SeqCst)
    }
}

/// One registered connection's state machine.
struct Conn {
    stream: TcpStream,
    /// Bytes read but not yet parsed into a request.
    buf: Vec<u8>,
    /// Serialized responses awaiting writability.
    out: Vec<u8>,
    out_pos: usize,
    /// A request from this connection is in the executor.
    executing: bool,
    /// Close once `out` is fully flushed (Connection: close, 400, drain).
    close_after_flush: bool,
    /// The peer half-closed (EOF on read).
    peer_eof: bool,
    /// A parse error was answered; no further reads or dispatches.
    poisoned: bool,
    /// Fatal I/O error; close immediately.
    failed: bool,
    last_activity: Instant,
    /// Last time a pending write made progress (write-stall bound).
    last_write_progress: Instant,
    /// When this connection last became ready for a fresh request
    /// (accept, or the previous response's delivery) — the start of the
    /// next request's `accept` span.
    req_wait_start: Instant,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        let now = Instant::now();
        Conn {
            stream,
            buf: Vec::with_capacity(1024),
            out: Vec::new(),
            out_pos: 0,
            executing: false,
            close_after_flush: false,
            peer_eof: false,
            poisoned: false,
            failed: false,
            last_activity: now,
            last_write_progress: now,
            req_wait_start: now,
        }
    }

    fn flushed(&self) -> bool {
        self.out_pos >= self.out.len()
    }

    fn wants_read(&self) -> bool {
        !self.poisoned && !self.peer_eof && !self.failed && self.buf.len() < PIPELINE_HIGH_WATER
    }

    fn wants_write(&self) -> bool {
        !self.flushed()
    }
}

/// A running server. Dropping (or calling [`Service::shutdown`]) drains
/// and joins every thread.
pub struct Service {
    addr: SocketAddr,
    shared: Arc<Shared>,
    poll: Option<JoinHandle<()>>,
    sched: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Service {
    /// Bind, spawn the executor pool and the poll loop, start serving.
    pub fn start(mut state: ServiceState, cfg: ServiceConfig) -> Result<Service> {
        // The trace ring is sized by the server config, not the state
        // constructor: rebuild it here so `--trace-capacity 0` really
        // disables retention and `--slow-us` takes effect. Same for the
        // plan-provenance ring and the opt-in event-log sink.
        state.traces = Arc::new(TraceRing::new(cfg.trace_capacity, cfg.slow_us));
        state.plans = Arc::new(Ring::new(cfg.plan_ring));
        if let Some(path) = &cfg.event_log {
            let sink = EventSink::to_path(path)
                .with_context(|| format!("opening event log {}", path.display()))?;
            state.events = Some(Arc::new(sink));
        }
        state.scheduler = Arc::new(SchedulerHandle::new(SchedulerConfig {
            replan_interval_us: cfg.replan_interval.as_secs_f64() * 1e6,
            horizon_us: cfg.horizon.as_secs_f64() * 1e6,
            ..SchedulerConfig::default()
        }));
        let listener =
            TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
        listener.set_nonblocking(true).context("listener nonblocking")?;
        let addr = listener.local_addr().context("resolving bound address")?;
        let metrics = Arc::new(Metrics::default());
        metrics.queue_capacity.store(cfg.queue_capacity.max(1), SeqCst);
        let (wake_tx, wake_rx) = wake_pair()?;
        let shared = Arc::new(Shared {
            state,
            metrics,
            exec: ExecQueue::new(),
            done: Mutex::new(Vec::new()),
            waker: Waker { tx: wake_tx },
            shutdown: AtomicBool::new(false),
            cfg: cfg.clone(),
        });
        let mut workers = Vec::with_capacity(cfg.workers.max(1));
        for i in 0..cfg.workers.max(1) {
            let sh = Arc::clone(&shared);
            let handle = std::thread::Builder::new()
                .name(format!("svc-exec-{i}"))
                .spawn(move || exec_loop(sh))
                .context("spawning service executor")?;
            workers.push(handle);
        }
        let poll = {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("svc-poll".to_string())
                .spawn(move || poll_loop(sh, listener, wake_rx))
                .context("spawning service poll loop")?
        };
        // The scheduler ticker advances the streaming job lifecycle
        // between requests (predicted completions, deadline checks,
        // re-plan epochs) and drains the outbox into metrics and the
        // event log. An idle scheduler ticks in O(1) and emits nothing.
        let sched = {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("svc-sched".to_string())
                .spawn(move || sched_loop(sh))
                .context("spawning scheduler ticker")?
        };
        Ok(Service { addr, shared, poll: Some(poll), sched: Some(sched), workers })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Live counters (shared with the running threads).
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.shared.metrics)
    }

    /// Graceful drain: stop accepting, finish what's in flight (bounded
    /// by a few poll ticks), close every connection, join every thread.
    pub fn shutdown(mut self) {
        self.shutdown_impl();
    }

    fn shutdown_impl(&mut self) {
        if !self.shared.shutdown.swap(true, SeqCst) {
            self.shared.waker.wake();
        }
        // Join the poll loop first: it needs live executors to finish
        // in-flight requests during the drain.
        if let Some(h) = self.poll.take() {
            let _ = h.join();
        }
        self.shared.exec.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.sched.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

/// Executor thread: pop parsed requests, compute, hand the response
/// back to the poll loop. The thread is occupied only for the body of
/// `routes::handle` — socket waiting happens in the poll loop.
fn exec_loop(shared: Arc<Shared>) {
    while let Some(w) = shared.exec.pop(&shared.metrics) {
        let queue = w.submitted.elapsed();
        // Cache/slab attribution only when traces are retained: the
        // snapshots are a handful of atomic loads, but the untraced
        // baseline should not pay even those.
        let before = shared.state.traces.enabled().then(|| {
            (shared.state.engine.cache_stats(), shared.state.engine.compute_stats())
        });
        let compute_start = Instant::now();
        let mut resp =
            routes::handle_traced(&shared.state, &shared.metrics, &w.req, Some(&w.spans.id));
        let compute = compute_start.elapsed();
        shared.metrics.record(w.route, resp.status, w.submitted.elapsed());
        resp.close = resp.close || !w.keep_alive || shared.is_shutdown();
        let (cache_hits, cache_misses, slab_calls) = match before {
            Some((c0, k0)) => {
                let c1 = shared.state.engine.cache_stats();
                let k1 = shared.state.engine.compute_stats().since(k0);
                (
                    c1.hits.saturating_sub(c0.hits),
                    c1.misses.saturating_sub(c0.misses),
                    k1.slab_calls,
                )
            }
            None => (0, 0, 0),
        };
        let trace = PendingTrace {
            id: w.spans.id.clone(),
            route: w.route,
            status: resp.status,
            accept: w.spans.accept,
            parse: w.spans.parse,
            queue,
            compute,
            cache_hits,
            cache_misses,
            slab_calls,
        };
        let resp = resp.with_header("X-Request-Id", w.spans.id);
        shared.done.lock().expect("done list poisoned").push(Done { conn: w.conn, resp, trace });
        shared.waker.wake();
    }
}

/// Scheduler ticker thread: advance the streaming job lifecycle at the
/// poll cadence and surface whatever happened (transitions, epoch
/// solves) through the same drain path the `/v2/jobs` handlers use —
/// so a job that completes between polls still reaches the event log
/// with its `job_transition` trail.
fn sched_loop(shared: Arc<Shared>) {
    while !shared.is_shutdown() {
        shared.state.scheduler.tick(&shared.state.engine);
        routes::drain_scheduler(&shared.state, &shared.metrics, None);
        std::thread::sleep(shared.cfg.poll_interval);
    }
}

/// Admission-control rejection: 429 + `Retry-After`, written straight
/// from the poll loop (microseconds — the accepted stream is still in
/// blocking mode, and the write is bounded by `write_timeout`). The
/// response goes out before any request is read; shedding is a
/// connection-level decision (DESIGN.md §9).
fn shed(shared: &Shared, mut stream: TcpStream) {
    shared.metrics.shed_total.fetch_add(1, SeqCst);
    let body = Value::obj(vec![
        ("error", Value::str("server overloaded, retry later")),
        ("code", Value::str("overloaded")),
        ("queue_capacity", Value::num(shared.cfg.queue_capacity as f64)),
    ]);
    let resp = HttpResponse::json(429, body.render())
        .with_header("Retry-After", shared.cfg.retry_after_secs.to_string())
        .closing();
    let _ = stream.set_nodelay(true);
    let _ = stream.set_write_timeout(Some(shared.cfg.write_timeout));
    if http::write_response(&mut stream, &resp).is_ok() {
        // Close as cleanly as cheaply possible: scoop request bytes
        // that already arrived so the FIN is not turned into an RST
        // that could destroy the 429 in the peer's receive buffer.
        // Non-blocking — shedding happens exactly when the server is
        // overloaded, so the poll loop must not stall here (bytes that
        // race in after this instant just risk the rare RST).
        let _ = stream.shutdown(Shutdown::Write);
        let _ = stream.set_nonblocking(true);
        let mut scratch = [0u8; 1024];
        let _ = stream.read(&mut scratch);
    }
}

/// Drain the connection's write buffer as far as the socket allows.
/// Returns `false` on a fatal write error (`failed` is set).
fn flush_out(c: &mut Conn) -> bool {
    while c.out_pos < c.out.len() {
        match c.stream.write(&c.out[c.out_pos..]) {
            Ok(0) => {
                c.failed = true;
                return false;
            }
            Ok(n) => {
                c.out_pos += n;
                c.last_write_progress = Instant::now();
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                c.failed = true;
                return false;
            }
        }
    }
    if c.out_pos > 0 && c.flushed() {
        c.out.clear();
        c.out_pos = 0;
    }
    true
}

/// Parse the next buffered request and dispatch it to the executors
/// (at most one in flight per connection — pipelining re-enters here on
/// delivery, preserving FIFO response order).
fn try_dispatch(shared: &Shared, c: &mut Conn, id: u64) {
    if c.executing || c.poisoned || c.close_after_flush || c.failed {
        return;
    }
    let parse_start = Instant::now();
    match http::try_parse(&c.buf) {
        Ok(Some((req, consumed))) => {
            let parse = parse_start.elapsed();
            // Everything since the connection was last ready for a
            // request is accept/read wait (saturates to zero).
            let accept = parse_start.duration_since(c.req_wait_start);
            c.buf.drain(..consumed);
            c.last_activity = Instant::now();
            c.executing = true;
            let id_str = request_id(&shared.state.traces, &req);
            shared.exec.push(
                Work {
                    conn: id,
                    route: Route::of_path(&req.path),
                    keep_alive: req.keep_alive(),
                    req,
                    submitted: Instant::now(),
                    spans: ReqSpans { id: id_str, accept, parse },
                },
                &shared.metrics,
            );
        }
        Ok(None) => {}
        Err(e) => {
            let body = Value::obj(vec![
                ("error", Value::str(e.message)),
                ("code", Value::str("bad_http")),
            ])
            .render();
            shared.metrics.record(Route::Other, 400, Duration::ZERO);
            let resp = HttpResponse::json(400, body).closing();
            http::encode_response_into(&resp, &mut c.out);
            c.poisoned = true;
            c.close_after_flush = true;
            c.last_write_progress = Instant::now();
            let _ = flush_out(c);
        }
    }
}

/// The request id echoed in `X-Request-Id`: the client's own header
/// when it is a sane token (so distributed traces correlate), else a
/// server-minted `req-<n>`.
fn request_id(ring: &TraceRing, req: &HttpRequest) -> String {
    match req.header("x-request-id") {
        Some(v) if !v.is_empty() && v.len() <= 64 && v.bytes().all(|b| b.is_ascii_graphic()) => {
            v.to_string()
        }
        _ => format!("req-{}", ring.next_request_id()),
    }
}

/// Complete a request's trace with the render and flush stages: feed
/// the per-stage `/metrics` histograms (always) and the slow-trace
/// ring (when retention is enabled and the total clears `--slow-us`).
fn finish_trace(shared: &Shared, t: PendingTrace, render: Duration, flush: Duration) {
    let m = &shared.metrics;
    m.record_stage(Stage::Accept, t.accept);
    m.record_stage(Stage::Parse, t.parse);
    m.record_stage(Stage::Queue, t.queue);
    m.record_stage(Stage::Compute, t.compute);
    m.record_stage(Stage::Render, render);
    m.record_stage(Stage::Flush, flush);
    let us = |d: Duration| d.as_secs_f64() * 1e6;
    let mut stages_us = [0.0; Stage::COUNT];
    stages_us[Stage::Accept.index()] = us(t.accept);
    stages_us[Stage::Parse.index()] = us(t.parse);
    stages_us[Stage::Queue.index()] = us(t.queue);
    stages_us[Stage::Compute.index()] = us(t.compute);
    stages_us[Stage::Render.index()] = us(render);
    stages_us[Stage::Flush.index()] = us(flush);
    // The event log sees every request regardless of trace retention:
    // the ring answers "what was slow lately", the log is the durable
    // correlation record (request_id joins it to solve/observation
    // events emitted by the handlers).
    if let Some(sink) = &shared.state.events {
        let total: f64 = stages_us.iter().sum();
        sink.emit(
            Value::obj(vec![
                ("event", Value::str("request_span")),
                ("request_id", Value::str(t.id.clone())),
                ("route", Value::str(t.route.name())),
                ("status", Value::num(f64::from(t.status))),
                ("total_us", Value::num(total)),
                (
                    "stages_us",
                    Value::obj(
                        Stage::ALL
                            .iter()
                            .map(|s| (s.name(), Value::num(stages_us[s.index()])))
                            .collect(),
                    ),
                ),
            ])
            .render(),
        );
    }
    if !shared.state.traces.enabled() {
        return;
    }
    shared.state.traces.record(TraceRecord {
        id: t.id,
        route: t.route.name(),
        status: t.status,
        stages_us,
        cache_hits: t.cache_hits,
        cache_misses: t.cache_misses,
        slab_calls: t.slab_calls,
    });
}

/// Apply one computed response: buffer it, flush opportunistically,
/// complete the trace, and chain the next pipelined request if one is
/// already buffered.
fn deliver(shared: &Shared, c: &mut Conn, id: u64, mut resp: HttpResponse, trace: PendingTrace) {
    c.executing = false;
    if shared.is_shutdown() {
        resp.close = true;
    }
    if resp.close {
        c.close_after_flush = true;
    }
    let render_start = Instant::now();
    http::encode_response_into(&resp, &mut c.out);
    let render = render_start.elapsed();
    c.last_activity = Instant::now();
    c.last_write_progress = Instant::now();
    let flush_start = Instant::now();
    let flush_ok = flush_out(c);
    // Charged flush time is the synchronous drain only; a slow
    // consumer's residual bytes trickle out on later poll ticks and are
    // not attributed (DESIGN.md §13).
    finish_trace(shared, trace, render, flush_start.elapsed());
    c.req_wait_start = Instant::now();
    if !flush_ok {
        return;
    }
    try_dispatch(shared, c, id);
}

/// Read as much as this tick's budget allows, then try to dispatch.
fn handle_read(shared: &Shared, c: &mut Conn, id: u64) {
    let mut chunk = [0u8; 16 * 1024];
    let mut taken = 0usize;
    while taken < READ_BUDGET_PER_TICK && c.wants_read() {
        match c.stream.read(&mut chunk) {
            Ok(0) => {
                c.peer_eof = true;
                break;
            }
            Ok(n) => {
                c.buf.extend_from_slice(&chunk[..n]);
                c.last_activity = Instant::now();
                taken += n;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                c.failed = true;
                return;
            }
        }
    }
    try_dispatch(shared, c, id);
}

/// Whether the connection has nothing left to do and should be dropped.
fn should_close(c: &Conn) -> bool {
    if c.failed {
        return true;
    }
    if c.executing {
        return false;
    }
    if c.close_after_flush && c.flushed() {
        return true;
    }
    // Half-closed peer: once the response pipeline is empty there is
    // nothing left to deliver (a partial request can never complete).
    c.peer_eof && c.flushed()
}

/// The readiness loop: owns the listener, the waker receive side and
/// every registered connection.
fn poll_loop(shared: Arc<Shared>, listener: TcpListener, wake_rx: TcpStream) {
    use readiness::{PollFd, POLLERR, POLLHUP, POLLIN, POLLNVAL, POLLOUT};

    let admission_credit = shared.cfg.workers.max(1) + shared.cfg.queue_capacity.max(1);
    let timeout_ms = shared.cfg.poll_interval.as_millis().clamp(1, 1_000) as i32;
    let mut conns: FxHashMap<u64, Conn> = FxHashMap::default();
    let mut next_id: u64 = 0;
    let mut fds: Vec<PollFd> = Vec::new();
    let mut ids: Vec<u64> = Vec::new();
    let mut draining = false;
    let mut drain_ticks: u32 = 0;

    loop {
        // Apply responses computed since the last tick.
        let done: Vec<Done> = {
            let mut g = shared.done.lock().expect("done list poisoned");
            std::mem::take(&mut *g)
        };
        for d in done {
            if let Some(c) = conns.get_mut(&d.conn) {
                deliver(&shared, c, d.conn, d.resp, d.trace);
            }
        }

        if shared.is_shutdown() && !draining {
            draining = true;
            drain_ticks = 0;
        }
        if draining && conns.is_empty() {
            return;
        }

        // Interest set: waker, listener, then every connection.
        fds.clear();
        ids.clear();
        fds.push(PollFd::new(readiness::stream_fd(&wake_rx), POLLIN));
        fds.push(PollFd::new(
            readiness::listener_fd(&listener),
            if draining { 0 } else { POLLIN },
        ));
        for (&id, c) in conns.iter() {
            let mut events = 0i16;
            if c.wants_read() {
                events |= POLLIN;
            }
            if c.wants_write() {
                events |= POLLOUT;
            }
            fds.push(PollFd::new(readiness::stream_fd(&c.stream), events));
            ids.push(id);
        }

        if readiness::wait(&mut fds, timeout_ms).is_err() {
            // A failed readiness syscall is unrecoverable; drop
            // everything rather than spin.
            return;
        }

        // Waker: drain the pending wake bytes.
        if fds[0].revents != 0 {
            let mut scratch = [0u8; 64];
            while let Ok(n) = (&wake_rx).read(&mut scratch) {
                if n == 0 {
                    break;
                }
            }
        }

        // Listener: accept everything pending; admit or shed.
        if !draining && fds[1].revents != 0 {
            loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        shared.metrics.connections_total.fetch_add(1, SeqCst);
                        if conns.len() >= admission_credit {
                            shed(&shared, stream);
                            continue;
                        }
                        let _ = stream.set_nodelay(true);
                        if stream.set_nonblocking(true).is_err() {
                            continue;
                        }
                        let id = next_id;
                        next_id += 1;
                        conns.insert(id, Conn::new(stream));
                        // A request may already be readable; the next
                        // tick's POLLIN picks it up.
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => break, // transient (EMFILE, aborted handshake)
                }
            }
        } else if draining && fds[1].revents != 0 {
            // Late connections during drain are accepted and dropped so
            // the backlog does not hold half-open sockets.
            while let Ok((s, _)) = listener.accept() {
                drop(s);
            }
        }

        // Connection readiness.
        for (i, &id) in ids.iter().enumerate() {
            let r = fds[i + 2].revents;
            if r == 0 {
                continue;
            }
            let Some(c) = conns.get_mut(&id) else { continue };
            if r & (POLLIN | POLLERR | POLLHUP | POLLNVAL) != 0 && c.wants_read() {
                handle_read(&shared, c, id);
            }
            if r & (POLLOUT | POLLERR | POLLHUP) != 0 && c.wants_write() {
                let _ = flush_out(c);
            }
        }

        // Maintenance: closes, timeouts, drain bookkeeping.
        if draining {
            drain_ticks = drain_ticks.saturating_add(1);
        }
        let now = Instant::now();
        let mut to_close: Vec<u64> = Vec::new();
        for (&id, c) in conns.iter() {
            if should_close(c) {
                to_close.push(id);
                continue;
            }
            if !c.flushed()
                && now.duration_since(c.last_write_progress) >= shared.cfg.write_timeout
            {
                to_close.push(id); // write stalled: peer stopped reading
                continue;
            }
            if draining {
                if c.executing || !c.flushed() {
                    // In flight: the delivered response closes it.
                } else if c.buf.is_empty() || drain_ticks >= DRAIN_POLLS {
                    // Idle connections close on the first drain tick; a
                    // partial request gets DRAIN_POLLS of grace.
                    to_close.push(id);
                }
            } else if !c.executing
                && now.duration_since(c.last_activity) >= shared.cfg.idle_timeout
            {
                to_close.push(id);
            }
        }
        for id in to_close {
            conns.remove(&id); // drop closes the socket (FIN)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dvfs::PowerModel;
    use crate::engine::Engine;
    use crate::model::{HwParams, KernelCounters};
    use crate::service::client::Client;

    fn test_counters() -> KernelCounters {
        KernelCounters {
            l2_hr: 0.1,
            gld_trans: 6.0,
            avr_inst: 1.5,
            n_blocks: 128.0,
            wpb: 8.0,
            aw: 64.0,
            n_sm: 16.0,
            o_itrs: 8.0,
            i_itrs: 0.0,
            uses_smem: false,
            smem_conflict: 1.0,
            gld_body: 6.0,
            gld_edge: 0.0,
            mem_ops: 2.0,
            l1_hr: 0.0,
        }
    }

    fn test_state() -> ServiceState {
        let hw = HwParams::paper_defaults();
        let mut s = ServiceState::new(
            Engine::native(hw),
            PowerModel::gtx980(),
            crate::microbench::standard_grid(),
        );
        s.register_kernel("VA", test_counters());
        s
    }

    fn fast_cfg(workers: usize, queue_capacity: usize) -> ServiceConfig {
        ServiceConfig {
            workers,
            queue_capacity,
            poll_interval: Duration::from_millis(10),
            ..ServiceConfig::default()
        }
    }

    #[test]
    fn keep_alive_round_trips_on_one_connection() {
        let svc = Service::start(test_state(), fast_cfg(2, 8)).unwrap();
        let mut c = Client::connect(&svc.addr()).unwrap();
        for _ in 0..3 {
            let r = c.get("/healthz").unwrap();
            assert_eq!(r.status, 200);
            assert!(r.body.contains("\"ok\""));
        }
        let r = c
            .post("/v1/predict", r#"{"kernel":"VA","core_mhz":700,"mem_mhz":700}"#)
            .unwrap();
        assert_eq!(r.status, 200);
        let m = svc.metrics();
        assert_eq!(m.route(Route::Healthz).requests.load(SeqCst), 3);
        assert_eq!(m.route(Route::Predict).requests.load(SeqCst), 1);
        drop(c);
        svc.shutdown();
    }

    #[test]
    fn concurrent_clients_each_get_correct_answers() {
        let svc = Service::start(test_state(), fast_cfg(4, 16)).unwrap();
        let addr = svc.addr();
        std::thread::scope(|scope| {
            for t in 0..4u32 {
                scope.spawn(move || {
                    let mut c = Client::connect(&addr).unwrap();
                    for i in 0..10 {
                        let cf = 400 + 100 * ((t as usize + i) % 7);
                        let body =
                            format!(r#"{{"kernel":"VA","core_mhz":{cf},"mem_mhz":700}}"#);
                        let r = c.post("/v1/predict", &body).unwrap();
                        assert_eq!(r.status, 200);
                        let v = r.json().unwrap();
                        assert_eq!(
                            v.get("core_mhz").and_then(Value::as_f64),
                            Some(cf as f64)
                        );
                        assert!(v.get("time_us").and_then(Value::as_f64).unwrap() > 0.0);
                    }
                });
            }
        });
        let m = svc.metrics();
        assert_eq!(m.route(Route::Predict).requests.load(SeqCst), 40);
        svc.shutdown();
    }

    #[test]
    fn overload_sheds_with_429_and_retry_after() {
        // workers + queue_capacity = 3 is the admission credit: one
        // active connection plus two idle ones exhaust it; the next
        // connection is shed at accept.
        let svc = Service::start(test_state(), fast_cfg(1, 2)).unwrap();
        let addr = svc.addr();
        let mut holder = Client::connect(&addr).unwrap();
        assert_eq!(holder.get("/healthz").unwrap().status, 200);
        // These two occupy the remaining admission credit.
        let _queued_a = Client::connect(&addr).unwrap();
        let _queued_b = Client::connect(&addr).unwrap();
        // Give the poll loop a moment to register both.
        std::thread::sleep(Duration::from_millis(100));
        let mut shed = Client::connect(&addr).unwrap();
        shed.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let r = shed.read_response().unwrap();
        assert_eq!(r.status, 429);
        assert_eq!(r.header("retry-after"), Some("1"));
        assert!(r.body.contains("overloaded"));
        assert!(svc.metrics().shed_total.load(SeqCst) >= 1);
        // Admitted connections keep working while the credit is full.
        assert_eq!(holder.get("/healthz").unwrap().status, 200);
        drop(holder);
        svc.shutdown();
    }

    #[test]
    fn many_keepalive_connections_exceed_the_worker_count() {
        // The whole point of the readiness core: 48 live keep-alive
        // connections served by 2 executor threads (the old model would
        // have parked 46 of them waiting for a worker).
        let svc = Service::start(test_state(), fast_cfg(2, 256)).unwrap();
        let addr = svc.addr();
        let mut clients: Vec<Client> =
            (0..48).map(|_| Client::connect(&addr).unwrap()).collect();
        for round in 0..2 {
            for c in clients.iter_mut() {
                let r = c.get("/healthz").unwrap();
                assert_eq!(r.status, 200, "round {round}");
            }
        }
        let m = svc.metrics();
        assert_eq!(m.route(Route::Healthz).requests.load(SeqCst), 96);
        assert_eq!(m.connections_total.load(SeqCst), 48);
        svc.shutdown();
    }

    #[test]
    fn pipelined_requests_are_answered_in_order() {
        use std::io::Write as _;
        let svc = Service::start(test_state(), fast_cfg(2, 8)).unwrap();
        let mut raw = TcpStream::connect(svc.addr()).unwrap();
        raw.write_all(
            b"GET /healthz HTTP/1.1\r\n\r\nGET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n",
        )
        .unwrap();
        let mut out = Vec::new();
        raw.read_to_end(&mut out).unwrap(); // server closes after the 2nd
        let text = String::from_utf8_lossy(&out);
        let first = text.find("HTTP/1.1 200").expect("first response");
        let second = text[first + 1..].find("HTTP/1.1 200").expect("second response");
        let metrics_body = &text[first + 1 + second..];
        assert!(text.contains("\"ok\""), "{text}");
        // The second response is /metrics and already counts the first.
        assert!(metrics_body.contains("service_requests_total"), "{text}");
        svc.shutdown();
    }

    #[test]
    fn shutdown_drains_and_joins() {
        let svc = Service::start(test_state(), fast_cfg(2, 8)).unwrap();
        let addr = svc.addr();
        let mut c = Client::connect(&addr).unwrap();
        assert_eq!(c.get("/healthz").unwrap().status, 200);
        let t0 = Instant::now();
        svc.shutdown(); // idle connection: closed within a poll tick
        assert!(t0.elapsed() < Duration::from_secs(5), "drain took {:?}", t0.elapsed());
        // The poll loop closed the kept-alive connection during drain
        // (asserting on the held connection, not the port — the
        // ephemeral port may be reassigned to a parallel test).
        let _ = c.set_read_timeout(Some(Duration::from_secs(5)));
        assert!(c.get("/healthz").is_err(), "connection must be closed after drain");
    }

    #[test]
    fn responses_echo_request_ids_and_retain_traces() {
        use std::io::Write as _;
        let cfg = ServiceConfig { slow_us: 0.0, trace_capacity: 8, ..fast_cfg(2, 8) };
        let svc = Service::start(test_state(), cfg).unwrap();
        let mut c = Client::connect(&svc.addr()).unwrap();
        // Server-minted ids are monotone `req-<n>` tokens.
        let r = c.get("/healthz").unwrap();
        let id = r.header("x-request-id").expect("id header").to_string();
        assert!(id.starts_with("req-"), "id {id}");
        let r2 = c.get("/healthz").unwrap();
        assert_ne!(r2.header("x-request-id"), Some(id.as_str()));
        // A sane client-supplied id is echoed verbatim.
        let mut raw = TcpStream::connect(svc.addr()).unwrap();
        raw.write_all(
            b"GET /healthz HTTP/1.1\r\nX-Request-Id: trace-abc123\r\nConnection: close\r\n\r\n",
        )
        .unwrap();
        let mut out = Vec::new();
        raw.read_to_end(&mut out).unwrap();
        let text = String::from_utf8_lossy(&out);
        assert!(text.contains("X-Request-Id: trace-abc123"), "{text}");
        // All three requests were retained (slow_us 0 keeps everything)
        // with per-stage breakdowns.
        let got = svc.shared.state.traces.snapshot();
        assert_eq!(got.len(), 3);
        assert_eq!(got[0].id, "trace-abc123"); // newest first
        assert!(got.iter().all(|t| t.route == "/healthz" && t.status == 200));
        assert!(got.iter().all(|t| t.total_us() > 0.0));
        // Stage histograms saw every request across all six stages.
        let m = svc.metrics();
        for s in Stage::ALL {
            assert_eq!(m.stage(s).count(), 3, "stage {}", s.name());
        }
        drop(c);
        svc.shutdown();
    }

    #[test]
    fn slow_us_threshold_and_capacity_zero_disable_retention() {
        // High threshold: /healthz traces (microseconds) never qualify.
        let cfg = ServiceConfig { slow_us: 5e6, trace_capacity: 8, ..fast_cfg(1, 4) };
        let svc = Service::start(test_state(), cfg).unwrap();
        let mut c = Client::connect(&svc.addr()).unwrap();
        assert_eq!(c.get("/healthz").unwrap().status, 200);
        assert!(svc.shared.state.traces.snapshot().is_empty());
        drop(c);
        svc.shutdown();

        // Capacity 0: retention fully off, the id echo stays.
        let cfg = ServiceConfig { trace_capacity: 0, ..fast_cfg(1, 4) };
        let svc = Service::start(test_state(), cfg).unwrap();
        let mut c = Client::connect(&svc.addr()).unwrap();
        let r = c.get("/healthz").unwrap();
        assert!(r.header("x-request-id").is_some());
        assert!(!svc.shared.state.traces.enabled());
        assert!(svc.shared.state.traces.snapshot().is_empty());
        drop(c);
        svc.shutdown();
    }

    #[test]
    fn event_log_and_plan_ring_are_wired_through_the_config() {
        let mut path = std::env::temp_dir();
        path.push(format!("gpufreq-server-events-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let cfg = ServiceConfig {
            plan_ring: 2,
            event_log: Some(path.clone()),
            ..fast_cfg(1, 4)
        };
        let svc = Service::start(test_state(), cfg).unwrap();
        assert_eq!(svc.shared.state.plans.capacity(), 2);
        let mut c = Client::connect(&svc.addr()).unwrap();
        assert_eq!(c.get("/healthz").unwrap().status, 200);
        let r = c.post("/v2/plan", r#"{"jobs":[{"kernel":"VA"}]}"#).unwrap();
        assert_eq!(r.status, 200, "{}", r.body);
        let plan_rid = r.header("x-request-id").expect("id header").to_string();
        assert_eq!(svc.shared.state.plans.snapshot().len(), 1);
        drop(c);
        svc.shutdown(); // drops the sink: flush + writer join
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<Value> = text.lines().map(|l| Value::parse(l).unwrap()).collect();
        // One solve event plus a request_span per request, in emission
        // order (the solve precedes its own span — it is emitted from
        // the handler, the span at delivery).
        let events: Vec<&str> =
            lines.iter().map(|l| l.get("event").and_then(Value::as_str).unwrap()).collect();
        assert_eq!(events, ["request_span", "solve", "request_span"], "{text}");
        assert_eq!(lines[1].get("request_id").and_then(Value::as_str), Some(plan_rid.as_str()));
        assert_eq!(lines[2].get("request_id").and_then(Value::as_str), Some(plan_rid.as_str()));
        assert_eq!(lines[2].get("route").and_then(Value::as_str), Some("/v2/plan"));
        assert_eq!(lines[2].get("status").and_then(Value::as_f64), Some(200.0));
        assert!(lines[2].get("total_us").and_then(Value::as_f64).unwrap() > 0.0);
        let stages = lines[2].get("stages_us").expect("stage breakdown");
        for s in Stage::ALL {
            assert!(stages.get(s.name()).and_then(Value::as_f64).is_some(), "{}", s.name());
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn scheduler_ticker_completes_jobs_between_requests() {
        let mut path = std::env::temp_dir();
        path.push(format!("gpufreq-server-sched-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let cfg = ServiceConfig { event_log: Some(path.clone()), ..fast_cfg(1, 4) };
        let svc = Service::start(test_state(), cfg).unwrap();
        let mut c = Client::connect(&svc.addr()).unwrap();
        let r = c.post("/v2/jobs", r#"{"kernel":"VA","name":"quick","scale":0.001}"#).unwrap();
        assert_eq!(r.status, 202, "{}", r.body);
        // The predicted completion is microseconds away; the ticker
        // thread observes it between requests.
        std::thread::sleep(Duration::from_millis(300));
        let r = c.get("/v2/jobs/job-1").unwrap();
        assert_eq!(r.status, 200);
        let v = r.json().unwrap();
        assert_eq!(v.get("state").and_then(Value::as_str), Some("done"), "{}", r.body);
        drop(c);
        svc.shutdown();
        let text = std::fs::read_to_string(&path).unwrap();
        // The completion transition reached the log, drained outside
        // any request (so it carries no request id).
        let done = text
            .lines()
            .map(|l| Value::parse(l).unwrap())
            .find(|l| {
                l.get("event").and_then(Value::as_str) == Some("job_transition")
                    && l.get("to").and_then(Value::as_str) == Some("done")
            })
            .unwrap_or_else(|| panic!("no done transition in {text}"));
        assert!(done.get("request_id").is_none(), "{text}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn malformed_requests_get_400_and_close() {
        use std::io::Write as _;
        let svc = Service::start(test_state(), fast_cfg(1, 4)).unwrap();
        let mut raw = TcpStream::connect(svc.addr()).unwrap();
        raw.write_all(b"NONSENSE\r\n\r\n").unwrap();
        let mut out = Vec::new();
        raw.read_to_end(&mut out).unwrap(); // server closes after 400
        let text = String::from_utf8_lossy(&out);
        assert!(text.starts_with("HTTP/1.1 400"), "{text}");
        svc.shutdown();
    }
}
