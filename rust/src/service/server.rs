//! The standing HTTP server (DESIGN.md §9): `TcpListener` acceptor,
//! bounded pending-connection queue with load shedding, and a fixed
//! worker pool that owns connections keep-alive style.
//!
//! ```text
//!   clients ──► acceptor ──► bounded queue ──► worker 0..W
//!                  │   (capacity = high-water)     │
//!                  └─► 429 + Retry-After when full └─► routes::handle
//! ```
//!
//! **Sizing model:** a worker serves one connection at a time (blocking
//! I/O — no epoll in `std`), so `workers` is the concurrent-connection
//! budget and the queue absorbs bursts. Past the high-water mark the
//! acceptor answers `429 Too Many Requests` with `Retry-After` and
//! closes — shedding at admission costs microseconds and keeps the
//! tail latency of admitted work flat (the alternative, unbounded
//! queueing, melts p999 first).
//!
//! **Shutdown/drain:** `Service::shutdown` flips the flag, wakes the
//! acceptor with a self-connect, closes the queue, then joins. Workers
//! finish the request in flight, serve anything already buffered on
//! their connection (bounded by a few poll intervals), and close with
//! `Connection: close`; queued-but-unserved connections get the same
//! bounded drain when popped.

use std::collections::VecDeque;
use std::io::Read;
use std::net::{IpAddr, Ipv4Addr, Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering::SeqCst};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context as _, Result};

use super::http::{self, HttpResponse};
use super::json::Value;
use super::metrics::{Metrics, Route};
use super::routes::{self, ServiceState};

/// Tunables for [`Service::start`].
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Bind address; port 0 picks an ephemeral port.
    pub addr: String,
    /// Worker threads = concurrent-connection budget.
    pub workers: usize,
    /// Pending-connection high-water mark; beyond it, 429.
    pub queue_capacity: usize,
    /// `Retry-After` seconds advertised on shed responses.
    pub retry_after_secs: u32,
    /// Worker read-poll interval: the granularity at which idle
    /// connections notice the shutdown flag.
    pub poll_interval: Duration,
    /// Close connections idle longer than this (frees the worker).
    pub idle_timeout: Duration,
    /// Per-syscall write timeout: a client that stops reading cannot
    /// pin a worker (or hang the drain) past this bound per write.
    pub write_timeout: Duration,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .clamp(2, 16),
            queue_capacity: 64,
            retry_after_secs: 1,
            poll_interval: Duration::from_millis(50),
            idle_timeout: Duration::from_secs(60),
            write_timeout: Duration::from_secs(10),
        }
    }
}

/// During drain, a connection gets this many poll intervals to finish
/// delivering an in-flight request before the worker closes it.
const DRAIN_POLLS: u32 = 4;

struct QueueInner {
    deque: VecDeque<TcpStream>,
    closed: bool,
}

/// Bounded MPMC connection queue: non-blocking producer (the acceptor
/// sheds instead of waiting), condvar-blocking consumers (workers).
struct ConnQueue {
    inner: Mutex<QueueInner>,
    ready: Condvar,
    capacity: usize,
}

impl ConnQueue {
    fn new(capacity: usize) -> Self {
        ConnQueue {
            inner: Mutex::new(QueueInner { deque: VecDeque::new(), closed: false }),
            ready: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Hand back the stream when the queue is at the high-water mark
    /// (or closed) so the caller can shed it.
    fn try_push(&self, s: TcpStream, metrics: &Metrics) -> std::result::Result<(), TcpStream> {
        let mut g = self.inner.lock().expect("queue poisoned");
        if g.closed || g.deque.len() >= self.capacity {
            return Err(s);
        }
        g.deque.push_back(s);
        metrics.queue_depth.store(g.deque.len(), SeqCst);
        drop(g);
        self.ready.notify_one();
        Ok(())
    }

    /// Blocking pop; drains remaining items after close, then `None`.
    fn pop(&self, metrics: &Metrics) -> Option<TcpStream> {
        let mut g = self.inner.lock().expect("queue poisoned");
        loop {
            if let Some(s) = g.deque.pop_front() {
                metrics.queue_depth.store(g.deque.len(), SeqCst);
                return Some(s);
            }
            if g.closed {
                return None;
            }
            g = self.ready.wait(g).expect("queue poisoned");
        }
    }

    fn close(&self) {
        self.inner.lock().expect("queue poisoned").closed = true;
        self.ready.notify_all();
    }
}

struct Shared {
    state: ServiceState,
    metrics: Arc<Metrics>,
    queue: ConnQueue,
    shutdown: AtomicBool,
    cfg: ServiceConfig,
}

impl Shared {
    fn is_shutdown(&self) -> bool {
        self.shutdown.load(SeqCst)
    }
}

/// A running server. Dropping (or calling [`Service::shutdown`]) drains
/// and joins every thread.
pub struct Service {
    addr: SocketAddr,
    shared: Arc<Shared>,
    acceptor: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Service {
    /// Bind, spawn the pool and start accepting.
    pub fn start(state: ServiceState, cfg: ServiceConfig) -> Result<Service> {
        let listener =
            TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr().context("resolving bound address")?;
        let metrics = Arc::new(Metrics::default());
        metrics.queue_capacity.store(cfg.queue_capacity.max(1), SeqCst);
        let shared = Arc::new(Shared {
            state,
            metrics,
            queue: ConnQueue::new(cfg.queue_capacity),
            shutdown: AtomicBool::new(false),
            cfg: cfg.clone(),
        });
        let mut workers = Vec::with_capacity(cfg.workers.max(1));
        for i in 0..cfg.workers.max(1) {
            let sh = Arc::clone(&shared);
            let handle = std::thread::Builder::new()
                .name(format!("svc-worker-{i}"))
                .spawn(move || worker_loop(sh))
                .context("spawning service worker")?;
            workers.push(handle);
        }
        let acceptor = {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("svc-acceptor".to_string())
                .spawn(move || acceptor_loop(sh, listener))
                .context("spawning service acceptor")?
        };
        Ok(Service { addr, shared, acceptor: Some(acceptor), workers })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Live counters (shared with the running threads).
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.shared.metrics)
    }

    /// Graceful drain: stop accepting, serve what's in flight (bounded
    /// by a few poll intervals per connection), join every thread.
    pub fn shutdown(mut self) {
        self.shutdown_impl();
    }

    fn shutdown_impl(&mut self) {
        if !self.shared.shutdown.swap(true, SeqCst) {
            // Wake the blocking accept. Bound-to-any addresses are not
            // connectable on every platform; aim at loopback instead.
            let mut wake = self.addr;
            if wake.ip().is_unspecified() {
                wake.set_ip(IpAddr::V4(Ipv4Addr::LOCALHOST));
            }
            let _ = TcpStream::connect_timeout(&wake, Duration::from_millis(500));
        }
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        // The acceptor closes the queue on exit; repeat in case it
        // died early, so workers cannot block forever.
        self.shared.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

fn acceptor_loop(shared: Arc<Shared>, listener: TcpListener) {
    for conn in listener.incoming() {
        if shared.is_shutdown() {
            break; // the wake connection (or a late client) is dropped
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => continue,
        };
        shared.metrics.connections_total.fetch_add(1, SeqCst);
        if let Err(rejected) = shared.queue.try_push(stream, &shared.metrics) {
            shed(&shared, rejected);
        }
    }
    shared.queue.close();
}

/// Admission-control rejection: 429 + `Retry-After`, written straight
/// from the acceptor (microseconds — no worker time spent). The
/// response goes out before any request is read; shedding is a
/// connection-level decision (DESIGN.md §9).
fn shed(shared: &Shared, mut stream: TcpStream) {
    shared.metrics.shed_total.fetch_add(1, SeqCst);
    let body = Value::obj(vec![
        ("error", Value::str("server overloaded, retry later")),
        ("code", Value::str("overloaded")),
        ("queue_capacity", Value::num(shared.cfg.queue_capacity as f64)),
    ]);
    let resp = HttpResponse::json(429, body.render())
        .with_header("Retry-After", shared.cfg.retry_after_secs.to_string())
        .closing();
    let _ = stream.set_nodelay(true);
    let _ = stream.set_write_timeout(Some(shared.cfg.write_timeout));
    if http::write_response(&mut stream, &resp).is_ok() {
        // Close as cleanly as cheaply possible: scoop request bytes
        // that already arrived so the FIN is not turned into an RST
        // that could destroy the 429 in the peer's receive buffer.
        // Non-blocking — shedding happens exactly when the server is
        // overloaded, so the acceptor must not stall here (bytes that
        // race in after this instant just risk the rare RST).
        let _ = stream.shutdown(Shutdown::Write);
        let _ = stream.set_nonblocking(true);
        let mut scratch = [0u8; 1024];
        let _ = stream.read(&mut scratch);
    }
}

fn worker_loop(shared: Arc<Shared>) {
    while let Some(stream) = shared.queue.pop(&shared.metrics) {
        serve_connection(&shared, stream);
    }
}

/// Serve one connection until close/EOF/error — HTTP/1.1 keep-alive
/// with pipelining (every complete buffered request is served before
/// the next read).
fn serve_connection(shared: &Shared, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    if stream.set_read_timeout(Some(shared.cfg.poll_interval)).is_err()
        || stream.set_write_timeout(Some(shared.cfg.write_timeout)).is_err()
    {
        return;
    }
    let mut buf: Vec<u8> = Vec::with_capacity(4096);
    let mut chunk = [0u8; 4096];
    let mut last_activity = Instant::now();
    let mut shutdown_polls: u32 = 0;
    loop {
        // Serve everything already buffered.
        loop {
            match http::try_parse(&buf) {
                Ok(Some((req, consumed))) => {
                    buf.drain(..consumed);
                    last_activity = Instant::now();
                    let route = Route::of_path(&req.path);
                    let t0 = Instant::now();
                    let mut resp = routes::handle(&shared.state, &shared.metrics, &req);
                    shared.metrics.record(route, resp.status, t0.elapsed());
                    resp.close = resp.close || !req.keep_alive() || shared.is_shutdown();
                    let close = resp.close;
                    if http::write_response(&mut stream, &resp).is_err() || close {
                        return;
                    }
                }
                Ok(None) => break,
                Err(e) => {
                    let body = Value::obj(vec![
                        ("error", Value::str(e.message)),
                        ("code", Value::str("bad_http")),
                    ])
                    .render();
                    shared.metrics.record(Route::Other, 400, Duration::ZERO);
                    let _ =
                        http::write_response(&mut stream, &HttpResponse::json(400, body).closing());
                    return;
                }
            }
        }
        match stream.read(&mut chunk) {
            Ok(0) => return, // client closed
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                last_activity = Instant::now();
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // Poll tick: notice shutdown and idle clients.
                if shared.is_shutdown() {
                    shutdown_polls += 1;
                    // Idle connections close on the first tick; one
                    // with a partial request gets a bounded grace.
                    if buf.is_empty() || shutdown_polls >= DRAIN_POLLS {
                        return;
                    }
                } else if last_activity.elapsed() >= shared.cfg.idle_timeout {
                    return;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dvfs::PowerModel;
    use crate::engine::Engine;
    use crate::model::{HwParams, KernelCounters};
    use crate::service::client::Client;

    fn test_counters() -> KernelCounters {
        KernelCounters {
            l2_hr: 0.1,
            gld_trans: 6.0,
            avr_inst: 1.5,
            n_blocks: 128.0,
            wpb: 8.0,
            aw: 64.0,
            n_sm: 16.0,
            o_itrs: 8.0,
            i_itrs: 0.0,
            uses_smem: false,
            smem_conflict: 1.0,
            gld_body: 6.0,
            gld_edge: 0.0,
            mem_ops: 2.0,
            l1_hr: 0.0,
        }
    }

    fn test_state() -> ServiceState {
        let hw = HwParams::paper_defaults();
        let mut s = ServiceState::new(
            Engine::native(hw),
            PowerModel::gtx980(),
            crate::microbench::standard_grid(),
        );
        s.register_kernel("VA", test_counters());
        s
    }

    fn fast_cfg(workers: usize, queue_capacity: usize) -> ServiceConfig {
        ServiceConfig {
            workers,
            queue_capacity,
            poll_interval: Duration::from_millis(10),
            ..ServiceConfig::default()
        }
    }

    #[test]
    fn keep_alive_round_trips_on_one_connection() {
        let svc = Service::start(test_state(), fast_cfg(2, 8)).unwrap();
        let mut c = Client::connect(&svc.addr()).unwrap();
        for _ in 0..3 {
            let r = c.get("/healthz").unwrap();
            assert_eq!(r.status, 200);
            assert!(r.body.contains("\"ok\""));
        }
        let r = c
            .post("/v1/predict", r#"{"kernel":"VA","core_mhz":700,"mem_mhz":700}"#)
            .unwrap();
        assert_eq!(r.status, 200);
        let m = svc.metrics();
        assert_eq!(m.route(Route::Healthz).requests.load(SeqCst), 3);
        assert_eq!(m.route(Route::Predict).requests.load(SeqCst), 1);
        drop(c);
        svc.shutdown();
    }

    #[test]
    fn concurrent_clients_each_get_correct_answers() {
        let svc = Service::start(test_state(), fast_cfg(4, 16)).unwrap();
        let addr = svc.addr();
        std::thread::scope(|scope| {
            for t in 0..4u32 {
                scope.spawn(move || {
                    let mut c = Client::connect(&addr).unwrap();
                    for i in 0..10 {
                        let cf = 400 + 100 * ((t as usize + i) % 7);
                        let body =
                            format!(r#"{{"kernel":"VA","core_mhz":{cf},"mem_mhz":700}}"#);
                        let r = c.post("/v1/predict", &body).unwrap();
                        assert_eq!(r.status, 200);
                        let v = r.json().unwrap();
                        assert_eq!(
                            v.get("core_mhz").and_then(Value::as_f64),
                            Some(cf as f64)
                        );
                        assert!(v.get("time_us").and_then(Value::as_f64).unwrap() > 0.0);
                    }
                });
            }
        });
        let m = svc.metrics();
        assert_eq!(m.route(Route::Predict).requests.load(SeqCst), 40);
        svc.shutdown();
    }

    #[test]
    fn overload_sheds_with_429_and_retry_after() {
        // One worker, tiny queue. A held-open connection pins the
        // worker; two more fill the queue; the next is shed.
        let svc = Service::start(test_state(), fast_cfg(1, 2)).unwrap();
        let addr = svc.addr();
        let mut holder = Client::connect(&addr).unwrap();
        assert_eq!(holder.get("/healthz").unwrap().status, 200);
        // These two sit in the queue (the worker is parked on `holder`).
        let _queued_a = Client::connect(&addr).unwrap();
        let _queued_b = Client::connect(&addr).unwrap();
        // Give the acceptor a moment to enqueue both.
        std::thread::sleep(Duration::from_millis(100));
        let mut shed = Client::connect(&addr).unwrap();
        shed.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let r = shed.read_response().unwrap();
        assert_eq!(r.status, 429);
        assert_eq!(r.header("retry-after"), Some("1"));
        assert!(r.body.contains("overloaded"));
        assert!(svc.metrics().shed_total.load(SeqCst) >= 1);
        drop(holder);
        svc.shutdown();
    }

    #[test]
    fn shutdown_drains_and_joins() {
        let svc = Service::start(test_state(), fast_cfg(2, 8)).unwrap();
        let addr = svc.addr();
        let mut c = Client::connect(&addr).unwrap();
        assert_eq!(c.get("/healthz").unwrap().status, 200);
        let t0 = Instant::now();
        svc.shutdown(); // idle connection: closed within a poll tick
        assert!(t0.elapsed() < Duration::from_secs(5), "drain took {:?}", t0.elapsed());
        // The worker closed the kept-alive connection during drain
        // (asserting on the held connection, not the port — the
        // ephemeral port may be reassigned to a parallel test).
        let _ = c.set_read_timeout(Some(Duration::from_secs(5)));
        assert!(c.get("/healthz").is_err(), "connection must be closed after drain");
    }

    #[test]
    fn malformed_requests_get_400_and_close() {
        use std::io::Write as _;
        let svc = Service::start(test_state(), fast_cfg(1, 4)).unwrap();
        let mut raw = TcpStream::connect(svc.addr()).unwrap();
        raw.write_all(b"NONSENSE\r\n\r\n").unwrap();
        let mut out = Vec::new();
        raw.read_to_end(&mut out).unwrap(); // server closes after 400
        let text = String::from_utf8_lossy(&out);
        assert!(text.starts_with("HTTP/1.1 400"), "{text}");
        svc.shutdown();
    }
}
