//! Streaming scheduler (DESIGN.md §14): an event-driven, rolling-horizon
//! job lifecycle layered on [`planner`].
//!
//! PR 4's planner is one-shot — full batch in, full plan out. Real fleets
//! are a *stream*: arrivals, early completions, progress reports that
//! contradict the model, devices coming and going (Ilager et al. and the
//! DSO optimizer in PAPERS.md both frame deadline-aware GPU frequency
//! scaling as exactly this online problem). This module keeps a
//! long-lived [`SchedulerCore`] whose state advances only through a
//! monotone event queue:
//!
//! ```text
//!   JobSubmitted ──► admission (provable deadline bound, 4096-job cap)
//!        │               │ reject: structured PlanError::Infeasible
//!        ▼               ▼
//!   Queued ──► Scheduled ──► Running ──► Done
//!     │  ▲        │  │          │
//!     │  └────────┘  └──────────┤   (device down re-queues; epoch
//!     ▼                         ▼    re-solve may displace)
//!   Missed (deadline passed)  Missed (finished late)
//!   Cancelled (operator DELETE, from any non-terminal state)
//! ```
//!
//! Two planning paths share one [`ScheduleTable`]:
//!
//! * **Incremental repair** — a single arrival is inserted into the
//!   existing placement via [`ScheduleTable::repair_insert`]: cheapest
//!   feasible device with slack, else one one-level relocation. Cost is
//!   at most one kernel slab (`total_points` candidates, zero for a
//!   kernel seen before) instead of the batch solver's `K × total_points`
//!   — the strict inequality `benches/scheduler_stream.rs` gates on.
//! * **Full re-solve** — when repair's achieved objective exceeds the
//!   cap-free optimum by more than [`SchedulerConfig::degrade_threshold`],
//!   or when the rolling horizon ticks over (every
//!   [`SchedulerConfig::replan_interval_us`]), the fleet of live
//!   Queued/Scheduled jobs is re-planned with [`planner::plan`].
//!
//! Admission control is *provable*: runtime in this model depends only on
//! the (device, point), never on co-located load, so
//! [`ScheduleTable::fastest_us`] — the minimum over every available
//! device and frequency — is a true lower bound. A deadline below it is
//! rejected at submit with a structured [`PlanError::Infeasible`]
//! (`infeasible_at_submit` on the wire); anything above it is admitted
//! optimistically and either completes in time or is explicitly
//! transitioned to `Missed` with a recorded cause.
//!
//! The core is clock-agnostic: unit and property tests drive
//! [`SchedulerCore::run_until`] on a virtual clock; serve mode wraps the
//! core in a [`SchedulerHandle`] whose `tick` advances it to wall-clock
//! now (µs since server start). Every state change and every solve lands
//! in an outbox ([`SchedulerCore::drain_outbox`]) the service layer
//! drains into `job_transition` JSONL events, `/debug/plans` provenance
//! and `scheduler_*` metrics.
//!
//! # Example
//!
//! ```
//! use std::sync::Arc;
//! use gpufreq::dvfs::PowerModel;
//! use gpufreq::engine::Engine;
//! use gpufreq::model::{HwParams, KernelCounters};
//! use gpufreq::registry::{DeviceRegistry, KernelCatalog};
//! use gpufreq::scheduler::{JobSpec, JobState, SchedulerConfig, SchedulerCore};
//!
//! let hw = HwParams::paper_defaults();
//! let registry = Arc::new(DeviceRegistry::new());
//! let gpu = registry.register("gtx980", hw, PowerModel::gtx980());
//! let catalog = Arc::new(KernelCatalog::new());
//! # let counters = KernelCounters {
//! #     l2_hr: 0.1, gld_trans: 6.0, avr_inst: 1.5, n_blocks: 128.0,
//! #     wpb: 8.0, aw: 64.0, n_sm: 16.0, o_itrs: 8.0, i_itrs: 0.0,
//! #     uses_smem: false, smem_conflict: 1.0, gld_body: 6.0,
//! #     gld_edge: 0.0, mem_ops: 2.0, l1_hr: 0.0,
//! # };
//! let kernel = catalog.register("VA", counters);
//! let engine = Engine::native(hw).with_handles(registry, catalog, gpu).unwrap();
//!
//! let mut sched = SchedulerCore::new(SchedulerConfig::default());
//! let id = sched.submit(&engine, JobSpec::new("stream-0", kernel, 2.0)).unwrap();
//! sched.run_until(&engine, 5e6); // advance the virtual clock 5 s
//! assert_eq!(sched.job(id).unwrap().state, JobState::Done);
//! ```
//!
//! [`planner`]: crate::planner
//! [`planner::plan`]: crate::planner::plan

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Instant;

use crate::engine::Engine;
use crate::planner::{
    plan, Job, PlanError, PlannerConfig, ScheduleTable, SolveReport, MAX_JOBS,
};
use crate::registry::{DeviceId, FreqPoint, KernelId};

/// Where a job is in its lifecycle. Terminal states are never left.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Admitted but not placed (no device has feasible slack yet).
    Queued,
    /// Placed on a device at an operating point, waiting for a slot.
    Scheduled,
    /// Occupying a device slot; a predicted completion is queued.
    Running,
    /// Completed within its deadline (or had none).
    Done,
    /// Deadline passed — while queued, while waiting, or by finishing
    /// late; `cause` on the record says which.
    Missed,
    /// Removed by an operator (`DELETE /v2/jobs/{id}`).
    Cancelled,
}

impl JobState {
    /// Stable wire name (`GET /v2/jobs` `state` field, JSONL events).
    pub fn name(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Scheduled => "scheduled",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Missed => "missed",
            JobState::Cancelled => "cancelled",
        }
    }

    /// Done, Missed and Cancelled are absorbing.
    pub fn is_terminal(self) -> bool {
        matches!(self, JobState::Done | JobState::Missed | JobState::Cancelled)
    }
}

/// What a client submits: the planner's [`Job`] with the deadline
/// expressed *relative to submission* (µs from now), since a streaming
/// client cannot know the scheduler's clock.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Operator-facing label; empty means "name it `job-<id>`".
    pub name: String,
    pub kernel: KernelId,
    /// Workload scale (runtime = `scale ×` single-invocation prediction).
    pub scale: f64,
    /// Budget on the scaled runtime, µs **from submission time**.
    pub deadline_us: Option<f64>,
}

impl JobSpec {
    /// A deadline-free spec (pure energy participation).
    pub fn new(name: impl Into<String>, kernel: KernelId, scale: f64) -> JobSpec {
        JobSpec { name: name.into(), kernel, scale, deadline_us: None }
    }

    /// Attach a relative deadline (µs from submission).
    pub fn with_deadline(mut self, deadline_us: f64) -> JobSpec {
        self.deadline_us = Some(deadline_us);
        self
    }
}

/// One job's full lifecycle record — everything `GET /v2/jobs/{id}`
/// serializes. All timestamps are scheduler-clock µs.
#[derive(Debug, Clone)]
pub struct JobRecord {
    /// Monotonic per-scheduler id (`job-<n>` on the wire).
    pub id: u64,
    pub name: String,
    pub kernel: KernelId,
    pub scale: f64,
    /// Absolute deadline instant (submission time + relative budget).
    pub deadline_at_us: Option<f64>,
    pub state: JobState,
    pub submitted_at_us: f64,
    /// Current placement, when Scheduled or Running.
    pub device: Option<DeviceId>,
    /// Chosen (core, mem) operating point, when placed.
    pub point: Option<FreqPoint>,
    /// Predicted scaled runtime at the chosen point, µs; refined by
    /// `JobProgress` observations while Running.
    pub predicted_us: Option<f64>,
    /// Board power at the chosen point, W, when placed.
    pub power_w: Option<f64>,
    /// Dynamic share of `power_w` (DESIGN.md §15), when placed.
    pub power_dynamic_w: Option<f64>,
    /// Leakage share of `power_w` (static + V-dependent), when placed.
    pub power_leakage_w: Option<f64>,
    pub started_at_us: Option<f64>,
    /// Set on any terminal transition.
    pub finished_at_us: Option<f64>,
    /// Why the job is where it is (miss cause, cancellation, re-queue).
    pub cause: Option<String>,
    /// The solve (`plan-<n>`) that produced the current placement.
    pub plan_id: Option<u64>,
    /// Bumped on every placement/start/finish so stale predicted
    /// completions in the event queue are recognized and dropped.
    generation: u64,
}

impl JobRecord {
    /// The wire form of [`id`](JobRecord::id).
    pub fn id_str(&self) -> String {
        format!("job-{}", self.id)
    }
}

/// External events the scheduler reacts to. In serve mode these arrive
/// through the `/v2/jobs` routes; on the virtual clock tests inject them
/// with [`SchedulerCore::schedule`].
#[derive(Debug, Clone)]
pub enum Event {
    JobSubmitted(JobSpec),
    /// The client observed the job finish (possibly before the model's
    /// prediction — the prediction is then discarded).
    JobCompleted { job: u64 },
    /// The client observed `fraction` of the job done; the scheduler
    /// fuses the observed rate into a refreshed completion estimate
    /// (the DSO argument: runtime signals beat static predictions).
    JobProgress { job: u64, fraction: f64 },
    DeviceUp(DeviceId),
    DeviceDown(DeviceId),
}

/// Internal queue entry kinds: external events plus the scheduler's own
/// timers (model-predicted completions and deadline checks).
#[derive(Debug, Clone)]
enum QueuedKind {
    External(Event),
    PredictedCompletion { job: u64, generation: u64 },
    DeadlineCheck { job: u64 },
}

/// Heap entry: earliest `at_us` first, FIFO (`seq`) within a tie.
#[derive(Debug)]
struct QueuedEvent {
    at_us: f64,
    seq: u64,
    kind: QueuedKind,
}

impl PartialEq for QueuedEvent {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for QueuedEvent {}

impl PartialOrd for QueuedEvent {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for QueuedEvent {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want the earliest time
        // (then lowest sequence number) on top.
        other.at_us.total_cmp(&self.at_us).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// One recorded state change, drained by the service layer into
/// `job_transition` JSONL events. `from: None` marks admission.
#[derive(Debug, Clone)]
pub struct TransitionRecord {
    pub job: u64,
    pub name: String,
    pub from: Option<JobState>,
    pub to: JobState,
    pub at_us: f64,
    /// The solve that caused the transition, when one did.
    pub plan_id: Option<u64>,
    pub cause: Option<String>,
    /// X-Request-Id of the HTTP request that triggered the transition,
    /// when one did (event-queue transitions have none).
    pub request_id: Option<String>,
}

/// Which planning path produced a [`SolveOutcome`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolveKind {
    /// Single-event incremental repair ([`ScheduleTable::repair_insert`]).
    Repair,
    /// Fleet re-solve ([`planner::plan`](crate::planner::plan)).
    Full,
}

impl SolveKind {
    /// Stable wire name (JSONL `solve` events, `/debug/plans`).
    pub fn name(self) -> &'static str {
        match self {
            SolveKind::Repair => "repair",
            SolveKind::Full => "full",
        }
    }
}

/// One solve the scheduler ran, drained by the service layer into
/// `/metrics` histograms and the `/debug/plans` provenance ring.
#[derive(Debug, Clone)]
pub struct SolveOutcome {
    pub kind: SolveKind,
    /// What forced the solve: `job_arrival`, `job_finished`,
    /// `job_cancelled`, `deadline_miss`, `device_change`,
    /// `repair_degraded` or `horizon_roll`.
    pub trigger: &'static str,
    pub at_us: f64,
    /// Jobs (re)placed by this solve.
    pub jobs: usize,
    /// Names of the (re)placed jobs, indexed by the report's
    /// `Explain::job` (the solve's provenance record needs them).
    pub job_names: Vec<String>,
    pub total_energy_mj: f64,
    pub max_time_us: f64,
    pub report: SolveReport,
}

/// Monotonic counters plus the `active` gauge, exported as
/// `scheduler_*` series on `/metrics`.
#[derive(Debug, Clone, Copy, Default)]
pub struct SchedulerStats {
    pub submitted: u64,
    pub admitted: u64,
    /// Rejected at admission (infeasible deadline or scheduler full).
    pub rejected: u64,
    pub completed: u64,
    pub missed: u64,
    pub cancelled: u64,
    /// Jobs currently in a non-terminal state (gauge).
    pub active: u64,
    /// Incremental repairs applied.
    pub repairs: u64,
    /// Full fleet re-solves run.
    pub full_solves: u64,
    /// Repairs whose degradation exceeded the threshold and escalated
    /// to a full re-solve.
    pub repair_fallbacks: u64,
    pub events_processed: u64,
}

/// Scheduler tuning. Non-finite or non-positive durations fall back to
/// the defaults at construction — the core must never stall on a zero
/// re-plan interval.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Rolling-horizon epoch length, µs (default 1 s). Each epoch with
    /// live Queued/Scheduled work triggers a full re-solve.
    pub replan_interval_us: f64,
    /// How far ahead an epoch re-solve looks, µs (default 30 s): queued
    /// jobs with deadlines beyond `now + horizon` wait for a later epoch.
    pub horizon_us: f64,
    /// Relative objective excess (repair's achieved placement over the
    /// cap-free optimum) beyond which repair escalates to a full
    /// re-solve (default 0.25).
    pub degrade_threshold: f64,
    /// Objective, device subset, per-device concurrency cap and
    /// candidate pairs, shared with the batch planner.
    pub planner: PlannerConfig,
}

impl Default for SchedulerConfig {
    fn default() -> SchedulerConfig {
        SchedulerConfig {
            replan_interval_us: 1e6,
            horizon_us: 30e6,
            degrade_threshold: 0.25,
            planner: PlannerConfig::default(),
        }
    }
}

/// The clock-agnostic scheduler: an event queue, a job table, a cached
/// [`ScheduleTable`] and an outbox of transitions/solves for the
/// observability layer. Time only moves forward, and only through
/// [`run_until`](SchedulerCore::run_until) (or the synchronous entry
/// points [`submit`](SchedulerCore::submit) /
/// [`cancel`](SchedulerCore::cancel), which act at the current instant).
pub struct SchedulerCore {
    cfg: SchedulerConfig,
    now_us: f64,
    queue: BinaryHeap<QueuedEvent>,
    seq: u64,
    jobs: Vec<JobRecord>,
    next_job_id: u64,
    /// Lazily built, rebuilt when the registry grows (dynamic `/v2/devices`
    /// registrations) — an idle server never prices anything.
    table: Option<ScheduleTable>,
    table_devices: usize,
    /// Devices currently marked down, survives table rebuilds.
    down: Vec<DeviceId>,
    next_epoch_at_us: f64,
    transitions: Vec<TransitionRecord>,
    solves: Vec<SolveOutcome>,
    stats: SchedulerStats,
    request_id: Option<String>,
}

impl SchedulerCore {
    pub fn new(cfg: SchedulerConfig) -> SchedulerCore {
        let mut cfg = cfg;
        if !(cfg.replan_interval_us.is_finite() && cfg.replan_interval_us > 0.0) {
            cfg.replan_interval_us = 1e6;
        }
        if !(cfg.horizon_us.is_finite() && cfg.horizon_us > 0.0) {
            cfg.horizon_us = 30e6;
        }
        if !(cfg.degrade_threshold.is_finite() && cfg.degrade_threshold >= 0.0) {
            cfg.degrade_threshold = 0.25;
        }
        let next_epoch_at_us = cfg.replan_interval_us;
        SchedulerCore {
            cfg,
            now_us: 0.0,
            queue: BinaryHeap::new(),
            seq: 0,
            jobs: Vec::new(),
            next_job_id: 1,
            table: None,
            table_devices: 0,
            down: Vec::new(),
            next_epoch_at_us,
            transitions: Vec::new(),
            solves: Vec::new(),
            stats: SchedulerStats::default(),
            request_id: None,
        }
    }

    /// Current scheduler-clock instant, µs.
    pub fn now_us(&self) -> f64 {
        self.now_us
    }

    pub fn config(&self) -> &SchedulerConfig {
        &self.cfg
    }

    /// Tag subsequent synchronous mutations with an X-Request-Id so
    /// their transitions correlate in the event log.
    pub fn set_request_id(&mut self, id: Option<String>) {
        self.request_id = id;
    }

    /// Every job record, in submission order.
    pub fn jobs(&self) -> &[JobRecord] {
        &self.jobs
    }

    pub fn job(&self, id: u64) -> Option<&JobRecord> {
        self.jobs.iter().find(|j| j.id == id)
    }

    /// Counters with the `active` gauge filled in.
    pub fn stats(&self) -> SchedulerStats {
        let mut s = self.stats;
        s.active = self.jobs.iter().filter(|j| !j.state.is_terminal()).count() as u64;
        s
    }

    /// Cumulative `(candidates_evaluated, slab_calls)` of the
    /// incremental table ((0, 0) before first use). Diff around a
    /// submit to attribute per-event pricing work — admission plus
    /// repair — which the bench gate compares against a full
    /// re-solve's `K × total_points`.
    pub fn table_counters(&self) -> (u64, u64) {
        self.table.as_ref().map_or((0, 0), |t| t.counters())
    }

    /// Take the accumulated transitions and solves (oldest first). The
    /// service layer turns these into JSONL events, metrics and plan
    /// provenance; tests use them as the ground-truth trace.
    pub fn drain_outbox(&mut self) -> (Vec<TransitionRecord>, Vec<SolveOutcome>) {
        (std::mem::take(&mut self.transitions), std::mem::take(&mut self.solves))
    }

    /// Queue an external event at `at_us` (clamped to now; time never
    /// rewinds). Virtual-clock entry point — serve mode calls
    /// [`submit`](SchedulerCore::submit)/[`cancel`](SchedulerCore::cancel)
    /// synchronously instead.
    pub fn schedule(&mut self, at_us: f64, event: Event) {
        let at = if at_us.is_finite() { at_us.max(self.now_us) } else { self.now_us };
        self.push_internal(at, QueuedKind::External(event));
    }

    /// Admit (or reject) a job at the current instant.
    ///
    /// Admission is *provable*, not load-aware: the only submit-time
    /// rejections are a deadline strictly below
    /// [`ScheduleTable::fastest_us`] (infeasible even at max frequency
    /// on an otherwise-idle device), a kernel the engine does not know,
    /// malformed numbers, or a full scheduler ([`MAX_JOBS`] live jobs).
    /// An admitted job that later cannot be placed in time is
    /// explicitly transitioned to `Missed` with a recorded cause.
    pub fn submit(&mut self, engine: &Engine, spec: JobSpec) -> Result<u64, PlanError> {
        self.stats.submitted += 1;
        if let Err(e) = self.admit(engine, &spec) {
            self.stats.rejected += 1;
            return Err(e);
        }
        self.stats.admitted += 1;
        let id = self.next_job_id;
        self.next_job_id += 1;
        let name =
            if spec.name.is_empty() { format!("job-{id}") } else { spec.name.clone() };
        let deadline_at_us = spec.deadline_us.map(|d| self.now_us + d);
        self.jobs.push(JobRecord {
            id,
            name: name.clone(),
            kernel: spec.kernel,
            scale: spec.scale,
            deadline_at_us,
            state: JobState::Queued,
            submitted_at_us: self.now_us,
            device: None,
            point: None,
            predicted_us: None,
            power_w: None,
            power_dynamic_w: None,
            power_leakage_w: None,
            started_at_us: None,
            finished_at_us: None,
            cause: None,
            plan_id: None,
            generation: 0,
        });
        self.transitions.push(TransitionRecord {
            job: id,
            name,
            from: None,
            to: JobState::Queued,
            at_us: self.now_us,
            plan_id: None,
            cause: None,
            request_id: self.request_id.clone(),
        });
        if let Some(at) = deadline_at_us {
            self.push_internal(at, QueuedKind::DeadlineCheck { job: id });
        }
        let idx = self.jobs.len() - 1;
        // Placement failures (caps, availability) leave the job Queued;
        // they are not submit errors.
        let _ = self.place_one(engine, idx, "job_arrival");
        self.dispatch_all();
        Ok(id)
    }

    /// Cancel a job at the current instant. `None` if the id is
    /// unknown; cancelling an already-terminal job is a no-op that
    /// returns the record unchanged.
    pub fn cancel(&mut self, engine: &Engine, id: u64) -> Option<JobRecord> {
        let idx = self.index_of(id)?;
        if !self.jobs[idx].state.is_terminal() {
            {
                let r = &mut self.jobs[idx];
                r.generation += 1;
                r.finished_at_us = Some(self.now_us);
            }
            self.stats.cancelled += 1;
            let plan_id = self.jobs[idx].plan_id;
            let cause = Some("cancelled by request".to_string());
            self.transition(idx, JobState::Cancelled, plan_id, cause);
            self.try_place_queued(engine, "job_cancelled");
        }
        Some(self.jobs[idx].clone())
    }

    /// Advance the clock to `t_us`, processing every queued event and
    /// every rolling-horizon epoch due on the way, in time order
    /// (FIFO within ties). Idle stretches cost nothing: epochs with no
    /// live work are skipped in O(1) and emit no solves or events.
    pub fn run_until(&mut self, engine: &Engine, t_us: f64) {
        if !t_us.is_finite() {
            return;
        }
        loop {
            let next_event = self.queue.peek().map(|e| e.at_us);
            let event_due = matches!(next_event, Some(at) if at <= t_us);
            let epoch_due = self.next_epoch_at_us <= t_us;
            let event_first = matches!(next_event, Some(at) if at <= self.next_epoch_at_us);
            if event_due && (!epoch_due || event_first) {
                let ev = self.queue.pop().expect("peeked above");
                if ev.at_us > self.now_us {
                    self.now_us = ev.at_us;
                }
                self.process(engine, ev.kind);
            } else if epoch_due {
                if self.next_epoch_at_us > self.now_us {
                    self.now_us = self.next_epoch_at_us;
                }
                if self.has_plannable() {
                    self.full_resolve(engine, "horizon_roll");
                }
                let step = self.cfg.replan_interval_us;
                self.next_epoch_at_us += step;
                if self.queue.is_empty() && !self.has_plannable() && self.next_epoch_at_us <= t_us
                {
                    // Idle fast-forward: the skipped epochs would all be
                    // no-ops, so jump past them in one step.
                    let missed = ((t_us - self.next_epoch_at_us) / step).floor();
                    if missed.is_finite() && missed > 0.0 {
                        self.next_epoch_at_us += missed * step;
                    }
                }
            } else {
                break;
            }
        }
        if t_us > self.now_us {
            self.now_us = t_us;
        }
    }

    // ---- admission ----------------------------------------------------

    fn admit(&mut self, engine: &Engine, spec: &JobSpec) -> Result<(), PlanError> {
        if !(spec.scale.is_finite() && spec.scale > 0.0) {
            return Err(PlanError::Invalid(format!(
                "job `{}`: scale must be positive and finite, got {}",
                spec.name, spec.scale
            )));
        }
        if let Some(d) = spec.deadline_us {
            if !(d.is_finite() && d > 0.0) {
                return Err(PlanError::Invalid(format!(
                    "job `{}`: deadline_us must be positive and finite, got {d}",
                    spec.name
                )));
            }
        }
        let live = self.jobs.iter().filter(|j| !j.state.is_terminal()).count();
        if live >= MAX_JOBS {
            return Err(PlanError::Invalid(format!(
                "scheduler is at its live-job limit ({MAX_JOBS}); drain or cancel before \
                 submitting more"
            )));
        }
        let name = spec.name.clone();
        let kernel = spec.kernel;
        let scale = spec.scale;
        let deadline = spec.deadline_us;
        let table = self.table_mut(engine)?;
        table.ensure_kernel(engine, kernel).map_err(|e| match e {
            PlanError::UnknownKernel { kernel, .. } => {
                PlanError::UnknownKernel { job: 0, name: name.clone(), kernel }
            }
            other => other,
        })?;
        if let Some(d) = deadline {
            let fastest = table.fastest_us(engine, kernel, scale)?;
            if fastest > d {
                return Err(PlanError::Infeasible {
                    job: 0,
                    name,
                    detail: format!(
                        "deadline {d} µs is provably unmeetable: the fastest achievable \
                         runtime over every available device and frequency — even at max \
                         frequency on an otherwise-idle device — is {fastest:.3} µs"
                    ),
                });
            }
        }
        Ok(())
    }

    // ---- planning -----------------------------------------------------

    /// The planner's view of a record: deadline rebased to the budget
    /// *remaining* at `now`.
    fn planner_job(&self, r: &JobRecord, now: f64) -> Job {
        let mut j = Job::new(r.name.clone(), r.kernel, r.scale);
        if let Some(at) = r.deadline_at_us {
            j = j.with_deadline(at - now);
        }
        j
    }

    /// Movable/pinned split for a repair around job `idx`: Scheduled
    /// jobs with remaining budget may relocate; Running jobs (and the
    /// rare Scheduled job whose deadline already passed but whose check
    /// has not fired) only pin their device's capacity.
    fn repair_context(&self, idx: usize) -> (Job, Vec<(Job, DeviceId)>, Vec<DeviceId>, Vec<usize>) {
        let now = self.now_us;
        let arrival = self.planner_job(&self.jobs[idx], now);
        let mut movable = Vec::new();
        let mut movable_idx = Vec::new();
        let mut pinned = Vec::new();
        for (i, j) in self.jobs.iter().enumerate() {
            if i == idx {
                continue;
            }
            match j.state {
                JobState::Running => {
                    if let Some(d) = j.device {
                        pinned.push(d);
                    }
                }
                JobState::Scheduled => {
                    let doomed = matches!(j.deadline_at_us, Some(at) if at <= now);
                    match (doomed, j.device) {
                        (false, Some(d)) => {
                            movable.push((self.planner_job(j, now), d));
                            movable_idx.push(i);
                        }
                        (true, Some(d)) => pinned.push(d),
                        _ => {}
                    }
                }
                _ => {}
            }
        }
        (arrival, movable, pinned, movable_idx)
    }

    /// Try to place one Queued job by incremental repair. Infeasible
    /// placements leave the job Queued (deadline checks decide its
    /// fate); a repair degraded beyond the threshold escalates to a
    /// full re-solve.
    fn place_one(
        &mut self,
        engine: &Engine,
        idx: usize,
        trigger: &'static str,
    ) -> Result<(), PlanError> {
        if self.jobs[idx].state != JobState::Queued {
            return Ok(());
        }
        if matches!(self.jobs[idx].deadline_at_us, Some(at) if at <= self.now_us) {
            return Ok(());
        }
        let (arrival, movable, pinned, movable_idx) = self.repair_context(idx);
        let outcome = {
            let table = self.table_mut(engine)?;
            match table.repair_insert(engine, &arrival, &movable, &pinned) {
                Ok(o) => o,
                Err(PlanError::Infeasible { .. }) => return Ok(()),
                Err(e) => return Err(e),
            }
        };
        if outcome.degradation > self.cfg.degrade_threshold {
            self.stats.repair_fallbacks += 1;
            self.full_resolve(engine, "repair_degraded");
            return Ok(());
        }
        let plan_id = outcome.report.plan_id;
        if let Some((mi, moved)) = outcome.moved {
            let r = &mut self.jobs[movable_idx[mi]];
            r.device = Some(moved.device);
            r.point = Some(moved.point);
            r.predicted_us = Some(moved.time_us);
            r.power_w = Some(moved.power_w);
            r.power_dynamic_w = Some(moved.power_dynamic_w);
            r.power_leakage_w = Some(moved.power_leakage_w);
            r.plan_id = Some(plan_id);
        }
        let p = outcome.placement;
        {
            let r = &mut self.jobs[idx];
            r.device = Some(p.device);
            r.point = Some(p.point);
            r.predicted_us = Some(p.time_us);
            r.power_w = Some(p.power_w);
            r.power_dynamic_w = Some(p.power_dynamic_w);
            r.power_leakage_w = Some(p.power_leakage_w);
        }
        self.transition(idx, JobState::Scheduled, Some(plan_id), None);
        self.stats.repairs += 1;
        let mut job_names = vec![self.jobs[idx].name.clone()];
        if let Some((mi, _)) = outcome.moved {
            job_names.push(self.jobs[movable_idx[mi]].name.clone());
        }
        self.solves.push(SolveOutcome {
            kind: SolveKind::Repair,
            trigger,
            at_us: self.now_us,
            jobs: 1 + usize::from(outcome.moved.is_some()),
            job_names,
            total_energy_mj: p.energy_mj + outcome.moved.map_or(0.0, |(_, m)| m.energy_mj),
            max_time_us: p.time_us.max(outcome.moved.map_or(0.0, |(_, m)| m.time_us)),
            report: outcome.report,
        });
        Ok(())
    }

    /// Full fleet re-solve over live Queued/Scheduled jobs inside the
    /// horizon. Jobs the batch solver proves infeasible are dropped
    /// from the solve one at a time (a Scheduled drop is demoted back
    /// to Queued); the deadline checks decide what becomes of them.
    fn full_resolve(&mut self, engine: &Engine, trigger: &'static str) {
        let now = self.now_us;
        let horizon = self.cfg.horizon_us;
        let mut idxs: Vec<usize> = self
            .jobs
            .iter()
            .enumerate()
            .filter(|(_, j)| match (j.state, j.deadline_at_us) {
                (JobState::Queued, None) | (JobState::Scheduled, None) => true,
                (JobState::Queued, Some(at)) => at > now && at - now <= horizon,
                (JobState::Scheduled, Some(at)) => at > now,
                _ => false,
            })
            .map(|(i, _)| i)
            .collect();
        if idxs.is_empty() {
            return;
        }
        let available = match self.table_mut(engine) {
            Ok(t) => t.available_ids(),
            Err(_) => return,
        };
        if available.is_empty() {
            return;
        }
        let mut cfg = self.cfg.planner.clone();
        cfg.devices = Some(available);
        let solved = loop {
            if idxs.is_empty() {
                return;
            }
            let jobs: Vec<Job> =
                idxs.iter().map(|&i| self.planner_job(&self.jobs[i], now)).collect();
            match plan(engine, &jobs, &cfg) {
                Ok(p) => break p,
                Err(PlanError::Infeasible { job, .. }) => {
                    let dropped = idxs.remove(job);
                    if self.jobs[dropped].state == JobState::Scheduled {
                        {
                            let r = &mut self.jobs[dropped];
                            r.device = None;
                            r.point = None;
                            r.predicted_us = None;
                            r.power_w = None;
                            r.power_dynamic_w = None;
                            r.power_leakage_w = None;
                            r.generation += 1;
                        }
                        self.transition(
                            dropped,
                            JobState::Queued,
                            None,
                            Some("displaced at re-solve: no feasible placement".to_string()),
                        );
                    }
                }
                Err(_) => return,
            }
        };
        let plan_id = solved.report.plan_id;
        for a in &solved.assignments {
            let i = idxs[a.job];
            let was_queued = {
                let r = &mut self.jobs[i];
                let was = r.state == JobState::Queued;
                r.device = Some(a.device);
                r.point = Some(a.point);
                r.predicted_us = Some(a.time_us);
                r.power_w = Some(a.power_w);
                r.power_dynamic_w = Some(a.power_dynamic_w);
                r.power_leakage_w = Some(a.power_leakage_w);
                r.plan_id = Some(plan_id);
                was
            };
            if was_queued {
                self.transition(i, JobState::Scheduled, Some(plan_id), None);
            }
        }
        self.stats.full_solves += 1;
        let job_names: Vec<String> =
            idxs.iter().map(|&i| self.jobs[i].name.clone()).collect();
        self.solves.push(SolveOutcome {
            kind: SolveKind::Full,
            trigger,
            at_us: now,
            jobs: solved.assignments.len(),
            job_names,
            total_energy_mj: solved.total_energy_mj,
            max_time_us: solved.max_time_us,
            report: solved.report,
        });
        self.dispatch_all();
    }

    // ---- execution ----------------------------------------------------

    /// Start every Scheduled job whose device has a free slot (the
    /// runtime analogue of the planner's per-device concurrency cap).
    fn dispatch_all(&mut self) {
        let cap = self.cfg.planner.device_cap;
        loop {
            let next = self.jobs.iter().position(|j| {
                j.state == JobState::Scheduled
                    && j.device
                        .is_some_and(|d| !self.down.contains(&d) && self.running_load(d) < cap)
            });
            let Some(i) = next else { break };
            self.start_job(i);
        }
    }

    fn running_load(&self, device: DeviceId) -> usize {
        self.jobs
            .iter()
            .filter(|j| j.state == JobState::Running && j.device == Some(device))
            .count()
    }

    fn start_job(&mut self, idx: usize) {
        let (job_id, at, generation, plan_id) = {
            let r = &mut self.jobs[idx];
            r.started_at_us = Some(self.now_us);
            r.generation += 1;
            (r.id, self.now_us + r.predicted_us.unwrap_or(0.0), r.generation, r.plan_id)
        };
        self.transition(idx, JobState::Running, plan_id, None);
        self.push_internal(at, QueuedKind::PredictedCompletion { job: job_id, generation });
    }

    /// A Running job finished (model-predicted or client-observed):
    /// judge it against its deadline, free the slot, pull in backlog.
    fn finish_job(&mut self, engine: &Engine, idx: usize, observed: bool) {
        let now = self.now_us;
        let (late, plan_id) = {
            let r = &mut self.jobs[idx];
            r.finished_at_us = Some(now);
            r.generation += 1;
            let late = match r.deadline_at_us {
                Some(at) if now > at => Some(now - at),
                _ => None,
            };
            (late, r.plan_id)
        };
        match late {
            None => {
                self.stats.completed += 1;
                let cause =
                    observed.then(|| "completion reported before the predicted finish".to_string());
                self.transition(idx, JobState::Done, plan_id, cause);
            }
            Some(l) => {
                self.stats.missed += 1;
                self.transition(
                    idx,
                    JobState::Missed,
                    plan_id,
                    Some(format!("completed {l:.3} µs after the deadline")),
                );
            }
        }
        self.try_place_queued(engine, "job_finished");
    }

    /// Fires at a job's absolute deadline: anything not yet Running is
    /// now provably late. Running jobs are judged at completion instead.
    fn deadline_check(&mut self, engine: &Engine, idx: usize) {
        let cause = match self.jobs[idx].state {
            JobState::Queued => "deadline passed while queued (never placed)",
            JobState::Scheduled => "deadline passed while waiting for a device slot",
            _ => return,
        };
        {
            let r = &mut self.jobs[idx];
            r.generation += 1;
            r.finished_at_us = Some(self.now_us);
        }
        self.stats.missed += 1;
        let plan_id = self.jobs[idx].plan_id;
        self.transition(idx, JobState::Missed, plan_id, Some(cause.to_string()));
        self.try_place_queued(engine, "deadline_miss");
    }

    /// Fuse an observed completion fraction into a refreshed estimate:
    /// if `fraction` of the work took `elapsed`, the whole job takes
    /// `elapsed / fraction` — re-queue the predicted completion.
    fn observe_progress(&mut self, idx: usize, fraction: f64) {
        if !(fraction.is_finite() && fraction > 0.0) {
            return;
        }
        let now = self.now_us;
        let queued = {
            let r = &mut self.jobs[idx];
            if r.state != JobState::Running {
                return;
            }
            let started = r.started_at_us.unwrap_or(now);
            let elapsed = now - started;
            if elapsed <= 0.0 {
                return; // no rate signal yet
            }
            let total = elapsed / fraction.min(1.0);
            r.predicted_us = Some(total);
            r.generation += 1;
            (now + (total - elapsed).max(0.0), r.id, r.generation)
        };
        let (at, job, generation) = queued;
        self.push_internal(at, QueuedKind::PredictedCompletion { job, generation });
    }

    /// Availability flip. Down re-queues every job placed on the device
    /// (the state machine's documented back-edge) and re-plans them.
    fn set_device(&mut self, engine: &Engine, device: DeviceId, up: bool) {
        if up {
            self.down.retain(|&d| d != device);
        } else if !self.down.contains(&device) {
            self.down.push(device);
        }
        if let Ok(table) = self.table_mut(engine) {
            table.set_available(device, up);
        }
        if !up {
            let displaced: Vec<usize> = self
                .jobs
                .iter()
                .enumerate()
                .filter(|(_, j)| {
                    matches!(j.state, JobState::Scheduled | JobState::Running)
                        && j.device == Some(device)
                })
                .map(|(i, _)| i)
                .collect();
            for i in displaced {
                {
                    let r = &mut self.jobs[i];
                    r.device = None;
                    r.point = None;
                    r.predicted_us = None;
                    r.power_w = None;
                    r.power_dynamic_w = None;
                    r.power_leakage_w = None;
                    r.started_at_us = None;
                    r.generation += 1;
                }
                let cause = Some(format!("device {device} went down"));
                self.transition(i, JobState::Queued, None, cause);
            }
        }
        self.try_place_queued(engine, "device_change");
    }

    /// Re-try placement for every Queued job with budget left, then
    /// start whatever now fits.
    fn try_place_queued(&mut self, engine: &Engine, trigger: &'static str) {
        let queued: Vec<usize> = self
            .jobs
            .iter()
            .enumerate()
            .filter(|(_, j)| j.state == JobState::Queued)
            .map(|(i, _)| i)
            .collect();
        for i in queued {
            if self.jobs[i].state != JobState::Queued {
                continue; // an earlier repair's fallback re-solve placed it
            }
            let _ = self.place_one(engine, i, trigger);
        }
        self.dispatch_all();
    }

    // ---- plumbing -----------------------------------------------------

    fn process(&mut self, engine: &Engine, kind: QueuedKind) {
        self.stats.events_processed += 1;
        match kind {
            QueuedKind::External(ev) => match ev {
                Event::JobSubmitted(spec) => {
                    // Trace-driven rejections are counted, not fatal.
                    let _ = self.submit(engine, spec);
                }
                Event::JobCompleted { job } => {
                    if let Some(i) = self.index_of(job) {
                        if self.jobs[i].state == JobState::Running {
                            self.finish_job(engine, i, true);
                        }
                    }
                }
                Event::JobProgress { job, fraction } => {
                    if let Some(i) = self.index_of(job) {
                        self.observe_progress(i, fraction);
                    }
                }
                Event::DeviceUp(d) => self.set_device(engine, d, true),
                Event::DeviceDown(d) => self.set_device(engine, d, false),
            },
            QueuedKind::PredictedCompletion { job, generation } => {
                if let Some(i) = self.index_of(job) {
                    let r = &self.jobs[i];
                    if r.state == JobState::Running && r.generation == generation {
                        self.finish_job(engine, i, false);
                    }
                }
            }
            QueuedKind::DeadlineCheck { job } => {
                if let Some(i) = self.index_of(job) {
                    self.deadline_check(engine, i);
                }
            }
        }
    }

    fn push_internal(&mut self, at_us: f64, kind: QueuedKind) {
        self.seq += 1;
        let at = if at_us.is_finite() { at_us.max(self.now_us) } else { self.now_us };
        self.queue.push(QueuedEvent { at_us: at, seq: self.seq, kind });
    }

    fn index_of(&self, id: u64) -> Option<usize> {
        self.jobs.iter().position(|j| j.id == id)
    }

    fn has_plannable(&self) -> bool {
        self.jobs
            .iter()
            .any(|j| matches!(j.state, JobState::Queued | JobState::Scheduled))
    }

    fn transition(&mut self, idx: usize, to: JobState, plan: Option<u64>, cause: Option<String>) {
        let rec = {
            let r = &mut self.jobs[idx];
            let from = Some(r.state);
            r.state = to;
            if plan.is_some() {
                r.plan_id = plan;
            }
            if cause.is_some() {
                r.cause.clone_from(&cause);
            }
            TransitionRecord {
                job: r.id,
                name: r.name.clone(),
                from,
                to,
                at_us: self.now_us,
                plan_id: r.plan_id,
                cause,
                request_id: self.request_id.clone(),
            }
        };
        self.transitions.push(rec);
    }

    fn table_mut(&mut self, engine: &Engine) -> Result<&mut ScheduleTable, PlanError> {
        let reg_len = match engine.registry() {
            Some(r) => r.list().len(),
            None => 0,
        };
        let rebuild = match &self.table {
            None => true,
            Some(_) => self.cfg.planner.devices.is_none() && reg_len != self.table_devices,
        };
        if rebuild {
            let mut t = ScheduleTable::new(engine, &self.cfg.planner)?;
            for &d in &self.down {
                t.set_available(d, false);
            }
            self.table_devices = reg_len;
            self.table = Some(t);
        }
        Ok(self.table.as_mut().expect("table was just built"))
    }
}

/// Wall-clock wrapper for serve mode: the core behind a mutex plus a
/// fixed epoch so every HTTP worker and the `svc-sched` ticker share
/// one monotone µs clock.
pub struct SchedulerHandle {
    core: Mutex<SchedulerCore>,
    epoch: Instant,
}

impl SchedulerHandle {
    pub fn new(cfg: SchedulerConfig) -> SchedulerHandle {
        SchedulerHandle { core: Mutex::new(SchedulerCore::new(cfg)), epoch: Instant::now() }
    }

    /// µs since the handle was created — the serve-mode scheduler clock.
    pub fn now_us(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64() * 1e6
    }

    /// Lock the core (poisoning is ignored: the core's state is kept
    /// consistent by value, a panicked writer cannot half-apply it).
    pub fn lock(&self) -> MutexGuard<'_, SchedulerCore> {
        self.core.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Advance the core to wall-clock now (the ticker thread's body).
    pub fn tick(&self, engine: &Engine) {
        let now = self.now_us();
        self.lock().run_until(engine, now);
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::dvfs::PowerModel;
    use crate::model::{HwParams, KernelCounters};
    use crate::registry::{DeviceRegistry, KernelCatalog};

    fn counters_membound() -> KernelCounters {
        KernelCounters {
            l2_hr: 0.0,
            gld_trans: 12.0,
            avr_inst: 0.4,
            n_blocks: 256.0,
            wpb: 8.0,
            aw: 64.0,
            n_sm: 16.0,
            o_itrs: 8.0,
            i_itrs: 0.0,
            uses_smem: false,
            smem_conflict: 1.0,
            gld_body: 12.0,
            gld_edge: 0.0,
            mem_ops: 3.0,
            l1_hr: 0.0,
        }
    }

    fn counters_compbound() -> KernelCounters {
        KernelCounters { avr_inst: 100.0, l2_hr: 0.9, gld_trans: 2.0, ..counters_membound() }
    }

    /// The planner fixture: two devices (the second with slower DRAM
    /// and a cheaper power model) and two kernels, 21 grid points per
    /// device (42 total).
    fn fixture() -> (Engine, Vec<DeviceId>, Vec<KernelId>) {
        let hw = HwParams::paper_defaults();
        let registry = Arc::new(DeviceRegistry::new());
        let a = registry.register("gpu-a", hw, PowerModel::gtx980());
        let mut hw_b = hw;
        hw_b.dm_del += 1.0;
        let mut power_b = PowerModel::gtx980();
        power_b.leakage.static_w = 14.0;
        power_b.dynamic.core_coeff = 0.05;
        let b = registry.register("gpu-b", hw_b, power_b);
        let catalog = Arc::new(KernelCatalog::new());
        let mem = catalog.register("membound", counters_membound());
        let comp = catalog.register("compbound", counters_compbound());
        let engine = Engine::native(hw).with_handles(registry, catalog, a).unwrap();
        (engine, vec![a, b], vec![mem, comp])
    }

    /// A config with epochs pushed out of every test's time range, so
    /// outcomes are decided by events alone (deterministic traces).
    fn no_epoch() -> SchedulerConfig {
        SchedulerConfig { replan_interval_us: 1e12, ..SchedulerConfig::default() }
    }

    #[test]
    fn events_fire_in_time_order_then_fifo_within_a_tie() {
        let (engine, _, kernels) = fixture();
        let mut s = SchedulerCore::new(no_epoch());
        s.schedule(300.0, Event::JobSubmitted(JobSpec::new("c", kernels[0], 1.0)));
        s.schedule(100.0, Event::JobSubmitted(JobSpec::new("a", kernels[0], 1.0)));
        s.schedule(200.0, Event::JobSubmitted(JobSpec::new("b", kernels[0], 1.0)));
        s.schedule(500.0, Event::JobSubmitted(JobSpec::new("d", kernels[0], 1.0)));
        s.schedule(500.0, Event::JobSubmitted(JobSpec::new("e", kernels[0], 1.0)));
        s.run_until(&engine, 1000.0);
        let names: Vec<&str> = s.jobs().iter().map(|j| j.name.as_str()).collect();
        assert_eq!(names, vec!["a", "b", "c", "d", "e"], "time order, FIFO within the tie");
        assert_eq!(s.jobs()[0].submitted_at_us, 100.0);
        assert_eq!(s.jobs()[2].submitted_at_us, 300.0);
        assert!(s.stats().events_processed >= 5);
        assert_eq!(s.now_us(), 1000.0);
    }

    #[test]
    fn lifecycle_reaches_done_with_a_full_transition_trace() {
        let (engine, _, kernels) = fixture();
        let mut s = SchedulerCore::new(SchedulerConfig::default());
        let id =
            s.submit(&engine, JobSpec::new("steady", kernels[0], 2.0).with_deadline(1e8)).unwrap();
        let (transitions, solves) = s.drain_outbox();
        let states: Vec<(Option<JobState>, JobState)> =
            transitions.iter().map(|t| (t.from, t.to)).collect();
        assert_eq!(
            states,
            vec![
                (None, JobState::Queued),
                (Some(JobState::Queued), JobState::Scheduled),
                (Some(JobState::Scheduled), JobState::Running),
            ]
        );
        assert!(transitions[1].plan_id.is_some(), "placement carries solve provenance");
        assert_eq!(solves.len(), 1);
        assert_eq!(solves[0].kind, SolveKind::Repair);
        assert_eq!(solves[0].trigger, "job_arrival");
        let r = s.job(id).unwrap();
        assert!(r.device.is_some() && r.point.is_some() && r.predicted_us.is_some());
        let (total, dynamic, leakage) =
            (r.power_w.unwrap(), r.power_dynamic_w.unwrap(), r.power_leakage_w.unwrap());
        assert!(
            (dynamic + leakage - total).abs() < 1e-9 * total,
            "placement carries the power split: {dynamic} + {leakage} != {total}"
        );
        assert_eq!(r.id_str(), format!("job-{id}"));
        s.run_until(&engine, 9e5);
        let r = s.job(id).unwrap();
        assert_eq!(r.state, JobState::Done);
        assert!(r.finished_at_us.unwrap() <= 1e8);
        let st = s.stats();
        assert_eq!((st.submitted, st.admitted, st.completed, st.active), (1, 1, 1, 0));
        assert_eq!(st.repairs, 1);
        let (transitions, _) = s.drain_outbox();
        assert_eq!(transitions.len(), 1);
        assert_eq!(transitions[0].to, JobState::Done);
    }

    #[test]
    fn admission_rejects_only_with_proof() {
        let (engine, _, kernels) = fixture();
        let mut s = SchedulerCore::new(no_epoch());
        let err = s
            .submit(&engine, JobSpec::new("tight", kernels[0], 1.0).with_deadline(1e-6))
            .unwrap_err();
        match err {
            PlanError::Infeasible { name, detail, .. } => {
                assert_eq!(name, "tight");
                assert!(detail.contains("provably unmeetable"), "{detail}");
            }
            other => panic!("want Infeasible, got {other}"),
        }
        assert!(matches!(
            s.submit(&engine, JobSpec::new("ghost", KernelId(999), 1.0)),
            Err(PlanError::UnknownKernel { .. })
        ));
        assert!(matches!(
            s.submit(&engine, JobSpec::new("nan", kernels[0], f64::NAN)),
            Err(PlanError::Invalid(_))
        ));
        assert!(matches!(
            s.submit(&engine, JobSpec::new("neg", kernels[0], 1.0).with_deadline(-5.0)),
            Err(PlanError::Invalid(_))
        ));
        let st = s.stats();
        assert_eq!((st.submitted, st.rejected, st.admitted), (4, 4, 0));
        assert!(s.jobs().is_empty(), "rejected jobs leave no record");
        // A meetable deadline is admitted: admission is a proof about
        // physics, not a guess about load.
        let id =
            s.submit(&engine, JobSpec::new("ok", kernels[0], 1.0).with_deadline(1e9)).unwrap();
        assert_eq!(s.job(id).unwrap().state, JobState::Running);
    }

    #[test]
    fn capacity_backlog_drains_as_jobs_finish() {
        let (engine, devices, kernels) = fixture();
        let cfg = SchedulerConfig {
            replan_interval_us: 1e12,
            planner: PlannerConfig {
                device_cap: 1,
                devices: Some(vec![devices[0]]),
                ..PlannerConfig::default()
            },
            ..SchedulerConfig::default()
        };
        let mut s = SchedulerCore::new(cfg);
        let a = s.submit(&engine, JobSpec::new("first", kernels[0], 3.0)).unwrap();
        let b = s.submit(&engine, JobSpec::new("second", kernels[0], 2.0)).unwrap();
        assert_eq!(s.job(a).unwrap().state, JobState::Running);
        assert_eq!(s.job(b).unwrap().state, JobState::Queued, "cap-bound arrival waits");
        s.run_until(&engine, 1e9);
        assert_eq!(s.job(a).unwrap().state, JobState::Done);
        assert_eq!(s.job(b).unwrap().state, JobState::Done);
        let first_done = s.job(a).unwrap().finished_at_us.unwrap();
        let second_start = s.job(b).unwrap().started_at_us.unwrap();
        assert!(second_start >= first_done, "the slot frees before the backlog starts");
        assert_eq!(s.stats().completed, 2);
    }

    #[test]
    fn deadline_miss_while_queued_is_explicit() {
        let (engine, devices, kernels) = fixture();
        let cfg = SchedulerConfig {
            replan_interval_us: 1e12,
            planner: PlannerConfig {
                device_cap: 1,
                devices: Some(vec![devices[0]]),
                ..PlannerConfig::default()
            },
            ..SchedulerConfig::default()
        };
        let mut s = SchedulerCore::new(cfg);
        let hog = s.submit(&engine, JobSpec::new("hog", kernels[0], 1e9)).unwrap();
        let late =
            s.submit(&engine, JobSpec::new("late", kernels[0], 1.0).with_deadline(1e5)).unwrap();
        assert_eq!(s.job(late).unwrap().state, JobState::Queued);
        s.run_until(&engine, 2e5);
        assert_eq!(s.job(hog).unwrap().state, JobState::Running);
        let r = s.job(late).unwrap();
        assert_eq!(r.state, JobState::Missed);
        assert_eq!(r.finished_at_us, Some(1e5));
        assert!(r.cause.as_deref().is_some_and(|c| c.contains("while queued")), "{:?}", r.cause);
        assert_eq!(s.stats().missed, 1);
    }

    #[test]
    fn device_down_requeues_and_replans_elsewhere() {
        let (engine, devices, kernels) = fixture();
        let mut s = SchedulerCore::new(no_epoch());
        let id = s.submit(&engine, JobSpec::new("mover", kernels[0], 1e6)).unwrap();
        let first = s.job(id).unwrap().device.unwrap();
        assert_eq!(s.job(id).unwrap().state, JobState::Running);
        s.schedule(10.0, Event::DeviceDown(first));
        s.run_until(&engine, 20.0);
        let r = s.job(id).unwrap();
        assert_eq!(r.state, JobState::Running, "re-planned onto the surviving device");
        let second = r.device.unwrap();
        assert_ne!(second, first);
        assert!(devices.contains(&second));
        let (transitions, _) = s.drain_outbox();
        assert!(
            transitions.iter().any(|t| {
                t.from == Some(JobState::Running)
                    && t.to == JobState::Queued
                    && t.cause.as_deref().is_some_and(|c| c.contains("went down"))
            }),
            "displacement is a recorded back-edge"
        );
        let p = s.job(id).unwrap().predicted_us.unwrap();
        s.run_until(&engine, 20.0 + 2.0 * p);
        assert_eq!(s.job(id).unwrap().state, JobState::Done);
    }

    #[test]
    fn progress_observation_reschedules_the_predicted_completion() {
        let (engine, _, kernels) = fixture();
        let mut s = SchedulerCore::new(no_epoch());
        let id = s.submit(&engine, JobSpec::new("slowpoke", kernels[0], 8.0)).unwrap();
        let p = s.job(id).unwrap().predicted_us.unwrap();
        assert!(p > 0.0);
        // Halfway through the predicted runtime only 1% is done: the
        // observed rate implies a 50x longer job.
        s.schedule(0.5 * p, Event::JobProgress { job: id, fraction: 0.01 });
        s.run_until(&engine, 2.0 * p);
        let r = s.job(id).unwrap();
        assert_eq!(r.state, JobState::Running, "stale model completion must be dropped");
        let total = r.predicted_us.unwrap();
        assert!((total - 50.0 * p).abs() <= 1e-6 * total, "{total} vs {}", 50.0 * p);
        s.run_until(&engine, 60.0 * p);
        assert_eq!(s.job(id).unwrap().state, JobState::Done);
    }

    #[test]
    fn observed_completion_beats_the_model_prediction() {
        let (engine, _, kernels) = fixture();
        let mut s = SchedulerCore::new(no_epoch());
        let id = s.submit(&engine, JobSpec::new("early", kernels[1], 1e6)).unwrap();
        let predicted = s.job(id).unwrap().predicted_us.unwrap();
        s.schedule(5.0, Event::JobCompleted { job: id });
        s.run_until(&engine, 10.0);
        let r = s.job(id).unwrap();
        assert_eq!(r.state, JobState::Done);
        assert_eq!(r.finished_at_us, Some(5.0));
        assert!(r.cause.as_deref().is_some_and(|c| c.contains("reported")), "{:?}", r.cause);
        // The model's now-stale completion event must not double-count.
        s.run_until(&engine, 2.0 * predicted);
        assert_eq!(s.stats().completed, 1);
    }

    #[test]
    fn cancel_frees_the_slot_and_terminal_cancel_is_a_no_op() {
        let (engine, devices, kernels) = fixture();
        let cfg = SchedulerConfig {
            replan_interval_us: 1e12,
            planner: PlannerConfig {
                device_cap: 1,
                devices: Some(vec![devices[0]]),
                ..PlannerConfig::default()
            },
            ..SchedulerConfig::default()
        };
        let mut s = SchedulerCore::new(cfg);
        let a = s.submit(&engine, JobSpec::new("doomed", kernels[0], 1e9)).unwrap();
        let b = s.submit(&engine, JobSpec::new("waiting", kernels[0], 1.0)).unwrap();
        assert_eq!(s.job(b).unwrap().state, JobState::Queued);
        assert!(s.cancel(&engine, 424242).is_none(), "unknown id");
        let rec = s.cancel(&engine, a).unwrap();
        assert_eq!(rec.state, JobState::Cancelled);
        assert_eq!(s.job(b).unwrap().state, JobState::Running, "cancel freed the slot");
        let again = s.cancel(&engine, a).unwrap();
        assert_eq!(again.state, JobState::Cancelled);
        assert_eq!(s.stats().cancelled, 1, "terminal cancel does not re-count");
    }

    #[test]
    fn repair_does_strictly_less_candidate_work_than_a_full_resolve() {
        let (engine, _, kernels) = fixture();
        let cfg = SchedulerConfig {
            replan_interval_us: 100.0,
            planner: PlannerConfig { device_cap: 1, ..PlannerConfig::default() },
            ..SchedulerConfig::default()
        };
        let mut s = SchedulerCore::new(cfg);
        // a/b fill both devices (cap 1); c/d queue behind them. Repeat
        // kernels are cache hits: zero candidates for c and d.
        let arrivals = [
            ("a", kernels[0], 1e6),
            ("b", kernels[1], 1e6),
            ("c", kernels[0], 1.0),
            ("d", kernels[1], 1.0),
        ];
        let mut event_work = Vec::new();
        for (name, k, scale) in arrivals {
            let before = s.table_counters().0;
            s.submit(&engine, JobSpec::new(name, k, scale)).unwrap();
            event_work.push(s.table_counters().0 - before);
        }
        let pts = (2 * crate::planner::device_grid(&PowerModel::gtx980()).len()) as u64;
        assert_eq!(event_work, vec![pts, pts, 0, 0], "one kernel slab max per event");
        // Crossing the epoch re-solves the queued pair in full: two
        // distinct kernels over the two-device table.
        s.run_until(&engine, 150.0);
        let (_, solves) = s.drain_outbox();
        let full = solves.iter().find(|o| o.kind == SolveKind::Full).expect("epoch full solve");
        assert_eq!(full.trigger, "horizon_roll");
        assert_eq!(full.report.candidates_evaluated, 2 * pts, "K=2 kernels x {pts} grid points");
        for &w in &event_work {
            assert!(
                w < full.report.candidates_evaluated,
                "per-event repair work ({w}) must be strictly below a full re-solve ({})",
                full.report.candidates_evaluated
            );
        }
        let st = s.stats();
        assert_eq!(st.full_solves, 1);
        assert!(st.repairs >= 2);
    }
}
