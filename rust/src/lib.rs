//! Reproduction of "GPGPU Performance Estimation with Core and Memory
//! Frequency Scaling" (Wang & Chu, 2017).
//!
//! Architecture (DESIGN.md):
//! * [`sim`] — `gpusim`, the dual-clock GPU timing simulator (ground truth)
//! * [`kernels`] — the paper's Table VI workloads as trace generators
//! * [`microbench`] — §IV hardware-parameter extraction on the simulator
//! * [`profiler`] — one-shot baseline counter collection (Nsight stand-in)
//! * [`model`] — the analytical model, Eqs. (2)–(21), scalar reference
//! * [`baselines`] — const-latency / linear-freq / MWP-CWP-lite ablations
//! * [`runtime`] — executor for the AOT JAX/Pallas artifacts
//! * [`engine`] — the unified prediction engine: pluggable backends
//!   (native scalar / scoped-thread batch / sharded PJRT service),
//!   sharded quantized grid cache, and the facade every consumer uses
//! * [`coordinator`] — sweep orchestration and validation
//! * [`registry`] — device registry + kernel catalog: the stable
//!   `(DeviceId, KernelId, FreqPoint)` handles behind the typed v2 API
//! * [`obs`] — trace-first observability: per-request span capture
//!   into a slow-trace ring and rolling per-(device, kernel) model
//!   accuracy windows (live MAPE)
//! * [`dvfs`] — power model + energy-conservation advisor (paper §VII)
//! * [`planner`] — fleet-scale DVFS planning: assign a batch of
//!   deadline-tagged jobs to devices and (core, mem) points,
//!   minimizing total energy (greedy + relocation/swap local search)
//! * [`scheduler`] — streaming job lifecycle on top of the planner:
//!   event-driven rolling-horizon re-planning with incremental repair,
//!   provable deadline admission control, and the `/v2/jobs` state
//!   machine (Queued → Scheduled → Running → Done/Missed/Cancelled)
//! * [`service`] — the standing HTTP prediction service (`gpufreq
//!   serve`): std-only HTTP/1.1 worker pool with bounded-queue
//!   admission control, DVFS-advisor routes and `/metrics`
//! * [`config`] — TOML-subset config system (Table V)
//! * [`report`] — table/figure emitters for every paper artifact
pub mod baselines;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dvfs;
pub mod engine;
pub mod kernels;
pub mod microbench;
pub mod model;
pub mod obs;
pub mod planner;
pub mod profiler;
pub mod registry;
pub mod report;
pub mod runtime;
pub mod scheduler;
pub mod service;
pub mod sim;
pub mod util;
