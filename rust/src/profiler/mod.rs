//! Profiler: the Nsight stand-in (DESIGN.md §2).
//!
//! Runs a kernel **once** at the baseline frequency and extracts the
//! performance counters the model needs (paper Table IV: `l2_hr`,
//! `gld_trans`, `comp_inst`→`avr_inst`, `#Aw`, `#Asm`) plus the
//! launch-derived and source-derived quantities (`#B`, `#Wpb`,
//! `o_itrs`, `i_itrs`). Exactly like the paper's methodology, this is a
//! one-time collection: every other frequency point is *predicted*.

use crate::model::KernelCounters;
use crate::sim::engine::simulate;
use crate::sim::isa::Kernel;
use crate::sim::stats::InstMix;
use crate::sim::{Clocks, GpuSpec};

/// The paper's baseline frequency (§VI-A): 700 MHz for both domains.
pub fn baseline_clocks() -> Clocks {
    Clocks::new(700.0, 700.0)
}

/// Everything the one-time profiling pass produces for one kernel.
#[derive(Debug, Clone)]
pub struct Profile {
    pub kernel: String,
    pub counters: KernelCounters,
    /// Dynamic instruction mix (Fig. 12).
    pub mix: InstMix,
    /// Ground-truth execution time at the baseline, microseconds.
    pub baseline_time_us: f64,
    /// Baseline clocks the counters were collected at.
    pub baseline: Clocks,
    /// Raw transaction totals, for reports.
    pub gl_txns: u64,
    pub dram_txns: u64,
    pub smem_txns: u64,
}

/// Profile `kernel` on `spec` at `baseline`.
pub fn profile_at(spec: &GpuSpec, kernel: &Kernel, baseline: Clocks) -> Profile {
    let r = simulate(spec, baseline, kernel);
    let warps = kernel.launch.total_warps() as f64;
    let o_itrs = kernel.program.o_itrs.max(1) as f64;
    let gl = r.stats.gl_txns.max(1) as f64;
    let counters = KernelCounters {
        l2_hr: r.stats.l2_hit_rate(),
        gld_trans: gl / (warps * o_itrs),
        avr_inst: r.stats.mix.compute as f64 / gl,
        n_blocks: kernel.launch.blocks as f64,
        wpb: kernel.launch.warps_per_block() as f64,
        aw: r.active_warps as f64,
        n_sm: r.stats.active_sms.max(1) as f64,
        o_itrs,
        i_itrs: kernel.program.smem_ops_per_iter() as f64,
        uses_smem: kernel.program.uses_smem(),
        smem_conflict: if r.stats.smem_accesses > 0 {
            r.stats.smem_txns as f64 / r.stats.smem_accesses as f64
        } else {
            1.0
        },
        gld_body: kernel.program.gld_body_per_iter() as f64,
        gld_edge: kernel.program.gld_edge() as f64,
        mem_ops: kernel.program.mem_ops_per_iter() as f64,
        l1_hr: r.stats.l1_hit_rate(),
    };
    Profile {
        kernel: kernel.name.clone(),
        counters,
        mix: r.stats.mix,
        baseline_time_us: r.stats.elapsed_ns / 1e3,
        baseline,
        gl_txns: r.stats.gl_txns,
        dram_txns: r.stats.dram_txns,
        smem_txns: r.stats.smem_txns,
    }
}

/// Profile at the paper's 700/700 baseline.
pub fn profile(spec: &GpuSpec, kernel: &Kernel) -> Profile {
    profile_at(spec, kernel, baseline_clocks())
}

/// Instruction-mix fractions for the Fig. 12 breakdown.
#[derive(Debug, Clone, Copy)]
pub struct MixBreakdown {
    pub compute: f64,
    pub global: f64,
    pub shared: f64,
    pub sync: f64,
}

impl Profile {
    pub fn mix_breakdown(&self) -> MixBreakdown {
        let t = self.mix.total().max(1) as f64;
        MixBreakdown {
            compute: self.mix.compute as f64 / t,
            global: (self.mix.global_ld + self.mix.global_st) as f64 / t,
            shared: self.mix.shared as f64 / t,
            sync: self.mix.sync as f64 / t,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels;

    #[test]
    fn baseline_is_700_700() {
        let b = baseline_clocks();
        assert_eq!(b.core_mhz, 700.0);
        assert_eq!(b.mem_mhz, 700.0);
    }

    #[test]
    fn profile_extracts_launch_shape() {
        let spec = GpuSpec::default();
        let k = kernels::vector_add();
        let p = profile(&spec, &k);
        assert_eq!(p.counters.n_blocks, 256.0);
        assert_eq!(p.counters.wpb, 8.0);
        assert_eq!(p.counters.o_itrs, 8.0);
        assert!(!p.counters.uses_smem);
        assert!(p.baseline_time_us > 0.0);
    }

    #[test]
    fn va_counters_match_program() {
        let spec = GpuSpec::default();
        let p = profile(&spec, &kernels::vector_add());
        // 12 transactions per warp per iteration (4+4 loads + 4 stores).
        assert!((p.counters.gld_trans - 12.0).abs() < 1e-9);
        // 4 compute instructions per 12 transactions.
        assert!((p.counters.avr_inst - 4.0 / 12.0).abs() < 1e-9);
        assert!(p.counters.l2_hr < 0.05);
    }

    #[test]
    fn smem_kernel_flags() {
        let spec = GpuSpec::default();
        let p = profile(&spec, &kernels::matrix_mul_shared());
        assert!(p.counters.uses_smem);
        assert_eq!(p.counters.i_itrs, 32.0); // 16 x 2 smem loads per tile
    }

    #[test]
    fn occupancy_counters() {
        let spec = GpuSpec::default();
        let p = profile(&spec, &kernels::vector_add());
        assert_eq!(p.counters.aw, 64.0); // 8 wpb * 8 blocks/SM
        assert_eq!(p.counters.n_sm, 16.0);
    }

    #[test]
    fn mix_breakdown_sums_to_one() {
        let spec = GpuSpec::default();
        for k in kernels::all() {
            let p = profile(&spec, &k);
            let m = p.mix_breakdown();
            let sum = m.compute + m.global + m.shared + m.sync;
            assert!((sum - 1.0).abs() < 1e-9, "{}: {}", k.name, sum);
        }
    }

    #[test]
    fn profiling_is_one_shot_and_deterministic() {
        let spec = GpuSpec::default();
        let a = profile(&spec, &kernels::scan());
        let b = profile(&spec, &kernels::scan());
        assert_eq!(a.counters.l2_hr, b.counters.l2_hr);
        assert_eq!(a.baseline_time_us, b.baseline_time_us);
    }
}
