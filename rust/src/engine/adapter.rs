//! Bridges between the legacy `baselines::Predictor` trait and the
//! engine's [`Backend`] abstraction, in both directions:
//!
//! * [`PredictorBackend`] — runs any `Predictor` (const-latency,
//!   linear-freq, MWP/CWP-lite, L1-extended, …) behind the facade, so
//!   the ablation bench and report emitters get caching and batching
//!   for free without rewriting the baselines.
//! * [`EnginePredictor`] — exposes an [`Engine`] wherever a
//!   `&dyn Predictor` is still accepted (`dvfs::advise`,
//!   `validate_with`), so legacy call sites can consume engine-backed
//!   predictions during the migration.

use anyhow::Result;

use crate::baselines::Predictor;

use super::{Backend, Engine, Estimate, Request};

/// `Predictor` → `Backend` adapter. The regime is `None`: baselines are
/// opaque time functions and cannot attribute a pipeline case.
pub struct PredictorBackend {
    inner: Box<dyn Predictor>,
}

impl PredictorBackend {
    pub fn new(inner: Box<dyn Predictor>) -> Self {
        PredictorBackend { inner }
    }
}

impl Backend for PredictorBackend {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn predict_batch(&self, reqs: &[Request]) -> Result<Vec<Estimate>> {
        Ok(reqs
            .iter()
            .map(|r| {
                let time_us = self.inner.predict_us(&r.counters, r.core_mhz, r.mem_mhz);
                // Back out the cycle quantities the facade reports
                // (Eq. (6) round count; exact for any time prediction).
                let t_exec_cycles = time_us * r.core_mhz;
                let rounds = (r.counters.wpb * r.counters.n_blocks
                    / (r.counters.aw * r.counters.n_sm))
                    .max(1.0);
                Estimate {
                    t_active: t_exec_cycles / rounds,
                    t_exec_cycles,
                    time_us,
                    regime: None,
                }
            })
            .collect())
    }
}

/// `Engine` → `Predictor` adapter for legacy call sites.
pub struct EnginePredictor {
    engine: Engine,
    label: &'static str,
}

impl EnginePredictor {
    pub fn new(engine: Engine, label: &'static str) -> Self {
        EnginePredictor { engine, label }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }
}

impl Predictor for EnginePredictor {
    fn name(&self) -> &'static str {
        self.label
    }

    fn predict_us(&self, c: &crate::model::KernelCounters, core_mhz: f64, mem_mhz: f64) -> f64 {
        self.engine
            .predict_one(c, core_mhz, mem_mhz)
            .expect("engine backend failed")
            .time_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{ConstLatency, PaperModel};
    use crate::model::{HwParams, KernelCounters};

    fn counters() -> KernelCounters {
        KernelCounters {
            l2_hr: 0.2,
            gld_trans: 4.0,
            avr_inst: 2.0,
            n_blocks: 128.0,
            wpb: 8.0,
            aw: 64.0,
            n_sm: 16.0,
            o_itrs: 8.0,
            i_itrs: 0.0,
            uses_smem: false,
            smem_conflict: 1.0,
            gld_body: 4.0,
            gld_edge: 0.0,
            mem_ops: 1.0,
            l1_hr: 0.0,
        }
    }

    #[test]
    fn predictor_backend_matches_direct_calls() {
        let hw = HwParams::paper_defaults();
        let cl = ConstLatency { hw, baseline_core_mhz: 700.0, baseline_mem_mhz: 700.0 };
        let want = cl.predict_us(&counters(), 500.0, 900.0);
        let backend = PredictorBackend::new(Box::new(ConstLatency {
            hw,
            baseline_core_mhz: 700.0,
            baseline_mem_mhz: 700.0,
        }));
        let got = backend
            .predict_batch(&[Request { counters: counters(), core_mhz: 500.0, mem_mhz: 900.0 }])
            .unwrap();
        assert_eq!(got[0].time_us.to_bits(), want.to_bits());
        assert_eq!(got[0].regime, None);
        assert_eq!(backend.name(), "const-latency");
        // Cycle back-out is consistent: time_us * cf == t_exec_cycles.
        assert!((got[0].t_exec_cycles - want * 500.0).abs() < 1e-9);
    }

    #[test]
    fn engine_predictor_round_trips_the_paper_model() {
        let hw = HwParams::paper_defaults();
        let engine = Engine::native(hw);
        let p = EnginePredictor::new(engine, "engine-native");
        let want = PaperModel { hw }.predict_us(&counters(), 800.0, 600.0);
        let got = p.predict_us(&counters(), 800.0, 600.0);
        assert_eq!(got.to_bits(), want.to_bits());
        assert_eq!(p.name(), "engine-native");
    }
}
