//! The unified prediction engine (DESIGN.md §8): **one entry point for
//! every prediction in the system**.
//!
//! Before this layer existed each consumer hand-wired its own path —
//! the CLI called `model::predict` directly, the DVFS advisor looped a
//! `baselines::Predictor`, the sweep validator re-simulated, and the
//! batched PJRT service lived off on its own in `coordinator/batcher`.
//! Three disjoint APIs, no shared caching, no shared concurrency. The
//! engine collapses them into one facade in front of pluggable
//! backends:
//!
//! ```text
//!   cli / dvfs / coordinator::{sweep,validate} / report / baselines
//!                         │
//!                   Engine facade
//!        predict_one · predict_grid · predict_stream
//!                         │
//!            sharded quantized grid cache (cache.rs)
//!                         │
//!        ┌────────────────┼──────────────────┐
//!   NativeScalar     NativeBatch         Pjrt (N workers,
//!  (model::predict)  (scoped threads)    sharded queues)
//!                                 └ PredictorBackend (any baseline)
//! ```
//!
//! * [`Backend`] — the execution strategy trait ([`NativeScalar`],
//!   [`NativeBatch`], [`pjrt::PjrtBackend`], [`adapter::PredictorBackend`]).
//! * [`cache::GridCache`] — sharded memoization keyed on the f32-quantized
//!   (counters, hw, core MHz, mem MHz) tuple; repeat advisor/sweep
//!   queries on the same grid never recompute.
//! * [`Engine`] — the facade: single-point, whole-grid and streaming
//!   prediction over any backend, cache-transparent.
//!
//! # Example
//!
//! ```
//! use gpufreq::engine::Engine;
//! use gpufreq::model::{HwParams, KernelCounters};
//!
//! let engine = Engine::native(HwParams::paper_defaults());
//! # let counters = KernelCounters {
//! #     l2_hr: 0.1, gld_trans: 6.0, avr_inst: 1.5, n_blocks: 128.0,
//! #     wpb: 8.0, aw: 64.0, n_sm: 16.0, o_itrs: 8.0, i_itrs: 0.0,
//! #     uses_smem: false, smem_conflict: 1.0, gld_body: 6.0,
//! #     gld_edge: 0.0, mem_ops: 2.0, l1_hr: 0.0,
//! # };
//! // One profiled kernel over two frequency points, one batched call;
//! // repeats on the same grid are served from the shared cache.
//! let grid = engine.predict_grid(&counters, &[(400.0, 1000.0), (1000.0, 400.0)]).unwrap();
//! assert_eq!(grid.len(), 2);
//! assert!(grid.iter().all(|e| e.time_us > 0.0));
//! ```

pub mod adapter;
pub mod backend;
pub mod cache;
pub mod pjrt;

use std::sync::mpsc::{self, Receiver};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

pub use adapter::{EnginePredictor, PredictorBackend};
pub use backend::{
    Backend, ComputeCounters, ComputeStats, Estimate, NativeBatch, NativeScalar, Request,
};
pub use cache::{CacheKey, CacheStats, GridCache, ANONYMOUS_DEVICE};
pub use pjrt::{BatchPrediction, BatchServer, PjrtBackend, ServerStats};

use crate::baselines::Predictor;
use crate::model::{HwParams, KernelCounters};
use crate::registry::{DeviceId, DeviceRecord, DeviceRegistry, FreqPoint, KernelCatalog, KernelId};
use crate::util::fxhash::FxHashMap;

/// One streaming job: predict a whole frequency grid for one profiled
/// kernel. `id` is echoed in the [`StreamReply`] so out-of-order
/// completions stay attributable.
#[derive(Debug, Clone)]
pub struct StreamJob {
    pub id: u64,
    pub counters: KernelCounters,
    pub pairs: Vec<(f64, f64)>,
}

/// Completion of one [`StreamJob`]. The error is stringly-typed because
/// replies cross a channel.
#[derive(Debug)]
pub struct StreamReply {
    pub id: u64,
    pub result: Result<Vec<Estimate>, String>,
}

/// How the engine reconstructs a backend for a *different* device than
/// the one it was built for (the handle path, DESIGN.md §10). Native
/// strategies rebuild per device from the device's measured `HwParams`;
/// opaque backends (PJRT service, boxed predictors, custom) are bound
/// to one parameter set, so other devices fall back to the scalar
/// native model — bit-identical to what the raw-struct path would
/// produce for that device.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum BackendKind {
    Scalar,
    Batch(usize),
    Opaque,
}

impl BackendKind {
    fn build(self, hw: HwParams) -> Arc<dyn Backend> {
        match self {
            BackendKind::Scalar | BackendKind::Opaque => Arc::new(NativeScalar::new(hw)),
            BackendKind::Batch(workers) => Arc::new(NativeBatch::new(hw, workers)),
        }
    }
}

/// Builder for [`Engine`] (backend choice, cache policy).
pub struct EngineBuilder {
    hw: HwParams,
    backend: Option<Arc<dyn Backend>>,
    kind: BackendKind,
    cache: bool,
    cache_shards: usize,
    cache_shard_capacity: usize,
}

impl EngineBuilder {
    pub fn new(hw: HwParams) -> Self {
        EngineBuilder {
            hw,
            backend: None,
            kind: BackendKind::Scalar,
            cache: true,
            cache_shards: cache::DEFAULT_SHARDS,
            cache_shard_capacity: cache::DEFAULT_SHARD_CAPACITY,
        }
    }

    /// Use the scalar native backend (default).
    pub fn scalar(mut self) -> Self {
        self.backend = Some(Arc::new(NativeScalar::new(self.hw)) as Arc<dyn Backend>);
        self.kind = BackendKind::Scalar;
        self
    }

    /// Use the scoped-thread chunked native backend.
    pub fn batch(mut self, workers: usize) -> Self {
        self.backend = Some(Arc::new(NativeBatch::new(self.hw, workers)) as Arc<dyn Backend>);
        self.kind = BackendKind::Batch(workers);
        self
    }

    /// Use the sharded PJRT batching service.
    pub fn pjrt(mut self, server: BatchServer) -> Self {
        self.backend = Some(Arc::new(PjrtBackend::new(server)) as Arc<dyn Backend>);
        self.kind = BackendKind::Opaque;
        self
    }

    /// Use any baseline `Predictor` through the adapter.
    pub fn predictor(mut self, p: Box<dyn Predictor>) -> Self {
        self.backend = Some(Arc::new(PredictorBackend::new(p)) as Arc<dyn Backend>);
        self.kind = BackendKind::Opaque;
        self
    }

    /// Use a custom backend.
    pub fn backend(mut self, b: Arc<dyn Backend>) -> Self {
        self.backend = Some(b);
        self.kind = BackendKind::Opaque;
        self
    }

    /// Disable the grid cache (always recompute).
    pub fn without_cache(mut self) -> Self {
        self.cache = false;
        self
    }

    /// Override cache geometry.
    pub fn cache_geometry(mut self, shards: usize, shard_capacity: usize) -> Self {
        self.cache_shards = shards;
        self.cache_shard_capacity = shard_capacity;
        self
    }

    pub fn build(self) -> Engine {
        Engine {
            backend: self
                .backend
                .unwrap_or_else(|| Arc::new(NativeScalar::new(self.hw)) as Arc<dyn Backend>),
            kind: self.kind,
            cache: if self.cache {
                Some(Arc::new(GridCache::new(self.cache_shards, self.cache_shard_capacity)))
            } else {
                None
            },
            hw: self.hw,
            device_key: ANONYMOUS_DEVICE,
            handles: None,
            compute: Arc::new(ComputeCounters::default()),
        }
    }
}

/// Handle-resolution state (DESIGN.md §10): the registry/catalog this
/// engine answers `(DeviceId, KernelId, FreqPoint)` calls against, plus
/// lazily-built per-device backends. Shared by engine clones.
struct Handles {
    registry: Arc<DeviceRegistry>,
    catalog: Arc<KernelCatalog>,
    /// The device the engine's primary backend was built for; its
    /// handle calls reuse that backend (PJRT batching included).
    primary: DeviceId,
    /// Lazily-built backends for every other device.
    per_device: Mutex<FxHashMap<u64, Arc<dyn Backend>>>,
}

/// The facade. Cheap to clone (`Arc` internals); clones share the
/// backend and the cache, so a cloned engine keeps the warm state.
#[derive(Clone)]
pub struct Engine {
    backend: Arc<dyn Backend>,
    kind: BackendKind,
    cache: Option<Arc<GridCache>>,
    hw: HwParams,
    /// Device-identity word raw-struct lookups are cached under:
    /// [`ANONYMOUS_DEVICE`] for a free-standing engine, the primary
    /// `DeviceId` once handles are attached (so the v1 shim and the v2
    /// handle path share warm entries on the default device).
    device_key: u64,
    handles: Option<Arc<Handles>>,
    /// Compute-span attribution counters (DESIGN.md §13), shared by
    /// clones like the cache.
    compute: Arc<ComputeCounters>,
}

impl Engine {
    pub fn builder(hw: HwParams) -> EngineBuilder {
        EngineBuilder::new(hw)
    }

    /// Scalar native backend with the default cache.
    pub fn native(hw: HwParams) -> Engine {
        Self::builder(hw).scalar().build()
    }

    /// Scoped-thread native backend with the default cache.
    pub fn native_batch(hw: HwParams, workers: usize) -> Engine {
        Self::builder(hw).batch(workers).build()
    }

    /// PJRT service backend (emulated executor, `workers` drain workers)
    /// with the default cache.
    pub fn pjrt_emulated(hw: HwParams, workers: usize) -> Result<Engine> {
        let (server, _handles) =
            BatchServer::start_emulated(hw.to_f32(), Duration::from_millis(1), workers)?;
        Ok(Self::builder(hw).pjrt(server).build())
    }

    /// Wrap a baseline predictor behind the facade (adapter + cache).
    pub fn from_predictor(hw: HwParams, p: Box<dyn Predictor>) -> Engine {
        Self::builder(hw).predictor(p).build()
    }

    /// Attach a device registry + kernel catalog, turning on the
    /// handle-based API (DESIGN.md §10). `primary` names the device the
    /// engine's backend was built for — it must already be registered,
    /// its measured parameters must match the engine's, and its handle
    /// calls reuse the primary backend (other devices get lazily-built
    /// native backends per the configured strategy). The raw-struct path is
    /// re-keyed under `primary`, so v1-shim traffic and v2 handle
    /// traffic on the default device share warm cache entries.
    pub fn with_handles(
        mut self,
        registry: Arc<DeviceRegistry>,
        catalog: Arc<KernelCatalog>,
        primary: DeviceId,
    ) -> Result<Engine> {
        let Some(record) = registry.get(primary) else {
            bail!("primary device {primary} is not in the registry");
        };
        if record.hw != self.hw {
            bail!(
                "primary device {primary} ({}) was registered with different hardware \
                 parameters than this engine was built for",
                record.name
            );
        }
        let mut per_device = FxHashMap::default();
        per_device.insert(primary.0, Arc::clone(&self.backend));
        self.device_key = primary.0;
        self.handles = Some(Arc::new(Handles {
            registry,
            catalog,
            primary,
            per_device: Mutex::new(per_device),
        }));
        Ok(self)
    }

    /// Whether the handle-based API is available.
    pub fn has_handles(&self) -> bool {
        self.handles.is_some()
    }

    pub fn registry(&self) -> Option<&Arc<DeviceRegistry>> {
        self.handles.as_ref().map(|h| &h.registry)
    }

    pub fn catalog(&self) -> Option<&Arc<KernelCatalog>> {
        self.handles.as_ref().map(|h| &h.catalog)
    }

    /// The device the primary backend serves (`None` before
    /// [`Engine::with_handles`]).
    pub fn primary_device(&self) -> Option<DeviceId> {
        self.handles.as_ref().map(|h| h.primary)
    }

    fn handles(&self) -> Result<&Handles> {
        match &self.handles {
            Some(h) => Ok(h.as_ref()),
            None => bail!("engine has no registry attached (Engine::with_handles)"),
        }
    }

    /// Resolve a device handle to its full record.
    pub fn device_record(&self, device: DeviceId) -> Result<DeviceRecord> {
        let h = self.handles()?;
        match h.registry.get(device) {
            Some(r) => Ok(r),
            None => bail!("unknown device {device}"),
        }
    }

    /// Resolve a kernel handle to its baseline-profiled counters.
    pub fn kernel_counters(&self, kernel: KernelId) -> Result<KernelCounters> {
        let h = self.handles()?;
        match h.catalog.get(kernel) {
            Some(e) => Ok(e.counters),
            None => bail!("unknown kernel {kernel}"),
        }
    }

    /// The backend serving `device`: the primary backend for the
    /// primary device, otherwise a lazily-built (and memoized) native
    /// backend around the device's measured parameters.
    fn backend_for(&self, record: &DeviceRecord) -> Result<Arc<dyn Backend>> {
        let h = self.handles()?;
        let mut g = h.per_device.lock().expect("per-device backends poisoned");
        Ok(Arc::clone(
            g.entry(record.id.0).or_insert_with(|| self.kind.build(record.hw)),
        ))
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn hw(&self) -> &HwParams {
        &self.hw
    }

    /// Whether this engine memoizes grids (false after `without_cache`).
    pub fn has_cache(&self) -> bool {
        self.cache.is_some()
    }

    /// Cache counters. When the cache is disabled via
    /// [`EngineBuilder::without_cache`] this returns an **all-zero**
    /// `CacheStats` rather than an `Option`: the serving layer's
    /// `/metrics` exposition must emit the `service_cache_*` series
    /// unconditionally (a scraper that sees the line disappear when an
    /// operator flips `--no-cache` reads it as a broken exporter, not a
    /// configuration change). Zero hits / zero misses is also literally
    /// true for a disabled cache. Use [`Engine::has_cache`] to
    /// distinguish "disabled" from "enabled but untouched".
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.as_ref().map(|c| c.stats()).unwrap_or_default()
    }

    /// Cumulative compute-side counters (SoA slab calls issued, points
    /// covered). The serving layer snapshots around a handler call to
    /// attribute slab work to that request's compute span.
    pub fn compute_stats(&self) -> ComputeStats {
        self.compute.snapshot()
    }

    /// Predict one (kernel, frequency-pair) sample.
    pub fn predict_one(&self, c: &KernelCounters, core_mhz: f64, mem_mhz: f64) -> Result<Estimate> {
        let mut v = self.predict_grid(c, &[(core_mhz, mem_mhz)])?;
        Ok(v.remove(0))
    }

    /// Handle path, single point: predict `kernel` on `device` at one
    /// frequency point (DESIGN.md §10).
    pub fn predict_handle(
        &self,
        device: DeviceId,
        kernel: KernelId,
        point: FreqPoint,
    ) -> Result<Estimate> {
        let mut v = self.predict_tuples(&[(device, kernel, point)])?;
        Ok(v.remove(0))
    }

    /// Handle path, one kernel over many frequency points — the v2
    /// grid/advise shape and the planner's candidate-table unit. This
    /// is the lean slab path: handles resolve once, cache hits are
    /// served per point, and all misses go to the device's backend as a
    /// single `model::soa` slab call (no per-point struct walks).
    pub fn predict_points(
        &self,
        device: DeviceId,
        kernel: KernelId,
        points: &[FreqPoint],
    ) -> Result<Vec<Estimate>> {
        if points.is_empty() {
            return Ok(Vec::new());
        }
        let record = self.device_record(device)?;
        let counters = self.kernel_counters(kernel)?;
        for p in points {
            if !p.is_valid() {
                bail!(
                    "invalid frequency point ({}, {}) MHz: frequencies must be positive \
                     and finite",
                    p.core_mhz,
                    p.mem_mhz
                );
            }
        }
        let backend = self.backend_for(&record)?;
        let Some(cache) = &self.cache else {
            let core: Vec<f64> = points.iter().map(|p| p.core_mhz).collect();
            let mem: Vec<f64> = points.iter().map(|p| p.mem_mhz).collect();
            self.compute.note_slab(points.len());
            return backend.predict_points(&counters, &core, &mem);
        };
        let mut out: Vec<Option<Estimate>> = Vec::with_capacity(points.len());
        let mut miss_idx: Vec<usize> = Vec::new();
        let mut miss_keys: Vec<CacheKey> = Vec::new();
        let mut miss_core: Vec<f64> = Vec::new();
        let mut miss_mem: Vec<f64> = Vec::new();
        for (i, p) in points.iter().enumerate() {
            let key =
                CacheKey::for_device(device.0, &counters, &record.hw, p.core_mhz, p.mem_mhz);
            match cache.get(&key) {
                Some(e) => out.push(Some(e)),
                None => {
                    out.push(None);
                    miss_idx.push(i);
                    miss_keys.push(key);
                    miss_core.push(p.core_mhz);
                    miss_mem.push(p.mem_mhz);
                }
            }
        }
        if !miss_idx.is_empty() {
            self.compute.note_slab(miss_core.len());
            let fresh = backend.predict_points(&counters, &miss_core, &miss_mem)?;
            for ((i, key), est) in miss_idx.into_iter().zip(miss_keys).zip(fresh) {
                cache.insert(key, est);
                out[i] = Some(est);
            }
        }
        Ok(out.into_iter().map(|e| e.expect("all points filled")).collect())
    }

    /// Handle path, batch-first (the `/v2/predict` shape): arbitrary
    /// `(device, kernel, frequency)` tuples in one call, answered in
    /// order. Handles resolve up front (one failed lookup fails the
    /// whole batch before any prediction runs), identical tuples are
    /// deduplicated (one evaluation fans back out to every duplicate —
    /// even on cache-disabled engines), cache hits are served per-tuple
    /// under the device-identity key, and misses are grouped **per
    /// (device, kernel)** into SoA slab calls to that device's backend.
    pub fn predict_tuples(
        &self,
        tuples: &[(DeviceId, KernelId, FreqPoint)],
    ) -> Result<Vec<Estimate>> {
        use std::collections::hash_map::Entry;

        // Resolve every handle first; records/counters are memoized so
        // grid-shaped batches pay one registry lookup per handle.
        let mut records: FxHashMap<u64, DeviceRecord> = FxHashMap::default();
        let mut kernels: FxHashMap<u64, KernelCounters> = FxHashMap::default();
        for &(d, k, p) in tuples {
            if let Entry::Vacant(slot) = records.entry(d.0) {
                slot.insert(self.device_record(d)?);
            }
            if let Entry::Vacant(slot) = kernels.entry(k.0) {
                slot.insert(self.kernel_counters(k)?);
            }
            if !p.is_valid() {
                bail!(
                    "invalid frequency point ({}, {}) MHz: frequencies must be positive \
                     and finite",
                    p.core_mhz,
                    p.mem_mhz
                );
            }
        }

        // Misses grouped by (device, kernel): each group becomes one
        // slab evaluation, preserving intra-group order.
        struct Group {
            idx: Vec<usize>,
            keys: Vec<Option<CacheKey>>,
            core: Vec<f64>,
            mem: Vec<f64>,
        }

        let mut out: Vec<Option<Estimate>> = vec![None; tuples.len()];
        // Duplicate tuples (same device, kernel and exact frequency
        // bits) are answered from their first occurrence, so
        // pathological planner inputs never pay P× redundant calls.
        let mut first_seen: FxHashMap<(u64, u64, u64, u64), usize> = FxHashMap::default();
        let mut dups: Vec<(usize, usize)> = Vec::new();
        let mut groups: FxHashMap<(u64, u64), Group> = FxHashMap::default();
        for (i, &(d, k, p)) in tuples.iter().enumerate() {
            match first_seen.entry((d.0, k.0, p.core_mhz.to_bits(), p.mem_mhz.to_bits())) {
                Entry::Occupied(first) => {
                    dups.push((i, *first.get()));
                    continue;
                }
                Entry::Vacant(slot) => {
                    slot.insert(i);
                }
            }
            let counters = &kernels[&k.0];
            let hw = &records[&d.0].hw;
            let key = self
                .cache
                .as_ref()
                .map(|_| CacheKey::for_device(d.0, counters, hw, p.core_mhz, p.mem_mhz));
            if let (Some(cache), Some(key)) = (&self.cache, &key) {
                if let Some(e) = cache.get(key) {
                    out[i] = Some(e);
                    continue;
                }
            }
            let g = groups.entry((d.0, k.0)).or_insert_with(|| Group {
                idx: Vec::new(),
                keys: Vec::new(),
                core: Vec::new(),
                mem: Vec::new(),
            });
            g.idx.push(i);
            g.keys.push(key);
            g.core.push(p.core_mhz);
            g.mem.push(p.mem_mhz);
        }

        for ((device, kernel), g) in groups {
            let backend = self.backend_for(&records[&device])?;
            self.compute.note_slab(g.core.len());
            let fresh = backend.predict_points(&kernels[&kernel], &g.core, &g.mem)?;
            for ((i, key), est) in g.idx.into_iter().zip(g.keys).zip(fresh) {
                if let (Some(cache), Some(key)) = (&self.cache, key) {
                    cache.insert(key, est);
                }
                out[i] = Some(est);
            }
        }
        for (i, first) in dups {
            out[i] = out[first];
        }
        Ok(out.into_iter().map(|e| e.expect("all tuples filled")).collect())
    }

    /// Predict a whole frequency grid for one profile, serving repeats
    /// from the cache and batching only the misses to the backend.
    pub fn predict_grid(
        &self,
        c: &KernelCounters,
        pairs: &[(f64, f64)],
    ) -> Result<Vec<Estimate>> {
        let core: Vec<f64> = pairs.iter().map(|&(cf, _)| cf).collect();
        let mem: Vec<f64> = pairs.iter().map(|&(_, mf)| mf).collect();
        self.predict_slabs(c, &core, &mem)
    }

    /// [`Engine::predict_grid`] over pre-split frequency slabs
    /// (`core_mhz[i]`, `mem_mhz[i]`) — the sweep/candidate-table shape.
    /// Callers that already hold slabs (coordinator sweeps, bench
    /// harnesses) skip the pair-tuple round trip; misses reach the
    /// backend as one `model::soa` slab call.
    pub fn predict_slabs(
        &self,
        c: &KernelCounters,
        core_mhz: &[f64],
        mem_mhz: &[f64],
    ) -> Result<Vec<Estimate>> {
        assert_eq!(core_mhz.len(), mem_mhz.len());
        let Some(cache) = &self.cache else {
            self.compute.note_slab(core_mhz.len());
            return self.backend.predict_points(c, core_mhz, mem_mhz);
        };

        let mut out: Vec<Option<Estimate>> = Vec::with_capacity(core_mhz.len());
        let mut miss_idx: Vec<usize> = Vec::new();
        let mut miss_keys: Vec<CacheKey> = Vec::new();
        let mut miss_core: Vec<f64> = Vec::new();
        let mut miss_mem: Vec<f64> = Vec::new();
        for (i, (&cf, &mf)) in core_mhz.iter().zip(mem_mhz).enumerate() {
            let key = CacheKey::for_device(self.device_key, c, &self.hw, cf, mf);
            match cache.get(&key) {
                Some(e) => out.push(Some(e)),
                None => {
                    out.push(None);
                    miss_idx.push(i);
                    miss_keys.push(key);
                    miss_core.push(cf);
                    miss_mem.push(mf);
                }
            }
        }
        if !miss_idx.is_empty() {
            self.compute.note_slab(miss_core.len());
            let fresh = self.backend.predict_points(c, &miss_core, &miss_mem)?;
            for ((i, key), est) in miss_idx.into_iter().zip(miss_keys).zip(fresh) {
                cache.insert(key, est);
                out[i] = Some(est);
            }
        }
        Ok(out.into_iter().map(|e| e.expect("all pairs filled")).collect())
    }

    /// Streaming API: evaluate many grid jobs on a detached worker,
    /// delivering completions over a channel as they finish. The worker
    /// shares this engine's backend and cache, so streamed results warm
    /// the same cache the synchronous paths read.
    ///
    /// Jobs are evaluated in order on one worker — intra-job rows fan
    /// out to the backend's own parallelism (the PJRT service's N
    /// drain workers, `NativeBatch`'s scoped threads), and identical
    /// jobs dedupe through the cache deterministically. Callers that
    /// want cross-job concurrency clone the engine per stream (clones
    /// share the backend and the warm cache).
    pub fn predict_stream(&self, jobs: Vec<StreamJob>) -> Receiver<StreamReply> {
        let (tx, rx) = mpsc::channel();
        let engine = self.clone();
        std::thread::spawn(move || {
            for job in jobs {
                let result = engine
                    .predict_grid(&job.counters, &job.pairs)
                    .map_err(|e| format!("{e:#}"));
                if tx.send(StreamReply { id: job.id, result }).is_err() {
                    return; // receiver dropped; stop evaluating
                }
            }
        });
        rx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model;

    fn counters() -> KernelCounters {
        KernelCounters {
            l2_hr: 0.1,
            gld_trans: 6.0,
            avr_inst: 1.5,
            n_blocks: 128.0,
            wpb: 8.0,
            aw: 64.0,
            n_sm: 16.0,
            o_itrs: 8.0,
            i_itrs: 0.0,
            uses_smem: false,
            smem_conflict: 1.0,
            gld_body: 6.0,
            gld_edge: 0.0,
            mem_ops: 2.0,
            l1_hr: 0.0,
        }
    }

    fn grid() -> Vec<(f64, f64)> {
        crate::microbench::standard_grid()
    }

    #[test]
    fn facade_matches_scalar_model() {
        let hw = HwParams::paper_defaults();
        let engine = Engine::native(hw);
        let c = counters();
        for &(cf, mf) in &[(400.0, 1000.0), (700.0, 700.0), (1000.0, 400.0)] {
            let e = engine.predict_one(&c, cf, mf).unwrap();
            let want = model::predict(&c, &hw, cf, mf);
            assert_eq!(e.time_us.to_bits(), want.time_us.to_bits());
            assert_eq!(e.regime, Some(want.regime));
        }
    }

    #[test]
    fn warm_grid_is_bit_identical_and_counts_hits() {
        let hw = HwParams::paper_defaults();
        let engine = Engine::native(hw);
        let c = counters();
        let cold = engine.predict_grid(&c, &grid()).unwrap();
        let s0 = engine.cache_stats();
        assert_eq!(s0.misses, 49);
        assert_eq!(s0.hits, 0);
        let warm = engine.predict_grid(&c, &grid()).unwrap();
        let s1 = engine.cache_stats();
        assert!(s1.hits >= 49, "hits {}", s1.hits);
        for (a, b) in cold.iter().zip(&warm) {
            assert_eq!(a.time_us.to_bits(), b.time_us.to_bits());
            assert_eq!(a.t_active.to_bits(), b.t_active.to_bits());
            assert_eq!(a.t_exec_cycles.to_bits(), b.t_exec_cycles.to_bits());
            assert_eq!(a.regime, b.regime);
        }
    }

    #[test]
    fn without_cache_reports_zeroed_stats() {
        let hw = HwParams::paper_defaults();
        let engine = Engine::builder(hw).scalar().without_cache().build();
        let c = counters();
        engine.predict_grid(&c, &grid()).unwrap();
        // Disabled cache: stats are present (so `/metrics` always has
        // the series) but identically zero, and `has_cache` tells the
        // difference from an untouched live cache.
        assert!(!engine.has_cache());
        assert_eq!(engine.cache_stats(), CacheStats::default());
        let cached = Engine::native(hw);
        assert!(cached.has_cache());
        assert_eq!(cached.cache_stats(), CacheStats::default());
        cached.predict_grid(&c, &grid()).unwrap();
        assert_eq!(cached.cache_stats().misses, 49);
    }

    #[test]
    fn compute_stats_attribute_slab_work_and_skip_warm_hits() {
        let hw = HwParams::paper_defaults();
        let engine = Engine::native(hw);
        let c = counters();
        assert_eq!(engine.compute_stats(), ComputeStats::default());
        engine.predict_grid(&c, &grid()).unwrap();
        let cold = engine.compute_stats();
        assert_eq!(cold, ComputeStats { slab_calls: 1, points: 49 });
        // Warm repeat: all 49 points served from cache, no slab issued.
        engine.predict_grid(&c, &grid()).unwrap();
        assert_eq!(engine.compute_stats().since(cold), ComputeStats::default());
        // Clones share the counters like they share the cache.
        assert_eq!(engine.clone().compute_stats(), cold);
    }

    #[test]
    fn clones_share_the_warm_cache() {
        let hw = HwParams::paper_defaults();
        let engine = Engine::native(hw);
        let c = counters();
        engine.predict_grid(&c, &grid()).unwrap();
        let clone = engine.clone();
        clone.predict_grid(&c, &grid()).unwrap();
        assert!(clone.cache_stats().hits >= 49);
    }

    #[test]
    fn stream_replies_cover_all_jobs() {
        let hw = HwParams::paper_defaults();
        let engine = Engine::native(hw);
        let c = counters();
        let jobs: Vec<StreamJob> = (0..4)
            .map(|i| StreamJob { id: i, counters: c, pairs: grid() })
            .collect();
        let rx = engine.predict_stream(jobs);
        let mut seen = Vec::new();
        for reply in rx {
            let ests = reply.result.expect("native backend cannot fail");
            assert_eq!(ests.len(), 49);
            seen.push(reply.id);
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3]);
        // All four jobs share one profile: 49 misses, 3*49 hits.
        let s = engine.cache_stats();
        assert_eq!(s.misses, 49);
        assert_eq!(s.hits, 3 * 49);
    }

    fn handle_engine() -> (Engine, DeviceId, DeviceId, KernelId) {
        let hw = HwParams::paper_defaults();
        let registry = Arc::new(crate::registry::DeviceRegistry::new());
        let primary = registry.register("gtx980", hw, crate::dvfs::PowerModel::gtx980());
        // A second device whose parameters differ only BELOW f32
        // resolution: quantized cache words are identical, but the f64
        // model evaluates to different bits.
        let mut hw_b = hw;
        hw_b.dm_del += 1e-9;
        let other = registry.register("gtx980-b", hw_b, crate::dvfs::PowerModel::gtx980());
        let catalog = Arc::new(crate::registry::KernelCatalog::new());
        let kernel = catalog.register("VA", counters());
        let engine = Engine::native(hw).with_handles(registry, catalog, primary).unwrap();
        (engine, primary, other, kernel)
    }

    #[test]
    fn handle_path_matches_raw_struct_path_bit_for_bit() {
        let (engine, primary, _, kernel) = handle_engine();
        let c = counters();
        let points: Vec<FreqPoint> = grid().iter().map(|&p| p.into()).collect();
        let via_handles = engine.predict_points(primary, kernel, &points).unwrap();
        let raw = engine.predict_grid(&c, &grid()).unwrap();
        for (a, b) in via_handles.iter().zip(&raw) {
            assert_eq!(a.time_us.to_bits(), b.time_us.to_bits());
            assert_eq!(a.regime, b.regime);
        }
        // Both paths key on the primary device: the raw pass re-reads
        // the handle pass's 49 entries instead of recomputing.
        let s = engine.cache_stats();
        assert_eq!(s.misses, 49);
        assert_eq!(s.hits, 49);
    }

    #[test]
    fn two_devices_never_share_cache_entries() {
        // Regression for the device-identity cache key (DESIGN.md §10):
        // dev-2's parameters differ from dev-1's only below f32
        // resolution, so WITHOUT the identity word both devices would
        // quantize to the same key and the second lookup would be a
        // false hit returning dev-1's estimate.
        let (engine, primary, other, kernel) = handle_engine();
        let p = FreqPoint::new(700.0, 700.0);
        let a = engine.predict_handle(primary, kernel, p).unwrap();
        let b = engine.predict_handle(other, kernel, p).unwrap();
        assert_ne!(
            a.time_us.to_bits(),
            b.time_us.to_bits(),
            "sub-f32 parameter difference must still change the f64 prediction"
        );
        let s = engine.cache_stats();
        assert_eq!((s.hits, s.misses), (0, 2), "second device must miss, not falsely hit");
        // Repeats hit per device and stay distinct.
        let a2 = engine.predict_handle(primary, kernel, p).unwrap();
        let b2 = engine.predict_handle(other, kernel, p).unwrap();
        assert_eq!(a.time_us.to_bits(), a2.time_us.to_bits());
        assert_eq!(b.time_us.to_bits(), b2.time_us.to_bits());
        assert_eq!(engine.cache_stats().hits, 2);
    }

    #[test]
    fn mixed_device_batch_answers_in_order() {
        let (engine, primary, other, kernel) = handle_engine();
        let tuples: Vec<(DeviceId, KernelId, FreqPoint)> = grid()
            .iter()
            .enumerate()
            .map(|(i, &(cf, mf))| {
                let d = if i % 2 == 0 { primary } else { other };
                (d, kernel, FreqPoint::new(cf, mf))
            })
            .collect();
        let got = engine.predict_tuples(&tuples).unwrap();
        let c = counters();
        for (e, &(d, _, p)) in got.iter().zip(&tuples) {
            let mut hw = HwParams::paper_defaults();
            if d != primary {
                hw.dm_del += 1e-9;
            }
            let want = model::predict(&c, &hw, p.core_mhz, p.mem_mhz);
            assert_eq!(e.time_us.to_bits(), want.time_us.to_bits(), "{d} {p:?}");
        }
    }

    #[test]
    fn duplicate_tuples_evaluate_once_and_fan_out() {
        let (engine, primary, _, kernel) = handle_engine();
        let p = FreqPoint::new(700.0, 700.0);
        let tuples = vec![(primary, kernel, p); 5];
        let got = engine.predict_tuples(&tuples).unwrap();
        assert_eq!(got.len(), 5);
        assert!(got.windows(2).all(|w| w[0] == w[1]));
        // Dedupe happens before the cache: one miss, zero hits.
        let s = engine.cache_stats();
        assert_eq!((s.misses, s.hits), (1, 0), "duplicates must not even touch the cache");
        let want = model::predict(&counters(), engine.hw(), 700.0, 700.0);
        assert_eq!(got[0].time_us.to_bits(), want.time_us.to_bits());
    }

    #[test]
    fn dedupe_reaches_backend_once_even_without_cache() {
        use std::sync::atomic::{AtomicUsize, Ordering};

        struct Counting {
            inner: NativeScalar,
            points: Arc<AtomicUsize>,
        }
        impl Backend for Counting {
            fn name(&self) -> &'static str {
                "counting"
            }
            fn predict_batch(&self, reqs: &[Request]) -> Result<Vec<Estimate>> {
                self.points.fetch_add(reqs.len(), Ordering::SeqCst);
                self.inner.predict_batch(reqs)
            }
        }

        let hw = HwParams::paper_defaults();
        let registry = Arc::new(crate::registry::DeviceRegistry::new());
        let primary = registry.register("gtx980", hw, crate::dvfs::PowerModel::gtx980());
        let catalog = Arc::new(crate::registry::KernelCatalog::new());
        let kernel = catalog.register("VA", counters());
        let evaluated = Arc::new(AtomicUsize::new(0));
        let engine = Engine::builder(hw)
            .backend(Arc::new(Counting {
                inner: NativeScalar::new(hw),
                points: Arc::clone(&evaluated),
            }))
            .without_cache()
            .build()
            .with_handles(registry, catalog, primary)
            .unwrap();
        let p = FreqPoint::new(700.0, 700.0);
        let tuples = vec![(primary, kernel, p); 7];
        let got = engine.predict_tuples(&tuples).unwrap();
        assert_eq!(got.len(), 7);
        assert!(got.windows(2).all(|w| w[0] == w[1]));
        assert_eq!(evaluated.load(Ordering::SeqCst), 1, "7 identical tuples, 1 model call");
    }

    #[test]
    fn handle_errors_are_typed_and_early() {
        let (engine, primary, _, kernel) = handle_engine();
        let p = FreqPoint::new(700.0, 700.0);
        let err = engine
            .predict_tuples(&[(DeviceId(99), kernel, p)])
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown device dev-99"), "{err}");
        let err = engine
            .predict_tuples(&[(primary, KernelId(42), p)])
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown kernel krn-42"), "{err}");
        let err = engine
            .predict_tuples(&[(primary, kernel, FreqPoint::new(f64::NAN, 700.0))])
            .unwrap_err()
            .to_string();
        assert!(err.contains("invalid frequency point"), "{err}");
        // A failed resolve anywhere in the batch fails before any
        // prediction runs: nothing is cached.
        let _ = engine.predict_tuples(&[
            (primary, kernel, p),
            (DeviceId(99), kernel, p),
        ]);
        assert_eq!(engine.cache_stats().entries, 0);
        // An engine without handles reports that, not a lookup miss.
        let bare = Engine::native(HwParams::paper_defaults());
        assert!(!bare.has_handles());
        let err = bare.predict_handle(primary, kernel, p).unwrap_err().to_string();
        assert!(err.contains("no registry attached"), "{err}");
    }

    #[test]
    fn with_handles_rejects_mismatched_primary() {
        let hw = HwParams::paper_defaults();
        let registry = Arc::new(crate::registry::DeviceRegistry::new());
        let mut other_hw = hw;
        other_hw.l2_lat += 50.0;
        let wrong = registry.register("other", other_hw, crate::dvfs::PowerModel::gtx980());
        let catalog = Arc::new(crate::registry::KernelCatalog::new());
        let err = Engine::native(hw)
            .with_handles(Arc::clone(&registry), Arc::clone(&catalog), wrong)
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("different hardware parameters"), "{err}");
        let err = Engine::native(hw)
            .with_handles(registry, catalog, DeviceId(7))
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("not in the registry"), "{err}");
    }

    #[test]
    fn mixed_hit_miss_grid_assembles_in_order() {
        let hw = HwParams::paper_defaults();
        let engine = Engine::native(hw);
        let c = counters();
        let small: Vec<(f64, f64)> = vec![(400.0, 400.0), (700.0, 700.0)];
        engine.predict_grid(&c, &small).unwrap();
        // Superset grid: 2 hits + 47 misses, order must match scalar.
        let full = engine.predict_grid(&c, &grid()).unwrap();
        for (e, &(cf, mf)) in full.iter().zip(&grid()) {
            let want = model::predict(&c, &hw, cf, mf);
            assert_eq!(e.time_us.to_bits(), want.time_us.to_bits(), "({cf},{mf})");
        }
    }
}
