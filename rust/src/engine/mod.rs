//! The unified prediction engine (DESIGN.md §8): **one entry point for
//! every prediction in the system**.
//!
//! Before this layer existed each consumer hand-wired its own path —
//! the CLI called `model::predict` directly, the DVFS advisor looped a
//! `baselines::Predictor`, the sweep validator re-simulated, and the
//! batched PJRT service lived off on its own in `coordinator/batcher`.
//! Three disjoint APIs, no shared caching, no shared concurrency. The
//! engine collapses them into one facade in front of pluggable
//! backends:
//!
//! ```text
//!   cli / dvfs / coordinator::{sweep,validate} / report / baselines
//!                         │
//!                   Engine facade
//!        predict_one · predict_grid · predict_stream
//!                         │
//!            sharded quantized grid cache (cache.rs)
//!                         │
//!        ┌────────────────┼──────────────────┐
//!   NativeScalar     NativeBatch         Pjrt (N workers,
//!  (model::predict)  (scoped threads)    sharded queues)
//!                                 └ PredictorBackend (any baseline)
//! ```
//!
//! * [`Backend`] — the execution strategy trait ([`NativeScalar`],
//!   [`NativeBatch`], [`pjrt::PjrtBackend`], [`adapter::PredictorBackend`]).
//! * [`cache::GridCache`] — sharded memoization keyed on the f32-quantized
//!   (counters, hw, core MHz, mem MHz) tuple; repeat advisor/sweep
//!   queries on the same grid never recompute.
//! * [`Engine`] — the facade: single-point, whole-grid and streaming
//!   prediction over any backend, cache-transparent.

pub mod adapter;
pub mod backend;
pub mod cache;
pub mod pjrt;

use std::sync::mpsc::{self, Receiver};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

pub use adapter::{EnginePredictor, PredictorBackend};
pub use backend::{Backend, Estimate, NativeBatch, NativeScalar, Request};
pub use cache::{CacheKey, CacheStats, GridCache};
pub use pjrt::{BatchPrediction, BatchServer, PjrtBackend, ServerStats};

use crate::baselines::Predictor;
use crate::model::{HwParams, KernelCounters};

/// One streaming job: predict a whole frequency grid for one profiled
/// kernel. `id` is echoed in the [`StreamReply`] so out-of-order
/// completions stay attributable.
#[derive(Debug, Clone)]
pub struct StreamJob {
    pub id: u64,
    pub counters: KernelCounters,
    pub pairs: Vec<(f64, f64)>,
}

/// Completion of one [`StreamJob`]. The error is stringly-typed because
/// replies cross a channel.
#[derive(Debug)]
pub struct StreamReply {
    pub id: u64,
    pub result: Result<Vec<Estimate>, String>,
}

/// Builder for [`Engine`] (backend choice, cache policy).
pub struct EngineBuilder {
    hw: HwParams,
    backend: Option<Arc<dyn Backend>>,
    cache: bool,
    cache_shards: usize,
    cache_shard_capacity: usize,
}

impl EngineBuilder {
    pub fn new(hw: HwParams) -> Self {
        EngineBuilder {
            hw,
            backend: None,
            cache: true,
            cache_shards: cache::DEFAULT_SHARDS,
            cache_shard_capacity: cache::DEFAULT_SHARD_CAPACITY,
        }
    }

    /// Use the scalar native backend (default).
    pub fn scalar(mut self) -> Self {
        self.backend = Some(Arc::new(NativeScalar::new(self.hw)) as Arc<dyn Backend>);
        self
    }

    /// Use the scoped-thread chunked native backend.
    pub fn batch(mut self, workers: usize) -> Self {
        self.backend = Some(Arc::new(NativeBatch::new(self.hw, workers)) as Arc<dyn Backend>);
        self
    }

    /// Use the sharded PJRT batching service.
    pub fn pjrt(mut self, server: BatchServer) -> Self {
        self.backend = Some(Arc::new(PjrtBackend::new(server)) as Arc<dyn Backend>);
        self
    }

    /// Use any baseline `Predictor` through the adapter.
    pub fn predictor(mut self, p: Box<dyn Predictor>) -> Self {
        self.backend = Some(Arc::new(PredictorBackend::new(p)) as Arc<dyn Backend>);
        self
    }

    /// Use a custom backend.
    pub fn backend(mut self, b: Arc<dyn Backend>) -> Self {
        self.backend = Some(b);
        self
    }

    /// Disable the grid cache (always recompute).
    pub fn without_cache(mut self) -> Self {
        self.cache = false;
        self
    }

    /// Override cache geometry.
    pub fn cache_geometry(mut self, shards: usize, shard_capacity: usize) -> Self {
        self.cache_shards = shards;
        self.cache_shard_capacity = shard_capacity;
        self
    }

    pub fn build(self) -> Engine {
        Engine {
            backend: self
                .backend
                .unwrap_or_else(|| Arc::new(NativeScalar::new(self.hw)) as Arc<dyn Backend>),
            cache: if self.cache {
                Some(Arc::new(GridCache::new(self.cache_shards, self.cache_shard_capacity)))
            } else {
                None
            },
            hw: self.hw,
        }
    }
}

/// The facade. Cheap to clone (`Arc` internals); clones share the
/// backend and the cache, so a cloned engine keeps the warm state.
#[derive(Clone)]
pub struct Engine {
    backend: Arc<dyn Backend>,
    cache: Option<Arc<GridCache>>,
    hw: HwParams,
}

impl Engine {
    pub fn builder(hw: HwParams) -> EngineBuilder {
        EngineBuilder::new(hw)
    }

    /// Scalar native backend with the default cache.
    pub fn native(hw: HwParams) -> Engine {
        Self::builder(hw).scalar().build()
    }

    /// Scoped-thread native backend with the default cache.
    pub fn native_batch(hw: HwParams, workers: usize) -> Engine {
        Self::builder(hw).batch(workers).build()
    }

    /// PJRT service backend (emulated executor, `workers` drain workers)
    /// with the default cache.
    pub fn pjrt_emulated(hw: HwParams, workers: usize) -> Result<Engine> {
        let (server, _handles) =
            BatchServer::start_emulated(hw.to_f32(), Duration::from_millis(1), workers)?;
        Ok(Self::builder(hw).pjrt(server).build())
    }

    /// Wrap a baseline predictor behind the facade (adapter + cache).
    pub fn from_predictor(hw: HwParams, p: Box<dyn Predictor>) -> Engine {
        Self::builder(hw).predictor(p).build()
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn hw(&self) -> &HwParams {
        &self.hw
    }

    /// Whether this engine memoizes grids (false after `without_cache`).
    pub fn has_cache(&self) -> bool {
        self.cache.is_some()
    }

    /// Cache counters. When the cache is disabled via
    /// [`EngineBuilder::without_cache`] this returns an **all-zero**
    /// `CacheStats` rather than an `Option`: the serving layer's
    /// `/metrics` exposition must emit the `service_cache_*` series
    /// unconditionally (a scraper that sees the line disappear when an
    /// operator flips `--no-cache` reads it as a broken exporter, not a
    /// configuration change). Zero hits / zero misses is also literally
    /// true for a disabled cache. Use [`Engine::has_cache`] to
    /// distinguish "disabled" from "enabled but untouched".
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.as_ref().map(|c| c.stats()).unwrap_or_default()
    }

    /// Predict one (kernel, frequency-pair) sample.
    pub fn predict_one(&self, c: &KernelCounters, core_mhz: f64, mem_mhz: f64) -> Result<Estimate> {
        let mut v = self.predict_grid(c, &[(core_mhz, mem_mhz)])?;
        Ok(v.remove(0))
    }

    /// Predict a whole frequency grid for one profile, serving repeats
    /// from the cache and batching only the misses to the backend.
    pub fn predict_grid(
        &self,
        c: &KernelCounters,
        pairs: &[(f64, f64)],
    ) -> Result<Vec<Estimate>> {
        let Some(cache) = &self.cache else {
            let reqs: Vec<Request> = pairs
                .iter()
                .map(|&(cf, mf)| Request { counters: *c, core_mhz: cf, mem_mhz: mf })
                .collect();
            return self.backend.predict_batch(&reqs);
        };

        let mut out: Vec<Option<Estimate>> = Vec::with_capacity(pairs.len());
        let mut miss_idx: Vec<usize> = Vec::new();
        let mut miss_reqs: Vec<Request> = Vec::new();
        let mut miss_keys: Vec<CacheKey> = Vec::new();
        for (i, &(cf, mf)) in pairs.iter().enumerate() {
            let key = CacheKey::new(c, &self.hw, cf, mf);
            match cache.get(&key) {
                Some(e) => out.push(Some(e)),
                None => {
                    out.push(None);
                    miss_idx.push(i);
                    miss_reqs.push(Request { counters: *c, core_mhz: cf, mem_mhz: mf });
                    miss_keys.push(key);
                }
            }
        }
        if !miss_reqs.is_empty() {
            let fresh = self.backend.predict_batch(&miss_reqs)?;
            for ((i, key), est) in miss_idx.into_iter().zip(miss_keys).zip(fresh) {
                cache.insert(key, est);
                out[i] = Some(est);
            }
        }
        Ok(out.into_iter().map(|e| e.expect("all pairs filled")).collect())
    }

    /// Streaming API: evaluate many grid jobs on a detached worker,
    /// delivering completions over a channel as they finish. The worker
    /// shares this engine's backend and cache, so streamed results warm
    /// the same cache the synchronous paths read.
    ///
    /// Jobs are evaluated in order on one worker — intra-job rows fan
    /// out to the backend's own parallelism (the PJRT service's N
    /// drain workers, `NativeBatch`'s scoped threads), and identical
    /// jobs dedupe through the cache deterministically. Callers that
    /// want cross-job concurrency clone the engine per stream (clones
    /// share the backend and the warm cache).
    pub fn predict_stream(&self, jobs: Vec<StreamJob>) -> Receiver<StreamReply> {
        let (tx, rx) = mpsc::channel();
        let engine = self.clone();
        std::thread::spawn(move || {
            for job in jobs {
                let result = engine
                    .predict_grid(&job.counters, &job.pairs)
                    .map_err(|e| format!("{e:#}"));
                if tx.send(StreamReply { id: job.id, result }).is_err() {
                    return; // receiver dropped; stop evaluating
                }
            }
        });
        rx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model;

    fn counters() -> KernelCounters {
        KernelCounters {
            l2_hr: 0.1,
            gld_trans: 6.0,
            avr_inst: 1.5,
            n_blocks: 128.0,
            wpb: 8.0,
            aw: 64.0,
            n_sm: 16.0,
            o_itrs: 8.0,
            i_itrs: 0.0,
            uses_smem: false,
            smem_conflict: 1.0,
            gld_body: 6.0,
            gld_edge: 0.0,
            mem_ops: 2.0,
            l1_hr: 0.0,
        }
    }

    fn grid() -> Vec<(f64, f64)> {
        crate::microbench::standard_grid()
    }

    #[test]
    fn facade_matches_scalar_model() {
        let hw = HwParams::paper_defaults();
        let engine = Engine::native(hw);
        let c = counters();
        for &(cf, mf) in &[(400.0, 1000.0), (700.0, 700.0), (1000.0, 400.0)] {
            let e = engine.predict_one(&c, cf, mf).unwrap();
            let want = model::predict(&c, &hw, cf, mf);
            assert_eq!(e.time_us.to_bits(), want.time_us.to_bits());
            assert_eq!(e.regime, Some(want.regime));
        }
    }

    #[test]
    fn warm_grid_is_bit_identical_and_counts_hits() {
        let hw = HwParams::paper_defaults();
        let engine = Engine::native(hw);
        let c = counters();
        let cold = engine.predict_grid(&c, &grid()).unwrap();
        let s0 = engine.cache_stats();
        assert_eq!(s0.misses, 49);
        assert_eq!(s0.hits, 0);
        let warm = engine.predict_grid(&c, &grid()).unwrap();
        let s1 = engine.cache_stats();
        assert!(s1.hits >= 49, "hits {}", s1.hits);
        for (a, b) in cold.iter().zip(&warm) {
            assert_eq!(a.time_us.to_bits(), b.time_us.to_bits());
            assert_eq!(a.t_active.to_bits(), b.t_active.to_bits());
            assert_eq!(a.t_exec_cycles.to_bits(), b.t_exec_cycles.to_bits());
            assert_eq!(a.regime, b.regime);
        }
    }

    #[test]
    fn without_cache_reports_zeroed_stats() {
        let hw = HwParams::paper_defaults();
        let engine = Engine::builder(hw).scalar().without_cache().build();
        let c = counters();
        engine.predict_grid(&c, &grid()).unwrap();
        // Disabled cache: stats are present (so `/metrics` always has
        // the series) but identically zero, and `has_cache` tells the
        // difference from an untouched live cache.
        assert!(!engine.has_cache());
        assert_eq!(engine.cache_stats(), CacheStats::default());
        let cached = Engine::native(hw);
        assert!(cached.has_cache());
        assert_eq!(cached.cache_stats(), CacheStats::default());
        cached.predict_grid(&c, &grid()).unwrap();
        assert_eq!(cached.cache_stats().misses, 49);
    }

    #[test]
    fn clones_share_the_warm_cache() {
        let hw = HwParams::paper_defaults();
        let engine = Engine::native(hw);
        let c = counters();
        engine.predict_grid(&c, &grid()).unwrap();
        let clone = engine.clone();
        clone.predict_grid(&c, &grid()).unwrap();
        assert!(clone.cache_stats().hits >= 49);
    }

    #[test]
    fn stream_replies_cover_all_jobs() {
        let hw = HwParams::paper_defaults();
        let engine = Engine::native(hw);
        let c = counters();
        let jobs: Vec<StreamJob> = (0..4)
            .map(|i| StreamJob { id: i, counters: c, pairs: grid() })
            .collect();
        let rx = engine.predict_stream(jobs);
        let mut seen = Vec::new();
        for reply in rx {
            let ests = reply.result.expect("native backend cannot fail");
            assert_eq!(ests.len(), 49);
            seen.push(reply.id);
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3]);
        // All four jobs share one profile: 49 misses, 3*49 hits.
        let s = engine.cache_stats();
        assert_eq!(s.misses, 49);
        assert_eq!(s.hits, 3 * 49);
    }

    #[test]
    fn mixed_hit_miss_grid_assembles_in_order() {
        let hw = HwParams::paper_defaults();
        let engine = Engine::native(hw);
        let c = counters();
        let small: Vec<(f64, f64)> = vec![(400.0, 400.0), (700.0, 700.0)];
        engine.predict_grid(&c, &small).unwrap();
        // Superset grid: 2 hits + 47 misses, order must match scalar.
        let full = engine.predict_grid(&c, &grid()).unwrap();
        for (e, &(cf, mf)) in full.iter().zip(&grid()) {
            let want = model::predict(&c, &hw, cf, mf);
            assert_eq!(e.time_us.to_bits(), want.time_us.to_bits(), "({cf},{mf})");
        }
    }
}
